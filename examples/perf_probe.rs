//! Perf probe: times the L3 hot paths (stencil engines, RTM steps,
//! derivative passes) — the measurement harness behind EXPERIMENTS.md
//! §Perf. Run after any optimization to check for regressions:
//! `cargo run --release --example perf_probe`
//!
//! Emits `BENCH_engines.json` (schema `metrics::bench_json`): per-engine
//! throughput, per-sweep heap-allocation counts (via the counting
//! global allocator below), and scratch-arena growth for star/box
//! r ∈ {1, 4}, plus the headline 256³ star-r4 interior-throughput
//! sweep.  CI runs a shrunken probe (env below) and uploads the JSON
//! as the perf-trajectory artifact; numbers are advisory, the schema
//! is validated.
//!
//! Env knobs: `PERF_PROBE_N` (grid edge, default 96), `PERF_PROBE_BIG_N`
//! (headline sweep edge, default 256; 0 skips), `PERF_PROBE_BUDGET_S`
//! (per-bench time budget, default 1.0), `BENCH_ENGINES_OUT` (output
//! path, default `BENCH_engines.json`).

use mmstencil::coordinator::scratch;
use mmstencil::grid::Grid3;
use mmstencil::metrics::bench_json::{self, EngineBench};
use mmstencil::rtm::{media, tti, vti};
use mmstencil::stencil::coeffs::{first_deriv, second_deriv};
use mmstencil::stencil::{matrix_unit, naive, simd, StencilSpec};
use mmstencil::util::alloc_count::CountingAlloc;
use mmstencil::util::bench::{bench_auto, report};

// Counting global allocator (shared impl with rust/tests/alloc_free.rs):
// the "allocation counts" column of the bench JSON.
#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Time `f`, then run one extra post-warm-up call under the allocation
/// counters, and record the entry.
#[allow(clippy::too_many_arguments)]
fn probe(
    entries: &mut Vec<EngineBench>,
    engine: &str,
    pattern: &str,
    radius: usize,
    n: usize,
    threads: usize,
    budget_s: f64,
    mut f: impl FnMut(),
) {
    let work = (n * n * n) as f64;
    let r = bench_auto(&format!("{engine:<16} {pattern}3d r{radius} {n}^3"), budget_s, &mut f);
    let (a0, g0) = (CountingAlloc::events(), scratch::grow_events());
    f();
    let allocs = CountingAlloc::events() - a0;
    let grows = scratch::grow_events() - g0;
    let mcells = work / r.median_s / 1e6;
    report(&r, &format!("{mcells:.1} Mcell/s  {allocs} allocs  {grows} arena-grows"));
    entries.push(EngineBench {
        engine: engine.into(),
        pattern: pattern.into(),
        radius,
        n,
        threads,
        mcells_per_s: mcells,
        allocs_per_sweep: allocs,
        arena_grows_per_sweep: grows,
    });
}

fn main() {
    let n = env_usize("PERF_PROBE_N", 96);
    let big_n = env_usize("PERF_PROBE_BIG_N", 256);
    let budget = env_f64("PERF_PROBE_BUDGET_S", 1.0);
    let threads = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(4);
    let dims = matrix_unit::BlockDims::default();
    let mut entries: Vec<EngineBench> = Vec::new();

    // ---- engine matrix: star/box, r ∈ {1, 4}, all engines ----
    let g = Grid3::random(n, n, n, 1);
    for (pattern, radius) in [("star", 1), ("star", 4), ("box", 1), ("box", 4)] {
        let spec = if pattern == "star" {
            StencilSpec::star3d(radius)
        } else {
            StencilSpec::box3d(radius)
        };
        probe(&mut entries, "naive", pattern, radius, n, 1, budget, || {
            std::hint::black_box(naive::apply3(&spec, &g));
        });
        probe(&mut entries, "simd", pattern, radius, n, 1, budget, || {
            std::hint::black_box(simd::apply3(&spec, &g));
        });
        probe(&mut entries, "matrix_unit", pattern, radius, n, 1, budget, || {
            std::hint::black_box(matrix_unit::apply3(&spec, &g, dims));
        });
        probe(&mut entries, "matrix_unit_par", pattern, radius, n, threads, budget, || {
            std::hint::black_box(matrix_unit::apply3_par(&spec, &g, dims, threads));
        });
    }

    // ---- headline interior-throughput sweep: star r4 at big_n³ ----
    if big_n > 0 {
        let spec = StencilSpec::star3d(4);
        let gb = Grid3::random(big_n, big_n, big_n, 2);
        probe(&mut entries, "simd", "star", 4, big_n, 1, budget, || {
            std::hint::black_box(simd::apply3(&spec, &gb));
        });
        probe(&mut entries, "matrix_unit_par", "star", 4, big_n, threads, budget, || {
            std::hint::black_box(matrix_unit::apply3_par(&spec, &gb, dims, threads));
        });
    }

    let out_path =
        std::env::var("BENCH_ENGINES_OUT").unwrap_or_else(|_| "BENCH_engines.json".into());
    let json = bench_json::render(&entries);
    bench_json::validate(&json).expect("BENCH_engines.json failed schema validation");
    std::fs::write(&out_path, &json).expect("writing BENCH_engines.json");
    println!("wrote {out_path} ({} entries)", entries.len());

    // ---- RTM steps (probe-only; not part of the engine JSON) ----
    let work = (n * n * n) as f64;
    let mid = n / 2;
    let m = media::layered_vti(n, n, n, 10.0, &media::default_layers());
    let w2 = second_deriv(4);
    let mut st = vti::VtiState::zeros(n, n, n);
    st.inject(mid, mid, mid, 1.0);
    let mut sc = vti::VtiScratch::new(n, n, n);
    let r = bench_auto(&format!("vti step {n}^3 (1 thread)"), budget, || {
        vti::step(&mut st, &m, &w2, 1, &mut sc)
    });
    report(&r, &format!("{:.1} Mcell/s", work / r.median_s / 1e6));

    let tm = media::layered_tti(n, n, n, 10.0, &media::default_layers());
    let trig = tti::TtiTrig::new(&tm);
    let w1 = first_deriv(4);
    let mut ts = tti::TtiState::zeros(n, n, n);
    ts.inject(mid, mid, mid, 1.0);
    let mut tsc = tti::TtiScratch::new(n, n, n);
    let r = bench_auto(&format!("tti step {n}^3 (1 thread)"), budget, || {
        tti::step(&mut ts, &tm, &trig, &w2, &w1, 1, &mut tsc)
    });
    report(&r, &format!("{:.1} Mcell/s", work / r.median_s / 1e6));

    // d2_axis per-axis breakdown
    for axis in 0..3 {
        let r = bench_auto(&format!("d2_axis axis={axis} {n}^3"), budget, || {
            std::hint::black_box(vti::d2_axis(&g, &w2, axis, 1));
        });
        report(&r, &format!("{:.1} Mcell/s", work / r.median_s / 1e6));
    }
}
