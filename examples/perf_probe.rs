//! Perf probe: times the L3 hot paths (stencil engines, RTM steps,
//! derivative passes) — the measurement harness behind EXPERIMENTS.md
//! §Perf. Run after any optimization to check for regressions:
//! `cargo run --release --example perf_probe`
//!
//! Every engine is exercised through the dispatch layer
//! (`stencil::Engine`, configured via `Engine::from_plan`) — no
//! per-engine closures — and emits `BENCH_engines.json` (schema
//! `metrics::bench_json` v7, every sweep/RTM row carrying the active
//! `TunePlan` string, its halo wire codec + transport byte count, and
//! every sweep row its wavefront tile geometry):
//! per-engine sweep throughput for star/box r ∈ {1, 4}, the headline
//! 256³ star-r4 sweep at temporal-blocking depths k ∈ {1, 2, 4}
//! (`Engine::apply3_fused` — the fused rows are the perf-trajectory
//! evidence for the deep-halo tentpole), the same headline workload
//! stepped through the in-rank (z, t) wavefront at fixed `(tile, wf)`
//! geometries (`coordinator::wavefront` via `Driver::with_wavefront` —
//! the PR 8 rows), and per-engine RTM step
//! throughput (VTI and TTI, classic `step_with` at depth 1 and the
//! fused `step_k_with` at depth 2), each with per-sweep/per-step
//! heap-allocation counts (counting global allocator below) and
//! scratch-arena growth.  A mini-survey through the shot service
//! (`rtm::service`) emits the v4 `survey_entries` rows — shots/hour
//! plus retry/failure accounting, with one injected-fault shot proving
//! the retry path end to end.  CI runs a shrunken probe (env below),
//! validates the schema, diffs against the committed baseline
//! (`scripts/bench_diff.py`, advisory), and uploads the JSON.
//!
//! Env knobs (documented in README §Perf trajectory):
//! * `PERF_PROBE_N` — engine-matrix / RTM grid edge (default 96)
//! * `PERF_PROBE_BIG_N` — headline sweep edge (default 256; 0 skips)
//! * `PERF_PROBE_SURVEY_SHOTS` — mini-survey shot count (default 4;
//!   0 skips the survey rows)
//! * `PERF_PROBE_SURVEY_N` — mini-survey grid edge (default 24)
//! * `PERF_PROBE_BUDGET_S` — per-bench time budget (default 1.0)
//! * `BENCH_ENGINES_OUT` — output path (default `BENCH_engines.json`)
//! * `MMSTENCIL_PROBE_ENGINES` — comma-separated row filter over the
//!   engine labels (`naive,simd,matrix_unit,matrix_gemm,
//!   matrix_unit_par,matrix_gemm_par`); unset runs everything.
//!   Filtered probes are for local iteration — CI needs the full set.
//! * `MMSTENCIL_PROBE_WAVEFRONTS` — comma-separated `tile:wf` pairs
//!   for the headline wavefront rows (e.g. `16:2,32:1`); unset runs
//!   the default fixed set, an empty value skips the rows.

use mmstencil::coordinator::scratch;
use mmstencil::grid::Grid3;
use mmstencil::metrics::bench_json::{self, EngineBench, RtmBench, SurveyBench};
use mmstencil::rtm::driver::{Medium, RtmConfig};
use mmstencil::rtm::service::{ShotJob, SurveyConfig, SurveyRunner};
use mmstencil::rtm::{media, tti, vti};
use mmstencil::simulator::Platform;
use mmstencil::stencil::coeffs::{first_deriv, second_deriv};
use mmstencil::stencil::{Engine, EngineKind, StencilSpec, TunePlan};
use mmstencil::util::alloc_count::CountingAlloc;
use mmstencil::util::bench::{bench_auto, report};

// Counting global allocator (shared impl with rust/tests/alloc_free.rs):
// the "allocation counts" column of the bench JSON.
#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// `MMSTENCIL_PROBE_ENGINES` row filter: `None` = run everything,
/// `Some(list)` = run only the named engine labels.
fn engine_filter() -> Option<Vec<String>> {
    let v = std::env::var("MMSTENCIL_PROBE_ENGINES").ok()?;
    let list: Vec<String> = v
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if list.is_empty() {
        None
    } else {
        Some(list)
    }
}

fn wants(filter: &Option<Vec<String>>, label: &str) -> bool {
    filter.as_ref().map_or(true, |f| f.iter().any(|e| e == label))
}

/// `MMSTENCIL_PROBE_WAVEFRONTS` geometry list (`tile:wf` pairs) for the
/// headline wavefront rows; unset = the default fixed set, an empty or
/// unparsable value skips the rows.  Mirrors the engine filter above:
/// env-selectable for local iteration, defaults for CI.
fn wavefront_geometries() -> Vec<(usize, usize)> {
    match std::env::var("MMSTENCIL_PROBE_WAVEFRONTS") {
        Err(_) => vec![(16, 2), (32, 1)],
        Ok(v) => v
            .split(',')
            .filter(|s| !s.trim().is_empty())
            .filter_map(|s| {
                let (t, w) = s.trim().split_once(':')?;
                Some((t.trim().parse().ok()?, w.trim().parse::<usize>().ok()?.max(1)))
            })
            .collect(),
    }
}

/// Plan for `kind` at a parallelism/depth — every probed engine is
/// configured through this, and its `Display` form is the v5 `plan`
/// column.
fn plan_for(kind: EngineKind, threads: usize, time_block: usize) -> TunePlan {
    TunePlan { engine: kind, threads, time_block, ..TunePlan::simd(1) }
}

/// Time `f`, then run one extra post-warm-up call under the allocation
/// counters; returns (mcells/s, allocs, arena grows) for `work` cells.
fn timed(label: &str, work: f64, budget_s: f64, mut f: impl FnMut()) -> (f64, u64, u64) {
    let r = bench_auto(label, budget_s, &mut f);
    let (a0, g0) = (CountingAlloc::events(), scratch::grow_events());
    f();
    let allocs = CountingAlloc::events() - a0;
    let grows = scratch::grow_events() - g0;
    let mcells = work / r.median_s / 1e6;
    report(&r, &format!("{mcells:.1} Mcell/s  {allocs} allocs  {grows} arena-grows"));
    (mcells, allocs, grows)
}

/// One engine × sweep workload through the dispatch layer, at a given
/// temporal-blocking depth (`time_block` fused sweeps per call).
#[allow(clippy::too_many_arguments)]
fn probe_sweep(
    entries: &mut Vec<EngineBench>,
    label: &str,
    plan: &TunePlan,
    spec: &StencilSpec,
    pattern: &str,
    g: &Grid3,
    budget_s: f64,
) {
    let n = g.nz;
    let eng = Engine::from_plan(plan);
    let time_block = plan.time_block.max(1);
    let (mcells, allocs, grows) = timed(
        &format!("{label:<16} {pattern}3d r{} {n}^3 k{time_block}", spec.radius),
        (time_block * n * n * n) as f64,
        budget_s,
        || {
            std::hint::black_box(eng.apply3_fused(spec, g, time_block));
        },
    );
    entries.push(EngineBench {
        engine: label.into(),
        pattern: pattern.into(),
        radius: spec.radius,
        n,
        threads: eng.threads,
        time_block,
        tile: plan.tile,
        wf: plan.wf.max(1),
        // periodic single-rank sweeps never touch the wire
        halo_codec: plan.halo.name().into(),
        transport_bytes: 0,
        mcells_per_s: mcells,
        allocs_per_sweep: allocs,
        arena_grows_per_sweep: grows,
        plan: plan.to_string(),
    });
}

fn main() {
    let n = env_usize("PERF_PROBE_N", 96);
    let big_n = env_usize("PERF_PROBE_BIG_N", 256);
    let budget = env_f64("PERF_PROBE_BUDGET_S", 1.0);
    let threads = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(4);
    let filter = engine_filter();
    let mut entries: Vec<EngineBench> = Vec::new();
    let mut rtm_entries: Vec<RtmBench> = Vec::new();

    // ---- engine matrix: star/box, r ∈ {1, 4}, all engines + par ----
    let g = Grid3::random(n, n, n, 1);
    for (pattern, radius) in [("star", 1), ("star", 4), ("box", 1), ("box", 4)] {
        let spec = if pattern == "star" {
            StencilSpec::star3d(radius)
        } else {
            StencilSpec::box3d(radius)
        };
        for kind in EngineKind::ALL {
            if !wants(&filter, kind.name()) {
                continue;
            }
            probe_sweep(&mut entries, kind.name(), &plan_for(kind, 1, 1), &spec, pattern, &g, budget);
        }
        for (label, kind) in [
            ("matrix_unit_par", EngineKind::MatrixUnit),
            ("matrix_gemm_par", EngineKind::MatrixGemm),
        ] {
            if wants(&filter, label) {
                probe_sweep(&mut entries, label, &plan_for(kind, threads, 1), &spec, pattern, &g, budget);
            }
        }
    }

    // ---- headline interior-throughput sweep: star r4 at big_n³, at
    // temporal-blocking depths 1/2/4 (the tentpole's Mcells/s evidence:
    // fused sweeps amortize the output allocation + keep the
    // destination hot, so k > 1 must not be slower per update) ----
    if big_n > 0 {
        let spec = StencilSpec::star3d(4);
        let gb = Grid3::random(big_n, big_n, big_n, 2);
        if wants(&filter, "simd") {
            probe_sweep(&mut entries, "simd", &plan_for(EngineKind::Simd, 1, 1), &spec, "star", &gb, budget);
        }
        for (label, kind) in [
            ("matrix_unit_par", EngineKind::MatrixUnit),
            ("matrix_gemm_par", EngineKind::MatrixGemm),
        ] {
            if wants(&filter, label) {
                for k in [1usize, 2, 4] {
                    probe_sweep(&mut entries, label, &plan_for(kind, threads, k), &spec, "star", &gb, budget);
                }
            }
        }

        // ---- headline wavefront rows (schema v6): the same star-r4
        // workload stepped as in-rank (z, t) wavefront tiles through
        // the dependency ledger (`coordinator::wavefront`) at fixed,
        // env-selectable geometries — k = 4 fused sub-steps per
        // exchange round; the tile=0 fused rows above are the classic
        // baseline these diff against ----
        let wavefronts = wavefront_geometries();
        if !wavefronts.is_empty() {
            use mmstencil::coordinator::driver::Driver;
            use mmstencil::coordinator::exchange::Backend;
            use mmstencil::grid::CartDecomp;
            let k = 4usize;
            let dec = CartDecomp::new(1, 1, 2);
            for (label, kind) in [
                ("matrix_unit_par", EngineKind::MatrixUnit),
                ("matrix_gemm_par", EngineKind::MatrixGemm),
            ] {
                if !wants(&filter, label) {
                    continue;
                }
                for &(tile, wf) in &wavefronts {
                    let plan = TunePlan { tile, wf, ..plan_for(kind, threads, k) };
                    let drv = Driver::new(threads, Platform::paper()).with_plan(&plan);
                    let mut wire_bytes = 0u64;
                    let (mcells, allocs, grows) = timed(
                        &format!("{label:<16} star3d r4 {big_n}^3 k{k} tile{tile} wf{wf}"),
                        (k * big_n * big_n * big_n) as f64,
                        budget,
                        || {
                            let (out, stats) =
                                drv.multirank_sweep(&spec, &gb, &dec, &Backend::sdma(), k);
                            wire_bytes = stats.exchanged_bytes;
                            std::hint::black_box(out);
                        },
                    );
                    entries.push(EngineBench {
                        engine: label.into(),
                        pattern: "star".into(),
                        radius: spec.radius,
                        n: big_n,
                        threads,
                        time_block: k,
                        tile,
                        wf,
                        halo_codec: plan.halo.name().into(),
                        transport_bytes: wire_bytes,
                        mcells_per_s: mcells,
                        allocs_per_sweep: allocs,
                        arena_grows_per_sweep: grows,
                        plan: plan.to_string(),
                    });
                }
            }
        }
    }

    // ---- RTM steps per engine (the v2 application rows) ----
    let work = (n * n * n) as f64;
    let mid = n / 2;
    let w2 = second_deriv(4);
    let w1 = first_deriv(4);
    let vm = media::layered_vti(n, n, n, 10.0, &media::default_layers());
    let tm = media::layered_tti(n, n, n, 10.0, &media::default_layers());
    let trig = tti::TtiTrig::new(&tm);
    for kind in EngineKind::ALL {
        if !wants(&filter, kind.name()) {
            continue;
        }
        // k = 1 is the classic per-step row; k = 2 measures the fused
        // boundary-free entry (step_k_with) so the RTM trajectory is
        // diffable per depth like the sweep rows
        for k in [1usize, 2] {
            let plan = plan_for(kind, threads, k);
            let eng = Engine::from_plan(&plan);
            let kwork = k as f64 * work;
            {
                let mut st = vti::VtiState::zeros(n, n, n);
                let mut sc = vti::VtiScratch::new(n, n, n);
                st.inject(mid, mid, mid, 1.0);
                let (mcells, allocs, grows) = timed(
                    &format!("rtm vti {:<12} {n}^3 x{threads} k{k}", kind.name()),
                    kwork,
                    budget,
                    || vti::step_k_with(&mut st, &vm, &w2, &eng, &mut sc, k),
                );
                rtm_entries.push(RtmBench {
                    engine: kind.name().into(),
                    medium: "vti".into(),
                    n,
                    threads,
                    time_block: k,
                    // single-rank steps: lossless codec, nothing on the wire
                    halo_codec: plan.halo.name().into(),
                    transport_bytes: 0,
                    mcells_per_s: mcells,
                    allocs_per_step: allocs,
                    arena_grows_per_step: grows,
                    plan: plan.to_string(),
                });
            }
            {
                let mut st = tti::TtiState::zeros(n, n, n);
                let mut sc = tti::TtiScratch::new(n, n, n);
                st.inject(mid, mid, mid, 1.0);
                let (mcells, allocs, grows) = timed(
                    &format!("rtm tti {:<12} {n}^3 x{threads} k{k}", kind.name()),
                    kwork,
                    budget,
                    || tti::step_k_with(&mut st, &tm, &trig, &w2, &w1, &eng, &mut sc, k),
                );
                rtm_entries.push(RtmBench {
                    engine: kind.name().into(),
                    medium: "tti".into(),
                    n,
                    threads,
                    time_block: k,
                    halo_codec: plan.halo.name().into(),
                    transport_bytes: 0,
                    mcells_per_s: mcells,
                    allocs_per_step: allocs,
                    arena_grows_per_step: grows,
                    plan: plan.to_string(),
                });
            }
        }
    }

    // ---- mini-survey through the shot service (the v4 rows): shots
    // sweep a source line, one shot carries an injected fault so the
    // emitted retry count proves the retry path end to end ----
    let mut survey_entries: Vec<SurveyBench> = Vec::new();
    let survey_shots = env_usize("PERF_PROBE_SURVEY_SHOTS", 4);
    if survey_shots > 0 {
        let sn = env_usize("PERF_PROBE_SURVEY_N", 24);
        for medium in [Medium::Vti, Medium::Tti] {
            let mut cfg = RtmConfig::small(medium);
            cfg.nz = sn;
            cfg.nx = sn;
            cfg.ny = sn;
            cfg.steps = 24;
            cfg.threads = 2;
            cfg.engine = EngineKind::MatrixUnit;
            let scfg = SurveyConfig::default();
            let mut runner = SurveyRunner::new(scfg, &Platform::paper())
                .expect("default survey config is valid");
            let (sz, _, sy) = cfg.src_pos();
            let lo = cfg.sponge_width + 1;
            let hi = (sn - cfg.sponge_width).saturating_sub(2).max(lo);
            let jobs: Vec<ShotJob> = (0..survey_shots)
                .map(|s| {
                    let sx = lo + (hi - lo) * s / (survey_shots - 1).max(1);
                    let b = ShotJob::builder(cfg.clone()).src(sz, sx, sy);
                    // shot 0 fails once and must succeed on the retry
                    let b = if s == 0 { b.inject_faults(1) } else { b };
                    b.build().expect("probe survey config is valid")
                })
                .collect();
            let rep = runner.run(jobs);
            assert_eq!(rep.failed(), 0, "probe survey shots must all complete");
            assert_eq!(rep.retries(), 1, "the injected fault must consume one retry");
            println!(
                "survey {:?} {survey_shots} shots / {} shards: {:.0} shots/hour ({} retried)",
                medium,
                rep.shards,
                rep.shots_per_hour(),
                rep.retries()
            );
            survey_entries.push(SurveyBench {
                engine: cfg.engine.name().into(),
                medium: if medium == Medium::Tti { "tti" } else { "vti" }.into(),
                n: sn,
                shots: survey_shots,
                shards: rep.shards,
                threads: cfg.threads,
                checkpoint: rep.checkpoint.name().into(),
                retries: rep.retries() as u64,
                failed: rep.failed() as u64,
                faults_injected: rep.faults_injected(),
                resumed_shots: rep.resumed_shots() as u64,
                shots_per_hour: rep.shots_per_hour(),
            });
        }
    }

    let out_path =
        std::env::var("BENCH_ENGINES_OUT").unwrap_or_else(|_| "BENCH_engines.json".into());
    let json = bench_json::render(&entries, &rtm_entries, &survey_entries);
    bench_json::validate(&json).expect("BENCH_engines.json failed schema validation");
    std::fs::write(&out_path, &json).expect("writing BENCH_engines.json");
    println!(
        "wrote {out_path} ({} sweep entries, {} rtm entries, {} survey entries)",
        entries.len(),
        rtm_entries.len(),
        survey_entries.len()
    );

    // ---- d2_axis per-axis breakdown (probe-only) ----
    if wants(&filter, "simd") {
        let simd = Engine::new(EngineKind::Simd);
        for axis in 0..3 {
            let r = bench_auto(&format!("d2_axis axis={axis} {n}^3"), budget, || {
                std::hint::black_box(simd.d2_axis(&g, &w2, axis));
            });
            report(&r, &format!("{:.1} Mcell/s", work / r.median_s / 1e6));
        }
    }
}
