//! Perf probe: times the L3 hot paths (stencil engines, RTM steps,
//! derivative passes) — the measurement harness behind EXPERIMENTS.md
//! §Perf. Run after any optimization to check for regressions:
//! `cargo run --release --example perf_probe`
use mmstencil::grid::Grid3;
use mmstencil::rtm::{media, vti, tti};
use mmstencil::stencil::coeffs::{first_deriv, second_deriv};
use mmstencil::stencil::{matrix_unit, simd, naive, StencilSpec};
use mmstencil::util::bench::{bench_auto, report};

fn main() {
    let n = 96;
    let g = Grid3::random(n, n, n, 1);
    let spec = StencilSpec::star3d(4);
    let work = (n * n * n) as f64;

    let r = bench_auto("naive star3d r4 96^3", 2.0, || {
        std::hint::black_box(naive::apply3(&spec, &g));
    });
    report(&r, &format!("{:.1} Mcell/s", work / r.median_s / 1e6));
    let r = bench_auto("simd  star3d r4 96^3", 2.0, || {
        std::hint::black_box(simd::apply3(&spec, &g));
    });
    report(&r, &format!("{:.1} Mcell/s", work / r.median_s / 1e6));
    let dims = matrix_unit::BlockDims::default();
    let r = bench_auto("mxu   star3d r4 96^3", 2.0, || {
        std::hint::black_box(matrix_unit::apply3(&spec, &g, dims));
    });
    report(&r, &format!("{:.1} Mcell/s", work / r.median_s / 1e6));

    let bspec = StencilSpec::box3d(2);
    let r = bench_auto("simd  box3d r2 96^3", 2.0, || {
        std::hint::black_box(simd::apply3(&bspec, &g));
    });
    report(&r, &format!("{:.1} Mcell/s", work / r.median_s / 1e6));
    let r = bench_auto("mxu   box3d r2 96^3", 2.0, || {
        std::hint::black_box(matrix_unit::apply3(&bspec, &g, dims));
    });
    report(&r, &format!("{:.1} Mcell/s", work / r.median_s / 1e6));

    // RTM steps
    let m = media::layered_vti(n, n, n, 10.0, &media::default_layers());
    let w2 = second_deriv(4);
    let mut st = vti::VtiState::zeros(n, n, n);
    st.inject(48, 48, 48, 1.0);
    let mut sc = vti::VtiScratch::new(n, n, n);
    let r = bench_auto("vti step 96^3 (1 thread)", 2.0, || vti::step(&mut st, &m, &w2, 1, &mut sc));
    report(&r, &format!("{:.1} Mcell/s", work / r.median_s / 1e6));

    let tm = media::layered_tti(n, n, n, 10.0, &media::default_layers());
    let trig = tti::TtiTrig::new(&tm);
    let w1 = first_deriv(4);
    let mut ts = tti::TtiState::zeros(n, n, n);
    ts.inject(48, 48, 48, 1.0);
    let mut tsc = tti::TtiScratch::new(n, n, n);
    let r = bench_auto("tti step 96^3 (1 thread)", 3.0, || {
        tti::step(&mut ts, &tm, &trig, &w2, &w1, 1, &mut tsc)
    });
    report(&r, &format!("{:.1} Mcell/s", work / r.median_s / 1e6));

    // d2_axis per-axis breakdown
    for axis in 0..3 {
        let r = bench_auto(&format!("d2_axis axis={axis} 96^3"), 1.5, || {
            std::hint::black_box(vti::d2_axis(&g, &w2, axis, 1));
        });
        report(&r, &format!("{:.1} Mcell/s", work / r.median_s / 1e6));
    }
}
