//! End-to-end RTM validation run (EXPERIMENTS.md §End-to-end).
//!
//! Drives the FULL stack on a real (small) seismic imaging workload:
//!
//! * synthetic 3-layer VTI earth model, 128×96×96 cells, r = 4 stencils;
//! * 15 Hz Ricker shot, 240 forward steps with surface recording and
//!   snapshot checkpointing, 240 backward steps with trace re-injection,
//!   zero-lag imaging condition with illumination normalization;
//! * one timestep cross-checked bit-tight against the AOT PJRT artifact
//!   `rtm_vti_r4_grid64` (the L1/L2 JAX path) — proving the rust L3
//!   propagator and the Pallas/JAX kernels compute the same physics;
//! * reports host throughput, the energy trace, and the simulated
//!   paper-platform metrics (util %, speedup vs SIMD baseline).
//!
//! Run with: `cargo run --release --example rtm_end_to_end`

use mmstencil::grid::Grid3;
use mmstencil::rtm::driver::{Medium, RtmConfig};
use mmstencil::rtm::service::{ShotJob, SurveyConfig, SurveyRunner};
use mmstencil::rtm::{media, vti};
use mmstencil::runtime::{Runtime, Tensor};
use mmstencil::simulator::Platform;
use mmstencil::stencil::coeffs::second_deriv;
use mmstencil::stencil::EngineKind;
use mmstencil::util::err::{Context, Result};
use mmstencil::util::Timer;

fn main() -> Result<()> {
    // ---- 1. cross-check one VTI step against the PJRT artifact ------------
    let rt = Runtime::open_default()?;
    let n = 64usize;
    let m = media::layered_vti(n, n, n, 10.0, &media::default_layers());
    let mut st = vti::VtiState::zeros(n, n, n);
    st.inject(32, 32, 32, 1.0);
    // a couple of warmup steps so the field is non-trivial
    let w2 = second_deriv(4);
    let mut sc = vti::VtiScratch::new(n, n, n);
    for _ in 0..3 {
        vti::step(&mut st, &m, &w2, 1, &mut sc);
    }
    let shape = vec![n, n, n];
    let t = |g: &Grid3| Tensor::new(shape.clone(), g.as_slice().to_vec());
    let outs = rt.execute(
        "rtm_vti_r4_grid64",
        &[
            t(&st.sh),
            t(&st.sv),
            t(&st.sh_prev),
            t(&st.sv_prev),
            t(&m.vp2dt2),
            t(&m.eps),
            t(&m.delta),
        ],
    )?;
    let mut rust_next = vti::VtiState {
        sh: st.sh.clone(),
        sv: st.sv.clone(),
        sh_prev: st.sh_prev.clone(),
        sv_prev: st.sv_prev.clone(),
    };
    vti::step(&mut rust_next, &m, &w2, 1, &mut sc);
    let err_h = max_err(&outs[0].data, rust_next.sh.as_slice());
    let err_v = max_err(&outs[1].data, rust_next.sv.as_slice());
    println!("L3-rust vs L1/L2-PJRT one VTI step @64³: max|Δ| sh={err_h:.2e} sv={err_v:.2e}");
    assert!(err_h < 1e-3 && err_v < 1e-3, "rust/JAX physics mismatch");

    // ---- 2. the full shot ---------------------------------------------------
    let cfg = RtmConfig {
        medium: Medium::Vti,
        nz: 96,
        nx: 80,
        ny: 80,
        dx: 10.0,
        steps: 640,
        f0: 15.0,
        threads: std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1),
        snap_every: 4,
        sponge_width: 10,
        src: None,
        receiver_z: 3,
        // the paper's application claim: propagate through the
        // matrix-unit engine, not the SIMD baseline
        engine: EngineKind::MatrixUnit,
        // shots clamp temporal blocking to 1 anyway (§III-B: the
        // sponge + per-step recording bound the fusable depth)
        time_block: 1,
    };
    println!(
        "\nRTM shot: {}×{}×{} VTI r=4, {} fwd + {} bwd steps, {} engine …",
        cfg.nz,
        cfg.nx,
        cfg.ny,
        cfg.steps,
        cfg.steps,
        cfg.engine.name()
    );
    let timer = Timer::start();
    let p = Platform::paper();
    // validated job + one-shot survey session: the service API behind
    // the old run_shot free function
    let job = ShotJob::builder(cfg.clone()).build().context("building the shot job")?;
    let mut runner =
        SurveyRunner::new(SurveyConfig::one_shot(), &p).context("starting the survey session")?;
    let (image, rep) = runner.run_one(job)?;
    let total = timer.secs();

    // energy trace: quiet start, source build-up, then bounded
    let peak_e = rep.energy_trace.iter().cloned().fold(0.0f64, f64::max);
    let final_e = *rep.energy_trace.last().unwrap();
    println!("  wall {total:.1}s  ({:.3} Gpoint/s)", rep.gpoints_per_s / 1e9);
    println!(
        "  energy: peak {peak_e:.3e}, final {final_e:.3e} (sponge-absorbed {:.0}%)",
        (1.0 - final_e / peak_e) * 100.0
    );
    println!(
        "  receivers: max amplitude {:.3e}; image energy {:.3e} ({} correlations)",
        rep.max_trace, rep.image_energy, image.correlations
    );
    let norm = image.normalized();
    // the strongest reflector in the normalized image should sit near a
    // layer boundary (z ≈ 0.4·nz = 38 or 0.75·nz = 72)
    // standard shallow mute: exclude the source/receiver near-field
    // (low-wavenumber RTM backscatter artifact) before picking
    let mute = 25usize;
    let (mut best_z, mut best_v) = (0usize, 0.0f32);
    for z in mute..cfg.nz - cfg.sponge_width {
        let mut row_max = 0.0f32;
        for x in cfg.nx / 4..3 * cfg.nx / 4 {
            for y in cfg.ny / 4..3 * cfg.ny / 4 {
                row_max = row_max.max(norm.get(z, x, y).abs());
            }
        }
        if row_max > best_v {
            best_v = row_max;
            best_z = z;
        }
    }
    println!("  strongest image response at z = {best_z} (layer boundaries at z≈38, z≈72)");
    println!(
        "\npaper-platform projection: {:.1}% bandwidth util, {:.2}× vs industrial SIMD baseline",
        rep.sim_bandwidth_util * 100.0,
        rep.sim_speedup_vs_simd()
    );
    assert!(rep.energy_trace.iter().all(|e| e.is_finite()), "instability detected");
    assert!(rep.image_energy > 0.0, "no image formed");
    println!("END-TO-END: OK");
    Ok(())
}

fn max_err(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}
