//! Fig. 15 companion run: RTM *survey* scaling across simulated NUMA
//! rank shards, driven end to end through the shot service
//! (`rtm::service::SurveyRunner`).
//!
//! The bench of the same name (`benches/fig15_rtm_scaling.rs`) covers
//! the halo-exchange scaling of one decomposed shot; this example
//! covers the orthogonal axis the paper's survey workload scales on:
//! many independent shots pipelined across rank shards.  For each shard
//! count it runs the SAME shot line through a fresh session and checks
//!
//! * every shot completes (no retries expected — no injected faults);
//! * the accumulated image is **bitwise identical** across shard
//!   counts (the tree reduction's shape depends only on the shot
//!   count, never on scheduling);
//! * the image energy is positive (a real image formed);
//! * shots/hour is reported per shard count — the throughput axis.
//!
//! Env knobs: `FIG15_SHOTS` (default 8), `FIG15_N` (grid edge, default
//! 24), `FIG15_STEPS` (default 24).
//!
//! Run with: `cargo run --release --example fig15_rtm_scaling`

use mmstencil::rtm::driver::{Medium, RtmConfig};
use mmstencil::rtm::service::{ShotJob, SurveyConfig, SurveyRunner};
use mmstencil::simulator::Platform;
use mmstencil::stencil::EngineKind;
use mmstencil::util::table::{f, Table};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn shot_line(cfg: &RtmConfig, shots: usize) -> Vec<ShotJob> {
    let (sz, _, sy) = cfg.src_pos();
    let lo = cfg.sponge_width + 1;
    let hi = (cfg.nx - cfg.sponge_width).saturating_sub(2).max(lo);
    (0..shots)
        .map(|s| {
            let sx = lo + (hi - lo) * s / shots.saturating_sub(1).max(1);
            ShotJob::builder(cfg.clone())
                .src(sz, sx, sy)
                .build()
                .expect("fig15 shot config is valid")
        })
        .collect()
}

fn main() {
    let shots = env_usize("FIG15_SHOTS", 8).max(2);
    let n = env_usize("FIG15_N", 24);
    let steps = env_usize("FIG15_STEPS", 24);
    let p = Platform::paper();

    let mut cfg = RtmConfig::small(Medium::Vti);
    cfg.nz = n;
    cfg.nx = n;
    cfg.ny = n;
    cfg.steps = steps;
    cfg.threads = 2;
    cfg.engine = EngineKind::MatrixUnit;

    println!(
        "RTM survey scaling: {shots} VTI shots at {n}³ × {steps} steps, \
         matrix_unit engine, full-state checkpoints\n"
    );
    let mut t =
        Table::new(&["shards", "workers", "stolen", "wall s", "shots/hour", "image energy"]);
    let mut reference: Option<Vec<f32>> = None;
    for shards in [1usize, 2, 4] {
        let mut scfg = SurveyConfig::default();
        scfg.shards = shards;
        scfg.queue_capacity = 2; // small bound: exercises backpressure
        let mut runner = SurveyRunner::new(scfg, &p).expect("survey config is valid");
        let report = runner.run(shot_line(&cfg, shots));
        assert_eq!(
            report.completed(),
            shots,
            "{shards} shard(s): every shot must complete"
        );
        assert_eq!(report.retries(), 0, "no faults injected, no retries expected");
        let image = report.image.as_ref().expect("completed survey has an image");
        assert!(image.img.energy() > 0.0, "no image formed");
        match &reference {
            None => reference = Some(image.img.data.clone()),
            Some(r) => assert_eq!(
                &image.img.data, r,
                "{shards} shard(s): survey image must be bitwise-stable across shard counts"
            ),
        }
        t.row(&[
            shards.to_string(),
            runner.workers().to_string(),
            report.stolen().to_string(),
            f(report.wall_s, 2),
            f(report.shots_per_hour(), 0),
            format!("{:.3e}", image.img.energy()),
        ]);
    }
    t.print();
    println!("\nsurvey image bitwise-stable across 1/2/4 shards: OK");
}
