//! Stencil gallery: every Table-I benchmark kernel through every engine.
//!
//! For each of the eight kernels this runs the compiler-baseline
//! (naive), the hand-SIMD stand-in, the matrix-unit emulation engine,
//! and — where an artifact exists — the Pallas block kernel via PJRT,
//! verifying they all agree, then prints the per-kernel instruction-mix
//! and the simulated paper-platform utilization (a Fig. 11 preview).
//!
//! Run with: `cargo run --release --example stencil_gallery`

use mmstencil::grid::{Grid2, Grid3};
use mmstencil::runtime::{Runtime, Tensor};
use mmstencil::simulator::roofline::{self, Engine, MemKind};
use mmstencil::simulator::Platform;
use mmstencil::stencil::{matrix_unit, naive, simd, StencilSpec};
use mmstencil::util::table::{f, Table};

fn main() {
    let p = Platform::paper();
    let rt = Runtime::open_default().ok();
    let dims = matrix_unit::BlockDims::default();
    let mut t = Table::new(&[
        "kernel", "points", "bound", "naive=simd", "naive=matrix", "pjrt block",
        "outer-products/pt", "sim util %", "sim vs SIMD",
    ]);

    for (name, spec) in StencilSpec::benchmark_suite() {
        let (agree_simd, agree_mm, counts, n_cells) = if spec.ndim == 3 {
            let g = Grid3::random(12, 32, 32, 7);
            let want = naive::apply3(&spec, &g);
            let simd_out = simd::apply3(&spec, &g);
            let (mm_out, counts) = matrix_unit::apply3(&spec, &g, dims);
            (
                want.max_abs_diff(&simd_out),
                want.max_abs_diff(&mm_out),
                counts,
                g.len(),
            )
        } else {
            let g = Grid2::random(64, 64, 7);
            let want = naive::apply2(&spec, &g);
            let simd_out = simd::apply2(&spec, &g);
            let (mm_out, counts) = matrix_unit::apply2(&spec, &g, dims);
            (
                want.max_abs_diff(&simd_out),
                want.max_abs_diff(&mm_out),
                counts,
                g.len(),
            )
        };
        assert!(agree_simd < 1e-3 && agree_mm < 1e-3, "{name}: engines disagree");

        // PJRT block artifact check (block kernels exist for all eight)
        let art = artifact_name(name);
        let pjrt = match &rt {
            Some(rt) => check_block(rt, &art, &spec)
                .map(|e| format!("{e:.1e}"))
                .unwrap_or("-".into()),
            None => "-".into(),
        };

        let n512 = if spec.ndim == 3 { 512usize.pow(3) } else { 8192usize.pow(2) };
        let mm_cfg = roofline::engine_cfg(Engine::MMStencil, MemKind::OnPkg);
        let mm = roofline::predict(&spec, n512, Engine::MMStencil, mm_cfg, &p);
        let sd_cfg = roofline::engine_cfg(Engine::Simd, MemKind::OnPkg);
        let sd = roofline::predict(&spec, n512, Engine::Simd, sd_cfg, &p);
        t.row(&[
            name.to_string(),
            spec.points().to_string(),
            format!("{}", mm.bound),
            format!("{agree_simd:.1e}"),
            format!("{agree_mm:.1e}"),
            pjrt,
            f(counts.outer_products as f64 / n_cells as f64, 2),
            f(mm.bandwidth_util * 100.0, 1),
            format!("{:.2}x", sd.time_s / mm.time_s),
        ]);
    }
    t.print();
    println!(
        "\n(sim columns are the paper-platform projection; Fig. 11 shape:\n SIMD wins 3DStarR2, MMStencil wins high-order, box gains biggest.)"
    );
}

fn artifact_name(kernel: &str) -> String {
    // "3DStarR4" → "star3d_r4_block"
    let (dim, rest) = kernel.split_at(2);
    let dim = dim.to_lowercase();
    let (pat, r) = rest.split_at(rest.len() - 2);
    format!("{}{}_{}_block", pat.to_lowercase(), dim, r.to_lowercase())
}

/// Run the Pallas block artifact on random data; return max error vs the
/// rust naive oracle, or None if the artifact is unavailable.
fn check_block(rt: &Runtime, art: &str, spec: &StencilSpec) -> Option<f32> {
    let meta = rt.manifest.get(art)?.clone();
    let ishape = meta.inputs[0].shape.clone();
    let r = spec.radius;
    let out = if spec.ndim == 3 {
        let halo = Grid3::random(ishape[0], ishape[1], ishape[2], 3);
        let got = rt.execute(art, &[Tensor::new(ishape.clone(), halo.as_slice().to_vec())]).ok()?;
        let oracle = naive::apply3(spec, &halo);
        let (oz, ox, oy) = (ishape[0] - 2 * r, ishape[1] - 2 * r, ishape[2] - 2 * r);
        let mut err = 0.0f32;
        for z in 0..oz {
            for x in 0..ox {
                for y in 0..oy {
                    let want = oracle.get(z + r, x + r, y + r);
                    err = err.max((want - got[0].data[(z * ox + x) * oy + y]).abs());
                }
            }
        }
        err
    } else {
        let halo = Grid2::random(ishape[0], ishape[1], 3);
        let got = rt.execute(art, &[Tensor::new(ishape.clone(), halo.as_slice().to_vec())]).ok()?;
        let oracle = naive::apply2(spec, &halo);
        let (ox, oy) = (ishape[0] - 2 * r, ishape[1] - 2 * r);
        let mut err = 0.0f32;
        for x in 0..ox {
            for y in 0..oy {
                err = err.max((oracle.get(x + r, y + r) - got[0].data[x * oy + y]).abs());
            }
        }
        err
    };
    Some(out)
}
