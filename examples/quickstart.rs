//! Quickstart: the MMStencil public API in five minutes.
//!
//! 1. load the AOT PJRT artifacts (the L1 Pallas kernels, compiled once
//!    by `make artifacts` — Python is never on this path);
//! 2. run one matrix-unit block stencil through PJRT and check it
//!    against the rust-native engines;
//! 3. run a multi-threaded sweep through the coordinator and read the
//!    paper-platform performance estimate.
//!
//! Run with: `cargo run --release --example quickstart`

use mmstencil::coordinator::driver;
use mmstencil::coordinator::tiles::Strategy;
use mmstencil::grid::Grid3;
use mmstencil::runtime::{Runtime, Tensor};
use mmstencil::simulator::Platform;
use mmstencil::stencil::{naive, tune, Engine, StencilSpec};
use mmstencil::util::err::Result;

fn main() -> Result<()> {
    // ---- 1. the AOT artifact runtime --------------------------------------
    let rt = Runtime::open_default()?;
    println!("PJRT platform: {} ({} artifacts)", rt.platform(), rt.artifact_names().len());

    // ---- 2. one 3DStarR4 block through the Pallas kernel ------------------
    let spec = StencilSpec::star3d(4);
    let meta = rt
        .manifest
        .get("star3d_r4_block")
        .expect("run `make artifacts` first")
        .clone();
    let ishape = meta.inputs[0].shape.clone(); // (VZ+2r, VX+2r, VY+2r)
    let halo = Grid3::random(ishape[0], ishape[1], ishape[2], 1);
    let feed = Tensor::new(ishape.clone(), halo.as_slice().to_vec());
    let out = rt.execute("star3d_r4_block", &[feed])?;

    // the rust-native oracle: periodic sweep on the halo cube, cropped
    let r = spec.radius;
    let oracle = naive::apply3(&spec, &halo);
    let (oz, ox, oy) = (ishape[0] - 2 * r, ishape[1] - 2 * r, ishape[2] - 2 * r);
    let mut max_err = 0.0f32;
    for z in 0..oz {
        for x in 0..ox {
            for y in 0..oy {
                let want = oracle.get(z + r, x + r, y + r);
                let got = out[0].data[(z * ox + x) * oy + y];
                max_err = max_err.max((want - got).abs());
            }
        }
    }
    println!("Pallas block kernel vs rust naive: max|Δ| = {max_err:.2e}");
    assert!(max_err < 1e-3, "kernel mismatch");

    // ---- 3. a coordinated multi-thread sweep -------------------------------
    let platform = Platform::paper();
    let g = Grid3::random(64, 64, 64, 2);
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let (out, stats) = driver::sweep(&spec, &g, threads, Strategy::SnoopAware, &platform);
    // cross-check through the plan-driven dispatch layer: the startup
    // autotuner picks (engine, geometry, depth, fan-out) for this shape
    let plan = tune::tune_default(&spec, 64, threads);
    println!("tuned plan for {}: {plan}", tune::shape_key(&spec, 64));
    let check = Engine::from_plan(&plan).apply3(&spec, &g);
    println!(
        "coordinator sweep 64³ ({} threads): {:.3} Gcell/s host, max|Δ| vs tuned plan = {:.2e}",
        threads,
        stats.gcells_per_s,
        out.max_abs_diff(&check)
    );
    println!(
        "paper-platform estimate: {:.2} ms/sweep, {:.1}% bandwidth utilization",
        stats.sim_s * 1e3,
        stats.sim_bandwidth_util * 100.0
    );
    Ok(())
}
