//! Multi-NUMA scaling experiment (paper §V-E, Fig. 13).
//!
//! Decomposes a periodic 3DStarR4 sweep across simulated NUMA-domain
//! ranks, runs the REAL data path (scatter → halo exchange → per-rank
//! sweep → gather) on this host, and attaches the simulated platform's
//! timing for MPI vs SDMA vs SDMA+pipeline — then prints the strong and
//! weak scaling tables with the A100/BrickLib reference series.
//!
//! Run with: `cargo run --release --example multi_numa_scaling`

use mmstencil::coordinator::driver::multirank_sweep;
use mmstencil::coordinator::exchange::Backend;
use mmstencil::grid::{CartDecomp, Grid3};
use mmstencil::simulator::roofline::{self, Engine, MemKind, SweepConfig};
use mmstencil::simulator::Platform;
use mmstencil::stencil::{naive, StencilSpec};
use mmstencil::util::table::{f, Table};

/// A100/BrickLib reference: elapsed time for one 3DStarR4 sweep of
/// `cells` points.  BrickLib sustains ~46% of the A100's 1955 GB/s on
/// this kernel (paper Fig. 3) → ~0.9 TB/s effective.
fn bricklib_a100_time(cells: usize) -> f64 {
    let eff_bw = 0.46 * Platform::a100_bw();
    cells as f64 * 8.0 / eff_bw
}

fn main() {
    let spec = StencilSpec::star3d(4);
    let p = Platform::paper();
    let threads = 4;
    let n = 48; // host-side verification grid (sim numbers scale to 512³)

    // verification run: decomposed result must equal the naive sweep
    let g = Grid3::random(n, n, n, 11);
    let want = naive::apply3(&spec, &g);
    let d = CartDecomp::new(2, 2, 2);
    let (got, _) = multirank_sweep(&spec, &g, &d, &Backend::sdma(), 1, threads, &p);
    let err = got.max_abs_diff(&want);
    println!("8-rank decomposed sweep vs naive @ {n}³: max|Δ| = {err:.2e}");
    assert!(err < 1e-3);

    // ---- strong scaling: fixed 512³ global grid --------------------------
    let global = 512usize * 512 * 512;
    println!("\nSTRONG scaling, 3DStarR4, 512³ global (simulated platform):");
    let mut t = Table::new(&[
        "ranks",
        "MPI ms",
        "SDMA ms",
        "SDMA+pipe ms",
        "speedup vs 1",
        "A100 BrickLib ms",
    ]);
    let base = sim_step(&spec, global, 1, &p).0;
    for ranks in [1usize, 2, 4, 8] {
        let (mpi, sdma, pipe) = sim_step(&spec, global, ranks, &p);
        t.row(&[
            ranks.to_string(),
            f(mpi * 1e3, 2),
            f(sdma * 1e3, 2),
            f(pipe * 1e3, 2),
            format!("{:.2}×", base / pipe),
            f(bricklib_a100_time(global) * 1e3, 2),
        ]);
    }
    t.print();

    // ---- weak scaling: 512³ per rank --------------------------------------
    println!("\nWEAK scaling, 3DStarR4, 512³ per rank (simulated platform):");
    let mut t = Table::new(&[
        "ranks",
        "MPI ms",
        "SDMA ms",
        "SDMA+pipe ms",
        "efficiency",
        "A100/rank ms",
    ]);
    let per_rank = 512usize * 512 * 512;
    let base_pipe = sim_step(&spec, per_rank, 1, &p).2;
    for ranks in [1usize, 2, 4, 8, 16] {
        let (mpi, sdma, pipe) = sim_step_weak(&spec, per_rank, ranks, &p);
        t.row(&[
            ranks.to_string(),
            f(mpi * 1e3, 2),
            f(sdma * 1e3, 2),
            f(pipe * 1e3, 2),
            format!("{:.0}%", base_pipe / pipe * 100.0),
            f(bricklib_a100_time(per_rank) * 1e3, 2),
        ]);
    }
    t.print();
    println!(
        "\n(paper: SDMA near-ideal to 4 ranks; x-direction comm stalls 8-rank\n strong scaling unless pipelined; ≥1.2–2.1× over BrickLib/A100 weak.)"
    );
}

/// Simulated per-step times (MPI, SDMA, SDMA+pipeline) for `ranks`
/// partitions of a `global`-point grid (strong scaling).
fn sim_step(spec: &StencilSpec, global: usize, ranks: usize, p: &Platform) -> (f64, f64, f64) {
    scaled_step(spec, global / ranks, ranks, 512, p)
}

fn sim_step_weak(
    spec: &StencilSpec,
    per_rank: usize,
    ranks: usize,
    p: &Platform,
) -> (f64, f64, f64) {
    scaled_step(spec, per_rank, ranks, 512, p)
}

/// Analytic per-step model mirroring `coordinator::driver::multirank_sweep`
/// accounting at paper scale: per-rank compute from the roofline and face
/// traffic through the two transport models, pipelined over 8 z-layers.
fn scaled_step(
    spec: &StencilSpec,
    rank_cells: usize,
    ranks: usize,
    edge: usize,
    p: &Platform,
) -> (f64, f64, f64) {
    use mmstencil::coordinator::pipeline::{equal_layers, step_time, Overlap};
    use mmstencil::simulator::{mpi::MpiModel, sdma::Sdma};

    let cfg = SweepConfig::best(MemKind::OnPkg);
    let est = roofline::predict(spec, rank_cells, Engine::MMStencil, cfg, p);
    // Cartesian split: count cut planes; each rank exchanges 2 faces per
    // cut axis of edge² cells × radius depth
    let cuts = match ranks {
        1 => (0, 0, 0),
        2 => (1, 0, 0),        // z only (contiguous)
        4 => (1, 1, 0),        // z + x
        8 => (1, 1, 1),        // all three (incl. strided y/X-direction)
        16 => (2, 1, 1),
        _ => (1, 1, 1),
    };
    let face_cells = edge * edge * spec.radius;
    let bytes = |n_faces: usize| (n_faces * 2 * face_cells * 4) as u64;
    let total_faces = cuts.0 + cuts.1 + cuts.2;
    if total_faces == 0 {
        return (est.time_s, est.time_s, est.time_s);
    }
    // run lengths by axis (z faces contiguous slabs, x faces row-runs,
    // y faces element-runs — the paper's X-direction worst case)
    let sdma = Sdma::default();
    let mpi = MpiModel::default();
    let runs = [edge * edge * 4, edge * 4, spec.radius * 4];
    let mut sdma_s = 0.0;
    let mut mpi_s = 0.0;
    for (i, &c) in [cuts.0, cuts.1, cuts.2].iter().enumerate() {
        if c == 0 {
            continue;
        }
        let b = bytes(c);
        let desc = mmstencil::simulator::sdma::CopyDesc { bytes: b, run_bytes: runs[i] as u64 };
        sdma_s += b as f64 / sdma.bandwidth(desc);
        mpi_s += mpi.transfer_time_s(b, runs[i] as u64);
    }
    let (comp_l, comm_l) = equal_layers(est.time_s, sdma_s, 8);
    let (sdma_step, pipe_step) = step_time(&comp_l, &comm_l, Overlap::Concurrent);
    let _ = sdma_step;
    (est.time_s + mpi_s, est.time_s + sdma_s, pipe_step)
}
