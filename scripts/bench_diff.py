#!/usr/bin/env python3
"""Diff two BENCH_engines.json files (schema mmstencil.bench_engines.v8).

Rows are matched by identity key — sweep rows on (engine, pattern,
radius, n, time_block, tile, wf, halo_codec), RTM rows on (engine,
medium, n, time_block, halo_codec), survey rows on (engine, medium, n,
shots, shards, checkpoint) — and the per-row throughput delta
(Mcell/s, or shots/hour for survey rows) is printed as a percentage.
Older baselines stay diffable: v3 documents simply have no
`survey_entries` array (the survey section prints every current row as
new), v4 rows lack the v5 `plan` string, which is ignored here — plans
describe *how* a row ran, not *which* row it is, so they are
deliberately not part of any identity key — v5 rows lack the v6
`tile`/`wf` geometry fields, which default to 0/1 (classic stepping)
so pre-wavefront baselines keep matching their untiled successors, and
v6 rows lack the v7 `halo_codec` wire-codec field, which defaults to
"f32" (the lossless classic transport; `transport_bytes` is a
measurement, not identity), and v7 survey rows lack the v8
`faults_injected`/`resumed_shots` chaos accounting, which — like
`retries` and `failed` — is a measurement, not identity, so the
identity keys are unchanged and v7 baselines keep matching their v8
successors.  `threads` is deliberately NOT
part of the key: the probe derives it from the host's core count, so
keying on it would silently stop matching rows whenever the runner
shape changes (engine labels already distinguish serial from parallel
rows).  Baseline rows with zero throughput (the committed zero-seeded
baseline, before any CI run has populated real numbers) print as "n/a"
instead of a bogus percentage, as do rows present on only one side.

Advisory by default: always exits 0, because throughput on shared
runners is noise-prone.  Pass --fail-on-regression PCT to turn any
matched row regressing worse than -PCT% into exit 1 (for local,
quiet-machine use; --fail-below is the deprecated spelling of the
same flag).

Usage:
    python3 scripts/bench_diff.py BASELINE.json CURRENT.json \
        [--fail-on-regression PCT]
"""

import argparse
import json
import sys

SWEEP_KEY = ("engine", "pattern", "radius", "n", "time_block", "tile", "wf", "halo_codec")
RTM_KEY = ("engine", "medium", "n", "time_block", "halo_codec")
SURVEY_KEY = ("engine", "medium", "n", "shots", "shards", "checkpoint")

# Keys absent from older-schema rows take these defaults, so old
# baselines keep matching: v2 rows lack time_block (classic stepping),
# v5 rows lack tile/wf (untiled), v6 rows lack halo_codec (lossless).
KEY_DEFAULTS = {"time_block": 1, "tile": 0, "wf": 1, "halo_codec": "f32"}


def load(path):
    with open(path) as f:
        doc = json.load(f)
    schema = doc.get("schema", "")
    if not schema.startswith("mmstencil.bench_engines."):
        sys.exit(f"{path}: not a bench_engines document (schema {schema!r})")
    return doc


def index(rows, key_fields):
    out = {}
    for row in rows:
        key = tuple(row.get(k, KEY_DEFAULTS.get(k)) for k in key_fields)
        out[key] = row
    return out


def fmt_key(key, key_fields):
    return " ".join(f"{k}={v}" for k, v in zip(key_fields, key))


def compare(base_rows, cur_rows, key_fields, value_field="mcells_per_s"):
    """Pure row comparison (the unit-testable core): returns a list of
    (key, status, current_value, pct) tuples sorted by key, where status
    is "new" | "unmeasured" | "matched" | "dropped" and pct is the
    percentage delta for matched rows (None otherwise)."""
    base = index(base_rows, key_fields)
    cur = index(cur_rows, key_fields)
    out = []
    for key in sorted(cur, key=str):
        b = base.get(key)
        cv = cur[key].get(value_field, 0.0)
        if b is None:
            out.append((key, "new", cv, None))
            continue
        bv = b.get(value_field, 0.0)
        if bv <= 0.0:
            out.append((key, "unmeasured", cv, None))
            continue
        out.append((key, "matched", cv, (cv - bv) / bv * 100.0))
    for key in sorted(set(base) - set(cur), key=str):
        out.append((key, "dropped", None, None))
    return out


def worst_pct(results):
    """Most negative matched-row delta across compare() outputs, or
    None when nothing matched."""
    pcts = [pct for _, status, _, pct in results if status == "matched"]
    return min(pcts) if pcts else None


def diff_section(name, base_rows, cur_rows, key_fields, value_field="mcells_per_s", unit="Mcell/s"):
    results = compare(base_rows, cur_rows, key_fields, value_field)
    n_cur = sum(1 for _, status, _, _ in results if status != "dropped")
    n_base = sum(1 for _, status, _, _ in results if status in ("matched", "unmeasured", "dropped"))
    print(f"== {name} ({n_cur} rows, baseline {n_base}) ==")
    for key, status, cv, pct in results:
        label = fmt_key(key, key_fields)
        if status == "new":
            print(f"  {label:<64} {cv:>10.1f} {unit}   (new row)")
        elif status == "unmeasured":
            print(f"  {label:<64} {cv:>10.1f} {unit}   (n/a: baseline unmeasured)")
        elif status == "matched":
            print(f"  {label:<64} {cv:>10.1f} {unit}   {pct:+7.1f}%")
        else:
            print(f"  {label:<64} {'—':>10}           (row dropped)")
    return worst_pct(results)


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument(
        "--fail-on-regression",
        "--fail-below",
        type=float,
        default=None,
        metavar="PCT",
        help="exit 1 if any matched row regresses more than PCT percent "
        "(default: off — purely advisory)",
    )
    args = ap.parse_args()

    base = load(args.baseline)
    cur = load(args.current)
    worst = []
    w = diff_section("sweep entries", base.get("entries", []), cur.get("entries", []), SWEEP_KEY)
    if w is not None:
        worst.append(w)
    w = diff_section(
        "rtm entries", base.get("rtm_entries", []), cur.get("rtm_entries", []), RTM_KEY
    )
    if w is not None:
        worst.append(w)
    # v3 and older baselines have no survey_entries; .get() keeps them
    # tolerated — every current survey row then prints as new
    w = diff_section(
        "survey entries",
        base.get("survey_entries", []),
        cur.get("survey_entries", []),
        SURVEY_KEY,
        value_field="shots_per_hour",
        unit="shots/h",
    )
    if w is not None:
        worst.append(w)

    if worst:
        print(f"worst matched delta: {min(worst):+.1f}%")
    else:
        print("no measured baseline rows to compare (advisory diff only)")
    if args.fail_on_regression is not None and worst and min(worst) < -abs(args.fail_on_regression):
        sys.exit(1)


if __name__ == "__main__":
    main()
