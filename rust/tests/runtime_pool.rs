//! Regression contract for the persistent worker runtime: workers are
//! spawned exactly once per runtime lifetime — repeated `parallel_for`
//! calls, sweeps, and multirank timesteps must never respawn threads —
//! and the scheduling metrics (per-worker utilization, steal counts,
//! spawn overhead) stay observable through the whole stack.

use mmstencil::coordinator::driver::{multirank_sweep, sweep, Driver};
use mmstencil::coordinator::exchange::Backend;
use mmstencil::coordinator::tiles::Strategy;
use mmstencil::coordinator::{pool, runtime};
use mmstencil::grid::{CartDecomp, Grid3};
use mmstencil::simulator::Platform;
use mmstencil::stencil::{naive, StencilSpec};
use mmstencil::util::prop::assert_allclose;

#[test]
fn global_pool_spawns_workers_exactly_once() {
    let rt = runtime::global();
    let spawned = rt.spawn_count();
    assert!(spawned >= 1);
    assert_eq!(spawned, rt.workers());

    // many parallel_for dispatches of varying shapes
    for n in [1usize, 2, 7, 64, 513] {
        for _ in 0..10 {
            pool::parallel_for(4, n, |_| {});
        }
    }
    assert_eq!(rt.spawn_count(), spawned, "parallel_for respawned workers");

    // full sweeps and multirank timesteps ride the same pool
    let p = Platform::paper();
    let spec = StencilSpec::star3d(2);
    let g = Grid3::random(12, 24, 24, 3);
    let want = naive::apply3(&spec, &g);
    for _ in 0..3 {
        let (got, stats) = sweep(&spec, &g, 4, Strategy::SnoopAware, &p);
        assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
        assert_eq!(stats.pool.workers, rt.workers());
    }
    let d = CartDecomp::new(1, 2, 2);
    for _ in 0..3 {
        let (got, stats) = multirank_sweep(&spec, &g, &d, &Backend::sdma(), 1, 4, &p);
        assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
        assert!(stats.pool.tasks > 0, "steps must run through the pool");
    }
    assert_eq!(
        rt.spawn_count(),
        spawned,
        "sweeps/timesteps must reuse the persistent workers"
    );
}

#[test]
fn driver_runtime_spawns_once_per_driver_lifetime() {
    let p = Platform::paper();
    let driver = Driver::new(2, p);
    assert_eq!(driver.runtime().spawn_count(), 2);
    let spec = StencilSpec::box3d(1);
    let g = Grid3::random(8, 16, 16, 11);
    let want = naive::apply3(&spec, &g);
    for _ in 0..8 {
        let (got, _) = driver.sweep(&spec, &g, Strategy::Square);
        assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
    }
    let d = CartDecomp::new(2, 1, 1);
    for _ in 0..4 {
        let (got, _) = driver.multirank_sweep(&spec, &g, &d, &Backend::sdma(), 1);
        assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
    }
    assert_eq!(
        driver.runtime().spawn_count(),
        2,
        "Driver workers are spawned once in Driver::new, never per call"
    );
}

#[test]
fn pool_metrics_account_for_all_dispatched_items() {
    let driver = Driver::new(3, Platform::paper());
    let rt = driver.runtime();
    rt.reset_stats();
    let spec = StencilSpec::star3d(4);
    let g = Grid3::random(10, 40, 40, 7);
    let (_, stats) = driver.sweep(&spec, &g, Strategy::SnoopAware);
    // the sweep dispatched one task per tile (3 tiles for 3 threads)
    assert_eq!(stats.pool.tasks, 3);
    let s = rt.stats();
    assert_eq!(s.jobs, 1);
    assert_eq!(s.items, 3);
    assert!(s.spawn_overhead_s >= 0.0);
    assert!(stats.pool.utilization >= 0.0 && stats.pool.utilization <= 1.0);
}

#[test]
fn overlapped_step_equals_barriered_reference() {
    // the SDMA overlap schedule (comm concurrent with deep interior,
    // boundary ordered after) must be numerically identical to the
    // fully-barriered MPI schedule and to the naive oracle
    let p = Platform::paper();
    let spec = StencilSpec::box3d(2);
    let g = Grid3::random(14, 14, 14, 21);
    let mut want = g.clone();
    for _ in 0..2 {
        want = naive::apply3(&spec, &want);
    }
    let d = CartDecomp::new(2, 2, 1);
    let (sdma, _) = multirank_sweep(&spec, &g, &d, &Backend::sdma(), 2, 4, &p);
    let (mpi, _) = multirank_sweep(&spec, &g, &d, &Backend::mpi(), 2, 4, &p);
    assert_allclose(&sdma.data, &want.data, 1e-3, 1e-4);
    assert_eq!(sdma.data, mpi.data, "overlap must not change the numerics");
}
