//! Temporal-blocking contract suite (the PR 5 tentpole's acceptance
//! tests): deep-halo fused multirank sweeps must be **bitwise** the
//! classic one-exchange-per-step path for any depth, worker count,
//! engine, and backend — while performing exactly one transport round
//! per `k` fused steps.
//!
//! The transport-round assertions read the process-global counter
//! (`exchange::transport_rounds`), so every exchange-touching check
//! lives in ONE test fn (test binaries are separate processes, but
//! tests inside a binary run concurrently — a second exchange-touching
//! test here would race the counter; same pattern as
//! `rust/tests/alloc_free.rs`).

use mmstencil::coordinator::driver::{multirank_sweep, multirank_sweep_fused, Driver};
use mmstencil::coordinator::exchange::{self, Backend};
use mmstencil::coordinator::temporal;
use mmstencil::coordinator::tiles::Strategy;
use mmstencil::grid::halo::HaloCodec;
use mmstencil::grid::{CartDecomp, Grid3};
use mmstencil::simulator::Platform;
use mmstencil::stencil::{Engine, EngineKind, StencilSpec};

#[test]
fn fused_multirank_is_bitwise_the_classic_path_with_one_exchange_per_k() {
    let p = Platform::paper();
    let spec = StencilSpec::star3d(2);
    let g = Grid3::random(12, 12, 12, 0xA11);
    let d = CartDecomp::new(1, 2, 2);
    let steps = 4usize;

    // classic oracle: one transport round per step, by construction
    let before = exchange::transport_rounds();
    let (want, base) = multirank_sweep(&spec, &g, &d, &Backend::sdma(), steps, 4, &p);
    assert_eq!(base.comm_rounds, steps as u64);
    assert_eq!(exchange::transport_rounds() - before, steps as u64);

    // fused path: k ∈ {1, 2, 4} × worker counts × backends, all bitwise
    // equal to the oracle; rounds collapse to ⌈steps / k_eff⌉ (k = 4 is
    // clamped to the decomposition's max depth 3 — 12/2 owned layers at
    // r = 2 per decomposed axis)
    assert_eq!(temporal::max_depth(&d, 12, 12, 12, 2), 3);
    for k in [1usize, 2, 4] {
        let k_eff = temporal::effective_depth(k, &d, 12, 12, 12, 2);
        // rounds = number of kk-sized chunks the run splits steps into
        let mut want_rounds = 0u64;
        let mut left = steps;
        while left > 0 {
            left -= k_eff.min(left);
            want_rounds += 1;
        }
        for threads in [1usize, 2, 5] {
            for backend in [Backend::sdma(), Backend::mpi()] {
                let before = exchange::transport_rounds();
                let (got, stats) =
                    multirank_sweep_fused(&spec, &g, &d, &backend, steps, threads, &p, k);
                let rounds = exchange::transport_rounds() - before;
                assert_eq!(
                    got.data,
                    want.data,
                    "k={k} threads={threads} {} diverged from the classic path",
                    backend.name()
                );
                assert_eq!(stats.comm_rounds, want_rounds, "k={k} (k_eff={k_eff})");
                assert_eq!(rounds, want_rounds, "transport counter, k={k}");
                assert!(stats.exchanged_bytes > 0);
            }
        }
    }

    // engine-agnostic: a matrix-unit Driver with time_block routes the
    // same fused path and stays bitwise vs its own classic path
    let mu = Engine::new(EngineKind::MatrixUnit);
    let classic = Driver::new(2, p.clone()).with_engine(mu);
    let (want_mu, _) = classic.multirank_sweep(&spec, &g, &d, &Backend::sdma(), steps);
    let fused = Driver::new(2, p.clone()).with_engine(mu).with_time_block(2);
    let before = exchange::transport_rounds();
    let (got_mu, stats_mu) = fused.multirank_sweep(&spec, &g, &d, &Backend::sdma(), steps);
    assert_eq!(got_mu.data, want_mu.data, "matrix-unit fused path diverged");
    assert_eq!(stats_mu.comm_rounds, 2);
    assert_eq!(exchange::transport_rounds() - before, 2);

    // uneven decomposition: prime-sized grid, lopsided 1×1×3 layout,
    // blocks 5/4/4 along y — one deep exchange feeds all four steps
    let spec1 = StencilSpec::star3d(1);
    let g2 = Grid3::random(7, 11, 13, 0xBEE);
    let d3 = CartDecomp::new(1, 1, 3);
    assert_eq!(temporal::max_depth(&d3, 7, 11, 13, 1), 4);
    let (want2, _) = multirank_sweep(&spec1, &g2, &d3, &Backend::sdma(), 4, 3, &p);
    let before = exchange::transport_rounds();
    let (got2, st2) = multirank_sweep_fused(&spec1, &g2, &d3, &Backend::sdma(), 4, 3, &p, 4);
    assert_eq!(got2.data, want2.data, "uneven-decomp fused path diverged");
    assert_eq!(st2.comm_rounds, 1);
    assert_eq!(exchange::transport_rounds() - before, 1);

    // halo-codec contract (PR 9): an explicit f32 codec is the same
    // code path — bitwise result, same wire bytes, same transport
    // schedule; bf16 exactly halves the simulated wire (2 vs 4 bytes
    // per value) without changing the exchange count
    let plain = Driver::new(2, p.clone());
    let (w0, s0) = plain.multirank_sweep(&spec, &g, &d, &Backend::sdma(), steps);
    let before = exchange::transport_rounds();
    let explicit = Driver::new(2, p.clone()).with_halo_codec(HaloCodec::F32);
    let (w1, s1) = explicit.multirank_sweep(&spec, &g, &d, &Backend::sdma(), steps);
    assert_eq!(w1.data, w0.data, "explicit f32 codec must stay bitwise");
    assert_eq!(s1.exchanged_bytes, s0.exchanged_bytes);
    assert_eq!(exchange::transport_rounds() - before, steps as u64);
    let squeezed = Driver::new(2, p.clone()).with_halo_codec(HaloCodec::Bf16);
    let before = exchange::transport_rounds();
    let (_, sb) = squeezed.multirank_sweep(&spec, &g, &d, &Backend::sdma(), steps);
    assert_eq!(sb.exchanged_bytes * 2, s0.exchanged_bytes, "bf16 wire must be half of f32");
    assert_eq!(sb.comm_rounds, s0.comm_rounds, "codec must not change the schedule");
    assert_eq!(exchange::transport_rounds() - before, steps as u64);
}

#[test]
fn fused_driver_sweep_is_bitwise_the_chained_sweeps() {
    // the single-grid arm of the time_block knob: k tiled sweeps
    // ping-ponged through the arena double buffer == k chained sweeps
    let p = Platform::paper();
    let spec = StencilSpec::star3d(2);
    let g = Grid3::random(10, 20, 24, 9);
    let classic = Driver::new(3, p.clone());
    let (one, s1) = classic.sweep(&spec, &g, Strategy::SnoopAware);
    let (two, _) = classic.sweep(&spec, &one, Strategy::SnoopAware);
    let fused = Driver::new(3, p).with_time_block(2);
    assert_eq!(fused.time_block(), 2);
    let (got, s2) = fused.sweep(&spec, &g, Strategy::SnoopAware);
    assert_eq!(got.data, two.data, "fused driver sweep diverged");
    assert_eq!(s2.cells, 2 * s1.cells, "fused stats must count all updates");
}
