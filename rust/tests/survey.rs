//! Shot-service contract suite (the PR 6 tentpole's acceptance tests):
//! the survey scheduler (`rtm::service`) must be **deterministic** —
//! the accumulated image bitwise-stable across worker counts AND shard
//! counts — must match the sum of sequential `run_shot` images, must
//! honor the bounded queue's FIFO/backpressure contracts, and must
//! retry a failed shot once before surfacing it, without ever wedging
//! a lane.
//!
//! Shots here are tiny (20³ × a dozen steps) — the contracts under
//! test are scheduling and reduction, not throughput.

use mmstencil::rtm::driver::{run_shot, Medium, RtmConfig};
use mmstencil::rtm::image::Image;
use mmstencil::rtm::service::{
    reduce_images, CheckpointStrategy, ShotJob, ShotStatus, SurveyConfig, SurveyRunner,
};
use mmstencil::simulator::Platform;
use mmstencil::stencil::EngineKind;

fn base_cfg(medium: Medium, engine: EngineKind) -> RtmConfig {
    let mut cfg = RtmConfig::small(medium);
    cfg.nz = 20;
    cfg.nx = 20;
    cfg.ny = 20;
    cfg.steps = 12;
    cfg.threads = 2;
    cfg.engine = engine;
    cfg
}

/// A line of shots whose sources sweep the interior x-axis.
fn shot_line(cfg: &RtmConfig, shots: usize) -> Vec<ShotJob> {
    let (sz, _, sy) = cfg.src_pos();
    let lo = cfg.sponge_width + 1;
    let hi = (cfg.nx - cfg.sponge_width).saturating_sub(2).max(lo);
    (0..shots)
        .map(|s| {
            let sx = lo + (hi - lo) * s / shots.saturating_sub(1).max(1);
            ShotJob::builder(cfg.clone()).src(sz, sx, sy).build().unwrap()
        })
        .collect()
}

fn run_survey(cfg: &RtmConfig, shots: usize, scfg: SurveyConfig) -> (Image, usize) {
    let mut runner = SurveyRunner::new(scfg, &Platform::paper()).unwrap();
    let report = runner.run(shot_line(cfg, shots));
    assert_eq!(report.completed(), shots, "all shots must complete");
    (report.image.unwrap(), report.stolen())
}

/// Acceptance: a mini-survey (8 shots, 2 ranks, matrix-unit engine)
/// produces an image bitwise-stable across worker counts and shard
/// counts, whose energy matches the merged sequential `run_shot`
/// images within 1e-4 relative.
#[test]
fn survey_image_is_deterministic_and_matches_sequential_shots() {
    let cfg = base_cfg(Medium::Vti, EngineKind::MatrixUnit);
    let shots = 8;

    // sequential oracle: run_shot per job, merged by the same tree
    let p = Platform::paper();
    let seq_images: Vec<Image> = shot_line(&cfg, shots)
        .into_iter()
        .map(|job| run_shot(job.config(), &p).0)
        .collect();
    let oracle = reduce_images(seq_images).unwrap();

    let mut reference: Option<Image> = None;
    for shards in [1usize, 2, 4] {
        for workers in [0usize, 2 * shards + 3] {
            let mut scfg = SurveyConfig::default();
            scfg.shards = shards;
            scfg.workers = workers;
            scfg.queue_capacity = 2; // keep the producer blocking under way
            let (image, _) = run_survey(&cfg, shots, scfg);
            match &reference {
                None => {
                    // the tree reduction's shape depends only on shot
                    // count, so the survey equals the oracle EXACTLY
                    assert_eq!(image.img.data, oracle.img.data, "survey vs sequential oracle");
                    assert_eq!(image.correlations, oracle.correlations);
                    // the headline acceptance bound, stated as energy:
                    // survey image energy vs the summed sequential
                    // images, ≤ 1e-4 relative (bitwise here)
                    let rel = (image.img.energy() / oracle.img.energy() - 1.0).abs();
                    assert!(rel < 1e-4, "energy diverges from sequential sum: rel {rel:.2e}");
                    reference = Some(image);
                }
                Some(r) => {
                    assert_eq!(
                        image.img.data, r.img.data,
                        "shards={shards} workers={workers}: image not bitwise-stable"
                    );
                    assert_eq!(image.illum.data, r.illum.data);
                    assert_eq!(image.correlations, r.correlations);
                }
            }
        }
    }
}

/// Cross-shard energy agreement: reducing per-shard partial images and
/// then merging across shards must agree with the flat reduction over
/// all shots (< 1e-4 relative on energy; exact here because the
/// per-shot images are identical inputs either way).
#[test]
fn cross_shard_partial_reductions_agree_with_the_flat_reduction() {
    let cfg = base_cfg(Medium::Vti, EngineKind::Simd);
    let shots = 8;
    let shards = 2;
    let p = Platform::paper();
    let images: Vec<Image> = shot_line(&cfg, shots)
        .into_iter()
        .map(|job| run_shot(job.config(), &p).0)
        .collect();
    let flat_energy = reduce_images(
        shot_line(&cfg, shots)
            .into_iter()
            .map(|job| run_shot(job.config(), &p).0)
            .collect(),
    )
    .unwrap()
    .img
    .energy();

    // shard-major grouping (id % shards), each shard tree-reduced, then
    // the partials tree-reduced across shards
    let mut by_shard: Vec<Vec<Image>> = (0..shards).map(|_| Vec::new()).collect();
    for (id, im) in images.into_iter().enumerate() {
        by_shard[id % shards].push(im);
    }
    let partials: Vec<Image> =
        by_shard.into_iter().map(|imgs| reduce_images(imgs).unwrap()).collect();
    let cross = reduce_images(partials).unwrap();
    let rel = (cross.img.energy() / flat_energy - 1.0).abs();
    assert!(rel < 1e-4, "cross-shard energy disagrees: rel {rel:.2e}");
}

/// Both checkpoint strategies must produce bitwise-identical survey
/// images — the trait contract, exercised through the whole scheduler.
#[test]
fn checkpoint_strategies_agree_bitwise_through_the_scheduler() {
    let mut cfg = base_cfg(Medium::Tti, EngineKind::Simd);
    cfg.snap_every = 2;
    let mut images = Vec::new();
    for checkpoint in [CheckpointStrategy::FullState, CheckpointStrategy::BoundarySaving] {
        let mut scfg = SurveyConfig::default();
        scfg.checkpoint = checkpoint;
        scfg.keyframe_every = 2;
        let (image, _) = run_survey(&cfg, 4, scfg);
        images.push(image);
    }
    assert_eq!(
        images[0].img.data, images[1].img.data,
        "full-state and boundary-saving imaged differently"
    );
    assert_eq!(images[0].illum.data, images[1].illum.data);
}

/// Failed shots are retried once, then surfaced in the report — and the
/// shots queued behind them still complete (the lane never wedges).
#[test]
fn failed_shots_retry_once_then_surface_without_wedging_the_queue() {
    let cfg = base_cfg(Medium::Vti, EngineKind::Simd);
    let mut scfg = SurveyConfig::default();
    scfg.shards = 1; // one lane: the failing shots sit IN FRONT of healthy ones
    scfg.queue_capacity = 2;
    let mut runner = SurveyRunner::new(scfg, &Platform::paper()).unwrap();
    let mut jobs = Vec::new();
    // job 0 fails once then succeeds; job 1 exhausts its retry budget
    jobs.push(ShotJob::builder(cfg.clone()).inject_faults(1).build().unwrap());
    jobs.push(ShotJob::builder(cfg.clone()).inject_faults(2).build().unwrap());
    jobs.extend(shot_line(&cfg, 3));
    let report = runner.run(jobs);

    assert_eq!(report.records.len(), 5);
    assert_eq!(report.records[0].status, ShotStatus::Completed, "retried shot completes");
    assert_eq!(report.records[0].attempts, 2);
    assert!(
        matches!(report.records[1].status, ShotStatus::Failed(_)),
        "fault-exhausted shot is surfaced, not retried forever"
    );
    assert_eq!(report.records[1].attempts, 2, "exactly one retry before giving up");
    for r in &report.records[2..] {
        assert_eq!(r.status, ShotStatus::Completed, "shot {} behind the failures", r.id);
    }
    assert_eq!((report.completed(), report.failed(), report.retries()), (4, 1, 2));
    // per-lane FIFO: the single lane dequeues in submission order
    let mut seqs: Vec<u64> = report.records.iter().map(|r| r.dequeue_seq).collect();
    let sorted = {
        let mut s = seqs.clone();
        s.sort_unstable();
        s
    };
    assert_eq!(seqs, sorted, "single-lane survey must dequeue FIFO");
    seqs.dedup();
    assert_eq!(seqs.len(), 5, "each shot dequeued exactly once");
    // failures never leak into the image: 4 completed shots accumulated
    assert_eq!(report.image.unwrap().correlations, 4 * (cfg.steps / cfg.snap_every.max(1)));
}

/// `run_shot` is now a thin wrapper over the service — its output must
/// be bitwise the single-job survey path.
#[test]
fn run_shot_wrapper_is_bitwise_the_service_path() {
    let cfg = base_cfg(Medium::Vti, EngineKind::Simd);
    let p = Platform::paper();
    let (wrapped, _) = run_shot(&cfg, &p);
    let mut runner = SurveyRunner::new(SurveyConfig::one_shot(), &p).unwrap();
    let (direct, _) = runner.run_one(ShotJob::builder(cfg).build().unwrap()).unwrap();
    assert_eq!(wrapped.img.data, direct.img.data);
    assert_eq!(wrapped.illum.data, direct.illum.data);
}
