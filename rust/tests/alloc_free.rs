//! Steady-state allocation accounting for the engine hot paths (the
//! PR 3 tentpole contract): after one warm-up sweep, the serial
//! matrix-unit sweep performs **zero heap allocations per block** —
//! interior blocks are zero-copy, boundary windows and star `tmp`
//! buffers come from the warm worker-local scratch arena
//! (`coordinator::scratch`), and results land directly in the claimed
//! output view.
//!
//! Enforced with a counting global allocator: allocation *events* per
//! sweep must be a small constant (the output grid + debug claim
//! ledger), independent of how many blocks the sweep visits.  The same
//! contract extends up the stack to a full RTM VTI step through the
//! matrix-unit engine (the PR 4 application rework): O(1) allocation
//! events per step after warm-up, independent of grid size.  Not run
//! under Miri (the CI miri job targets `aliasing.rs` only).

use mmstencil::coordinator::scratch;
use mmstencil::grid::Grid3;
use mmstencil::rtm::{media, vti};
use mmstencil::stencil::coeffs::second_deriv;
use mmstencil::stencil::matrix_unit::{self, BlockDims};
use mmstencil::stencil::{gemm, CoeffTable, Engine, EngineKind, StencilSpec, TunePlan};
use mmstencil::util::alloc_count::CountingAlloc;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Minimum allocation-event count of `reps` runs of `f`.  The minimum
/// filters the rare harness-side allocation (test-runner bookkeeping on
/// another thread) out of the measurement — noise only ever adds.
fn min_events_during(reps: usize, mut f: impl FnMut()) -> u64 {
    (0..reps)
        .map(|_| {
            let before = CountingAlloc::events();
            f();
            CountingAlloc::events() - before
        })
        .min()
        .unwrap()
}

// One test fn on purpose: a second concurrently starting test would
// share the global counter.
#[test]
fn matrix_unit_hot_path_allocation_contract() {
    let dims = BlockDims::default();
    // same (vz, vl, vl) block shapes, 8× the block count: with the
    // default (4,16,16) blocks the small grid has 2·2·2 = 8 blocks and
    // the big one 4·4·4 = 64
    let small = Grid3::random(8, 32, 32, 1);
    let big = Grid3::random(16, 64, 64, 2);
    // user-defined coefficient tables ride the exact same scratch-arena
    // plumbing as the Table-I kernels, so a custom radius (r = 3, a
    // band no benchmark kernel uses) must keep the O(1) contract too
    let custom_star =
        StencilSpec::parse("custom:star:r3:0.02,-0.05,0.4,-0.7,0.4,-0.05,0.02").unwrap();
    let custom_box = StencilSpec::from_table(&CoeffTable::boxed(3, 1, vec![0.01; 27]).unwrap());
    for spec in
        [StencilSpec::star3d(4), StencilSpec::box3d(2), custom_star.clone(), custom_box.clone()]
    {
        // warm-up: sizes the thread-local arena for every buffer shape
        matrix_unit::apply3(&spec, &big, dims);
        matrix_unit::apply3(&spec, &small, dims);

        let a_small = min_events_during(3, || {
            matrix_unit::apply3(&spec, &small, dims);
        });
        let a_big = min_events_during(3, || {
            matrix_unit::apply3(&spec, &big, dims);
        });
        // per-sweep constant (output grid + debug claim ledger), never
        // per block: 8× the blocks must not change the count
        assert_eq!(
            a_small, a_big,
            "allocation count scales with block count ({a_small} vs {a_big})"
        );
        assert!(a_big <= 8, "steady-state sweep allocated {a_big} times");

        // and the arena itself must be warm
        let grows = scratch::local_grow_events();
        matrix_unit::apply3(&spec, &big, dims);
        assert_eq!(scratch::local_grow_events(), grows, "arena grew after warm-up");
    }

    // ---- gemm engine: the banded-GEMM reformulation inherits the ----
    // same steady-state contract — the band operand and x-panels are
    // scratch-arena checkouts, never per-sweep heap allocations
    for spec in [StencilSpec::star3d(4), StencilSpec::box3d(2), custom_star, custom_box] {
        gemm::apply3(&spec, &big, dims);
        gemm::apply3(&spec, &small, dims);

        let a_small = min_events_during(3, || {
            gemm::apply3(&spec, &small, dims);
        });
        let a_big = min_events_during(3, || {
            gemm::apply3(&spec, &big, dims);
        });
        assert_eq!(
            a_small, a_big,
            "gemm allocation count scales with block count ({a_small} vs {a_big})"
        );
        assert!(a_big <= 8, "steady-state gemm sweep allocated {a_big} times");

        let grows = scratch::local_grow_events();
        gemm::apply3(&spec, &big, dims);
        assert_eq!(scratch::local_grow_events(), grows, "gemm arena grew after warm-up");
    }

    // all-interior sweep on a fresh, larger grid: interior blocks are
    // zero-copy, so even the *first* big-grid sweep stays at the
    // per-sweep constant — its r=1 boundary windows are no bigger than
    // the warm ones from the small grid below (same block dims)
    let spec = StencilSpec::star3d(1);
    let warm = Grid3::random(8, 32, 32, 3);
    matrix_unit::apply3(&spec, &warm, dims);
    let g = Grid3::random(24, 96, 96, 4);
    // reps must be 1 here: the *first* (cold) big-grid sweep is the
    // measurement — later reps would be warm and hide a regression.
    // The <=8 slack absorbs the rare harness-side stray allocation the
    // min-filter would otherwise remove.
    let first = min_events_during(1, || {
        matrix_unit::apply3(&spec, &g, dims);
    });
    assert!(first <= 8, "cold interior sweep allocated {first} times");

    // ---- RTM step through the matrix-unit engine: O(1) allocations ----
    // per step after warm-up.  Each step performs a fixed number of
    // runtime dispatches (3 axis passes + 3 pointwise chunk passes),
    // each costing a constant handful of events (job Arc, chunk-bounds
    // vec, debug claim ledger) — never per block or per cell, so 8×
    // the cells must not move the count beyond ledger-growth noise.
    let eng = Engine::from_plan(&TunePlan {
        engine: EngineKind::MatrixUnit,
        threads: 2,
        ..TunePlan::simd(1)
    });
    let w2 = second_deriv(4);
    let shot = |n: usize| {
        let m = media::layered_vti(n, n, n, 10.0, &media::default_layers());
        let mut st = vti::VtiState::zeros(n, n, n);
        let mut sc = vti::VtiScratch::new(n, n, n);
        st.inject(n / 2, n / 2, n / 2, 1.0);
        // warm-up: sizes arenas, runtime queues, and ledger capacity
        vti::step_with(&mut st, &m, &w2, &eng, &mut sc);
        vti::step_with(&mut st, &m, &w2, &eng, &mut sc);
        min_events_during(3, || {
            vti::step_with(&mut st, &m, &w2, &eng, &mut sc);
        })
    };
    let small_step = shot(16);
    let big_step = shot(32);
    assert!(
        big_step <= small_step + 24,
        "RTM step allocations scale with grid size ({small_step} vs {big_step})"
    );
    assert!(big_step <= 96, "steady-state RTM step allocated {big_step} times");

    // ---- fused stepping keeps the O(1)-per-sub-step contract ----
    // step_k_with(k) is k fused sub-steps sharing warm scratch; its
    // allocation events must stay within k × the single-step budget
    // (plus harness slack), never grow with depth beyond that.  Depth
    // is env-selected (default 2): CI runs this suite once more with
    // MMSTENCIL_TIME_BLOCK=3 on top of the default run.
    let k: usize = std::env::var("MMSTENCIL_TIME_BLOCK")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2)
        .max(1);
    let n = 16;
    let m = media::layered_vti(n, n, n, 10.0, &media::default_layers());
    let mut st = vti::VtiState::zeros(n, n, n);
    let mut sc = vti::VtiScratch::new(n, n, n);
    st.inject(8, 8, 8, 1.0);
    // warm-up: arenas, runtime queues, claim-ledger capacity
    vti::step_k_with(&mut st, &m, &w2, &eng, &mut sc, k);
    let single = min_events_during(3, || vti::step_with(&mut st, &m, &w2, &eng, &mut sc));
    let fused = min_events_during(3, || vti::step_k_with(&mut st, &m, &w2, &eng, &mut sc, k));
    assert!(
        fused <= k as u64 * single + 24,
        "fused step (k={k}) allocated {fused}, single step {single}"
    );

    // ---- wavefront-tiled fused stepping: O(1) allocation events ----
    // The band planner builds its CSR dependency ledger with counted
    // passes + with_capacity and the executor pre-sizes its ready
    // queue, so a bigger grid means *longer* vectors (bigger single
    // events), never *more* events — 8× the cells must not move the
    // per-sweep event count beyond harness noise.
    use mmstencil::coordinator::driver::multirank_sweep_wavefront;
    use mmstencil::coordinator::exchange::Backend;
    use mmstencil::grid::CartDecomp;
    use mmstencil::simulator::Platform;
    let p = Platform::paper();
    let spec = StencilSpec::star3d(1);
    let d = CartDecomp::new(1, 1, 2);
    let wave = |n: usize| {
        let g = Grid3::random(n, n, n, 0xA110C);
        // warm-up sizes arenas, runtime queues, and ledger capacity
        multirank_sweep_wavefront(&spec, &g, &d, &Backend::sdma(), 2, 2, &p, 2, 2, 1);
        min_events_during(3, || {
            multirank_sweep_wavefront(&spec, &g, &d, &Backend::sdma(), 2, 2, &p, 2, 2, 1);
        })
    };
    let small_wave = wave(8);
    let big_wave = wave(16);
    assert!(
        big_wave <= small_wave + 24,
        "wavefront sweep allocations scale with grid size ({small_wave} vs {big_wave})"
    );
}
