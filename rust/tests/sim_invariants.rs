//! Property tests over the simulator substrate: cache, directory, NoC,
//! stream model, SDMA/MPI transports, roofline — the invariants any
//! reasonable implementation of the paper's platform must satisfy.

use mmstencil::grid::brick::{BrickDims, BrickLayout};
use mmstencil::grid::Grid3;
use mmstencil::simulator::cache::Cache;
use mmstencil::simulator::mpi::MpiModel;
use mmstencil::simulator::roofline::{engine_cfg, predict, Engine, MemKind, SweepConfig};
use mmstencil::simulator::sdma::{CopyDesc, Sdma};
use mmstencil::simulator::{stream, Platform};
use mmstencil::stencil::StencilSpec;
use mmstencil::util::prop;

#[test]
fn cache_lru_hit_rate_monotone_in_size() {
    // bigger cache never hurts on any access trace
    prop::forall(20, 0xCACE, |rng| {
        let line = 64;
        let trace: Vec<u64> = (0..2000).map(|_| (rng.range(0, 256) * line) as u64).collect();
        let mut small = Cache::new(8 << 10, 4, line);
        let mut big = Cache::new(32 << 10, 4, line);
        let mut hits_small = 0;
        let mut hits_big = 0;
        for &a in &trace {
            hits_small += small.access(a, false) as usize;
            hits_big += big.access(a, false) as usize;
        }
        assert!(hits_big >= hits_small, "big {hits_big} < small {hits_small}");
    });
}

#[test]
fn cache_sequential_streaming_hits_within_lines() {
    let mut c = Cache::new(32 << 10, 8, 64);
    let mut hits = 0;
    for b in 0..4096u64 {
        hits += c.access(b, false) as usize; // byte stream: 63/64 hit
    }
    assert!(hits >= 4096 - 4096 / 64 - 8);
}

#[test]
fn brick_roundtrip_any_shape() {
    prop::forall(20, 0xB41C, |rng| {
        let dims = BrickDims::default();
        // shapes that are multiples of the brick dims
        let nz = dims.bz * rng.range(1, 6);
        let nx = dims.bx * rng.range(1, 4);
        let ny = dims.by * rng.range(1, 8);
        let g = Grid3::random(nz, nx, ny, rng.next_u64());
        let bl = BrickLayout::from_grid(&g, dims);
        assert_eq!(bl.to_grid(), g);
        // point access agrees too
        for _ in 0..50 {
            let (z, x, y) = (rng.range(0, nz - 1), rng.range(0, nx - 1), rng.range(0, ny - 1));
            assert_eq!(bl.get(z, x, y), g.get(z, x, y));
        }
    });
}

#[test]
fn sdma_efficiency_monotone_in_run_length() {
    let s = Sdma::default();
    let mut last = 0.0;
    for run in [16u64, 64, 256, 1024, 8192, 65536, 1 << 22] {
        let e = s.efficiency(run);
        assert!(e >= last, "efficiency must be monotone: {run} gives {e}");
        assert!((0.0..=1.0).contains(&e));
        last = e;
    }
}

#[test]
fn sdma_beats_mpi_on_every_face_shape() {
    // Table II generalized: any face of a 3D halo exchange
    let s = Sdma::default();
    let m = MpiModel::default();
    prop::forall(30, 0x5D3A, |rng| {
        let depth = rng.range(1, 8);
        let a = rng.range(16, 512);
        let b = rng.range(16, 512);
        let bytes = (depth * a * b * 4) as u64;
        let run = (b * 4) as u64;
        let sdma_bw = s.bandwidth(CopyDesc { bytes, run_bytes: run });
        let mpi_bw = m.bandwidth(bytes, run);
        assert!(sdma_bw > 3.0 * mpi_bw, "SDMA {sdma_bw:.2e} vs MPI {mpi_bw:.2e}");
    });
}

#[test]
fn mpi_bandwidth_capped_by_copy_bw() {
    let m = MpiModel::default();
    prop::forall(30, 0x3141, |rng| {
        let bytes = rng.range(1 << 10, 1 << 26) as u64;
        let run = rng.range(16, 1 << 20) as u64;
        assert!(m.bandwidth(bytes, run) <= m.copy_bw * 1.001);
    });
}

#[test]
fn stream_efficiency_bounded_and_monotone() {
    prop::forall(40, 0x57E4, |rng| {
        let port = 128;
        let run = rng.range(16, 1 << 16);
        let streams = rng.range(1, 400);
        let e = stream::onpkg_efficiency(run, streams, port);
        assert!((0.0..=1.0).contains(&e));
        // more streams never help
        let e2 = stream::onpkg_efficiency(run, streams + 50, port);
        assert!(e2 <= e + 1e-12);
        // longer runs never hurt
        let e3 = stream::onpkg_efficiency(run * 2, streams, port);
        assert!(e3 >= e - 1e-12);
    });
}

#[test]
fn roofline_time_decomposes_and_scales() {
    let p = Platform::paper();
    prop::forall(25, 0x800F, |rng| {
        let (name, _) = StencilSpec::benchmark_suite()[rng.range(0, 7)].clone();
        let spec = StencilSpec::parse(name).unwrap();
        let n = rng.range(1 << 18, 1 << 24);
        for mem in [MemKind::Ddr, MemKind::OnPkg] {
            for engine in [Engine::Compiler, Engine::Simd, Engine::MMStencil] {
                let cfg = engine_cfg(engine, mem);
                let e1 = predict(&spec, n, engine, cfg, &p);
                let e2 = predict(&spec, 2 * n, engine, cfg, &p);
                // linear in n
                assert!((e2.time_s / e1.time_s - 2.0).abs() < 0.02, "{name} {engine:?}");
                // time ≥ max(compute, memory) components
                assert!(e1.time_s >= e1.compute_s.max(e1.memory_s) * 0.999);
                // utilization in (0, 1]
                assert!(
                    e1.bandwidth_util > 0.0 && e1.bandwidth_util <= 1.0,
                    "{name} {engine:?} {mem:?}: {}",
                    e1.bandwidth_util
                );
            }
        }
    });
}

#[test]
fn roofline_best_config_is_fastest() {
    // enabling any optimization must never slow a kernel down
    let p = Platform::paper();
    for (name, spec) in StencilSpec::benchmark_suite() {
        for mem in [MemKind::Ddr, MemKind::OnPkg] {
            let best = predict(&spec, 1 << 22, Engine::MMStencil, SweepConfig::best(mem), &p);
            for brick in [false, true] {
                for snoop in [false, true] {
                    for prefetch in [false, true] {
                        let cfg = SweepConfig { mem, brick, snoop, prefetch };
                        let e = predict(&spec, 1 << 22, Engine::MMStencil, cfg, &p);
                        assert!(
                            best.time_s <= e.time_s * 1.001,
                            "{name} {mem:?} brick={brick} snoop={snoop} pf={prefetch}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn onpkg_always_at_least_as_fast_as_ddr() {
    let p = Platform::paper();
    for (name, spec) in StencilSpec::benchmark_suite() {
        let on = predict(&spec, 1 << 22, Engine::MMStencil, SweepConfig::best(MemKind::OnPkg), &p);
        let dd = predict(&spec, 1 << 22, Engine::MMStencil, SweepConfig::best(MemKind::Ddr), &p);
        assert!(on.time_s <= dd.time_s, "{name}: on-package slower than DDR?");
    }
}

#[test]
fn iv_b_speedup_model_monotone_and_anchored() {
    let p = Platform::paper();
    let mut last = 0.0;
    for r in 1..=4 {
        let s = p.mmstencil_speedup(r);
        assert!(s > last);
        last = s;
    }
    // §IV-B: "at r = 4 ... theoretical 1.5× speedup" (before freq ratio)
    let raw: f64 = 16.0 * 9.0 * 0.5 / (24.0 * 2.0);
    assert!((raw - 1.5).abs() < 1e-12);
}
