//! Oracle-equivalence suite for user-defined coefficient tables (the
//! `custom:` spec family): seeded random [`CoeffTable`]s — star and box,
//! radii 1..4 — must produce the same field through every engine, every
//! worker count, and the fused/wavefront coordinator paths as an
//! *independent* dense convolution written directly from the table
//! (not through the engines' shared weight plumbing, so a
//! `StencilSpec::from_table` conversion bug cannot cancel itself out).
//!
//! The CI matrix lane pins `MMSTENCIL_WORKERS` / `MMSTENCIL_HALO_CODEC`
//! to one cell; unset, each test sweeps its own in-test matrix.  Tables
//! are normalized to unit L∞ gain (Σ|w| = 1) so chained applications
//! stay O(1) in magnitude and the codec-composition budget is tight.

use mmstencil::coordinator::driver::Driver;
use mmstencil::coordinator::exchange::Backend;
use mmstencil::grid::halo::HaloCodec;
use mmstencil::grid::{CartDecomp, Grid3};
use mmstencil::simulator::Platform;
use mmstencil::stencil::{naive, CoeffTable, Engine, EngineKind, Pattern, StencilSpec, TunePlan};
use mmstencil::util::prop::assert_allclose;
use mmstencil::util::XorShift;

fn max_diff(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).fold(0f32, |m, (x, y)| m.max((x - y).abs())) as f64
}

fn env_workers() -> Vec<usize> {
    match std::env::var("MMSTENCIL_WORKERS") {
        Ok(s) => vec![s.parse().expect("MMSTENCIL_WORKERS must be a worker count")],
        Err(_) => vec![1, 2, 4],
    }
}

fn env_codecs() -> Vec<HaloCodec> {
    match std::env::var("MMSTENCIL_HALO_CODEC") {
        Ok(s) => vec![HaloCodec::parse(&s).expect("MMSTENCIL_HALO_CODEC must name a codec")],
        Err(_) => vec![HaloCodec::F32, HaloCodec::Bf16, HaloCodec::F16],
    }
}

/// Random star band, normalized so the applied stencil's Σ|w| = 1
/// (the centre is counted once per axis, so the full gain is
/// 3·Σ|band| for a 3D table).
fn random_star(rng: &mut XorShift, radius: usize) -> CoeffTable {
    let n = 2 * radius + 1;
    let mut band: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
    let total: f32 = 3.0 * band.iter().map(|w| w.abs()).sum::<f32>();
    for w in &mut band {
        *w /= total;
    }
    CoeffTable::star(3, radius, band).expect("generated band is well-formed")
}

/// Random dense box tensor, normalized to Σ|w| = 1.
fn random_box(rng: &mut XorShift, radius: usize) -> CoeffTable {
    let n = 2 * radius + 1;
    let mut taps: Vec<f32> = (0..n * n * n).map(|_| rng.next_f32() - 0.5).collect();
    let total: f32 = taps.iter().map(|w| w.abs()).sum();
    for w in &mut taps {
        *w /= total;
    }
    CoeffTable::boxed(3, radius, taps).expect("generated tensor is well-formed")
}

/// Independent periodic convolution straight from the table — the
/// star arm sums the full band along each axis (which equals the
/// engines' once-counted-centre convention: 3·band[r] at the centre).
fn oracle(table: &CoeffTable, g: &Grid3) -> Grid3 {
    assert_eq!(table.ndim, 3);
    let r = table.radius as isize;
    let n = 2 * table.radius + 1;
    let mut out = Grid3::zeros(g.nz, g.nx, g.ny);
    for z in 0..g.nz as isize {
        for x in 0..g.nx as isize {
            for y in 0..g.ny as isize {
                let mut acc = 0f32;
                match table.pattern {
                    Pattern::Star => {
                        for (j, &w) in table.taps.iter().enumerate() {
                            let o = j as isize - r;
                            acc += w * g.get_wrap(z + o, x, y);
                            acc += w * g.get_wrap(z, x + o, y);
                            acc += w * g.get_wrap(z, x, y + o);
                        }
                    }
                    Pattern::Box => {
                        for dz in 0..n {
                            for dx in 0..n {
                                for dy in 0..n {
                                    let w = table.taps[(dz * n + dx) * n + dy];
                                    acc += w
                                        * g.get_wrap(
                                            z + dz as isize - r,
                                            x + dx as isize - r,
                                            y + dy as isize - r,
                                        );
                                }
                            }
                        }
                    }
                }
                out.set(z as usize, x as usize, y as usize, acc);
            }
        }
    }
    out
}

#[test]
fn random_tables_match_an_independent_oracle_on_every_engine() {
    let mut rng = XorShift::new(0xC0FFEE);
    let g = Grid3::random(10, 12, 14, 0x7AB);
    let mut tables: Vec<CoeffTable> = (1..=4).map(|r| random_star(&mut rng, r)).collect();
    tables.extend((1..=2).map(|r| random_box(&mut rng, r)));
    for table in &tables {
        let spec = StencilSpec::from_table(table);
        let want = oracle(table, &g);
        // the shared-plumbing oracle agrees with the independent one
        assert_allclose(&naive::apply3(&spec, &g).data, &want.data, 1e-4, 1e-5);
        for kind in EngineKind::ALL {
            let mut per_worker: Vec<Vec<f32>> = Vec::new();
            for &threads in &env_workers() {
                let eng = Engine::from_plan(&TunePlan {
                    engine: kind,
                    threads,
                    ..TunePlan::simd(1)
                });
                let got = eng.apply3(&spec, &g);
                assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
                per_worker.push(got.data);
            }
            // worker-count independence stays bitwise for custom taps
            for d in &per_worker[1..] {
                assert_eq!(
                    d, &per_worker[0],
                    "{kind:?} {:?} r={}: result depends on worker count",
                    table.pattern, table.radius
                );
            }
        }
    }
}

#[test]
fn custom_tables_ride_the_fused_and_wavefront_paths() {
    let p = Platform::paper();
    let mut rng = XorShift::new(0x5EED5);
    let table = random_star(&mut rng, 3);
    let spec = StencilSpec::from_table(&table);
    let g = Grid3::random(12, 12, 12, 0xF0);
    // fused == chained, bitwise, for every engine (the single-grid arm)
    for kind in EngineKind::ALL {
        let eng = Engine::from_plan(&TunePlan { engine: kind, threads: 2, ..TunePlan::simd(1) });
        let once = eng.apply3(&spec, &g);
        let twice = eng.apply3(&spec, &once);
        let fused = eng.apply3_fused(&spec, &g, 2);
        assert_eq!(fused.data, twice.data, "{kind:?}: fused custom sweep diverged");
    }
    // multirank + wavefront vs four chained oracle steps: the deep-halo
    // exchange, the (z, t) tiles, and the custom radius-3 band compose
    let d = CartDecomp::new(1, 2, 2);
    let mut want = g.clone();
    for _ in 0..4 {
        want = oracle(&table, &want);
    }
    for threads in env_workers() {
        let drv = Driver::new(threads, p.clone()).with_time_block(2).with_wavefront(3, 2);
        let (got, stats) = drv.multirank_sweep(&spec, &g, &d, &Backend::sdma(), 4);
        assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
        assert!(stats.exchanged_bytes > 0, "threads={threads}: no halo traffic recorded");
    }
}

#[test]
fn custom_tables_compose_with_the_wire_codecs() {
    let p = Platform::paper();
    let mut rng = XorShift::new(0xABCD);
    let table = random_star(&mut rng, 2);
    let spec = StencilSpec::from_table(&table);
    let g = Grid3::random(12, 12, 12, 0x11);
    let d = CartDecomp::new(1, 2, 2);
    let steps = 3usize;
    // unit gain ⇒ every level stays ≤ the initial magnitude, and the
    // lossy drift bound is simply rounds · (rel·M + abs)
    let m = g.data.iter().fold(0f32, |a, &x| a.max(x.abs())) as f64;
    for threads in env_workers() {
        let base = Driver::new(threads, p.clone());
        let (want, ws) = base.multirank_sweep(&spec, &g, &d, &Backend::sdma(), steps);
        for codec in env_codecs() {
            let drv = Driver::new(threads, p.clone()).with_halo_codec(codec);
            let (got, stats) = drv.multirank_sweep(&spec, &g, &d, &Backend::sdma(), steps);
            match codec {
                HaloCodec::F32 => {
                    assert_eq!(got.data, want.data, "f32 codec diverged on a custom table");
                    assert_eq!(stats.exchanged_bytes, ws.exchanged_bytes);
                }
                HaloCodec::Bf16 | HaloCodec::F16 => {
                    assert_eq!(stats.exchanged_bytes * 2, ws.exchanged_bytes);
                    let (rel, abs) = match codec {
                        HaloCodec::Bf16 => (0.00390625, 0.0), // 2⁻⁸
                        _ => (0.00048828125, 2.9802322387695313e-8), // 2⁻¹¹, 2⁻²⁵
                    };
                    let budget = steps as f64 * (rel * m + abs);
                    let diff = max_diff(&got.data, &want.data);
                    assert!(
                        diff <= budget,
                        "{} threads={threads}: drift {diff:e} over unit-gain budget {budget:e}",
                        codec.name()
                    );
                }
            }
        }
    }
}

#[test]
fn malformed_custom_specs_are_rejected_with_segment_and_grammar() {
    for bad in [
        "custom:star:r2:1,2",                          // wrong star tap count
        "custom:blob:r2:1,2,1,2,1",                    // unknown pattern
        "custom:star:r0:1",                            // zero radius
        "custom:star:rX:1,2,1",                        // unparsable radius
        "custom:box:2d:r1:1,2,3",                      // wrong box tensor size
        "custom:star:r1:1,inf,1",                      // non-finite coefficient
        "custom:star:r1:1,two,1",                      // non-numeric token
        "custom:star:r1:file=/nonexistent/coeffs.txt", // unreadable file
        "custom:star:r1",                              // missing taps
        "custom:",                                     // empty grammar
    ] {
        let err = StencilSpec::parse(bad).expect_err(bad);
        assert_eq!(err.what, "custom stencil table", "{bad}");
        assert!(err.detail.is_some(), "{bad}: reject must carry the failing segment");
        assert!(err.to_string().contains("custom:<star|box>"), "{bad}: grammar not shown");
    }
    // and the CLI-visible inline grammar still round-trips a good spec
    let spec = StencilSpec::parse("custom:star:r1:0.25,0.5,0.25").unwrap();
    assert_eq!((spec.pattern, spec.ndim, spec.radius), (Pattern::Star, 3, 1));
}
