//! Aliasing-model regression suite — the test target the CI `miri` job
//! runs under stacked borrows (`cargo +nightly miri test --test
//! aliasing`).
//!
//! Everything here drives the *real* parallel paths (persistent worker
//! runtime, tile claims, overlapped halo exchange) on grids small
//! enough for Miri.  Only `Driver`-owned runtimes are used: their
//! workers join on drop, so the interpreted process exits with no live
//! threads.  The `#[cfg(miri)]` switches keep the Miri subset ≤ 8³
//! while native runs get slightly larger grids and an extra fuzz pass.

use mmstencil::coordinator::driver::Driver;
use mmstencil::coordinator::exchange::Backend;
use mmstencil::coordinator::tiles::Strategy;
use mmstencil::grid::{CartDecomp, Grid3, ParGrid3, ParSlice};
use mmstencil::simulator::Platform;
use mmstencil::stencil::matrix_unit::{self, BlockDims};
use mmstencil::stencil::{naive, StencilSpec};
use mmstencil::util::prop::assert_allclose;

// ---------------------------------------------------------------------------
// (a) parallel sweeps through the runtime vs the naive oracle
// ---------------------------------------------------------------------------

#[test]
fn parallel_star_sweep_is_bitwise_equal_to_naive() {
    // 8³ with r = 4: no wrap-free interior exists (nz ≤ 2r), so every
    // point takes the wrapped path, whose accumulation order is
    // identical to naive's — the parallel sweep must be *bitwise* equal.
    let spec = StencilSpec::star3d(4);
    let g = Grid3::random(8, 8, 8, 42);
    let want = naive::apply3(&spec, &g);
    let d = Driver::new(2, Platform::paper());
    for strat in [Strategy::Square, Strategy::SnoopAware] {
        let (got, stats) = d.sweep(&spec, &g, strat);
        assert_eq!(got.as_slice(), want.as_slice(), "{strat:?} diverged");
        assert!(stats.pool.tasks > 0);
    }
}

#[test]
fn parallel_box_sweep_is_bitwise_equal_to_naive() {
    // same all-boundary construction for the box pattern: 4³ ≤ 2r at
    // r = 2 keeps every point on the order-preserving wrap path
    let spec = StencilSpec::box3d(2);
    let g = Grid3::random(4, 4, 4, 7);
    let want = naive::apply3(&spec, &g);
    let d = Driver::new(2, Platform::paper());
    let (got, _) = d.sweep(&spec, &g, Strategy::SnoopAware);
    assert_eq!(got.as_slice(), want.as_slice());
}

#[test]
fn interior_fast_path_sweep_matches_naive() {
    // a grid with a wrap-free interior exercises the blocked row path
    // through the tile views (fp reassociation → tolerance, not bits)
    #[cfg(miri)]
    let (n, threads) = (8, 2);
    #[cfg(not(miri))]
    let (n, threads) = (12, 4);
    let spec = StencilSpec::star3d(2);
    let g = Grid3::random(n, n, n, 5);
    let want = naive::apply3(&spec, &g);
    let d = Driver::new(threads, Platform::paper());
    let (got, _) = d.sweep(&spec, &g, Strategy::SnoopAware);
    assert_allclose(got.as_slice(), want.as_slice(), 1e-4, 1e-5);
}

#[test]
fn multirank_overlapped_step_matches_naive() {
    // the overlapped SDMA step runs the exchange as a pool task writing
    // halo frames through claims while deep-interior tasks read the
    // same storage — the exact concurrency Miri must accept
    #[cfg(miri)]
    let (n, steps, decomp) = (6, 1, CartDecomp::new(1, 1, 2));
    #[cfg(not(miri))]
    let (n, steps, decomp) = (12, 2, CartDecomp::new(1, 2, 2));
    let spec = StencilSpec::star3d(1);
    let g = Grid3::random(n, n, n, 11);
    let mut want = g.clone();
    for _ in 0..steps {
        want = naive::apply3(&spec, &want);
    }
    let d = Driver::new(2, Platform::paper());
    for backend in [Backend::sdma(), Backend::mpi()] {
        let (got, stats) = d.multirank_sweep(&spec, &g, &decomp, &backend, steps);
        assert_allclose(got.as_slice(), want.as_slice(), 1e-3, 1e-4);
        assert!(stats.exchanged_bytes > 0, "{}", backend.name());
    }
}

#[test]
fn fused_multirank_sweep_is_bitwise_the_classic_path() {
    // the temporal-blocking path under the aliasing model: kk·r-deep
    // halo claims, arena-checked-out double buffers, trapezoid
    // sub-step views ping-ponging between the two storages — exactly
    // the concurrency Miri must accept.  Depth comes from
    // MMSTENCIL_TIME_BLOCK (default 2 so the fused path is always
    // exercised; CI adds an env-selected depth-3 run).
    let k: usize = std::env::var("MMSTENCIL_TIME_BLOCK")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2);
    #[cfg(miri)]
    let (n, steps, decomp) = (6, 2, CartDecomp::new(1, 1, 2));
    #[cfg(not(miri))]
    let (n, steps, decomp) = (12, 4, CartDecomp::new(1, 2, 2));
    let spec = StencilSpec::star3d(1);
    let g = Grid3::random(n, n, n, 0xF5D);
    let classic = Driver::new(2, Platform::paper());
    let (want, _) = classic.multirank_sweep(&spec, &g, &decomp, &Backend::sdma(), steps);
    let fused = Driver::new(2, Platform::paper()).with_time_block(k);
    for backend in [Backend::sdma(), Backend::mpi()] {
        let (got, stats) = fused.multirank_sweep(&spec, &g, &decomp, &backend, steps);
        assert_eq!(
            got.as_slice(),
            want.as_slice(),
            "time_block={k} {} diverged",
            backend.name()
        );
        assert!(stats.comm_rounds <= steps as u64);
    }
}

#[test]
fn wavefront_tiled_fused_sweep_is_bitwise_the_classic_path() {
    // the PR 8 wavefront path under the aliasing model: both ping-pong
    // storages held open as ParGrid3 for a whole band while ledger
    // tasks claim disjoint (z, t) tile views and read the other storage
    // as GridSrc — the read-vs-claim concurrency Miri must accept
    #[cfg(miri)]
    let (n, steps, decomp, threads) = (6, 2, CartDecomp::new(1, 1, 2), 2);
    #[cfg(not(miri))]
    let (n, steps, decomp, threads) = (12, 4, CartDecomp::new(1, 2, 2), 4);
    let spec = StencilSpec::star3d(1);
    let g = Grid3::random(n, n, n, 0xFADE);
    let classic = Driver::new(threads, Platform::paper());
    let (want, _) = classic.multirank_sweep(&spec, &g, &decomp, &Backend::sdma(), steps);
    for (tile, wf) in [(2usize, 1usize), (3, 2)] {
        let drv = Driver::new(threads, Platform::paper())
            .with_time_block(2)
            .with_wavefront(tile, wf);
        let (got, stats) = drv.multirank_sweep(&spec, &g, &decomp, &Backend::sdma(), steps);
        assert_eq!(
            got.as_slice(),
            want.as_slice(),
            "tile={tile} wf={wf} diverged from the classic path"
        );
        assert!(stats.comm_rounds <= steps as u64);
    }
}

#[test]
fn parallel_matrix_unit_sweep_is_bitwise_serial_with_exact_counts() {
    // the PR 3 parallel matrix-unit sweep: z-slab TileViewMut claims on
    // the persistent runtime, per-task Counts merged by reduction.
    // Block dims chosen so both the zero-copy interior window path and
    // the arena-packed boundary path run even on the Miri-sized grid
    // (vl = 3 puts block origins at 3 and 6: origin ≥ r and
    // origin + vl + r ≤ n hold on n = 8 with r = 1).
    #[cfg(miri)]
    let n = 8;
    #[cfg(not(miri))]
    let n = 12;
    let dims = BlockDims { vl: 3, vz: 2 };
    let d = Driver::new(2, Platform::paper());
    for spec in [StencilSpec::star3d(1), StencilSpec::box3d(1)] {
        let g = Grid3::random(n, n, n, 0xBEEF);
        let (want, cw) = matrix_unit::apply3(&spec, &g, dims);
        let (got, cg) = matrix_unit::apply3_on(d.runtime(), &spec, &g, dims, 2);
        assert_eq!(got.as_slice(), want.as_slice(), "parallel sweep diverged");
        assert_eq!(cg, cw, "instruction accounting diverged");
    }
}

#[cfg(not(miri))]
#[test]
fn random_region_splits_compose_to_the_full_sweep() {
    // native-only fuzz: random y-splits of the region entry point agree
    // with the whole-grid sweep
    use mmstencil::stencil::simd;
    use mmstencil::util::prop::forall;
    forall(10, 0xA11A5, |rng| {
        let spec = StencilSpec::star3d(rng.range(1, 3));
        let (nz, nx, ny) = (rng.range(4, 9), rng.range(4, 11), rng.range(6, 14));
        let g = Grid3::random(nz, nx, ny, rng.next_u64());
        let want = naive::apply3(&spec, &g);
        let mut out = Grid3::zeros(nz, nx, ny);
        {
            let pg = ParGrid3::new(&mut out);
            let cut = rng.range(1, ny);
            for (y0, y1) in [(0, cut), (cut, ny)] {
                let mut view = pg.view(0, nz, 0, nx, y0, y1);
                simd::apply3_region(&spec, &g, &mut view);
            }
        }
        assert_allclose(out.as_slice(), want.as_slice(), 1e-4, 1e-5);
    });
}

// ---------------------------------------------------------------------------
// (b) overlap claims panic in debug builds
// ---------------------------------------------------------------------------

#[cfg(debug_assertions)]
mod overlap_guard {
    use super::*;

    #[test]
    #[should_panic(expected = "overlapping TileViewMut")]
    fn overlapping_tile_views_panic() {
        let mut g = Grid3::zeros(4, 4, 4);
        let pg = ParGrid3::new(&mut g);
        let _a = pg.view(0, 4, 0, 2, 0, 4);
        let _b = pg.view(0, 4, 1, 3, 0, 4); // x-ranges intersect
    }

    #[test]
    #[should_panic(expected = "overlapping TileViewMut")]
    fn full_view_conflicts_with_any_live_view() {
        let mut g = Grid3::zeros(2, 2, 2);
        let pg = ParGrid3::new(&mut g);
        let _a = pg.view(1, 2, 0, 2, 0, 2);
        let _b = pg.full_view();
    }

    #[test]
    #[should_panic(expected = "overlapping ParSlice claim")]
    fn overlapping_slice_claims_panic() {
        let mut v = vec![0.0f32; 16];
        let ps = ParSlice::new(&mut v);
        let _a = ps.claim(0, 9);
        let _b = ps.claim(8, 16);
    }

    #[test]
    fn sequential_reclaim_after_drop_is_fine() {
        let mut g = Grid3::zeros(3, 3, 3);
        let pg = ParGrid3::new(&mut g);
        {
            let _a = pg.full_view();
        }
        let _b = pg.full_view(); // claim was released on drop
    }

    #[test]
    fn disjoint_views_coexist() {
        let mut g = Grid3::zeros(4, 6, 6);
        let pg = ParGrid3::new(&mut g);
        let _a = pg.view(0, 2, 0, 6, 0, 6);
        let _b = pg.view(2, 4, 0, 6, 0, 3);
        let _c = pg.view(2, 4, 0, 6, 3, 6);
    }
}
