//! Wavefront temporal-tiling contract suite (the PR 8 tentpole's
//! acceptance tests): in-rank (z, t) diamond tiles advanced through the
//! dependency ledger must be **bitwise** the classic fused path for any
//! tile geometry, engine, worker count, and rank decomposition — with
//! exactly the same transport rounds (intra-rank tiling must never add
//! exchanges) and strictly fewer sub-step barriers at `wf > 1`.
//!
//! The transport-round assertions read the process-global counter
//! (`exchange::transport_rounds`), so every exchange-touching check
//! lives in ONE test fn (test binaries are separate processes, but
//! tests inside a binary run concurrently — a second exchange-touching
//! test here would race the counter; same pattern as
//! `rust/tests/temporal.rs`).

use mmstencil::coordinator::driver::{
    multirank_sweep, multirank_sweep_fused, multirank_sweep_wavefront, Driver,
};
use mmstencil::coordinator::exchange::{self, Backend};
use mmstencil::coordinator::temporal;
use mmstencil::grid::halo::HaloCodec;
use mmstencil::grid::{CartDecomp, Grid3};
use mmstencil::simulator::Platform;
use mmstencil::stencil::{Engine, EngineKind, StencilSpec};

#[test]
fn wavefront_stepping_is_bitwise_classic_for_every_engine_geometry_and_worker_count() {
    let p = Platform::paper();
    let spec = StencilSpec::star3d(2);
    let g = Grid3::random(12, 12, 12, 0x5EED);
    let d = CartDecomp::new(1, 2, 2);
    let steps = 4usize;
    assert_eq!(temporal::max_depth(&d, 12, 12, 12, 2), 3);

    for kind in EngineKind::ALL {
        let eng = Engine::new(kind);
        let classic = Driver::new(4, p.clone()).with_engine(eng);
        let (want, base) = classic.multirank_sweep(&spec, &g, &d, &Backend::sdma(), steps);
        assert_eq!(base.substep_barriers, 0, "{kind:?}: unfused path has no sub-step barriers");
        for k in [1usize, 2, 4] {
            let k_eff = temporal::effective_depth(k, &d, 12, 12, 12, 2);
            // plain fused reference: same result, and the stats baseline
            // the wavefront runs are compared against
            let fused = Driver::new(4, p.clone()).with_engine(eng).with_time_block(k);
            let (fwant, fstats) = fused.multirank_sweep(&spec, &g, &d, &Backend::sdma(), steps);
            assert_eq!(fwant.data, want.data, "{kind:?} k={k}: fused reference diverged");
            assert_eq!(fstats.substep_barriers, fstats.comm_rounds * (k_eff as u64 - 1));
            // tile geometries: narrow, mid-with-band-depth, and one tile
            // wider than any rank's z extent (clamps to one tile/level)
            for (tile, wf) in [(2usize, 1usize), (3, 2), (64, 1)] {
                for threads in [1usize, 2, 4] {
                    let drv = Driver::new(threads, p.clone())
                        .with_engine(eng)
                        .with_time_block(k)
                        .with_wavefront(tile, wf);
                    assert_eq!(drv.wavefront(), (tile, wf));
                    let before = exchange::transport_rounds();
                    let (got, stats) = drv.multirank_sweep(&spec, &g, &d, &Backend::sdma(), steps);
                    let rounds = exchange::transport_rounds() - before;
                    assert_eq!(
                        got.data, want.data,
                        "{kind:?} k={k} tile={tile} wf={wf} threads={threads} diverged"
                    );
                    // intra-rank tiling must not change the exchange
                    // schedule in any way
                    assert_eq!(stats.comm_rounds, fstats.comm_rounds, "{kind:?} k={k}");
                    assert_eq!(rounds, fstats.comm_rounds, "transport counter, {kind:?} k={k}");
                    assert_eq!(stats.exchanged_bytes, fstats.exchanged_bytes);
                    // one dispatch barrier per wf-deep band instead of
                    // one per sub-step level
                    let per_round = if k_eff > 1 { (k_eff - 1).div_ceil(wf) as u64 } else { 0 };
                    assert_eq!(
                        stats.substep_barriers,
                        stats.comm_rounds * per_round,
                        "{kind:?} k={k} tile={tile} wf={wf}"
                    );
                    assert!(stats.substep_barriers <= fstats.substep_barriers);
                    if wf > 1 && k_eff > 2 {
                        assert!(
                            stats.substep_barriers < fstats.substep_barriers,
                            "{kind:?} k={k} wf={wf}: barrier count must drop"
                        );
                    }
                }
            }
        }
    }

    // uneven decomposition at full depth: prime-sized grid, lopsided
    // 1×1×3 layout, k = 4 fused steps in one exchange round — the
    // barrier count drops from k−1 to ⌈(k−1)/wf⌉ while the result and
    // the transport schedule stay pinned, on both backends
    let spec1 = StencilSpec::star3d(1);
    let g2 = Grid3::random(7, 11, 13, 0xF00D);
    let d3 = CartDecomp::new(1, 1, 3);
    assert_eq!(temporal::max_depth(&d3, 7, 11, 13, 1), 4);
    let (want2, _) = multirank_sweep(&spec1, &g2, &d3, &Backend::sdma(), 4, 3, &p);
    let (flat, flat_stats) = multirank_sweep_fused(&spec1, &g2, &d3, &Backend::sdma(), 4, 3, &p, 4);
    assert_eq!(flat.data, want2.data);
    assert_eq!(flat_stats.comm_rounds, 1);
    assert_eq!(flat_stats.substep_barriers, 3, "flat fused: one barrier per sub-step level");
    for (wf, want_barriers) in [(1usize, 3u64), (2, 2), (4, 1)] {
        for backend in [Backend::sdma(), Backend::mpi()] {
            let before = exchange::transport_rounds();
            let (got, stats) =
                multirank_sweep_wavefront(&spec1, &g2, &d3, &backend, 4, 3, &p, 4, 3, wf);
            assert_eq!(got.data, want2.data, "wf={wf} {} diverged", backend.name());
            assert_eq!(stats.comm_rounds, 1, "wf={wf}");
            assert_eq!(exchange::transport_rounds() - before, 1, "wf={wf}");
            assert_eq!(stats.substep_barriers, want_barriers, "wf={wf}");
        }
    }

    // halo-codec contract (PR 9) on the wavefront path: an explicit
    // f32 codec stays bitwise with identical wire bytes, and bf16
    // halves the simulated wire without touching the transport
    // schedule or the barrier ledger
    let drv_f32 = Driver::new(3, p.clone())
        .with_time_block(4)
        .with_wavefront(3, 2)
        .with_halo_codec(HaloCodec::F32);
    let before = exchange::transport_rounds();
    let (got_f32, s_f32) = drv_f32.multirank_sweep(&spec1, &g2, &d3, &Backend::sdma(), 4);
    assert_eq!(got_f32.data, want2.data, "explicit f32 codec must stay bitwise");
    assert_eq!(s_f32.exchanged_bytes, flat_stats.exchanged_bytes);
    assert_eq!(exchange::transport_rounds() - before, 1);
    let drv_bf = Driver::new(3, p.clone())
        .with_time_block(4)
        .with_wavefront(3, 2)
        .with_halo_codec(HaloCodec::Bf16);
    let before = exchange::transport_rounds();
    let (_, s_bf) = drv_bf.multirank_sweep(&spec1, &g2, &d3, &Backend::sdma(), 4);
    assert_eq!(s_bf.exchanged_bytes * 2, flat_stats.exchanged_bytes, "bf16 wire must be half");
    assert_eq!(s_bf.comm_rounds, 1, "codec must not change the exchange schedule");
    assert_eq!(s_bf.substep_barriers, 2, "codec must not change the barrier ledger");
    assert_eq!(exchange::transport_rounds() - before, 1);
}
