//! Error-budget suite for the mixed-precision halo codecs (the PR 9
//! tentpole's acceptance tests): every budget asserted here is the
//! analytic bound DESIGN.md §15 derives, not an empirical tolerance —
//! a codec or exchange change that leaks more error than the wire
//! format mathematically permits fails these tests.
//!
//! Three layers, matching where the error enters and how it travels:
//!
//! 1. **Per-face ulp bounds** — `HaloCodec::quantize` on staged face
//!    values must stay inside the format's round-to-nearest-even
//!    budget: rel ≤ 2⁻⁸ (bf16), rel ≤ 2⁻¹¹ + 2⁻²⁵ absolute floor
//!    (f16), bitwise identity (f32).
//! 2. **Propagation** — error injected at each exchange round is
//!    amplified per step by at most the stencil's L∞ gain Σ|w|, so a
//!    multirank run under a lossy codec stays within
//!    `rounds · (rel·M + abs) · max(1, G)^steps` of its f32 twin.
//! 3. **Whole-shot energy drift** — full VTI/TTI imaging shots under
//!    the 16-bit codecs must track the f32 energy trace within the
//!    documented drift budget, and `F32` must stay bitwise.
//!
//! The CI matrix lane pins cells via `MMSTENCIL_WORKERS` /
//! `MMSTENCIL_HALO_CODEC`; unset, each test sweeps its own matrix.
//! No test here reads `exchange::transport_rounds()` (that process-
//! global counter belongs to `tests/temporal.rs` / `wavefront.rs`);
//! all byte accounting uses the per-run `StepStats::exchanged_bytes`.

use mmstencil::coordinator::driver::Driver;
use mmstencil::coordinator::exchange::Backend;
use mmstencil::grid::halo::HaloCodec;
use mmstencil::grid::{CartDecomp, Grid3};
use mmstencil::rtm::driver::{run_shot, Medium, RtmConfig};
use mmstencil::simulator::Platform;
use mmstencil::stencil::{naive, CoeffTable, StencilSpec};
use mmstencil::util::XorShift;

/// bf16 keeps 8 significand bits: relative round-trip error ≤ 2⁻⁸ for
/// any value in the f32 normal range (DESIGN.md §15).
const BF16_REL: f64 = 0.00390625; // 2⁻⁸

/// f16 keeps 11 significand bits: relative error ≤ 2⁻¹¹ in the half
/// normal range, with gradual underflow bounded by half the smallest
/// subnormal (2⁻²⁵) near zero.
const F16_REL: f64 = 0.00048828125; // 2⁻¹¹
const F16_ABS: f64 = 2.9802322387695313e-8; // 2⁻²⁵

/// Documented whole-shot energy-drift budgets: the radius-4 boundary
/// shell is quantized once per step, deep inside the absorbing sponge,
/// so per-step energy perturbation is ≤ 2·rel · (shell energy share);
/// linear accumulation over a full shot stays well under these caps
/// (derivation in DESIGN.md §15).
const BF16_SHOT_DRIFT: f64 = 0.10;
const F16_SHOT_DRIFT: f64 = 0.02;

/// (relative, absolute) per-value quantization budget of a codec.
fn codec_budget(codec: HaloCodec) -> (f64, f64) {
    match codec {
        HaloCodec::F32 => (0.0, 0.0),
        HaloCodec::Bf16 => (BF16_REL, 0.0),
        HaloCodec::F16 => (F16_REL, F16_ABS),
    }
}

/// Worker counts to sweep: `MMSTENCIL_WORKERS` pins one cell (the CI
/// matrix lane), unset sweeps the in-test default.
fn env_workers() -> Vec<usize> {
    match std::env::var("MMSTENCIL_WORKERS") {
        Ok(s) => vec![s.parse().expect("MMSTENCIL_WORKERS must be a worker count")],
        Err(_) => vec![1, 2, 4],
    }
}

/// Codecs to sweep: `MMSTENCIL_HALO_CODEC` pins one cell, unset sweeps
/// all three.
fn env_codecs() -> Vec<HaloCodec> {
    match std::env::var("MMSTENCIL_HALO_CODEC") {
        Ok(s) => vec![HaloCodec::parse(&s).expect("MMSTENCIL_HALO_CODEC must name a codec")],
        Err(_) => vec![HaloCodec::F32, HaloCodec::Bf16, HaloCodec::F16],
    }
}

/// L∞ amplification of one stencil application: Σ|w| over every tap
/// the kernel touches, clamped to ≥ 1 because the *last* exchange
/// round's injection is never attenuated below itself.
fn linf_gain(spec: &StencilSpec) -> f64 {
    let mut g = spec.star_center.abs() as f64;
    for axis in &spec.star_axes {
        g += axis.iter().map(|w| w.abs() as f64).sum::<f64>();
    }
    g += spec.box_w.iter().map(|w| w.abs() as f64).sum::<f64>();
    g.max(1.0)
}

fn maxabs(xs: &[f32]) -> f64 {
    xs.iter().fold(0f32, |a, &x| a.max(x.abs())) as f64
}

fn max_diff(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).fold(0f32, |m, (x, y)| m.max((x - y).abs())) as f64
}

/// A unit-gain custom star table (Σ|w| = 1 over the applied stencil):
/// under it the propagation bound collapses to `rounds · (rel·M + abs)`
/// — tight enough to catch a codec off by even one extra rounding.
fn unit_gain_star(radius: usize, seed: u64) -> StencilSpec {
    let mut rng = XorShift::new(seed);
    let n = 2 * radius + 1;
    let mut band: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
    let total: f32 = 3.0 * band.iter().map(|w| w.abs()).sum::<f32>();
    for w in &mut band {
        *w /= total;
    }
    StencilSpec::from_table(&CoeffTable::star(3, radius, band).expect("band is well-formed"))
}

#[test]
fn face_quantization_stays_inside_the_analytic_ulp_budgets() {
    // magnitudes spanning 2⁻²⁰..2¹⁰ — through the f16 subnormal range
    // (abs floor territory) up to mid-range normals, plus exact zeros
    let mut rng = XorShift::new(0x9E37);
    let mut vals: Vec<f32> = (0..4096)
        .map(|_| {
            let m = rng.next_f32() - 0.5;
            let e = (rng.next_f32() * 30.0 - 20.0).round();
            m * f32::exp2(e)
        })
        .collect();
    vals.extend([0.0, -0.0, 1.0, -1.0]);
    for codec in env_codecs() {
        let mut q = vals.clone();
        codec.quantize(&mut q);
        let (rel, abs) = codec_budget(codec);
        for (&x, &y) in vals.iter().zip(&q) {
            if codec == HaloCodec::F32 {
                assert_eq!(y.to_bits(), x.to_bits(), "f32 codec must be bitwise");
                continue;
            }
            let err = (y - x).abs() as f64;
            assert!(
                err <= rel * x.abs() as f64 + abs,
                "{}: {x} -> {y} (err {err:e} over budget)",
                codec.name()
            );
        }
        // idempotence: a value already on the wire grid stays put, so
        // re-packing an unpacked halo injects nothing new
        let mut q2 = q.clone();
        codec.quantize(&mut q2);
        let (a, b): (Vec<u32>, Vec<u32>) =
            (q.iter().map(|v| v.to_bits()).collect(), q2.iter().map(|v| v.to_bits()).collect());
        assert_eq!(a, b, "{}: quantization must be idempotent", codec.name());
    }
}

#[test]
fn injected_face_error_amplifies_no_faster_than_the_linf_gain() {
    let p = Platform::paper();
    let g = Grid3::random(12, 12, 12, 0xEC0);
    let d = CartDecomp::new(1, 2, 2);
    let steps = 3usize;
    // one Table-I kernel (gain ≫ 1: the bound is the analytic envelope)
    // and one unit-gain custom table (gain = 1: the bound is tight)
    for spec in [StencilSpec::star3d(2), unit_gain_star(2, 0x1D5)] {
        let gain = linf_gain(&spec);
        // M: max |field| over every time level of the f32 evolution
        let mut m = maxabs(&g.data);
        let mut cur = g.clone();
        for _ in 0..steps {
            cur = naive::apply3(&spec, &cur);
            m = m.max(maxabs(&cur.data));
        }
        for threads in env_workers() {
            for k in [1usize, 2] {
                let oracle = Driver::new(threads, p.clone()).with_time_block(k);
                let (want, ws) = oracle.multirank_sweep(&spec, &g, &d, &Backend::sdma(), steps);
                for codec in env_codecs() {
                    let drv =
                        Driver::new(threads, p.clone()).with_time_block(k).with_halo_codec(codec);
                    let (got, stats) = drv.multirank_sweep(&spec, &g, &d, &Backend::sdma(), steps);
                    if codec == HaloCodec::F32 {
                        // the lossless contract: bitwise, same wire
                        assert_eq!(got.data, want.data, "f32 codec diverged (k={k})");
                        assert_eq!(stats.exchanged_bytes, ws.exchanged_bytes);
                        continue;
                    }
                    // 16-bit wire: exactly half the bytes...
                    assert_eq!(
                        stats.exchanged_bytes * 2,
                        ws.exchanged_bytes,
                        "{} must halve the wire (k={k})",
                        codec.name()
                    );
                    // ...and error inside the propagation envelope:
                    // ≤ steps rounds inject ≤ rel·M + abs each, each
                    // amplified ≤ gain^steps before the run ends
                    let (rel, abs) = codec_budget(codec);
                    let budget = steps as f64 * (rel * m + abs) * gain.powi(steps as i32);
                    let diff = max_diff(&got.data, &want.data);
                    assert!(
                        diff <= budget,
                        "{} k={k} threads={threads}: drift {diff:e} over budget {budget:e} \
                         (gain {gain}, M {m:e})",
                        codec.name()
                    );
                    assert!(
                        diff > 0.0,
                        "{} k={k}: no error injected — the lossy path is not being exercised",
                        codec.name()
                    );
                }
            }
        }
    }
}

#[test]
fn shot_energy_drift_stays_inside_the_documented_budget() {
    let p = Platform::paper();
    for (medium, n, steps) in [(Medium::Vti, 24usize, 30usize), (Medium::Tti, 20, 24)] {
        let mut cfg = RtmConfig::small(medium);
        cfg.nz = n;
        cfg.nx = n;
        cfg.ny = n;
        cfg.steps = steps;
        cfg.threads = 2;
        let (img_f32, rep_f32) = run_shot(&cfg, &p);
        // the default config IS the f32 codec: stating it explicitly
        // must change nothing, bitwise
        let mut explicit = cfg.clone();
        explicit.halo_codec = HaloCodec::F32;
        let (img_exp, rep_exp) = run_shot(&explicit, &p);
        assert_eq!(rep_exp.energy_trace, rep_f32.energy_trace, "{medium:?}: f32 trace drifted");
        assert_eq!(img_exp.img.data, img_f32.img.data, "{medium:?}: f32 image drifted");

        let e_scale = rep_f32.energy_trace.iter().cloned().fold(0f64, f64::max);
        assert!(e_scale > 0.0, "{medium:?}: dead f32 shot");
        for codec in env_codecs() {
            let drift_budget = match codec {
                HaloCodec::F32 => continue, // the bitwise arm above
                HaloCodec::Bf16 => BF16_SHOT_DRIFT,
                HaloCodec::F16 => F16_SHOT_DRIFT,
            };
            let mut lossy = cfg.clone();
            lossy.halo_codec = codec;
            let (img_c, rep_c) = run_shot(&lossy, &p);
            assert!(
                rep_c.energy_trace.iter().all(|e| e.is_finite()),
                "{medium:?} {}: non-finite energy",
                codec.name()
            );
            // per-step energy drift: relative where the f32 energy is
            // meaningful, absolute (scaled) where it is still near zero
            for (i, (ef, ec)) in rep_f32.energy_trace.iter().zip(&rep_c.energy_trace).enumerate() {
                assert!(
                    (ec - ef).abs() <= drift_budget * ef + 1e-6 * e_scale,
                    "{medium:?} {} step {i}: energy {ec} vs f32 {ef} (budget {drift_budget})",
                    codec.name()
                );
            }
            // the image the shot exists to produce survives compression
            assert!(rep_c.image_energy > 0.0, "{medium:?} {}: empty image", codec.name());
            assert!(
                (rep_c.image_energy / rep_f32.image_energy - 1.0).abs() <= drift_budget,
                "{medium:?} {}: image energy {} vs f32 {} over budget",
                codec.name(),
                rep_c.image_energy,
                rep_f32.image_energy
            );
            assert!(img_c.correlations > 0);
        }
    }
}
