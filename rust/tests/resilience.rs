//! Chaos contract suite (the PR 10 tentpole's acceptance tests): the
//! layered resilience subsystem (`rtm::resilience` + the shot service)
//! must **contain** every injected fault class — kernel panic, halo
//! transport corruption, checkpoint-store failure, worker stall — and
//! still produce a final image **bitwise-identical** to a fault-free
//! run; a journaled survey killed mid-flight must resume without
//! re-running completed shots and image bitwise-identically; a worker
//! panic must fail only its own shot, never the process.
//!
//! Shots are tiny (20³ × a dozen steps) — the contracts under test are
//! containment and determinism, not throughput.  The CI chaos lane
//! additionally pins one env-selected (fault plan × health policy)
//! cell per run via `MMSTENCIL_FAULTS` / `MMSTENCIL_HEALTH`.

use mmstencil::grid::halo::HaloCodec;
use mmstencil::rtm::driver::{Medium, RtmConfig};
use mmstencil::rtm::resilience::{FaultPlan, HealthPolicy};
use mmstencil::rtm::service::{ShotJob, ShotStatus, SurveyConfig, SurveyRunner};
use mmstencil::simulator::Platform;
use mmstencil::stencil::EngineKind;

fn base_cfg() -> RtmConfig {
    let mut cfg = RtmConfig::small(Medium::Vti);
    cfg.nz = 20;
    cfg.nx = 20;
    cfg.ny = 20;
    cfg.steps = 12;
    cfg.threads = 2;
    cfg.engine = EngineKind::Simd;
    // a lossy wire codec, so the transport-corruption fault layer has
    // real bytes to flip (the f32 wire is bitwise and injects nothing)
    cfg.halo_codec = HaloCodec::Bf16;
    cfg
}

/// A line of shots sweeping the interior x-axis, every shot carrying
/// the same fault plan (`FaultPlan::default()` = fault-free).
fn shot_line(cfg: &RtmConfig, shots: usize, plan: FaultPlan) -> Vec<ShotJob> {
    let (sz, _, sy) = cfg.src_pos();
    let lo = cfg.sponge_width + 1;
    let hi = (cfg.nx - cfg.sponge_width).saturating_sub(2).max(lo);
    (0..shots)
        .map(|s| {
            let sx = lo + (hi - lo) * s / shots.saturating_sub(1).max(1);
            ShotJob::builder(cfg.clone()).src(sz, sx, sy).fault_plan(plan).build().unwrap()
        })
        .collect()
}

fn run(cfg: &RtmConfig, shots: usize, plan: FaultPlan, scfg: SurveyConfig) -> mmstencil::rtm::service::SurveyReport {
    let mut runner = SurveyRunner::new(scfg, &Platform::paper()).unwrap();
    runner.run(shot_line(cfg, shots, plan))
}

/// Acceptance: a seeded plan landing one retryable fault in **each** of
/// the four layers across an 8-shot survey recovers every shot, and the
/// final image is bitwise-identical to a fault-free run — twice, to pin
/// that injection decisions reproduce bit-for-bit.
#[test]
fn one_retryable_fault_per_layer_recovers_bitwise() {
    let cfg = base_cfg();
    let plan =
        FaultPlan::parse("seed=7 kernel=1@shot1 transport=1@shot2 checkpoint=1@shot3 stall=1@shot4")
            .unwrap();
    let clean = run(&cfg, 8, FaultPlan::default(), SurveyConfig::default());
    assert_eq!(clean.completed(), 8);
    assert_eq!(clean.faults_injected(), 0);
    let oracle = clean.image.unwrap();

    let mut previous: Option<Vec<f32>> = None;
    for _ in 0..2 {
        let rep = run(&cfg, 8, plan, SurveyConfig::default());
        assert_eq!(
            (rep.completed(), rep.failed()),
            (8, 0),
            "every injected fault must be contained and retried"
        );
        // kernel panic, wire corruption (caught by the health monitor),
        // and checkpoint failure each spend exactly one retry; the
        // stall only delays its attempt
        for (id, attempts) in [(1usize, 2usize), (2, 2), (3, 2), (4, 1), (0, 1)] {
            assert_eq!(rep.records[id].attempts, attempts, "shot {id}");
        }
        assert_eq!(rep.retries(), 3);
        assert_eq!(rep.faults_injected(), 4, "one injection per layer");
        let image = rep.image.unwrap();
        assert_eq!(image.img.data, oracle.img.data, "chaos survey vs fault-free image");
        assert_eq!(image.illum.data, oracle.illum.data);
        assert_eq!(image.correlations, oracle.correlations);
        if let Some(prev) = &previous {
            assert_eq!(&image.img.data, prev, "fault injection must reproduce bit-for-bit");
        }
        previous = Some(image.img.data);
    }
}

/// A worker panic (the kernel fault layer fires `panic!` inside the
/// forward pass) is contained to its own shot: with no retry budget the
/// shot fails, every other shot completes, and the process — this test
/// runner — survives to assert it.
#[test]
fn a_worker_panic_fails_only_its_shot() {
    let cfg = base_cfg();
    let mut scfg = SurveyConfig::default();
    scfg.max_retries = 0;
    let rep = run(&cfg, 5, FaultPlan::parse("kernel=1@shot2").unwrap(), scfg);
    assert_eq!((rep.completed(), rep.failed()), (4, 1));
    let r = &rep.records[2];
    assert_eq!(r.attempts, 1);
    match &r.status {
        ShotStatus::Failed(e) => {
            assert!(e.contains("injected fault (kernel)"), "panic payload lost: {e}")
        }
        s => panic!("shot 2 should have failed, got {s:?}"),
    }
    for id in [0usize, 1, 3, 4] {
        assert_eq!(rep.records[id].status, ShotStatus::Completed, "shot {id}");
    }
    assert!(rep.image.is_some(), "survivors must still accumulate an image");
}

/// Kill/resume: a journaled survey whose second half fails (simulating
/// a crash after four shots landed) resumes from the journal — the
/// completed shots are adopted bitwise with their attempt counts
/// untouched, only the missing shots re-run, and the final image is
/// bitwise-identical to an uninterrupted fault-free survey.
#[test]
fn killed_survey_resumes_bitwise_without_rerunning_completed_shots() {
    let cfg = base_cfg();
    let path = std::env::temp_dir()
        .join(format!("mmstencil_resilience_resume_{}.journal", std::process::id()));
    let _ = std::fs::remove_file(&path);

    // uninterrupted oracle
    let clean = run(&cfg, 8, FaultPlan::default(), SurveyConfig::default());
    let oracle = clean.image.as_ref().unwrap();

    // phase A: shots 4..7 carry an inexhaustible kernel fault, so only
    // the first half lands in the journal as completed
    let jobs: Vec<ShotJob> = shot_line(&cfg, 8, FaultPlan::default())
        .into_iter()
        .take(4)
        .chain(
            shot_line(&cfg, 8, FaultPlan::parse("kernel=9").unwrap()).into_iter().skip(4),
        )
        .collect();
    let mut runner = SurveyRunner::new(SurveyConfig::default(), &Platform::paper()).unwrap();
    let partial = runner.run_journaled(jobs, &path).unwrap();
    assert_eq!((partial.completed(), partial.failed()), (4, 4));
    let first_half_attempts: Vec<usize> =
        partial.records[..4].iter().map(|r| r.attempts).collect();

    // phase B: a fresh runner resumes the journal with healthy jobs
    // (the "hardware fault" cleared with the restart)
    let mut runner = SurveyRunner::new(SurveyConfig::default(), &Platform::paper()).unwrap();
    let resumed = runner.resume(shot_line(&cfg, 8, FaultPlan::default()), &path).unwrap();
    assert_eq!((resumed.completed(), resumed.failed()), (8, 0));
    assert_eq!(resumed.resumed_shots(), 4);
    for (id, r) in resumed.records.iter().enumerate() {
        if id < 4 {
            assert!(r.resumed, "journaled shot {id} must be adopted, not re-run");
            assert_eq!(r.attempts, first_half_attempts[id], "shot {id} attempts changed");
            assert!(r.report.is_none(), "adopted shots carry no fresh perf report");
        } else {
            assert!(!r.resumed, "failed shot {id} must re-run");
        }
    }
    let image = resumed.image.unwrap();
    assert_eq!(image.img.data, oracle.img.data, "resumed survey vs uninterrupted image");
    assert_eq!(image.illum.data, oracle.illum.data);
    assert_eq!(image.correlations, oracle.correlations);

    // a mismatched shot count is a refused resume, not silent corruption
    let mut runner = SurveyRunner::new(SurveyConfig::default(), &Platform::paper()).unwrap();
    let err = runner.resume(shot_line(&cfg, 5, FaultPlan::default()), &path).unwrap_err();
    assert!(err.to_string().contains("records 8 shots"), "{err}");
    let _ = std::fs::remove_file(&path);
}

/// The wavefield health monitor catches wire corruption (NaN smuggled
/// through a lossy halo exchange) and routes it per policy: `abort_shot`
/// fails the shot terminally, `retry` recovers bitwise, and
/// `fallback_f32_codec` recovers on a lossless re-attempt.
#[test]
fn health_policies_route_wire_corruption_as_documented() {
    let cfg = base_cfg();
    let plan = FaultPlan::parse("transport=1@shot1").unwrap();
    let clean = run(&cfg, 3, FaultPlan::default(), SurveyConfig::default());
    let oracle = clean.image.unwrap();

    for policy in [HealthPolicy::AbortShot, HealthPolicy::Retry, HealthPolicy::FallbackF32Codec] {
        let mut scfg = SurveyConfig::default();
        scfg.health = policy;
        let rep = run(&cfg, 3, plan, scfg);
        let r = &rep.records[1];
        match policy {
            HealthPolicy::AbortShot => {
                assert_eq!((rep.completed(), rep.failed()), (2, 1));
                assert_eq!(r.attempts, 1, "abort_shot must not spend retries");
                match &r.status {
                    ShotStatus::Failed(e) => {
                        assert!(e.contains("health policy abort_shot"), "{e}");
                        assert!(e.contains("wavefield energy"), "{e}");
                    }
                    s => panic!("expected abort, got {s:?}"),
                }
            }
            HealthPolicy::Retry => {
                assert_eq!((rep.completed(), rep.failed()), (3, 0));
                assert_eq!(r.attempts, 2);
                let image = rep.image.unwrap();
                assert_eq!(image.img.data, oracle.img.data, "retry must recover bitwise");
            }
            HealthPolicy::FallbackF32Codec => {
                // the re-attempt runs on the lossless f32 wire, so the
                // shot completes but is NOT bitwise the bf16 oracle —
                // that trade is the policy's contract
                assert_eq!((rep.completed(), rep.failed()), (3, 0));
                assert_eq!(r.attempts, 2);
                assert!(rep.image.is_some());
            }
        }
    }
}

/// CI matrix cell: when the chaos lane pins a fault plan and health
/// policy via the environment, drive them through a 4-shot survey and
/// hold the policy-specific containment contract.  Without the env
/// vars (a plain `cargo test`) this is a no-op.
#[test]
fn env_pinned_chaos_cell_is_contained() {
    let Ok(spec) = std::env::var("MMSTENCIL_FAULTS") else { return };
    let plan = FaultPlan::parse(&spec).expect("MMSTENCIL_FAULTS must parse");
    let policy = HealthPolicy::parse(
        &std::env::var("MMSTENCIL_HEALTH").unwrap_or_else(|_| "retry".into()),
    )
    .expect("MMSTENCIL_HEALTH must parse");

    let cfg = base_cfg();
    let shots = 4;
    let mut scfg = SurveyConfig::default();
    scfg.health = policy;
    let rep = run(&cfg, shots, plan, scfg);
    // containment: every shot reaches a terminal state (the survey
    // never wedges) and the survivors image
    assert_eq!(rep.records.len(), shots);
    assert!(rep.completed() + rep.failed() == shots);
    assert!(rep.completed() > 0, "the whole survey died under {spec:?}");
    assert!(rep.image.is_some());
    match policy {
        // abort_shot may fail health-tripped shots; nothing else may fail
        HealthPolicy::AbortShot => {}
        _ => assert_eq!(
            rep.failed(),
            0,
            "retryable single faults must recover under {policy:?}: {:?}",
            rep.records.iter().map(|r| &r.status).collect::<Vec<_>>()
        ),
    }
}
