//! Integration: PJRT artifacts (Pallas L1 / jnp L2, AOT-lowered) must
//! agree with the rust-native stencil engines — the cross-layer
//! correctness contract of the whole stack.
//!
//! Requires `make artifacts`; tests skip (with a message) if the artifact
//! directory is absent so `cargo test` stays runnable pre-build.

use mmstencil::grid::{Grid2, Grid3};
use mmstencil::runtime::{Runtime, Tensor};
use mmstencil::stencil::{matrix_unit, naive, StencilSpec};
use mmstencil::util::prop::assert_allclose;

fn runtime() -> Option<Runtime> {
    match Runtime::open_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping artifact test (run `make artifacts`): {e:#}");
            None
        }
    }
}

/// Extract a periodic halo cube around block (z0,x0,y0) as a Tensor.
fn halo_cube(
    g: &Grid3,
    z0: isize,
    x0: isize,
    y0: isize,
    bz: usize,
    bx: usize,
    by: usize,
    r: usize,
) -> Tensor {
    let data = g.extract_wrap(
        z0 - r as isize,
        x0 - r as isize,
        y0 - r as isize,
        bz + 2 * r,
        bx + 2 * r,
        by + 2 * r,
    );
    Tensor::new(vec![bz + 2 * r, bx + 2 * r, by + 2 * r], data)
}

#[test]
fn star3d_r4_block_matches_native() {
    let Some(rt) = runtime() else { return };
    let spec = StencilSpec::star3d(4);
    let g = Grid3::random(8, 32, 32, 42);
    let want = naive::apply3(&spec, &g);
    // run the Pallas block operator at block (4..8, 16..32, 0..16)
    let (z0, x0, y0) = (4usize, 16usize, 0usize);
    let input = halo_cube(&g, z0 as isize, x0 as isize, y0 as isize, 4, 16, 16, 4);
    let out = rt.execute("star3d_r4_block", &[input]).unwrap();
    assert_eq!(out[0].shape, vec![4, 16, 16]);
    let mut expect = Vec::new();
    for z in 0..4 {
        for x in 0..16 {
            for y in 0..16 {
                expect.push(want.get(z0 + z, x0 + x, y0 + y));
            }
        }
    }
    assert_allclose(&out[0].data, &expect, 2e-4, 2e-5);
}

#[test]
fn star3d_r2_block_matches_native() {
    let Some(rt) = runtime() else { return };
    let spec = StencilSpec::star3d(2);
    let g = Grid3::random(8, 32, 32, 43);
    let want = naive::apply3(&spec, &g);
    let input = halo_cube(&g, 0, 0, 0, 4, 16, 16, 2);
    let out = rt.execute("star3d_r2_block", &[input]).unwrap();
    let mut expect = Vec::new();
    for z in 0..4 {
        for x in 0..16 {
            for y in 0..16 {
                expect.push(want.get(z, x, y));
            }
        }
    }
    assert_allclose(&out[0].data, &expect, 2e-4, 2e-5);
}

#[test]
fn box3d_blocks_match_native() {
    let Some(rt) = runtime() else { return };
    for r in [1usize, 2] {
        let spec = StencilSpec::box3d(r);
        let g = Grid3::random(8, 32, 32, 44 + r as u64);
        let want = naive::apply3(&spec, &g);
        let input = halo_cube(&g, 0, 0, 0, 4, 16, 16, r);
        let out = rt.execute(&format!("box3d_r{r}_block"), &[input]).unwrap();
        let mut expect = Vec::new();
        for z in 0..4 {
            for x in 0..16 {
                for y in 0..16 {
                    expect.push(want.get(z, x, y));
                }
            }
        }
        assert_allclose(&out[0].data, &expect, 2e-4, 2e-5);
    }
}

#[test]
fn star2d_and_box2d_blocks_match_native() {
    let Some(rt) = runtime() else { return };
    for (name, spec) in [
        ("star2d_r2_block", StencilSpec::star2d(2)),
        ("star2d_r4_block", StencilSpec::star2d(4)),
        ("box2d_r2_block", StencilSpec::box2d(2)),
        ("box2d_r3_block", StencilSpec::box2d(3)),
    ] {
        let r = spec.radius;
        let g = Grid2::random(32, 32, 50 + r as u64);
        let want = naive::apply2(&spec, &g);
        let mut data = Vec::new();
        for dx in 0..16 + 2 * r {
            for dy in 0..16 + 2 * r {
                data.push(g.get_wrap(dx as isize - r as isize, dy as isize - r as isize));
            }
        }
        let input = Tensor::new(vec![16 + 2 * r, 16 + 2 * r], data);
        let out = rt.execute(name, &[input]).unwrap();
        let mut expect = Vec::new();
        for x in 0..16 {
            for y in 0..16 {
                expect.push(want.get(x, y));
            }
        }
        assert_allclose(&out[0].data, &expect, 2e-4, 2e-5);
    }
}

#[test]
fn grid_artifact_matches_native_sweep() {
    let Some(rt) = runtime() else { return };
    let spec = StencilSpec::star3d(4);
    let g = Grid3::random(32, 32, 32, 60);
    let want = naive::apply3(&spec, &g);
    let input = Tensor::new(vec![32, 32, 32], g.data.clone());
    let out = rt.execute("star3d_r4_grid32", &[input]).unwrap();
    assert_allclose(&out[0].data, &want.data, 2e-4, 2e-5);
}

#[test]
fn matrix_unit_engine_matches_pallas_block() {
    // the rust emulation and the Pallas kernel implement the same
    // algorithm; both must agree with each other through the artifact
    let Some(rt) = runtime() else { return };
    let spec = StencilSpec::star3d(4);
    let g = Grid3::random(4, 16, 16, 61);
    let (mu, _) = matrix_unit::apply3(&spec, &g, matrix_unit::BlockDims::default());
    let input = halo_cube(&g, 0, 0, 0, 4, 16, 16, 4);
    let out = rt.execute("star3d_r4_block", &[input]).unwrap();
    assert_allclose(&out[0].data, &mu.data, 2e-4, 2e-5);
}

#[test]
fn transpose_block_roundtrip() {
    let Some(rt) = runtime() else { return };
    let mut rng = mmstencil::util::XorShift::new(7);
    let data = rng.normal_vec(256);
    let t = Tensor::new(vec![16, 16], data.clone());
    let out = rt.execute("transpose16_block", &[t]).unwrap();
    for i in 0..16 {
        for j in 0..16 {
            assert!((out[0].data[j * 16 + i] - data[i * 16 + j]).abs() < 1e-5);
        }
    }
}

#[test]
fn manifest_covers_all_table1_kernels() {
    let Some(rt) = runtime() else { return };
    let names = rt.artifact_names();
    for base in [
        "star2d_r2", "star2d_r4", "box2d_r2", "box2d_r3",
        "star3d_r2", "star3d_r4", "box3d_r1", "box3d_r2",
    ] {
        assert!(
            names.iter().any(|n| n.starts_with(base) && n.ends_with("_block")),
            "missing block artifact for {base}"
        );
    }
    assert!(names.contains(&"rtm_vti_r4_block".to_string()));
    assert!(names.contains(&"rtm_tti_r4_block".to_string()));
}

#[test]
fn execute_rejects_wrong_shape() {
    let Some(rt) = runtime() else { return };
    let bad = Tensor::new(vec![4, 4], vec![0.0; 16]);
    assert!(rt.execute("star3d_r4_block", &[bad]).is_err());
}

// ---------------------------------------------------------------------------
// Failure injection: the runtime must reject malformed feeds loudly, and
// the registry must surface missing artifacts as errors (not panics).
// ---------------------------------------------------------------------------

#[test]
fn execute_rejects_wrong_input_count() {
    let Some(rt) = runtime() else { return };
    let err = rt.execute("star3d_r4_block", &[]).unwrap_err();
    assert!(err.to_string().contains("expected 1 inputs"), "{err}");
}

#[test]
fn unknown_artifact_is_an_error() {
    let Some(rt) = runtime() else { return };
    let err = rt.execute("no_such_kernel", &[]).unwrap_err();
    assert!(err.to_string().contains("not in manifest"), "{err}");
}

#[test]
fn manifest_rejects_corrupt_lines() {
    use mmstencil::runtime::Manifest;
    assert!(Manifest::parse("garbage line with no pipes").is_err());
    assert!(Manifest::parse("a|b|in=bogus|out=f32[1]|meta=").is_err());
}

#[test]
fn zero_input_still_roundtrips() {
    // all-zero input → all-zero output (stencils are linear)
    let Some(rt) = runtime() else { return };
    let meta = rt.manifest.get("box3d_r2_block").unwrap().clone();
    let shape = meta.inputs[0].shape.clone();
    let n: usize = shape.iter().product();
    let out = rt.execute("box3d_r2_block", &[Tensor::new(shape, vec![0.0; n])]).unwrap();
    assert!(out[0].data.iter().all(|&v| v == 0.0));
}

#[test]
fn block_artifact_is_linear() {
    // f(ax + by) = a f(x) + b f(y) — catches any affine contamination
    let Some(rt) = runtime() else { return };
    let meta = rt.manifest.get("star3d_r2_block").unwrap().clone();
    let shape = meta.inputs[0].shape.clone();
    let g1 = Grid3::random(shape[0], shape[1], shape[2], 101);
    let g2 = Grid3::random(shape[0], shape[1], shape[2], 202);
    let (a, b) = (2.5f32, -0.75f32);
    let mix: Vec<f32> = g1.data.iter().zip(&g2.data).map(|(x, y)| a * x + b * y).collect();
    let o1 = rt.execute("star3d_r2_block", &[Tensor::new(shape.clone(), g1.data.clone())]).unwrap();
    let o2 = rt.execute("star3d_r2_block", &[Tensor::new(shape.clone(), g2.data.clone())]).unwrap();
    let om = rt.execute("star3d_r2_block", &[Tensor::new(shape.clone(), mix)]).unwrap();
    let want: Vec<f32> = o1[0].data.iter().zip(&o2[0].data).map(|(x, y)| a * x + b * y).collect();
    assert_allclose(&om[0].data, &want, 1e-4, 1e-5);
}
