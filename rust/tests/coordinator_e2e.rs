//! Integration: the L3 coordinator end-to-end — decomposition, halo
//! exchange, snoop-aware tiling, pipeline overlap, RTM application —
//! everything composed, on real data.

use mmstencil::config;
use mmstencil::coordinator::driver::{multirank_sweep, sweep};
use mmstencil::coordinator::exchange::{self, Backend};
use mmstencil::coordinator::tiles::{self, Strategy};
use mmstencil::grid::{CartDecomp, Grid3};
use mmstencil::rtm::driver::{run_shot, Medium, RtmConfig};
use mmstencil::rtm::service::{ShotJob, SurveyConfig, SurveyRunner};
use mmstencil::rtm::{media, vti};
use mmstencil::simulator::Platform;
use mmstencil::stencil::coeffs::second_deriv;
use mmstencil::stencil::{naive, StencilSpec};
use mmstencil::util::prop::{self, assert_allclose};

#[test]
fn every_kernel_sweeps_correctly_with_both_strategies() {
    let p = Platform::paper();
    for (name, spec) in StencilSpec::benchmark_suite() {
        if spec.ndim != 3 {
            continue;
        }
        let g = Grid3::random(10, 24, 24, 3);
        let want = naive::apply3(&spec, &g);
        for strat in [Strategy::Square, Strategy::SnoopAware] {
            let (got, stats) = sweep(&spec, &g, 3, strat, &p);
            assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
            assert!(stats.sim_bandwidth_util > 0.0 && stats.sim_bandwidth_util < 1.0, "{name}");
        }
    }
}

#[test]
fn multirank_all_decomps_match_naive() {
    let p = Platform::paper();
    let spec = StencilSpec::star3d(4);
    let g = Grid3::random(16, 16, 16, 5);
    let want = naive::apply3(&spec, &g);
    for d in [
        CartDecomp::new(1, 1, 1),
        CartDecomp::new(2, 1, 1),
        CartDecomp::new(1, 2, 1),
        CartDecomp::new(1, 1, 2),
        CartDecomp::new(2, 2, 1),
        CartDecomp::new(2, 2, 2),
    ] {
        for backend in [Backend::sdma(), Backend::mpi()] {
            let (got, _) = multirank_sweep(&spec, &g, &d, &backend, 1, 2, &p);
            assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
        }
    }
}

#[test]
fn multistep_multirank_stays_equal_to_sequential() {
    let p = Platform::paper();
    let spec = StencilSpec::star3d(2);
    let g = Grid3::random(12, 12, 12, 9);
    let mut want = g.clone();
    for _ in 0..3 {
        want = naive::apply3(&spec, &want);
    }
    let d = CartDecomp::new(2, 1, 2);
    let (got, stats) = multirank_sweep(&spec, &g, &d, &Backend::sdma(), 3, 2, &p);
    assert_allclose(&got.data, &want.data, 1e-3, 1e-4);
    assert!(stats.exchanged_bytes > 0);
    assert!(stats.sim_step_pipelined_s <= stats.sim_step_s + 1e-12);
}

#[test]
fn property_random_decomp_random_kernel() {
    // property test: any (pz,px,py) ≤ 2 × any 3D kernel × any grid shape
    // that fits → decomposed sweep equals the naive sweep
    let p = Platform::paper();
    prop::forall(12, 0xC0FFEE, |rng| {
        let spec = match rng.range(0, 3) {
            0 => StencilSpec::star3d(rng.range(1, 4)),
            1 => StencilSpec::box3d(rng.range(1, 2)),
            2 => StencilSpec::star3d(4),
            _ => StencilSpec::box3d(2),
        };
        let nz = 2 * rng.range(5, 9);
        let nx = 2 * rng.range(5, 9);
        let ny = 2 * rng.range(5, 9);
        let g = Grid3::random(nz, nx, ny, rng.next_u64());
        let d = CartDecomp::new(rng.range(1, 2), rng.range(1, 2), rng.range(1, 2));
        let want = naive::apply3(&spec, &g);
        let (got, _) = multirank_sweep(&spec, &g, &d, &Backend::sdma(), 1, 2, &p);
        assert_allclose(&got.data, &want.data, 1e-3, 1e-4);
    });
}

#[test]
fn tile_plans_partition_domain_exactly() {
    prop::forall(40, 77, |rng| {
        let threads = rng.range(1, 40);
        let nx = rng.range(8, 200);
        let ny = rng.range(8, 200);
        for strat in [Strategy::Square, Strategy::SnoopAware] {
            let plan = tiles::plan(strat, threads, nx, ny);
            // every cell covered exactly once
            let mut hits = vec![0u8; nx * ny];
            for t in &plan.tiles {
                for x in t.x0..t.x1 {
                    for y in t.y0..t.y1 {
                        hits[x * ny + y] += 1;
                    }
                }
            }
            assert!(hits.iter().all(|&h| h == 1), "{strat:?} {threads} {nx}x{ny}");
        }
    });
}

#[test]
fn exchange_halos_match_global_wrap() {
    // after a full exchange, every rank's halo must equal the periodic
    // neighbourhood of its block in the global grid
    let g = Grid3::random(12, 12, 12, 31);
    let d = CartDecomp::new(2, 2, 2);
    let r = 2;
    let mut grids = exchange::scatter(&g, &d, r);
    exchange::exchange(&d, &mut grids, &Backend::sdma());
    exchange::fill_halos_from_global(&g, &d, &mut grids, true);
    for rk in 0..d.ranks() {
        let b = d.block(rk, g.nz, g.nx, g.ny);
        let hg = &grids[rk];
        for z in 0..hg.nz + 2 * r {
            for x in 0..hg.nx + 2 * r {
                for y in 0..hg.ny + 2 * r {
                    let gz = b.z0 as isize + z as isize - r as isize;
                    let gx = b.x0 as isize + x as isize - r as isize;
                    let gy = b.y0 as isize + y as isize - r as isize;
                    let want = g.get_wrap(gz, gx, gy);
                    let got = hg.grid.get(z, x, y);
                    assert_eq!(got, want, "rank {rk} at ({z},{x},{y})");
                }
            }
        }
    }
}

#[test]
fn distributed_vti_step_equals_whole_grid_step() {
    // RTM across ranks: decompose all four state fields + media, exchange
    // halos, step each rank locally, recompose — must equal the global step
    let n = 16;
    let r = 4;
    let m = media::layered_vti(n, n, n, 10.0, &media::default_layers());
    let w2 = second_deriv(4);
    let mut whole = vti::VtiState::zeros(n, n, n);
    whole.inject(8, 8, 8, 1.0);
    let snapshot = whole.sh.clone();
    let mut sc = vti::VtiScratch::new(n, n, n);
    vti::step(&mut whole, &m, &w2, 1, &mut sc);

    let d = CartDecomp::new(1, 2, 2);
    let mut init = vti::VtiState::zeros(n, n, n);
    init.inject(8, 8, 8, 1.0);
    let scatter_filled = |g: &Grid3| {
        let mut hg = exchange::scatter(g, &d, r);
        exchange::exchange(&d, &mut hg, &Backend::sdma());
        exchange::fill_halos_from_global(g, &d, &mut hg, true);
        hg
    };
    let sh = scatter_filled(&init.sh);
    let sv = scatter_filled(&init.sv);
    let shp = exchange::scatter(&init.sh_prev, &d, 0);
    let svp = exchange::scatter(&init.sv_prev, &d, 0);
    let med = scatter_filled(&m.vp2dt2);
    let eps = scatter_filled(&m.eps);
    let del = scatter_filled(&m.delta);

    let mut out = Grid3::zeros(n, n, n);
    for rk in 0..d.ranks() {
        let b = d.block(rk, n, n, n);
        // local halo grids as periodic sub-problems: since every halo is
        // filled with true neighbour data and the stencil radius equals
        // the halo width, a periodic step on the extended grid computes
        // the correct interior
        let (lz, lx, ly) = (b.z1 - b.z0, b.x1 - b.x0, b.y1 - b.y0);
        let mut st = vti::VtiState {
            sh: sh[rk].grid.clone(),
            sv: sv[rk].grid.clone(),
            sh_prev: embed(&shp[rk].grid, r),
            sv_prev: embed(&svp[rk].grid, r),
        };
        let lm = media::VtiMedia {
            vp2dt2: med[rk].grid.clone(),
            eps: eps[rk].grid.clone(),
            delta: del[rk].grid.clone(),
            dt: m.dt,
            dx: m.dx,
        };
        let mut lsc = vti::VtiScratch::new(lz + 2 * r, lx + 2 * r, ly + 2 * r);
        vti::step(&mut st, &lm, &w2, 1, &mut lsc);
        // interior of the halo grid is the rank's block
        for z in 0..lz {
            for x in 0..lx {
                for y in 0..ly {
                    out.set(b.z0 + z, b.x0 + x, b.y0 + y, st.sh.get(z + r, x + r, y + r));
                }
            }
        }
    }
    assert_allclose(&out.data, &whole.sh.data, 1e-4, 1e-5);
    // sanity: the step moved the field
    assert!(whole.sh.max_abs_diff(&snapshot) > 0.0);
}

/// Embed an interior grid into a zero halo frame of width r.
fn embed(g: &Grid3, r: usize) -> Grid3 {
    let mut out = Grid3::zeros(g.nz + 2 * r, g.nx + 2 * r, g.ny + 2 * r);
    out.insert_block(r, r, r, g.nz, g.nx, g.ny, &g.data);
    out
}

#[test]
fn rtm_shot_through_config_file() {
    // config file → validated ShotJob → survey session: the config path
    // feeds the same redesigned service surface the CLI uses
    let cfg = config::from_text(
        "[rtm]\nmedium = \"vti\"\nnz = 24\nnx = 24\nny = 24\nsteps = 30\nthreads = 2\nsponge_width = 6\n\
         [survey]\nshards = 2\nqueue_capacity = 2\n",
    )
    .unwrap();
    let p = Platform::paper();
    let job = ShotJob::builder(cfg.rtm.clone()).build().expect("config already validated");
    let mut scfg = SurveyConfig::default();
    scfg.shards = cfg.survey.shards;
    scfg.queue_capacity = cfg.survey.queue_capacity;
    scfg.checkpoint = cfg.survey.checkpoint;
    let mut runner = SurveyRunner::new(scfg, &p).unwrap();
    let (image, rep) = runner.run_one(job).unwrap();
    assert!(rep.energy_trace.iter().all(|e| e.is_finite()));
    assert!(image.correlations > 0);
}

#[test]
fn rtm_both_media_images_differ() {
    // TTI tilt must change the physics measurably
    let p = Platform::paper();
    let mk = |medium| {
        let mut c = RtmConfig::small(medium);
        c.nz = 24;
        c.nx = 24;
        c.ny = 24;
        c.steps = 40;
        c.threads = 2;
        run_shot(&c, &p)
    };
    let (_, vti_rep) = mk(Medium::Vti);
    let (_, tti_rep) = mk(Medium::Tti);
    assert!(vti_rep.max_trace > 0.0 && tti_rep.max_trace > 0.0);
    assert!((vti_rep.max_trace - tti_rep.max_trace).abs() > 1e-9);
}
