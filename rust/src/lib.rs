//! # MMStencil
//!
//! A reproduction of *MMStencil: Optimizing High-order Stencils on
//! Multicore CPU using Matrix Unit* (CS.DC 2025) as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **L1** (`python/compile/kernels/`) — the matrix-unit stencil
//!   algorithm as Pallas banded-matrix contractions, AOT-lowered;
//! * **L2** (`python/compile/model.py`) — whole-grid JAX models;
//! * **L3** (this crate) — the coordinator: domain decomposition, brick
//!   layout, cache-snoop-aware multi-thread scheduling, SDMA/MPI halo
//!   exchange with pipeline overlap, the RTM application driver, and a
//!   parametric simulator of the paper's (confidential) multicore SoC.
//!
//! The L3 data flow (README has the full walkthrough):
//!
//! ```text
//! Grid3 ──ParGrid3 views──▶ engines (naive | simd | matrix_unit | matrix_gemm)
//!            │                  ▲ stencil::Engine, configured by a
//!            ▼                  │ stencil::TunePlan (stencil::tune)
//!   persistent runtime ◀──coordinator tiles / z-slabs
//!            │
//!            ▼
//!   rtm::{vti, tti} steps ──▶ RTM shots (rtm::driver)
//! ```
//!
//! See DESIGN.md for the system inventory and per-experiment index;
//! §10 documents the engine-dispatch layer and the RTM data flow.

#![warn(missing_docs)]

// The `stencil` and `rtm` trees are fully item-documented (enforced by
// the CI docs lane through `missing_docs` + `RUSTDOCFLAGS=-D warnings`);
// the remaining modules carry their ownership/aliasing contracts in the
// module headers and opt out of per-item coverage until their own docs
// pass lands.

#[allow(missing_docs)]
pub mod config;
#[allow(missing_docs)]
pub mod coordinator;
#[allow(missing_docs)]
pub mod grid;
#[allow(missing_docs)]
pub mod metrics;
pub mod rtm;
#[allow(missing_docs)]
pub mod runtime;
#[allow(missing_docs)]
pub mod simulator;
pub mod stencil;
#[allow(missing_docs)]
pub mod util;

/// The README's code samples compile and run as doctests (the CI docs
/// lane executes them with `cargo test --doc`).
#[cfg(doctest)]
#[doc = include_str!("../../README.md")]
pub struct ReadmeDoctests;
