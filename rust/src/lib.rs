//! # MMStencil
//!
//! A reproduction of *MMStencil: Optimizing High-order Stencils on
//! Multicore CPU using Matrix Unit* (CS.DC 2025) as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **L1** (`python/compile/kernels/`) — the matrix-unit stencil
//!   algorithm as Pallas banded-matrix contractions, AOT-lowered;
//! * **L2** (`python/compile/model.py`) — whole-grid JAX models;
//! * **L3** (this crate) — the coordinator: domain decomposition, brick
//!   layout, cache-snoop-aware multi-thread scheduling, SDMA/MPI halo
//!   exchange with pipeline overlap, the RTM application driver, and a
//!   parametric simulator of the paper's (confidential) multicore SoC.
//!
//! See DESIGN.md for the system inventory and per-experiment index.

pub mod config;
pub mod coordinator;
pub mod grid;
pub mod metrics;
pub mod rtm;
pub mod runtime;
pub mod simulator;
pub mod stencil;
pub mod util;
