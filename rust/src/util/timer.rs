//! Wall-clock timing helpers.

use std::time::Instant;

/// A simple stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    /// Elapsed seconds since start.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed milliseconds since start.
    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }

    pub fn restart(&mut self) -> f64 {
        let t = self.secs();
        self.start = Instant::now();
        t
    }
}

/// Time a closure, returning (seconds, result).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (f64, T) {
    let t = Timer::start();
    let out = f();
    (t.secs(), out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        let a = t.secs();
        let b = t.secs();
        assert!(b >= a);
    }

    #[test]
    fn time_it_returns_result() {
        let (secs, v) = time_it(|| 40 + 2);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
