//! ASCII / markdown table rendering for bench reports (the paper-figure
//! benches print the same rows/series the paper reports).

/// A simple table builder.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width {} != header width {}",
            cells.len(),
            self.headers.len()
        );
        self.rows.push(cells.to_vec());
        self
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as a markdown table.
    pub fn to_markdown(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        let fmt_row = |cells: &[String], w: &[usize]| -> String {
            let body: Vec<String> = cells
                .iter()
                .zip(w)
                .map(|(c, &wi)| format!("{:width$}", c, width = wi))
                .collect();
            format!("| {} |", body.join(" | "))
        };
        out.push_str(&fmt_row(&self.headers, &w));
        out.push('\n');
        let sep: Vec<String> = w.iter().map(|&wi| "-".repeat(wi)).collect();
        out.push_str(&format!("|-{}-|", sep.join("-|-")));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &w));
            out.push('\n');
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.to_markdown());
    }
}

/// Format a float with fixed precision as String (helper for rows).
pub fn f(v: f64, prec: usize) -> String {
    format!("{:.*}", prec, v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new(&["kernel", "GB/s"]);
        t.row(&["star3d".into(), "285.1".into()]);
        t.row(&["x".into(), "3.6".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| kernel | GB/s  |"));
        assert!(md.lines().count() == 4);
        let lens: Vec<usize> = md.lines().map(|l| l.len()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]), "uneven rows: {md}");
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn f_formats() {
        assert_eq!(f(3.14159, 2), "3.14");
    }
}
