//! Minimal property-testing harness (proptest is unavailable offline).
//!
//! Runs a property against `n` randomly generated cases; on failure it
//! reports the seed and case index so the exact case can be replayed:
//!
//! ```no_run
//! # // no_run: doctest binaries lack the libxla rpath in this image
//! use mmstencil::util::prop::forall;
//! forall(100, 0xBEEF, |rng| {
//!     let v = rng.range(0, 1000);
//!     assert!(v <= 1000);
//! });
//! ```

use super::rng::XorShift;

/// Run `property` against `cases` generated inputs.  Panics with the seed
/// and case index on the first failing case.
pub fn forall<F>(cases: usize, seed: u64, mut property: F)
where
    F: FnMut(&mut XorShift),
{
    for case in 0..cases {
        let case_seed = seed.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9);
        let mut rng = XorShift::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            property(&mut rng)
        }));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| err.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property failed at case {case}/{cases} (replay seed: {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Assert two f32 slices are element-wise close.
#[track_caller]
pub fn assert_allclose(got: &[f32], want: &[f32], rtol: f32, atol: f32) {
    assert_eq!(got.len(), want.len(), "length mismatch");
    let mut worst = (0usize, 0.0f32, 0.0f32, 0.0f32);
    for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
        let err = (g - w).abs();
        let bound = atol + rtol * w.abs();
        if err > bound && err - bound > worst.1 - (atol + rtol * worst.3.abs()) {
            worst = (i, err, g, w);
        }
    }
    let (i, err, g, w) = worst;
    if err > atol + rtol * w.abs() {
        panic!(
            "allclose failed at index {i}: got {g}, want {w} (|err| = {err:.3e}, \
             bound = {:.3e})",
            atol + rtol * w.abs()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(50, 1, |rng| {
            let a = rng.range(0, 10);
            let b = rng.range(0, 10);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure() {
        forall(50, 2, |rng| {
            assert!(rng.range(0, 100) < 90, "drew a large value");
        });
    }

    #[test]
    fn allclose_accepts_close() {
        assert_allclose(&[1.0, 2.0], &[1.0 + 1e-7, 2.0 - 1e-7], 1e-5, 1e-6);
    }

    #[test]
    #[should_panic(expected = "allclose failed")]
    fn allclose_rejects_far() {
        assert_allclose(&[1.0], &[1.1], 1e-5, 1e-6);
    }
}
