//! Summary statistics over benchmark samples.

/// Median of a sample (copies and sorts).
pub fn median(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Median absolute deviation (robust spread).
pub fn mad(xs: &[f64]) -> f64 {
    let m = median(xs);
    let devs: Vec<f64> = xs.iter().map(|x| (x - m).abs()).collect();
    median(&devs)
}

pub fn mean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Geometric mean (inputs must be positive).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn mad_of_constant_is_zero() {
        assert_eq!(mad(&[5.0, 5.0, 5.0]), 0.0);
    }

    #[test]
    fn geomean_of_ratios() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    fn min_max() {
        let xs = [3.0, -1.0, 7.5];
        assert_eq!(min(&xs), -1.0);
        assert_eq!(max(&xs), 7.5);
    }
}
