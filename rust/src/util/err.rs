//! Minimal error + context shim (anyhow is unavailable in the offline
//! vendor set — see DESIGN.md §7).
//!
//! Provides the small slice of the `anyhow` API the crate uses: a
//! string-backed [`Error`], a [`Result`] alias, the [`Context`]
//! extension trait for `Result`/`Option`, and the `anyhow!` / `bail!`
//! macros (exported at the crate root, imported as
//! `use crate::{anyhow, bail}`).

use std::fmt;

/// A string-backed error with optional context layers.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl Into<String>) -> Self {
        Self { msg: m.into() }
    }

    /// Wrap with an outer context layer (`context: inner`).
    pub fn wrap(self, ctx: impl fmt::Display) -> Self {
        Self { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Self::msg(e.to_string())
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(..)` for `Result` and `Option`,
/// mirroring anyhow's trait of the same name.
pub trait Context<T> {
    fn context(self, ctx: impl fmt::Display) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, ctx: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, ctx: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Build an [`Error`](crate::util::err::Error) from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($t:tt)*) => {
        $crate::util::err::Error::msg(format!($($t)*))
    };
}

/// Return early with an [`Error`](crate::util::err::Error).
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::util::err::Error::msg(format!($($t)*)))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        Err(crate::anyhow!("value {} too big", 7))
    }

    #[test]
    fn macro_formats() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "value 7 too big");
    }

    #[test]
    fn bail_returns_early() {
        fn f(x: i32) -> Result<i32> {
            if x < 0 {
                crate::bail!("negative: {x}");
            }
            Ok(x)
        }
        assert!(f(1).is_ok());
        assert!(f(-1).unwrap_err().to_string().contains("negative"));
    }

    #[test]
    fn context_layers_on_result() {
        let base: std::result::Result<(), String> = Err("inner".into());
        let e = base.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
    }

    #[test]
    fn context_on_option() {
        let n: Option<u8> = None;
        let e = n.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");
    }
}
