//! Small self-contained utilities: RNG, property-test harness, timing,
//! benchmarking, statistics, and table rendering.
//!
//! Criterion and proptest are unavailable in the offline vendor set (see
//! DESIGN.md §7), so `bench` and `prop` provide minimal, dependency-free
//! equivalents used by `benches/*` and the test suites.
//!
//! Contract: every helper here is self-contained and owns its state;
//! the only process-global pieces are the counting allocator
//! (`alloc_count`, read-only counters) and the FTZ flag helpers, which
//! mutate thread-local FP state only.

pub mod alloc_count;
pub mod bench;
pub mod err;
pub mod lowp;
pub mod parse;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
pub mod timer;

pub use parse::ParseKindError;
pub use rng::XorShift;
pub use timer::Timer;

/// Enable flush-to-zero / denormals-are-zero on x86_64 (no-op elsewhere,
/// and under Miri, which does not model the MXCSR intrinsics).
///
/// Wave propagation decays fields toward the denormal range where x86
/// FP units fall off a 10–100× performance cliff; seismic codes run FTZ
/// as standard practice (the paper's platform has no denormal penalty).
/// Call once per worker thread before a long propagation.
pub fn enable_flush_to_zero() {
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    #[allow(deprecated)]
    unsafe {
        use std::arch::x86_64::{_mm_getcsr, _mm_setcsr};
        // bit 15 = FTZ, bit 6 = DAZ
        _mm_setcsr(_mm_getcsr() | (1 << 15) | (1 << 6));
    }
}

/// Scoped variant of [`enable_flush_to_zero`]: FTZ/DAZ hold for the
/// guard's lifetime and the caller's previous MXCSR is restored on
/// drop (no-op off x86_64).  Used where a *non-worker* thread runs
/// numeric task bodies (the runtime's submitter help loop) so the
/// pool does not permanently alter an embedder thread's FP
/// environment.
pub struct FtzGuard {
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    saved: u32,
}

impl FtzGuard {
    pub fn new() -> Self {
        #[cfg(all(target_arch = "x86_64", not(miri)))]
        {
            #[allow(deprecated)]
            unsafe {
                use std::arch::x86_64::{_mm_getcsr, _mm_setcsr};
                let saved = _mm_getcsr();
                _mm_setcsr(saved | (1 << 15) | (1 << 6));
                return Self { saved };
            }
        }
        #[cfg(not(all(target_arch = "x86_64", not(miri))))]
        {
            Self {}
        }
    }
}

impl Default for FtzGuard {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for FtzGuard {
    fn drop(&mut self) {
        #[cfg(all(target_arch = "x86_64", not(miri)))]
        #[allow(deprecated)]
        unsafe {
            std::arch::x86_64::_mm_setcsr(self.saved);
        }
    }
}
