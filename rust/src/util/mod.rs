//! Small self-contained utilities: RNG, property-test harness, timing,
//! benchmarking, statistics, and table rendering.
//!
//! Criterion and proptest are unavailable in the offline vendor set (see
//! DESIGN.md §7), so `bench` and `prop` provide minimal, dependency-free
//! equivalents used by `benches/*` and the test suites.

pub mod bench;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
pub mod timer;

pub use rng::XorShift;
pub use timer::Timer;

/// Enable flush-to-zero / denormals-are-zero on x86_64 (no-op elsewhere).
///
/// Wave propagation decays fields toward the denormal range where x86
/// FP units fall off a 10–100× performance cliff; seismic codes run FTZ
/// as standard practice (the paper's platform has no denormal penalty).
/// Call once per worker thread before a long propagation.
pub fn enable_flush_to_zero() {
    #[cfg(target_arch = "x86_64")]
    #[allow(deprecated)]
    unsafe {
        use std::arch::x86_64::{_mm_getcsr, _mm_setcsr};
        // bit 15 = FTZ, bit 6 = DAZ
        _mm_setcsr(_mm_getcsr() | (1 << 15) | (1 << 6));
    }
}
