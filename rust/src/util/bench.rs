//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! Warmup + N timed reps, reporting median/MAD.  Used by `benches/*`
//! (declared `harness = false`) and the perf pass.

use super::{stats, timer::Timer};

/// One measured benchmark result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Median seconds per iteration.
    pub median_s: f64,
    /// Median absolute deviation of the per-iteration seconds.
    pub mad_s: f64,
    pub reps: usize,
}

impl BenchResult {
    /// Derived throughput given `work` units per iteration (e.g. stencil
    /// points); returns units/second.
    pub fn throughput(&self, work: f64) -> f64 {
        work / self.median_s
    }
}

/// Benchmark a closure: `warmup` untimed runs then `reps` timed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, reps: usize, mut f: F) -> BenchResult {
    assert!(reps >= 1);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Timer::start();
        f();
        samples.push(t.secs());
    }
    BenchResult {
        name: name.to_string(),
        median_s: stats::median(&samples),
        mad_s: stats::mad(&samples),
        reps,
    }
}

/// Auto-scaling variant: picks a rep count so total time ≈ `budget_s`,
/// bounded to [3, 200] reps.
pub fn bench_auto<F: FnMut()>(name: &str, budget_s: f64, mut f: F) -> BenchResult {
    let t = Timer::start();
    f(); // warmup + pilot
    let pilot = t.secs().max(1e-9);
    let reps = ((budget_s / pilot) as usize).clamp(3, 200);
    bench(name, 1, reps, f)
}

/// Pretty-print a result line (`name  median ± mad  [extra]`).
pub fn report(r: &BenchResult, extra: &str) {
    println!(
        "{:40} {:>12.6} ms ± {:>9.6} ms  ({} reps){}{}",
        r.name,
        r.median_s * 1e3,
        r.mad_s * 1e3,
        r.reps,
        if extra.is_empty() { "" } else { "  " },
        extra
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_runs() {
        let mut n = 0;
        let r = bench("t", 2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(r.reps, 5);
        assert!(r.median_s >= 0.0);
    }

    #[test]
    fn throughput_inverse_of_time() {
        let r = BenchResult { name: "x".into(), median_s: 0.5, mad_s: 0.0, reps: 1 };
        assert!((r.throughput(1.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn bench_auto_runs_at_least_once() {
        let mut n = 0;
        let r = bench_auto("t", 0.001, || n += 1);
        assert!(n >= 4); // pilot + warmup + >=3 reps
        assert!(r.reps >= 3);
    }
}
