//! Software reduced-precision scalar codecs: `bf16` (bfloat16) and
//! `f16` (IEEE 754 binary16) conversions, zero-dep and deterministic.
//!
//! The halo-compression path (`grid::halo::HaloCodec`,
//! `coordinator::exchange::exchange_views_codec`) quantizes face values
//! through these conversions before they cross a simulated NUMA link —
//! halving transport bytes per value.  `half`/`num` crates are
//! unavailable in the offline vendor set (DESIGN.md §7), so the
//! conversions are hand-rolled here with the standard round-to-nearest-
//! even (RNE) semantics the hardware formats use:
//!
//! * `bf16` is the top 16 bits of an f32 (same 8-bit exponent, 7-bit
//!   mantissa): encode rounds the dropped 16 mantissa bits RNE, decode
//!   is a lossless shift.  Relative error of a round-trip is ≤ 2⁻⁸ for
//!   any finite normal value.
//! * `f16` is IEEE binary16 (5-bit exponent, 10-bit mantissa), with
//!   gradual underflow: subnormals, ±inf, and NaN payloads are encoded
//!   per the standard; overflow rounds to ±inf.  Relative error of a
//!   round-trip is ≤ 2⁻¹¹ in the normal range, with an absolute floor
//!   of 2⁻²⁵ (half the smallest subnormal) near zero.
//!
//! Contract (pinned by the property suite below, Miri-clean): decode ∘
//! encode is the identity on every representable 16-bit pattern —
//! including NaNs — and encode is monotone on ordered finite inputs.

/// Encode an `f32` as bfloat16 bits, rounding to nearest-even.
///
/// NaNs keep their sign and top mantissa bits; if truncation would
/// silence the NaN (payload only in the dropped low bits) the quiet bit
/// is forced so the result is still a NaN.
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        let top = (bits >> 16) as u16;
        return if top & 0x007F != 0 { top } else { top | 0x0040 };
    }
    // RNE: add half of the dropped ulp, plus one more when the kept lsb
    // is odd (tie goes to even); a mantissa carry into the exponent is
    // the correct round-up (to the next binade, or to ±inf at the top)
    let round = 0x7FFF + ((bits >> 16) & 1);
    (bits.wrapping_add(round) >> 16) as u16
}

/// Decode bfloat16 bits to the `f32` they exactly represent (lossless).
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Encode an `f32` as IEEE binary16 bits, rounding to nearest-even.
///
/// Handles the full format: gradual underflow to subnormals, underflow
/// to signed zero below half the smallest subnormal, overflow to ±inf,
/// and NaN payload preservation (top 10 payload bits; the quiet bit is
/// forced if the payload would vanish).
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;
    if exp == 0xFF {
        // inf / NaN
        if man == 0 {
            return sign | 0x7C00;
        }
        let payload = (man >> 13) as u16 & 0x03FF;
        return sign | 0x7C00 | if payload != 0 { payload } else { 0x0200 };
    }
    let unbiased = exp - 127;
    if unbiased >= 16 {
        return sign | 0x7C00; // above the half range: ±inf
    }
    if unbiased >= -14 {
        // normal half: drop 13 mantissa bits with RNE
        let e16 = (unbiased + 15) as u32;
        let mut out = (e16 << 10) | (man >> 13);
        let dropped = man & 0x1FFF;
        if dropped > 0x1000 || (dropped == 0x1000 && out & 1 == 1) {
            out += 1; // carry into the exponent is the correct round-up
        }
        return sign | out as u16;
    }
    if unbiased >= -25 {
        // subnormal half: value = m·2^(unbiased-23) with the implicit
        // bit restored, re-scaled to units of 2⁻²⁴
        let m = man | 0x0080_0000;
        let shift = (-1 - unbiased) as u32; // 13..=24 dropped bits
        let mut out = m >> shift;
        let dropped = m & ((1 << shift) - 1);
        let half = 1u32 << (shift - 1);
        if dropped > half || (dropped == half && out & 1 == 1) {
            out += 1;
        }
        return sign | out as u16;
    }
    sign // below half the smallest subnormal: signed zero
}

/// Decode IEEE binary16 bits to the `f32` they exactly represent
/// (lossless: every half value — normal, subnormal, inf, NaN — has an
/// exact f32 image).
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = (h >> 10) & 0x1F;
    let man = (h & 0x03FF) as u32;
    let bits = match (exp, man) {
        (0, 0) => sign,
        (0, m) => {
            // subnormal: normalize m·2⁻²⁴ into an f32 normal
            let k = 31 - m.leading_zeros(); // msb position, 0..=9
            let e32 = k + 103; // k - 24 + 127
            sign | (e32 << 23) | ((m << (23 - k)) & 0x007F_FFFF)
        }
        (0x1F, 0) => sign | 0x7F80_0000,
        (0x1F, m) => sign | 0x7F80_0000 | (m << 13),
        (e, m) => sign | ((e as u32 + 112) << 23) | (m << 13),
    };
    f32::from_bits(bits)
}

/// Round every value to the nearest bfloat16 in place (encode + decode).
pub fn quantize_bf16(xs: &mut [f32]) {
    for x in xs {
        *x = bf16_to_f32(f32_to_bf16(*x));
    }
}

/// Round every value to the nearest binary16 in place (encode + decode).
pub fn quantize_f16(xs: &mut [f32]) {
    for x in xs {
        *x = f16_to_f32(f32_to_f16(*x));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bf16_round_trips_every_bit_pattern() {
        // decode ∘ encode is the identity on all 2^16 patterns —
        // normals, subnormals, ±0, ±inf, and every NaN payload
        for b in 0..=u16::MAX {
            let x = bf16_to_f32(b);
            let again = f32_to_bf16(x);
            assert_eq!(again, b, "bf16 pattern {b:#06x} decoded to {x}, re-encoded {again:#06x}");
        }
    }

    #[test]
    fn f16_round_trips_every_bit_pattern() {
        for h in 0..=u16::MAX {
            let x = f16_to_f32(h);
            let again = f32_to_f16(x);
            assert_eq!(again, h, "f16 pattern {h:#06x} decoded to {x}, re-encoded {again:#06x}");
        }
    }

    #[test]
    fn bf16_rounds_to_nearest_even() {
        // exactly halfway between two bf16 values: tie goes to the even
        // mantissa.  1.0 = 0x3F80_0000; the next bf16 up is 0x3F81_0000.
        assert_eq!(f32_to_bf16(f32::from_bits(0x3F80_8000)), 0x3F80); // tie → even (down)
        assert_eq!(f32_to_bf16(f32::from_bits(0x3F81_8000)), 0x3F82); // tie → even (up)
        assert_eq!(f32_to_bf16(f32::from_bits(0x3F80_8001)), 0x3F81); // above tie → up
        assert_eq!(f32_to_bf16(f32::from_bits(0x3F80_7FFF)), 0x3F80); // below tie → down
        // mantissa carry rides into the exponent: just below 2.0 rounds up
        assert_eq!(f32_to_bf16(f32::from_bits(0x3FFF_FFFF)), 0x4000);
        // the top of the f32 range rounds to +inf
        assert_eq!(f32_to_bf16(f32::MAX), 0x7F80);
        assert_eq!(f32_to_bf16(f32::MIN), 0xFF80);
    }

    #[test]
    fn f16_rne_edge_cases() {
        // ties between adjacent halves resolve to the even mantissa:
        // 1.0 = 0x3C00; half ulp at 1.0 is 2⁻¹¹
        let ulp = f32::exp2(-10.0);
        assert_eq!(f32_to_f16(1.0 + 0.5 * ulp), 0x3C00); // tie → even (down)
        assert_eq!(f32_to_f16(1.0 + 1.5 * ulp), 0x3C02); // tie → even (up)
        assert_eq!(f32_to_f16(1.0 + 0.5 * ulp + f32::EPSILON), 0x3C01);
        // overflow: max half is 65504; halfway to the next step (65520)
        // ties to even = inf, anything above goes to inf
        assert_eq!(f32_to_f16(65504.0), 0x7BFF);
        assert_eq!(f32_to_f16(65520.0), 0x7C00);
        assert_eq!(f32_to_f16(1e9), 0x7C00);
        assert_eq!(f32_to_f16(-1e9), 0xFC00);
        // underflow: half the smallest subnormal (2⁻²⁵) ties to zero,
        // anything above it rounds to the smallest subnormal 0x0001
        assert_eq!(f32_to_f16(f32::exp2(-25.0)), 0x0000);
        assert_eq!(f32_to_f16(f32::exp2(-25.0) * 1.0001), 0x0001);
        assert_eq!(f32_to_f16(f32::exp2(-24.0)), 0x0001);
        assert_eq!(f32_to_f16(-f32::exp2(-24.0)), 0x8001);
        // normal/subnormal boundary: 2⁻¹⁴ is the smallest normal
        assert_eq!(f32_to_f16(f32::exp2(-14.0)), 0x0400);
        assert_eq!(f32_to_f16(f32::exp2(-14.0) * 0.9999), 0x0400); // rounds back up
        assert_eq!(f32_to_f16(f32::exp2(-15.0)), 0x0200); // subnormal
        // inf and NaN payloads
        assert_eq!(f32_to_f16(f32::INFINITY), 0x7C00);
        assert_eq!(f32_to_f16(f32::NEG_INFINITY), 0xFC00);
        let nan = f32_to_f16(f32::NAN);
        assert_eq!(nan & 0x7C00, 0x7C00);
        assert_ne!(nan & 0x03FF, 0, "NaN must stay NaN");
        // a payload living only in the dropped low bits still yields NaN
        let low_payload_nan = f32::from_bits(0x7F80_0001);
        let h = f32_to_f16(low_payload_nan);
        assert!(f16_to_f32(h).is_nan());
    }

    #[test]
    fn signed_zeros_and_sign_preservation() {
        assert_eq!(f32_to_bf16(-0.0), 0x8000);
        assert_eq!(f32_to_bf16(0.0), 0x0000);
        assert_eq!(f32_to_f16(-0.0), 0x8000);
        assert_eq!(f32_to_f16(0.0), 0x0000);
        assert!(bf16_to_f32(0x8000).is_sign_negative());
        assert!(f16_to_f32(0x8000).is_sign_negative());
    }

    #[test]
    fn encodings_are_monotone_on_finite_inputs() {
        // walk an ordered sample of finite f32s; the encodings, compared
        // as sign-magnitude integers, must never invert the order
        let key = |b: u16| -> i32 {
            if b & 0x8000 != 0 { -((b & 0x7FFF) as i32) } else { (b & 0x7FFF) as i32 }
        };
        let mut xs: Vec<f32> = Vec::new();
        let mut v = -3.5e38f32;
        while v < 3.5e38 {
            xs.push(v);
            v = if v.abs() < 1e-30 { 1e-30 } else { v * 0.97 + f32::MIN_POSITIVE };
            if v == *xs.last().unwrap() {
                break;
            }
        }
        xs.extend([-1e4, -2.5, -1.0, -1e-3, -1e-30, 0.0, 1e-30, 1e-3, 1.0, 2.5, 1e4]);
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for w in xs.windows(2) {
            assert!(
                key(f32_to_bf16(w[0])) <= key(f32_to_bf16(w[1])),
                "bf16 not monotone at {} < {}",
                w[0],
                w[1]
            );
            assert!(
                key(f32_to_f16(w[0])) <= key(f32_to_f16(w[1])),
                "f16 not monotone at {} < {}",
                w[0],
                w[1]
            );
        }
    }

    #[test]
    fn round_trip_error_is_within_the_documented_budgets()  {
        // the analytic bounds DESIGN.md §15 derives and tests/precision.rs
        // builds on: rel ≤ 2⁻⁸ (bf16) / 2⁻¹¹ (f16) in the normal range
        let mut rng = crate::util::XorShift::new(0x1b0f);
        for _ in 0..20_000 {
            let x = (rng.next_f32() - 0.5) * 2.0e4;
            let db = bf16_to_f32(f32_to_bf16(x));
            let dh = f16_to_f32(f32_to_f16(x));
            let scale = x.abs().max(f32::MIN_POSITIVE);
            assert!((db - x).abs() / scale <= f32::exp2(-8.0), "bf16 {x} -> {db}");
            assert!(
                (dh - x).abs() <= f32::exp2(-11.0) * scale + f32::exp2(-25.0),
                "f16 {x} -> {dh}"
            );
        }
    }

    #[test]
    fn quantize_helpers_match_the_scalar_paths() {
        let src = [1.5f32, -0.003, 7.0e4, -2.0e-26, 0.0, 1.0e-8];
        let mut b = src;
        quantize_bf16(&mut b);
        let mut h = src;
        quantize_f16(&mut h);
        for (i, &x) in src.iter().enumerate() {
            assert_eq!(b[i].to_bits(), bf16_to_f32(f32_to_bf16(x)).to_bits());
            assert_eq!(h[i].to_bits(), f16_to_f32(f32_to_f16(x)).to_bits());
        }
        // quantization is idempotent: a second pass changes nothing
        let (b2, h2) = (b, h);
        let mut b3 = b2;
        quantize_bf16(&mut b3);
        let mut h3 = h2;
        quantize_f16(&mut h3);
        assert_eq!(b3.map(f32::to_bits), b2.map(f32::to_bits));
        assert_eq!(h3.map(f32::to_bits), h2.map(f32::to_bits));
    }
}
