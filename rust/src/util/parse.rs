//! Shared name-parsing error for the crate's runtime-selection trio
//! (`StencilSpec::parse`, `EngineKind::parse`,
//! `CheckpointStrategy::parse`).
//!
//! Before this module each selector returned a bare `Option` from a
//! `by_name` method, so every config/CLI call site invented its own
//! "unknown X" message and the three selectors drifted apart.
//! [`ParseKindError`] carries the rejected name, what kind of name it
//! was, and the allowed list, so an error reads identically no matter
//! which selector produced it:
//!
//! ```text
//! unknown engine "avx512" (expected one of: naive | simd | matrix_unit | matrix_gemm)
//! ```
//!
//! The `Option`-returning `by_name` shims have been removed after their
//! one-release deprecation window; `parse` is the only spelling.

use std::fmt;

/// A name that did not match any known kind.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ParseKindError {
    /// What family of names was being parsed ("engine", "stencil
    /// kernel", "checkpoint strategy") — the word after "unknown".
    pub what: &'static str,
    /// The rejected name, verbatim.
    pub name: String,
    /// The canonical names that would have parsed.
    pub allowed: &'static [&'static str],
    /// For grammar-bearing families (`custom:` stencil tables): why the
    /// value was rejected, not just that it was.  `None` for the plain
    /// fixed-menu selectors.
    pub detail: Option<String>,
}

impl ParseKindError {
    /// Build an error for `name` against the `what` family.
    pub fn new(what: &'static str, name: &str, allowed: &'static [&'static str]) -> Self {
        Self { what, name: name.to_string(), allowed, detail: None }
    }

    /// Attach the reason a grammar-bearing value failed (tap-count
    /// mismatch, bad float, unreadable file …); switches the message
    /// from the "unknown X" menu form to an "invalid X: why" form.
    pub fn with_detail(mut self, detail: impl Into<String>) -> Self {
        self.detail = Some(detail.into());
        self
    }
}

impl fmt::Display for ParseKindError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.detail {
            None => write!(
                f,
                "unknown {} {:?} (expected one of: {})",
                self.what,
                self.name,
                self.allowed.join(" | ")
            ),
            Some(d) => write!(
                f,
                "invalid {} {:?}: {d} (expected {})",
                self.what,
                self.name,
                self.allowed.join(" | ")
            ),
        }
    }
}

impl std::error::Error for ParseKindError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn message_names_the_family_and_the_allowed_list() {
        let e = ParseKindError::new("engine", "avx512", &["naive", "simd", "matrix_unit"]);
        assert_eq!(
            e.to_string(),
            "unknown engine \"avx512\" (expected one of: naive | simd | matrix_unit)"
        );
    }

    #[test]
    fn detail_switches_to_the_invalid_form() {
        let e = ParseKindError::new("custom stencil table", "custom:star:r2:1", &["custom:…"])
            .with_detail("star band needs 5 taps, got 1");
        assert_eq!(
            e.to_string(),
            "invalid custom stencil table \"custom:star:r2:1\": \
             star band needs 5 taps, got 1 (expected custom:…)"
        );
    }
}
