//! xorshift64* PRNG — deterministic, seedable, no dependencies.

/// A small fast PRNG (xorshift64*). Not cryptographic; used for test data,
/// property-test generation and synthetic workloads.
#[derive(Clone, Debug)]
pub struct XorShift {
    state: u64,
}

impl XorShift {
    pub fn new(seed: u64) -> Self {
        // avoid the all-zero fixed point
        Self { state: seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1 }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [lo, hi] (inclusive).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + (self.next_u64() as usize) % (hi - lo + 1)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fill a slice with standard-normal f32s.
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal() as f32;
        }
    }

    /// A fresh vector of standard-normal f32s.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        let mut v = vec![0.0; n];
        self.fill_normal(&mut v);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = XorShift::new(42);
        let mut b = XorShift::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = XorShift::new(1);
        let mut b = XorShift::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = XorShift::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_inclusive_bounds() {
        let mut r = XorShift::new(9);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.range(3, 5);
            assert!((3..=5).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 5;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = XorShift::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
