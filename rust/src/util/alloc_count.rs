//! Counting global allocator shared by the allocation-accounting
//! binaries (`rust/tests/alloc_free.rs`, `examples/perf_probe.rs`).
//!
//! Counts allocation *events* (alloc / alloc_zeroed / realloc), not
//! bytes — the hot-path contract under test is "how many times did we
//! hit the heap", not "how much".  Each binary installs its own
//! instance:
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: CountingAlloc = CountingAlloc;
//! let before = CountingAlloc::events();
//! ```

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static EVENTS: AtomicU64 = AtomicU64::new(0);

/// System allocator wrapper that counts allocation events process-wide.
pub struct CountingAlloc;

impl CountingAlloc {
    /// Allocation events since process start (all threads).
    pub fn events() -> u64 {
        EVENTS.load(Ordering::Relaxed)
    }
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }

    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        EVENTS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, n)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}
