//! First-class engine dispatch: one place that names the compute
//! engines, selects them at runtime (`parse`, mirroring
//! [`StencilSpec::parse`]), and fans their kernels over the
//! persistent worker runtime.
//!
//! Before this layer existed every call site hardcoded an engine
//! (`simd::apply3_region` in the coordinator driver, one closure per
//! engine in `examples/perf_probe.rs` and the benches, hand-rolled
//! derivative loops in `rtm::{vti,tti}`).  Now a single [`Engine`]
//! value carries the selection plus its tuning knobs, and the three
//! call-site families — whole-grid sweeps, per-tile region tasks, and
//! the RTM 1-D axis-derivative passes — all dispatch through it.
//!
//! Determinism contract: every parallel entry point partitions work
//! into fixed-size z-slabs (granularity [`BlockDims::vz`], never the
//! worker count), and each slab claims an exclusive
//! [`TileViewMut`](crate::grid::par::TileViewMut) and runs the same
//! per-region kernel the serial path runs.  Results are therefore
//! **bitwise identical for any `threads` value** — the property the
//! RTM engine-equivalence suite pins.  The fused entry points extend
//! the contract: [`Engine::apply3_fused`] (k ping-ponged sweeps, one
//! arena intermediate) is bitwise the k-chained sweeps, and
//! [`Engine::band_axes_into`] (independent axis passes batched into one
//! runtime dispatch — the RTM propagators' barrier-fusion path) is
//! bitwise the sequential per-pass calls.
//!
//! ```
//! use mmstencil::grid::Grid3;
//! use mmstencil::stencil::{Engine, EngineKind, StencilSpec, TunePlan};
//!
//! let spec = StencilSpec::parse("3DStarR2").unwrap();
//! let g = Grid3::random(8, 12, 12, 7);
//! let serial = Engine::new(EngineKind::MatrixUnit).apply3(&spec, &g);
//! // the plan-based surface: every knob travels in one parseable value
//! let plan = TunePlan::parse("engine=matrix_unit vl=16 vz=4 tb=1 threads=4").unwrap();
//! let par = Engine::from_plan(&plan).apply3(&spec, &g);
//! assert_eq!(serial.data, par.data); // worker count never changes bits
//! ```

use super::matrix_unit::BlockDims;
use super::tune::TunePlan;
use super::{gemm, matrix_unit, naive, simd, StencilSpec};
use crate::coordinator::{runtime, scratch};
use crate::grid::par::{GridSrc, ParGrid3, TileViewMut};
use crate::grid::Grid3;

/// The compute-engine families (see the [`super`] module docs for what
/// each one models).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum EngineKind {
    /// Direct scalar loops — the semantic oracle every other engine is
    /// checked against (the paper's "compiler baseline").
    Naive,
    /// Blocked, auto-vectorization-friendly sweeps (the paper's
    /// hand-tuned SIMD-intrinsic baseline).
    Simd,
    /// The MMStencil matrix-unit algorithm: blockwise outer-product
    /// accumulation with instruction accounting.
    MatrixUnit,
    /// The banded-matrix GEMM reformulation of the matrix-unit
    /// algorithm: a resident (2r+1)-band coefficient operand, strided
    /// panel swapping, and no intermediate-buffer round-trip
    /// ([`gemm`](super::gemm)).
    MatrixGemm,
}

impl EngineKind {
    /// Every engine kind, in oracle-first order.
    pub const ALL: [EngineKind; 4] = [
        EngineKind::Naive,
        EngineKind::Simd,
        EngineKind::MatrixUnit,
        EngineKind::MatrixGemm,
    ];

    /// Canonical names, aligned with [`ALL`](Self::ALL) — the allowed
    /// list [`parse`](Self::parse) reports on a miss.
    pub const NAMES: [&'static str; 4] = ["naive", "simd", "matrix_unit", "matrix_gemm"];

    /// Runtime selection by canonical name (`"naive"`, `"simd"`,
    /// `"matrix_unit"`, `"matrix_gemm"`) — the `StencilSpec::parse`
    /// analogue used by configs, the CLI, and the bench JSON.  Unknown
    /// names return the crate-wide
    /// [`ParseKindError`](crate::util::ParseKindError), so a typo reads
    /// the same no matter which selector rejected it.
    pub fn parse(name: &str) -> Result<Self, crate::util::ParseKindError> {
        match name {
            "naive" => Ok(EngineKind::Naive),
            "simd" => Ok(EngineKind::Simd),
            "matrix_unit" => Ok(EngineKind::MatrixUnit),
            "matrix_gemm" => Ok(EngineKind::MatrixGemm),
            _ => Err(crate::util::ParseKindError::new("engine", name, &Self::NAMES)),
        }
    }

    /// Canonical name; `parse(kind.name())` round-trips.
    pub fn name(self) -> &'static str {
        match self {
            EngineKind::Naive => "naive",
            EngineKind::Simd => "simd",
            EngineKind::MatrixUnit => "matrix_unit",
            EngineKind::MatrixGemm => "matrix_gemm",
        }
    }
}

/// A configured engine: the kind plus the tuning state the kernels
/// need.  Cheap to copy; construct once and pass by reference.
#[derive(Clone, Copy, Debug)]
pub struct Engine {
    /// Which engine implementation the kernels dispatch to.
    pub kind: EngineKind,
    /// Parallelism hint: > 1 fans fixed-size z-slabs over the global
    /// persistent runtime ([`runtime::global`]); 1 runs inline on the
    /// caller.  Never changes results (see the module docs).
    pub threads: usize,
    /// Matrix-unit block geometry; its `vz` is also the z-slab
    /// granularity every engine's parallel fan-out uses, so serial and
    /// parallel partitions coincide.
    pub dims: BlockDims,
}

impl Engine {
    /// A serial engine of `kind` with default tuning.
    pub fn new(kind: EngineKind) -> Self {
        Self { kind, threads: 1, dims: BlockDims::default() }
    }

    /// Runtime selection by canonical kind name (see
    /// [`EngineKind::parse`]).
    pub fn parse(name: &str) -> Result<Self, crate::util::ParseKindError> {
        EngineKind::parse(name).map(Self::new)
    }

    /// Configure an engine from a [`TunePlan`] — the plan-based surface
    /// every production caller (`Driver`, the RTM services, the CLI)
    /// uses instead of chaining raw knobs.  The plan's `time_block` is
    /// a sweep-scheduling knob consumed by the *caller* (fused-sweep
    /// depth), not engine state; everything else maps 1:1.
    pub fn from_plan(plan: &TunePlan) -> Self {
        Self { kind: plan.engine, threads: plan.threads.max(1), dims: plan.dims }
    }

    /// Fan `f` over fixed-size z-slab views of `out` (serial when
    /// `threads <= 1`; same partition either way).
    fn fan_zslabs<F>(&self, out: &mut Grid3, f: F)
    where
        F: Fn(&mut TileViewMut<'_>) + Sync,
    {
        let (nz, nx, ny) = out.shape();
        let vz = self.dims.vz.max(1);
        let nslabs = nz.div_ceil(vz);
        let pg = ParGrid3::new(out);
        let pg = &pg;
        let task = |i: usize| {
            let z0 = i * vz;
            let z1 = (z0 + vz).min(nz);
            let mut view = pg.view(z0, z1, 0, nx, 0, ny);
            f(&mut view);
        };
        if self.threads <= 1 || nslabs <= 1 {
            for i in 0..nslabs {
                task(i);
            }
        } else {
            runtime::global().run(self.threads, nslabs, &task);
        }
    }

    /// One full periodic sweep of `spec` over `g` through this engine.
    pub fn apply3<S: GridSrc>(&self, spec: &StencilSpec, g: &S) -> Grid3 {
        assert_eq!(spec.ndim, 3, "Engine::apply3 needs a 3D spec");
        let (nz, nx, ny) = g.shape();
        let mut out = Grid3::zeros(nz, nx, ny);
        self.fan_zslabs(&mut out, |view| self.apply3_region(spec, g, view));
        out
    }

    /// `k` fused periodic sweeps of `spec` over `g` — the
    /// temporal-blocking form of [`apply3`](Self::apply3) for a single
    /// shared-memory grid (no halos to pay, so the fusion win is purely
    /// allocation traffic and destination reuse): intermediate levels
    /// ping-pong through **one** arena-checked-out grid instead of
    /// allocating and zeroing a fresh grid per sweep.  Bitwise equal to
    /// `k` chained [`apply3`](Self::apply3) calls for any `k` and any
    /// worker count (same z-slab partition, same per-region kernels).
    pub fn apply3_fused<S: GridSrc>(&self, spec: &StencilSpec, g: &S, k: usize) -> Grid3 {
        assert!(k >= 1, "apply3_fused needs k >= 1");
        let mut out = self.apply3(spec, g);
        if k > 1 {
            let (nz, nx, ny) = g.shape();
            let mut other = scratch::grid(nz, nx, ny);
            for _ in 1..k {
                // every slab claim is fully overwritten, so the stale
                // arena contents are never observable
                self.fan_zslabs(&mut *other, |view| self.apply3_region(spec, &out, view));
                std::mem::swap(&mut out, &mut *other);
            }
        }
        out
    }

    /// Compute the claimed region of `out` from `g` — the per-tile task
    /// body of the parallel coordinator (`coordinator::driver`).  Runs
    /// serially inside the claim; parallelism is the caller's tiling.
    pub fn apply3_region<S: GridSrc>(&self, spec: &StencilSpec, g: &S, out: &mut TileViewMut<'_>) {
        match self.kind {
            EngineKind::Naive => naive::apply3_region(spec, g, out),
            EngineKind::Simd => simd::apply3_region(spec, g, out),
            EngineKind::MatrixUnit => {
                matrix_unit::apply3_region(spec, g, out, self.dims);
            }
            EngineKind::MatrixGemm => {
                gemm::apply3_region(spec, g, out, self.dims);
            }
        }
    }

    /// Second derivative along `axis` (0 = z, 1 = x, 2 = y) with
    /// periodic wrap: `out[p] = Σ_k w2[k+r]·g[p + k·axis]`.  `out` is
    /// fully overwritten; z-slabs fan over the persistent runtime.
    pub fn d2_axis_into<S: GridSrc>(&self, g: &S, w2: &[f32], axis: usize, out: &mut Grid3) {
        self.band_axis_into(g, w2, axis, out);
    }

    /// First derivative along `axis` with periodic wrap (antisymmetric
    /// band `w1`, zero centre).  `out` is fully overwritten.
    pub fn d1_axis_into<S: GridSrc>(&self, g: &S, w1: &[f32], axis: usize, out: &mut Grid3) {
        self.band_axis_into(g, w1, axis, out);
    }

    /// Allocating convenience form of [`d2_axis_into`](Self::d2_axis_into).
    pub fn d2_axis<S: GridSrc>(&self, g: &S, w2: &[f32], axis: usize) -> Grid3 {
        let (nz, nx, ny) = g.shape();
        let mut out = Grid3::zeros(nz, nx, ny);
        self.d2_axis_into(g, w2, axis, &mut out);
        out
    }

    /// Allocating convenience form of [`d1_axis_into`](Self::d1_axis_into).
    pub fn d1_axis<S: GridSrc>(&self, g: &S, w1: &[f32], axis: usize) -> Grid3 {
        let (nz, nx, ny) = g.shape();
        let mut out = Grid3::zeros(nz, nx, ny);
        self.d1_axis_into(g, w1, axis, &mut out);
        out
    }

    /// The shared 1-D band pass behind `d1`/`d2`: the band (length
    /// 2r+1, centre at index r) is applied along `axis` as a 1-D star
    /// stencil by the selected engine's axis kernel.
    fn band_axis_into<S: GridSrc>(&self, g: &S, band: &[f32], axis: usize, out: &mut Grid3) {
        assert!(axis < 3, "axis must be 0 (z), 1 (x), or 2 (y)");
        assert_eq!(band.len() % 2, 1, "band must have odd length");
        assert_eq!(g.shape(), out.shape(), "band_axis_into shape mismatch");
        self.fan_zslabs(out, |view| match self.kind {
            EngineKind::Naive => naive::d_axis_region(band, axis, g, view),
            EngineKind::Simd => simd::d_axis_region(band, axis, g, view),
            EngineKind::MatrixUnit => {
                matrix_unit::d_axis_region(band, axis, g, view, self.dims);
            }
            EngineKind::MatrixGemm => {
                gemm::d_axis_region(band, axis, g, view, self.dims);
            }
        });
    }

    /// Run several **independent** 1-D band passes as one batch: all
    /// slab tasks of every pass fan over the runtime in a single
    /// dispatch, so a propagator step pays one barrier per dependency
    /// level instead of one per pass (a VTI step's three derivative
    /// passes become one barrier; a TTI field's eight become two).
    ///
    /// Passes must be independent: no pass's `out` may be another
    /// pass's `src` (debug-asserted).  Each pass gets exactly the slab
    /// partition and kernels of [`d1_axis_into`](Self::d1_axis_into) /
    /// [`d2_axis_into`](Self::d2_axis_into), and the serial path runs
    /// the passes in order — results are **bitwise identical** to
    /// sequential per-pass calls for any worker count.
    pub fn band_axes_into(&self, passes: &mut [AxisPass<'_>]) {
        #[cfg(debug_assertions)]
        {
            let srcs: Vec<*const f32> = passes.iter().map(|p| p.src.data.as_ptr()).collect();
            for p in passes.iter() {
                let out_ptr: *const f32 = p.out.data.as_ptr();
                assert!(
                    !srcs.contains(&out_ptr),
                    "band_axes_into passes must be independent (an out aliases a src)"
                );
            }
        }
        let vz = self.dims.vz.max(1);
        struct Job<'a> {
            src: &'a Grid3,
            band: &'a [f32],
            axis: usize,
            pg: ParGrid3<'a>,
            nz: usize,
            nx: usize,
            ny: usize,
            first_task: usize,
        }
        let mut jobs: Vec<Job<'_>> = Vec::with_capacity(passes.len());
        let mut total = 0usize;
        for p in passes.iter_mut() {
            assert!(p.axis < 3, "axis must be 0 (z), 1 (x), or 2 (y)");
            assert_eq!(p.band.len() % 2, 1, "band must have odd length");
            assert_eq!(p.src.shape(), p.out.shape(), "band_axes_into shape mismatch");
            let (nz, nx, ny) = p.src.shape();
            jobs.push(Job {
                src: p.src,
                band: p.band,
                axis: p.axis,
                pg: ParGrid3::new(p.out),
                nz,
                nx,
                ny,
                first_task: total,
            });
            total += nz.div_ceil(vz);
        }
        let jobs = &jobs;
        let task = |i: usize| {
            let j = jobs
                .iter()
                .rev()
                .find(|j| j.first_task <= i)
                .expect("task index maps to a job");
            let s = i - j.first_task;
            let z0 = s * vz;
            let z1 = (z0 + vz).min(j.nz);
            let mut view = j.pg.view(z0, z1, 0, j.nx, 0, j.ny);
            match self.kind {
                EngineKind::Naive => naive::d_axis_region(j.band, j.axis, j.src, &mut view),
                EngineKind::Simd => simd::d_axis_region(j.band, j.axis, j.src, &mut view),
                EngineKind::MatrixUnit => {
                    matrix_unit::d_axis_region(j.band, j.axis, j.src, &mut view, self.dims);
                }
                EngineKind::MatrixGemm => {
                    gemm::d_axis_region(j.band, j.axis, j.src, &mut view, self.dims);
                }
            }
        };
        if self.threads <= 1 || total <= 1 {
            for i in 0..total {
                task(i);
            }
        } else {
            runtime::global().run(self.threads, total, &task);
        }
    }
}

/// One 1-D band pass of a fused batch — see [`Engine::band_axes_into`].
pub struct AxisPass<'a> {
    /// Input grid (periodic along `axis`).
    pub src: &'a Grid3,
    /// Band weights, odd length 2r+1, centre at index r.
    pub band: &'a [f32],
    /// Axis the band runs along: 0 = z, 1 = x, 2 = y.
    pub axis: usize,
    /// Output grid, fully overwritten; must not alias any `src` in the
    /// same batch.
    pub out: &'a mut Grid3,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::coeffs::{first_deriv, second_deriv};
    use crate::util::prop::assert_allclose;

    /// Plan-built engine of `kind` with a parallelism hint — the
    /// post-redesign spelling of the old `.with_threads(t)` chain.
    fn eng(kind: EngineKind, threads: usize) -> Engine {
        Engine::from_plan(&TunePlan { engine: kind, threads, ..TunePlan::simd(threads) })
    }

    #[test]
    fn kind_names_round_trip() {
        for (kind, name) in EngineKind::ALL.into_iter().zip(EngineKind::NAMES) {
            assert_eq!(kind.name(), name, "{kind:?}");
            assert_eq!(EngineKind::parse(kind.name()), Ok(kind), "{kind:?}");
            assert_eq!(Engine::parse(kind.name()).unwrap().kind, kind);
        }
    }

    #[test]
    fn unknown_engine_names_report_the_allowed_list() {
        for bad in ["", "SIMD", "avx512", "matrix-unit", "matrix_unit_par", "naive "] {
            let err = EngineKind::parse(bad).unwrap_err();
            assert_eq!(err.what, "engine", "{bad:?}");
            assert_eq!(err.name, bad, "{bad:?}");
            assert!(
                err.to_string().contains("naive | simd | matrix_unit | matrix_gemm"),
                "{bad:?}: {err}"
            );
            assert!(Engine::parse(bad).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn plan_surface_covers_the_removed_knob_chain() {
        // the 0.3.0 knob shims (default_simd / with_threads / with_dims)
        // are gone after their one-release deprecation window; the plan
        // surface carries every knob they covered
        let plan = Engine::from_plan(&TunePlan::simd(3));
        assert_eq!(plan.kind, EngineKind::Simd);
        assert_eq!(plan.threads, 3);
        assert_eq!(plan.dims, TunePlan::simd(3).dims);
        let custom = Engine::from_plan(&TunePlan {
            engine: EngineKind::MatrixUnit,
            threads: 0, // clamps, like with_threads(0) did
            ..TunePlan::simd(1)
        });
        assert_eq!(custom.kind, EngineKind::MatrixUnit);
        assert_eq!(custom.threads, 1);
    }

    #[test]
    fn from_plan_clamps_threads_to_one() {
        let mut plan = TunePlan::simd(0);
        assert_eq!(Engine::from_plan(&plan).threads, 1);
        plan.engine = EngineKind::MatrixGemm;
        assert_eq!(Engine::from_plan(&plan).kind, EngineKind::MatrixGemm);
    }

    #[test]
    fn every_engine_matches_the_naive_oracle() {
        for (name, spec) in StencilSpec::benchmark_suite() {
            if spec.ndim != 3 {
                continue;
            }
            let g = Grid3::random(10, 18, 22, 11);
            let want = naive::apply3(&spec, &g);
            for kind in EngineKind::ALL {
                let got = Engine::new(kind).apply3(&spec, &g);
                assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
                let _ = name;
            }
        }
    }

    #[test]
    fn parallel_sweep_is_bitwise_serial() {
        let spec = StencilSpec::star3d(4);
        let g = Grid3::random(11, 20, 24, 5);
        for kind in EngineKind::ALL {
            let want = Engine::new(kind).apply3(&spec, &g);
            for threads in [2, 5] {
                let got = eng(kind, threads).apply3(&spec, &g);
                assert_eq!(got.data, want.data, "{kind:?} threads={threads}");
            }
        }
    }

    #[test]
    fn fused_sweeps_are_bitwise_the_chained_sweeps() {
        // apply3_fused(k) must equal k chained apply3 calls bit-for-bit:
        // same z-slab partition, same per-region kernels, only the
        // intermediate allocations differ
        let spec = StencilSpec::star3d(2);
        let g = Grid3::random(10, 14, 18, 77);
        for kind in EngineKind::ALL {
            for threads in [1, 3] {
                let eng = eng(kind, threads);
                let one = eng.apply3(&spec, &g);
                assert_eq!(eng.apply3_fused(&spec, &g, 1).data, one.data, "{kind:?} k=1");
                for k in [2usize, 4] {
                    let got = eng.apply3_fused(&spec, &g, k);
                    let mut want = one.clone();
                    for _ in 1..k {
                        want = eng.apply3(&spec, &want);
                    }
                    assert_eq!(got.data, want.data, "{kind:?} threads={threads} k={k}");
                }
            }
        }
    }

    #[test]
    fn batched_axis_passes_are_bitwise_the_sequential_passes() {
        // one band_axes_into dispatch == the per-pass calls, bit-for-bit
        let g1 = Grid3::random(9, 11, 13, 21);
        let g2 = Grid3::random(9, 11, 13, 22);
        let w2 = second_deriv(4);
        let w1 = first_deriv(3);
        for kind in EngineKind::ALL {
            for threads in [1, 4] {
                let eng = eng(kind, threads);
                let want = [
                    eng.d2_axis(&g1, &w2, 1),
                    eng.d2_axis(&g1, &w2, 2),
                    eng.d2_axis(&g2, &w2, 0),
                    eng.d1_axis(&g1, &w1, 0),
                ];
                let (nz, nx, ny) = g1.shape();
                let mut outs: Vec<Grid3> = (0..4).map(|_| Grid3::zeros(nz, nx, ny)).collect();
                {
                    let [a, b, c, d] = &mut outs[..] else { unreachable!() };
                    let mut passes = [
                        AxisPass { src: &g1, band: &w2, axis: 1, out: a },
                        AxisPass { src: &g1, band: &w2, axis: 2, out: b },
                        AxisPass { src: &g2, band: &w2, axis: 0, out: c },
                        AxisPass { src: &g1, band: &w1, axis: 0, out: d },
                    ];
                    eng.band_axes_into(&mut passes);
                }
                for (got, want) in outs.iter().zip(&want) {
                    assert_eq!(got.data, want.data, "{kind:?} threads={threads}");
                }
            }
        }
    }

    #[test]
    fn axis_kernels_match_the_direct_loop() {
        let g = Grid3::random(7, 9, 11, 3);
        let w2 = second_deriv(3);
        let w1 = first_deriv(4);
        for (band, is_d2) in [(&w2, true), (&w1, false)] {
            let r = band.len() as isize / 2;
            for axis in 0..3 {
                let want = Grid3::from_fn(7, 9, 11, |z, x, y| {
                    let mut acc = 0.0;
                    for k in -r..=r {
                        let (mut zz, mut xx, mut yy) = (z as isize, x as isize, y as isize);
                        match axis {
                            0 => zz += k,
                            1 => xx += k,
                            _ => yy += k,
                        }
                        acc += band[(k + r) as usize] * g.get_wrap(zz, xx, yy);
                    }
                    acc
                });
                for kind in EngineKind::ALL {
                    let eng = Engine::new(kind);
                    let got = if is_d2 {
                        eng.d2_axis(&g, band, axis)
                    } else {
                        eng.d1_axis(&g, band, axis)
                    };
                    assert_allclose(&got.data, &want.data, 1e-4, 1e-6);
                }
            }
        }
    }

    #[test]
    fn axis_kernels_are_bitwise_stable_across_threads() {
        let g = Grid3::random(13, 10, 17, 9);
        let w2 = second_deriv(4);
        for kind in EngineKind::ALL {
            for axis in 0..3 {
                let want = Engine::new(kind).d2_axis(&g, &w2, axis);
                for threads in [2, 6] {
                    let got = eng(kind, threads).d2_axis(&g, &w2, axis);
                    assert_eq!(got.data, want.data, "{kind:?} axis={axis} threads={threads}");
                }
            }
        }
    }

    #[test]
    fn small_all_boundary_grids_agree() {
        // grid shorter than the band along every axis: the axis kernels
        // run entirely on their wrapped boundary paths
        let g = Grid3::random(4, 4, 4, 2);
        let w2 = second_deriv(4);
        let want = Engine::new(EngineKind::Naive).d2_axis(&g, &w2, 1);
        for kind in [EngineKind::Simd, EngineKind::MatrixUnit, EngineKind::MatrixGemm] {
            let got = Engine::new(kind).d2_axis(&g, &w2, 1);
            assert_allclose(&got.data, &want.data, 1e-5, 1e-6);
        }
    }
}
