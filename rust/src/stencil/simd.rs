//! Blocked, vectorization-friendly engine — stands in for the paper's
//! hand-tuned SIMD-intrinsic baseline (2.5D blocking + a 16×4×2-style
//! layout-friendly sweep; see paper §V-A).
//!
//! The interior is computed with wrap-free, y-contiguous inner loops that
//! LLVM auto-vectorizes; the periodic boundary shell falls back to the
//! wrap path so results are bit-comparable with [`super::naive`] up to
//! fp reassociation.  The shell is enumerated directly as the ≤6
//! O(N²·r) slabs of `grid::shell` — never by scanning the full volume
//! with an `inside()` predicate.
//!
//! Reads go through [`GridSrc`] (a quiescent `&Grid3` *or* a `ParGrid3`
//! whose halo frame is being filled concurrently) and writes through an
//! exclusive [`TileViewMut`] claim — the per-tile contract of the
//! parallel coordinator (see `grid::par`).  Accumulator rows come from
//! the worker-local scratch arena (`coordinator::scratch`), so tiles of
//! any `ty` work (the old fixed `[f32; 512]` stack buffer made
//! `ty > 512` panic) and the steady state allocates nothing.

use super::{Pattern, StencilSpec};
use crate::coordinator::scratch;
use crate::grid::par::{GridSrc, ParGrid3, TileViewMut};
use crate::grid::{shell, Grid2, Grid3};

/// 2.5D tile used for the blocked sweep (paper's SIMD baseline uses a
/// 16×4×2 brick; the tile here is the per-core working set).
#[derive(Clone, Copy, Debug)]
pub struct Tile {
    /// Tile extent along z (slowest axis).
    pub tz: usize,
    /// Tile extent along x.
    pub tx: usize,
    /// Tile extent along y (the contiguous axis).
    pub ty: usize,
}

impl Default for Tile {
    fn default() -> Self {
        // swept in the §Perf pass (EXPERIMENTS.md): wider x-tiles keep
        // the 2r+1 x-neighbour rows resident across the y sweep
        Self { tz: 2, tx: 16, ty: 256 }
    }
}

/// Apply a 3D spec with blocked interior + wrapped boundary.
pub fn apply3(spec: &StencilSpec, g: &Grid3) -> Grid3 {
    apply3_tiled(spec, g, Tile::default())
}

/// [`apply3`] with an explicit tile shape.
pub fn apply3_tiled(spec: &StencilSpec, g: &Grid3, tile: Tile) -> Grid3 {
    assert_eq!(spec.ndim, 3);
    let r = spec.radius;
    let mut out = Grid3::zeros(g.nz, g.nx, g.ny);
    {
        let pg = ParGrid3::new(&mut out);
        let mut view = pg.full_view();
        // interior: wrap-free fast path, tiled
        if g.nz > 2 * r && g.nx > 2 * r && g.ny > 2 * r {
            let (z0, z1) = (r, g.nz - r);
            let (x0, x1) = (r, g.nx - r);
            let (y0, y1) = (r, g.ny - r);
            let mut z = z0;
            while z < z1 {
                let ze = (z + tile.tz).min(z1);
                let mut x = x0;
                while x < x1 {
                    let xe = (x + tile.tx).min(x1);
                    let mut y = y0;
                    while y < y1 {
                        let ye = (y + tile.ty).min(y1);
                        match spec.pattern {
                            Pattern::Star => star3_block(spec, g, &mut view, z, ze, x, xe, y, ye),
                            Pattern::Box => box3_block(spec, g, &mut view, z, ze, x, xe, y, ye),
                        }
                        y = ye;
                    }
                    x = xe;
                }
                z = ze;
            }
        }
        // boundary shell: wrap path over the O(N²·r) slabs only
        for b in shell::boundary_boxes(g.nz, g.nx, g.ny, r) {
            for z in b[0]..b[1] {
                for x in b[2]..b[3] {
                    for y in b[4]..b[5] {
                        view.set(z, x, y, point3_wrap(spec, g, z as isize, x as isize, y as isize));
                    }
                }
            }
        }
    }
    out
}

#[inline]
pub(crate) fn point3_wrap<S: GridSrc>(
    spec: &StencilSpec,
    g: &S,
    z: isize,
    x: isize,
    y: isize,
) -> f32 {
    let r = spec.radius as isize;
    match spec.pattern {
        Pattern::Star => {
            let (wz, wx, wy) = (&spec.star_axes[0], &spec.star_axes[1], &spec.star_axes[2]);
            let mut acc = spec.star_center * g.get_wrap(z, x, y);
            for k in -r..=r {
                if k == 0 {
                    continue;
                }
                let i = (k + r) as usize;
                acc += wz[i] * g.get_wrap(z + k, x, y);
                acc += wx[i] * g.get_wrap(z, x + k, y);
                acc += wy[i] * g.get_wrap(z, x, y + k);
            }
            acc
        }
        Pattern::Box => {
            let n = 2 * r + 1;
            let mut acc = 0.0;
            for c in 0..n {
                for a in 0..n {
                    for b in 0..n {
                        acc += spec.box_w[((c * n + a) * n + b) as usize]
                            * g.get_wrap(z + c - r, x + a - r, y + b - r);
                    }
                }
            }
            acc
        }
    }
}

/// Centre + y-axis taps of one (z, x) row of the wrap-free star:
/// `o[i] = c·row[r+i]`, then the 2r y-taps in ascending k order, as
/// shifted y-contiguous slice passes.  Shared by the coarsened
/// row-pair path and the single-row remainder of [`star3_block`] so
/// both keep the exact same per-element operation order.
#[inline(always)]
fn star3_y_phase<S: GridSrc>(
    spec: &StencilSpec,
    g: &S,
    out: &mut TileViewMut<'_>,
    z: usize,
    x: usize,
    y0: usize,
    ny: usize,
) {
    let (_, gnx, gny) = g.shape();
    let r = spec.radius;
    let wy = &spec.star_axes[2];
    let cb = (z * gnx + x) * gny + y0;
    let row = g.span(cb - r, ny + 2 * r);
    let o = out.row_mut(z, x, y0, ny);
    for i in 0..ny {
        o[i] = spec.star_center * row[r + i];
    }
    for k in 0..2 * r + 1 {
        if k == r {
            continue;
        }
        let w = wy[k];
        for i in 0..ny {
            o[i] += w * row[k + i];
        }
    }
}

/// Wrap-free star on one tile: per (z,x) row, accumulate the 2·ndim·r+1
/// contributions as shifted y-contiguous slices (auto-vectorizes).
///
/// Thread coarsening (the wavefront tile core): adjacent x-row pairs
/// share one pass over the z/x tap loop — each tap's weights, index
/// arithmetic, and loop control amortize over two live accumulator
/// rows, and the pair's independent FMA chains double the
/// register-level ILP.  The two rows never feed each other, and every
/// element keeps the single-row accumulation order (centre, y-taps
/// ascending, then fused z+x taps ascending), so the coarsened path
/// is bitwise identical to the remainder path.
#[inline]
fn star3_block<S: GridSrc>(
    spec: &StencilSpec,
    g: &S,
    out: &mut TileViewMut<'_>,
    z0: usize,
    z1: usize,
    x0: usize,
    x1: usize,
    y0: usize,
    y1: usize,
) {
    let (_, gnx, gny) = g.shape();
    let r = spec.radius;
    let ny = y1 - y0;
    let (wz, wx) = (&spec.star_axes[0], &spec.star_axes[1]);
    // x/z accumulator rows from the worker-local arena: one checkout
    // per block (two rows for the coarsened pair), reused across every
    // (z, x) row — removes the old fixed `[f32; 512]` stack buffer and
    // its `ty > 512` panic cliff
    scratch::with(2 * ny, |scr| {
        let (acc0, acc1) = scr.split_at_mut(ny);
        for z in z0..z1 {
            let mut x = x0;
            while x + 2 <= x1 {
                star3_y_phase(spec, g, out, z, x, y0, ny);
                star3_y_phase(spec, g, out, z, x + 1, y0, ny);
                acc0.fill(0.0);
                acc1.fill(0.0);
                for k in 0..2 * r + 1 {
                    if k == r {
                        continue;
                    }
                    // row x+1's z/x taps sit exactly one y-row (gny)
                    // past row x's
                    let zb = ((z + k - r) * gnx + x) * gny + y0;
                    let xb = (z * gnx + (x + k - r)) * gny + y0;
                    let (wzk, wxk) = (wz[k], wx[k]);
                    let (zr, xr) = (g.span(zb, ny), g.span(xb, ny));
                    for ((a, &zv), &xv) in acc0.iter_mut().zip(zr).zip(xr) {
                        *a += wzk * zv + wxk * xv;
                    }
                    let (zr, xr) = (g.span(zb + gny, ny), g.span(xb + gny, ny));
                    for ((a, &zv), &xv) in acc1.iter_mut().zip(zr).zip(xr) {
                        *a += wzk * zv + wxk * xv;
                    }
                }
                for (o, &a) in out.row_mut(z, x, y0, ny).iter_mut().zip(acc0.iter()) {
                    *o += a;
                }
                for (o, &a) in out.row_mut(z, x + 1, y0, ny).iter_mut().zip(acc1.iter()) {
                    *o += a;
                }
                x += 2;
            }
            if x < x1 {
                // single-row remainder: the original uncoarsened path
                star3_y_phase(spec, g, out, z, x, y0, ny);
                acc0.fill(0.0);
                for k in 0..2 * r + 1 {
                    if k == r {
                        continue;
                    }
                    let zb = ((z + k - r) * gnx + x) * gny + y0;
                    let xb = (z * gnx + (x + k - r)) * gny + y0;
                    let (wzk, wxk) = (wz[k], wx[k]);
                    let (zr, xr) = (g.span(zb, ny), g.span(xb, ny));
                    for ((a, &zv), &xv) in acc0.iter_mut().zip(zr).zip(xr) {
                        *a += wzk * zv + wxk * xv;
                    }
                }
                for (o, &a) in out.row_mut(z, x, y0, ny).iter_mut().zip(acc0.iter()) {
                    *o += a;
                }
            }
        }
    });
}

#[inline]
fn box3_block<S: GridSrc>(
    spec: &StencilSpec,
    g: &S,
    out: &mut TileViewMut<'_>,
    z0: usize,
    z1: usize,
    x0: usize,
    x1: usize,
    y0: usize,
    y1: usize,
) {
    let (_, gnx, gny) = g.shape();
    let r = spec.radius;
    let n = 2 * r + 1;
    let ny = y1 - y0;
    for z in z0..z1 {
        for x in x0..x1 {
            let row = out.row_mut(z, x, y0, ny);
            row.fill(0.0);
            for c in 0..n {
                for a in 0..n {
                    let sb = ((z + c - r) * gnx + (x + a - r)) * gny + y0 - r;
                    for b in 0..n {
                        let w = spec.box_w[(c * n + a) * n + b];
                        let src = g.span(sb + b, ny);
                        for i in 0..ny {
                            row[i] += w * src[i];
                        }
                    }
                }
            }
        }
    }
}

/// Compute the claimed region of `out` — an arbitrary sub-box
/// `[z0,z1)×[x0,x1)×[y0,y1)` of the periodic sweep — from `g`.  The
/// per-tile entry point of the parallel coordinator
/// (`coordinator::driver`): the view *is* the region, so a task cannot
/// write outside the box it was handed.  The region is split against
/// the wrap-free deep interior (one blocked call) and the ≤6 boundary
/// slabs of `grid::shell` (wrapped points) — no per-row `inside()`
/// scanning.
pub fn apply3_region<S: GridSrc>(spec: &StencilSpec, g: &S, out: &mut TileViewMut<'_>) {
    assert_eq!(spec.ndim, 3);
    debug_assert_eq!(g.shape(), out.grid_shape());
    let (gnz, gnx, gny) = g.shape();
    let (z0, z1, x0, x1, y0, y1) = out.bounds();
    let bounds = [z0, z1, x0, x1, y0, y1];
    let r = spec.radius;
    if let Some(d) =
        shell::interior_box(gnz, gnx, gny, r).and_then(|ib| shell::intersect(bounds, ib))
    {
        match spec.pattern {
            Pattern::Star => star3_block(spec, g, out, d[0], d[1], d[2], d[3], d[4], d[5]),
            Pattern::Box => box3_block(spec, g, out, d[0], d[1], d[2], d[3], d[4], d[5]),
        }
    }
    for sb in shell::boundary_boxes(gnz, gnx, gny, r) {
        if let Some(b) = shell::intersect(bounds, sb) {
            for z in b[0]..b[1] {
                for x in b[2]..b[3] {
                    for y in b[4]..b[5] {
                        out.set(z, x, y, point3_wrap(spec, g, z as isize, x as isize, y as isize));
                    }
                }
            }
        }
    }
}

/// 1-D band pass along `axis` (0 = z, 1 = x, 2 = y) over the claimed
/// region — the blocked axis-derivative kernel behind
/// `Engine::{d1,d2}_axis_into` for [`EngineKind::Simd`](super::EngineKind).
///
/// The region is split against `grid::shell`'s **per-axis** boxes: a
/// 1-D band only wraps along its own axis, so the wrap-free interior is
/// the grid shrunk by `r` along `axis` alone
/// ([`shell::axis_interior_box`]), computed as shifted y-contiguous
/// [`GridSrc::span`] accumulations that LLVM auto-vectorizes; the ≤2
/// boundary slabs ([`shell::axis_boundary_boxes`]) take the wrapped
/// per-point path.  `band` has odd length 2r+1, centre at index r.
pub fn d_axis_region<S: GridSrc>(band: &[f32], axis: usize, g: &S, out: &mut TileViewMut<'_>) {
    assert!(axis < 3, "axis must be 0 (z), 1 (x), or 2 (y)");
    assert_eq!(band.len() % 2, 1, "band must have odd length");
    debug_assert_eq!(g.shape(), out.grid_shape());
    let r = band.len() / 2;
    let (gnz, gnx, gny) = g.shape();
    let (z0, z1, x0, x1, y0, y1) = out.bounds();
    let bounds = [z0, z1, x0, x1, y0, y1];
    let stride = match axis {
        0 => (gnx * gny) as isize,
        1 => gny as isize,
        _ => 1,
    };
    let interior = shell::axis_interior_box(gnz, gnx, gny, axis, r);
    if let Some(d) = interior.and_then(|ib| shell::intersect(bounds, ib)) {
        let len = d[5] - d[4];
        for z in d[0]..d[1] {
            for x in d[2]..d[3] {
                let base = ((z * gnx + x) * gny + d[4]) as isize;
                let o = out.row_mut(z, x, d[4], len);
                let c = g.span(base as usize, len);
                for i in 0..len {
                    o[i] = band[r] * c[i];
                }
                for (k, &wk) in band.iter().enumerate() {
                    if k == r {
                        continue;
                    }
                    let s = g.span((base + (k as isize - r as isize) * stride) as usize, len);
                    for i in 0..len {
                        o[i] += wk * s[i];
                    }
                }
            }
        }
    }
    for sb in shell::axis_boundary_boxes(gnz, gnx, gny, axis, r) {
        if let Some(b) = shell::intersect(bounds, sb) {
            // wrapped taps: one definition of the tap order, the oracle's
            super::naive::d_axis_box(band, axis, g, out, b);
        }
    }
}

/// 2D variant (blocked rows, wrapped boundary shell).
pub fn apply2(spec: &StencilSpec, g: &Grid2) -> Grid2 {
    assert_eq!(spec.ndim, 2);
    let r = spec.radius;
    let mut out = Grid2::zeros(g.nx, g.ny);
    if g.nx > 2 * r && g.ny > 2 * r {
        for x in r..g.nx - r {
            let ny = g.ny - 2 * r;
            let ob = out.idx(x, r);
            match spec.pattern {
                Pattern::Star => {
                    let (wx, wy) = (&spec.star_axes[0], &spec.star_axes[1]);
                    let cb = g.idx(x, r);
                    for i in 0..ny {
                        out.data[ob + i] = spec.star_center * g.data[cb + i];
                    }
                    for k in 0..2 * r + 1 {
                        if k == r {
                            continue;
                        }
                        let yb = g.idx(x, 0);
                        let xb = g.idx(x + k - r, r);
                        let (wyk, wxk) = (wy[k], wx[k]);
                        for i in 0..ny {
                            out.data[ob + i] += wyk * g.data[yb + k + i] + wxk * g.data[xb + i];
                        }
                    }
                }
                Pattern::Box => {
                    let n = 2 * r + 1;
                    out.data[ob..ob + ny].fill(0.0);
                    for a in 0..n {
                        let sb = g.idx(x + a - r, 0);
                        for b in 0..n {
                            let w = spec.box_w[a * n + b];
                            for i in 0..ny {
                                out.data[ob + i] += w * g.data[sb + b + i];
                            }
                        }
                    }
                }
            }
        }
    }
    // boundary shell: the ≤4 O(N·r) slabs, no full-plane scan
    for b in shell::boundary_boxes2(g.nx, g.ny, r) {
        for x in b[0]..b[1] {
            for y in b[2]..b[3] {
                out.set(x, y, point2_wrap(spec, g, x as isize, y as isize));
            }
        }
    }
    out
}

#[inline]
fn point2_wrap(spec: &StencilSpec, g: &Grid2, x: isize, y: isize) -> f32 {
    let r = spec.radius as isize;
    match spec.pattern {
        Pattern::Star => {
            let (wx, wy) = (&spec.star_axes[0], &spec.star_axes[1]);
            let mut acc = spec.star_center * g.get_wrap(x, y);
            for k in -r..=r {
                if k == 0 {
                    continue;
                }
                let i = (k + r) as usize;
                acc += wx[i] * g.get_wrap(x + k, y);
                acc += wy[i] * g.get_wrap(x, y + k);
            }
            acc
        }
        Pattern::Box => {
            let n = 2 * r + 1;
            let mut acc = 0.0;
            for a in 0..n {
                for b in 0..n {
                    acc += spec.box_w[(a * n + b) as usize] * g.get_wrap(x + a - r, y + b - r);
                }
            }
            acc
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::naive;
    use crate::util::prop::{assert_allclose, forall};

    #[test]
    fn matches_naive_on_all_benchmarks_3d() {
        for (name, spec) in StencilSpec::benchmark_suite() {
            if spec.ndim != 3 {
                continue;
            }
            let g = Grid3::random(12, 20, 24, 1);
            let want = naive::apply3(&spec, &g);
            let got = apply3(&spec, &g);
            assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
            let _ = name;
        }
    }

    #[test]
    fn matches_naive_on_all_benchmarks_2d() {
        for (_, spec) in StencilSpec::benchmark_suite() {
            if spec.ndim != 2 {
                continue;
            }
            let g = Grid2::random(24, 40, 2);
            let want = naive::apply2(&spec, &g);
            let got = apply2(&spec, &g);
            assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
        }
    }

    #[test]
    fn random_tile_shapes_agree() {
        forall(15, 0x51D, |rng| {
            let spec = StencilSpec::star3d(rng.range(1, 4));
            let g = Grid3::random(10, 12, 16, rng.next_u64());
            let tile = Tile {
                tz: rng.range(1, 4),
                tx: rng.range(1, 6),
                ty: rng.range(4, 16),
            };
            let want = naive::apply3(&spec, &g);
            let got = apply3_tiled(&spec, &g, tile);
            assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
        });
    }

    #[test]
    fn tile_ty_above_512_matches_naive() {
        // regression: the old fixed `[f32; 512]` accumulator made any
        // tile with ty > 512 panic (debug assert / release slice OOB);
        // the arena row must handle 1024-wide tiles on a ny > 1024 grid
        let spec = StencilSpec::star3d(1);
        let g = Grid3::random(4, 6, 1100, 77);
        let want = naive::apply3(&spec, &g);
        let got = apply3_tiled(&spec, &g, Tile { tz: 2, tx: 4, ty: 1024 });
        assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
    }

    #[test]
    fn small_grid_all_boundary() {
        // grid smaller than 2r+1: everything goes through the wrap path
        let spec = StencilSpec::star3d(4);
        let g = Grid3::random(4, 4, 4, 3);
        let want = naive::apply3(&spec, &g);
        let got = apply3(&spec, &g);
        assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
    }

    #[test]
    fn region_views_compose_to_the_full_sweep() {
        // y-strip views covering the grid reproduce the whole-grid sweep
        let spec = StencilSpec::star3d(2);
        let g = Grid3::random(8, 10, 12, 4);
        let want = apply3(&spec, &g);
        let mut out = Grid3::zeros(8, 10, 12);
        {
            let pg = ParGrid3::new(&mut out);
            for (y0, y1) in [(0, 3), (3, 7), (7, 12)] {
                let mut view = pg.view(0, 8, 0, 10, y0, y1);
                apply3_region(&spec, &g, &mut view);
            }
        }
        assert_allclose(&out.data, &want.data, 1e-6, 1e-7);
    }
}
