//! Redundant-Access Zeroing box decomposition (paper §IV-C.d) as a
//! standalone, inspectable transform — plus the *naive decomposition* it
//! replaces, so the ablation bench can show the traffic difference.
//!
//! A 2D box of radius r decomposes into 2r+1 y-axis 1D stencils; the j-th
//! sub-stencil reads rows shifted by `j - r` in x.  Executed independently
//! (`decomposed_traffic`) each sub-stencil re-reads nearly the whole
//! window; restructured with the sub-stencil loop innermost over one
//! shared window (`zeroed_traffic`, what `matrix_unit` implements) every
//! element is read exactly once.

use super::StencilSpec;
use crate::grid::Grid2;

/// Result of a box decomposition into 1D sub-stencils.
pub struct Decomposition {
    /// Per-sub-stencil y-axis weight rows (2r+1 rows of 2r+1 weights).
    pub rows: Vec<Vec<f32>>,
    /// Radius of the decomposed box.
    pub radius: usize,
}

/// Decompose a 2D box spec into its 2r+1 y-axis sub-stencils.
pub fn decompose2(spec: &StencilSpec) -> Decomposition {
    assert_eq!(spec.ndim, 2);
    let n = 2 * spec.radius + 1;
    let rows = (0..n).map(|a| spec.box_w[a * n..(a + 1) * n].to_vec()).collect();
    Decomposition { rows, radius: spec.radius }
}

impl Decomposition {
    /// Apply to a periodic grid by accumulating the sub-stencils — must
    /// equal the direct box application.
    pub fn apply(&self, g: &Grid2) -> Grid2 {
        let r = self.radius as isize;
        let mut out = Grid2::zeros(g.nx, g.ny);
        for (a, row) in self.rows.iter().enumerate() {
            let dx = a as isize - r;
            for x in 0..g.nx as isize {
                for y in 0..g.ny as isize {
                    let mut acc = 0.0;
                    for (b, &w) in row.iter().enumerate() {
                        acc += w * g.get_wrap(x + dx, y + b as isize - r);
                    }
                    let i = out.idx(x as usize, y as usize);
                    out.data[i] += acc;
                }
            }
        }
        out
    }

    /// f32 elements read from memory per (VL×VL) output tile when each
    /// sub-stencil runs independently (the pre-optimization layout): every
    /// pass re-loads its own shifted (VL, VL+2r) window.
    pub fn decomposed_traffic(&self, vl: usize) -> usize {
        let r = self.radius;
        (2 * r + 1) * vl * (vl + 2 * r)
    }

    /// f32 elements read per tile with the Redundant-Access Zeroing
    /// restructure: one shared (VL+2r, VL+2r) window load.
    pub fn zeroed_traffic(&self, vl: usize) -> usize {
        let r = self.radius;
        (vl + 2 * r) * (vl + 2 * r)
    }

    /// Traffic reduction factor of the optimization.
    pub fn traffic_reduction(&self, vl: usize) -> f64 {
        self.decomposed_traffic(vl) as f64 / self.zeroed_traffic(vl) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::naive;
    use crate::util::prop::assert_allclose;

    #[test]
    fn decomposition_equals_direct_box() {
        for r in [1, 2, 3] {
            let spec = StencilSpec::box2d(r);
            let g = Grid2::random(20, 24, 21);
            let want = naive::apply2(&spec, &g);
            let got = decompose2(&spec).apply(&g);
            assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
        }
    }

    #[test]
    fn rows_cover_all_weights() {
        let spec = StencilSpec::box2d(3);
        let d = decompose2(&spec);
        assert_eq!(d.rows.len(), 7);
        let total: usize = d.rows.iter().map(|r| r.len()).sum();
        assert_eq!(total, 49);
    }

    #[test]
    fn zeroing_reduces_traffic() {
        // r=3, VL=16: naive decomposition reads 7·16·22 = 2464 elements
        // per tile; the shared window is 22·22 = 484 → 5.09× reduction.
        let spec = StencilSpec::box2d(3);
        let d = decompose2(&spec);
        assert_eq!(d.decomposed_traffic(16), 2464);
        assert_eq!(d.zeroed_traffic(16), 484);
        assert!(d.traffic_reduction(16) > 5.0);
    }

    #[test]
    fn reduction_grows_with_radius() {
        let r1 = decompose2(&StencilSpec::box2d(1)).traffic_reduction(16);
        let r3 = decompose2(&StencilSpec::box2d(3)).traffic_reduction(16);
        assert!(r3 > r1);
    }
}
