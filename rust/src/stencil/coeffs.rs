//! Finite-difference coefficient tables — exact mirror of
//! `python/compile/coeffs.py` (cross-checked through the AOT artifacts in
//! `rust/tests/runtime_artifacts.rs`).

/// Second-derivative central coefficients (order 2r), index k+r.
pub fn second_deriv(radius: usize) -> Vec<f32> {
    let w: Vec<f64> = match radius {
        1 => vec![1.0, -2.0, 1.0],
        2 => vec![-1.0 / 12.0, 4.0 / 3.0, -5.0 / 2.0, 4.0 / 3.0, -1.0 / 12.0],
        3 => vec![
            1.0 / 90.0, -3.0 / 20.0, 3.0 / 2.0, -49.0 / 18.0, 3.0 / 2.0, -3.0 / 20.0,
            1.0 / 90.0,
        ],
        4 => vec![
            -1.0 / 560.0, 8.0 / 315.0, -1.0 / 5.0, 8.0 / 5.0, -205.0 / 72.0, 8.0 / 5.0,
            -1.0 / 5.0, 8.0 / 315.0, -1.0 / 560.0,
        ],
        _ => panic!("unsupported radius {radius}"),
    };
    w.into_iter().map(|v| v as f32).collect()
}

/// First-derivative central coefficients (order 2r), antisymmetric.
pub fn first_deriv(radius: usize) -> Vec<f32> {
    let w: Vec<f64> = match radius {
        1 => vec![-0.5, 0.0, 0.5],
        2 => vec![1.0 / 12.0, -2.0 / 3.0, 0.0, 2.0 / 3.0, -1.0 / 12.0],
        3 => vec![
            -1.0 / 60.0, 3.0 / 20.0, -3.0 / 4.0, 0.0, 3.0 / 4.0, -3.0 / 20.0, 1.0 / 60.0,
        ],
        4 => vec![
            1.0 / 280.0, -4.0 / 105.0, 1.0 / 5.0, -4.0 / 5.0, 0.0, 4.0 / 5.0, -1.0 / 5.0,
            4.0 / 105.0, -1.0 / 280.0,
        ],
        _ => panic!("unsupported radius {radius}"),
    };
    w.into_iter().map(|v| v as f32).collect()
}

/// Benchmark star weights: `(center, per-axis bands with zero centres)` —
/// the Laplacian-style pattern of `coeffs.star_weights`.
pub fn star_weights(ndim: usize, radius: usize) -> (f32, Vec<Vec<f32>>) {
    let base = second_deriv(radius);
    let center = ndim as f32 * base[radius];
    let mut axis = base;
    axis[radius] = 0.0;
    (center, vec![axis; ndim])
}

/// Benchmark box weights: dense `(2r+1)^ndim` tensor, row-major — the
/// Gaussian-times-ripple pattern of `coeffs.box_weights` (same f64 math).
pub fn box_weights(ndim: usize, radius: usize) -> Vec<f32> {
    let n = 2 * radius + 1;
    let count = n.pow(ndim as u32);
    let rr = radius.max(1) as f64;
    let mut w = vec![0.0f64; count];
    for (flat, v) in w.iter_mut().enumerate() {
        // decompose flat into ndim indices, row-major
        let mut idx = vec![0usize; ndim];
        let mut rem = flat;
        for d in (0..ndim).rev() {
            idx[d] = rem % n;
            rem /= n;
        }
        let mut g = 1.0f64;
        for &i in &idx {
            let d = i as f64 - radius as f64;
            g *= (-0.5 * d * d / (rr * rr)).exp();
        }
        *v = g * (1.0 + 0.3 * (1.7 * flat as f64 + 0.4).sin());
    }
    let norm: f64 = w.iter().map(|v| v.abs()).sum();
    w.into_iter().map(|v| (v / norm) as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn second_deriv_sums_to_zero() {
        for r in 1..=4 {
            let s: f64 = second_deriv(r).iter().map(|&v| v as f64).sum();
            assert!(s.abs() < 1e-6, "r={r}: {s}");
        }
    }

    #[test]
    fn second_deriv_curvature_two() {
        for r in 1..=4 {
            let w = second_deriv(r);
            let s: f64 = w
                .iter()
                .enumerate()
                .map(|(i, &v)| v as f64 * ((i as f64 - r as f64).powi(2)))
                .sum();
            assert!((s - 2.0).abs() < 1e-5, "r={r}: {s}");
        }
    }

    #[test]
    fn first_deriv_antisymmetric_unit_slope() {
        for r in 1..=4 {
            let w = first_deriv(r);
            for k in 0..w.len() {
                assert!((w[k] + w[w.len() - 1 - k]).abs() < 1e-7);
            }
            let s: f64 = w
                .iter()
                .enumerate()
                .map(|(i, &v)| v as f64 * (i as f64 - r as f64))
                .sum();
            assert!((s - 1.0).abs() < 1e-5, "r={r}: {s}");
        }
    }

    #[test]
    fn box_weights_normalized_dense() {
        for (nd, r) in [(2, 2), (2, 3), (3, 1), (3, 2)] {
            let w = box_weights(nd, r);
            assert_eq!(w.len(), (2 * r + 1).pow(nd as u32));
            assert!(w.iter().all(|&v| v != 0.0));
            let s: f64 = w.iter().map(|&v| v.abs() as f64).sum();
            assert!((s - 1.0).abs() < 1e-4, "{nd}D r{r}: {s}");
        }
    }

    #[test]
    fn star_center_is_ndim_times_second_center() {
        let (c, axes) = star_weights(3, 4);
        assert!((c - 3.0 * second_deriv(4)[4]).abs() < 1e-6);
        assert_eq!(axes.len(), 3);
        assert_eq!(axes[0][4], 0.0);
    }
}
