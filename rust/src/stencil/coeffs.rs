//! Finite-difference coefficient tables — exact mirror of
//! `python/compile/coeffs.py` (cross-checked through the AOT artifacts in
//! `rust/tests/runtime_artifacts.rs`) — plus [`CoeffTable`], the
//! user-supplied table behind `custom:` stencil specs.

use super::Pattern;

/// A user-supplied stencil coefficient table: one `(2r+1)` band reused
/// on every axis (star) or a dense `(2r+1)^ndim` row-major tensor
/// (box), for any radius ≥ 1.
///
/// Built either directly ([`CoeffTable::star`] / [`CoeffTable::boxed`])
/// or from the CLI/config grammar
/// `custom:<star|box>[:<2d|3d>]:r<radius>:<w0,w1,…|file=path>`
/// ([`CoeffTable::parse`], routed through
/// [`StencilSpec::parse`](super::StencilSpec::parse)).  Errors are
/// plain strings naming the rejected segment; the spec layer wraps
/// them into the crate-wide
/// [`ParseKindError`](crate::util::ParseKindError) shape.
#[derive(Clone, Debug, PartialEq)]
pub struct CoeffTable {
    /// Star (per-axis band) or Box (dense tensor).
    pub pattern: Pattern,
    /// Grid dimensionality: 2 or 3.
    pub ndim: usize,
    /// Stencil radius (halo width per axis).
    pub radius: usize,
    /// Star: the `2r+1` band, centre included at index `r`.
    /// Box: `(2r+1)^ndim` dense taps, row-major over `(x,y)` / `(z,x,y)`.
    pub taps: Vec<f32>,
}

impl CoeffTable {
    /// A star table: `band` (len `2r+1`, centre at index `radius`) is
    /// applied along every axis; the centre tap is counted once per
    /// axis, exactly like [`star_weights`].
    pub fn star(ndim: usize, radius: usize, band: Vec<f32>) -> Result<Self, String> {
        check_shape(ndim, radius)?;
        let want = 2 * radius + 1;
        if band.len() != want {
            return Err(format!("star band needs {want} taps, got {}", band.len()));
        }
        check_finite(&band)?;
        Ok(Self { pattern: Pattern::Star, ndim, radius, taps: band })
    }

    /// A dense box table: `taps` is the full `(2r+1)^ndim` row-major
    /// weight tensor.
    pub fn boxed(ndim: usize, radius: usize, taps: Vec<f32>) -> Result<Self, String> {
        check_shape(ndim, radius)?;
        let want = (2 * radius + 1).pow(ndim as u32);
        if taps.len() != want {
            return Err(format!("box tensor needs {want} taps, got {}", taps.len()));
        }
        check_finite(&taps)?;
        Ok(Self { pattern: Pattern::Box, ndim, radius, taps })
    }

    /// Parse the grammar *after* the `custom:` prefix:
    /// `<star|box>[:<2d|3d>]:r<radius>:<w0,w1,…|file=path>` (ndim
    /// defaults to 3d).  Inline taps are comma-separated; a
    /// `file=path` tail reads whitespace/comma-separated floats from
    /// the file.  The error string names the segment that failed.
    pub fn parse(table: &str) -> Result<Self, String> {
        let mut parts = table.split(':');
        let pattern = match parts.next().unwrap_or("") {
            "star" => Pattern::Star,
            "box" => Pattern::Box,
            other => return Err(format!("pattern must be star or box, got {other:?}")),
        };
        let mut seg = parts.next().ok_or("missing r<radius> segment")?;
        let ndim = match seg {
            "2d" | "3d" => {
                let nd = if seg == "2d" { 2 } else { 3 };
                seg = parts.next().ok_or("missing r<radius> segment")?;
                nd
            }
            _ => 3,
        };
        let radius: usize = seg
            .strip_prefix('r')
            .and_then(|d| d.parse().ok())
            .filter(|&r| r >= 1)
            .ok_or_else(|| format!("bad radius segment {seg:?} (want r1, r2, …)"))?;
        // the tail is everything after the radius — re-joined so that
        // file paths containing ':' survive
        let tail = parts.collect::<Vec<_>>().join(":");
        if tail.is_empty() {
            return Err("missing taps segment (w0,w1,… or file=path)".into());
        }
        let text = match tail.strip_prefix("file=") {
            Some(path) => std::fs::read_to_string(path)
                .map_err(|e| format!("coefficient file {path:?}: {e}"))?,
            None => tail,
        };
        let taps = text
            .split([',', ' ', '\t', '\n', '\r'])
            .filter(|t| !t.is_empty())
            .map(|t| t.parse::<f32>().map_err(|_| format!("bad coefficient {t:?}")))
            .collect::<Result<Vec<f32>, String>>()?;
        match pattern {
            Pattern::Star => Self::star(ndim, radius, taps),
            Pattern::Box => Self::boxed(ndim, radius, taps),
        }
    }
}

fn check_shape(ndim: usize, radius: usize) -> Result<(), String> {
    if ndim != 2 && ndim != 3 {
        return Err(format!("ndim must be 2 or 3, got {ndim}"));
    }
    if radius == 0 {
        return Err("radius must be ≥ 1".into());
    }
    Ok(())
}

fn check_finite(taps: &[f32]) -> Result<(), String> {
    match taps.iter().find(|v| !v.is_finite()) {
        Some(v) => Err(format!("non-finite coefficient {v}")),
        None => Ok(()),
    }
}

/// Second-derivative central coefficients (order 2r), index k+r.
pub fn second_deriv(radius: usize) -> Vec<f32> {
    let w: Vec<f64> = match radius {
        1 => vec![1.0, -2.0, 1.0],
        2 => vec![-1.0 / 12.0, 4.0 / 3.0, -5.0 / 2.0, 4.0 / 3.0, -1.0 / 12.0],
        3 => vec![
            1.0 / 90.0, -3.0 / 20.0, 3.0 / 2.0, -49.0 / 18.0, 3.0 / 2.0, -3.0 / 20.0,
            1.0 / 90.0,
        ],
        4 => vec![
            -1.0 / 560.0, 8.0 / 315.0, -1.0 / 5.0, 8.0 / 5.0, -205.0 / 72.0, 8.0 / 5.0,
            -1.0 / 5.0, 8.0 / 315.0, -1.0 / 560.0,
        ],
        _ => panic!("unsupported radius {radius}"),
    };
    w.into_iter().map(|v| v as f32).collect()
}

/// First-derivative central coefficients (order 2r), antisymmetric.
pub fn first_deriv(radius: usize) -> Vec<f32> {
    let w: Vec<f64> = match radius {
        1 => vec![-0.5, 0.0, 0.5],
        2 => vec![1.0 / 12.0, -2.0 / 3.0, 0.0, 2.0 / 3.0, -1.0 / 12.0],
        3 => vec![
            -1.0 / 60.0, 3.0 / 20.0, -3.0 / 4.0, 0.0, 3.0 / 4.0, -3.0 / 20.0, 1.0 / 60.0,
        ],
        4 => vec![
            1.0 / 280.0, -4.0 / 105.0, 1.0 / 5.0, -4.0 / 5.0, 0.0, 4.0 / 5.0, -1.0 / 5.0,
            4.0 / 105.0, -1.0 / 280.0,
        ],
        _ => panic!("unsupported radius {radius}"),
    };
    w.into_iter().map(|v| v as f32).collect()
}

/// Benchmark star weights: `(center, per-axis bands with zero centres)` —
/// the Laplacian-style pattern of `coeffs.star_weights`.
pub fn star_weights(ndim: usize, radius: usize) -> (f32, Vec<Vec<f32>>) {
    let base = second_deriv(radius);
    let center = ndim as f32 * base[radius];
    let mut axis = base;
    axis[radius] = 0.0;
    (center, vec![axis; ndim])
}

/// Benchmark box weights: dense `(2r+1)^ndim` tensor, row-major — the
/// Gaussian-times-ripple pattern of `coeffs.box_weights` (same f64 math).
pub fn box_weights(ndim: usize, radius: usize) -> Vec<f32> {
    let n = 2 * radius + 1;
    let count = n.pow(ndim as u32);
    let rr = radius.max(1) as f64;
    let mut w = vec![0.0f64; count];
    for (flat, v) in w.iter_mut().enumerate() {
        // decompose flat into ndim indices, row-major
        let mut idx = vec![0usize; ndim];
        let mut rem = flat;
        for d in (0..ndim).rev() {
            idx[d] = rem % n;
            rem /= n;
        }
        let mut g = 1.0f64;
        for &i in &idx {
            let d = i as f64 - radius as f64;
            g *= (-0.5 * d * d / (rr * rr)).exp();
        }
        *v = g * (1.0 + 0.3 * (1.7 * flat as f64 + 0.4).sin());
    }
    let norm: f64 = w.iter().map(|v| v.abs()).sum();
    w.into_iter().map(|v| (v / norm) as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn second_deriv_sums_to_zero() {
        for r in 1..=4 {
            let s: f64 = second_deriv(r).iter().map(|&v| v as f64).sum();
            assert!(s.abs() < 1e-6, "r={r}: {s}");
        }
    }

    #[test]
    fn second_deriv_curvature_two() {
        for r in 1..=4 {
            let w = second_deriv(r);
            let s: f64 = w
                .iter()
                .enumerate()
                .map(|(i, &v)| v as f64 * ((i as f64 - r as f64).powi(2)))
                .sum();
            assert!((s - 2.0).abs() < 1e-5, "r={r}: {s}");
        }
    }

    #[test]
    fn first_deriv_antisymmetric_unit_slope() {
        for r in 1..=4 {
            let w = first_deriv(r);
            for k in 0..w.len() {
                assert!((w[k] + w[w.len() - 1 - k]).abs() < 1e-7);
            }
            let s: f64 = w
                .iter()
                .enumerate()
                .map(|(i, &v)| v as f64 * (i as f64 - r as f64))
                .sum();
            assert!((s - 1.0).abs() < 1e-5, "r={r}: {s}");
        }
    }

    #[test]
    fn box_weights_normalized_dense() {
        for (nd, r) in [(2, 2), (2, 3), (3, 1), (3, 2)] {
            let w = box_weights(nd, r);
            assert_eq!(w.len(), (2 * r + 1).pow(nd as u32));
            assert!(w.iter().all(|&v| v != 0.0));
            let s: f64 = w.iter().map(|&v| v.abs() as f64).sum();
            assert!((s - 1.0).abs() < 1e-4, "{nd}D r{r}: {s}");
        }
    }

    #[test]
    fn star_center_is_ndim_times_second_center() {
        let (c, axes) = star_weights(3, 4);
        assert!((c - 3.0 * second_deriv(4)[4]).abs() < 1e-6);
        assert_eq!(axes.len(), 3);
        assert_eq!(axes[0][4], 0.0);
    }

    #[test]
    fn coeff_table_grammar_parses_inline_star_and_box() {
        let t = CoeffTable::parse("star:r1:1,-2,1").unwrap();
        assert_eq!(
            t,
            CoeffTable {
                pattern: Pattern::Star,
                ndim: 3,
                radius: 1,
                taps: vec![1.0, -2.0, 1.0]
            }
        );
        // explicit 2d, r1 box: 9 taps
        let t = CoeffTable::parse("box:2d:r1:1,2,1,2,4,2,1,2,1").unwrap();
        assert_eq!(t.pattern, Pattern::Box);
        assert_eq!((t.ndim, t.radius), (2, 1));
        assert_eq!(t.taps.len(), 9);
    }

    #[test]
    fn coeff_table_reads_whitespace_separated_files() {
        let dir = std::env::temp_dir().join("mmstencil_coeff_table_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("band.txt");
        std::fs::write(&path, "0.1 -0.2\n0.0\t-0.2 0.1\n").unwrap();
        let t = CoeffTable::parse(&format!("star:r2:file={}", path.display())).unwrap();
        assert_eq!(t.taps, vec![0.1, -0.2, 0.0, -0.2, 0.1]);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn coeff_table_rejects_malformed_specs_with_the_failing_segment() {
        for (bad, needle) in [
            ("ring:r2:1,2,3,4,5", "star or box"),
            ("star:1,-2,1", "radius"),
            ("star:r0:1", "radius"),
            ("star:rx:1", "radius"),
            ("star:4d:r1:1,-2,1", "radius"), // unknown dim token reads as a bad radius
            ("star:r1", "missing taps"),
            ("star:r2:1,-2,1", "5 taps, got 3"),
            ("box:2d:r1:1,2,3", "9 taps, got 3"),
            ("star:r1:1,two,1", "bad coefficient \"two\""),
            ("star:r1:1,inf,1", "non-finite"),
            ("star:r1:file=/definitely/not/here.txt", "coefficient file"),
        ] {
            let err = CoeffTable::parse(bad).unwrap_err();
            assert!(err.contains(needle), "{bad:?}: {err}");
        }
    }
}
