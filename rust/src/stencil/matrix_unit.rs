//! The MMStencil matrix-unit algorithm (paper §IV-A/§IV-C), emulated.
//!
//! Numerics: the grid is swept in `(VZ, VX, VY)` blocks; each block
//! reads a halo-extended window and computes per-axis 1D stencils as
//! outer-product accumulations into 16×16 tiles, with the x/y partial
//! kept in a temporary buffer before the z pass (Cache Pollution
//! Avoiding Intermediate Result Placement).
//!
//! Memory discipline (PR 3): the hot path is **allocation-free after
//! warm-up** and **zero-copy for interior blocks** —
//!
//! * blocks whose halo window lies fully inside the grid read strided
//!   y-rows straight from the [`GridSrc`] (`DirectWin`) — no window
//!   materialization at all;
//! * only the O(surface) boundary blocks wrap-copy their window, into a
//!   worker-local scratch-arena buffer (`coordinator::scratch`), never
//!   a fresh `Vec`;
//! * the star `tmp` buffer comes from the same arena, and results land
//!   directly in the claimed output view (no per-block result `Vec`).
//!
//! Parallelism: [`apply3_on`] fans the z-block loop out over the
//! persistent worker runtime via disjoint `TileViewMut` z-slab claims;
//! per-task [`Counts`] are merged by reduction, so the instruction
//! accounting is *exactly* the serial sweep's (integer sums commute)
//! and the grid bytes are *bitwise* the serial sweep's (identical
//! per-block kernels on disjoint regions).
//!
//! Instruction accounting: every block records the instruction mix the
//! paper reasons about —
//!
//! * `outer_products` — one per VL-element input vector consumed by a
//!   1D-stencil pass (`window_elems / VL`, the Fig. 4 mapping),
//! * `tile_slices`   — Tile-Assisted Vector Transpose: 2·VL per 16×16
//!   tile transposed (vs `VL·log2(VL)` SIMD permutes, also recorded for
//!   the comparison bench),
//! * `vec_loads` / `vec_stores` — window loads, result stores, and the
//!   intermediate-buffer round-trip of the z pass,
//!
//! which `simulator::roofline` converts to cycles with CPI_Matrix = 2,
//! 4-cycle outer-product latency, and the SIMD/Matrix frequency ratio.

use super::{Pattern, StencilSpec};
use crate::coordinator::runtime::{self, Runtime};
use crate::coordinator::scratch;
use crate::grid::par::{GridSrc, ParGrid3, TileViewMut};
use crate::grid::{Grid2, Grid3};

/// Instruction counters for the matrix-unit model.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Counts {
    /// Matrix-unit outer-product instructions (one per VL-element input
    /// vector consumed by a 1D-stencil pass — the Fig. 4 mapping).
    pub outer_products: u64,
    /// Vector loads: halo-window reads plus intermediate-buffer reloads.
    pub vec_loads: u64,
    /// Vector stores: results plus the intermediate-buffer round-trip.
    pub vec_stores: u64,
    /// Matrix-tile horizontal/vertical slice insert/extract instructions.
    pub tile_slices: u64,
    /// SIMD permutation count a permutation-network transpose *would*
    /// have used (for the §IV-C.b comparison; not on the hot path).
    pub simd_permutes_avoided: u64,
    /// Strided-gather vector loads a direct x-axis sweep *would* need.
    pub gathers_avoided: u64,
}

impl Counts {
    /// Accumulate another counter set (integer sums commute, so merge
    /// order never changes the total).
    pub fn add(&mut self, o: &Counts) {
        self.outer_products += o.outer_products;
        self.vec_loads += o.vec_loads;
        self.vec_stores += o.vec_stores;
        self.tile_slices += o.tile_slices;
        self.simd_permutes_avoided += o.simd_permutes_avoided;
        self.gathers_avoided += o.gathers_avoided;
    }

    /// Total MACs implied by the outer products (VL×VL each).
    pub fn macs(&self, vl: u64) -> u64 {
        self.outer_products * vl * vl
    }
}

/// Block geometry. Paper defaults: VL = 16 fp32 lanes, VZ = 4 tiles.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockDims {
    /// Vector length: blocks are `vl × vl` in the x/y plane.
    pub vl: usize,
    /// Block extent along z (tiles stacked per block).
    pub vz: usize,
}

impl Default for BlockDims {
    fn default() -> Self {
        Self { vl: 16, vz: 4 }
    }
}

#[inline]
fn div_up(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

/// Halo-window rows: `row(z, x)` is the y-contiguous `hy`-length row at
/// window coordinates `(z, x)`.  The two implementations are the
/// zero-copy / wrap-copy split: [`DirectWin`] for interior blocks,
/// [`PackedWin`] for boundary blocks.  Crate-visible so the banded-GEMM
/// engine (`stencil::gemm`) stages its panels through the same split.
pub(crate) trait Win {
    fn row(&self, z: usize, x: usize) -> &[f32];
}

/// Packed window buffer (boundary blocks; wrap-copied into the arena).
pub(crate) struct PackedWin<'a> {
    pub(crate) w: &'a [f32],
    pub(crate) hx: usize,
    pub(crate) hy: usize,
}

impl Win for PackedWin<'_> {
    #[inline(always)]
    fn row(&self, z: usize, x: usize) -> &[f32] {
        let b = (z * self.hx + x) * self.hy;
        &self.w[b..b + self.hy]
    }
}

/// Zero-copy window over a fully interior block: rows are strided spans
/// read straight from the source grid — no copy, no allocation.
pub(crate) struct DirectWin<'a, S: GridSrc> {
    pub(crate) g: &'a S,
    pub(crate) nx: usize,
    pub(crate) ny: usize,
    /// Grid coordinates of window origin (block origin minus radius).
    pub(crate) z0: usize,
    pub(crate) x0: usize,
    pub(crate) y0: usize,
    pub(crate) hy: usize,
}

impl<S: GridSrc> Win for DirectWin<'_, S> {
    #[inline(always)]
    fn row(&self, z: usize, x: usize) -> &[f32] {
        let b = ((self.z0 + z) * self.nx + (self.x0 + x)) * self.ny + self.y0;
        self.g.span(b, self.hy)
    }
}

/// Wrap-copy a halo window into `out` (packed `(z, x, y)` order) — the
/// boundary-block path; `out` comes from the scratch arena.
#[allow(clippy::too_many_arguments)]
pub(crate) fn fill_window_wrap<S: GridSrc>(
    g: &S,
    z0: isize,
    x0: isize,
    y0: isize,
    hz: usize,
    hx: usize,
    hy: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), hz * hx * hy);
    let mut i = 0;
    for dz in 0..hz as isize {
        for dx in 0..hx as isize {
            for dy in 0..hy as isize {
                out[i] = g.get_wrap(z0 + dz, x0 + dx, y0 + dy);
                i += 1;
            }
        }
    }
}

/// Star block: x/y passes accumulate into the arena `tmp` buffer; the z
/// pass is applied after the intermediate-buffer round-trip, storing
/// straight into the claimed view rows.
fn star3_block<W: Win>(
    spec: &StencilSpec,
    w: &W,
    out: &mut TileViewMut<'_>,
    z0: usize,
    x0: usize,
    y0: usize,
    bz: usize,
    bx: usize,
    by: usize,
    tmp: &mut [f32],
) {
    let r = spec.radius;
    let (wz, wx, wy) = (&spec.star_axes[0], &spec.star_axes[1], &spec.star_axes[2]);
    debug_assert_eq!(tmp.len(), bz * bx * by);
    // temp buffer = x/y partial + centre (lives in the tile accumulators)
    for z in 0..bz {
        for x in 0..bx {
            let t = &mut tmp[(z * bx + x) * by..][..by];
            let c = w.row(z + r, x + r);
            for y in 0..by {
                t[y] = spec.star_center * c[y + r];
            }
            for i in 0..2 * r + 1 {
                if i == r {
                    continue;
                }
                let wyi = wy[i];
                for y in 0..by {
                    t[y] += wyi * c[y + i];
                }
                let xr = w.row(z + r, x + i);
                let wxi = wx[i];
                for y in 0..by {
                    t[y] += wxi * xr[y + r];
                }
            }
        }
    }
    // z pass reads the window again (different tile orientation) and
    // lands the result in the exclusive view
    for z in 0..bz {
        for x in 0..bx {
            let t = &tmp[(z * bx + x) * by..][..by];
            let o = out.row_mut(z0 + z, x0 + x, y0, by);
            o.copy_from_slice(t);
            for i in 0..2 * r + 1 {
                if i == r {
                    continue;
                }
                let zr = w.row(z + i, x + r);
                let wzi = wz[i];
                for y in 0..by {
                    o[y] += wzi * zr[y + r];
                }
            }
        }
    }
}

fn box3_block<W: Win>(
    spec: &StencilSpec,
    w: &W,
    out: &mut TileViewMut<'_>,
    z0: usize,
    x0: usize,
    y0: usize,
    bz: usize,
    bx: usize,
    by: usize,
) {
    let r = spec.radius;
    let n = 2 * r + 1;
    // Redundant-Access Zeroing order: sub-stencil loop over the shared
    // window (one load of the halo cube serves all (2r+1)^2 passes)
    for z in 0..bz {
        for x in 0..bx {
            let o = out.row_mut(z0 + z, x0 + x, y0, by);
            o.fill(0.0);
            for c in 0..n {
                for a in 0..n {
                    let srow = w.row(z + c, x + a);
                    for b in 0..n {
                        let wv = spec.box_w[(c * n + a) * n + b];
                        for y in 0..by {
                            o[y] += wv * srow[y + b];
                        }
                    }
                }
            }
        }
    }
}

/// Dispatch one block through the zero-copy / wrap-copy window split.
fn compute_block<S: GridSrc>(
    spec: &StencilSpec,
    g: &S,
    view: &mut TileViewMut<'_>,
    z0: usize,
    x0: usize,
    y0: usize,
    bz: usize,
    bx: usize,
    by: usize,
) {
    let r = spec.radius;
    let (gnz, gnx, gny) = g.shape();
    let (hz, hx, hy) = (bz + 2 * r, bx + 2 * r, by + 2 * r);
    let interior = z0 >= r
        && z0 + bz + r <= gnz
        && x0 >= r
        && x0 + bx + r <= gnx
        && y0 >= r
        && y0 + by + r <= gny;
    if interior {
        // zero-copy: strided spans straight from the source
        let win = DirectWin { g, nx: gnx, ny: gny, z0: z0 - r, x0: x0 - r, y0: y0 - r, hy };
        run_block(spec, &win, view, z0, x0, y0, bz, bx, by);
    } else {
        // O(surface) boundary block: wrap-copy into the arena
        scratch::with(hz * hx * hy, |w| {
            fill_window_wrap(
                g,
                z0 as isize - r as isize,
                x0 as isize - r as isize,
                y0 as isize - r as isize,
                hz,
                hx,
                hy,
                w,
            );
            let win = PackedWin { w, hx, hy };
            run_block(spec, &win, view, z0, x0, y0, bz, bx, by);
        });
    }
}

fn run_block<W: Win>(
    spec: &StencilSpec,
    win: &W,
    view: &mut TileViewMut<'_>,
    z0: usize,
    x0: usize,
    y0: usize,
    bz: usize,
    bx: usize,
    by: usize,
) {
    match spec.pattern {
        Pattern::Star => scratch::with(bz * bx * by, |tmp| {
            star3_block(spec, win, view, z0, x0, y0, bz, bx, by, tmp)
        }),
        Pattern::Box => box3_block(spec, win, view, z0, x0, y0, bz, bx, by),
    }
}

/// Compute the claimed region of `out` — an arbitrary sub-box of the
/// periodic sweep — blockwise, returning the accumulated instruction
/// counts.  Blocks tile the *claimed box* from its origin; because
/// every per-point accumulation order is block-independent, the result
/// bytes equal the whole-grid sweep's on that box regardless of how
/// the grid was partitioned into claims.  The per-tile matrix-unit
/// entry point of the engine dispatch layer (`stencil::engine`).
pub fn apply3_region<S: GridSrc>(
    spec: &StencilSpec,
    g: &S,
    out: &mut TileViewMut<'_>,
    dims: BlockDims,
) -> Counts {
    assert_eq!(spec.ndim, 3);
    debug_assert_eq!(g.shape(), out.grid_shape());
    let (vl, vz) = (dims.vl.max(1), dims.vz.max(1));
    let (z0, z1, x0, x1, y0, y1) = out.bounds();
    let mut counts = Counts::default();
    let mut zb = z0;
    while zb < z1 {
        let bz = vz.min(z1 - zb);
        let mut xb = x0;
        while xb < x1 {
            let bx = vl.min(x1 - xb);
            let mut yb = y0;
            while yb < y1 {
                let by = vl.min(y1 - yb);
                counts.add(&match spec.pattern {
                    Pattern::Star => star3_counts(spec, bz, bx, by, vl),
                    Pattern::Box => box3_counts(spec, bz, bx, by, vl),
                });
                compute_block(spec, g, out, zb, xb, yb, bz, bx, by);
                yb += by;
            }
            xb += bx;
        }
        zb += bz;
    }
    counts
}

/// Apply a 3D spec over a periodic grid, blockwise (serial).  Returns
/// the result and the accumulated instruction counts.  Reads go through
/// [`GridSrc`] (zero-copy for interior blocks) and block results land
/// through an exclusive grid view; [`apply3_on`] is the task-parallel
/// form over the same kernels.
pub fn apply3<S: GridSrc>(spec: &StencilSpec, g: &S, dims: BlockDims) -> (Grid3, Counts) {
    assert_eq!(spec.ndim, 3);
    let (gnz, gnx, gny) = g.shape();
    let mut out = Grid3::zeros(gnz, gnx, gny);
    let counts;
    {
        let pg = ParGrid3::new(&mut out);
        let mut view = pg.full_view();
        counts = apply3_region(spec, g, &mut view, dims);
    }
    (out, counts)
}

/// Parallel matrix-unit sweep on `rt`: the z-block loop fans out over
/// the persistent runtime, each task claiming a disjoint z-slab
/// [`TileViewMut`] and running the same per-block kernels as the serial
/// [`apply3`].  Per-task [`Counts`] are merged by reduction — the total
/// is exactly the serial sweep's, and the grid is bitwise identical.
pub fn apply3_on<S: GridSrc>(
    rt: &Runtime,
    spec: &StencilSpec,
    g: &S,
    dims: BlockDims,
    threads: usize,
) -> (Grid3, Counts) {
    assert_eq!(spec.ndim, 3);
    let (gnz, gnx, gny) = g.shape();
    let vz = dims.vz.max(1);
    let nslabs = gnz.div_ceil(vz);
    let mut out = Grid3::zeros(gnz, gnx, gny);
    // one shared accumulator, one uncontended lock per slab: u64 sums
    // commute, so the total is exactly the serial sweep's regardless of
    // task completion order
    let total = std::sync::Mutex::new(Counts::default());
    {
        let pg = ParGrid3::new(&mut out);
        let pg = &pg;
        let total = &total;
        rt.run(threads.max(1), nslabs, &|i| {
            let z0 = i * vz;
            let z1 = (z0 + vz).min(gnz);
            let mut view = pg.view(z0, z1, 0, gnx, 0, gny);
            let c = apply3_region(spec, g, &mut view, dims);
            total.lock().unwrap().add(&c);
        });
    }
    let counts = total.into_inner().unwrap();
    (out, counts)
}

/// [`apply3_on`] over the process-global runtime.
pub fn apply3_par<S: GridSrc>(
    spec: &StencilSpec,
    g: &S,
    dims: BlockDims,
    threads: usize,
) -> (Grid3, Counts) {
    apply3_on(runtime::global(), spec, g, dims, threads)
}

/// 1-D band pass along `axis` (0 = z, 1 = x, 2 = y) over the claimed
/// region — the matrix-unit axis-derivative kernel behind
/// `Engine::{d1,d2}_axis_into` (the §IV-G decomposition: RTM derivative
/// sweeps as single outer-product passes).
///
/// Blockwise like [`apply3_region`], with the same zero-copy /
/// wrap-copy window split — except the halo extends along `axis`
/// **only** (a 1-D band needs no halo on the other axes), so boundary
/// windows are a 2r-slab, not a cube.  `band` has odd length 2r+1,
/// centre at index r.  Returns the one-pass instruction counts
/// (window loads, outer products, result stores; the x-axis pass also
/// records its Tile-Assisted Vector Transpose slices).
pub fn d_axis_region<S: GridSrc>(
    band: &[f32],
    axis: usize,
    g: &S,
    out: &mut TileViewMut<'_>,
    dims: BlockDims,
) -> Counts {
    assert!(axis < 3, "axis must be 0 (z), 1 (x), or 2 (y)");
    assert_eq!(band.len() % 2, 1, "band must have odd length");
    debug_assert_eq!(g.shape(), out.grid_shape());
    let r = band.len() / 2;
    let (vl, vz) = (dims.vl.max(1), dims.vz.max(1));
    let (z0, z1, x0, x1, y0, y1) = out.bounds();
    let mut counts = Counts::default();
    let mut zb = z0;
    while zb < z1 {
        let bz = vz.min(z1 - zb);
        let mut xb = x0;
        while xb < x1 {
            let bx = vl.min(x1 - xb);
            let mut yb = y0;
            while yb < y1 {
                let by = vl.min(y1 - yb);
                counts.add(&axis_counts(r, axis, bz, bx, by, vl));
                compute_axis_block(band, axis, g, out, zb, xb, yb, bz, bx, by);
                yb += by;
            }
            xb += bx;
        }
        zb += bz;
    }
    counts
}

/// Dispatch one axis-pass block through the zero-copy / wrap-copy
/// window split (halo along `axis` only).
#[allow(clippy::too_many_arguments)]
fn compute_axis_block<S: GridSrc>(
    band: &[f32],
    axis: usize,
    g: &S,
    view: &mut TileViewMut<'_>,
    z0: usize,
    x0: usize,
    y0: usize,
    bz: usize,
    bx: usize,
    by: usize,
) {
    let r = band.len() / 2;
    let (gnz, gnx, gny) = g.shape();
    let hz = bz + if axis == 0 { 2 * r } else { 0 };
    let hx = bx + if axis == 1 { 2 * r } else { 0 };
    let hy = by + if axis == 2 { 2 * r } else { 0 };
    let oz = z0 as isize - if axis == 0 { r as isize } else { 0 };
    let ox = x0 as isize - if axis == 1 { r as isize } else { 0 };
    let oy = y0 as isize - if axis == 2 { r as isize } else { 0 };
    let interior = oz >= 0
        && oz as usize + hz <= gnz
        && ox >= 0
        && ox as usize + hx <= gnx
        && oy >= 0
        && oy as usize + hy <= gny;
    if interior {
        let win = DirectWin {
            g,
            nx: gnx,
            ny: gny,
            z0: oz as usize,
            x0: ox as usize,
            y0: oy as usize,
            hy,
        };
        axis_band_block(band, axis, &win, view, z0, x0, y0, bz, bx, by);
    } else {
        scratch::with(hz * hx * hy, |buf| {
            fill_window_wrap(g, oz, ox, oy, hz, hx, hy, buf);
            let win = PackedWin { w: buf, hx, hy };
            axis_band_block(band, axis, &win, view, z0, x0, y0, bz, bx, by);
        });
    }
}

/// One axis-pass block: per output row, accumulate the 2r+1 band taps
/// as whole shifted window rows (axis z/x) or shifted in-row slices
/// (axis y), landing straight in the claimed view.
#[allow(clippy::too_many_arguments)]
fn axis_band_block<W: Win>(
    band: &[f32],
    axis: usize,
    win: &W,
    out: &mut TileViewMut<'_>,
    z0: usize,
    x0: usize,
    y0: usize,
    bz: usize,
    bx: usize,
    by: usize,
) {
    let r = band.len() / 2;
    for z in 0..bz {
        for x in 0..bx {
            let o = out.row_mut(z0 + z, x0 + x, y0, by);
            if axis == 2 {
                let c = win.row(z, x);
                for y in 0..by {
                    o[y] = band[r] * c[y + r];
                }
                for (k, &wk) in band.iter().enumerate() {
                    if k == r {
                        continue;
                    }
                    for y in 0..by {
                        o[y] += wk * c[y + k];
                    }
                }
            } else {
                {
                    let c = if axis == 0 { win.row(z + r, x) } else { win.row(z, x + r) };
                    for y in 0..by {
                        o[y] = band[r] * c[y];
                    }
                }
                for (k, &wk) in band.iter().enumerate() {
                    if k == r {
                        continue;
                    }
                    let s = if axis == 0 { win.row(z + k, x) } else { win.row(z, x + k) };
                    for y in 0..by {
                        o[y] += wk * s[y];
                    }
                }
            }
        }
    }
}

/// Instruction counts of one 1-D axis pass on one block: the window is
/// loaded once and consumed by a single outer-product pass; the x-axis
/// pass additionally pays (and saves) the tile-transpose traffic.
fn axis_counts(r: usize, axis: usize, bz: usize, bx: usize, by: usize, vl: usize) -> Counts {
    let hz = bz + if axis == 0 { 2 * r } else { 0 };
    let hx = bx + if axis == 1 { 2 * r } else { 0 };
    let hy = by + if axis == 2 { 2 * r } else { 0 };
    let mut c = Counts::default();
    c.vec_loads += (hz * hx * div_up(hy, vl)) as u64;
    c.outer_products += div_up(hz * hx * hy, vl) as u64;
    if axis == 1 {
        c.tile_slices += (2 * vl * bz) as u64;
        c.simd_permutes_avoided += (vl * vl.ilog2() as usize * bz) as u64;
        c.gathers_avoided += (bz * hx) as u64;
    }
    c.vec_stores += div_up(bz * bx * by, vl) as u64;
    c
}

fn star3_counts(spec: &StencilSpec, bz: usize, bx: usize, by: usize, vl: usize) -> Counts {
    let r = spec.radius;
    let (hz, hx, hy) = (bz + 2 * r, bx + 2 * r, by + 2 * r);
    let vl64 = vl as u64;
    let mut c = Counts::default();
    // one window load (brick scheme: whole halo cube, contiguous bricks)
    c.vec_loads += (hz * hx * div_up(hy, vl)) as u64;
    // y pass: consume (bz, bx, hy) window
    c.outer_products += div_up(bz * bx * hy, vl) as u64;
    // x pass: consume (bz, hx, by); needs per-layer tile transpose
    c.outer_products += div_up(bz * hx * by, vl) as u64;
    c.tile_slices += (2 * vl * bz) as u64;
    c.simd_permutes_avoided += (vl * vl.ilog2() as usize * bz) as u64;
    c.gathers_avoided += (bz * hx) as u64;
    // z pass: consume (hz, bx, by); intermediate buffer round-trip
    c.outer_products += div_up(hz * bx * by, vl) as u64;
    c.vec_stores += div_up(bz * bx * by, vl) as u64; // tmp store
    c.vec_loads += div_up(bz * bx * by, vl) as u64; // tmp reload
    // final result store
    c.vec_stores += div_up(bz * bx * by, vl) as u64;
    let _ = vl64;
    c
}

fn box3_counts(spec: &StencilSpec, bz: usize, bx: usize, by: usize, vl: usize) -> Counts {
    let r = spec.radius;
    let n = (2 * r + 1) as u64;
    let (hz, hx, hy) = (bz + 2 * r, bx + 2 * r, by + 2 * r);
    let mut c = Counts::default();
    c.vec_loads += (hz * hx * div_up(hy, vl)) as u64;
    // (2r+1)^2 y-axis passes over the shared window (splicing: no reloads)
    c.outer_products += n * n * div_up(bz * bx * hy, vl) as u64;
    c.vec_stores += div_up(bz * bx * by, vl) as u64;
    c
}

/// 2D window rows (`row(x)` is the y-contiguous `hy`-length row):
/// zero-copy for interior blocks, arena-packed for boundary blocks.
enum Win2<'a> {
    Packed { w: &'a [f32], hy: usize },
    Direct { data: &'a [f32], ny: usize, x0: usize, y0: usize, hy: usize },
}

impl Win2<'_> {
    #[inline(always)]
    fn row(&self, x: usize) -> &[f32] {
        match *self {
            Win2::Packed { w, hy } => &w[x * hy..(x + 1) * hy],
            Win2::Direct { data, ny, x0, y0, hy } => {
                let b = (x0 + x) * ny + y0;
                &data[b..b + hy]
            }
        }
    }
}

fn star2_block(
    spec: &StencilSpec,
    w: &Win2<'_>,
    out: &mut Grid2,
    x0: usize,
    y0: usize,
    bx: usize,
    by: usize,
) {
    let r = spec.radius;
    let (wx, wy) = (&spec.star_axes[0], &spec.star_axes[1]);
    for x in 0..bx {
        let ob = out.idx(x0 + x, y0);
        let o = &mut out.data[ob..ob + by];
        let c = w.row(x + r);
        for y in 0..by {
            o[y] = spec.star_center * c[y + r];
        }
        for i in 0..2 * r + 1 {
            if i == r {
                continue;
            }
            let wyi = wy[i];
            for y in 0..by {
                o[y] += wyi * c[y + i];
            }
            let xr = w.row(x + i);
            let wxi = wx[i];
            for y in 0..by {
                o[y] += wxi * xr[y + r];
            }
        }
    }
}

fn run2_block(
    spec: &StencilSpec,
    w: &Win2<'_>,
    out: &mut Grid2,
    x0: usize,
    y0: usize,
    bx: usize,
    by: usize,
) {
    match spec.pattern {
        Pattern::Star => star2_block(spec, w, out, x0, y0, bx, by),
        Pattern::Box => box2_block(spec, w, out, x0, y0, bx, by),
    }
}

fn box2_block(
    spec: &StencilSpec,
    w: &Win2<'_>,
    out: &mut Grid2,
    x0: usize,
    y0: usize,
    bx: usize,
    by: usize,
) {
    let r = spec.radius;
    let n = 2 * r + 1;
    for x in 0..bx {
        let ob = out.idx(x0 + x, y0);
        let o = &mut out.data[ob..ob + by];
        o.fill(0.0);
        for a in 0..n {
            let srow = w.row(x + a);
            for b in 0..n {
                let wv = spec.box_w[a * n + b];
                for y in 0..by {
                    o[y] += wv * srow[y + b];
                }
            }
        }
    }
}

/// 2D variant (VZ = 1 blocks), with the same zero-copy / wrap-copy
/// window split as [`apply3`]: interior blocks read rows straight from
/// the grid, boundary blocks wrap-copy into the scratch arena.
pub fn apply2(spec: &StencilSpec, g: &Grid2, dims: BlockDims) -> (Grid2, Counts) {
    assert_eq!(spec.ndim, 2);
    let vl = dims.vl;
    let r = spec.radius;
    let mut out = Grid2::zeros(g.nx, g.ny);
    let mut counts = Counts::default();
    let mut x0 = 0;
    while x0 < g.nx {
        let bx = vl.min(g.nx - x0);
        let mut y0 = 0;
        while y0 < g.ny {
            let by = vl.min(g.ny - y0);
            let (hx, hy) = (bx + 2 * r, by + 2 * r);
            let interior = x0 >= r && x0 + bx + r <= g.nx && y0 >= r && y0 + by + r <= g.ny;
            if interior {
                let win = Win2::Direct { data: &g.data, ny: g.ny, x0: x0 - r, y0: y0 - r, hy };
                run2_block(spec, &win, &mut out, x0, y0, bx, by);
            } else {
                scratch::with(hx * hy, |buf| {
                    let mut i = 0;
                    for dx in 0..hx as isize {
                        for dy in 0..hy as isize {
                            let gx = x0 as isize - r as isize + dx;
                            let gy = y0 as isize - r as isize + dy;
                            buf[i] = g.get_wrap(gx, gy);
                            i += 1;
                        }
                    }
                    let win = Win2::Packed { w: buf, hy };
                    run2_block(spec, &win, &mut out, x0, y0, bx, by);
                });
            }
            match spec.pattern {
                Pattern::Star => {
                    counts.vec_loads += (hx * div_up(hy, vl)) as u64;
                    counts.outer_products += div_up(bx * hy, vl) as u64; // y
                    counts.outer_products += div_up(hx * by, vl) as u64; // x
                    counts.tile_slices += (2 * vl) as u64;
                    counts.simd_permutes_avoided += (vl * vl.ilog2() as usize) as u64;
                    counts.gathers_avoided += hx as u64;
                    counts.vec_stores += div_up(bx * by, vl) as u64;
                }
                Pattern::Box => {
                    let n = (2 * r + 1) as u64;
                    counts.vec_loads += (hx * div_up(hy, vl)) as u64;
                    counts.outer_products += n * div_up(bx * hy, vl) as u64;
                    counts.vec_stores += div_up(bx * by, vl) as u64;
                }
            }
            y0 += by;
        }
        x0 += bx;
    }
    (out, counts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::naive;
    use crate::util::prop::{assert_allclose, forall};

    #[test]
    fn matches_naive_all_benchmarks() {
        for (name, spec) in StencilSpec::benchmark_suite() {
            if spec.ndim == 3 {
                let g = Grid3::random(8, 20, 24, 7);
                let want = naive::apply3(&spec, &g);
                let (got, counts) = apply3(&spec, &g, BlockDims::default());
                assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
                assert!(counts.outer_products > 0, "{name}");
            } else {
                let g = Grid2::random(24, 40, 8);
                let want = naive::apply2(&spec, &g);
                let (got, counts) = apply2(&spec, &g, BlockDims::default());
                assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
                assert!(counts.outer_products > 0, "{name}");
            }
        }
    }

    #[test]
    fn ragged_grids_agree() {
        forall(10, 0x3A7, |rng| {
            let spec = StencilSpec::star3d(rng.range(1, 4));
            // dims not multiples of the block
            let (nz, nx, ny) = (rng.range(3, 9), rng.range(5, 21), rng.range(5, 21));
            let g = Grid3::random(nz, nx, ny, rng.next_u64());
            let want = naive::apply3(&spec, &g);
            let (got, _) = apply3(&spec, &g, BlockDims::default());
            assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
        });
    }

    #[test]
    fn interior_blocks_agree_with_boundary_blocks() {
        // a grid large enough that the default (16,16,4) blocks include
        // fully interior ones: the zero-copy path must agree with naive
        for spec in [StencilSpec::star3d(2), StencilSpec::box3d(1)] {
            let g = Grid3::random(12, 40, 40, 13);
            let want = naive::apply3(&spec, &g);
            let (got, _) = apply3(&spec, &g, BlockDims::default());
            assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
        }
    }

    #[test]
    fn apply2_interior_split_agrees() {
        for spec in [StencilSpec::star2d(2), StencilSpec::box2d(1)] {
            let g = Grid2::random(40, 40, 17);
            let want = naive::apply2(&spec, &g);
            let (got, _) = apply2(&spec, &g, BlockDims::default());
            assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
        }
    }

    #[test]
    fn parallel_sweep_is_bitwise_serial_with_exact_counts() {
        // blocks chosen so interior (zero-copy) and boundary (packed)
        // paths both run; counts must be *exactly* equal and the grid
        // *bitwise* equal for any worker count
        let dims = BlockDims::default();
        for spec in [StencilSpec::star3d(3), StencilSpec::box3d(2)] {
            let g = Grid3::random(13, 40, 37, 3);
            let (want, cw) = apply3(&spec, &g, dims);
            for workers in [1, 2, 4] {
                let rt = Runtime::with_workers(workers);
                let (got, cg) = apply3_on(&rt, &spec, &g, dims, workers);
                assert_eq!(got.data, want.data, "workers={workers}");
                assert_eq!(cg, cw, "workers={workers}");
            }
        }
    }

    #[test]
    fn outer_product_count_matches_iv_b_model() {
        // One full (4,16,16) star block, radius r: the §IV-B model says a
        // (VL,VL) tile takes VL+2r outer products per axis pass.
        let r = 4;
        let spec = StencilSpec::star3d(r);
        let g = Grid3::random(4, 16, 16, 9);
        let (_, c) = apply3(&spec, &g, BlockDims::default());
        let vl = 16u64;
        let vz = 4u64;
        let want_y = vz * (vl + 2 * r as u64); // 4 tiles × 24
        let want_x = vz * (vl + 2 * r as u64);
        let want_z = (vz + 2 * r as u64) * vl; // layer-axis pass
        assert_eq!(c.outer_products, want_y + want_x + want_z);
    }

    #[test]
    fn transpose_instruction_savings() {
        // 2·VL tile slices vs VL·log2(VL) permutes: 32 vs 64 at VL=16
        let spec = StencilSpec::star2d(2);
        let g = Grid2::random(16, 16, 10);
        let (_, c) = apply2(&spec, &g, BlockDims::default());
        assert_eq!(c.tile_slices, 32);
        assert_eq!(c.simd_permutes_avoided, 64);
    }

    #[test]
    fn box_zeroing_loads_window_once() {
        // box3 r2 on one block: loads = halo cube vectors, independent of
        // the (2r+1)^2 = 25 sub-stencil passes
        let spec = StencilSpec::box3d(2);
        let g = Grid3::random(4, 16, 16, 11);
        let (_, c) = apply3(&spec, &g, BlockDims::default());
        let loads = (4 + 4) * (16 + 4) * (20f64 / 16f64).ceil() as u64;
        assert_eq!(c.vec_loads, loads);
        assert_eq!(c.outer_products, 25 * ((4 * 16 * 20) as f64 / 16.0).ceil() as u64);
    }

    #[test]
    fn axis_pass_counts_one_block() {
        // one (4,16,16) block, r=4 band along y: window = (4,16,24),
        // loaded once, consumed by one outer-product pass
        let w2 = crate::stencil::coeffs::second_deriv(4);
        let g = Grid3::random(4, 16, 16, 5);
        let mut out = Grid3::zeros(4, 16, 16);
        let c;
        {
            let pg = ParGrid3::new(&mut out);
            let mut view = pg.full_view();
            c = d_axis_region(&w2, 2, &g, &mut view, BlockDims::default());
        }
        assert_eq!(c.vec_loads, (4 * 16 * 2) as u64);
        assert_eq!(c.outer_products, ((4 * 16 * 24) / 16) as u64);
        assert_eq!(c.vec_stores, 64);
        assert_eq!(c.tile_slices, 0, "y pass needs no tile transpose");
        // the x-axis pass pays the Tile-Assisted Vector Transpose
        let mut out2 = Grid3::zeros(4, 16, 16);
        let cx;
        {
            let pg = ParGrid3::new(&mut out2);
            let mut view = pg.full_view();
            cx = d_axis_region(&w2, 1, &g, &mut view, BlockDims::default());
        }
        assert_eq!(cx.tile_slices, (2 * 16 * 4) as u64);
        assert_eq!(cx.gathers_avoided, (4 * 24) as u64);
    }

    #[test]
    fn steady_state_sweeps_do_not_grow_the_arena() {
        // serial sweeps run on this thread: after one warm-up pass the
        // thread-local arena must satisfy every block without growing
        let dims = BlockDims::default();
        let g = Grid3::random(8, 40, 40, 23);
        for spec in [StencilSpec::star3d(4), StencilSpec::box3d(2)] {
            apply3(&spec, &g, dims); // warm-up
            let before = scratch::local_grow_events();
            apply3(&spec, &g, dims);
            apply3(&spec, &g, dims);
            assert_eq!(scratch::local_grow_events(), before, "arena grew after warm-up");
        }
    }
}
