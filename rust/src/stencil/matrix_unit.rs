//! The MMStencil matrix-unit algorithm (paper §IV-A/§IV-C), emulated.
//!
//! Numerics: the grid is swept in `(VZ, VX, VY)` blocks; each block loads
//! a halo-extended window once (the brick scheme) and computes per-axis
//! 1D stencils as outer-product accumulations into 16×16 tiles, with the
//! x/y partial kept in a temporary buffer before the z pass (Cache
//! Pollution Avoiding Intermediate Result Placement).
//!
//! Instruction accounting: every block records the instruction mix the
//! paper reasons about —
//!
//! * `outer_products` — one per VL-element input vector consumed by a
//!   1D-stencil pass (`window_elems / VL`, the Fig. 4 mapping),
//! * `tile_slices`   — Tile-Assisted Vector Transpose: 2·VL per 16×16
//!   tile transposed (vs `VL·log2(VL)` SIMD permutes, also recorded for
//!   the comparison bench),
//! * `vec_loads` / `vec_stores` — window loads, result stores, and the
//!   intermediate-buffer round-trip of the z pass,
//!
//! which `simulator::roofline` converts to cycles with CPI_Matrix = 2,
//! 4-cycle outer-product latency, and the SIMD/Matrix frequency ratio.

use super::{Pattern, StencilSpec};
use crate::grid::par::{GridSrc, ParGrid3};
use crate::grid::{Grid2, Grid3};

/// Instruction counters for the matrix-unit model.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Counts {
    pub outer_products: u64,
    pub vec_loads: u64,
    pub vec_stores: u64,
    /// Matrix-tile horizontal/vertical slice insert/extract instructions.
    pub tile_slices: u64,
    /// SIMD permutation count a permutation-network transpose *would*
    /// have used (for the §IV-C.b comparison; not on the hot path).
    pub simd_permutes_avoided: u64,
    /// Strided-gather vector loads a direct x-axis sweep *would* need.
    pub gathers_avoided: u64,
}

impl Counts {
    pub fn add(&mut self, o: &Counts) {
        self.outer_products += o.outer_products;
        self.vec_loads += o.vec_loads;
        self.vec_stores += o.vec_stores;
        self.tile_slices += o.tile_slices;
        self.simd_permutes_avoided += o.simd_permutes_avoided;
        self.gathers_avoided += o.gathers_avoided;
    }

    /// Total MACs implied by the outer products (VL×VL each).
    pub fn macs(&self, vl: u64) -> u64 {
        self.outer_products * vl * vl
    }
}

/// Block geometry. Paper defaults: VL = 16 fp32 lanes, VZ = 4 tiles.
#[derive(Clone, Copy, Debug)]
pub struct BlockDims {
    pub vl: usize,
    pub vz: usize,
}

impl Default for BlockDims {
    fn default() -> Self {
        Self { vl: 16, vz: 4 }
    }
}

#[inline]
fn div_up(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

/// Apply a 3D spec over a periodic grid, blockwise. Returns the result
/// and the accumulated instruction counts.  Reads go through [`GridSrc`]
/// and block results land through an exclusive grid view, so the block
/// loop is ready to be task-parallelized over disjoint claims.
pub fn apply3<S: GridSrc>(spec: &StencilSpec, g: &S, dims: BlockDims) -> (Grid3, Counts) {
    assert_eq!(spec.ndim, 3);
    let (vl, vz) = (dims.vl, dims.vz);
    let r = spec.radius;
    let (gnz, gnx, gny) = g.shape();
    let mut out = Grid3::zeros(gnz, gnx, gny);
    let mut counts = Counts::default();
    {
        let pg = ParGrid3::new(&mut out);
        let mut view = pg.full_view();
        let mut z0 = 0;
        while z0 < gnz {
            let bz = vz.min(gnz - z0);
            let mut x0 = 0;
            while x0 < gnx {
                let bx = vl.min(gnx - x0);
                let mut y0 = 0;
                while y0 < gny {
                    let by = vl.min(gny - y0);
                    let window = g.extract_wrap(
                        z0 as isize - r as isize,
                        x0 as isize - r as isize,
                        y0 as isize - r as isize,
                        bz + 2 * r,
                        bx + 2 * r,
                        by + 2 * r,
                    );
                    let block = match spec.pattern {
                        Pattern::Star => {
                            counts.add(&star3_counts(spec, bz, bx, by, vl));
                            star3_block(spec, &window, bz, bx, by)
                        }
                        Pattern::Box => {
                            counts.add(&box3_counts(spec, bz, bx, by, vl));
                            box3_block(spec, &window, bz, bx, by)
                        }
                    };
                    view.insert_block(z0, x0, y0, bz, bx, by, &block);
                    y0 += by;
                }
                x0 += bx;
            }
            z0 += bz;
        }
    }
    (out, counts)
}

/// Star block: x/y passes accumulate into a temp tile buffer; z pass is
/// applied after an intermediate-buffer round-trip.
fn star3_block(spec: &StencilSpec, w: &[f32], bz: usize, bx: usize, by: usize) -> Vec<f32> {
    let r = spec.radius;
    let (wz, wx, wy) = (&spec.star_axes[0], &spec.star_axes[1], &spec.star_axes[2]);
    let (hx, hy) = (bx + 2 * r, by + 2 * r);
    let at = |z: usize, x: usize, y: usize| w[(z * hx + x) * hy + y];
    // temp buffer = x/y partial + centre (lives in the tile accumulators)
    let mut tmp = vec![0.0f32; bz * bx * by];
    for z in 0..bz {
        for x in 0..bx {
            for y in 0..by {
                // outer-product order: iterate input index, accumulate
                let mut acc = spec.star_center * at(z + r, x + r, y + r);
                for i in 0..2 * r + 1 {
                    if i == r {
                        continue;
                    }
                    acc += wy[i] * at(z + r, x + r, y + i);
                    acc += wx[i] * at(z + r, x + i, y + r);
                }
                tmp[(z * bx + x) * by + y] = acc;
            }
        }
    }
    // z pass reads the window again (different tile orientation)
    let mut outb = tmp;
    for z in 0..bz {
        for x in 0..bx {
            for y in 0..by {
                let mut acc = 0.0f32;
                for i in 0..2 * r + 1 {
                    if i == r {
                        continue;
                    }
                    acc += wz[i] * at(z + i, x + r, y + r);
                }
                outb[(z * bx + x) * by + y] += acc;
            }
        }
    }
    outb
}

fn box3_block(spec: &StencilSpec, w: &[f32], bz: usize, bx: usize, by: usize) -> Vec<f32> {
    let r = spec.radius;
    let n = 2 * r + 1;
    let (hx, hy) = (bx + 2 * r, by + 2 * r);
    let at = |z: usize, x: usize, y: usize| w[(z * hx + x) * hy + y];
    let mut outb = vec![0.0f32; bz * bx * by];
    // Redundant-Access Zeroing order: sub-stencil loop innermost over the
    // shared window (one load of the halo cube serves all (2r+1)^2 passes)
    for z in 0..bz {
        for x in 0..bx {
            for y in 0..by {
                let mut acc = 0.0f32;
                for c in 0..n {
                    for a in 0..n {
                        for b in 0..n {
                            acc += spec.box_w[(c * n + a) * n + b] * at(z + c, x + a, y + b);
                        }
                    }
                }
                outb[(z * bx + x) * by + y] = acc;
            }
        }
    }
    outb
}

fn star3_counts(spec: &StencilSpec, bz: usize, bx: usize, by: usize, vl: usize) -> Counts {
    let r = spec.radius;
    let (hz, hx, hy) = (bz + 2 * r, bx + 2 * r, by + 2 * r);
    let vl64 = vl as u64;
    let mut c = Counts::default();
    // one window load (brick scheme: whole halo cube, contiguous bricks)
    c.vec_loads += (hz * hx * div_up(hy, vl)) as u64;
    // y pass: consume (bz, bx, hy) window
    c.outer_products += div_up(bz * bx * hy, vl) as u64;
    // x pass: consume (bz, hx, by); needs per-layer tile transpose
    c.outer_products += div_up(bz * hx * by, vl) as u64;
    c.tile_slices += (2 * vl * bz) as u64;
    c.simd_permutes_avoided += (vl * vl.ilog2() as usize * bz) as u64;
    c.gathers_avoided += (bz * hx) as u64;
    // z pass: consume (hz, bx, by); intermediate buffer round-trip
    c.outer_products += div_up(hz * bx * by, vl) as u64;
    c.vec_stores += div_up(bz * bx * by, vl) as u64; // tmp store
    c.vec_loads += div_up(bz * bx * by, vl) as u64; // tmp reload
    // final result store
    c.vec_stores += div_up(bz * bx * by, vl) as u64;
    let _ = vl64;
    c
}

fn box3_counts(spec: &StencilSpec, bz: usize, bx: usize, by: usize, vl: usize) -> Counts {
    let r = spec.radius;
    let n = (2 * r + 1) as u64;
    let (hz, hx, hy) = (bz + 2 * r, bx + 2 * r, by + 2 * r);
    let mut c = Counts::default();
    c.vec_loads += (hz * hx * div_up(hy, vl)) as u64;
    // (2r+1)^2 y-axis passes over the shared window (splicing: no reloads)
    c.outer_products += n * n * div_up(bz * bx * hy, vl) as u64;
    c.vec_stores += div_up(bz * bx * by, vl) as u64;
    c
}

/// 2D variant (VZ = 1 blocks).
pub fn apply2(spec: &StencilSpec, g: &Grid2, dims: BlockDims) -> (Grid2, Counts) {
    assert_eq!(spec.ndim, 2);
    let vl = dims.vl;
    let r = spec.radius;
    let mut out = Grid2::zeros(g.nx, g.ny);
    let mut counts = Counts::default();
    let mut x0 = 0;
    while x0 < g.nx {
        let bx = vl.min(g.nx - x0);
        let mut y0 = 0;
        while y0 < g.ny {
            let by = vl.min(g.ny - y0);
            let (hx, hy) = (bx + 2 * r, by + 2 * r);
            let mut window = Vec::with_capacity(hx * hy);
            for dx in 0..hx as isize {
                for dy in 0..hy as isize {
                    let gx = x0 as isize - r as isize + dx;
                    let gy = y0 as isize - r as isize + dy;
                    window.push(g.get_wrap(gx, gy));
                }
            }
            let at = |x: usize, y: usize| window[x * hy + y];
            match spec.pattern {
                Pattern::Star => {
                    let (wx, wy) = (&spec.star_axes[0], &spec.star_axes[1]);
                    for x in 0..bx {
                        for y in 0..by {
                            let mut acc = spec.star_center * at(x + r, y + r);
                            for i in 0..2 * r + 1 {
                                if i == r {
                                    continue;
                                }
                                acc += wy[i] * at(x + r, y + i);
                                acc += wx[i] * at(x + i, y + r);
                            }
                            out.set(x0 + x, y0 + y, acc);
                        }
                    }
                    counts.vec_loads += (hx * div_up(hy, vl)) as u64;
                    counts.outer_products += div_up(bx * hy, vl) as u64; // y
                    counts.outer_products += div_up(hx * by, vl) as u64; // x
                    counts.tile_slices += (2 * vl) as u64;
                    counts.simd_permutes_avoided += (vl * vl.ilog2() as usize) as u64;
                    counts.gathers_avoided += hx as u64;
                    counts.vec_stores += div_up(bx * by, vl) as u64;
                }
                Pattern::Box => {
                    let n = 2 * r + 1;
                    for x in 0..bx {
                        for y in 0..by {
                            let mut acc = 0.0f32;
                            for a in 0..n {
                                for b in 0..n {
                                    acc += spec.box_w[a * n + b] * at(x + a, y + b);
                                }
                            }
                            out.set(x0 + x, y0 + y, acc);
                        }
                    }
                    counts.vec_loads += (hx * div_up(hy, vl)) as u64;
                    counts.outer_products += (n as u64) * div_up(bx * hy, vl) as u64;
                    counts.vec_stores += div_up(bx * by, vl) as u64;
                }
            }
            y0 += by;
        }
        x0 += bx;
    }
    (out, counts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::naive;
    use crate::util::prop::{assert_allclose, forall};

    #[test]
    fn matches_naive_all_benchmarks() {
        for (name, spec) in StencilSpec::benchmark_suite() {
            if spec.ndim == 3 {
                let g = Grid3::random(8, 20, 24, 7);
                let want = naive::apply3(&spec, &g);
                let (got, counts) = apply3(&spec, &g, BlockDims::default());
                assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
                assert!(counts.outer_products > 0, "{name}");
            } else {
                let g = Grid2::random(24, 40, 8);
                let want = naive::apply2(&spec, &g);
                let (got, counts) = apply2(&spec, &g, BlockDims::default());
                assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
                assert!(counts.outer_products > 0, "{name}");
            }
        }
    }

    #[test]
    fn ragged_grids_agree() {
        forall(10, 0x3A7, |rng| {
            let spec = StencilSpec::star3d(rng.range(1, 4));
            // dims not multiples of the block
            let (nz, nx, ny) = (rng.range(3, 9), rng.range(5, 21), rng.range(5, 21));
            let g = Grid3::random(nz, nx, ny, rng.next_u64());
            let want = naive::apply3(&spec, &g);
            let (got, _) = apply3(&spec, &g, BlockDims::default());
            assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
        });
    }

    #[test]
    fn outer_product_count_matches_iv_b_model() {
        // One full (4,16,16) star block, radius r: the §IV-B model says a
        // (VL,VL) tile takes VL+2r outer products per axis pass.
        let r = 4;
        let spec = StencilSpec::star3d(r);
        let g = Grid3::random(4, 16, 16, 9);
        let (_, c) = apply3(&spec, &g, BlockDims::default());
        let vl = 16u64;
        let vz = 4u64;
        let want_y = vz * (vl + 2 * r as u64); // 4 tiles × 24
        let want_x = vz * (vl + 2 * r as u64);
        let want_z = (vz + 2 * r as u64) * vl; // layer-axis pass
        assert_eq!(c.outer_products, want_y + want_x + want_z);
    }

    #[test]
    fn transpose_instruction_savings() {
        // 2·VL tile slices vs VL·log2(VL) permutes: 32 vs 64 at VL=16
        let spec = StencilSpec::star2d(2);
        let g = Grid2::random(16, 16, 10);
        let (_, c) = apply2(&spec, &g, BlockDims::default());
        assert_eq!(c.tile_slices, 32);
        assert_eq!(c.simd_permutes_avoided, 64);
    }

    #[test]
    fn box_zeroing_loads_window_once() {
        // box3 r2 on one block: loads = halo cube vectors, independent of
        // the (2r+1)^2 = 25 sub-stencil passes
        let spec = StencilSpec::box3d(2);
        let g = Grid3::random(4, 16, 16, 11);
        let (_, c) = apply3(&spec, &g, BlockDims::default());
        let loads = (4 + 4) * (16 + 4) * (20f64 / 16f64).ceil() as u64;
        assert_eq!(c.vec_loads, loads);
        assert_eq!(c.outer_products, 25 * ((4 * 16 * 20) as f64 / 16.0).ceil() as u64);
    }
}
