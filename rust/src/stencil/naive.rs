//! Naive direct-loop engines — the paper's "compiler baseline" and the
//! semantic reference for every other rust engine.  Periodic boundaries,
//! matching the jnp.roll grid oracles in `python/compile/kernels/ref.py`.
//!
//! The 3D write path goes through an exclusive `TileViewMut`, so the
//! same code doubles as the per-region oracle for the parallel
//! coordinator tests ([`apply3_region`]).
//!
//! The 3D sweeps split the region against `grid::shell`: deep-interior
//! points read directly (no `rem_euclid`), only the O(surface) shell
//! slabs take the wrapped path.  The per-point accumulation order is
//! identical in both branches and a direct read equals a wrapped read
//! of an in-bounds point, so results are **bitwise unchanged** — the
//! oracle stays the oracle, just without a full-volume wrap scan.

use super::{Pattern, StencilSpec};
use crate::grid::par::{GridSrc, ParGrid3, TileViewMut};
use crate::grid::{shell, Grid2, Grid3};

/// Apply a 3D spec to a periodic grid.
pub fn apply3(spec: &StencilSpec, g: &Grid3) -> Grid3 {
    assert_eq!(spec.ndim, 3);
    let mut out = Grid3::zeros(g.nz, g.nx, g.ny);
    {
        let pg = ParGrid3::new(&mut out);
        let mut view = pg.full_view();
        apply3_region(spec, g, &mut view);
    }
    out
}

/// Reference result for the claimed region of `out` — the per-tile
/// oracle the parallel coordinator and the aliasing suite check against.
pub fn apply3_region<S: GridSrc>(spec: &StencilSpec, g: &S, out: &mut TileViewMut<'_>) {
    assert_eq!(spec.ndim, 3);
    match spec.pattern {
        Pattern::Star => star3(spec, g, out),
        Pattern::Box => box3(spec, g, out),
    }
}

/// Apply a 2D spec to a periodic grid.
pub fn apply2(spec: &StencilSpec, g: &Grid2) -> Grid2 {
    assert_eq!(spec.ndim, 2);
    match spec.pattern {
        Pattern::Star => star2(spec, g),
        Pattern::Box => box2(spec, g),
    }
}

/// 1-D band pass along `axis` (0 = z, 1 = x, 2 = y) over the claimed
/// region, with periodic wrap everywhere — **the axis-derivative
/// oracle**.  These are the RTM propagators' original scalar loops,
/// demoted here when `rtm::{vti,tti}` moved onto the engine dispatch
/// layer: one wrapped multiply-accumulate per band tap per point, taps
/// in ascending `k` order (matching the `jnp.roll` reference), no
/// interior/shell split.  `band` has odd length 2r+1 with the centre
/// weight at index r (zero for first derivatives).
pub fn d_axis_region<S: GridSrc>(band: &[f32], axis: usize, g: &S, out: &mut TileViewMut<'_>) {
    assert!(axis < 3, "axis must be 0 (z), 1 (x), or 2 (y)");
    assert_eq!(band.len() % 2, 1, "band must have odd length");
    let (z0, z1, x0, x1, y0, y1) = out.bounds();
    d_axis_box(band, axis, g, out, [z0, z1, x0, x1, y0, y1]);
}

/// The wrapped per-point band loop over one `[z0,z1,x0,x1,y0,y1]`
/// sub-box of the claim — the single definition of the oracle tap
/// order, shared with `simd::d_axis_region`'s boundary arm.
pub(crate) fn d_axis_box<S: GridSrc>(
    band: &[f32],
    axis: usize,
    g: &S,
    out: &mut TileViewMut<'_>,
    b: [usize; 6],
) {
    let r = (band.len() / 2) as isize;
    for z in b[0]..b[1] {
        for x in b[2]..b[3] {
            for y in b[4]..b[5] {
                let mut acc = 0.0f32;
                for (k, &wk) in band.iter().enumerate() {
                    let d = k as isize - r;
                    let (zz, xx, yy) = match axis {
                        0 => (z as isize + d, x as isize, y as isize),
                        1 => (z as isize, x as isize + d, y as isize),
                        _ => (z as isize, x as isize, y as isize + d),
                    };
                    acc += wk * g.get_wrap(zz, xx, yy);
                }
                out.set(z, x, y, acc);
            }
        }
    }
}

fn star3<S: GridSrc>(spec: &StencilSpec, g: &S, out: &mut TileViewMut<'_>) {
    let r = spec.radius as isize;
    let (wz, wx, wy) = (&spec.star_axes[0], &spec.star_axes[1], &spec.star_axes[2]);
    let (gnz, gnx, gny) = g.shape();
    let (z0, z1, x0, x1, y0, y1) = out.bounds();
    let bounds = [z0, z1, x0, x1, y0, y1];
    let deep =
        shell::interior_box(gnz, gnx, gny, spec.radius).and_then(|ib| shell::intersect(bounds, ib));
    if let Some(b) = deep {
        // wrap-free interior: same accumulation order, direct reads —
        // bitwise equal to the wrapped path for in-bounds points
        for z in b[0]..b[1] {
            for x in b[2]..b[3] {
                for y in b[4]..b[5] {
                    let (zi, xi, yi) = (z as isize, x as isize, y as isize);
                    let mut acc = spec.star_center * g.get(z, x, y);
                    for k in -r..=r {
                        if k == 0 {
                            continue;
                        }
                        let i = (k + r) as usize;
                        acc += wz[i] * g.get((zi + k) as usize, x, y);
                        acc += wx[i] * g.get(z, (xi + k) as usize, y);
                        acc += wy[i] * g.get(z, x, (yi + k) as usize);
                    }
                    out.set(z, x, y, acc);
                }
            }
        }
    }
    for sb in shell::boundary_boxes(gnz, gnx, gny, spec.radius) {
        let Some(b) = shell::intersect(bounds, sb) else { continue };
        for z in b[0]..b[1] {
            for x in b[2]..b[3] {
                for y in b[4]..b[5] {
                    let (zi, xi, yi) = (z as isize, x as isize, y as isize);
                    let mut acc = spec.star_center * g.get_wrap(zi, xi, yi);
                    for k in -r..=r {
                        if k == 0 {
                            continue;
                        }
                        let i = (k + r) as usize;
                        acc += wz[i] * g.get_wrap(zi + k, xi, yi);
                        acc += wx[i] * g.get_wrap(zi, xi + k, yi);
                        acc += wy[i] * g.get_wrap(zi, xi, yi + k);
                    }
                    out.set(z, x, y, acc);
                }
            }
        }
    }
}

fn box3<S: GridSrc>(spec: &StencilSpec, g: &S, out: &mut TileViewMut<'_>) {
    let r = spec.radius as isize;
    let n = (2 * spec.radius + 1) as isize;
    let (gnz, gnx, gny) = g.shape();
    let (z0, z1, x0, x1, y0, y1) = out.bounds();
    let bounds = [z0, z1, x0, x1, y0, y1];
    let deep =
        shell::interior_box(gnz, gnx, gny, spec.radius).and_then(|ib| shell::intersect(bounds, ib));
    if let Some(bx) = deep {
        for z in bx[0]..bx[1] {
            for x in bx[2]..bx[3] {
                for y in bx[4]..bx[5] {
                    let (zi, xi, yi) = (z as isize, x as isize, y as isize);
                    let mut acc = 0.0f32;
                    for c in 0..n {
                        for a in 0..n {
                            for b in 0..n {
                                let w = spec.box_w[((c * n + a) * n + b) as usize];
                                acc += w
                                    * g.get(
                                        (zi + c - r) as usize,
                                        (xi + a - r) as usize,
                                        (yi + b - r) as usize,
                                    );
                            }
                        }
                    }
                    out.set(z, x, y, acc);
                }
            }
        }
    }
    for sb in shell::boundary_boxes(gnz, gnx, gny, spec.radius) {
        let Some(bx) = shell::intersect(bounds, sb) else { continue };
        for z in bx[0]..bx[1] {
            for x in bx[2]..bx[3] {
                for y in bx[4]..bx[5] {
                    let (zi, xi, yi) = (z as isize, x as isize, y as isize);
                    let mut acc = 0.0f32;
                    for c in 0..n {
                        for a in 0..n {
                            for b in 0..n {
                                let w = spec.box_w[((c * n + a) * n + b) as usize];
                                acc += w * g.get_wrap(zi + c - r, xi + a - r, yi + b - r);
                            }
                        }
                    }
                    out.set(z, x, y, acc);
                }
            }
        }
    }
}

fn star2(spec: &StencilSpec, g: &Grid2) -> Grid2 {
    let r = spec.radius as isize;
    let (wx, wy) = (&spec.star_axes[0], &spec.star_axes[1]);
    let mut out = Grid2::zeros(g.nx, g.ny);
    for x in 0..g.nx as isize {
        for y in 0..g.ny as isize {
            let mut acc = spec.star_center * g.get_wrap(x, y);
            for k in -r..=r {
                if k == 0 {
                    continue;
                }
                let i = (k + r) as usize;
                acc += wx[i] * g.get_wrap(x + k, y);
                acc += wy[i] * g.get_wrap(x, y + k);
            }
            out.set(x as usize, y as usize, acc);
        }
    }
    out
}

fn box2(spec: &StencilSpec, g: &Grid2) -> Grid2 {
    let r = spec.radius as isize;
    let n = (2 * spec.radius + 1) as isize;
    let mut out = Grid2::zeros(g.nx, g.ny);
    for x in 0..g.nx as isize {
        for y in 0..g.ny as isize {
            let mut acc = 0.0f32;
            for a in 0..n {
                for b in 0..n {
                    let w = spec.box_w[(a * n + b) as usize];
                    acc += w * g.get_wrap(x + a - r, y + b - r);
                }
            }
            out.set(x as usize, y as usize, acc);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn star3_constant_field_annihilated() {
        // Laplacian weights sum to zero → constant input maps to ~0
        let spec = StencilSpec::star3d(4);
        let g = Grid3::from_fn(8, 8, 8, |_, _, _| 7.5);
        let out = apply3(&spec, &g);
        for &v in &out.data {
            assert!(v.abs() < 1e-4, "{v}");
        }
    }

    #[test]
    fn star3_impulse_spreads_cross_shape() {
        let spec = StencilSpec::star3d(2);
        let mut g = Grid3::zeros(9, 9, 9);
        g.set(4, 4, 4, 1.0);
        let out = apply3(&spec, &g);
        // out at (4,4,4±k) = wy[k+r]; off-axis neighbours see nothing
        assert!((out.get(4, 4, 6) - spec.star_axes[2][4]).abs() < 1e-7);
        assert_eq!(out.get(3, 3, 4), 0.0);
        assert!((out.get(4, 4, 4) - spec.star_center).abs() < 1e-6);
    }

    #[test]
    fn region_oracle_matches_full_sweep() {
        let spec = StencilSpec::star3d(1);
        let g = Grid3::random(6, 7, 8, 21);
        let want = apply3(&spec, &g);
        let mut out = Grid3::zeros(6, 7, 8);
        {
            let pg = ParGrid3::new(&mut out);
            let mut view = pg.view(2, 5, 1, 6, 0, 8);
            apply3_region(&spec, &g, &mut view);
        }
        for z in 2..5 {
            for x in 1..6 {
                for y in 0..8 {
                    assert_eq!(out.get(z, x, y), want.get(z, x, y));
                }
            }
        }
        assert_eq!(out.get(0, 0, 0), 0.0); // outside the region: untouched
    }

    #[test]
    fn interior_split_is_bitwise_the_wrap_path() {
        // the shell/interior split must not change a single bit vs the
        // all-points wrapped accumulation (same order, direct reads)
        let spec = StencilSpec::star3d(2);
        let g = Grid3::random(9, 10, 11, 31);
        let got = apply3(&spec, &g);
        let r = spec.radius as isize;
        let (wz, wx, wy) = (&spec.star_axes[0], &spec.star_axes[1], &spec.star_axes[2]);
        for z in 0..9isize {
            for x in 0..10isize {
                for y in 0..11isize {
                    let mut acc = spec.star_center * g.get_wrap(z, x, y);
                    for k in -r..=r {
                        if k == 0 {
                            continue;
                        }
                        let i = (k + r) as usize;
                        acc += wz[i] * g.get_wrap(z + k, x, y);
                        acc += wx[i] * g.get_wrap(z, x + k, y);
                        acc += wy[i] * g.get_wrap(z, x, y + k);
                    }
                    assert_eq!(got.get(z as usize, x as usize, y as usize), acc);
                }
            }
        }
    }

    #[test]
    fn box2_matches_manual_sum() {
        let spec = StencilSpec::box2d(1);
        let g = Grid2::random(6, 6, 9);
        let out = apply2(&spec, &g);
        // hand-compute one point
        let (x, y) = (3, 4);
        let mut want = 0.0f32;
        for a in 0..3 {
            for b in 0..3 {
                want += spec.box_w[a * 3 + b]
                    * g.get_wrap(x as isize + a as isize - 1, y as isize + b as isize - 1);
            }
        }
        assert!((out.get(x, y) - want).abs() < 1e-6);
    }

    #[test]
    fn periodic_wrap_consistency() {
        // shifting the input cyclically shifts the output
        let spec = StencilSpec::star2d(2);
        let g = Grid2::random(8, 8, 10);
        let mut gs = Grid2::zeros(8, 8);
        for x in 0..8 {
            for y in 0..8 {
                gs.set(x, y, g.get_wrap(x as isize + 1, y as isize));
            }
        }
        let a = apply2(&spec, &g);
        let b = apply2(&spec, &gs);
        for x in 0..8 {
            for y in 0..8 {
                assert!((b.get(x, y) - a.get_wrap(x as isize + 1, y as isize)).abs() < 1e-6);
            }
        }
    }
}
