//! The banded-matrix GEMM reformulation of the matrix-unit algorithm
//! (Stencil Matrixization / SPIDER strided swapping — PAPERS.md,
//! arxiv 2310.16298 + 2506.22035).
//!
//! Where [`matrix_unit`](super::matrix_unit) emulates the paper's
//! per-axis outer-product passes with an intermediate-buffer round-trip
//! between the x/y partial and the z pass, this engine expresses each
//! axis derivative as a **banded-matrix GEMM**: the (2r+1)-band
//! coefficient operand is built **once per region call** in a
//! scratch-arena checkout and stays resident in the matrix-unit tiles
//! for the whole sweep, each staged input panel row is loaded **once**
//! and reused across the whole band, and the accumulator tile stays
//! resident across all three axis GEMMs — no intermediate store/reload.
//!
//! The three structural differences from the matrix-unit engine:
//!
//! * **Band operand residency** — the star coefficients are packed into
//!   one `[y-band | x-band | z-band]` arena buffer per
//!   [`apply3_region`] call (centre tap folded into the y band), the
//!   GEMM's `B` operand; blocks never re-broadcast coefficients.
//! * **Strided swapping** — the x-axis pass stages its panel through an
//!   arena buffer once per z-layer (the SPIDER tile-transpose path), so
//!   each neighbour row enters the matrix unit a single time instead of
//!   once per band tap.
//! * **Accumulator residency** — the z-band GEMM accumulates straight
//!   into the claimed output rows; the matrix-unit engine's `tmp`
//!   store + reload disappears from both the data path and the
//!   instruction accounting ([`star3_counts`] vs
//!   `matrix_unit::star3_counts` — equal outer products, strictly fewer
//!   auxiliary loads/stores, which is what makes the autotuner pick
//!   this engine for the high-order star headline).
//!
//! Contracts inherited verbatim from the matrix-unit engine (and pinned
//! by the same suites via [`EngineKind::ALL`](super::EngineKind::ALL)):
//! every per-point accumulation order is fixed (y band ascending with
//! the centre folded at index r, then x taps ascending, then z taps
//! ascending) and block-independent, so results are **bitwise identical
//! for any tiling, thread count, or claim partition**; interior blocks
//! are zero-copy through [`DirectWin`]; only O(surface) boundary blocks
//! wrap-copy through the arena ([`PackedWin`]); the hot path performs
//! zero heap allocations per block after warm-up
//! (`rust/tests/alloc_free.rs`).

use super::matrix_unit::{fill_window_wrap, BlockDims, Counts, DirectWin, PackedWin, Win};
use super::{Pattern, StencilSpec};
use crate::coordinator::runtime::{self, Runtime};
use crate::coordinator::scratch;
use crate::grid::par::{GridSrc, ParGrid3, TileViewMut};
use crate::grid::Grid3;

#[inline]
fn div_up(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

/// The banded coefficient operand: the three (2r+1) star bands packed
/// `[y | x | z]` into one arena buffer, built once per region call.
/// The centre tap is folded into the y band (index r), so the y GEMM's
/// first tap initializes the accumulator tile and no separate centre
/// broadcast exists.
struct BandOperand<'a> {
    n: usize,
    b: &'a [f32],
}

impl BandOperand<'_> {
    #[inline(always)]
    fn y(&self) -> &[f32] {
        &self.b[..self.n]
    }

    #[inline(always)]
    fn x(&self) -> &[f32] {
        &self.b[self.n..2 * self.n]
    }

    #[inline(always)]
    fn z(&self) -> &[f32] {
        &self.b[2 * self.n..3 * self.n]
    }
}

/// Pack the star bands of `spec` into `out` (`3·(2r+1)` long, from the
/// arena).  `star_axes` order is `[z, x, y]`; the centre tap lands in
/// the y band.
fn build_star_operand(spec: &StencilSpec, out: &mut [f32]) {
    let n = 2 * spec.radius + 1;
    debug_assert_eq!(out.len(), 3 * n);
    for i in 0..n {
        out[i] = if i == spec.radius { spec.star_center } else { spec.star_axes[2][i] };
        out[n + i] = spec.star_axes[1][i];
        out[2 * n + i] = spec.star_axes[0][i];
    }
}

/// Register-tile width of the star GEMM core: accumulator chunks this
/// wide live in a local array (registers, after unrolling) across all
/// three band passes, so the output row round-trips memory once per
/// chunk instead of once per tap.
const RT: usize = 8;

/// Star block as three banded GEMMs sharing one resident accumulator
/// tile.  Per-point accumulation order (fixed, block-independent):
/// y taps ascending (centre folded at index r), x taps ascending
/// (skipping the zero centre), z taps ascending (skipping the zero
/// centre).
///
/// Register tiling (the wavefront tile core): the y extent is walked
/// in [`RT`]-wide chunks whose accumulator is a local `[f32; RT]` —
/// every tap of all three bands lands in registers, and the chunk is
/// stored to the output row once at the end.  Each element's tap
/// order is exactly the scalar remainder path's, so the tiled path is
/// bitwise identical for any `by`.
#[allow(clippy::too_many_arguments)]
fn star3_gemm_block<W: Win>(
    r: usize,
    bop: &BandOperand<'_>,
    w: &W,
    out: &mut TileViewMut<'_>,
    z0: usize,
    x0: usize,
    y0: usize,
    bz: usize,
    bx: usize,
    by: usize,
    panel: &mut [f32],
) {
    let (wy, wx, wz) = (bop.y(), bop.x(), bop.z());
    let hx = bx + 2 * r;
    debug_assert_eq!(panel.len(), hx * by);
    for z in 0..bz {
        // strided swapping: stage the x-axis panel for this layer once —
        // each neighbour row enters the matrix unit a single time and is
        // reused by every output row of the band
        for xi in 0..hx {
            let src = w.row(z + r, xi);
            panel[xi * by..(xi + 1) * by].copy_from_slice(&src[r..r + by]);
        }
        for x in 0..bx {
            let o = out.row_mut(z0 + z, x0 + x, y0, by);
            let c = w.row(z + r, x + r);
            let mut y = 0;
            while y + RT <= by {
                // y-band GEMM: the folded centre means tap 0
                // initializes the register accumulator
                let mut acc = [0.0f32; RT];
                for j in 0..RT {
                    acc[j] = wy[0] * c[y + j];
                }
                for (i, &wv) in wy.iter().enumerate().skip(1) {
                    for j in 0..RT {
                        acc[j] += wv * c[y + j + i];
                    }
                }
                // x-band GEMM over the staged (strided-swapped) panel
                for (i, &wv) in wx.iter().enumerate() {
                    if i == r {
                        continue;
                    }
                    let row = &panel[(x + i) * by..(x + i + 1) * by];
                    for j in 0..RT {
                        acc[j] += wv * row[y + j];
                    }
                }
                // z-band GEMM: the accumulator stays resident — no
                // intermediate-buffer round-trip
                for (i, &wv) in wz.iter().enumerate() {
                    if i == r {
                        continue;
                    }
                    let s = w.row(z + i, x + r);
                    for j in 0..RT {
                        acc[j] += wv * s[y + j + r];
                    }
                }
                o[y..y + RT].copy_from_slice(&acc);
                y += RT;
            }
            if y < by {
                // scalar remainder: the original untiled band passes
                for yy in y..by {
                    o[yy] = wy[0] * c[yy];
                }
                for (i, &wv) in wy.iter().enumerate().skip(1) {
                    for yy in y..by {
                        o[yy] += wv * c[yy + i];
                    }
                }
                for (i, &wv) in wx.iter().enumerate() {
                    if i == r {
                        continue;
                    }
                    let row = &panel[(x + i) * by..(x + i + 1) * by];
                    for yy in y..by {
                        o[yy] += wv * row[yy];
                    }
                }
                for (i, &wv) in wz.iter().enumerate() {
                    if i == r {
                        continue;
                    }
                    let s = w.row(z + i, x + r);
                    for yy in y..by {
                        o[yy] += wv * s[yy + r];
                    }
                }
            }
        }
    }
}

/// Box block as (2r+1)² banded y-GEMMs over the shared halo window:
/// the window is loaded once and every band pass reuses it from the
/// matrix-unit tiles.  The `box_w` rows *are* the banded operand —
/// already packed per (c, a) sub-stencil, so no arena copy is needed.
/// Traversal order matches the matrix-unit engine (c, a, b ascending),
/// keeping the per-point accumulation order fixed and block-independent.
#[allow(clippy::too_many_arguments)]
fn box3_gemm_block<W: Win>(
    spec: &StencilSpec,
    w: &W,
    out: &mut TileViewMut<'_>,
    z0: usize,
    x0: usize,
    y0: usize,
    bz: usize,
    bx: usize,
    by: usize,
) {
    let r = spec.radius;
    let n = 2 * r + 1;
    for z in 0..bz {
        for x in 0..bx {
            let o = out.row_mut(z0 + z, x0 + x, y0, by);
            o.fill(0.0);
            for c in 0..n {
                for a in 0..n {
                    let srow = w.row(z + c, x + a);
                    let band = &spec.box_w[(c * n + a) * n..][..n];
                    for (b, &wv) in band.iter().enumerate() {
                        for y in 0..by {
                            o[y] += wv * srow[y + b];
                        }
                    }
                }
            }
        }
    }
}

/// Run one block's kernels: the star path checks its strided-swap panel
/// out of the arena (nested under the window checkout on boundary
/// blocks — nested checkouts pop distinct buffers).
#[allow(clippy::too_many_arguments)]
fn run_block<W: Win>(
    spec: &StencilSpec,
    bop: Option<&BandOperand<'_>>,
    win: &W,
    view: &mut TileViewMut<'_>,
    z0: usize,
    x0: usize,
    y0: usize,
    bz: usize,
    bx: usize,
    by: usize,
) {
    match spec.pattern {
        Pattern::Star => {
            let r = spec.radius;
            let bop = bop.expect("star sweep built a band operand");
            scratch::with((bx + 2 * r) * by, |panel| {
                star3_gemm_block(r, bop, win, view, z0, x0, y0, bz, bx, by, panel)
            })
        }
        Pattern::Box => box3_gemm_block(spec, win, view, z0, x0, y0, bz, bx, by),
    }
}

/// Dispatch one block through the zero-copy / wrap-copy window split —
/// identical interior test and staging discipline to the matrix-unit
/// engine (`matrix_unit::compute_block`).
#[allow(clippy::too_many_arguments)]
fn compute_block<S: GridSrc>(
    spec: &StencilSpec,
    bop: Option<&BandOperand<'_>>,
    g: &S,
    view: &mut TileViewMut<'_>,
    z0: usize,
    x0: usize,
    y0: usize,
    bz: usize,
    bx: usize,
    by: usize,
) {
    let r = spec.radius;
    let (gnz, gnx, gny) = g.shape();
    let (hz, hx, hy) = (bz + 2 * r, bx + 2 * r, by + 2 * r);
    let interior = z0 >= r
        && z0 + bz + r <= gnz
        && x0 >= r
        && x0 + bx + r <= gnx
        && y0 >= r
        && y0 + by + r <= gny;
    if interior {
        let win = DirectWin { g, nx: gnx, ny: gny, z0: z0 - r, x0: x0 - r, y0: y0 - r, hy };
        run_block(spec, bop, &win, view, z0, x0, y0, bz, bx, by);
    } else {
        scratch::with(hz * hx * hy, |w| {
            fill_window_wrap(
                g,
                z0 as isize - r as isize,
                x0 as isize - r as isize,
                y0 as isize - r as isize,
                hz,
                hx,
                hy,
                w,
            );
            let win = PackedWin { w, hx, hy };
            run_block(spec, bop, &win, view, z0, x0, y0, bz, bx, by);
        });
    }
}

/// Compute the claimed region of `out` blockwise through the banded-GEMM
/// kernels, returning the accumulated instruction counts.  The band
/// coefficient operand is built once per call in a scratch checkout and
/// shared by every block.  Per-point accumulation order is
/// block-independent, so the result bytes equal the whole-grid sweep's
/// on that box regardless of the claim partition — the same contract as
/// `matrix_unit::apply3_region`.
pub fn apply3_region<S: GridSrc>(
    spec: &StencilSpec,
    g: &S,
    out: &mut TileViewMut<'_>,
    dims: BlockDims,
) -> Counts {
    assert_eq!(spec.ndim, 3, "gemm::apply3_region needs a 3D spec");
    debug_assert_eq!(g.shape(), out.grid_shape());
    let (vl, vz) = (dims.vl.max(1), dims.vz.max(1));
    let nb = 2 * spec.radius + 1;
    let (z0, z1, x0, x1, y0, y1) = out.bounds();
    // the banded coefficient operand: one arena checkout per region
    // call, resident for the whole sweep
    scratch::with(3 * nb, |bb| {
        let bop = match spec.pattern {
            Pattern::Star => {
                build_star_operand(spec, bb);
                Some(BandOperand { n: nb, b: &*bb })
            }
            // box_w is already the packed per-(c, a) banded operand
            Pattern::Box => None,
        };
        let mut counts = Counts::default();
        let mut zb = z0;
        while zb < z1 {
            let bz = vz.min(z1 - zb);
            let mut xb = x0;
            while xb < x1 {
                let bx = vl.min(x1 - xb);
                let mut yb = y0;
                while yb < y1 {
                    let by = vl.min(y1 - yb);
                    counts.add(&match spec.pattern {
                        Pattern::Star => star3_counts(spec, bz, bx, by, vl),
                        Pattern::Box => box3_counts(spec, bz, bx, by, vl),
                    });
                    compute_block(spec, bop.as_ref(), g, out, zb, xb, yb, bz, bx, by);
                    yb += by;
                }
                xb += bx;
            }
            zb += bz;
        }
        counts
    })
}

/// One full periodic banded-GEMM sweep (serial).  Returns the result
/// and the accumulated instruction counts.
pub fn apply3<S: GridSrc>(spec: &StencilSpec, g: &S, dims: BlockDims) -> (Grid3, Counts) {
    assert_eq!(spec.ndim, 3);
    let (gnz, gnx, gny) = g.shape();
    let mut out = Grid3::zeros(gnz, gnx, gny);
    let counts;
    {
        let pg = ParGrid3::new(&mut out);
        let mut view = pg.full_view();
        counts = apply3_region(spec, g, &mut view, dims);
    }
    (out, counts)
}

/// Parallel banded-GEMM sweep on `rt`: the z-block loop fans out over
/// the persistent runtime, each task claiming a disjoint z-slab and
/// running the same per-block kernels as the serial [`apply3`].
/// Per-task [`Counts`] merge by reduction — the total is exactly the
/// serial sweep's, and the grid is bitwise identical.
pub fn apply3_on<S: GridSrc>(
    rt: &Runtime,
    spec: &StencilSpec,
    g: &S,
    dims: BlockDims,
    threads: usize,
) -> (Grid3, Counts) {
    assert_eq!(spec.ndim, 3);
    let (gnz, gnx, gny) = g.shape();
    let vz = dims.vz.max(1);
    let nslabs = gnz.div_ceil(vz);
    let mut out = Grid3::zeros(gnz, gnx, gny);
    let total = std::sync::Mutex::new(Counts::default());
    {
        let pg = ParGrid3::new(&mut out);
        let pg = &pg;
        let total = &total;
        rt.run(threads.max(1), nslabs, &|i| {
            let z0 = i * vz;
            let z1 = (z0 + vz).min(gnz);
            let mut view = pg.view(z0, z1, 0, gnx, 0, gny);
            let c = apply3_region(spec, g, &mut view, dims);
            total.lock().unwrap().add(&c);
        });
    }
    let counts = total.into_inner().unwrap();
    (out, counts)
}

/// [`apply3_on`] over the process-global runtime.
pub fn apply3_par<S: GridSrc>(
    spec: &StencilSpec,
    g: &S,
    dims: BlockDims,
    threads: usize,
) -> (Grid3, Counts) {
    apply3_on(runtime::global(), spec, g, dims, threads)
}

/// 1-D banded-GEMM pass along `axis` (0 = z, 1 = x, 2 = y) over the
/// claimed region — the gemm engine's axis-derivative kernel behind
/// `Engine::{d1,d2}_axis_into`.  The band itself is the GEMM's banded
/// operand; the x-axis pass stages its panel through the arena
/// (strided swapping) so each neighbour row is loaded once per layer.
/// Taps accumulate in ascending band order (fixed, block-independent).
pub fn d_axis_region<S: GridSrc>(
    band: &[f32],
    axis: usize,
    g: &S,
    out: &mut TileViewMut<'_>,
    dims: BlockDims,
) -> Counts {
    assert!(axis < 3, "axis must be 0 (z), 1 (x), or 2 (y)");
    assert_eq!(band.len() % 2, 1, "band must have odd length");
    debug_assert_eq!(g.shape(), out.grid_shape());
    let r = band.len() / 2;
    let (vl, vz) = (dims.vl.max(1), dims.vz.max(1));
    let (z0, z1, x0, x1, y0, y1) = out.bounds();
    let mut counts = Counts::default();
    let mut zb = z0;
    while zb < z1 {
        let bz = vz.min(z1 - zb);
        let mut xb = x0;
        while xb < x1 {
            let bx = vl.min(x1 - xb);
            let mut yb = y0;
            while yb < y1 {
                let by = vl.min(y1 - yb);
                counts.add(&axis_counts(r, axis, bz, bx, by, vl));
                compute_axis_block(band, axis, g, out, zb, xb, yb, bz, bx, by);
                yb += by;
            }
            xb += bx;
        }
        zb += bz;
    }
    counts
}

/// Dispatch one axis-pass block through the zero-copy / wrap-copy
/// window split (halo along `axis` only).
#[allow(clippy::too_many_arguments)]
fn compute_axis_block<S: GridSrc>(
    band: &[f32],
    axis: usize,
    g: &S,
    view: &mut TileViewMut<'_>,
    z0: usize,
    x0: usize,
    y0: usize,
    bz: usize,
    bx: usize,
    by: usize,
) {
    let r = band.len() / 2;
    let (gnz, gnx, gny) = g.shape();
    let hz = bz + if axis == 0 { 2 * r } else { 0 };
    let hx = bx + if axis == 1 { 2 * r } else { 0 };
    let hy = by + if axis == 2 { 2 * r } else { 0 };
    let oz = z0 as isize - if axis == 0 { r as isize } else { 0 };
    let ox = x0 as isize - if axis == 1 { r as isize } else { 0 };
    let oy = y0 as isize - if axis == 2 { r as isize } else { 0 };
    let interior = oz >= 0
        && oz as usize + hz <= gnz
        && ox >= 0
        && ox as usize + hx <= gnx
        && oy >= 0
        && oy as usize + hy <= gny;
    if interior {
        let win = DirectWin {
            g,
            nx: gnx,
            ny: gny,
            z0: oz as usize,
            x0: ox as usize,
            y0: oy as usize,
            hy,
        };
        axis_gemm_block(band, axis, &win, view, z0, x0, y0, bz, bx, by);
    } else {
        scratch::with(hz * hx * hy, |buf| {
            fill_window_wrap(g, oz, ox, oy, hz, hx, hy, buf);
            let win = PackedWin { w: buf, hx, hy };
            axis_gemm_block(band, axis, &win, view, z0, x0, y0, bz, bx, by);
        });
    }
}

/// One axis-pass block as a banded GEMM: taps accumulate in ascending
/// band order; the x-axis pass stages a strided-swapped panel per
/// z-layer so each window row is loaded once and reused across the
/// whole band.
#[allow(clippy::too_many_arguments)]
fn axis_gemm_block<W: Win>(
    band: &[f32],
    axis: usize,
    win: &W,
    out: &mut TileViewMut<'_>,
    z0: usize,
    x0: usize,
    y0: usize,
    bz: usize,
    bx: usize,
    by: usize,
) {
    let r = band.len() / 2;
    if axis == 1 {
        // strided swapping: stage the (bx + 2r) panel rows of each
        // z-layer once; every output row of the band reuses them
        let hx = bx + 2 * r;
        scratch::with(hx * by, |panel| {
            for z in 0..bz {
                for xi in 0..hx {
                    panel[xi * by..(xi + 1) * by].copy_from_slice(&win.row(z, xi)[..by]);
                }
                for x in 0..bx {
                    let o = out.row_mut(z0 + z, x0 + x, y0, by);
                    for y in 0..by {
                        o[y] = band[0] * panel[x * by + y];
                    }
                    for (k, &wk) in band.iter().enumerate().skip(1) {
                        let row = &panel[(x + k) * by..(x + k + 1) * by];
                        for y in 0..by {
                            o[y] += wk * row[y];
                        }
                    }
                }
            }
        });
        return;
    }
    for z in 0..bz {
        for x in 0..bx {
            let o = out.row_mut(z0 + z, x0 + x, y0, by);
            if axis == 2 {
                let c = win.row(z, x);
                for y in 0..by {
                    o[y] = band[0] * c[y];
                }
                for (k, &wk) in band.iter().enumerate().skip(1) {
                    for y in 0..by {
                        o[y] += wk * c[y + k];
                    }
                }
            } else {
                {
                    let s = win.row(z, x);
                    for y in 0..by {
                        o[y] = band[0] * s[y];
                    }
                }
                for (k, &wk) in band.iter().enumerate().skip(1) {
                    let s = win.row(z + k, x);
                    for y in 0..by {
                        o[y] += wk * s[y];
                    }
                }
            }
        }
    }
}

/// Instruction counts of one 1-D banded-GEMM axis pass on one block:
/// the band is held in the resident operand, so the pass consumes each
/// window vector exactly once; the x-axis pass pays (and saves) the
/// strided-swap transpose traffic.
fn axis_counts(r: usize, axis: usize, bz: usize, bx: usize, by: usize, vl: usize) -> Counts {
    let hz = bz + if axis == 0 { 2 * r } else { 0 };
    let hx = bx + if axis == 1 { 2 * r } else { 0 };
    let hy = by + if axis == 2 { 2 * r } else { 0 };
    let mut c = Counts::default();
    c.vec_loads += (hz * hx * div_up(hy, vl)) as u64;
    c.outer_products += div_up(hz * hx * hy, vl) as u64;
    if axis == 1 {
        c.tile_slices += (2 * vl * bz) as u64;
        c.simd_permutes_avoided += (vl * vl.ilog2() as usize * bz) as u64;
        c.gathers_avoided += (bz * hx) as u64;
    }
    c.vec_stores += div_up(bz * bx * by, vl) as u64;
    c
}

/// Star-sweep instruction counts of one block under the banded-GEMM
/// reformulation.  Band reuse accounting vs the matrix-unit engine:
/// outer products are **equal** (each axis GEMM consumes the same panel
/// vectors), but each axis pass loads only its own panel — not the full
/// halo cube — and the resident accumulator removes the intermediate
/// store + reload, so auxiliary traffic is strictly lower.  At the
/// (4, 16, 16) r=4 headline block: 416 loads + 64 stores vs the
/// matrix-unit engine's 640 + 128.
fn star3_counts(spec: &StencilSpec, bz: usize, bx: usize, by: usize, vl: usize) -> Counts {
    let r = spec.radius;
    let (hz, hx, hy) = (bz + 2 * r, bx + 2 * r, by + 2 * r);
    let mut c = Counts::default();
    // per-axis panel loads: each neighbour row enters the matrix unit
    // exactly once (band reuse from the resident operand)
    c.vec_loads += (bz * bx * div_up(hy, vl)) as u64; // y panel
    c.vec_loads += (bz * hx * div_up(by, vl)) as u64; // x panel (staged)
    c.vec_loads += (hz * bx * div_up(by, vl)) as u64; // z panel
    // one banded GEMM per axis, consuming the same vectors as the
    // matrix-unit engine's outer-product passes
    c.outer_products += div_up(bz * bx * hy, vl) as u64;
    c.outer_products += div_up(bz * hx * by, vl) as u64;
    c.outer_products += div_up(hz * bx * by, vl) as u64;
    // strided swapping of the x panel (Tile-Assisted Vector Transpose)
    c.tile_slices += (2 * vl * bz) as u64;
    c.simd_permutes_avoided += (vl * vl.ilog2() as usize * bz) as u64;
    c.gathers_avoided += (bz * hx) as u64;
    // single resident-accumulator store — no intermediate round-trip
    c.vec_stores += div_up(bz * bx * by, vl) as u64;
    c
}

/// Box-sweep instruction counts of one block: the shared window is
/// loaded once and every (2r+1)² banded y-GEMM reuses it — identical to
/// the matrix-unit engine's Redundant-Access Zeroing accounting (the
/// gemm win is star-specific: box has no intermediate round-trip to
/// remove).
fn box3_counts(spec: &StencilSpec, bz: usize, bx: usize, by: usize, vl: usize) -> Counts {
    let r = spec.radius;
    let n = (2 * r + 1) as u64;
    let (hz, hx, hy) = (bz + 2 * r, bx + 2 * r, by + 2 * r);
    let mut c = Counts::default();
    c.vec_loads += (hz * hx * div_up(hy, vl)) as u64;
    c.outer_products += n * n * div_up(bz * bx * hy, vl) as u64;
    c.vec_stores += div_up(bz * bx * by, vl) as u64;
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::{matrix_unit, naive};
    use crate::util::prop::{assert_allclose, forall};

    #[test]
    fn matches_naive_star_and_box_across_radii() {
        // oracle equivalence, pointwise + energy, star/box × r ∈ {1,2,4}
        for r in [1usize, 2, 4] {
            for spec in [StencilSpec::star3d(r), StencilSpec::box3d(r.min(2))] {
                let g = Grid3::random(9, 21, 23, 7 + r as u64);
                let want = naive::apply3(&spec, &g);
                let (got, counts) = apply3(&spec, &g, BlockDims::default());
                assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
                assert!(counts.outer_products > 0);
                let (e, eo) = (got.energy(), want.energy());
                assert!((e / eo - 1.0).abs() < 1e-4, "r={r}: energy {e} vs oracle {eo}");
            }
        }
    }

    #[test]
    fn ragged_grids_agree() {
        forall(10, 0x5C1, |rng| {
            let spec = StencilSpec::star3d(rng.range(1, 4));
            let (nz, nx, ny) = (rng.range(3, 9), rng.range(5, 21), rng.range(5, 21));
            let g = Grid3::random(nz, nx, ny, rng.next_u64());
            let want = naive::apply3(&spec, &g);
            let (got, _) = apply3(&spec, &g, BlockDims::default());
            assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
        });
    }

    #[test]
    fn interior_blocks_agree_with_boundary_blocks() {
        // grids large enough that the default blocks include fully
        // interior (zero-copy) ones
        for spec in [StencilSpec::star3d(2), StencilSpec::box3d(1)] {
            let g = Grid3::random(12, 40, 40, 29);
            let want = naive::apply3(&spec, &g);
            let (got, _) = apply3(&spec, &g, BlockDims::default());
            assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
        }
    }

    #[test]
    fn parallel_sweep_is_bitwise_serial_with_exact_counts() {
        let dims = BlockDims::default();
        for spec in [StencilSpec::star3d(3), StencilSpec::box3d(2)] {
            let g = Grid3::random(13, 40, 37, 31);
            let (want, cw) = apply3(&spec, &g, dims);
            for workers in [1, 2, 4] {
                let rt = Runtime::with_workers(workers);
                let (got, cg) = apply3_on(&rt, &spec, &g, dims, workers);
                assert_eq!(got.data, want.data, "workers={workers}");
                assert_eq!(cg, cw, "workers={workers}");
            }
        }
    }

    #[test]
    fn axis_pass_matches_direct_loop_and_is_bitwise_across_tilings() {
        let g = Grid3::random(7, 9, 11, 41);
        let w2 = crate::stencil::coeffs::second_deriv(3);
        let r = 3isize;
        for axis in 0..3 {
            let want = Grid3::from_fn(7, 9, 11, |z, x, y| {
                let mut acc = 0.0;
                for k in -r..=r {
                    let (mut zz, mut xx, mut yy) = (z as isize, x as isize, y as isize);
                    match axis {
                        0 => zz += k,
                        1 => xx += k,
                        _ => yy += k,
                    }
                    acc += w2[(k + r) as usize] * g.get_wrap(zz, xx, yy);
                }
                acc
            });
            let run = |dims: BlockDims| {
                let mut out = Grid3::zeros(7, 9, 11);
                {
                    let pg = ParGrid3::new(&mut out);
                    let mut view = pg.full_view();
                    d_axis_region(&w2, axis, &g, &mut view, dims);
                }
                out
            };
            let got = run(BlockDims::default());
            assert_allclose(&got.data, &want.data, 1e-4, 1e-6);
            // different tiling, same bits: the per-point order is fixed
            let other = run(BlockDims { vl: 5, vz: 2 });
            assert_eq!(got.data, other.data, "axis={axis}");
        }
    }

    #[test]
    fn band_reuse_beats_matrix_unit_on_the_headline_block() {
        // the §13 accounting claim: equal outer products, strictly less
        // auxiliary traffic on one full (4, 16, 16) star-r4 block
        let spec = StencilSpec::star3d(4);
        let dims = BlockDims::default();
        let g = Grid3::random(4, 16, 16, 3);
        let (_, cg) = apply3(&spec, &g, dims);
        let (_, cm) = matrix_unit::apply3(&spec, &g, dims);
        assert_eq!(cg.outer_products, cm.outer_products, "axis GEMMs consume the same vectors");
        let aux_g = cg.vec_loads + cg.vec_stores + cg.tile_slices;
        let aux_m = cm.vec_loads + cm.vec_stores + cm.tile_slices;
        assert!(aux_g < aux_m, "gemm aux {aux_g} must beat matrix_unit aux {aux_m}");
    }

    #[test]
    fn steady_state_sweeps_do_not_grow_the_arena() {
        let dims = BlockDims::default();
        let g = Grid3::random(8, 40, 40, 53);
        for spec in [StencilSpec::star3d(4), StencilSpec::box3d(2)] {
            apply3(&spec, &g, dims); // warm-up
            let before = scratch::local_grow_events();
            apply3(&spec, &g, dims);
            apply3(&spec, &g, dims);
            assert_eq!(scratch::local_grow_events(), before, "arena grew after warm-up");
        }
    }
}
