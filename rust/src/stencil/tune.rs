//! The startup autotuner and its [`TunePlan`] — the plan-based
//! configuration surface of the engine stack.
//!
//! Before this layer, every call site chained raw knobs
//! (`Engine::new(kind).with_threads(t).with_dims(d)` plus a separate
//! `time_block` argument threaded through the drivers).  A [`TunePlan`]
//! carries all the choices — engine kind, block geometry, fused-sweep
//! depth, worker fan-out, wavefront tile geometry — as **one value**
//! with a `Display`/[`parse`]
//! round-trip (the same contract as
//! [`StencilSpec::parse`](super::StencilSpec::parse)), so configs, the
//! CLI, the runtime manifest, and the RTM services all speak the same
//! string:
//!
//! ```text
//! engine=matrix_gemm vl=16 vz=4 tb=1 threads=4 tile=16 wf=2 halo=f32
//! ```
//!
//! The `tile=`/`wf=` keys (PR 8) select the in-rank (z, t) wavefront
//! geometry of the fused sub-steps (`coordinator::wavefront`); they are
//! **optional on parse** — plans serialized before they existed still
//! parse, defaulting to the classic flat path (`tile=0 wf=1`) — and
//! always present in the `Display` form.  The `halo=` key (PR 9)
//! selects the halo wire codec
//! ([`HaloCodec`](crate::grid::halo::HaloCodec)) of the multirank
//! exchanges; it is likewise optional on parse (defaulting to the
//! bitwise `f32` transport) and always serialized.
//!
//! [`tune`] is the startup search: it scores every candidate
//! (engine, BlockDims, time_block, threads) combination for one
//! (pattern, radius, n) shape against the `simulator::roofline` cost
//! model — matrix-family candidates are scored from their **own
//! measured instruction mix** (one-block emulation at the candidate
//! geometry, [`roofline::predict_with_counts`]) — and returns the plan
//! with the lowest modelled wall time.  The search is fully
//! deterministic (fixed candidate order, integer-derived scores, no
//! clocks): the same shape always yields the same plan, which is what
//! lets the runtime manifest cache plans by shape key
//! (`runtime::manifest::PlanCache`) and replay them bitwise-stably.
//!
//! Ties break toward the **later** candidate only when it is strictly
//! better on modelled compute time: the banded-GEMM engine spends the
//! same outer products as the matrix-unit engine but strictly less
//! auxiliary traffic, so on memory-bound shapes — where both tie on
//! wall time — the plan still selects `matrix_gemm`, the engine with
//! headroom.

use super::engine::EngineKind;
use super::matrix_unit::{self, BlockDims, Counts};
use super::{gemm, Pattern, StencilSpec};
use crate::grid::halo::HaloCodec;
use crate::grid::Grid3;
use crate::simulator::roofline::{self, MemKind};
use crate::simulator::soc::Platform;
use crate::util::err::Result;
use crate::{anyhow, bail};

/// One tuned configuration: everything a caller needs to run a sweep —
/// engine kind, block geometry, fused-sweep depth, worker fan-out —
/// as a single copyable, parseable value.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TunePlan {
    /// Engine the kernels dispatch to.
    pub engine: EngineKind,
    /// Matrix-unit block geometry / z-slab granularity.
    pub dims: BlockDims,
    /// Fused-sweep depth (temporal blocking): how many sweeps/steps the
    /// caller fuses per halo exchange.  Consumed by the drivers, not by
    /// `Engine` itself.
    pub time_block: usize,
    /// Worker fan-out for the parallel entry points.
    pub threads: usize,
    /// Wavefront z-tile extent for in-rank (z, t) tiling of the fused
    /// sub-steps (`coordinator::wavefront`); 0 = classic
    /// level-at-a-time stepping.  Optional in the string form
    /// (defaults to 0), so v7-era plans still parse.
    pub tile: usize,
    /// Wavefront band depth: sub-step levels advanced per dispatch
    /// barrier when `tile > 0`.  Optional in the string form (defaults
    /// to 1).
    pub wf: usize,
    /// Halo wire codec of the multirank exchanges (`f32` | `bf16` |
    /// `f16`).  Consumed by the drivers, not by `Engine` itself.
    /// Optional in the string form (defaults to the bitwise `f32`
    /// transport), so plans serialized before PR 9 still parse.
    pub halo: HaloCodec,
}

impl TunePlan {
    /// The untuned fallback for a shape: the crate's historical default
    /// (serial simd engine, paper-default block geometry, no fusion).
    /// Shape-independent today; the signature carries the shape so
    /// callers don't change when the fallback learns to look at it.
    pub fn default_for(_spec: &StencilSpec, _n: usize) -> Self {
        Self::simd(1)
    }

    /// The simd engine with a parallelism hint and default geometry —
    /// the plan the old `Engine::default_simd(threads)` shim maps to,
    /// and what the `threads`-keyed compatibility entry points use.
    pub fn simd(threads: usize) -> Self {
        Self {
            engine: EngineKind::Simd,
            dims: BlockDims::default(),
            time_block: 1,
            threads,
            tile: 0,
            wf: 1,
            halo: HaloCodec::F32,
        }
    }

    /// Parse the `Display` form back into a plan.  The five original
    /// `key=value` fields are required, in any order, exactly once:
    /// `engine=<kind> vl=<n> vz=<n> tb=<n> threads=<n>`.  The wavefront
    /// keys `tile=<n> wf=<n>` are **optional** (defaulting to `0` and
    /// `1`) so plans serialized before PR 8 — including cached
    /// `runtime::PlanCache` manifests — still parse, and the halo-codec
    /// key `halo=<codec>` is likewise optional (defaulting to the
    /// bitwise `f32` transport) for pre-PR-9 plans.
    pub fn parse(s: &str) -> Result<Self> {
        let (mut engine, mut vl, mut vz, mut tb, mut threads) = (None, None, None, None, None);
        let (mut tile, mut wf) = (None, None);
        let mut halo: Option<HaloCodec> = None;
        for tok in s.split_whitespace() {
            let (key, val) = tok
                .split_once('=')
                .ok_or_else(|| anyhow!("tune plan: token {tok:?} is not key=value"))?;
            let num = || -> Result<usize> {
                val.parse::<usize>()
                    .map_err(|_| anyhow!("tune plan: {key}={val:?} is not a number"))
            };
            let slot: &mut Option<usize> = match key {
                "engine" => {
                    let kind = EngineKind::parse(val).map_err(|e| anyhow!("tune plan: {e}"))?;
                    if engine.replace(kind).is_some() {
                        bail!("tune plan: duplicate key {key:?}");
                    }
                    continue;
                }
                "halo" => {
                    let codec = HaloCodec::parse(val).map_err(|e| anyhow!("tune plan: {e}"))?;
                    if halo.replace(codec).is_some() {
                        bail!("tune plan: duplicate key {key:?}");
                    }
                    continue;
                }
                "vl" => &mut vl,
                "vz" => &mut vz,
                "tb" => &mut tb,
                "threads" => &mut threads,
                "tile" => &mut tile,
                "wf" => &mut wf,
                _ => bail!(
                    "tune plan: unknown key {key:?} \
                     (engine | vl | vz | tb | threads | tile | wf | halo)"
                ),
            };
            if slot.replace(num()?).is_some() {
                bail!("tune plan: duplicate key {key:?}");
            }
        }
        let need = |v: Option<usize>, key: &str| {
            v.ok_or_else(|| anyhow!("tune plan: missing key {key:?}"))
        };
        Ok(Self {
            engine: engine.ok_or_else(|| anyhow!("tune plan: missing key \"engine\""))?,
            dims: BlockDims { vl: need(vl, "vl")?, vz: need(vz, "vz")? },
            time_block: need(tb, "tb")?,
            threads: need(threads, "threads")?,
            tile: tile.unwrap_or(0),
            wf: wf.unwrap_or(1).max(1),
            halo: halo.unwrap_or(HaloCodec::F32),
        })
    }
}

impl std::fmt::Display for TunePlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "engine={} vl={} vz={} tb={} threads={} tile={} wf={} halo={}",
            self.engine.name(),
            self.dims.vl,
            self.dims.vz,
            self.time_block,
            self.threads,
            self.tile,
            self.wf,
            self.halo.name()
        )
    }
}

/// Manifest cache key of one tuned shape: pattern, radius, and cubic
/// grid extent — everything the deterministic search depends on besides
/// the platform.  E.g. `3DStarR4@n256`.
pub fn shape_key(spec: &StencilSpec, n: usize) -> String {
    let pat = match spec.pattern {
        Pattern::Star => "Star",
        Pattern::Box => "Box",
    };
    format!("{}D{}R{}@n{}", spec.ndim, pat, spec.radius, n)
}

/// Candidate block geometries the search sweeps for the matrix-family
/// engines (the scalar engines only use `vz` as slab granularity, so
/// they are scored at the paper default).
const CAND_VL: [usize; 3] = [8, 16, 32];
const CAND_VZ: [usize; 3] = [2, 4, 8];
/// Candidate fused-sweep depths.
const CAND_TB: [usize; 3] = [1, 2, 4];
/// Candidate in-rank wavefront geometries `(tile, wf)` —
/// `(0, 1)` is the classic flat path; the rest are z-tile extents ×
/// band depths scored for cache residency by the roofline model.
const CAND_WAVE: [(usize, usize); 7] =
    [(0, 1), (8, 1), (8, 2), (16, 1), (16, 2), (32, 1), (32, 2)];

/// Modelled cost of spawning one worker task on the persistent runtime.
const SPAWN_S: f64 = 2e-6;
/// Parallel-efficiency erosion per extra active core (synchronization +
/// shared-cache pressure on one NUMA node).
const CORE_PENALTY: f64 = 0.03;

/// Parallel speedup of `t` workers on `cores` physical cores.
fn fanout_eff(t: usize, cores: usize) -> f64 {
    let active = t.min(cores).max(1) as f64;
    active / (1.0 + CORE_PENALTY * (active - 1.0))
}

/// Modelled wall time of one sweep-step under `plan`, given the
/// roofline single-node estimate `(time_s, compute_s)` of the sweep:
/// serialize the node estimate to one core, re-apply the plan's
/// fan-out, amortize the halo-exchange cost over the fused depth, and
/// charge the deep-halo growth the extra fused steps compute.
fn step_time(sweep: (f64, f64), plan: &TunePlan, spec: &StencilSpec, n: usize, p: &Platform) -> f64 {
    let cores = p.cores_per_numa.max(1);
    let t1 = sweep.0 * cores as f64; // single-core serialization
    let fan = t1 / fanout_eff(plan.threads, cores) + plan.threads as f64 * SPAWN_S;
    // halo exchange: the six faces of the cube, r deep, amortized over
    // the fused depth (deep-halo temporal blocking)
    let exch_s = (6 * n * n * spec.radius * 4) as f64 / p.onpkg_bw_per_numa;
    let k = plan.time_block.max(1) as f64;
    // each extra fused step recomputes an r-deep halo shell
    let growth = (k - 1.0) * (spec.radius as f64 / n.max(1) as f64) * fan;
    let mut t = fan + exch_s / k + growth;
    if plan.tile > 0 && plan.time_block > 1 {
        // In-rank (z, t) wavefront tiling: when the tile working set is
        // cache-resident, the k-1 fused sub-steps past the first stream
        // their operands from aggregate L2 instead of re-walking DRAM.
        // The discount is a constant factor on `fan`, so the
        // cross-engine ordering at any fixed geometry is unchanged.
        if roofline::wavefront_residency(p, spec, n, plan.tile, plan.wf)
            == roofline::Residency::Cache
        {
            t -= (k - 1.0) / k * fan * (1.0 - 1.0 / roofline::CACHE_BW_RATIO);
        }
        // Ledger dispatch cost: one task per tile per band, so tiny
        // tiles (and shallow bands) pay for their scheduling.
        let bands = ((k - 1.0) / plan.wf.max(1) as f64).ceil();
        t += bands * (n as f64 / plan.tile as f64).ceil() * SPAWN_S;
    }
    t
}

/// Roofline estimate of one sweep for a candidate: matrix-family
/// engines are scored from their own measured per-point instruction mix
/// at the candidate geometry; scalar engines from the calibrated
/// efficiency model.  Returns `(time_s, compute_s)`.
fn sweep_estimate(
    spec: &StencilSpec,
    n_points: usize,
    engine: EngineKind,
    dims: BlockDims,
    p: &Platform,
) -> (f64, f64) {
    let est = match engine {
        EngineKind::Naive => roofline::predict(
            spec,
            n_points,
            roofline::Engine::Compiler,
            roofline::engine_cfg(roofline::Engine::Compiler, MemKind::OnPkg),
            p,
        ),
        EngineKind::Simd => roofline::predict(
            spec,
            n_points,
            roofline::Engine::Simd,
            roofline::engine_cfg(roofline::Engine::Simd, MemKind::OnPkg),
            p,
        ),
        EngineKind::MatrixUnit | EngineKind::MatrixGemm => {
            // measure the candidate's own instruction mix: one block at
            // exactly the candidate geometry
            let g = Grid3::zeros(dims.vz, dims.vl, dims.vl);
            let (_, c) = match engine {
                EngineKind::MatrixUnit => matrix_unit::apply3(spec, &g, dims),
                _ => gemm::apply3(spec, &g, dims),
            };
            let per_kpoint: Counts =
                roofline::scale_counts(c, (dims.vz * dims.vl * dims.vl) as f64);
            roofline::predict_with_counts(
                spec,
                n_points,
                per_kpoint,
                dims,
                roofline::engine_cfg(roofline::Engine::MMStencil, MemKind::OnPkg),
                p,
            )
        }
    };
    (est.time_s, est.compute_s)
}

/// Deterministic startup search over (engine, BlockDims, time_block,
/// threads, wavefront tile geometry) for one cubic shape: every
/// candidate is scored against the
/// roofline cost model and the lowest modelled step time wins; exact
/// wall-time ties break toward strictly lower modelled compute time
/// (the candidate with compute headroom).  `max_threads` caps the
/// fan-out candidates (powers of two).  Same inputs always produce the
/// same plan — the property the manifest plan cache relies on.
pub fn tune(spec: &StencilSpec, n: usize, max_threads: usize, p: &Platform) -> TunePlan {
    assert_eq!(spec.ndim, 3, "tune searches cubic 3D shapes");
    let n_points = n * n * n;
    let mut threads_cands = vec![1usize];
    while threads_cands.last().unwrap() * 2 <= max_threads.max(1) {
        threads_cands.push(threads_cands.last().unwrap() * 2);
    }
    let mut best: Option<(f64, f64, TunePlan)> = None;
    for engine in EngineKind::ALL {
        let matrix = matches!(engine, EngineKind::MatrixUnit | EngineKind::MatrixGemm);
        let dims_cands: Vec<BlockDims> = if matrix {
            CAND_VL
                .iter()
                .flat_map(|&vl| CAND_VZ.iter().map(move |&vz| BlockDims { vl, vz }))
                .collect()
        } else {
            vec![BlockDims::default()]
        };
        for dims in dims_cands {
            let sweep = sweep_estimate(spec, n_points, engine, dims, p);
            for &threads in &threads_cands {
                for tb in CAND_TB {
                    for (tile, wf) in CAND_WAVE {
                        // the codec is an accuracy choice, not a speed
                        // knob: the search never trades error for time,
                        // so every candidate stays on the bitwise wire
                        let plan = TunePlan {
                            engine,
                            dims,
                            time_block: tb,
                            threads,
                            tile,
                            wf,
                            halo: HaloCodec::F32,
                        };
                        let t = step_time(sweep, &plan, spec, n, p);
                        let better = match &best {
                            None => true,
                            Some((bt, bc, _)) => t < *bt || (t == *bt && sweep.1 < *bc),
                        };
                        if better {
                            best = Some((t, sweep.1, plan));
                        }
                    }
                }
            }
        }
    }
    best.expect("candidate set is never empty").2
}

/// [`tune`] on the paper platform — the convenience entry the drivers
/// and the CLI use.
pub fn tune_default(spec: &StencilSpec, n: usize, max_threads: usize) -> TunePlan {
    tune(spec, n, max_threads, &Platform::paper())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_parse_round_trips() {
        for engine in EngineKind::ALL {
            for (vl, vz, tb, threads, tile, wf, halo) in [
                (16, 4, 1, 1, 0, 1, HaloCodec::F32),
                (8, 2, 4, 16, 16, 2, HaloCodec::Bf16),
                (32, 8, 2, 3, 8, 1, HaloCodec::F16),
            ] {
                let plan = TunePlan {
                    engine,
                    dims: BlockDims { vl, vz },
                    time_block: tb,
                    threads,
                    tile,
                    wf,
                    halo,
                };
                let again = TunePlan::parse(&plan.to_string()).unwrap();
                assert_eq!(again, plan, "{plan}");
                // and the string form itself is stable
                assert_eq!(again.to_string(), plan.to_string());
            }
        }
    }

    #[test]
    fn parse_accepts_any_key_order() {
        let plan = TunePlan::parse("threads=2 tb=1 vz=4 vl=16 engine=matrix_gemm").unwrap();
        assert_eq!(plan.engine, EngineKind::MatrixGemm);
        assert_eq!(plan.dims, BlockDims { vl: 16, vz: 4 });
        assert_eq!(plan.threads, 2);
        let plan = TunePlan::parse("wf=2 tile=8 threads=2 tb=1 vz=4 vl=16 engine=simd").unwrap();
        assert_eq!((plan.tile, plan.wf), (8, 2));
        let plan =
            TunePlan::parse("halo=bf16 threads=2 tb=1 vz=4 vl=16 engine=simd").unwrap();
        assert_eq!(plan.halo, HaloCodec::Bf16);
    }

    #[test]
    fn parse_defaults_wavefront_keys_for_v7_plans() {
        // plans serialized before the tile=/wf= keys existed (PR 7 and
        // earlier manifests) must keep parsing, landing on the classic
        // flat path — and before the halo= key (PR 8 and earlier),
        // landing on the bitwise f32 wire; the re-serialized form
        // carries all the new keys
        let v7 = "engine=matrix_gemm vl=16 vz=4 tb=1 threads=8";
        let plan = TunePlan::parse(v7).unwrap();
        assert_eq!((plan.tile, plan.wf), (0, 1));
        assert_eq!(plan.halo, HaloCodec::F32);
        assert_eq!(
            plan.to_string(),
            "engine=matrix_gemm vl=16 vz=4 tb=1 threads=8 tile=0 wf=1 halo=f32"
        );
        // a degenerate wf=0 clamps to 1 rather than dividing by zero
        // somewhere downstream
        let plan = TunePlan::parse("engine=simd vl=16 vz=4 tb=2 threads=1 tile=4 wf=0").unwrap();
        assert_eq!(plan.wf, 1);
    }

    #[test]
    fn parse_rejects_malformed_plans() {
        for (bad, what) in [
            ("engine=simd vl=16 vz=4 tb=1", "missing key \"threads\""),
            ("engine=simd vl=16 vz=4 tb=1 threads=2 vl=8", "duplicate key \"vl\""),
            ("engine=simd vl=sixteen vz=4 tb=1 threads=2", "not a number"),
            ("engine=simd vl=16 vz=4 tb=1 threads=2 cores=9", "unknown key \"cores\""),
            ("engine=simd vl=16 vz=4 tb=1 threads", "not key=value"),
            ("vl=16 vz=4 tb=1 threads=2", "missing key \"engine\""),
        ] {
            let err = TunePlan::parse(bad).unwrap_err().to_string();
            assert!(err.contains(what), "{bad:?}: {err}");
        }
        // a bad engine name reports the engine allowed-list
        let err = TunePlan::parse("engine=avx512 vl=16 vz=4 tb=1 threads=2")
            .unwrap_err()
            .to_string();
        assert!(err.contains("naive | simd | matrix_unit | matrix_gemm"), "{err}");
        // and a bad halo codec reports the codec allowed-list
        let err = TunePlan::parse("engine=simd vl=16 vz=4 tb=1 threads=2 halo=fp8")
            .unwrap_err()
            .to_string();
        assert!(err.contains("f32 | bf16 | f16"), "{err}");
        let err = TunePlan::parse("engine=simd vl=16 vz=4 tb=1 threads=2 halo=f32 halo=bf16")
            .unwrap_err()
            .to_string();
        assert!(err.contains("duplicate key \"halo\""), "{err}");
    }

    #[test]
    fn shape_keys_are_distinct_per_shape() {
        let a = shape_key(&StencilSpec::star3d(4), 256);
        assert_eq!(a, "3DStarR4@n256");
        assert_ne!(a, shape_key(&StencilSpec::star3d(2), 256));
        assert_ne!(a, shape_key(&StencilSpec::star3d(4), 128));
        assert_ne!(a, shape_key(&StencilSpec::box3d(4), 256));
    }

    #[test]
    fn headline_shape_selects_the_gemm_engine() {
        // the acceptance pin: the 256³ star-r4 headline plan must select
        // matrix_gemm — equal outer products to matrix_unit, strictly
        // lower auxiliary traffic — and beat the untuned default plan
        // under the same cost model
        let spec = StencilSpec::star3d(4);
        let p = Platform::paper();
        let plan = tune(&spec, 256, 8, &p);
        assert_eq!(plan.engine, EngineKind::MatrixGemm, "{plan}");
        // PR 8 pin: the headline plan is wavefront-tiled and its
        // (tile, wf) working set scores cache-resident in the roofline
        // model — cache-bandwidth-bound, not DRAM-bound
        assert!(
            plan.tile > 0 && plan.time_block > 1,
            "headline plan must be wavefront-tiled: {plan}"
        );
        assert_eq!(
            roofline::wavefront_residency(&p, &spec, 256, plan.tile, plan.wf),
            roofline::Residency::Cache,
            "{plan}"
        );
        let n_points = 256 * 256 * 256;
        let tuned = step_time(
            sweep_estimate(&spec, n_points, plan.engine, plan.dims, &p),
            &plan,
            &spec,
            256,
            &p,
        );
        let default = TunePlan::default_for(&spec, 256);
        let untuned = step_time(
            sweep_estimate(&spec, n_points, default.engine, default.dims, &p),
            &default,
            &spec,
            256,
            &p,
        );
        assert!(tuned <= untuned, "tuned {tuned:e} vs default {untuned:e}");
    }

    #[test]
    fn tune_is_deterministic() {
        let spec = StencilSpec::star3d(4);
        let p = Platform::paper();
        let a = tune(&spec, 128, 4, &p);
        let b = tune(&spec, 128, 4, &p);
        assert_eq!(a, b);
        assert_eq!(a.to_string(), b.to_string());
    }

    #[test]
    fn gemm_never_loses_to_matrix_unit_at_equal_geometry() {
        // the tie-break precondition: at every candidate geometry the
        // gemm mix has equal outer products and strictly lower aux, so
        // its modelled (time, compute) is lexicographically <= the
        // matrix-unit engine's
        let p = Platform::paper();
        for spec in [StencilSpec::star3d(2), StencilSpec::star3d(4)] {
            for vl in CAND_VL {
                for vz in CAND_VZ {
                    let dims = BlockDims { vl, vz };
                    let n_points = 128 * 128 * 128;
                    let mu = sweep_estimate(&spec, n_points, EngineKind::MatrixUnit, dims, &p);
                    let mg = sweep_estimate(&spec, n_points, EngineKind::MatrixGemm, dims, &p);
                    assert!(
                        mg.0 < mu.0 || (mg.0 == mu.0 && mg.1 < mu.1),
                        "vl={vl} vz={vz}: gemm ({:?}) vs matrix_unit ({:?})",
                        mg,
                        mu
                    );
                }
            }
        }
    }

    #[test]
    fn default_plan_is_the_historical_default() {
        let plan = TunePlan::default_for(&StencilSpec::star3d(2), 64);
        assert_eq!(plan.engine, EngineKind::Simd);
        assert_eq!(plan.dims, BlockDims::default());
        assert_eq!(plan.time_block, 1);
        assert_eq!(plan.threads, 1);
    }
}
