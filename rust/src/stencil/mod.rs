//! Stencil specifications and compute engines.
//!
//! Engines (all semantically identical, checked against each other and —
//! through the AOT artifacts — against the Pallas kernels):
//!
//! * [`naive`] — straight loops; the paper's "compiler baseline".
//! * [`simd`] — 2.5D-blocked, unroll-friendly inner loops; stands in for
//!   the paper's hand-tuned SIMD-intrinsic baseline.
//! * [`matrix_unit`] — the MMStencil algorithm: per-(VX,VY,VZ)-block
//!   outer-product accumulation into 16×16 tiles, with instruction
//!   counters feeding the microarchitectural performance model.
//! * [`gemm`] — the banded-matrix GEMM reformulation of the matrix-unit
//!   algorithm: a resident (2r+1)-band coefficient operand, strided
//!   panel swapping, no intermediate round-trip.
//! * [`box_zeroing`] — the Redundant-Access Zeroing box decomposition.
//!
//! [`engine`] is the dispatch layer over them: an [`Engine`] value
//! selects a kind at runtime ([`EngineKind::parse`]) and fans sweeps,
//! per-tile region tasks, and the RTM 1-D axis-derivative passes over
//! the persistent worker runtime with a worker-count-independent
//! partition (bitwise-stable results for any thread count).
//! [`tune`] sits above the dispatch layer: its startup autotuner scores
//! (engine, BlockDims, time_block, threads) candidates against the
//! `simulator::roofline` cost model and emits a [`TunePlan`] — the
//! single parseable value every production caller configures an
//! [`Engine`] from ([`Engine::from_plan`]).
//!
//! Ownership/aliasing contract: engines **read** through
//! [`GridSrc`](crate::grid::par::GridSrc) (a quiescent `&Grid3` or a
//! `ParGrid3` whose other cells are written concurrently) and **write**
//! only through the exclusive [`TileViewMut`](crate::grid::par::TileViewMut)
//! claim they are handed — a task cannot touch cells outside its claim.

pub mod box_zeroing;
pub mod coeffs;
pub mod engine;
pub mod gemm;
pub mod matrix_unit;
pub mod naive;
pub mod simd;
pub mod tune;

pub use coeffs::{box_weights, first_deriv, second_deriv, star_weights, CoeffTable};
pub use engine::{Engine, EngineKind};
pub use tune::TunePlan;

/// Stencil pattern class (paper Fig. 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Pattern {
    /// Axis-aligned cross: 1 + 2·ndim·r points.
    Star,
    /// Dense (2r+1)^ndim neighbourhood.
    Box,
}

/// A stencil benchmark kernel specification.
#[derive(Clone, Debug)]
pub struct StencilSpec {
    /// Neighbourhood shape (star cross or dense box).
    pub pattern: Pattern,
    /// Grid dimensionality: 2 or 3.
    pub ndim: usize,
    /// Stencil radius `r` (halo width per axis).
    pub radius: usize,
    /// Star only: the centre-point weight (the per-axis bands carry a
    /// zero centre so the point is counted once).
    pub star_center: f32,
    /// Star only: per-axis weights (len 2r+1, zero centre) in axis
    /// order `[x, y]` (2D) or `[z, x, y]` (3D).
    pub star_axes: Vec<Vec<f32>>,
    /// Box only: dense weight tensor, row-major over `(x,y)` /
    /// `(z,x,y)`.
    pub box_w: Vec<f32>,
}

impl StencilSpec {
    /// 2D star (cross) kernel of the given radius.
    pub fn star2d(radius: usize) -> Self {
        let (c, axes) = star_weights(2, radius);
        Self {
            pattern: Pattern::Star,
            ndim: 2,
            radius,
            star_center: c,
            star_axes: axes,
            box_w: Vec::new(),
        }
    }

    /// 3D star (cross) kernel of the given radius.
    pub fn star3d(radius: usize) -> Self {
        let (c, axes) = star_weights(3, radius);
        Self {
            pattern: Pattern::Star,
            ndim: 3,
            radius,
            star_center: c,
            star_axes: axes,
            box_w: Vec::new(),
        }
    }

    /// 2D dense box kernel of the given radius.
    pub fn box2d(radius: usize) -> Self {
        Self {
            pattern: Pattern::Box,
            ndim: 2,
            radius,
            star_center: 0.0,
            star_axes: Vec::new(),
            box_w: box_weights(2, radius),
        }
    }

    /// 3D dense box kernel of the given radius.
    pub fn box3d(radius: usize) -> Self {
        Self {
            pattern: Pattern::Box,
            ndim: 3,
            radius,
            star_center: 0.0,
            star_axes: Vec::new(),
            box_w: box_weights(3, radius),
        }
    }

    /// A kernel from a user-supplied [`CoeffTable`] (the `custom:`
    /// spec family).  Star tables reuse the band on every axis with
    /// the centre counted once per axis — the same convention as
    /// [`star_weights`]; box tables are the dense tensor verbatim.
    /// Engines treat the result exactly like a Table-I kernel: same
    /// `coeffs` plumbing, same oracle, same bitwise-stability
    /// contract.
    pub fn from_table(table: &CoeffTable) -> Self {
        match table.pattern {
            Pattern::Star => {
                let mut axis = table.taps.clone();
                let center = table.ndim as f32 * axis[table.radius];
                axis[table.radius] = 0.0;
                Self {
                    pattern: Pattern::Star,
                    ndim: table.ndim,
                    radius: table.radius,
                    star_center: center,
                    star_axes: vec![axis; table.ndim],
                    box_w: Vec::new(),
                }
            }
            Pattern::Box => Self {
                pattern: Pattern::Box,
                ndim: table.ndim,
                radius: table.radius,
                star_center: 0.0,
                star_axes: Vec::new(),
                box_w: table.taps.clone(),
            },
        }
    }

    /// The eight Table-I benchmark kernel names, in suite order.
    pub const NAMES: [&'static str; 8] = [
        "2DStarR2", "2DStarR4", "2DBoxR2", "2DBoxR3",
        "3DStarR2", "3DStarR4", "3DBoxR1", "3DBoxR2",
    ];

    /// The `custom:` table grammar, as shown in parse errors.
    pub const CUSTOM_GRAMMAR: [&'static str; 1] =
        ["custom:<star|box>[:<2d|3d>]:r<radius>:<w0,w1,…|file=path>"];

    /// Kernel by Table-I name (e.g. "3DStarR4") or by a `custom:`
    /// coefficient-table spec (e.g. `custom:star:r3:file=coeffs.txt`
    /// or `custom:box:2d:r1:1,2,1,2,4,2,1,2,1` — see
    /// [`CoeffTable::parse`] for the grammar).
    ///
    /// The error names the rejected string and the full Table-I list,
    /// matching [`EngineKind::parse`](crate::stencil::engine::EngineKind::parse)
    /// so config/CLI messages read identically across selectors; a
    /// malformed `custom:` spec instead reports the failing segment
    /// and the grammar.
    pub fn parse(name: &str) -> Result<Self, crate::util::ParseKindError> {
        if let Some(table) = name.strip_prefix("custom:") {
            return CoeffTable::parse(table)
                .map(|t| Self::from_table(&t))
                .map_err(|detail| {
                    crate::util::ParseKindError::new(
                        "custom stencil table",
                        name,
                        &Self::CUSTOM_GRAMMAR,
                    )
                    .with_detail(detail)
                });
        }
        Ok(match name {
            "2DStarR2" => Self::star2d(2),
            "2DStarR4" => Self::star2d(4),
            "2DBoxR2" => Self::box2d(2),
            "2DBoxR3" => Self::box2d(3),
            "3DStarR2" => Self::star3d(2),
            "3DStarR4" => Self::star3d(4),
            "3DBoxR1" => Self::box3d(1),
            "3DBoxR2" => Self::box3d(2),
            _ => {
                return Err(crate::util::ParseKindError::new(
                    "stencil kernel",
                    name,
                    &Self::NAMES,
                ))
            }
        })
    }

    /// All eight Table-I benchmark kernels.
    pub fn benchmark_suite() -> Vec<(&'static str, Self)> {
        Self::NAMES
            .iter()
            .map(|&n| (n, Self::parse(n).unwrap()))
            .collect()
    }

    /// Number of stencil points (Table I "Points" column).
    pub fn points(&self) -> usize {
        match self.pattern {
            Pattern::Star => 1 + 2 * self.ndim * self.radius,
            Pattern::Box => (2 * self.radius + 1).pow(self.ndim as u32),
        }
    }

    /// Flops per output point (mul+add per neighbour).
    pub fn flops_per_point(&self) -> usize {
        2 * self.points()
    }

    /// Minimum bytes moved per output point (read + write, perfect reuse):
    /// the denominator of the paper's bandwidth-utilization metric.
    pub fn min_bytes_per_point(&self) -> usize {
        2 * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_point_counts() {
        for (name, pts) in [
            ("2DStarR2", 9),
            ("2DStarR4", 17),
            ("2DBoxR2", 25),
            ("2DBoxR3", 49),
            ("3DStarR2", 13),
            ("3DStarR4", 25),
            ("3DBoxR1", 27),
            ("3DBoxR2", 125),
        ] {
            assert_eq!(StencilSpec::parse(name).unwrap().points(), pts, "{name}");
        }
    }

    #[test]
    fn unknown_names_report_the_table1_list() {
        for bad in ["4DStarR9", "", "3dstarr4", "3DStarR4 ", "3DStar"] {
            let err = StencilSpec::parse(bad).unwrap_err();
            assert_eq!(err.what, "stencil kernel", "{bad:?}");
            assert_eq!(err.name, bad, "{bad:?}");
            assert!(err.to_string().contains("3DStarR4"), "{bad:?}: {err}");
        }
    }

    #[test]
    fn parse_round_trips_the_benchmark_suite() {
        // every suite name resolves to the kernel the suite carries
        for (name, spec) in StencilSpec::benchmark_suite() {
            let again = StencilSpec::parse(name).unwrap();
            assert_eq!(again.pattern, spec.pattern, "{name}");
            assert_eq!(again.ndim, spec.ndim, "{name}");
            assert_eq!(again.radius, spec.radius, "{name}");
            assert_eq!(again.points(), spec.points(), "{name}");
            assert_eq!(again.star_axes, spec.star_axes, "{name}");
            assert_eq!(again.box_w, spec.box_w, "{name}");
        }
    }

    #[test]
    fn suite_has_eight_kernels() {
        assert_eq!(StencilSpec::benchmark_suite().len(), 8);
    }

    #[test]
    fn custom_star_matches_the_star_weights_convention() {
        // the benchmark band fed back through custom: reproduces 3DStarR2
        let band: Vec<String> =
            coeffs::second_deriv(2).iter().map(|v| format!("{v:.9}")).collect();
        let spec = StencilSpec::parse(&format!("custom:star:r2:{}", band.join(","))).unwrap();
        let want = StencilSpec::star3d(2);
        assert_eq!(spec.pattern, Pattern::Star);
        assert_eq!((spec.ndim, spec.radius, spec.points()), (3, 2, 13));
        assert!((spec.star_center - want.star_center).abs() < 1e-6);
        assert_eq!(spec.star_axes.len(), 3);
        assert_eq!(spec.star_axes[0][2], 0.0);
        for (a, b) in spec.star_axes[0].iter().zip(&want.star_axes[0]) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn custom_box_is_the_dense_tensor_verbatim() {
        let spec = StencilSpec::parse("custom:box:2d:r1:1,2,1,2,4,2,1,2,1").unwrap();
        assert_eq!(spec.pattern, Pattern::Box);
        assert_eq!((spec.ndim, spec.radius, spec.points()), (2, 1, 9));
        assert_eq!(spec.box_w, vec![1.0, 2.0, 1.0, 2.0, 4.0, 2.0, 1.0, 2.0, 1.0]);
    }

    #[test]
    fn malformed_custom_specs_report_segment_and_grammar() {
        let err = StencilSpec::parse("custom:star:r2:1,-2,1").unwrap_err();
        assert_eq!(err.what, "custom stencil table");
        assert_eq!(err.name, "custom:star:r2:1,-2,1");
        let msg = err.to_string();
        assert!(msg.contains("5 taps, got 3"), "{msg}");
        assert!(msg.contains("custom:<star|box>"), "{msg}");
        // a bare "custom:" is a grammar error, not an unknown kernel
        let err = StencilSpec::parse("custom:").unwrap_err();
        assert_eq!(err.what, "custom stencil table");
        assert!(err.detail.is_some());
    }
}
