//! Stencil specifications and compute engines.
//!
//! Engines (all semantically identical, checked against each other and —
//! through the AOT artifacts — against the Pallas kernels):
//!
//! * [`naive`] — straight loops; the paper's "compiler baseline".
//! * [`simd`] — 2.5D-blocked, unroll-friendly inner loops; stands in for
//!   the paper's hand-tuned SIMD-intrinsic baseline.
//! * [`matrix_unit`] — the MMStencil algorithm: per-(VX,VY,VZ)-block
//!   outer-product accumulation into 16×16 tiles, with instruction
//!   counters feeding the microarchitectural performance model.
//! * [`gemm`] — the banded-matrix GEMM reformulation of the matrix-unit
//!   algorithm: a resident (2r+1)-band coefficient operand, strided
//!   panel swapping, no intermediate round-trip.
//! * [`box_zeroing`] — the Redundant-Access Zeroing box decomposition.
//!
//! [`engine`] is the dispatch layer over them: an [`Engine`] value
//! selects a kind at runtime ([`EngineKind::parse`]) and fans sweeps,
//! per-tile region tasks, and the RTM 1-D axis-derivative passes over
//! the persistent worker runtime with a worker-count-independent
//! partition (bitwise-stable results for any thread count).
//! [`tune`] sits above the dispatch layer: its startup autotuner scores
//! (engine, BlockDims, time_block, threads) candidates against the
//! `simulator::roofline` cost model and emits a [`TunePlan`] — the
//! single parseable value every production caller configures an
//! [`Engine`] from ([`Engine::from_plan`]).
//!
//! Ownership/aliasing contract: engines **read** through
//! [`GridSrc`](crate::grid::par::GridSrc) (a quiescent `&Grid3` or a
//! `ParGrid3` whose other cells are written concurrently) and **write**
//! only through the exclusive [`TileViewMut`](crate::grid::par::TileViewMut)
//! claim they are handed — a task cannot touch cells outside its claim.

pub mod box_zeroing;
pub mod coeffs;
pub mod engine;
pub mod gemm;
pub mod matrix_unit;
pub mod naive;
pub mod simd;
pub mod tune;

pub use coeffs::{box_weights, first_deriv, second_deriv, star_weights};
pub use engine::{Engine, EngineKind};
pub use tune::TunePlan;

/// Stencil pattern class (paper Fig. 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Pattern {
    /// Axis-aligned cross: 1 + 2·ndim·r points.
    Star,
    /// Dense (2r+1)^ndim neighbourhood.
    Box,
}

/// A stencil benchmark kernel specification.
#[derive(Clone, Debug)]
pub struct StencilSpec {
    /// Neighbourhood shape (star cross or dense box).
    pub pattern: Pattern,
    /// Grid dimensionality: 2 or 3.
    pub ndim: usize,
    /// Stencil radius `r` (halo width per axis).
    pub radius: usize,
    /// Star only: the centre-point weight (the per-axis bands carry a
    /// zero centre so the point is counted once).
    pub star_center: f32,
    /// Star only: per-axis weights (len 2r+1, zero centre) in axis
    /// order `[x, y]` (2D) or `[z, x, y]` (3D).
    pub star_axes: Vec<Vec<f32>>,
    /// Box only: dense weight tensor, row-major over `(x,y)` /
    /// `(z,x,y)`.
    pub box_w: Vec<f32>,
}

impl StencilSpec {
    /// 2D star (cross) kernel of the given radius.
    pub fn star2d(radius: usize) -> Self {
        let (c, axes) = star_weights(2, radius);
        Self {
            pattern: Pattern::Star,
            ndim: 2,
            radius,
            star_center: c,
            star_axes: axes,
            box_w: Vec::new(),
        }
    }

    /// 3D star (cross) kernel of the given radius.
    pub fn star3d(radius: usize) -> Self {
        let (c, axes) = star_weights(3, radius);
        Self {
            pattern: Pattern::Star,
            ndim: 3,
            radius,
            star_center: c,
            star_axes: axes,
            box_w: Vec::new(),
        }
    }

    /// 2D dense box kernel of the given radius.
    pub fn box2d(radius: usize) -> Self {
        Self {
            pattern: Pattern::Box,
            ndim: 2,
            radius,
            star_center: 0.0,
            star_axes: Vec::new(),
            box_w: box_weights(2, radius),
        }
    }

    /// 3D dense box kernel of the given radius.
    pub fn box3d(radius: usize) -> Self {
        Self {
            pattern: Pattern::Box,
            ndim: 3,
            radius,
            star_center: 0.0,
            star_axes: Vec::new(),
            box_w: box_weights(3, radius),
        }
    }

    /// The eight Table-I benchmark kernel names, in suite order.
    pub const NAMES: [&'static str; 8] = [
        "2DStarR2", "2DStarR4", "2DBoxR2", "2DBoxR3",
        "3DStarR2", "3DStarR4", "3DBoxR1", "3DBoxR2",
    ];

    /// Benchmark kernel by Table-I name (e.g. "3DStarR4").
    ///
    /// The error names the rejected string and the full Table-I list,
    /// matching [`EngineKind::parse`](crate::stencil::engine::EngineKind::parse)
    /// so config/CLI messages read identically across selectors.
    pub fn parse(name: &str) -> Result<Self, crate::util::ParseKindError> {
        Ok(match name {
            "2DStarR2" => Self::star2d(2),
            "2DStarR4" => Self::star2d(4),
            "2DBoxR2" => Self::box2d(2),
            "2DBoxR3" => Self::box2d(3),
            "3DStarR2" => Self::star3d(2),
            "3DStarR4" => Self::star3d(4),
            "3DBoxR1" => Self::box3d(1),
            "3DBoxR2" => Self::box3d(2),
            _ => {
                return Err(crate::util::ParseKindError::new(
                    "stencil kernel",
                    name,
                    &Self::NAMES,
                ))
            }
        })
    }

    /// All eight Table-I benchmark kernels.
    pub fn benchmark_suite() -> Vec<(&'static str, Self)> {
        Self::NAMES
            .iter()
            .map(|&n| (n, Self::parse(n).unwrap()))
            .collect()
    }

    /// Number of stencil points (Table I "Points" column).
    pub fn points(&self) -> usize {
        match self.pattern {
            Pattern::Star => 1 + 2 * self.ndim * self.radius,
            Pattern::Box => (2 * self.radius + 1).pow(self.ndim as u32),
        }
    }

    /// Flops per output point (mul+add per neighbour).
    pub fn flops_per_point(&self) -> usize {
        2 * self.points()
    }

    /// Minimum bytes moved per output point (read + write, perfect reuse):
    /// the denominator of the paper's bandwidth-utilization metric.
    pub fn min_bytes_per_point(&self) -> usize {
        2 * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_point_counts() {
        for (name, pts) in [
            ("2DStarR2", 9),
            ("2DStarR4", 17),
            ("2DBoxR2", 25),
            ("2DBoxR3", 49),
            ("3DStarR2", 13),
            ("3DStarR4", 25),
            ("3DBoxR1", 27),
            ("3DBoxR2", 125),
        ] {
            assert_eq!(StencilSpec::parse(name).unwrap().points(), pts, "{name}");
        }
    }

    #[test]
    fn unknown_names_report_the_table1_list() {
        for bad in ["4DStarR9", "", "3dstarr4", "3DStarR4 ", "3DStar"] {
            let err = StencilSpec::parse(bad).unwrap_err();
            assert_eq!(err.what, "stencil kernel", "{bad:?}");
            assert_eq!(err.name, bad, "{bad:?}");
            assert!(err.to_string().contains("3DStarR4"), "{bad:?}: {err}");
        }
    }

    #[test]
    fn parse_round_trips_the_benchmark_suite() {
        // every suite name resolves to the kernel the suite carries
        for (name, spec) in StencilSpec::benchmark_suite() {
            let again = StencilSpec::parse(name).unwrap();
            assert_eq!(again.pattern, spec.pattern, "{name}");
            assert_eq!(again.ndim, spec.ndim, "{name}");
            assert_eq!(again.radius, spec.radius, "{name}");
            assert_eq!(again.points(), spec.points(), "{name}");
            assert_eq!(again.star_axes, spec.star_axes, "{name}");
            assert_eq!(again.box_w, spec.box_w, "{name}");
        }
    }

    #[test]
    fn suite_has_eight_kernels() {
        assert_eq!(StencilSpec::benchmark_suite().len(), 8);
    }
}
