//! Run records and the paper's reporting metrics: GStencil/s,
//! bandwidth utilization, speedups, plus CSV/markdown export for the
//! bench harness (criterion is unavailable offline — `util::bench` does
//! the timing, this module does the bookkeeping).  [`bench_json`]
//! carries the stable `BENCH_engines.json` schema behind the perf
//! trajectory (v2: sweep rows + per-engine RTM step rows).
//!
//! Contract: everything here is pure bookkeeping over owned values —
//! no shared mutable state, no grid access; records are built from
//! numbers the measuring code already owns.

pub mod bench_json;

use crate::stencil::StencilSpec;

/// The paper's bandwidth-utilization metric (§III-B d):
/// `2 · sizeof(datatype) · stencils_per_s / peak_bandwidth`.
pub fn bandwidth_utilization(stencils_per_s: f64, elem_bytes: usize, peak_bw: f64) -> f64 {
    2.0 * elem_bytes as f64 * stencils_per_s / peak_bw
}

/// GStencil/s from a cell count and elapsed seconds.
pub fn gstencils_per_s(cells: usize, secs: f64) -> f64 {
    cells as f64 / secs / 1e9
}

/// Effective GFLOP/s of a sweep.
pub fn gflops_per_s(spec: &StencilSpec, cells: usize, secs: f64) -> f64 {
    spec.flops_per_point() as f64 * cells as f64 / secs / 1e9
}

/// One experiment measurement, as reported in EXPERIMENTS.md.
#[derive(Clone, Debug)]
pub struct RunRecord {
    /// experiment id, e.g. "fig11" / "tab02"
    pub experiment: String,
    /// series within the experiment, e.g. "MMStencil" / "SIMD"
    pub series: String,
    /// workload label, e.g. "3DStarR4" or "X-direction"
    pub workload: String,
    /// metric name, e.g. "bandwidth_util" / "GB/s" / "time_s"
    pub metric: String,
    pub value: f64,
    /// paper's value for the same cell, if stated (for the delta column)
    pub paper_value: Option<f64>,
}

impl RunRecord {
    pub fn new(
        experiment: &str,
        series: &str,
        workload: &str,
        metric: &str,
        value: f64,
    ) -> Self {
        Self {
            experiment: experiment.into(),
            series: series.into(),
            workload: workload.into(),
            metric: metric.into(),
            value,
            paper_value: None,
        }
    }

    pub fn with_paper(mut self, v: f64) -> Self {
        self.paper_value = Some(v);
        self
    }

    /// measured / paper ratio (1.0 = exact match), if paper value known.
    pub fn ratio_to_paper(&self) -> Option<f64> {
        self.paper_value.map(|p| self.value / p)
    }
}

/// A set of run records with export helpers.
#[derive(Clone, Debug, Default)]
pub struct RecordSet {
    pub records: Vec<RunRecord>,
}

impl RecordSet {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, r: RunRecord) {
        self.records.push(r);
    }

    /// Append a batch of records (e.g. the per-worker utilization /
    /// steal-count rows from `coordinator::runtime::RuntimeStats`).
    pub fn extend(&mut self, records: impl IntoIterator<Item = RunRecord>) {
        self.records.extend(records);
    }

    pub fn add(
        &mut self,
        experiment: &str,
        series: &str,
        workload: &str,
        metric: &str,
        value: f64,
    ) {
        self.push(RunRecord::new(experiment, series, workload, metric, value));
    }

    /// CSV with a fixed header; `paper` column empty when unknown.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("experiment,series,workload,metric,value,paper\n");
        for r in &self.records {
            s.push_str(&format!(
                "{},{},{},{},{:.6e},{}\n",
                r.experiment,
                r.series,
                r.workload,
                r.metric,
                r.value,
                r.paper_value.map(|v| format!("{v:.6e}")).unwrap_or_default()
            ));
        }
        s
    }

    /// Markdown table (series × workload) for one metric.
    pub fn to_markdown(&self, metric: &str, prec: usize) -> String {
        let mut workloads: Vec<&str> = Vec::new();
        let mut series: Vec<&str> = Vec::new();
        for r in self.records.iter().filter(|r| r.metric == metric) {
            if !workloads.contains(&r.workload.as_str()) {
                workloads.push(&r.workload);
            }
            if !series.contains(&r.series.as_str()) {
                series.push(&r.series);
            }
        }
        let mut out = String::from("| series |");
        for w in &workloads {
            out.push_str(&format!(" {w} |"));
        }
        out.push('\n');
        out.push_str("|---|");
        for _ in &workloads {
            out.push_str("---|");
        }
        out.push('\n');
        for s in &series {
            out.push_str(&format!("| {s} |"));
            for w in &workloads {
                let v = self
                    .records
                    .iter()
                    .find(|r| r.metric == metric && &r.series == s && &r.workload == w)
                    .map(|r| format!("{:.*}", prec, r.value))
                    .unwrap_or_else(|| "—".into());
                out.push_str(&format!(" {v} |"));
            }
            out.push('\n');
        }
        out
    }

    /// Geometric-mean ratio to the paper over records that carry one.
    pub fn geomean_ratio_to_paper(&self) -> Option<f64> {
        let ratios: Vec<f64> = self.records.iter().filter_map(|r| r.ratio_to_paper()).collect();
        if ratios.is_empty() {
            return None;
        }
        Some(crate::util::stats::geomean(&ratios))
    }

    /// Write CSV next to the bench outputs (best effort).
    pub fn save_csv(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_csv())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn utilization_metric_matches_paper_definition() {
        // 512³ sweep at 1 GStencil/s on 400 GB/s: 8 GB/s of 400 = 2%
        let u = bandwidth_utilization(1e9, 4, 400e9);
        assert!((u - 0.02).abs() < 1e-12);
    }

    #[test]
    fn gstencil_rate() {
        assert!((gstencils_per_s(512 * 512 * 512, 1.0) - 0.134217728).abs() < 1e-9);
    }

    #[test]
    fn csv_roundtrip_columns() {
        let mut rs = RecordSet::new();
        rs.push(RunRecord::new("fig11", "MMStencil", "3DStarR4", "util", 0.57).with_paper(0.57));
        rs.add("fig11", "SIMD", "3DStarR4", "util", 0.4);
        let csv = rs.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.lines().nth(1).unwrap().ends_with(",5.700000e-1"));
        assert!(csv.lines().nth(2).unwrap().ends_with(","));
    }

    #[test]
    fn markdown_grid_is_complete() {
        let mut rs = RecordSet::new();
        for s in ["A", "B"] {
            for w in ["w1", "w2"] {
                rs.add("x", s, w, "m", 1.0);
            }
        }
        let md = rs.to_markdown("m", 2);
        assert_eq!(md.lines().count(), 4);
        assert!(md.contains("| A | 1.00 | 1.00 |"));
    }

    #[test]
    fn extend_appends_batches() {
        let mut rs = RecordSet::new();
        rs.extend(vec![
            RunRecord::new("fig13", "pool", "w0@numa0", "worker_utilization", 0.92),
            RunRecord::new("fig13", "pool", "w0@numa0", "steals", 3.0),
        ]);
        assert_eq!(rs.records.len(), 2);
        assert!(rs.to_csv().contains("worker_utilization"));
    }

    #[test]
    fn ratio_and_geomean() {
        let mut rs = RecordSet::new();
        rs.push(RunRecord::new("e", "s", "w", "m", 2.0).with_paper(1.0));
        rs.push(RunRecord::new("e", "s", "w2", "m", 0.5).with_paper(1.0));
        let g = rs.geomean_ratio_to_paper().unwrap();
        assert!((g - 1.0).abs() < 1e-12);
    }
}
