//! Stable JSON schema for the per-engine perf trajectory
//! (`BENCH_engines.json`, emitted by `examples/perf_probe.rs` and
//! uploaded as a CI artifact).
//!
//! The numbers are advisory — host-dependent throughput is never gated
//! on — but the **schema is contract**: CI validates it on every PR so
//! the trajectory stays machine-readable across the PR sequence.
//! Renderer and validator are hand-rolled (no serde; DESIGN.md §7).

/// Schema tag carried in the document; bump on breaking field changes.
pub const SCHEMA: &str = "mmstencil.bench_engines.v1";

/// One engine × workload measurement.
#[derive(Clone, Debug)]
pub struct EngineBench {
    /// "naive" | "simd" | "matrix_unit" | "matrix_unit_par" | …
    pub engine: String,
    /// "star" | "box"
    pub pattern: String,
    pub radius: usize,
    /// Cubic grid edge (the workload is an n³ periodic sweep).
    pub n: usize,
    /// Parallelism the engine ran with (1 for serial engines).
    pub threads: usize,
    /// Median throughput in million stencil outputs per second.
    pub mcells_per_s: f64,
    /// Heap allocations observed during one post-warm-up sweep
    /// (counting global allocator in the probe binary).
    pub allocs_per_sweep: u64,
    /// Scratch-arena growth events during the same sweep
    /// (`coordinator::scratch::grow_events` delta; 0 in steady state).
    pub arena_grows_per_sweep: u64,
}

fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Render the document.  Entries keep their push order, so re-runs of
/// the same probe diff cleanly.
pub fn render(entries: &[EngineBench]) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!("  \"schema\": \"{SCHEMA}\",\n"));
    s.push_str("  \"entries\": [\n");
    for (i, e) in entries.iter().enumerate() {
        let m = if e.mcells_per_s.is_finite() { e.mcells_per_s } else { 0.0 };
        s.push_str(&format!(
            "    {{\"engine\": \"{}\", \"pattern\": \"{}\", \"radius\": {}, \"n\": {}, \
             \"threads\": {}, \"mcells_per_s\": {:.3}, \"allocs_per_sweep\": {}, \
             \"arena_grows_per_sweep\": {}}}{}\n",
            esc(&e.engine),
            esc(&e.pattern),
            e.radius,
            e.n,
            e.threads,
            m,
            e.allocs_per_sweep,
            e.arena_grows_per_sweep,
            if i + 1 == entries.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// Structural validation of a rendered document: schema tag, balanced
/// nesting, and every entry carrying the full key set.  Returns the
/// entry count.  (CI additionally parses the artifact with a real JSON
/// parser; this keeps the contract testable offline.)
pub fn validate(s: &str) -> Result<usize, String> {
    if !s.contains(&format!("\"schema\": \"{SCHEMA}\"")) {
        return Err(format!("missing schema tag {SCHEMA}"));
    }
    let (mut brace, mut bracket) = (0i64, 0i64);
    for c in s.chars() {
        match c {
            '{' => brace += 1,
            '}' => brace -= 1,
            '[' => bracket += 1,
            ']' => bracket -= 1,
            _ => {}
        }
        if brace < 0 || bracket < 0 {
            return Err("unbalanced nesting".into());
        }
    }
    if brace != 0 || bracket != 0 {
        return Err("unbalanced nesting".into());
    }
    let count = s.matches("\"engine\":").count();
    for k in [
        "\"pattern\":",
        "\"radius\":",
        "\"n\":",
        "\"threads\":",
        "\"mcells_per_s\":",
        "\"allocs_per_sweep\":",
        "\"arena_grows_per_sweep\":",
    ] {
        if s.matches(k).count() != count {
            return Err(format!("key {k} count mismatch (expected {count})"));
        }
    }
    Ok(count)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<EngineBench> {
        vec![
            EngineBench {
                engine: "simd".into(),
                pattern: "star".into(),
                radius: 4,
                n: 96,
                threads: 1,
                mcells_per_s: 123.456,
                allocs_per_sweep: 2,
                arena_grows_per_sweep: 0,
            },
            EngineBench {
                engine: "matrix_unit_par".into(),
                pattern: "box".into(),
                radius: 1,
                n: 96,
                threads: 8,
                mcells_per_s: 77.0,
                allocs_per_sweep: 31,
                arena_grows_per_sweep: 0,
            },
        ]
    }

    #[test]
    fn render_validates() {
        let doc = render(&sample());
        assert_eq!(validate(&doc), Ok(2));
        assert!(doc.contains("\"schema\": \"mmstencil.bench_engines.v1\""));
        assert!(doc.contains("\"mcells_per_s\": 123.456"));
    }

    #[test]
    fn empty_document_is_valid_with_zero_entries() {
        assert_eq!(validate(&render(&[])), Ok(0));
    }

    #[test]
    fn tampered_documents_fail() {
        let doc = render(&sample());
        assert!(validate(&doc.replace("bench_engines.v1", "v0")).is_err());
        assert!(validate(&doc.replace("\"radius\":", "\"r\":")).is_err());
        assert!(validate(doc.trim_end().trim_end_matches('}')).is_err());
    }

    #[test]
    fn non_finite_throughput_is_clamped() {
        let mut e = sample();
        e[0].mcells_per_s = f64::INFINITY;
        let doc = render(&e);
        assert!(validate(&doc).is_ok());
        assert!(doc.contains("\"mcells_per_s\": 0.000"));
    }
}
