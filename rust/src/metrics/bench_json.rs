//! Stable JSON schema for the per-engine perf trajectory
//! (`BENCH_engines.json`, emitted by `examples/perf_probe.rs` and
//! uploaded as a CI artifact).
//!
//! The numbers are advisory — host-dependent throughput is never gated
//! on — but the **schema is contract**: CI validates it on every PR so
//! the trajectory stays machine-readable across the PR sequence.
//! Renderer and validator are hand-rolled (no serde; DESIGN.md §7).
//!
//! v2 extended the document with `rtm_entries`: per-engine RTM step
//! throughput, so the trajectory covers the application workload, not
//! just raw sweeps.  v3 added a `time_block` field to every row — the
//! temporal-blocking depth the workload ran at (1 = classic stepping)
//! — so the fused-sweep trajectory is diffable per depth
//! (`scripts/bench_diff.py`).  v4 added `survey_entries`: multi-shot
//! surveys through [`rtm::service`](crate::rtm::service), reported as
//! shots/hour with retry/failure accounting and the checkpoint strategy
//! the shots ran under.  v5 added a `plan` field to every
//! sweep and RTM row — the active [`TunePlan`](crate::stencil::TunePlan)
//! in its `Display` form — so each measurement records the exact
//! (engine, geometry, depth, fan-out) it ran under and a tuner change
//! shows up as a row diff, not a silent re-baselining.  v6 added
//! `tile`/`wf` to every sweep row — the wavefront (z, t) tile
//! geometry ([`coordinator::wavefront`](crate::coordinator::wavefront))
//! the row stepped under, `0`/`1` for classic level-at-a-time stepping
//! — so the temporal-tiling trajectory is diffable per geometry
//! (`scripts/bench_diff.py` keys sweep rows on them).  v7 (this PR)
//! adds `halo_codec`/`transport_bytes` to every sweep and RTM row —
//! the halo wire codec ([`HaloCodec`](crate::grid::halo::HaloCodec))
//! the row exchanged under and the bytes it put on the simulated wire
//! (0 for single-rank/periodic workloads that never exchange) — so a
//! compression-ratio change is a visible row diff.  v8 (this PR) adds
//! `faults_injected`/`resumed_shots` to every survey row — the chaos
//! accounting of the resilience subsystem
//! ([`rtm::resilience`](crate::rtm::resilience)).  In the probe's
//! baseline rows `faults_injected` equals `retries` (one deliberate
//! kernel fault proves the retry path) and `resumed_shots` is 0, so
//! any other value in the artifact flags an unexpected fault plan.

/// Schema tag carried in the document; bump on breaking field changes.
/// v1 → v2: added the `rtm_entries` array.
/// v2 → v3: added `time_block` to every sweep and RTM row.
/// v3 → v4: added the `survey_entries` array (shot-service surveys).
/// v4 → v5: added `plan` (active `TunePlan` string) to sweep/RTM rows.
/// v5 → v6: added `tile`/`wf` (wavefront tile geometry) to sweep rows.
/// v6 → v7: added `halo_codec`/`transport_bytes` to sweep/RTM rows.
/// v7 → v8: added `faults_injected`/`resumed_shots` to survey rows.
pub const SCHEMA: &str = "mmstencil.bench_engines.v8";

/// One engine × sweep-workload measurement.
#[derive(Clone, Debug)]
pub struct EngineBench {
    /// "naive" | "simd" | "matrix_unit" | "matrix_unit_par" | …
    pub engine: String,
    /// "star" | "box"
    pub pattern: String,
    /// Stencil radius.
    pub radius: usize,
    /// Cubic grid edge (the workload is an n³ periodic sweep).
    pub n: usize,
    /// Parallelism the engine ran with (1 for serial engines).
    pub threads: usize,
    /// Temporal-blocking depth: sweeps fused per measured call
    /// (`Engine::apply3_fused`); 1 = one classic sweep.  Throughput
    /// counts all `time_block · n³` updates.
    pub time_block: usize,
    /// Wavefront z-tile extent the fused sub-steps were cut into
    /// ([`coordinator::wavefront`](crate::coordinator::wavefront));
    /// 0 = classic level-at-a-time stepping.  Added in schema v6.
    pub tile: usize,
    /// Wavefront band depth: sub-step levels advanced per dispatch
    /// barrier (1 when untiled).  Added in schema v6.
    pub wf: usize,
    /// Halo wire codec name (`HaloCodec::name`): "f32" | "bf16" |
    /// "f16".  Added in schema v7.
    pub halo_codec: String,
    /// Bytes the workload put on the simulated wire (halo exchanges);
    /// 0 for periodic/single-rank rows.  Added in schema v7.
    pub transport_bytes: u64,
    /// Median throughput in million stencil outputs per second.
    pub mcells_per_s: f64,
    /// Heap allocations observed during one post-warm-up sweep
    /// (counting global allocator in the probe binary).
    pub allocs_per_sweep: u64,
    /// Scratch-arena growth events during the same sweep
    /// (`coordinator::scratch::grow_events` delta; 0 in steady state).
    pub arena_grows_per_sweep: u64,
    /// The active [`TunePlan`](crate::stencil::TunePlan) (its `Display`
    /// form) the row ran under — round-trippable via `TunePlan::parse`.
    pub plan: String,
}

/// One engine × RTM-step measurement (schema v2): a full propagator
/// timestep — derivative passes plus pointwise update — through the
/// engine dispatch layer.
#[derive(Clone, Debug)]
pub struct RtmBench {
    /// Canonical engine-kind name (`EngineKind::name`).
    pub engine: String,
    /// "vti" | "tti"
    pub medium: String,
    /// Cubic grid edge of the step.
    pub n: usize,
    /// Worker-parallelism of the step.
    pub threads: usize,
    /// Temporal-blocking depth of the measured call: 1 = one classic
    /// `step_with`, > 1 = a `step_k_with` fused call (throughput counts
    /// all `time_block · n³` updates).
    pub time_block: usize,
    /// Halo wire codec name the shot's subdomain shells were squeezed
    /// through ("f32" = lossless no-op).  Added in schema v7.
    pub halo_codec: String,
    /// Bytes on the simulated wire; 0 for single-rank shots.  Added in
    /// schema v7.
    pub transport_bytes: u64,
    /// Median cell-update throughput of one step, in millions/s.
    pub mcells_per_s: f64,
    /// Heap allocations during one post-warm-up step.
    pub allocs_per_step: u64,
    /// Scratch-arena growth events during the same step.
    pub arena_grows_per_step: u64,
    /// The active [`TunePlan`](crate::stencil::TunePlan) (its `Display`
    /// form) the step's derivative passes dispatched through.
    pub plan: String,
}

/// One survey measurement (added in schema v4, unchanged in v5 — shots
/// carry no single plan, each pump configures its own engine): a
/// multi-shot run through the
/// shot service ([`rtm::service`](crate::rtm::service)) — throughput in
/// shots/hour plus the scheduler's retry/failure accounting.
#[derive(Clone, Debug)]
pub struct SurveyBench {
    /// Canonical engine-kind name every shot propagated with.
    pub engine: String,
    /// "vti" | "tti"
    pub medium: String,
    /// Cubic grid edge of each shot.
    pub n: usize,
    /// Shots submitted to the survey.
    pub shots: usize,
    /// Simulated NUMA rank shards the queue was split across.
    pub shards: usize,
    /// Propagator worker-parallelism of each shot.
    pub threads: usize,
    /// Checkpoint strategy name (`CheckpointStrategy::name`).
    pub checkpoint: String,
    /// Retry attempts consumed across the survey.
    pub retries: u64,
    /// Shots recorded as failed after exhausting their retries.
    pub failed: u64,
    /// Faults the resilience subsystem injected across all attempts
    /// ([`SurveyReport::faults_injected`]
    /// (crate::rtm::service::SurveyReport::faults_injected)); 0 for the
    /// fault-free baseline.  Added in schema v8.
    pub faults_injected: u64,
    /// Shots adopted from a survey journal instead of re-run
    /// ([`SurveyReport::resumed_shots`]
    /// (crate::rtm::service::SurveyReport::resumed_shots)); 0 for a
    /// from-scratch run.  Added in schema v8.
    pub resumed_shots: u64,
    /// Completed-shot throughput.
    pub shots_per_hour: f64,
}

fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn finite(v: f64) -> f64 {
    if v.is_finite() {
        v
    } else {
        0.0
    }
}

/// Render the document.  Entries keep their push order, so re-runs of
/// the same probe diff cleanly.
pub fn render(
    entries: &[EngineBench],
    rtm_entries: &[RtmBench],
    survey_entries: &[SurveyBench],
) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!("  \"schema\": \"{SCHEMA}\",\n"));
    s.push_str("  \"entries\": [\n");
    for (i, e) in entries.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"engine\": \"{}\", \"pattern\": \"{}\", \"radius\": {}, \"n\": {}, \
             \"threads\": {}, \"time_block\": {}, \"tile\": {}, \"wf\": {}, \
             \"halo_codec\": \"{}\", \"transport_bytes\": {}, \
             \"mcells_per_s\": {:.3}, \
             \"allocs_per_sweep\": {}, \"arena_grows_per_sweep\": {}, \"plan\": \"{}\"}}{}\n",
            esc(&e.engine),
            esc(&e.pattern),
            e.radius,
            e.n,
            e.threads,
            e.time_block,
            e.tile,
            e.wf,
            esc(&e.halo_codec),
            e.transport_bytes,
            finite(e.mcells_per_s),
            e.allocs_per_sweep,
            e.arena_grows_per_sweep,
            esc(&e.plan),
            if i + 1 == entries.len() { "" } else { "," }
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"rtm_entries\": [\n");
    for (i, e) in rtm_entries.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"engine\": \"{}\", \"medium\": \"{}\", \"n\": {}, \"threads\": {}, \
             \"time_block\": {}, \"halo_codec\": \"{}\", \"transport_bytes\": {}, \
             \"mcells_per_s\": {:.3}, \"allocs_per_step\": {}, \
             \"arena_grows_per_step\": {}, \"plan\": \"{}\"}}{}\n",
            esc(&e.engine),
            esc(&e.medium),
            e.n,
            e.threads,
            e.time_block,
            esc(&e.halo_codec),
            e.transport_bytes,
            finite(e.mcells_per_s),
            e.allocs_per_step,
            e.arena_grows_per_step,
            esc(&e.plan),
            if i + 1 == rtm_entries.len() { "" } else { "," }
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"survey_entries\": [\n");
    for (i, e) in survey_entries.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"engine\": \"{}\", \"medium\": \"{}\", \"n\": {}, \"shots\": {}, \
             \"shards\": {}, \"threads\": {}, \"checkpoint\": \"{}\", \"retries\": {}, \
             \"failed\": {}, \"faults_injected\": {}, \"resumed_shots\": {}, \
             \"shots_per_hour\": {:.3}}}{}\n",
            esc(&e.engine),
            esc(&e.medium),
            e.n,
            e.shots,
            e.shards,
            e.threads,
            esc(&e.checkpoint),
            e.retries,
            e.failed,
            e.faults_injected,
            e.resumed_shots,
            finite(e.shots_per_hour),
            if i + 1 == survey_entries.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// Structural validation of a rendered document: schema tag, balanced
/// nesting, and every entry carrying its full key set.  Returns the
/// `(sweep, rtm, survey)` entry counts.  (CI additionally parses the
/// artifact with a real JSON parser; this keeps the contract testable
/// offline.)
pub fn validate(s: &str) -> Result<(usize, usize, usize), String> {
    if !s.contains(&format!("\"schema\": \"{SCHEMA}\"")) {
        return Err(format!("missing schema tag {SCHEMA}"));
    }
    let (mut brace, mut bracket) = (0i64, 0i64);
    for c in s.chars() {
        match c {
            '{' => brace += 1,
            '}' => brace -= 1,
            '[' => bracket += 1,
            ']' => bracket -= 1,
            _ => {}
        }
        if brace < 0 || bracket < 0 {
            return Err("unbalanced nesting".into());
        }
    }
    if brace != 0 || bracket != 0 {
        return Err("unbalanced nesting".into());
    }
    if !s.contains("\"rtm_entries\":") {
        return Err("missing rtm_entries array".into());
    }
    if !s.contains("\"survey_entries\":") {
        return Err("missing survey_entries array".into());
    }
    // sweep entries are the only rows with "pattern"; survey rows the
    // only ones with "checkpoint"; RTM and survey rows both carry
    // "medium"; shared keys must appear once per row of each family
    let sweeps = s.matches("\"pattern\":").count();
    let surveys = s.matches("\"checkpoint\":").count();
    let rtms = s
        .matches("\"medium\":")
        .count()
        .checked_sub(surveys)
        .ok_or("more checkpoint keys than medium keys")?;
    for k in [
        "\"radius\":",
        "\"tile\":",
        "\"wf\":",
        "\"allocs_per_sweep\":",
        "\"arena_grows_per_sweep\":",
    ] {
        if s.matches(k).count() != sweeps {
            return Err(format!("key {k} count mismatch (expected {sweeps})"));
        }
    }
    for k in ["\"allocs_per_step\":", "\"arena_grows_per_step\":"] {
        if s.matches(k).count() != rtms {
            return Err(format!("key {k} count mismatch (expected {rtms})"));
        }
    }
    for k in [
        "\"shots\":",
        "\"shards\":",
        "\"retries\":",
        "\"failed\":",
        "\"faults_injected\":",
        "\"resumed_shots\":",
        "\"shots_per_hour\":",
    ] {
        if s.matches(k).count() != surveys {
            return Err(format!("key {k} count mismatch (expected {surveys})"));
        }
    }
    for k in [
        "\"time_block\":",
        "\"halo_codec\":",
        "\"transport_bytes\":",
        "\"mcells_per_s\":",
        "\"plan\":",
    ] {
        if s.matches(k).count() != sweeps + rtms {
            return Err(format!("key {k} count mismatch (expected {})", sweeps + rtms));
        }
    }
    for k in ["\"engine\":", "\"n\":", "\"threads\":"] {
        if s.matches(k).count() != sweeps + rtms + surveys {
            return Err(format!(
                "key {k} count mismatch (expected {})",
                sweeps + rtms + surveys
            ));
        }
    }
    Ok((sweeps, rtms, surveys))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<EngineBench> {
        vec![
            EngineBench {
                engine: "simd".into(),
                pattern: "star".into(),
                radius: 4,
                n: 96,
                threads: 1,
                time_block: 1,
                tile: 0,
                wf: 1,
                halo_codec: "f32".into(),
                transport_bytes: 0,
                mcells_per_s: 123.456,
                allocs_per_sweep: 2,
                arena_grows_per_sweep: 0,
                plan: "engine=simd vl=16 vz=4 tb=1 threads=1 tile=0 wf=1 halo=f32".into(),
            },
            EngineBench {
                engine: "matrix_unit_par".into(),
                pattern: "box".into(),
                radius: 1,
                n: 96,
                threads: 8,
                time_block: 4,
                tile: 16,
                wf: 2,
                halo_codec: "bf16".into(),
                transport_bytes: 1_048_576,
                mcells_per_s: 77.0,
                allocs_per_sweep: 31,
                arena_grows_per_sweep: 0,
                plan: "engine=matrix_unit vl=16 vz=4 tb=4 threads=8 tile=16 wf=2 halo=bf16"
                    .into(),
            },
        ]
    }

    fn rtm_sample() -> Vec<RtmBench> {
        vec![RtmBench {
            engine: "matrix_unit".into(),
            medium: "vti".into(),
            n: 96,
            threads: 8,
            time_block: 1,
            halo_codec: "f32".into(),
            transport_bytes: 0,
            mcells_per_s: 450.5,
            allocs_per_step: 12,
            arena_grows_per_step: 0,
            plan: "engine=matrix_unit vl=16 vz=4 tb=1 threads=8 tile=0 wf=1 halo=f32".into(),
        }]
    }

    fn survey_sample() -> Vec<SurveyBench> {
        vec![SurveyBench {
            engine: "matrix_unit".into(),
            medium: "tti".into(),
            n: 24,
            shots: 4,
            shards: 2,
            threads: 2,
            checkpoint: "boundary_saving".into(),
            retries: 1,
            failed: 0,
            faults_injected: 0,
            resumed_shots: 0,
            shots_per_hour: 1234.5,
        }]
    }

    #[test]
    fn render_validates() {
        let doc = render(&sample(), &rtm_sample(), &survey_sample());
        assert_eq!(validate(&doc), Ok((2, 1, 1)));
        assert!(doc.contains("\"schema\": \"mmstencil.bench_engines.v8\""));
        assert!(doc.contains("\"mcells_per_s\": 123.456"));
        assert!(doc.contains("\"medium\": \"vti\""));
        assert!(doc.contains("\"allocs_per_step\": 12"));
        assert!(doc.contains("\"time_block\": 4"));
        // v6: sweep rows carry the wavefront tile geometry
        assert!(doc.contains("\"tile\": 0, \"wf\": 1"));
        assert!(doc.contains("\"tile\": 16, \"wf\": 2"));
        // v7: sweep + RTM rows carry the wire codec and its byte count
        assert!(doc.contains("\"halo_codec\": \"bf16\", \"transport_bytes\": 1048576"));
        assert!(doc.contains("\"halo_codec\": \"f32\", \"transport_bytes\": 0"));
        assert!(doc.contains("\"checkpoint\": \"boundary_saving\""));
        assert!(doc.contains("\"shots_per_hour\": 1234.500"));
        // v8: survey rows carry the chaos accounting, zero at baseline
        assert!(doc.contains("\"faults_injected\": 0, \"resumed_shots\": 0"));
        assert!(doc.contains(
            "\"plan\": \"engine=matrix_unit vl=16 vz=4 tb=4 threads=8 tile=16 wf=2 halo=bf16\""
        ));
        // every recorded plan string round-trips through the parser
        use crate::stencil::TunePlan;
        for row in doc.lines().filter(|l| l.contains("\"plan\":")) {
            let s = row.split("\"plan\": \"").nth(1).unwrap().split('"').next().unwrap();
            let plan = TunePlan::parse(s).expect("recorded plan must parse");
            assert_eq!(plan.to_string(), s);
        }
    }

    #[test]
    fn empty_document_is_valid_with_zero_entries() {
        assert_eq!(validate(&render(&[], &[], &[])), Ok((0, 0, 0)));
    }

    #[test]
    fn tampered_documents_fail() {
        let doc = render(&sample(), &rtm_sample(), &survey_sample());
        assert!(validate(&doc.replace("bench_engines.v8", "v7")).is_err());
        assert!(validate(&doc.replacen("\"faults_injected\":", "\"faults\":", 1)).is_err());
        assert!(validate(&doc.replacen("\"resumed_shots\":", "\"resumed\":", 1)).is_err());
        assert!(validate(&doc.replacen("\"plan\":", "\"p\":", 1)).is_err());
        assert!(validate(&doc.replace("\"radius\":", "\"r\":")).is_err());
        assert!(validate(&doc.replace("\"tile\":", "\"t\":")).is_err());
        assert!(validate(&doc.replacen("\"halo_codec\":", "\"codec\":", 1)).is_err());
        assert!(validate(&doc.replacen("\"transport_bytes\":", "\"bytes\":", 1)).is_err());
        assert!(validate(&doc.replacen("\"wf\":", "\"w\":", 1)).is_err());
        assert!(validate(&doc.replace("\"allocs_per_step\":", "\"a\":")).is_err());
        assert!(validate(&doc.replace("\"rtm_entries\":", "\"rtm\":")).is_err());
        assert!(validate(&doc.replace("\"survey_entries\":", "\"surveys\":")).is_err());
        assert!(validate(&doc.replace("\"shots_per_hour\":", "\"sph\":")).is_err());
        // dropping the survey row's medium key makes the rtm count
        // arithmetic impossible, not silently wrong
        assert!(validate(&doc.replace("\"medium\": \"tti\"", "\"med\": \"tti\"")).is_err());
        assert!(validate(&doc.replacen("\"time_block\":", "\"tb\":", 1)).is_err());
        assert!(validate(doc.trim_end().trim_end_matches('}')).is_err());
    }

    #[test]
    fn non_finite_throughput_is_clamped() {
        let mut e = sample();
        e[0].mcells_per_s = f64::INFINITY;
        let doc = render(&e, &[], &[]);
        assert!(validate(&doc).is_ok());
        assert!(doc.contains("\"mcells_per_s\": 0.000"));
    }
}
