//! Pseudo-acoustic VTI leapfrog propagator (paper §II-A, §V-F).
//!
//! Semantics mirror `python/compile/kernels/ref.py::vti_step` exactly
//! (periodic boundaries, Duveneck–Bakker/Zhou coupling — see DESIGN.md
//! §Substitutions for why the paper's printed z-branch is replaced):
//!
//! ```text
//! d²σH/dt² = Vp²{ (1+2ε)(∂xx σH + ∂yy σH) + √(1+2δ) ∂zz σV }
//! d²σV/dt² = Vp²{ √(1+2δ)(∂xx σH + ∂yy σH) + ∂zz σV }
//! ```
//!
//! The derivative passes are decomposed into 1D axis stencils — exactly
//! the §IV-G scheme the block artifacts (`rtm_vti_block.hlo.txt`)
//! implement — and dispatched through the engine layer
//! ([`stencil::engine`](crate::stencil::engine), DESIGN.md §10):
//! [`step_with`] fans each pass as fixed z-slab claims over the
//! persistent worker runtime through any [`Engine`] (simd, matrix-unit,
//! or the naive scalar oracle the engines are checked against), and the
//! pointwise leapfrog stages run through the pool's `ParSlice`-backed
//! chunk helpers — no raw-pointer sharing, O(1) allocations per step
//! after warm-up (`rust/tests/alloc_free.rs`).

use super::media::VtiMedia;
use crate::coordinator::pool;
use crate::grid::Grid3;
use crate::stencil::engine::AxisPass;
use crate::stencil::{Engine, TunePlan};

/// The two leapfrog time levels of both stress components.
pub struct VtiState {
    /// Horizontal stress σH, current time level.
    pub sh: Grid3,
    /// Vertical stress σV, current time level.
    pub sv: Grid3,
    /// σH one step back (overwritten with the next level each step).
    pub sh_prev: Grid3,
    /// σV one step back (overwritten with the next level each step).
    pub sv_prev: Grid3,
}

impl VtiState {
    /// All-zero wavefields of the given shape.
    pub fn zeros(nz: usize, nx: usize, ny: usize) -> Self {
        Self {
            sh: Grid3::zeros(nz, nx, ny),
            sv: Grid3::zeros(nz, nx, ny),
            sh_prev: Grid3::zeros(nz, nx, ny),
            sv_prev: Grid3::zeros(nz, nx, ny),
        }
    }

    /// Add a point source sample to both stress components.
    pub fn inject(&mut self, z: usize, x: usize, y: usize, amp: f32) {
        let i = self.sh.idx(z, x, y);
        self.sh.data[i] += amp;
        self.sv.data[i] += amp;
    }

    /// Total wavefield energy (sum of squares of both components).
    pub fn energy(&self) -> f64 {
        self.sh.energy() + self.sv.energy()
    }
}

/// Second derivative along `axis` (0 = z, 1 = x, 2 = y) with periodic
/// wrap — mirror of `ref.py::d2_axis`, routed through the simd engine's
/// axis kernel (z-slabs fanned over the persistent runtime).
pub fn d2_axis(g: &Grid3, w2: &[f32], axis: usize, threads: usize) -> Grid3 {
    let mut out = Grid3::zeros(g.nz, g.nx, g.ny);
    d2_axis_into(g, w2, axis, &mut out, threads);
    out
}

/// In-place variant of [`d2_axis`]: `out` is fully overwritten.
pub fn d2_axis_into(g: &Grid3, w2: &[f32], axis: usize, out: &mut Grid3, threads: usize) {
    Engine::from_plan(&TunePlan::simd(threads)).d2_axis_into(g, w2, axis, out);
}

/// First derivative along `axis` with periodic wrap (antisymmetric
/// band) — mirror of `ref.py::d1_axis`, engine-routed like [`d2_axis`].
pub fn d1_axis(g: &Grid3, w1: &[f32], axis: usize, threads: usize) -> Grid3 {
    let mut out = Grid3::zeros(g.nz, g.nx, g.ny);
    d1_axis_into(g, w1, axis, &mut out, threads);
    out
}

/// In-place variant of [`d1_axis`]: `out` is fully overwritten.
pub fn d1_axis_into(g: &Grid3, w1: &[f32], axis: usize, out: &mut Grid3, threads: usize) {
    Engine::from_plan(&TunePlan::simd(threads)).d1_axis_into(g, w1, axis, out);
}

/// Scratch buffers reused across steps (avoids per-step allocation of
/// three whole-grid temporaries — see EXPERIMENTS.md §Perf).
pub struct VtiScratch {
    lap: Grid3,
    tmp: Grid3,
    dzz: Grid3,
}

impl VtiScratch {
    /// Scratch sized for `(nz, nx, ny)` wavefields.
    pub fn new(nz: usize, nx: usize, ny: usize) -> Self {
        Self {
            lap: Grid3::zeros(nz, nx, ny),
            tmp: Grid3::zeros(nz, nx, ny),
            dzz: Grid3::zeros(nz, nx, ny),
        }
    }
}

/// One leapfrog step through the default simd engine; rotates `state`
/// in place.  Compatibility wrapper over [`step_with`].
pub fn step(state: &mut VtiState, m: &VtiMedia, w2: &[f32], threads: usize, s: &mut VtiScratch) {
    step_with(state, m, w2, &Engine::from_plan(&TunePlan::simd(threads)), s);
}

/// One leapfrog step through an explicit [`Engine`]; rotates `state` in
/// place.  The three derivative passes fan fixed z-slab claims over the
/// persistent runtime via the engine's axis kernels (bitwise-stable for
/// any `eng.threads`); the pointwise coupling/leapfrog stages run
/// through the pool chunk helpers.  Allocation-free after warm-up up to
/// a per-step constant (`rust/tests/alloc_free.rs`).
pub fn step_with(state: &mut VtiState, m: &VtiMedia, w2: &[f32], eng: &Engine, s: &mut VtiScratch) {
    // decaying wavefields hit the x86 denormal cliff without FTZ
    crate::util::enable_flush_to_zero();
    let (nz, nx, ny) = state.sh.shape();
    assert_eq!(m.vp2dt2.shape(), (nz, nx, ny));
    let threads = eng.threads;

    // xy-laplacian of σH and ∂zz of σV as 1D axis passes — the three
    // passes are independent, so they run as one batched dispatch (one
    // runtime barrier instead of three; bitwise the sequential calls)
    let mut passes = [
        AxisPass { src: &state.sh, band: w2, axis: 1, out: &mut s.lap },
        AxisPass { src: &state.sh, band: w2, axis: 2, out: &mut s.tmp },
        AxisPass { src: &state.sv, band: w2, axis: 0, out: &mut s.dzz },
    ];
    eng.band_axes_into(&mut passes);
    {
        let lap = &mut s.lap.data;
        let tmp = &s.tmp.data;
        pool::parallel_mut_chunks(threads, lap, |off, chunk| {
            for (i, v) in chunk.iter_mut().enumerate() {
                *v += tmp[off + i];
            }
        });
    }

    // pointwise leapfrog update; prev arrays become the new time level
    let lap = &s.lap.data;
    let dzz = &s.dzz.data;
    let sh = &state.sh.data;
    let sv = &state.sv.data;
    let v2 = &m.vp2dt2.data;
    let eps = &m.eps.data;
    let del = &m.delta.data;
    {
        let shp = &mut state.sh_prev.data;
        pool::parallel_mut_chunks(threads, shp, |off, chunk| {
            for (i, out) in chunk.iter_mut().enumerate() {
                let j = off + i;
                let sq = (1.0 + 2.0 * del[j]).sqrt();
                let rhs = (1.0 + 2.0 * eps[j]) * lap[j] + sq * dzz[j];
                *out = 2.0 * sh[j] - *out + v2[j] * rhs;
            }
        });
    }
    {
        let svp = &mut state.sv_prev.data;
        pool::parallel_mut_chunks(threads, svp, |off, chunk| {
            for (i, out) in chunk.iter_mut().enumerate() {
                let j = off + i;
                let sq = (1.0 + 2.0 * del[j]).sqrt();
                let rhs = sq * lap[j] + dzz[j];
                *out = 2.0 * sv[j] - *out + v2[j] * rhs;
            }
        });
    }
    std::mem::swap(&mut state.sh, &mut state.sh_prev);
    std::mem::swap(&mut state.sv, &mut state.sv_prev);
}

/// `k` fused leapfrog steps through an explicit [`Engine`] — the
/// `[runtime] time_block` consumer for **boundary-free** (periodic)
/// propagation: the scratch grids and both time levels stay hot across
/// the fused sub-steps and no per-step host work intervenes.  Bitwise
/// identical to `k` calls of [`step_with`] for any `k`, engine, and
/// worker count (`rust/tests/temporal.rs`).
///
/// Imaging shots cannot use `k > 1`: the sponge boundary, source
/// injection, and receiver recording are per-step operations, which is
/// exactly the paper's §III-B point that boundary handling constrains
/// the depth of temporal blocking — see
/// [`RtmConfig::time_block`](super::driver::RtmConfig::time_block) and
/// DESIGN.md §11.
pub fn step_k_with(
    state: &mut VtiState,
    m: &VtiMedia,
    w2: &[f32],
    eng: &Engine,
    s: &mut VtiScratch,
    k: usize,
) {
    for _ in 0..k.max(1) {
        step_with(state, m, w2, eng, s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rtm::fixtures::{self, PAR_WORKERS, WORKER_COUNTS};
    use crate::stencil::coeffs::second_deriv;
    use crate::stencil::EngineKind;
    use crate::util::prop::assert_allclose;

    fn planned(kind: EngineKind, workers: usize) -> Engine {
        Engine::from_plan(&TunePlan { engine: kind, threads: workers, ..TunePlan::simd(1) })
    }

    #[test]
    fn d2_axis_matches_direct_loop() {
        let g = Grid3::random(6, 7, 9, 11);
        let w2 = second_deriv(3);
        let r = 3isize;
        for axis in 0..3 {
            let got = d2_axis(&g, &w2, axis, PAR_WORKERS);
            let want = Grid3::from_fn(6, 7, 9, |z, x, y| {
                let mut acc = 0.0;
                for k in -r..=r {
                    let (mut zz, mut xx, mut yy) = (z as isize, x as isize, y as isize);
                    match axis {
                        0 => zz += k,
                        1 => xx += k,
                        _ => yy += k,
                    }
                    acc += w2[(k + r) as usize] * g.get_wrap(zz, xx, yy);
                }
                acc
            });
            assert_allclose(&got.data, &want.data, 1e-5, 1e-6);
        }
    }

    #[test]
    fn d1_axis_matches_direct_loop() {
        let g = Grid3::random(5, 8, 6, 13);
        let w1 = crate::stencil::coeffs::first_deriv(4);
        let r = 4isize;
        for axis in 0..3 {
            let got = d1_axis(&g, &w1, axis, PAR_WORKERS);
            let want = Grid3::from_fn(5, 8, 6, |z, x, y| {
                let mut acc = 0.0;
                for k in -r..=r {
                    let (mut zz, mut xx, mut yy) = (z as isize, x as isize, y as isize);
                    match axis {
                        0 => zz += k,
                        1 => xx += k,
                        _ => yy += k,
                    }
                    acc += w1[(k + r) as usize] * g.get_wrap(zz, xx, yy);
                }
                acc
            });
            assert_allclose(&got.data, &want.data, 1e-5, 1e-6);
        }
    }

    #[test]
    fn d2_of_cosine_has_right_eigenvalue() {
        let n = 32;
        let g = fixtures::cosine_grid(n);
        let w2 = second_deriv(4);
        let d = d2_axis(&g, &w2, 0, PAR_WORKERS);
        let lam = -(2.0 * std::f32::consts::PI / n as f32).powi(2);
        for (got, f) in d.data.iter().zip(&g.data) {
            assert!((got - lam * f).abs() < 1e-4, "{got} vs {}", lam * f);
        }
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let g = Grid3::random(8, 8, 8, 17);
        let w2 = second_deriv(2);
        let a = d2_axis(&g, &w2, 1, WORKER_COUNTS[0]);
        for &workers in &WORKER_COUNTS[1..] {
            let b = d2_axis(&g, &w2, 1, workers);
            assert_eq!(a.data, b.data, "workers={workers}");
        }
    }

    #[test]
    fn impulse_stays_bounded_many_steps() {
        let (nz, nx, ny) = (24, 24, 24);
        let m = fixtures::vti_media(nz, nx, ny);
        let mut st = VtiState::zeros(nz, nx, ny);
        let mut sc = VtiScratch::new(nz, nx, ny);
        st.inject(12, 12, 12, 1.0);
        let w2 = second_deriv(4);
        for _ in 0..200 {
            step(&mut st, &m, &w2, PAR_WORKERS, &mut sc);
        }
        let e = st.energy();
        assert!(e.is_finite() && e < 1e6, "unstable: energy {e}");
    }

    #[test]
    fn wave_spreads_from_source() {
        let (nz, nx, ny) = (32, 32, 32);
        let m = fixtures::vti_media(nz, nx, ny);
        let mut st = VtiState::zeros(nz, nx, ny);
        let mut sc = VtiScratch::new(nz, nx, ny);
        let w2 = second_deriv(4);
        for i in 0..40 {
            st.inject(16, 16, 16, super::super::wavelet::ricker(i as f64 * m.dt, 15.0));
            step(&mut st, &m, &w2, PAR_WORKERS, &mut sc);
        }
        // energy must have propagated away from the source cell
        let far = st.sh.get(16, 16, 26).abs() + st.sh.get(26, 16, 16).abs();
        assert!(far > 0.0, "no propagation");
        assert!(st.energy() > 0.0);
    }

    #[test]
    fn every_engine_step_matches_the_naive_oracle() {
        // the engine-equivalence contract of the RTM rework: a few VTI
        // steps through each engine agree with the scalar oracle in
        // energy and pointwise within 1e-4 relative tolerance
        let (nz, nx, ny) = (18, 20, 22);
        let m = fixtures::vti_media(nz, nx, ny);
        let w2 = second_deriv(4);
        let run = |eng: &Engine| {
            let mut st = VtiState::zeros(nz, nx, ny);
            let mut sc = VtiScratch::new(nz, nx, ny);
            st.inject(9, 10, 11, 1.0);
            for _ in 0..6 {
                step_with(&mut st, &m, &w2, eng, &mut sc);
            }
            st
        };
        let oracle = run(&Engine::new(EngineKind::Naive));
        for kind in [EngineKind::Simd, EngineKind::MatrixUnit, EngineKind::MatrixGemm] {
            for &workers in &WORKER_COUNTS {
                let got = run(&planned(kind, workers));
                assert_allclose(&got.sh.data, &oracle.sh.data, 1e-4, 1e-6);
                assert_allclose(&got.sv.data, &oracle.sv.data, 1e-4, 1e-6);
                let (e, eo) = (got.energy(), oracle.energy());
                assert!(
                    (e / eo - 1.0).abs() < 1e-4,
                    "{kind:?} workers={workers}: energy {e} vs oracle {eo}"
                );
            }
        }
    }

    #[test]
    fn fused_steps_are_bitwise_the_stepped_loop() {
        // step_k_with(k) == k × step_with, bit for bit, per engine and
        // worker count — the RTM half of the time_block contract
        let (nz, nx, ny) = (14, 16, 18);
        let m = fixtures::vti_media(nz, nx, ny);
        let w2 = second_deriv(4);
        for kind in EngineKind::ALL {
            for &workers in &WORKER_COUNTS {
                let eng = planned(kind, workers);
                let mk = || {
                    let mut st = VtiState::zeros(nz, nx, ny);
                    st.inject(7, 8, 9, 1.0);
                    st
                };
                for k in [1usize, 2, 4] {
                    let mut fused = mk();
                    let mut sc = VtiScratch::new(nz, nx, ny);
                    step_k_with(&mut fused, &m, &w2, &eng, &mut sc, k);
                    let mut looped = mk();
                    let mut sc2 = VtiScratch::new(nz, nx, ny);
                    for _ in 0..k {
                        step_with(&mut looped, &m, &w2, &eng, &mut sc2);
                    }
                    assert_eq!(fused.sh.data, looped.sh.data, "{kind:?} w={workers} k={k}");
                    assert_eq!(fused.sv.data, looped.sv.data, "{kind:?} w={workers} k={k}");
                }
            }
        }
    }

    #[test]
    fn matrix_unit_step_is_bitwise_stable_across_workers() {
        let (nz, nx, ny) = (16, 18, 20);
        let m = fixtures::vti_media(nz, nx, ny);
        let w2 = second_deriv(4);
        let run = |workers: usize| {
            let mut st = VtiState::zeros(nz, nx, ny);
            let mut sc = VtiScratch::new(nz, nx, ny);
            st.inject(8, 9, 10, 1.0);
            let eng = planned(EngineKind::MatrixUnit, workers);
            for _ in 0..4 {
                step_with(&mut st, &m, &w2, &eng, &mut sc);
            }
            st
        };
        let want = run(WORKER_COUNTS[0]);
        for &workers in &WORKER_COUNTS[1..] {
            let got = run(workers);
            assert_eq!(got.sh.data, want.sh.data, "workers={workers}");
            assert_eq!(got.sv.data, want.sv.data, "workers={workers}");
        }
    }
}
