//! Pseudo-acoustic VTI leapfrog propagator (paper §II-A, §V-F).
//!
//! Semantics mirror `python/compile/kernels/ref.py::vti_step` exactly
//! (periodic boundaries, Duveneck–Bakker/Zhou coupling — see DESIGN.md
//! §Substitutions for why the paper's printed z-branch is replaced):
//!
//! ```text
//! d²σH/dt² = Vp²{ (1+2ε)(∂xx σH + ∂yy σH) + √(1+2δ) ∂zz σV }
//! d²σV/dt² = Vp²{ √(1+2δ)(∂xx σH + ∂yy σH) + ∂zz σV }
//! ```
//!
//! The derivative passes are decomposed into 1D axis stencils — exactly
//! the §IV-G scheme the block artifacts (`rtm_vti_block.hlo.txt`)
//! implement — and parallelized over z-slabs with the coordinator pool.
//! Each slab task claims its output plane as an exclusive
//! `TileViewMut`, and the pointwise stages run through the pool's
//! `ParSlice`-backed chunk helpers — no raw-pointer sharing.

use super::media::VtiMedia;
use crate::coordinator::pool;
use crate::grid::par::ParGrid3;
use crate::grid::Grid3;

/// The two leapfrog time levels of both stress components.
pub struct VtiState {
    pub sh: Grid3,
    pub sv: Grid3,
    pub sh_prev: Grid3,
    pub sv_prev: Grid3,
}

impl VtiState {
    pub fn zeros(nz: usize, nx: usize, ny: usize) -> Self {
        Self {
            sh: Grid3::zeros(nz, nx, ny),
            sv: Grid3::zeros(nz, nx, ny),
            sh_prev: Grid3::zeros(nz, nx, ny),
            sv_prev: Grid3::zeros(nz, nx, ny),
        }
    }

    /// Add a point source sample to both stress components.
    pub fn inject(&mut self, z: usize, x: usize, y: usize, amp: f32) {
        let i = self.sh.idx(z, x, y);
        self.sh.data[i] += amp;
        self.sv.data[i] += amp;
    }

    pub fn energy(&self) -> f64 {
        self.sh.energy() + self.sv.energy()
    }
}

/// Second derivative along `axis` (0 = z, 1 = x, 2 = y) with periodic
/// wrap — mirror of `ref.py::d2_axis`.  Parallel over z-slabs.
pub fn d2_axis(g: &Grid3, w2: &[f32], axis: usize, threads: usize) -> Grid3 {
    let mut out = Grid3::zeros(g.nz, g.nx, g.ny);
    d2_axis_into(g, w2, axis, &mut out, threads);
    out
}

/// In-place variant of [`d2_axis`]: `out` is fully overwritten.
pub fn d2_axis_into(g: &Grid3, w2: &[f32], axis: usize, out: &mut Grid3, threads: usize) {
    assert_eq!(g.shape(), out.shape());
    let r = (w2.len() - 1) / 2;
    let (nz, nx, ny) = g.shape();
    let plane = nx * ny;
    let pg = ParGrid3::new(out);
    let pg = &pg;
    match axis {
        0 => {
            // z: per output slab, accumulate whole shifted planes
            pool::parallel_for(threads, nz, |z| {
                let mut view = pg.view(z, z + 1, 0, nx, 0, ny);
                let dst = view.as_mut_slice();
                dst.copy_from_slice(&g.data[z * plane..(z + 1) * plane]);
                for v in dst.iter_mut() {
                    *v *= w2[r];
                }
                for k in 1..=r {
                    let zp = (z + k) % nz;
                    let zm = (z + nz - k) % nz;
                    let a = &g.data[zp * plane..(zp + 1) * plane];
                    let b = &g.data[zm * plane..(zm + 1) * plane];
                    let w = w2[r + k];
                    for ((d, &p), &m) in dst.iter_mut().zip(a).zip(b) {
                        *d += w * (p + m);
                    }
                }
            });
        }
        1 => {
            // x: per z-slab, accumulate shifted y-rows
            pool::parallel_for(threads, nz, |z| {
                let base = z * plane;
                let mut view = pg.view(z, z + 1, 0, nx, 0, ny);
                let dst = view.as_mut_slice();
                for x in 0..nx {
                    let row = &mut dst[x * ny..(x + 1) * ny];
                    let src = &g.data[base + x * ny..base + (x + 1) * ny];
                    for (d, &s) in row.iter_mut().zip(src) {
                        *d = w2[r] * s;
                    }
                    for k in 1..=r {
                        let xp = (x + k) % nx;
                        let xm = (x + nx - k) % nx;
                        let a = &g.data[base + xp * ny..base + xp * ny + ny];
                        let b = &g.data[base + xm * ny..base + xm * ny + ny];
                        let w = w2[r + k];
                        for ((d, &p), &m) in row.iter_mut().zip(a).zip(b) {
                            *d += w * (p + m);
                        }
                    }
                }
            });
        }
        2 => {
            // y: contiguous rows; vectorizable shifted-slice interior,
            // wrapped scalar edges
            pool::parallel_for(threads, nz, |z| {
                let base = z * plane;
                let mut view = pg.view(z, z + 1, 0, nx, 0, ny);
                let dst = view.as_mut_slice();
                for x in 0..nx {
                    let row = &mut dst[x * ny..(x + 1) * ny];
                    let src = &g.data[base + x * ny..base + (x + 1) * ny];
                    if ny >= 2 * r + 1 {
                        // interior: row[y] = Σ w2[k+r]·src[y+k], y ∈ [r, ny-r)
                        let inner = ny - 2 * r;
                        for (d, &s) in row[r..r + inner].iter_mut().zip(&src[r..r + inner]) {
                            *d = w2[r] * s;
                        }
                        for k in 1..=r {
                            let w = w2[r + k];
                            let (p, m) = (&src[r + k..r + k + inner], &src[r - k..r - k + inner]);
                            for ((d, &a), &b) in row[r..r + inner].iter_mut().zip(p).zip(m) {
                                *d += w * (a + b);
                            }
                        }
                        // wrapped edges
                        for y in (0..r).chain(ny - r..ny) {
                            let mut acc = w2[r] * src[y];
                            for k in 1..=r {
                                acc += w2[r + k] * (src[(y + k) % ny] + src[(y + ny - k) % ny]);
                            }
                            row[y] = acc;
                        }
                    } else {
                        for y in 0..ny {
                            let mut acc = w2[r] * src[y];
                            for k in 1..=r {
                                acc += w2[r + k] * (src[(y + k) % ny] + src[(y + ny - k) % ny]);
                            }
                            row[y] = acc;
                        }
                    }
                }
            });
        }
        _ => panic!("axis must be 0, 1, or 2"),
    }
}

/// First derivative along `axis` with periodic wrap (antisymmetric
/// band) — mirror of `ref.py::d1_axis`.
pub fn d1_axis(g: &Grid3, w1: &[f32], axis: usize, threads: usize) -> Grid3 {
    let mut out = Grid3::zeros(g.nz, g.nx, g.ny);
    d1_axis_into(g, w1, axis, &mut out, threads);
    out
}

/// In-place variant of [`d1_axis`]: `out` is fully overwritten.
pub fn d1_axis_into(g: &Grid3, w1: &[f32], axis: usize, out: &mut Grid3, threads: usize) {
    assert_eq!(g.shape(), out.shape());
    let r = (w1.len() - 1) / 2;
    let (nz, nx, ny) = g.shape();
    let plane = nx * ny;
    let pg = ParGrid3::new(out);
    let pg = &pg;
    match axis {
        0 => {
            pool::parallel_for(threads, nz, |z| {
                let mut view = pg.view(z, z + 1, 0, nx, 0, ny);
                let dst = view.as_mut_slice();
                dst.fill(0.0);
                for k in 1..=r {
                    let zp = (z + k) % nz;
                    let zm = (z + nz - k) % nz;
                    let a = &g.data[zp * plane..(zp + 1) * plane];
                    let b = &g.data[zm * plane..(zm + 1) * plane];
                    let w = w1[r + k];
                    for ((d, &p), &m) in dst.iter_mut().zip(a).zip(b) {
                        *d += w * (p - m);
                    }
                }
            });
        }
        1 => {
            pool::parallel_for(threads, nz, |z| {
                let base = z * plane;
                let mut view = pg.view(z, z + 1, 0, nx, 0, ny);
                let dst = view.as_mut_slice();
                for x in 0..nx {
                    let row = &mut dst[x * ny..(x + 1) * ny];
                    row.fill(0.0);
                    for k in 1..=r {
                        let xp = (x + k) % nx;
                        let xm = (x + nx - k) % nx;
                        let a = &g.data[base + xp * ny..base + xp * ny + ny];
                        let b = &g.data[base + xm * ny..base + xm * ny + ny];
                        let w = w1[r + k];
                        for ((d, &p), &m) in row.iter_mut().zip(a).zip(b) {
                            *d += w * (p - m);
                        }
                    }
                }
            });
        }
        2 => {
            pool::parallel_for(threads, nz, |z| {
                let base = z * plane;
                let mut view = pg.view(z, z + 1, 0, nx, 0, ny);
                let dst = view.as_mut_slice();
                for x in 0..nx {
                    let row = &mut dst[x * ny..(x + 1) * ny];
                    let src = &g.data[base + x * ny..base + (x + 1) * ny];
                    if ny >= 2 * r + 1 {
                        let inner = ny - 2 * r;
                        row[r..r + inner].fill(0.0);
                        for k in 1..=r {
                            let w = w1[r + k];
                            let (p, m) = (&src[r + k..r + k + inner], &src[r - k..r - k + inner]);
                            for ((d, &a), &b) in row[r..r + inner].iter_mut().zip(p).zip(m) {
                                *d += w * (a - b);
                            }
                        }
                        for y in (0..r).chain(ny - r..ny) {
                            let mut acc = 0.0f32;
                            for k in 1..=r {
                                acc += w1[r + k] * (src[(y + k) % ny] - src[(y + ny - k) % ny]);
                            }
                            row[y] = acc;
                        }
                    } else {
                        for y in 0..ny {
                            let mut acc = 0.0f32;
                            for k in 1..=r {
                                acc += w1[r + k] * (src[(y + k) % ny] - src[(y + ny - k) % ny]);
                            }
                            row[y] = acc;
                        }
                    }
                }
            });
        }
        _ => panic!("axis must be 0, 1, or 2"),
    }
}

/// Scratch buffers reused across steps (avoids per-step allocation of
/// three whole-grid temporaries — see EXPERIMENTS.md §Perf).
pub struct VtiScratch {
    lap: Grid3,
    tmp: Grid3,
    dzz: Grid3,
}

impl VtiScratch {
    pub fn new(nz: usize, nx: usize, ny: usize) -> Self {
        Self {
            lap: Grid3::zeros(nz, nx, ny),
            tmp: Grid3::zeros(nz, nx, ny),
            dzz: Grid3::zeros(nz, nx, ny),
        }
    }
}

/// One leapfrog step; rotates `state` in place.
pub fn step(state: &mut VtiState, m: &VtiMedia, w2: &[f32], threads: usize, s: &mut VtiScratch) {
    // decaying wavefields hit the x86 denormal cliff without FTZ
    crate::util::enable_flush_to_zero();
    let (nz, nx, ny) = state.sh.shape();
    assert_eq!(m.vp2dt2.shape(), (nz, nx, ny));

    // xy-laplacian of σH and ∂zz of σV, each as 1D axis passes
    d2_axis_into(&state.sh, w2, 1, &mut s.lap, threads);
    d2_axis_into(&state.sh, w2, 2, &mut s.tmp, threads);
    d2_axis_into(&state.sv, w2, 0, &mut s.dzz, threads);
    {
        let lap = &mut s.lap.data;
        let tmp = &s.tmp.data;
        pool::parallel_mut_chunks(threads, lap, |off, chunk| {
            for (i, v) in chunk.iter_mut().enumerate() {
                *v += tmp[off + i];
            }
        });
    }

    // pointwise leapfrog update; prev arrays become the new time level
    let lap = &s.lap.data;
    let dzz = &s.dzz.data;
    let sh = &state.sh.data;
    let sv = &state.sv.data;
    let v2 = &m.vp2dt2.data;
    let eps = &m.eps.data;
    let del = &m.delta.data;
    {
        let shp = &mut state.sh_prev.data;
        pool::parallel_mut_chunks(threads, shp, |off, chunk| {
            for (i, out) in chunk.iter_mut().enumerate() {
                let j = off + i;
                let sq = (1.0 + 2.0 * del[j]).sqrt();
                let rhs = (1.0 + 2.0 * eps[j]) * lap[j] + sq * dzz[j];
                *out = 2.0 * sh[j] - *out + v2[j] * rhs;
            }
        });
    }
    {
        let svp = &mut state.sv_prev.data;
        pool::parallel_mut_chunks(threads, svp, |off, chunk| {
            for (i, out) in chunk.iter_mut().enumerate() {
                let j = off + i;
                let sq = (1.0 + 2.0 * del[j]).sqrt();
                let rhs = sq * lap[j] + dzz[j];
                *out = 2.0 * sv[j] - *out + v2[j] * rhs;
            }
        });
    }
    std::mem::swap(&mut state.sh, &mut state.sh_prev);
    std::mem::swap(&mut state.sv, &mut state.sv_prev);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rtm::media;
    use crate::stencil::coeffs::second_deriv;
    use crate::util::prop::assert_allclose;

    fn quadratic_grid(n: usize) -> Grid3 {
        // f = cos(2πz/n): d2/dz2 with the exact band ≈ -(2π/n)² f
        Grid3::from_fn(n, n, n, |z, _, _| {
            (2.0 * std::f32::consts::PI * z as f32 / n as f32).cos()
        })
    }

    #[test]
    fn d2_axis_matches_direct_loop() {
        let g = Grid3::random(6, 7, 9, 11);
        let w2 = second_deriv(3);
        let r = 3isize;
        for axis in 0..3 {
            let got = d2_axis(&g, &w2, axis, 3);
            let want = Grid3::from_fn(6, 7, 9, |z, x, y| {
                let mut acc = 0.0;
                for k in -r..=r {
                    let (mut zz, mut xx, mut yy) = (z as isize, x as isize, y as isize);
                    match axis {
                        0 => zz += k,
                        1 => xx += k,
                        _ => yy += k,
                    }
                    acc += w2[(k + r) as usize] * g.get_wrap(zz, xx, yy);
                }
                acc
            });
            assert_allclose(&got.data, &want.data, 1e-5, 1e-6);
        }
    }

    #[test]
    fn d1_axis_matches_direct_loop() {
        let g = Grid3::random(5, 8, 6, 13);
        let w1 = crate::stencil::coeffs::first_deriv(4);
        let r = 4isize;
        for axis in 0..3 {
            let got = d1_axis(&g, &w1, axis, 2);
            let want = Grid3::from_fn(5, 8, 6, |z, x, y| {
                let mut acc = 0.0;
                for k in -r..=r {
                    let (mut zz, mut xx, mut yy) = (z as isize, x as isize, y as isize);
                    match axis {
                        0 => zz += k,
                        1 => xx += k,
                        _ => yy += k,
                    }
                    acc += w1[(k + r) as usize] * g.get_wrap(zz, xx, yy);
                }
                acc
            });
            assert_allclose(&got.data, &want.data, 1e-5, 1e-6);
        }
    }

    #[test]
    fn d2_of_cosine_has_right_eigenvalue() {
        let n = 32;
        let g = quadratic_grid(n);
        let w2 = second_deriv(4);
        let d = d2_axis(&g, &w2, 0, 4);
        let lam = -(2.0 * std::f32::consts::PI / n as f32).powi(2);
        for (got, f) in d.data.iter().zip(&g.data) {
            assert!((got - lam * f).abs() < 1e-4, "{got} vs {}", lam * f);
        }
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let g = Grid3::random(8, 8, 8, 17);
        let w2 = second_deriv(2);
        let a = d2_axis(&g, &w2, 1, 1);
        let b = d2_axis(&g, &w2, 1, 7);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn impulse_stays_bounded_many_steps() {
        let (nz, nx, ny) = (24, 24, 24);
        let m = media::layered_vti(nz, nx, ny, 10.0, &media::default_layers());
        let mut st = VtiState::zeros(nz, nx, ny);
        let mut sc = VtiScratch::new(nz, nx, ny);
        st.inject(12, 12, 12, 1.0);
        let w2 = second_deriv(4);
        for _ in 0..200 {
            step(&mut st, &m, &w2, 4, &mut sc);
        }
        let e = st.energy();
        assert!(e.is_finite() && e < 1e6, "unstable: energy {e}");
    }

    #[test]
    fn wave_spreads_from_source() {
        let (nz, nx, ny) = (32, 32, 32);
        let m = media::layered_vti(nz, nx, ny, 10.0, &media::default_layers());
        let mut st = VtiState::zeros(nz, nx, ny);
        let mut sc = VtiScratch::new(nz, nx, ny);
        let w2 = second_deriv(4);
        for i in 0..40 {
            st.inject(16, 16, 16, super::super::wavelet::ricker(i as f64 * m.dt, 15.0));
            step(&mut st, &m, &w2, 4, &mut sc);
        }
        // energy must have propagated away from the source cell
        let far = st.sh.get(16, 16, 26).abs() + st.sh.get(26, 16, 16).abs();
        assert!(far > 0.0, "no propagation");
        assert!(st.energy() > 0.0);
    }
}
