//! Sponge absorbing boundary (Cerjan-style exponential damping).
//!
//! Real-world RTM cannot use periodic boundaries; a damping ramp of
//! `width` cells multiplies the wavefield near every face, absorbing
//! outgoing energy.  This is also why "boundary-condition handling often
//! constrains the depth of temporal blocking" (paper §III-B) — each step
//! must apply the sponge before the next stencil.

use crate::grid::Grid3;

/// Precomputed per-cell damping factors.
pub struct Sponge {
    /// Damping-ramp width (cells) at every face.
    pub width: usize,
    factors: Vec<f32>,
    nz: usize,
    nx: usize,
    ny: usize,
}

impl Sponge {
    /// Build for a grid of `(nz, nx, ny)` with ramp `width` and strength
    /// `alpha` (typical 0.0053 per Cerjan).
    pub fn new(nz: usize, nx: usize, ny: usize, width: usize, alpha: f64) -> Self {
        let ramp = |i: usize, n: usize| -> f64 {
            let d = i.min(n - 1 - i);
            if d >= width {
                1.0
            } else {
                let u = (width - d) as f64;
                (-alpha * alpha * u * u).exp()
            }
        };
        let mut factors = vec![0.0f32; nz * nx * ny];
        for z in 0..nz {
            let fz = ramp(z, nz);
            for x in 0..nx {
                let fx = ramp(x, nx);
                for y in 0..ny {
                    let fy = ramp(y, ny);
                    factors[(z * nx + x) * ny + y] = (fz * fx * fy) as f32;
                }
            }
        }
        Self { width, factors, nz, nx, ny }
    }

    /// Apply the damping in place.
    pub fn apply(&self, g: &mut Grid3) {
        assert_eq!((g.nz, g.nx, g.ny), (self.nz, self.nx, self.ny));
        for (v, &f) in g.data.iter_mut().zip(&self.factors) {
            *v *= f;
        }
    }

    /// Damping factor at a cell (for tests).
    pub fn factor(&self, z: usize, x: usize, y: usize) -> f32 {
        self.factors[(z * self.nx + x) * self.ny + y]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interior_undamped_boundary_damped() {
        let s = Sponge::new(32, 32, 32, 8, 0.0053);
        assert_eq!(s.factor(16, 16, 16), 1.0);
        assert!(s.factor(0, 16, 16) < 1.0);
        assert!(s.factor(0, 0, 0) < s.factor(0, 16, 16));
    }

    #[test]
    fn monotone_ramp() {
        let s = Sponge::new(40, 40, 40, 10, 0.0053);
        for d in 0..9 {
            assert!(s.factor(d, 20, 20) <= s.factor(d + 1, 20, 20) + 1e-9);
        }
    }

    #[test]
    fn absorbs_energy() {
        let s = Sponge::new(16, 16, 16, 6, 0.02);
        let mut g = Grid3::random(16, 16, 16, 4);
        let before = g.energy();
        s.apply(&mut g);
        assert!(g.energy() < before);
    }
}
