//! Layered survey resilience (DESIGN.md §16): deterministic fault
//! injection, the crash-consistent survey journal, and the wavefield
//! health policy.
//!
//! Three cooperating pieces, all deterministic:
//!
//! * [`FaultPlan`] — a **seeded, reproducible** fault schedule parsed
//!   from a compact spec string (`"seed=7 kernel=0.05
//!   transport=1@shot3"`), replacing the old ad-hoc
//!   `inject_faults(n)` counter.  Faults target four layers
//!   ([`FaultLayer`]): a forward-step **kernel** panic, **transport**
//!   corruption of a quantized halo shell, a **checkpoint**-store
//!   read-back failure, and a worker **stall**.  Every injection
//!   decision is a pure function of `(seed, layer, shot, attempt)` —
//!   never of wall clock or scheduling — so a chaos run replays
//!   bit-for-bit regardless of worker or shard interleaving.
//! * [`SurveyJournal`] — a write-ahead, shot-indexed journal in the
//!   crate's manifest idiom (`key|value` lines, canonical sorted
//!   serialization, same family as `runtime::PlanCache`).  Every
//!   terminal shot record — and, for completed shots, the **bit-exact**
//!   image slot (`f32::to_bits` hex) — is published by writing a
//!   sibling temp file and `fs::rename`-ing it over the journal, so a
//!   kill at any instant leaves either the previous or the next
//!   consistent journal, never a torn one.  Because the survey image is
//!   a tree reduction over shot-indexed slots, a resumed survey that
//!   replays only the missing shots reproduces the fault-free image
//!   **bitwise** (pinned in `rust/tests/resilience.rs`).
//! * [`HealthPolicy`] — what the per-step wavefield health monitor (an
//!   O(1)-alloc finite/ceiling check on the energy reduction the
//!   forward pass already computes) does when a shot goes non-finite or
//!   blows past [`HEALTH_ENERGY_CEILING`]: abort the shot, retry the
//!   attempt, or retry with the halo wire codec forced back to lossless
//!   f32 ([`HealthPolicy::FallbackF32Codec`]) so bf16/f16 compression
//!   degrades gracefully instead of corrupting the image.
//!
//! The service integration lives in [`rtm::service`](super::service);
//! the CLI exposes the spec string as `--faults`, the policy as
//! `--health`, and the journal as `--journal` / `--resume`.

use super::image::Image;
use crate::grid::Grid3;
use crate::util::err::{Context, Result as ErrResult};
use crate::util::{ParseKindError, XorShift};
use crate::{anyhow, bail};
use std::collections::BTreeMap;
use std::fmt;
use std::path::{Path, PathBuf};

/// Wavefield-health energy ceiling: a per-step field energy above this
/// (or any non-finite energy) marks the attempt unhealthy.  Orders of
/// magnitude above any legitimate shot (tiny fixtures peak around 1e6;
/// f32 fields cap total energy near 1e38) and far below `f64::MAX`, so
/// healthy runs never trip it and genuine blow-ups always do.
pub const HEALTH_ENERGY_CEILING: f64 = 1e30;

/// Injected worker-stall duration, milliseconds.  Long enough to
/// genuinely perturb pump scheduling in a chaos run, short enough to
/// keep CI-sized fault matrices cheap.
pub const STALL_MS: u64 = 10;

// ---------------------------------------------------------------------------
// fault taxonomy
// ---------------------------------------------------------------------------

/// The four layers a [`FaultPlan`] can inject at (DESIGN.md §16 fault
/// taxonomy).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FaultLayer {
    /// Forward-step kernel panic: the attempt panics before touching
    /// the propagators; the pump's containment turns it into a failed
    /// attempt routed through the retry path.
    Kernel,
    /// Halo-transport corruption: a NaN lands in the quantized boundary
    /// shell (only meaningful under a lossy wire codec — a lossless f32
    /// wire is bitwise and cannot corrupt).  Detected by the health
    /// monitor, handled per [`HealthPolicy`].
    Transport,
    /// Checkpoint-store read-back failure: the snapshot store reports
    /// an unreadable snapshot at record time; the attempt fails with an
    /// ordinary error and retries.
    Checkpoint,
    /// Worker stall: the attempt sleeps [`STALL_MS`] before running.
    /// Perturbs scheduling without failing anything — the determinism
    /// contracts must hold through it.
    Stall,
}

impl FaultLayer {
    /// Every layer, in spec/display order.
    pub const ALL: [FaultLayer; 4] =
        [FaultLayer::Kernel, FaultLayer::Transport, FaultLayer::Checkpoint, FaultLayer::Stall];

    /// Canonical spec keys, aligned with [`ALL`](Self::ALL).
    pub const NAMES: [&'static str; 4] = ["kernel", "transport", "checkpoint", "stall"];

    /// Canonical spec key of this layer.
    pub fn name(self) -> &'static str {
        Self::NAMES[self.index()]
    }

    fn index(self) -> usize {
        match self {
            FaultLayer::Kernel => 0,
            FaultLayer::Transport => 1,
            FaultLayer::Checkpoint => 2,
            FaultLayer::Stall => 3,
        }
    }
}

/// One layer's injection rule inside a [`FaultPlan`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultRule {
    /// Inject on the first `n` attempts — of shot `shot` when present
    /// (`"1@shot3"`), of every shot otherwise (`"2"`, the old
    /// `inject_faults(n)` counter semantics).
    Count {
        /// Attempts 1..=`n` inject.
        n: u32,
        /// Restrict to one shot id; `None` applies to every shot.
        shot: Option<u32>,
    },
    /// Inject each attempt independently with probability `ppm / 1e6`
    /// (`"0.05"`; probabilities are quantized to parts-per-million so
    /// the plan stays `Eq` and round-trips exactly).
    Prob {
        /// Injection probability in parts-per-million.
        ppm: u32,
    },
}

impl fmt::Display for FaultRule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultRule::Count { n, shot: None } => write!(f, "{n}"),
            FaultRule::Count { n, shot: Some(s) } => write!(f, "{n}@shot{s}"),
            // Debug float formatting keeps the decimal point ("1.0",
            // "0.05"), which is what disambiguates Prob from Count on
            // re-parse
            FaultRule::Prob { ppm } => write!(f, "{:?}", *ppm as f64 / 1e6),
        }
    }
}

/// A seeded, deterministic fault schedule: at most one [`FaultRule`]
/// per [`FaultLayer`], plus the seed that keys probabilistic rules.
///
/// Parsed from a whitespace-separated `key=value` spec
/// ([`parse`](Self::parse)), re-emitted canonically by `Display`
/// (`parse(plan.to_string()) == plan`).  `Copy + Eq`, so it threads
/// through `ShotJob` and config structs without breaking their
/// by-value idioms.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub struct FaultPlan {
    seed: u64,
    rules: [Option<FaultRule>; 4],
}

impl FaultPlan {
    /// Every key the spec grammar accepts (`seed` plus the four
    /// layers) — the allowed list parse errors report.
    pub const SPEC_KEYS: [&'static str; 5] =
        ["seed", "kernel", "transport", "checkpoint", "stall"];

    /// Parse a compact spec string: whitespace-separated `key=value`
    /// tokens where `key` is `seed` or a layer name and a layer's value
    /// is `<count>`, `<count>@shot<id>`, or a probability containing a
    /// decimal point.  The empty string parses to the empty plan.
    ///
    /// ```
    /// use mmstencil::rtm::resilience::FaultPlan;
    /// let plan = FaultPlan::parse("seed=7 kernel=0.05 transport=1@shot3").unwrap();
    /// assert_eq!(FaultPlan::parse(&plan.to_string()).unwrap(), plan);
    /// ```
    pub fn parse(spec: &str) -> Result<Self, ParseKindError> {
        let mut plan = FaultPlan::default();
        for tok in spec.split_whitespace() {
            let Some((key, val)) = tok.split_once('=') else {
                return Err(ParseKindError::new("fault spec", tok, &Self::SPEC_KEYS)
                    .with_detail("token is not a key=value pair"));
            };
            if key == "seed" {
                plan.seed = val.parse().map_err(|_| {
                    ParseKindError::new("fault spec", tok, &Self::SPEC_KEYS)
                        .with_detail(format!("seed must be an unsigned integer, got {val:?}"))
                })?;
                continue;
            }
            let Some(layer) = FaultLayer::ALL
                .into_iter()
                .find(|l| l.name() == key)
            else {
                return Err(ParseKindError::new("fault layer", key, &Self::SPEC_KEYS));
            };
            plan.rules[layer.index()] = Some(Self::parse_rule(tok, val)?);
        }
        Ok(plan)
    }

    fn parse_rule(tok: &str, val: &str) -> Result<FaultRule, ParseKindError> {
        let bad = |detail: String| {
            ParseKindError::new("fault rule", tok, &Self::SPEC_KEYS).with_detail(detail)
        };
        if let Some((n, rest)) = val.split_once('@') {
            let shot = rest
                .strip_prefix("shot")
                .ok_or_else(|| bad(format!("expected <count>@shot<id>, got {val:?}")))?;
            let n = n
                .parse()
                .map_err(|_| bad(format!("count must be an unsigned integer, got {n:?}")))?;
            let shot = shot
                .parse()
                .map_err(|_| bad(format!("shot id must be an unsigned integer, got {shot:?}")))?;
            Ok(FaultRule::Count { n, shot: Some(shot) })
        } else if val.contains('.') {
            let p: f64 = val
                .parse()
                .map_err(|_| bad(format!("probability must be a float in [0, 1], got {val:?}")))?;
            if !(0.0..=1.0).contains(&p) {
                return Err(bad(format!("probability {p} outside [0, 1]")));
            }
            Ok(FaultRule::Prob { ppm: (p * 1e6).round() as u32 })
        } else {
            let n = val
                .parse()
                .map_err(|_| bad(format!("count must be an unsigned integer, got {val:?}")))?;
            Ok(FaultRule::Count { n, shot: None })
        }
    }

    /// The legacy `inject_faults(n)` counter as a plan: the first `n`
    /// attempts of every shot fail at the kernel layer.
    pub fn counter(n: usize) -> Self {
        let mut plan = Self::default();
        if n > 0 {
            plan.rules[FaultLayer::Kernel.index()] =
                Some(FaultRule::Count { n: n as u32, shot: None });
        }
        plan
    }

    /// The every-shot kernel fault budget (the `inject_faults(n)`
    /// compatibility view); 0 when the kernel rule is absent, shot-
    /// scoped, or probabilistic.
    pub fn counter_budget(&self) -> usize {
        match self.rules[FaultLayer::Kernel.index()] {
            Some(FaultRule::Count { n, shot: None }) => n as usize,
            _ => 0,
        }
    }

    /// Replace the seed, keeping the rules.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// The seed keying probabilistic rules.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The rule installed for `layer`, if any.
    pub fn rule(&self, layer: FaultLayer) -> Option<FaultRule> {
        self.rules[layer.index()]
    }

    /// True when no layer has a rule — the plan injects nothing.
    pub fn is_empty(&self) -> bool {
        self.rules.iter().all(Option::is_none)
    }

    /// The injection decision for `layer` on 1-based `attempt` of
    /// `shot` — a pure function of `(seed, layer, shot, attempt)`, so
    /// chaos runs replay identically under any scheduling.
    pub fn injects(&self, layer: FaultLayer, shot: usize, attempt: usize) -> bool {
        match self.rules[layer.index()] {
            None => false,
            Some(FaultRule::Count { n, shot: scope }) => {
                scope.map_or(true, |s| s as usize == shot) && attempt <= n as usize
            }
            Some(FaultRule::Prob { ppm }) => {
                let mut key = self.seed ^ 0x6A09_E667_F3BC_C909;
                key = key.wrapping_add(
                    (layer.index() as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                );
                key = key.wrapping_add((shot as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9));
                key = key.wrapping_add((attempt as u64).wrapping_mul(0x94D0_49BB_1331_11EB));
                XorShift::new(key).next_f64() < ppm as f64 / 1e6
            }
        }
    }
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "seed={}", self.seed)?;
        for layer in FaultLayer::ALL {
            if let Some(rule) = self.rules[layer.index()] {
                write!(f, " {}={}", layer.name(), rule)?;
            }
        }
        Ok(())
    }
}

/// One `(plan, shot, attempt)` evaluation point — the view of a
/// [`FaultPlan`] the forward pass consults.
#[derive(Clone, Copy, Debug)]
pub struct FaultSite {
    plan: FaultPlan,
    /// Shot id the attempt belongs to.
    pub shot: usize,
    /// 1-based attempt number.
    pub attempt: usize,
}

impl FaultSite {
    /// The evaluation point for `attempt` (1-based) of `shot`.
    pub fn new(plan: FaultPlan, shot: usize, attempt: usize) -> Self {
        Self { plan, shot, attempt }
    }

    /// A site that injects nothing (replay and single-shot paths).
    pub fn none() -> Self {
        Self { plan: FaultPlan::default(), shot: 0, attempt: 1 }
    }

    /// Whether `layer` injects at this site.
    pub fn injects(&self, layer: FaultLayer) -> bool {
        self.plan.injects(layer, self.shot, self.attempt)
    }
}

// ---------------------------------------------------------------------------
// wavefield health policy
// ---------------------------------------------------------------------------

/// What the wavefield health monitor does when a forward attempt goes
/// non-finite or blows past [`HEALTH_ENERGY_CEILING`].  Policies only
/// act on *unhealthy* attempts — a healthy survey images bitwise
/// identically under every policy (pinned in
/// `rust/tests/resilience.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, Default)]
pub enum HealthPolicy {
    /// Fail the shot immediately — no retries, the error surfaces in
    /// the report.
    AbortShot,
    /// Fail the attempt and route it through the ordinary retry budget
    /// (the default: matches the service's retry-once philosophy).
    #[default]
    Retry,
    /// Retry with the halo wire codec forced back to lossless f32 for
    /// the remaining attempts — graceful degradation for bf16/f16
    /// compression (trades the bandwidth win for a finite image, so the
    /// recovered shot is *not* bitwise the lossy-codec shot).
    FallbackF32Codec,
}

impl HealthPolicy {
    /// Canonical names, aligned with the variants.
    pub const NAMES: [&'static str; 3] = ["abort_shot", "retry", "fallback_f32_codec"];

    /// Runtime selection by canonical name — same [`ParseKindError`]
    /// contract as the crate's other `parse` selectors.
    pub fn parse(name: &str) -> Result<Self, ParseKindError> {
        match name {
            "abort_shot" => Ok(HealthPolicy::AbortShot),
            "retry" => Ok(HealthPolicy::Retry),
            "fallback_f32_codec" => Ok(HealthPolicy::FallbackF32Codec),
            _ => Err(ParseKindError::new("health policy", name, &Self::NAMES)),
        }
    }

    /// Canonical name; `parse(policy.name())` round-trips.
    pub fn name(self) -> &'static str {
        match self {
            HealthPolicy::AbortShot => "abort_shot",
            HealthPolicy::Retry => "retry",
            HealthPolicy::FallbackF32Codec => "fallback_f32_codec",
        }
    }
}

// ---------------------------------------------------------------------------
// crash-consistent survey journal
// ---------------------------------------------------------------------------

/// One journaled shot: the terminal scheduling record plus, for
/// completed shots, the bit-exact image slot.
#[derive(Clone, Debug)]
pub struct JournalEntry {
    /// Shot id (the tree-reduction slot index).
    pub id: usize,
    /// Shard whose pipeline processed the shot.
    pub shard: usize,
    /// Whether the shot was stolen from another shard's lane.
    pub stolen: bool,
    /// Forward attempts consumed.
    pub attempts: usize,
    /// Global dequeue sequence number.
    pub dequeue_seq: u64,
    /// Faults the plan injected into this shot, across all attempts.
    pub faults_injected: u64,
    /// `None` for a completed shot; the terminal error otherwise
    /// (resume re-runs failed shots).
    pub error: Option<String>,
    /// Completed shots carry their image slot (serialized via
    /// `f32::to_bits`, so the round trip is bitwise).
    pub image: Option<Image>,
}

impl JournalEntry {
    /// True when the shot completed and its image slot is present.
    pub fn completed(&self) -> bool {
        self.error.is_none() && self.image.is_some()
    }
}

/// Write-ahead, shot-indexed survey journal in the crate's manifest
/// idiom: `key|value` lines, `#` comments and blanks skipped, canonical
/// id-sorted serialization (byte-stable round trip).
///
/// **Atomic-rename invariant**: [`commit`](Self::commit) serializes the
/// whole journal to a sibling `*.tmp` file and `fs::rename`s it over
/// the journal path.  Rename within a directory is atomic, so a crash
/// at any instant leaves either the pre-commit or post-commit journal
/// intact — never a torn file.  A survey killed between shots resumes
/// from exactly the shots the journal holds.
pub struct SurveyJournal {
    path: PathBuf,
    shots: usize,
    entries: BTreeMap<usize, JournalEntry>,
}

impl SurveyJournal {
    const HEADER: &'static str = "# mmstencil survey journal v1: shot|id|meta, err|id|…, img/illum|id|dims|hex\n";

    /// Start a fresh journal for a `shots`-shot survey at `path`,
    /// publishing the empty header immediately (so a kill before the
    /// first shot still leaves a loadable journal).
    pub fn create(path: impl Into<PathBuf>, shots: usize) -> ErrResult<Self> {
        let j = Self { path: path.into(), shots, entries: BTreeMap::new() };
        j.store()?;
        Ok(j)
    }

    /// Load an existing journal from `path`.
    pub fn load(path: impl AsRef<Path>) -> ErrResult<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading survey journal {}", path.display()))?;
        let mut j = Self::parse(&text)
            .with_context(|| format!("parsing survey journal {}", path.display()))?;
        j.path = path.to_path_buf();
        Ok(j)
    }

    /// Load `path` if it exists (resuming a prior run), else create a
    /// fresh journal.  A journal recorded for a different shot count is
    /// rejected — resuming must re-present the same survey.
    pub fn open(path: impl Into<PathBuf>, shots: usize) -> ErrResult<Self> {
        let path = path.into();
        if path.exists() {
            let j = Self::load(&path)?;
            if j.shots != shots {
                bail!(
                    "survey journal {} records {} shots, survey has {shots}",
                    path.display(),
                    j.shots
                );
            }
            Ok(j)
        } else {
            Self::create(path, shots)
        }
    }

    /// Parse the manifest text (path is set by the loader).
    pub fn parse(text: &str) -> ErrResult<Self> {
        let mut shots = None;
        let mut entries: BTreeMap<usize, JournalEntry> = BTreeMap::new();
        let mut grids: BTreeMap<usize, (Option<Grid3>, Option<Grid3>, usize)> = BTreeMap::new();
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let at = |msg: &str| anyhow!("line {}: {msg}", ln + 1);
            let mut fields = line.splitn(3, '|');
            let kind = fields.next().unwrap_or_default();
            match kind {
                "shots" => {
                    let n = fields.next().ok_or_else(|| at("shots needs a count"))?;
                    shots = Some(n.parse().map_err(|_| at("shots count is not an integer"))?);
                }
                "shot" => {
                    let id: usize = fields
                        .next()
                        .and_then(|s| s.parse().ok())
                        .ok_or_else(|| at("shot needs an integer id"))?;
                    let meta = fields.next().ok_or_else(|| at("shot needs metadata"))?;
                    let mut e = JournalEntry {
                        id,
                        shard: 0,
                        stolen: false,
                        attempts: 0,
                        dequeue_seq: 0,
                        faults_injected: 0,
                        error: None,
                        image: None,
                    };
                    for kv in meta.split_whitespace() {
                        let (k, v) =
                            kv.split_once('=').ok_or_else(|| at("metadata must be key=value"))?;
                        let n = || v.parse::<u64>().map_err(|_| at("metadata value not integer"));
                        match k {
                            "shard" => e.shard = n()? as usize,
                            "stolen" => e.stolen = n()? != 0,
                            "attempts" => e.attempts = n()? as usize,
                            "seq" => e.dequeue_seq = n()?,
                            "faults" => e.faults_injected = n()?,
                            "corr" => grids.entry(id).or_default().2 = n()? as usize,
                            _ => return Err(at(&format!("unknown shot metadata key {k:?}"))),
                        }
                    }
                    entries.insert(id, e);
                }
                "err" => {
                    let id: usize = fields
                        .next()
                        .and_then(|s| s.parse().ok())
                        .ok_or_else(|| at("err needs an integer id"))?;
                    let msg = fields.next().unwrap_or_default().to_string();
                    entries
                        .get_mut(&id)
                        .ok_or_else(|| at("err precedes its shot line"))?
                        .error = Some(msg);
                }
                "img" | "illum" => {
                    let id: usize = fields
                        .next()
                        .and_then(|s| s.parse().ok())
                        .ok_or_else(|| at("grid line needs an integer id"))?;
                    let rest = fields.next().ok_or_else(|| at("grid line needs dims|hex"))?;
                    let (dims, hex) =
                        rest.split_once('|').ok_or_else(|| at("grid line needs dims|hex"))?;
                    let g = decode_grid(dims, hex).map_err(|e| at(&e.to_string()))?;
                    let slot = grids.entry(id).or_default();
                    if kind == "img" {
                        slot.0 = Some(g);
                    } else {
                        slot.1 = Some(g);
                    }
                }
                other => return Err(at(&format!("unknown record kind {other:?}"))),
            }
        }
        for (id, (img, illum, corr)) in grids {
            let e = entries
                .get_mut(&id)
                .ok_or_else(|| anyhow!("image slot for unknown shot {id}"))?;
            match (img, illum) {
                (Some(img), Some(illum)) => {
                    e.image = Some(Image { img, illum, correlations: corr })
                }
                _ => bail!("shot {id} has a partial image slot (img/illum pair incomplete)"),
            }
        }
        Ok(Self {
            path: PathBuf::new(),
            shots: shots.ok_or_else(|| anyhow!("journal has no shots header"))?,
            entries,
        })
    }

    /// Canonical serialization: header, shot count, then entries in
    /// ascending id order — byte-stable (`parse(serialize()) `
    /// re-serializes identically).
    pub fn serialize(&self) -> String {
        use fmt::Write;
        let mut out = String::from(Self::HEADER);
        let _ = writeln!(out, "shots|{}", self.shots);
        for e in self.entries.values() {
            let _ = write!(
                out,
                "shot|{}|shard={} stolen={} attempts={} seq={} faults={}",
                e.id, e.shard, e.stolen as u8, e.attempts, e.dequeue_seq, e.faults_injected
            );
            if let Some(im) = &e.image {
                let _ = write!(out, " corr={}", im.correlations);
            }
            out.push('\n');
            if let Some(err) = &e.error {
                // the error is the line's final field: kept verbatim
                // (newlines squashed so one entry stays one line)
                let _ = writeln!(out, "err|{}|{}", e.id, err.replace('\n', " "));
            }
            if let Some(im) = &e.image {
                encode_grid("img", e.id, &im.img, &mut out);
                encode_grid("illum", e.id, &im.illum, &mut out);
            }
        }
        out
    }

    /// Write-ahead publish: serialize to `<path>.tmp`, then atomically
    /// rename over the journal path.
    pub fn store(&self) -> ErrResult<()> {
        let tmp = self.path.with_extension("journal.tmp");
        std::fs::write(&tmp, self.serialize())
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, &self.path)
            .with_context(|| format!("publishing {}", self.path.display()))
    }

    /// Record one terminal shot and publish the journal atomically —
    /// the write-ahead step the survey pumps call per shot.
    pub fn commit(&mut self, entry: JournalEntry) -> ErrResult<()> {
        self.entries.insert(entry.id, entry);
        self.store()
    }

    /// The shot count the journal was created for.
    pub fn shots(&self) -> usize {
        self.shots
    }

    /// Journaled entries so far (terminal records, completed or
    /// failed).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing has been journaled yet.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The journal's entry for shot `id`, if recorded.
    pub fn get(&self, id: usize) -> Option<&JournalEntry> {
        self.entries.get(&id)
    }

    /// Entries in ascending shot-id order.
    pub fn entries(&self) -> impl Iterator<Item = &JournalEntry> {
        self.entries.values()
    }

    /// The journal file path.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

fn encode_grid(kind: &str, id: usize, g: &Grid3, out: &mut String) {
    use fmt::Write;
    let _ = write!(out, "{kind}|{id}|{}x{}x{}|", g.nz, g.nx, g.ny);
    out.reserve(g.data.len() * 8 + 1);
    for v in &g.data {
        let _ = write!(out, "{:08x}", v.to_bits());
    }
    out.push('\n');
}

fn decode_grid(dims: &str, hex: &str) -> ErrResult<Grid3> {
    let mut it = dims.split('x').map(|d| d.parse::<usize>());
    let (nz, nx, ny) = match (it.next(), it.next(), it.next(), it.next()) {
        (Some(Ok(nz)), Some(Ok(nx)), Some(Ok(ny)), None) => (nz, nx, ny),
        _ => bail!("grid dims must be <nz>x<nx>x<ny>, got {dims:?}"),
    };
    let cells = nz * nx * ny;
    if hex.len() != cells * 8 {
        bail!("grid payload holds {} hex chars, dims {dims} need {}", hex.len(), cells * 8);
    }
    let mut g = Grid3::zeros(nz, nx, ny);
    for (i, slot) in g.data.iter_mut().enumerate() {
        let word = u32::from_str_radix(&hex[i * 8..i * 8 + 8], 16)
            .with_context(|| format!("grid cell {i} is not hex"))?;
        *slot = f32::from_bits(word);
    }
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_plan_parses_and_round_trips_canonically() {
        let plan = FaultPlan::parse("seed=7 kernel=0.05 transport=1@shot3 stall=2").unwrap();
        assert_eq!(plan.seed(), 7);
        assert_eq!(plan.rule(FaultLayer::Kernel), Some(FaultRule::Prob { ppm: 50_000 }));
        assert_eq!(
            plan.rule(FaultLayer::Transport),
            Some(FaultRule::Count { n: 1, shot: Some(3) })
        );
        assert_eq!(plan.rule(FaultLayer::Checkpoint), None);
        assert_eq!(plan.rule(FaultLayer::Stall), Some(FaultRule::Count { n: 2, shot: None }));
        let text = plan.to_string();
        assert_eq!(text, "seed=7 kernel=0.05 transport=1@shot3 stall=2");
        assert_eq!(FaultPlan::parse(&text).unwrap(), plan, "canonical form must round-trip");
        // whole-probability rules keep their decimal point so re-parse
        // stays Prob, not Count
        let p = FaultPlan::parse("kernel=1.0").unwrap();
        assert_eq!(p.rule(FaultLayer::Kernel), Some(FaultRule::Prob { ppm: 1_000_000 }));
        assert_eq!(p.to_string(), "seed=0 kernel=1.0");
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn fault_plan_rejects_malformed_specs_with_the_crate_error_shape() {
        let err = FaultPlan::parse("kerel=1").unwrap_err();
        assert_eq!(err.what, "fault layer");
        assert!(err.to_string().contains("kernel | transport | checkpoint | stall"), "{err}");
        let err = FaultPlan::parse("kernel").unwrap_err();
        assert!(err.to_string().contains("key=value"), "{err}");
        let err = FaultPlan::parse("kernel=1@step3").unwrap_err();
        assert!(err.to_string().contains("@shot"), "{err}");
        let err = FaultPlan::parse("transport=1.5").unwrap_err();
        assert!(err.to_string().contains("outside [0, 1]"), "{err}");
        let err = FaultPlan::parse("seed=minus").unwrap_err();
        assert!(err.to_string().contains("unsigned integer"), "{err}");
    }

    #[test]
    fn injection_decisions_are_deterministic_and_seed_keyed() {
        let plan = FaultPlan::parse("seed=7 kernel=0.5").unwrap();
        // pure function of (seed, layer, shot, attempt): same inputs,
        // same answer, every time
        for shot in 0..64 {
            for attempt in 1..4 {
                let a = plan.injects(FaultLayer::Kernel, shot, attempt);
                let b = plan.injects(FaultLayer::Kernel, shot, attempt);
                assert_eq!(a, b);
            }
        }
        // p=0.5 over 64 shots lands strictly between the degenerate
        // extremes, and a different seed reshuffles the pattern
        let hits = |p: &FaultPlan| {
            (0..64).filter(|&s| p.injects(FaultLayer::Kernel, s, 1)).collect::<Vec<_>>()
        };
        let h7 = hits(&plan);
        assert!(!h7.is_empty() && h7.len() < 64, "degenerate fault pattern: {}", h7.len());
        let h8 = hits(&FaultPlan::parse("seed=8 kernel=0.5").unwrap());
        assert_ne!(h7, h8, "seed must rekey the schedule");
        // count rules are exact: first n attempts, scoped shot only
        let plan = FaultPlan::parse("transport=2@shot3").unwrap();
        assert!(plan.injects(FaultLayer::Transport, 3, 1));
        assert!(plan.injects(FaultLayer::Transport, 3, 2));
        assert!(!plan.injects(FaultLayer::Transport, 3, 3));
        assert!(!plan.injects(FaultLayer::Transport, 2, 1));
        // the legacy counter shim reproduces inject_faults(n)
        let c = FaultPlan::counter(2);
        assert_eq!(c.counter_budget(), 2);
        assert!(c.injects(FaultLayer::Kernel, 11, 2));
        assert!(!c.injects(FaultLayer::Kernel, 11, 3));
    }

    #[test]
    fn health_policy_parses_and_round_trips() {
        for (name, want) in [
            ("abort_shot", HealthPolicy::AbortShot),
            ("retry", HealthPolicy::Retry),
            ("fallback_f32_codec", HealthPolicy::FallbackF32Codec),
        ] {
            assert_eq!(HealthPolicy::parse(name), Ok(want));
            assert_eq!(want.name(), name);
        }
        assert_eq!(HealthPolicy::default(), HealthPolicy::Retry);
        let err = HealthPolicy::parse("panic").unwrap_err();
        assert_eq!(err.what, "health policy");
        assert!(err.to_string().contains("abort_shot | retry | fallback_f32_codec"), "{err}");
    }

    fn tiny_image(seed: u64) -> Image {
        let mut im = Image::zeros(3, 4, 5);
        im.accumulate(&Grid3::random(3, 4, 5, seed), &Grid3::random(3, 4, 5, seed + 9));
        im
    }

    #[test]
    fn journal_round_trips_bitwise_and_byte_stable() {
        let dir = std::env::temp_dir().join(format!("mmstencil-journal-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("round_trip.journal");
        let mut j = SurveyJournal::create(&path, 3).unwrap();
        let im = tiny_image(5);
        j.commit(JournalEntry {
            id: 0,
            shard: 1,
            stolen: true,
            attempts: 2,
            dequeue_seq: 4,
            faults_injected: 1,
            error: None,
            image: Some(im.clone()),
        })
        .unwrap();
        j.commit(JournalEntry {
            id: 2,
            shard: 0,
            stolen: false,
            attempts: 2,
            dequeue_seq: 5,
            faults_injected: 2,
            error: Some("injected fault (kernel) on attempt 2".into()),
            image: None,
        })
        .unwrap();

        let back = SurveyJournal::load(&path).unwrap();
        assert_eq!(back.shots(), 3);
        assert_eq!(back.len(), 2);
        let e0 = back.get(0).unwrap();
        assert!(e0.completed());
        assert_eq!((e0.shard, e0.stolen, e0.attempts, e0.dequeue_seq), (1, true, 2, 4));
        let got = e0.image.as_ref().unwrap();
        assert_eq!(got.img.data, im.img.data, "image slot must round-trip bitwise");
        assert_eq!(got.illum.data, im.illum.data);
        assert_eq!(got.correlations, im.correlations);
        let e2 = back.get(2).unwrap();
        assert!(!e2.completed());
        assert_eq!(e2.error.as_deref(), Some("injected fault (kernel) on attempt 2"));
        // canonical serialization is byte-stable through a round trip
        assert_eq!(back.serialize(), j.serialize());
        // the atomic publish leaves no temp file behind
        assert!(!path.with_extension("journal.tmp").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn journal_rejects_torn_or_mismatched_state() {
        assert!(SurveyJournal::parse("shot|0|attempts=1").is_err(), "missing shots header");
        assert!(SurveyJournal::parse("shots|2\nbogus|1|x").is_err(), "unknown record kind");
        // a partial image slot (img without illum) is torn state
        let torn = "shots|2\nshot|0|shard=0 stolen=0 attempts=1 seq=1 faults=0 corr=1\n\
                    img|0|1x1x1|3f800000\n";
        assert!(SurveyJournal::parse(torn).is_err(), "partial image slot must be rejected");
        let dir = std::env::temp_dir().join(format!("mmstencil-journal2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mismatch.journal");
        SurveyJournal::create(&path, 4).unwrap();
        let err = SurveyJournal::open(&path, 8).unwrap_err();
        assert!(err.to_string().contains("records 4 shots"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
