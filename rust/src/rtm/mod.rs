//! Reverse Time Migration (paper §IV-G, §V-F): the real-world HPC
//! application MMStencil integrates into.
//!
//! * [`media`]    — synthetic layered earth models (VTI/TTI parameters);
//! * [`wavelet`]  — Ricker source wavelet;
//! * [`boundary`] — sponge absorbing boundary;
//! * [`vti`]      — pseudo-acoustic VTI leapfrog propagator;
//! * [`tti`]      — TTI propagator (six second derivatives incl. mixed,
//!   composed from 1D first-derivative stencils);
//! * [`image`]    — zero-lag cross-correlation imaging condition;
//! * [`driver`]   — shot loop: forward + backward propagation, imaging,
//!   metrics, and PJRT artifact cross-checks.

pub mod boundary;
pub mod driver;
pub mod image;
pub mod media;
pub mod pjrt_prop;
pub mod tti;
pub mod vti;
pub mod wavelet;
