//! Reverse Time Migration (paper §IV-G, §V-F): the real-world HPC
//! application MMStencil integrates into.
//!
//! * [`media`]    — synthetic layered earth models (VTI/TTI parameters);
//! * [`wavelet`]  — Ricker source wavelet;
//! * [`boundary`] — sponge absorbing boundary;
//! * [`vti`]      — pseudo-acoustic VTI leapfrog propagator;
//! * [`tti`]      — TTI propagator (six second derivatives incl. mixed,
//!   composed from 1D first-derivative stencils);
//! * [`image`]    — zero-lag cross-correlation imaging condition;
//! * [`driver`]   — one-shot entry point ([`driver::run_shot`]), config
//!   validation, metrics, and PJRT artifact cross-checks;
//! * [`service`]  — survey-scale shot scheduler: sharded work-stealing
//!   queue, pipelined forward/adjoint pumps, strategy-selectable
//!   wavefield checkpointing, tree-reduced image accumulation
//!   ([`ShotJob`](service::ShotJob) / [`SurveyRunner`](service::SurveyRunner));
//! * [`resilience`] — seeded deterministic fault injection
//!   ([`FaultPlan`](resilience::FaultPlan)), the crash-consistent
//!   survey journal ([`SurveyJournal`](resilience::SurveyJournal)),
//!   and the wavefield health policy
//!   ([`HealthPolicy`](resilience::HealthPolicy)) — DESIGN.md §16.
//!
//! Ownership/engine contract (DESIGN.md §10): the propagators own their
//! wavefield grids and whole-grid scratch (`VtiScratch`/`TtiScratch`);
//! every derivative sweep is dispatched through the engine layer
//! ([`stencil::engine`](crate::stencil::engine)) as fixed z-slab
//! [`TileViewMut`](crate::grid::par::TileViewMut) claims fanned over
//! the persistent worker runtime, and the pointwise stages run through
//! the pool's `ParSlice` chunk claims — the propagators never share
//! mutable grid state between tasks by any other means.  The scalar
//! loops the propagators started with live on as the naive engine's
//! axis oracle (`stencil::naive::d_axis_region`).

pub mod boundary;
pub mod driver;
pub mod image;
pub mod media;
pub mod pjrt_prop;
pub mod resilience;
pub mod service;
pub mod tti;
pub mod vti;
pub mod wavelet;

/// Shared RTM test fixtures: the media/grid builders and the worker
/// counts every RTM test sweeps, hoisted here so `vti`, `tti`, and the
/// driver tests stop duplicating helpers and hardcoding per-test thread
/// counts.
#[cfg(test)]
pub(crate) mod fixtures {
    use super::media::{self, TtiMedia, VtiMedia};
    use crate::grid::Grid3;

    /// Worker counts the RTM suites sweep — widen here, not per test.
    /// Index 0 is the serial reference leg.
    pub const WORKER_COUNTS: [usize; 2] = [1, 4];

    /// The parallel leg of two-leg tests.
    pub const PAR_WORKERS: usize = WORKER_COUNTS[1];

    /// Default layered VTI medium at 10 m spacing.
    pub fn vti_media(nz: usize, nx: usize, ny: usize) -> VtiMedia {
        media::layered_vti(nz, nx, ny, 10.0, &media::default_layers())
    }

    /// Default layered TTI medium at 10 m spacing.
    pub fn tti_media(nz: usize, nx: usize, ny: usize) -> TtiMedia {
        media::layered_tti(nz, nx, ny, 10.0, &media::default_layers())
    }

    /// f = cos(2πz/n): an eigenfunction of the periodic ∂zz band with
    /// eigenvalue ≈ −(2π/n)² (the helper `vti` tests used to duplicate
    /// under the misleading name `quadratic_grid`).
    pub fn cosine_grid(n: usize) -> Grid3 {
        Grid3::from_fn(n, n, n, |z, _, _| {
            (2.0 * std::f32::consts::PI * z as f32 / n as f32).cos()
        })
    }
}
