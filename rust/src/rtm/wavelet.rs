//! Ricker source wavelet — the standard seismic source.

use std::f64::consts::PI;

/// Ricker wavelet value at time `t` (seconds) for peak frequency `f0`,
/// delayed so the wavelet starts near zero.
pub fn ricker(t: f64, f0: f64) -> f32 {
    let t0 = 1.2 / f0;
    let arg = PI * f0 * (t - t0);
    let a2 = arg * arg;
    ((1.0 - 2.0 * a2) * (-a2).exp()) as f32
}

/// Sampled wavelet for `n` steps of `dt`.
pub fn ricker_series(n: usize, dt: f64, f0: f64) -> Vec<f32> {
    (0..n).map(|i| ricker(i as f64 * dt, f0)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_at_delay() {
        let f0 = 15.0;
        let t0 = 1.2 / f0;
        let peak = ricker(t0, f0);
        assert!((peak - 1.0).abs() < 1e-6);
        assert!(ricker(t0 + 0.01, f0) < peak);
        assert!(ricker(t0 - 0.01, f0) < peak);
    }

    #[test]
    fn starts_and_ends_near_zero() {
        let f0 = 15.0;
        assert!(ricker(0.0, f0).abs() < 0.02);
        assert!(ricker(1.0, f0).abs() < 1e-6);
    }

    #[test]
    fn series_has_zero_mean_tail() {
        // integral of a Ricker wavelet is ~0
        let s = ricker_series(4000, 0.0005, 15.0);
        let sum: f64 = s.iter().map(|&v| v as f64).sum();
        assert!(sum.abs() < 0.05, "sum {sum}");
    }
}
