//! Survey-scale RTM shot service (paper §V-F): the "heavy traffic"
//! workload — many independent shots over a shared velocity model —
//! scheduled across simulated NUMA rank shards on a persistent
//! [`coordinator::runtime`](crate::coordinator::runtime) pool.
//!
//! The public surface is a job/session pair:
//!
//! * [`ShotJob`] — one shot, built through a validating builder
//!   ([`ShotJob::builder`] → [`ShotJobBuilder::build`] returns
//!   `Result`, so a bad field fails at construction, not inside the
//!   propagators);
//! * [`SurveyRunner`] — a session owning the worker runtime, the media
//!   cache, and the scheduler shape ([`SurveyConfig`]); [`run`]
//!   (`SurveyRunner::run`) drives a whole survey, [`run_one`]
//!   (`SurveyRunner::run_one`) a single job (the implementation behind
//!   [`driver::run_shot`](super::driver::run_shot)).
//!
//! Scheduler shape (DESIGN.md §12): shots enter a **bounded sharded
//! queue** ([`ShardedQueue`]) — one FIFO lane per simulated NUMA rank
//! shard, submission blocks at capacity (backpressure, items are never
//! dropped).  Each shard runs a two-stage pipeline on two dedicated
//! pool workers: a *forward pump* pops shots (stealing from other
//! shards' tails when its own lane is dry) and records traces plus
//! wavefield snapshots, then hands the product through a one-slot
//! rendezvous to the shard's *adjoint pump*, which back-propagates and
//! images.  A shot's adjoint therefore overlaps the next shot's forward
//! on the same shard, and different shots overlap across shards.
//!
//! Per-shot wavefield checkpointing for the adjoint pass is strategy-
//! selectable ([`CheckpointStrategy`]) behind one trait
//! ([`SnapshotStore`]): full-state snapshots (the classic
//! store-everything layout) or boundary-saving sparse keyframes that
//! re-propagate each segment on demand (Griewank-style recompute —
//! ~`1/keyframe_every` of the snapshot memory for one extra forward
//! pass of compute).  Propagation is deterministic, so the two
//! strategies produce **bitwise identical** images — a contract the
//! tests diff directly.
//!
//! Determinism: per-shot results never depend on which worker ran them
//! (the engine layer's fixed z-slab partition), per-shot images are
//! keyed by shot id, and the final image is a **tree reduction**
//! ([`reduce_images`]) whose shape depends only on the shot count — so
//! the accumulated survey image is bitwise-stable across worker counts
//! AND shard counts.
//!
//! Failure handling (DESIGN.md §16): a shot that errors is retried
//! (once, by default), then recorded as [`ShotStatus::Failed`] in the
//! report — it never wedges the queue.  Containment is layered:
//!
//! * a **panic** inside a forward or adjoint pass is caught at the pump
//!   and becomes a failed *attempt* (forward) or a failed shot
//!   (adjoint) — the survey keeps going and the process exits cleanly;
//! * every forward step runs the **wavefield health monitor** — an
//!   O(1)-alloc non-finite/energy-blowup check piggybacked on the
//!   existing per-step energy reduction — whose verdicts are routed by
//!   [`SurveyConfig::health`] ([`HealthPolicy`]): abort the shot, spend
//!   a retry, or retry with the halo codec forced to lossless
//!   [`HaloCodec::F32`];
//! * submission can carry a deadline ([`SurveyConfig::submit_timeout_ms`],
//!   [`ShardedQueue::push_deadline`]) so a wedged consumer surfaces a
//!   [`SubmitError::Timeout`] instead of blocking the driver forever;
//! * with [`run_journaled`](SurveyRunner::run_journaled) every terminal
//!   shot is committed write-ahead to a crash-consistent
//!   [`SurveyJournal`], and [`resume`](SurveyRunner::resume) adopts the
//!   completed slots bitwise instead of re-running them (the
//!   tree reduction is keyed by shot id, so the resumed final image is
//!   bit-for-bit the uninterrupted one).
//!
//! Chaos hooks: [`ShotJobBuilder::fault_plan`] attaches a seeded
//! deterministic [`FaultPlan`] (four injectable layers — kernel panic,
//! halo-transport corruption, checkpoint-store read failure, worker
//! stall); [`ShotJobBuilder::inject_faults`] is the legacy counter shim
//! the retry-contract tests use.

use super::boundary::Sponge;
use super::driver::{self, ConfigError, Medium, RtmConfig, RtmReport};
use super::image::Image;
use super::media::{self, TtiMedia, VtiMedia};
use super::resilience::{
    FaultLayer, FaultPlan, FaultSite, HealthPolicy, JournalEntry, SurveyJournal,
    HEALTH_ENERGY_CEILING, STALL_MS,
};
use super::tti::{self, TtiScratch, TtiState, TtiTrig};
use super::vti::{self, VtiScratch, VtiState};
use super::wavelet;
use crate::anyhow;
use crate::coordinator::runtime::{Runtime, RuntimeConfig};
use crate::grid::halo::HaloCodec;
use crate::grid::shell;
use crate::grid::Grid3;
use crate::simulator::roofline::Engine as SimEngine;
use crate::simulator::Platform;
use crate::stencil::coeffs::{first_deriv, second_deriv};
use crate::stencil::Engine;
use crate::bail;
use crate::util::err::Result as ErrResult;
use crate::util::{ParseKindError, Timer};
use std::collections::{HashMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// checkpoint strategies
// ---------------------------------------------------------------------------

/// How the forward pass retains the source wavefield for the adjoint
/// pass's imaging correlation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CheckpointStrategy {
    /// Store every snapshot field in full — maximum memory, zero
    /// recompute (the pre-service driver's behaviour).
    FullState,
    /// Store sparse full-state *keyframes* and re-propagate each
    /// segment on demand during the adjoint pass — ~`1/keyframe_every`
    /// of the snapshot memory for one extra forward pass of compute.
    /// Bitwise identical to [`FullState`](Self::FullState) because
    /// propagation is deterministic.
    BoundarySaving,
}

impl CheckpointStrategy {
    /// Canonical names, aligned with the variants — the allowed list
    /// [`parse`](Self::parse) reports on a miss.
    pub const NAMES: [&'static str; 2] = ["full_state", "boundary_saving"];

    /// Runtime selection by canonical name — the third member of the
    /// crate's `parse` trio (`StencilSpec::parse`, `EngineKind::parse`),
    /// sharing [`ParseKindError`] so a typo reads identically no matter
    /// which selector rejected it.
    pub fn parse(name: &str) -> Result<Self, ParseKindError> {
        match name {
            "full_state" => Ok(CheckpointStrategy::FullState),
            "boundary_saving" => Ok(CheckpointStrategy::BoundarySaving),
            _ => Err(ParseKindError::new("checkpoint strategy", name, &Self::NAMES)),
        }
    }

    /// Canonical name; `parse(strategy.name())` round-trips.
    pub fn name(self) -> &'static str {
        match self {
            CheckpointStrategy::FullState => "full_state",
            CheckpointStrategy::BoundarySaving => "boundary_saving",
        }
    }
}

/// A full propagator state capture: both fields and both previous-step
/// fields at one forward step — enough to resume propagation bitwise
/// (the leapfrog scheme's entire time-dependent state).
pub struct PropCheckpoint {
    /// Forward step index the state was captured *after* (the resume
    /// point is step `step + 1`).
    pub step: usize,
    a: Grid3,
    b: Grid3,
    a_prev: Grid3,
    b_prev: Grid3,
}

impl PropCheckpoint {
    /// Retained f32 words (4 full grids).
    pub fn words(&self) -> usize {
        self.a.data.len() + self.b.data.len() + self.a_prev.data.len() + self.b_prev.data.len()
    }
}

/// Strategy-erased snapshot storage: the forward pass [`record`]s
/// (`SnapshotStore::record`) every step, the adjoint pass [`fetch`]es
/// (`SnapshotStore::fetch`) snapshot fields back in descending step
/// order.  One trait so tests can run the same shot through both
/// strategies and diff the images bitwise.
pub trait SnapshotStore: Send {
    /// Which strategy this store implements.
    fn strategy(&self) -> CheckpointStrategy;

    /// Observe forward step `step`.  `snap_due` marks the imaging
    /// cadence (`step % snap_every == 0`); `field` is the imaging field
    /// at this step, and `capture` produces a full propagator
    /// checkpoint on demand (only called if the store wants one).
    fn record(
        &mut self,
        step: usize,
        snap_due: bool,
        field: &Grid3,
        capture: &mut dyn FnMut() -> PropCheckpoint,
    );

    /// Return the imaging field of snapshot step `step`.  Called in
    /// strictly descending step order over exactly the `snap_due`
    /// steps.  `replay` re-propagates from a checkpoint up to a step,
    /// returning every snapshot field in `(checkpoint.step, upto]` —
    /// recompute-based stores use it to fill a segment in one pass.
    fn fetch(
        &mut self,
        step: usize,
        replay: &mut dyn FnMut(&PropCheckpoint, usize) -> Vec<(usize, Grid3)>,
    ) -> Grid3;

    /// Currently retained f32 words — the memory half of the
    /// strategy trade-off (measured by tests between the passes).
    fn retained_words(&self) -> usize;
}

/// [`CheckpointStrategy::FullState`]: every snapshot field stored
/// whole, popped back LIFO (the adjoint walks steps in reverse).
pub struct FullStateStore {
    snaps: Vec<(usize, Grid3)>,
}

impl FullStateStore {
    /// An empty store.
    pub fn new() -> Self {
        Self { snaps: Vec::new() }
    }
}

impl Default for FullStateStore {
    fn default() -> Self {
        Self::new()
    }
}

impl SnapshotStore for FullStateStore {
    fn strategy(&self) -> CheckpointStrategy {
        CheckpointStrategy::FullState
    }

    fn record(
        &mut self,
        step: usize,
        snap_due: bool,
        field: &Grid3,
        _capture: &mut dyn FnMut() -> PropCheckpoint,
    ) {
        if snap_due {
            self.snaps.push((step, field.clone()));
        }
    }

    fn fetch(
        &mut self,
        step: usize,
        _replay: &mut dyn FnMut(&PropCheckpoint, usize) -> Vec<(usize, Grid3)>,
    ) -> Grid3 {
        let (s, g) = self.snaps.pop().expect("fetch past the recorded snapshots");
        assert_eq!(s, step, "snapshots must be fetched in recording order, reversed");
        g
    }

    fn retained_words(&self) -> usize {
        self.snaps.iter().map(|(_, g)| g.data.len()).sum()
    }
}

/// Default keyframe cadence of [`BoundarySavingStore`]: one full
/// checkpoint (4 grids) per 8 snapshot steps → half the footprint of
/// full-state storage, amortized further as `snap_every` shrinks.
pub const DEFAULT_KEYFRAME_EVERY: usize = 8;

/// [`CheckpointStrategy::BoundarySaving`]: sparse keyframe checkpoints
/// plus on-demand segment replay.  Each segment between keyframes is
/// re-propagated exactly once during the adjoint pass (the transient
/// replayed fields are handed out as the imaging loop reaches them), so
/// the total recompute is one extra forward pass.
pub struct BoundarySavingStore {
    keyframe_every: usize,
    snaps_seen: usize,
    keyframes: Vec<PropCheckpoint>,
    replayed: Vec<(usize, Grid3)>,
}

impl BoundarySavingStore {
    /// A store keeping one keyframe per `keyframe_every` snapshot steps
    /// (clamped to ≥ 1).
    pub fn new(keyframe_every: usize) -> Self {
        Self {
            keyframe_every: keyframe_every.max(1),
            snaps_seen: 0,
            keyframes: Vec::new(),
            replayed: Vec::new(),
        }
    }
}

impl SnapshotStore for BoundarySavingStore {
    fn strategy(&self) -> CheckpointStrategy {
        CheckpointStrategy::BoundarySaving
    }

    fn record(
        &mut self,
        _step: usize,
        snap_due: bool,
        _field: &Grid3,
        capture: &mut dyn FnMut() -> PropCheckpoint,
    ) {
        if !snap_due {
            return;
        }
        if self.snaps_seen % self.keyframe_every == 0 {
            self.keyframes.push(capture());
        }
        self.snaps_seen += 1;
    }

    fn fetch(
        &mut self,
        step: usize,
        replay: &mut dyn FnMut(&PropCheckpoint, usize) -> Vec<(usize, Grid3)>,
    ) -> Grid3 {
        if let Some(pos) = self.replayed.iter().position(|(s, _)| *s == step) {
            return self.replayed.swap_remove(pos).1;
        }
        let ki = self
            .keyframes
            .iter()
            .rposition(|k| k.step <= step)
            .expect("a keyframe precedes every snapshot step");
        if self.keyframes[ki].step == step {
            // the keyframe's own imaging field answers directly
            return self.keyframes[ki].a.clone();
        }
        let segment = replay(&self.keyframes[ki], step);
        let mut wanted = None;
        for (s, g) in segment {
            if s == step {
                wanted = Some(g);
            } else {
                // later fetches (lower steps come later; higher steps
                // never recur) drain these without another replay
                self.replayed.push((s, g));
            }
        }
        wanted.expect("replay covers the requested step")
    }

    fn retained_words(&self) -> usize {
        self.keyframes.iter().map(PropCheckpoint::words).sum::<usize>()
            + self.replayed.iter().map(|(_, g)| g.data.len()).sum::<usize>()
    }
}

// ---------------------------------------------------------------------------
// bounded sharded work-stealing queue
// ---------------------------------------------------------------------------

/// One dequeued item plus its scheduling provenance.
pub struct Popped<T> {
    /// The dequeued item.
    pub item: T,
    /// True when the item was stolen from another shard's tail.
    pub stolen: bool,
    /// Global dequeue sequence number (1-based) — the FIFO-fairness
    /// audit trail ([`ShotRecord::dequeue_seq`]).
    pub seq: u64,
}

/// `try_push` rejection at capacity: carries the item back to the
/// caller — a bounded submission is refused, never dropped.
#[derive(Debug)]
pub struct QueueFull<T>(
    /// The refused item, returned intact.
    pub T,
);

/// [`push_deadline`](ShardedQueue::push_deadline) refusal: either way
/// the item is handed back intact — a deadline-aware submission is
/// refused, never dropped.
#[derive(Debug)]
pub enum SubmitError<T> {
    /// The lane stayed at capacity for the whole deadline (a wedged or
    /// fatally slow consumer).
    Timeout(
        /// The refused item, returned intact.
        T,
    ),
    /// The queue was closed while the submitter waited.  `push` treats
    /// this as a driver bug and panics; a deadline-aware submitter is
    /// exactly the kind that must survive a shut-down consumer, so it
    /// gets an error instead.
    Closed(
        /// The refused item, returned intact.
        T,
    ),
}

struct QueueState<T> {
    lanes: Vec<VecDeque<T>>,
    closed: bool,
    pops: u64,
}

/// Bounded multi-producer multi-consumer queue with one FIFO lane per
/// shard and tail-stealing between shards.
///
/// Contracts (pinned by the queue tests):
/// * per-shard FIFO — a consumer popping its own lane sees submission
///   order;
/// * backpressure — [`push`](Self::push) blocks at `capacity` items per
///   lane ([`try_push`](Self::try_push) refuses, returning the item);
///   nothing is ever dropped;
/// * stealing — an empty lane's consumer takes the *tail* of the
///   fullest... of the next non-empty lane in ring order, keeping the
///   victim's own FIFO head intact;
/// * termination — after [`close`](Self::close), `pop` drains what
///   remains and then returns `None`.
pub struct ShardedQueue<T> {
    state: Mutex<QueueState<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

impl<T> ShardedQueue<T> {
    /// A queue with `shards` lanes of `capacity_per_shard` items each
    /// (both clamped to ≥ 1).
    pub fn new(shards: usize, capacity_per_shard: usize) -> Self {
        Self {
            state: Mutex::new(QueueState {
                lanes: (0..shards.max(1)).map(|_| VecDeque::new()).collect(),
                closed: false,
                pops: 0,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity: capacity_per_shard.max(1),
        }
    }

    /// Lane count.
    pub fn shards(&self) -> usize {
        self.state.lock().unwrap().lanes.len()
    }

    /// Items currently waiting in `shard`'s lane.
    pub fn len(&self, shard: usize) -> usize {
        self.state.lock().unwrap().lanes[shard].len()
    }

    /// True when `shard`'s lane holds no waiting items.
    pub fn is_empty(&self, shard: usize) -> bool {
        self.len(shard) == 0
    }

    /// Enqueue on `shard`, blocking while the lane is at capacity.
    /// Panics if the queue was closed (a bug in the submitting driver,
    /// not a load condition).
    pub fn push(&self, shard: usize, item: T) {
        let mut g = self.state.lock().unwrap();
        loop {
            assert!(!g.closed, "push on a closed queue");
            if g.lanes[shard].len() < self.capacity {
                g.lanes[shard].push_back(item);
                self.not_empty.notify_all();
                return;
            }
            g = self.not_full.wait(g).unwrap();
        }
    }

    /// Non-blocking enqueue: at capacity the item is handed back in
    /// [`QueueFull`] instead of blocking or being dropped.
    pub fn try_push(&self, shard: usize, item: T) -> Result<(), QueueFull<T>> {
        let mut g = self.state.lock().unwrap();
        assert!(!g.closed, "push on a closed queue");
        if g.lanes[shard].len() < self.capacity {
            g.lanes[shard].push_back(item);
            self.not_empty.notify_all();
            Ok(())
        } else {
            Err(QueueFull(item))
        }
    }

    /// Deadline-aware [`push`](Self::push): blocks while the lane is at
    /// capacity, but at most `timeout` — then the item comes back as
    /// [`SubmitError::Timeout`] instead of the submitter hanging on a
    /// wedged consumer forever.  A concurrent [`close`](Self::close)
    /// surfaces as [`SubmitError::Closed`] (not the `push` panic).
    pub fn push_deadline(
        &self,
        shard: usize,
        item: T,
        timeout: Duration,
    ) -> Result<(), SubmitError<T>> {
        let deadline = Instant::now() + timeout;
        let mut g = self.state.lock().unwrap();
        loop {
            if g.closed {
                return Err(SubmitError::Closed(item));
            }
            if g.lanes[shard].len() < self.capacity {
                g.lanes[shard].push_back(item);
                self.not_empty.notify_all();
                return Ok(());
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(SubmitError::Timeout(item));
            }
            g = self.not_full.wait_timeout(g, deadline - now).unwrap().0;
        }
    }

    /// Dequeue for `shard`: own lane's head first, then steal from the
    /// tail of the next non-empty lane in ring order.  Blocks while
    /// everything is empty; returns `None` once the queue is closed and
    /// fully drained.
    pub fn pop(&self, shard: usize) -> Option<Popped<T>> {
        let mut g = self.state.lock().unwrap();
        loop {
            if let Some(item) = g.lanes[shard].pop_front() {
                g.pops += 1;
                let seq = g.pops;
                self.not_full.notify_all();
                return Some(Popped { item, stolen: false, seq });
            }
            let n = g.lanes.len();
            for d in 1..n {
                let victim = (shard + d) % n;
                if let Some(item) = g.lanes[victim].pop_back() {
                    g.pops += 1;
                    let seq = g.pops;
                    self.not_full.notify_all();
                    return Some(Popped { item, stolen: true, seq });
                }
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Mark the queue closed: no further pushes; consumers drain the
    /// remaining items and then see `None`.
    pub fn close(&self) {
        let mut g = self.state.lock().unwrap();
        g.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

// ---------------------------------------------------------------------------
// jobs
// ---------------------------------------------------------------------------

/// One validated shot: an [`RtmConfig`] that passed
/// [`RtmConfig::validate`], plus service-level options.  Construct via
/// [`ShotJob::builder`].
#[derive(Clone, Debug)]
pub struct ShotJob {
    cfg: RtmConfig,
    faults: FaultPlan,
}

impl ShotJob {
    /// Start building a job from a shot configuration.
    pub fn builder(cfg: RtmConfig) -> ShotJobBuilder {
        ShotJobBuilder { cfg, faults: FaultPlan::default() }
    }

    /// The validated shot configuration.
    pub fn config(&self) -> &RtmConfig {
        &self.cfg
    }

    /// The job's deterministic fault plan (empty by default — see
    /// [`ShotJobBuilder::fault_plan`]).
    pub fn fault_plan(&self) -> FaultPlan {
        self.faults
    }

    /// Legacy injected-fault budget: the kernel-layer counter the plan
    /// carries (see [`ShotJobBuilder::inject_faults`]).
    pub fn injected_faults(&self) -> usize {
        self.faults.counter_budget()
    }
}

/// Builder for [`ShotJob`]: field setters plus a validating
/// [`build`](Self::build) — the only way to construct a job, so every
/// job in the queue is known-good before a worker touches it.
#[derive(Clone, Debug)]
pub struct ShotJobBuilder {
    cfg: RtmConfig,
    faults: FaultPlan,
}

impl ShotJobBuilder {
    /// Override the source position (z, x, y).
    pub fn src(mut self, z: usize, x: usize, y: usize) -> Self {
        self.cfg.src = Some((z, x, y));
        self
    }

    /// Override the propagation engine.
    pub fn engine(mut self, kind: crate::stencil::EngineKind) -> Self {
        self.cfg.engine = kind;
        self
    }

    /// Overlay a tuned plan ([`RtmConfig::with_plan`]): engine, worker
    /// fan-out, and requested temporal-blocking depth in one value.
    pub fn plan(mut self, plan: &crate::stencil::TunePlan) -> Self {
        self.cfg = self.cfg.with_plan(plan);
        self
    }

    /// Override the propagator worker-parallelism.
    pub fn threads(mut self, threads: usize) -> Self {
        self.cfg.threads = threads;
        self
    }

    /// Override the timestep count.
    pub fn steps(mut self, steps: usize) -> Self {
        self.cfg.steps = steps;
        self
    }

    /// Chaos hook for the retry contract: the shot's first `n` forward
    /// attempts fail with an injected error before touching the
    /// propagators.  With the default retry budget (one retry), `n = 1`
    /// exercises retry-then-succeed and `n = 2` retry-then-fail.  A
    /// shorthand for [`fault_plan`](Self::fault_plan) with
    /// [`FaultPlan::counter`].
    pub fn inject_faults(mut self, n: usize) -> Self {
        self.faults = FaultPlan::counter(n);
        self
    }

    /// Attach a seeded deterministic fault plan (kernel / transport /
    /// checkpoint / stall layers — see
    /// [`FaultPlan::parse`](FaultPlan::parse) for the spec grammar).
    /// Every injection decision is a pure function of (plan, shot id,
    /// attempt), so chaos runs are reproducible bit-for-bit.
    pub fn fault_plan(mut self, plan: FaultPlan) -> Self {
        self.faults = plan;
        self
    }

    /// Validate and seal the job ([`RtmConfig::validate`]).
    pub fn build(self) -> Result<ShotJob, ConfigError> {
        self.cfg.validate()?;
        Ok(ShotJob { cfg: self.cfg, faults: self.faults })
    }
}

// ---------------------------------------------------------------------------
// survey session
// ---------------------------------------------------------------------------

/// Scheduler shape of a [`SurveyRunner`].
#[derive(Clone, Copy, Debug)]
pub struct SurveyConfig {
    /// Simulated NUMA rank shards: queue lanes × forward/adjoint pump
    /// pairs.
    pub shards: usize,
    /// Bounded queue capacity per shard (backpressure threshold).
    pub queue_capacity: usize,
    /// Wavefield checkpointing strategy for every shot's adjoint pass.
    pub checkpoint: CheckpointStrategy,
    /// Keyframe cadence of the boundary-saving strategy, in snapshot
    /// steps ([`DEFAULT_KEYFRAME_EVERY`]).
    pub keyframe_every: usize,
    /// Pool workers; 0 derives `2 × shards` (one forward + one adjoint
    /// pump per shard).  Values below `2 × shards` are raised to it —
    /// every pump must hold a worker for the pipeline to be
    /// deadlock-free.
    pub workers: usize,
    /// Retries granted to a failed shot before it is recorded as
    /// [`ShotStatus::Failed`].
    pub max_retries: usize,
    /// Routing for wavefield-health violations (non-finite or blown-up
    /// per-step energy): abort the shot, spend a retry (default), or
    /// retry with the halo codec forced to lossless f32.
    pub health: HealthPolicy,
    /// Deadline in milliseconds for enqueueing each shot (`0` = block
    /// indefinitely, the classic backpressure behaviour).  On expiry
    /// the shot is recorded as [`ShotStatus::Failed`] with a submit
    /// timeout — the driver is never wedged by a stuck consumer.
    pub submit_timeout_ms: u64,
}

impl Default for SurveyConfig {
    fn default() -> Self {
        Self {
            shards: 2,
            queue_capacity: 4,
            checkpoint: CheckpointStrategy::FullState,
            keyframe_every: DEFAULT_KEYFRAME_EVERY,
            workers: 0,
            max_retries: 1,
            health: HealthPolicy::Retry,
            submit_timeout_ms: 0,
        }
    }
}

impl SurveyConfig {
    /// The single-shot shape [`driver::run_shot`] wraps: one shard, one
    /// queue slot, full-state snapshots, no retries.
    pub fn one_shot() -> Self {
        Self { shards: 1, queue_capacity: 1, max_retries: 0, ..Self::default() }
    }
}

/// Terminal state of one queued shot.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ShotStatus {
    /// Forward + adjoint completed; the shot contributed to the image.
    Completed,
    /// All attempts failed; the error is carried verbatim.
    Failed(String),
}

/// Scheduling + outcome record of one shot, indexed by submission id.
#[derive(Clone, Debug)]
pub struct ShotRecord {
    /// Submission index (also the tree-reduction key).
    pub id: usize,
    /// Shard whose pipeline processed the shot.
    pub shard: usize,
    /// True when the processing shard stole the shot from another
    /// shard's lane.
    pub stolen: bool,
    /// Forward attempts consumed (`> 1` means retried).
    pub attempts: usize,
    /// Global dequeue sequence number ([`Popped::seq`]); `0` for a shot
    /// adopted from a journal or refused at submission.
    pub dequeue_seq: u64,
    /// Faults the shot's [`FaultPlan`] actually injected, summed over
    /// its attempts.
    pub faults_injected: u64,
    /// True when the shot was adopted bitwise from a resume journal
    /// instead of being re-run ([`SurveyRunner::resume`]).
    pub resumed: bool,
    /// Terminal state.
    pub status: ShotStatus,
    /// Per-shot metrics (completed shots only).
    pub report: Option<RtmReport>,
}

/// Result of [`SurveyRunner::run`]: the accumulated image plus the
/// per-shot audit trail and throughput accounting.
pub struct SurveyReport {
    /// Tree-reduced image over every completed shot (`None` if none
    /// completed).
    pub image: Option<Image>,
    /// One record per submitted shot, in submission order.
    pub records: Vec<ShotRecord>,
    /// Shards the survey ran on.
    pub shards: usize,
    /// Checkpoint strategy every shot used.
    pub checkpoint: CheckpointStrategy,
    /// Wall time of the whole survey (submission to last image).
    pub wall_s: f64,
}

impl SurveyReport {
    /// Shots that completed and contributed to the image.
    pub fn completed(&self) -> usize {
        self.records
            .iter()
            .filter(|r| r.status == ShotStatus::Completed)
            .count()
    }

    /// Shots recorded as failed after exhausting their retries.
    pub fn failed(&self) -> usize {
        self.records.len() - self.completed()
    }

    /// Total retry attempts consumed across all shots.
    pub fn retries(&self) -> usize {
        self.records.iter().map(|r| r.attempts.saturating_sub(1)).sum()
    }

    /// Shots that ran on a shard other than their home lane.
    pub fn stolen(&self) -> usize {
        self.records.iter().filter(|r| r.stolen).count()
    }

    /// Faults the survey's fault plans actually injected, summed over
    /// every shot and attempt (`0` for a fault-free run — the bench
    /// baseline contract).
    pub fn faults_injected(&self) -> u64 {
        self.records.iter().map(|r| r.faults_injected).sum()
    }

    /// Shots adopted bitwise from a resume journal instead of re-run.
    pub fn resumed_shots(&self) -> usize {
        self.records.iter().filter(|r| r.resumed).count()
    }

    /// Completed-shot throughput — the paper-§V-F survey metric
    /// reported in `BENCH_engines.json`'s `survey_entries`.
    pub fn shots_per_hour(&self) -> f64 {
        if self.wall_s <= 0.0 {
            return 0.0;
        }
        self.completed() as f64 * 3600.0 / self.wall_s
    }
}

type MediaKey = (Medium, usize, usize, usize, u64);

/// Shared, immutable earth model — one per distinct (medium, dims,
/// spacing), reused across every shot of the survey.
#[derive(Clone)]
enum ShotMedia {
    Vti(Arc<VtiMedia>),
    Tti(Arc<TtiMedia>),
}

impl ShotMedia {
    fn dt(&self) -> f64 {
        match self {
            ShotMedia::Vti(m) => m.dt,
            ShotMedia::Tti(m) => m.dt,
        }
    }
}

/// A survey session: owns the persistent worker runtime the pumps run
/// on, the media cache, and the scheduler shape.  Reused across
/// [`run`](Self::run) calls (the runtime spawns once).
pub struct SurveyRunner {
    cfg: SurveyConfig,
    platform: Platform,
    rt: Runtime,
    media: HashMap<MediaKey, ShotMedia>,
}

impl SurveyRunner {
    /// Build a session, validating the scheduler shape and spawning its
    /// worker pool (`workers`, raised to at least `2 × shards`).
    pub fn new(cfg: SurveyConfig, platform: &Platform) -> Result<Self, ConfigError> {
        if cfg.shards == 0 {
            return Err(ConfigError::ZeroShards);
        }
        if cfg.queue_capacity == 0 {
            return Err(ConfigError::ZeroQueueCapacity);
        }
        let workers = cfg.workers.max(2 * cfg.shards);
        let rt = Runtime::new(RuntimeConfig {
            workers,
            cores_per_numa: workers.div_ceil(cfg.shards),
            numa_nodes: cfg.shards,
        });
        Ok(Self { cfg, platform: platform.clone(), rt, media: HashMap::new() })
    }

    /// The session's scheduler shape.
    pub fn config(&self) -> &SurveyConfig {
        &self.cfg
    }

    /// Workers in the session's pool (≥ `2 × shards`).
    pub fn workers(&self) -> usize {
        self.rt.workers()
    }

    fn media_for(&mut self, cfg: &RtmConfig) -> ShotMedia {
        let key: MediaKey = (cfg.medium, cfg.nz, cfg.nx, cfg.ny, cfg.dx.to_bits());
        self.media
            .entry(key)
            .or_insert_with(|| match cfg.medium {
                Medium::Vti => ShotMedia::Vti(Arc::new(media::layered_vti(
                    cfg.nz,
                    cfg.nx,
                    cfg.ny,
                    cfg.dx,
                    &media::default_layers(),
                ))),
                Medium::Tti => ShotMedia::Tti(Arc::new(media::layered_tti(
                    cfg.nz,
                    cfg.nx,
                    cfg.ny,
                    cfg.dx,
                    &media::default_layers(),
                ))),
            })
            .clone()
    }

    /// Run a whole survey: enqueue every job (blocking on backpressure),
    /// pipeline forward/adjoint passes across the shards, and
    /// tree-reduce the per-shot images into one survey image.
    pub fn run(&mut self, jobs: Vec<ShotJob>) -> SurveyReport {
        self.run_inner(jobs, None)
            .expect("an unjournaled survey has no fallible I/O")
    }

    /// [`run`](Self::run) with a crash-consistent journal at `path`:
    /// every terminal shot (record + image slot) is committed
    /// write-ahead with an atomic rename before the survey moves on.
    /// If `path` already holds a journal for this shot count, the run
    /// *resumes* it — completed shots are adopted bitwise instead of
    /// re-run — so a killed survey restarts with the identical call.
    pub fn run_journaled(
        &mut self,
        jobs: Vec<ShotJob>,
        path: impl Into<PathBuf>,
    ) -> ErrResult<SurveyReport> {
        let journal = SurveyJournal::open(path, jobs.len())?;
        self.run_inner(jobs, Some(journal))
    }

    /// Resume a killed journaled survey: `jobs` must re-present the
    /// same survey (the journal pins the shot count; shot ids key the
    /// adoption).  Completed shots are adopted bitwise from the journal
    /// — `attempts` untouched, no recompute — and only the remainder
    /// runs, so the final image is bit-for-bit the uninterrupted run's
    /// (the tree reduction depends only on shot-indexed slots).  Unlike
    /// [`run_journaled`](Self::run_journaled) the journal must already
    /// exist.
    pub fn resume(&mut self, jobs: Vec<ShotJob>, path: impl AsRef<Path>) -> ErrResult<SurveyReport> {
        let journal = SurveyJournal::load(path.as_ref())?;
        if journal.shots() != jobs.len() {
            bail!(
                "survey journal {} records {} shots, resume presented {}",
                path.as_ref().display(),
                journal.shots(),
                jobs.len()
            );
        }
        self.run_inner(jobs, Some(journal))
    }

    fn run_inner(
        &mut self,
        jobs: Vec<ShotJob>,
        journal: Option<SurveyJournal>,
    ) -> ErrResult<SurveyReport> {
        let t_wall = Timer::start();
        let shards = self.cfg.shards;
        let n = jobs.len();
        let outcomes: Vec<Mutex<Option<ShotOutcome>>> = (0..n).map(|_| Mutex::new(None)).collect();
        // adopt completed journal slots bitwise; resolve shared media
        // for the rest up front (needs &mut self; everything after this
        // point borrows the session immutably)
        let mut queued: Vec<QueuedShot> = Vec::with_capacity(n);
        for (id, job) in jobs.into_iter().enumerate() {
            let adopted = journal
                .as_ref()
                .and_then(|j| j.get(id))
                .filter(|e| e.completed())
                .cloned();
            if let Some(e) = adopted {
                *outcomes[id].lock().unwrap() = Some(ShotOutcome {
                    image: e.image,
                    record: ShotRecord {
                        id,
                        shard: e.shard,
                        stolen: e.stolen,
                        attempts: e.attempts,
                        dequeue_seq: e.dequeue_seq,
                        faults_injected: e.faults_injected,
                        resumed: true,
                        status: ShotStatus::Completed,
                        report: None,
                    },
                });
            } else {
                queued.push(QueuedShot {
                    id,
                    home: id % shards,
                    media: self.media_for(job.config()),
                    job,
                });
            }
        }

        let scfg = self.cfg;
        let platform = &self.platform;
        let queue: ShardedQueue<QueuedShot> = ShardedQueue::new(shards, scfg.queue_capacity);
        let handoffs: Vec<Handoff> = (0..shards).map(|_| Handoff::new()).collect();
        let journal = journal.map(Mutex::new);
        let journal_err: Mutex<Option<String>> = Mutex::new(None);
        let sink = JournalSink { journal: journal.as_ref(), err: &journal_err };

        let pump = |p: usize| {
            if p < shards {
                forward_pump(p, &scfg, &queue, &handoffs[p], &outcomes, sink);
            } else {
                adjoint_pump(p - shards, platform, &handoffs[p - shards], &outcomes, sink);
            }
        };
        {
            // SAFETY: the handle joins on wait() (and on drop, even
            // during unwind) before `pump` and its borrows go away
            let handle = unsafe { self.rt.submit_scoped(2 * shards, &pump) };
            let deadline = (scfg.submit_timeout_ms > 0)
                .then(|| Duration::from_millis(scfg.submit_timeout_ms));
            for qs in queued {
                let home = qs.home;
                match deadline {
                    // bounded: blocks under backpressure
                    None => queue.push(home, qs),
                    Some(d) => {
                        if let Err(e) = queue.push_deadline(home, qs, d) {
                            let (qs, why) = match e {
                                SubmitError::Timeout(qs) => (
                                    qs,
                                    format!(
                                        "submit timeout after {}ms",
                                        scfg.submit_timeout_ms
                                    ),
                                ),
                                SubmitError::Closed(qs) => {
                                    (qs, "queue closed during submission".to_string())
                                }
                            };
                            let record = ShotRecord {
                                id: qs.id,
                                shard: qs.home,
                                stolen: false,
                                attempts: 0,
                                dequeue_seq: 0,
                                faults_injected: 0,
                                resumed: false,
                                status: ShotStatus::Failed(why),
                                report: None,
                            };
                            sink.commit(&record, None);
                            *outcomes[qs.id].lock().unwrap() =
                                Some(ShotOutcome { image: None, record });
                        }
                    }
                }
            }
            queue.close();
            handle.wait();
        }
        if let Some(e) = journal_err.into_inner().unwrap() {
            bail!("survey journal write failed: {e}");
        }

        let mut records = Vec::with_capacity(n);
        let mut images = Vec::new();
        for slot in outcomes {
            let o = slot
                .into_inner()
                .unwrap()
                .expect("every queued shot reaches a terminal record");
            if let Some(img) = o.image {
                images.push(img);
            }
            records.push(o.record);
        }
        Ok(SurveyReport {
            image: reduce_images(images),
            records,
            shards,
            checkpoint: scfg.checkpoint,
            wall_s: t_wall.secs(),
        })
    }

    /// Run a single job (the implementation behind
    /// [`driver::run_shot`]); a failed job surfaces its error.
    pub fn run_one(&mut self, job: ShotJob) -> ErrResult<(Image, RtmReport)> {
        let mut report = self.run(vec![job]);
        let record = report.records.pop().expect("one job in, one record out");
        match record.status {
            ShotStatus::Completed => Ok((
                report.image.expect("completed shot produced an image"),
                record.report.expect("completed shot carries a report"),
            )),
            ShotStatus::Failed(e) => {
                Err(anyhow!("shot failed after {} attempts: {e}", record.attempts))
            }
        }
    }
}

/// Tree-reduce per-shot images in id order: adjacent pairs merge at
/// each level, so the reduction shape — and therefore every f32
/// rounding decision — depends only on the image *count*, never on
/// worker or shard scheduling.  `None` for an empty survey.
pub fn reduce_images(images: Vec<Image>) -> Option<Image> {
    let mut level = images;
    while level.len() > 1 {
        let mut next = Vec::with_capacity(level.len().div_ceil(2));
        let mut it = level.into_iter();
        while let Some(mut a) = it.next() {
            if let Some(b) = it.next() {
                a.merge(&b);
            }
            next.push(a);
        }
        level = next;
    }
    level.pop()
}

// ---------------------------------------------------------------------------
// pipeline internals
// ---------------------------------------------------------------------------

struct QueuedShot {
    id: usize,
    home: usize,
    media: ShotMedia,
    job: ShotJob,
}

/// Forward product handed from a shard's forward pump to its adjoint
/// pump through the one-slot rendezvous.
struct FwdProduct {
    id: usize,
    stolen: bool,
    attempts: usize,
    faults_injected: u64,
    seq: u64,
    job: ShotJob,
    media: ShotMedia,
    store: Box<dyn SnapshotStore>,
    fwd: FwdTrace,
}

struct ShotOutcome {
    image: Option<Image>,
    record: ShotRecord,
}

/// Shared write-ahead sink the pumps commit terminal shots through.
/// Journal I/O failures are latched (first error wins) instead of
/// panicking a pump — the survey finishes in memory and the driver
/// surfaces the stale-journal error afterwards.
#[derive(Clone, Copy)]
struct JournalSink<'a> {
    journal: Option<&'a Mutex<SurveyJournal>>,
    err: &'a Mutex<Option<String>>,
}

impl JournalSink<'_> {
    fn commit(&self, record: &ShotRecord, image: Option<&Image>) {
        let Some(j) = self.journal else { return };
        let entry = JournalEntry {
            id: record.id,
            shard: record.shard,
            stolen: record.stolen,
            attempts: record.attempts,
            dequeue_seq: record.dequeue_seq,
            faults_injected: record.faults_injected,
            error: match &record.status {
                ShotStatus::Failed(e) => Some(e.clone()),
                ShotStatus::Completed => None,
            },
            image: image.cloned(),
        };
        if let Err(e) = j.lock().unwrap().commit(entry) {
            let mut slot = self.err.lock().unwrap();
            if slot.is_none() {
                *slot = Some(e.to_string());
            }
        }
    }
}

/// Render a panic payload caught by a pump to a message string.
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    payload
        .downcast_ref::<&'static str>()
        .map(|s| s.to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "<non-string panic payload>".to_string())
}

/// Faults the plan will actually execute at this site, respecting the
/// layer precedence of [`forward_pass`]: a stall always runs first; a
/// kernel panic preempts the step loop (so transport/checkpoint never
/// fire); transport corruption only exists on a lossy wire codec
/// (an f32 shell round-trips bitwise — there is nothing to corrupt).
fn count_injections(site: &FaultSite, codec: HaloCodec) -> u64 {
    let stall = u64::from(site.injects(FaultLayer::Stall));
    if site.injects(FaultLayer::Kernel) {
        return stall + 1;
    }
    stall
        + u64::from(site.injects(FaultLayer::Transport) && codec.is_lossy())
        + u64::from(site.injects(FaultLayer::Checkpoint))
}

/// One-slot rendezvous between a shard's forward and adjoint pumps:
/// `put` blocks while the slot is full (the adjoint is the pipeline's
/// natural backpressure), `take` blocks until a product or the
/// producer's `finish` mark arrives.
struct Handoff {
    state: Mutex<(Option<FwdProduct>, bool)>,
    ready: Condvar,
    space: Condvar,
}

impl Handoff {
    fn new() -> Self {
        Self { state: Mutex::new((None, false)), ready: Condvar::new(), space: Condvar::new() }
    }

    fn put(&self, p: FwdProduct) {
        let mut g = self.state.lock().unwrap();
        while g.0.is_some() {
            g = self.space.wait(g).unwrap();
        }
        g.0 = Some(p);
        self.ready.notify_all();
    }

    fn finish(&self) {
        self.state.lock().unwrap().1 = true;
        self.ready.notify_all();
    }

    fn take(&self) -> Option<FwdProduct> {
        let mut g = self.state.lock().unwrap();
        loop {
            if let Some(p) = g.0.take() {
                self.space.notify_all();
                return Some(p);
            }
            if g.1 {
                return None;
            }
            g = self.ready.wait(g).unwrap();
        }
    }
}

fn make_store(cfg: &SurveyConfig) -> Box<dyn SnapshotStore> {
    match cfg.checkpoint {
        CheckpointStrategy::FullState => Box::new(FullStateStore::new()),
        CheckpointStrategy::BoundarySaving => {
            Box::new(BoundarySavingStore::new(cfg.keyframe_every))
        }
    }
}

fn forward_pump(
    shard: usize,
    scfg: &SurveyConfig,
    queue: &ShardedQueue<QueuedShot>,
    handoff: &Handoff,
    outcomes: &[Mutex<Option<ShotOutcome>>],
    sink: JournalSink<'_>,
) {
    while let Some(popped) = queue.pop(shard) {
        let qs = popped.item;
        let plan = qs.job.fault_plan();
        let mut attempts = 0;
        let mut faults_injected: u64 = 0;
        let mut force_f32 = false;
        let result = loop {
            attempts += 1;
            let site = FaultSite::new(plan, qs.id, attempts);
            let mut cfg = qs.job.config().clone();
            if force_f32 {
                // fallback_f32_codec verdict from a previous attempt:
                // lossless wire, so transport corruption cannot recur
                cfg.halo_codec = HaloCodec::F32;
            }
            faults_injected += count_injections(&site, cfg.halo_codec);
            let mut store = make_store(scfg);
            // containment boundary: a panic anywhere in the forward
            // pass (injected or genuine) becomes a failed *attempt*,
            // never a dead pump
            let attempt = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                forward_pass(&cfg, &qs.media, store.as_mut(), &site)
            }));
            let err = match attempt {
                Ok(Ok(fwd)) => break Ok((store, fwd)),
                Ok(Err(e)) => e,
                Err(payload) => {
                    let msg = panic_message(payload);
                    let msg = if msg.contains("injected fault") {
                        msg
                    } else {
                        format!("forward pass panicked: {msg}")
                    };
                    AttemptError::Other(msg)
                }
            };
            match (err, scfg.health) {
                (AttemptError::Health(msg), HealthPolicy::AbortShot) => {
                    break Err(format!("health policy abort_shot: {msg}"));
                }
                (AttemptError::Health(msg), policy) => {
                    if policy == HealthPolicy::FallbackF32Codec {
                        force_f32 = true;
                    }
                    if attempts > scfg.max_retries {
                        break Err(msg);
                    }
                }
                (AttemptError::Other(msg), _) => {
                    if attempts > scfg.max_retries {
                        break Err(msg);
                    }
                }
            }
        };
        match result {
            Ok((store, fwd)) => handoff.put(FwdProduct {
                id: qs.id,
                stolen: popped.stolen,
                attempts,
                faults_injected,
                seq: popped.seq,
                job: qs.job,
                media: qs.media,
                store,
                fwd,
            }),
            Err(e) => {
                // record the failure and keep pumping — a dead shot
                // must never wedge the lane
                let record = ShotRecord {
                    id: qs.id,
                    shard,
                    stolen: popped.stolen,
                    attempts,
                    dequeue_seq: popped.seq,
                    faults_injected,
                    resumed: false,
                    status: ShotStatus::Failed(e),
                    report: None,
                };
                sink.commit(&record, None);
                *outcomes[qs.id].lock().unwrap() =
                    Some(ShotOutcome { image: None, record });
            }
        }
    }
    handoff.finish();
}

fn adjoint_pump(
    shard: usize,
    platform: &Platform,
    handoff: &Handoff,
    outcomes: &[Mutex<Option<ShotOutcome>>],
    sink: JournalSink<'_>,
) {
    while let Some(mut p) = handoff.take() {
        // containment boundary: an adjoint panic fails the shot (the
        // forward product is spent — there is no adjoint retry path,
        // DESIGN.md §16) but never the pump or the process
        let computed = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let cfg = p.job.config();
            let (image, backward_s) =
                adjoint_pass(cfg, &p.media, p.store.as_mut(), &p.fwd.traces);
            let energy = image.img.energy();
            (image, backward_s, energy)
        }));
        let outcome = match computed {
            Ok((image, backward_s, energy)) => {
                let report =
                    assemble_report(p.job.config(), platform, p.fwd, backward_s, energy);
                ShotOutcome {
                    image: Some(image),
                    record: ShotRecord {
                        id: p.id,
                        shard,
                        stolen: p.stolen,
                        attempts: p.attempts,
                        dequeue_seq: p.seq,
                        faults_injected: p.faults_injected,
                        resumed: false,
                        status: ShotStatus::Completed,
                        report: Some(report),
                    },
                }
            }
            Err(payload) => ShotOutcome {
                image: None,
                record: ShotRecord {
                    id: p.id,
                    shard,
                    stolen: p.stolen,
                    attempts: p.attempts,
                    dequeue_seq: p.seq,
                    faults_injected: p.faults_injected,
                    resumed: false,
                    status: ShotStatus::Failed(format!(
                        "adjoint pass panicked: {}",
                        panic_message(payload)
                    )),
                    report: None,
                },
            },
        };
        sink.commit(&outcome.record, outcome.image.as_ref());
        *outcomes[p.id].lock().unwrap() = Some(outcome);
    }
}

// ---------------------------------------------------------------------------
// the shot passes (op order preserved bit-for-bit from the pre-service
// driver: inject → step → sponge ×4 → record/snapshot → energy)
// ---------------------------------------------------------------------------

fn record_plane(g: &Grid3, z: usize) -> Vec<f32> {
    g.as_slice()[z * g.nx * g.ny..(z + 1) * g.nx * g.ny].to_vec()
}

fn inject_plane(g: &mut Grid3, z: usize, plane: &[f32]) {
    let off = z * g.nx * g.ny;
    for (d, &s) in g.as_mut_slice()[off..off + plane.len()].iter_mut().zip(plane) {
        *d += s;
    }
}

/// Quantize the `r`-deep boundary shell of `g` through `codec` — the
/// single-rank image of the multirank halo compression: the shell is
/// exactly what a decomposed run would put on the wire each step.
/// [`HaloCodec::F32`] is a no-op, so default shots stay bitwise.
fn quantize_shell(g: &mut Grid3, r: usize, codec: HaloCodec) {
    if codec == HaloCodec::F32 {
        return;
    }
    let (nz, nx, ny) = g.shape();
    for [z0, z1, x0, x1, y0, y1] in shell::boundary_boxes(nz, nx, ny, r) {
        for z in z0..z1 {
            for x in x0..x1 {
                let i = g.idx(z, x, y0);
                codec.quantize(&mut g.as_mut_slice()[i..i + (y1 - y0)]);
            }
        }
    }
}

enum PropKind {
    Vti { m: Arc<VtiMedia>, w2: Vec<f32>, st: VtiState, sc: VtiScratch },
    Tti {
        m: Arc<TtiMedia>,
        trig: TtiTrig,
        w2: Vec<f32>,
        w1: Vec<f32>,
        st: TtiState,
        sc: TtiScratch,
    },
}

/// Medium-erased propagator: one forward/adjoint step machine holding
/// the state pair, scratch, engine, and sponge of a shot.
struct Prop {
    eng: Engine,
    fuse: usize,
    sponge: Sponge,
    codec: HaloCodec,
    kind: PropKind,
}

impl Prop {
    fn new(cfg: &RtmConfig, media: &ShotMedia) -> Self {
        let (nz, nx, ny) = (cfg.nz, cfg.nx, cfg.ny);
        let kind = match media {
            ShotMedia::Vti(m) => PropKind::Vti {
                m: m.clone(),
                w2: second_deriv(4),
                st: VtiState::zeros(nz, nx, ny),
                sc: VtiScratch::new(nz, nx, ny),
            },
            ShotMedia::Tti(m) => PropKind::Tti {
                trig: TtiTrig::new(m),
                m: m.clone(),
                w2: second_deriv(4),
                w1: first_deriv(4),
                st: TtiState::zeros(nz, nx, ny),
                sc: TtiScratch::new(nz, nx, ny),
            },
        };
        Prop {
            eng: cfg.propagation_engine(),
            // per-step sponge + recording clamp the depth to 1 (§III-B)
            fuse: cfg.shot_time_block(),
            sponge: Sponge::new(nz, nx, ny, cfg.sponge_width, 0.0053),
            codec: cfg.halo_codec,
            kind,
        }
    }

    fn step_and_sponge(&mut self) {
        // after the sponge, run the propagating fields' radius-4
        // boundary shells through the wire codec — what a decomposed
        // run would have exchanged this step (replay uses the same
        // Prop, so recompute-based checkpointing stays bitwise)
        match &mut self.kind {
            PropKind::Vti { m, w2, st, sc } => {
                vti::step_k_with(st, m, w2, &self.eng, sc, self.fuse);
                self.sponge.apply(&mut st.sh);
                self.sponge.apply(&mut st.sv);
                self.sponge.apply(&mut st.sh_prev);
                self.sponge.apply(&mut st.sv_prev);
                quantize_shell(&mut st.sh, 4, self.codec);
                quantize_shell(&mut st.sv, 4, self.codec);
            }
            PropKind::Tti { m, trig, w2, w1, st, sc } => {
                tti::step_k_with(st, m, trig, w2, w1, &self.eng, sc, self.fuse);
                self.sponge.apply(&mut st.p);
                self.sponge.apply(&mut st.q);
                self.sponge.apply(&mut st.p_prev);
                self.sponge.apply(&mut st.q_prev);
                quantize_shell(&mut st.p, 4, self.codec);
                quantize_shell(&mut st.q, 4, self.codec);
            }
        }
    }

    /// One forward step: point-source injection, propagation, sponge.
    fn advance_source(&mut self, src: (usize, usize, usize), amp: f32) {
        match &mut self.kind {
            PropKind::Vti { st, .. } => st.inject(src.0, src.1, src.2, amp),
            PropKind::Tti { st, .. } => st.inject(src.0, src.1, src.2, amp),
        }
        self.step_and_sponge();
    }

    /// One adjoint step: receiver-plane trace injection into both
    /// fields, propagation, sponge.
    fn advance_traces(&mut self, z: usize, plane: &[f32]) {
        match &mut self.kind {
            PropKind::Vti { st, .. } => {
                inject_plane(&mut st.sh, z, plane);
                inject_plane(&mut st.sv, z, plane);
            }
            PropKind::Tti { st, .. } => {
                inject_plane(&mut st.p, z, plane);
                inject_plane(&mut st.q, z, plane);
            }
        }
        self.step_and_sponge();
    }

    fn imaging_field(&self) -> &Grid3 {
        match &self.kind {
            PropKind::Vti { st, .. } => &st.sh,
            PropKind::Tti { st, .. } => &st.p,
        }
    }

    /// Chaos hook (transport layer): overwrite one boundary-shell value
    /// of the propagating field with NaN — the footprint of a corrupted
    /// halo exchange.  The health monitor's energy scan flags it on the
    /// same step.
    fn corrupt_wire(&mut self) {
        match &mut self.kind {
            PropKind::Vti { st, .. } => st.sh.as_mut_slice()[0] = f32::NAN,
            PropKind::Tti { st, .. } => st.p.as_mut_slice()[0] = f32::NAN,
        }
    }

    fn record_plane(&self, z: usize) -> Vec<f32> {
        record_plane(self.imaging_field(), z)
    }

    fn energy(&self) -> f64 {
        match &self.kind {
            PropKind::Vti { st, .. } => st.energy(),
            PropKind::Tti { st, .. } => st.energy(),
        }
    }

    fn checkpoint(&self, step: usize) -> PropCheckpoint {
        match &self.kind {
            PropKind::Vti { st, .. } => PropCheckpoint {
                step,
                a: st.sh.clone(),
                b: st.sv.clone(),
                a_prev: st.sh_prev.clone(),
                b_prev: st.sv_prev.clone(),
            },
            PropKind::Tti { st, .. } => PropCheckpoint {
                step,
                a: st.p.clone(),
                b: st.q.clone(),
                a_prev: st.p_prev.clone(),
                b_prev: st.q_prev.clone(),
            },
        }
    }

    fn restore(&mut self, ck: &PropCheckpoint) {
        match &mut self.kind {
            PropKind::Vti { st, .. } => {
                st.sh = ck.a.clone();
                st.sv = ck.b.clone();
                st.sh_prev = ck.a_prev.clone();
                st.sv_prev = ck.b_prev.clone();
            }
            PropKind::Tti { st, .. } => {
                st.p = ck.a.clone();
                st.q = ck.b.clone();
                st.p_prev = ck.a_prev.clone();
                st.q_prev = ck.b_prev.clone();
            }
        }
    }
}

struct FwdTrace {
    traces: Vec<Vec<f32>>,
    energy_trace: Vec<f64>,
    max_trace: f32,
    forward_s: f64,
}

/// Why a forward attempt failed — routed differently by the pump: a
/// health verdict answers to [`SurveyConfig::health`], anything else to
/// the plain retry budget.
enum AttemptError {
    /// The wavefield health monitor tripped (non-finite or blown-up
    /// per-step energy).
    Health(String),
    /// Any other attempt failure (injected checkpoint fault, …); caught
    /// panics are converted by the pump, not here.
    Other(String),
}

/// One forward pass.  `site` is the shot/attempt-resolved fault plan:
/// a stall sleeps first, a kernel fault panics before the propagators
/// (the pump's `catch_unwind` contains it), transport corruption
/// poisons the wire shell after step 0 (lossy codecs only — an f32
/// shell is bitwise, there is nothing to corrupt), and a checkpoint
/// fault fails the first snapshot store.  Every step ends with the
/// health monitor: an O(1)-alloc scan of the per-step energy the pass
/// already computes — no extra reduction, no allocation.
fn forward_pass(
    cfg: &RtmConfig,
    media: &ShotMedia,
    store: &mut dyn SnapshotStore,
    site: &FaultSite,
) -> Result<FwdTrace, AttemptError> {
    if site.injects(FaultLayer::Stall) {
        // slowdown, not failure: the attempt proceeds (and must stay
        // bitwise) once the stall elapses
        std::thread::sleep(Duration::from_millis(STALL_MS));
    }
    if site.injects(FaultLayer::Kernel) {
        panic!("injected fault (kernel) on attempt {}", site.attempt);
    }
    let corrupt_wire = site.injects(FaultLayer::Transport) && cfg.halo_codec.is_lossy();
    let mut prop = Prop::new(cfg, media);
    let src = cfg.src_pos();
    let src_series = wavelet::ricker_series(cfg.steps, media.dt(), cfg.f0);
    let mut traces: Vec<Vec<f32>> = Vec::with_capacity(cfg.steps);
    let mut energy_trace = Vec::with_capacity(cfg.steps);
    let t_fwd = Timer::start();
    for (i, &amp) in src_series.iter().enumerate() {
        prop.advance_source(src, amp);
        if corrupt_wire && i == 0 {
            prop.corrupt_wire();
        }
        traces.push(prop.record_plane(cfg.receiver_z));
        let snap_due = i % cfg.snap_every == 0;
        if snap_due && i == 0 && site.injects(FaultLayer::Checkpoint) {
            return Err(AttemptError::Other(format!(
                "injected fault (checkpoint): snapshot store failed at step {i} on attempt {}",
                site.attempt
            )));
        }
        store.record(i, snap_due, prop.imaging_field(), &mut || prop.checkpoint(i));
        let e = prop.energy();
        energy_trace.push(e);
        if !e.is_finite() || e > HEALTH_ENERGY_CEILING {
            return Err(AttemptError::Health(format!(
                "wavefield energy {e:e} at step {i} is non-finite or above {HEALTH_ENERGY_CEILING:e}"
            )));
        }
    }
    let forward_s = t_fwd.secs();
    let max_trace = traces
        .iter()
        .flat_map(|t| t.iter().map(|v| v.abs()))
        .fold(0.0f32, f32::max);
    Ok(FwdTrace { traces, energy_trace, max_trace, forward_s })
}

fn adjoint_pass(
    cfg: &RtmConfig,
    media: &ShotMedia,
    store: &mut dyn SnapshotStore,
    traces: &[Vec<f32>],
) -> (Image, f64) {
    let mut rb = Prop::new(cfg, media);
    let mut image = Image::zeros(cfg.nz, cfg.nx, cfg.ny);
    let src = cfg.src_pos();
    let src_series = wavelet::ricker_series(cfg.steps, media.dt(), cfg.f0);
    // segment replay for recompute-based stores: resume from a
    // checkpoint and collect every snapshot field up to `upto` —
    // bitwise the original forward pass, because propagation is
    // deterministic and scratch is fully overwritten each step
    let mut replay = |ck: &PropCheckpoint, upto: usize| -> Vec<(usize, Grid3)> {
        let mut p = Prop::new(cfg, media);
        p.restore(ck);
        let mut out = Vec::new();
        for j in ck.step + 1..=upto {
            p.advance_source(src, src_series[j]);
            if j % cfg.snap_every == 0 {
                out.push((j, p.imaging_field().clone()));
            }
        }
        out
    };
    let t_bwd = Timer::start();
    for i in (0..cfg.steps).rev() {
        rb.advance_traces(cfg.receiver_z, &traces[i]);
        if i % cfg.snap_every == 0 {
            let snap = store.fetch(i, &mut replay);
            image.accumulate(&snap, rb.imaging_field());
        }
    }
    (image, t_bwd.secs())
}

fn assemble_report(
    cfg: &RtmConfig,
    platform: &Platform,
    fwd: FwdTrace,
    backward_s: f64,
    image_energy: f64,
) -> RtmReport {
    let (sim_step_s, sim_util) = driver::simulate_step(cfg, SimEngine::MMStencil, platform);
    let (sim_step_simd_s, _) = driver::simulate_step(cfg, SimEngine::Simd, platform);
    RtmReport {
        medium: cfg.medium,
        steps: cfg.steps,
        cells: cfg.cells(),
        forward_s: fwd.forward_s,
        backward_s,
        gpoints_per_s: (2.0 * 2.0 * cfg.steps as f64 * cfg.cells() as f64)
            / (fwd.forward_s + backward_s),
        energy_trace: fwd.energy_trace,
        max_trace: fwd.max_trace,
        image_energy,
        sim_bandwidth_util: sim_util,
        sim_step_s,
        sim_step_simd_s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::EngineKind;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn tiny_cfg(medium: Medium) -> RtmConfig {
        let mut cfg = RtmConfig::small(medium);
        cfg.nz = 20;
        cfg.nx = 20;
        cfg.ny = 20;
        cfg.steps = 12;
        cfg.threads = 2;
        cfg
    }

    #[test]
    fn checkpoint_strategy_parses_and_round_trips() {
        for (name, want) in [
            ("full_state", CheckpointStrategy::FullState),
            ("boundary_saving", CheckpointStrategy::BoundarySaving),
        ] {
            assert_eq!(CheckpointStrategy::parse(name), Ok(want));
            assert_eq!(want.name(), name);
        }
        let err = CheckpointStrategy::parse("rematerialize").unwrap_err();
        assert_eq!(err.what, "checkpoint strategy");
        assert!(err.to_string().contains("full_state | boundary_saving"), "{err}");
    }

    #[test]
    fn builder_validates_and_sets_fields() {
        let job = ShotJob::builder(tiny_cfg(Medium::Vti))
            .engine(EngineKind::MatrixUnit)
            .src(10, 9, 8)
            .steps(7)
            .build()
            .unwrap();
        assert_eq!(job.config().engine, EngineKind::MatrixUnit);
        assert_eq!(job.config().src, Some((10, 9, 8)));
        assert_eq!(job.config().steps, 7);
        // a tuned plan overlays engine + fan-out + depth in one value
        let plan = crate::stencil::TunePlan::parse(
            "engine=matrix_gemm vl=16 vz=4 tb=2 threads=3",
        )
        .unwrap();
        let job = ShotJob::builder(tiny_cfg(Medium::Vti)).plan(&plan).build().unwrap();
        assert_eq!(job.config().engine, EngineKind::MatrixGemm);
        assert_eq!(job.config().threads, 3);
        assert_eq!(job.config().time_block, 2);
        // out-of-bounds source rejected by the same builder
        let err = ShotJob::builder(tiny_cfg(Medium::Vti)).src(99, 0, 0).build().unwrap_err();
        assert!(matches!(err, ConfigError::SourceOutOfBounds { .. }));
    }

    // ----- queue contracts -------------------------------------------------

    #[test]
    fn queue_is_fifo_per_shard_under_saturation() {
        // capacity 2, 8 items: the producer repeatedly blocks on the
        // full lane; order must still come out exactly as submitted
        let q: Arc<ShardedQueue<usize>> = Arc::new(ShardedQueue::new(1, 2));
        let qc = q.clone();
        let consumer = std::thread::spawn(move || {
            let mut seen = Vec::new();
            while let Some(p) = qc.pop(0) {
                assert!(!p.stolen);
                seen.push(p.item);
                // slow consumer keeps the lane saturated
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            seen
        });
        for i in 0..8 {
            q.push(0, i);
        }
        q.close();
        let seen = consumer.join().unwrap();
        assert_eq!(seen, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn try_push_refuses_at_capacity_and_returns_the_item() {
        let q: ShardedQueue<String> = ShardedQueue::new(2, 1);
        assert!(q.try_push(0, "a".into()).is_ok());
        // lane 0 is full: refused, item handed back, nothing dropped
        let QueueFull(back) = q.try_push(0, "b".into()).unwrap_err();
        assert_eq!(back, "b");
        assert_eq!(q.len(0), 1);
        // the other lane still has room
        assert!(q.try_push(1, "c".into()).is_ok());
        let drained: Vec<String> = std::iter::from_fn(|| {
            q.close();
            q.pop(0).map(|p| p.item)
        })
        .collect();
        assert_eq!(drained, ["a", "c"]);
    }

    #[test]
    fn push_blocks_at_capacity_until_a_pop_frees_space() {
        let q: Arc<ShardedQueue<usize>> = Arc::new(ShardedQueue::new(1, 1));
        q.push(0, 0);
        let qc = q.clone();
        let blocked = Arc::new(AtomicUsize::new(0));
        let bc = blocked.clone();
        let producer = std::thread::spawn(move || {
            qc.push(0, 1); // must block: lane at capacity
            bc.store(1, Ordering::SeqCst);
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(blocked.load(Ordering::SeqCst), 0, "push returned while full");
        assert_eq!(q.pop(0).unwrap().item, 0);
        producer.join().unwrap();
        assert_eq!(blocked.load(Ordering::SeqCst), 1);
        assert_eq!(q.pop(0).unwrap().item, 1);
    }

    #[test]
    fn close_wakes_a_blocked_push_with_a_panic_not_a_deadlock() {
        // a submitter blocked on a full lane must not sleep forever
        // when the queue shuts down: close() notifies not_full too, the
        // waiter re-checks the closed flag and surfaces the driver bug
        // as the same "push on a closed queue" panic an un-blocked push
        // would have hit — never a deadlock, never a silent enqueue
        let q: Arc<ShardedQueue<usize>> = Arc::new(ShardedQueue::new(1, 1));
        q.push(0, 0);
        let qc = q.clone();
        let producer = std::thread::spawn(move || {
            qc.push(0, 1); // must block: lane at capacity
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        let payload = producer.join().unwrap_err();
        let msg = payload
            .downcast_ref::<&'static str>()
            .map(|s| s.to_string())
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        assert!(msg.contains("push on a closed queue"), "{msg:?}");
        // the blocked item was never enqueued: the lane drains exactly
        // its pre-close contents
        assert_eq!(q.pop(0).unwrap().item, 0);
        assert!(q.pop(0).is_none());
    }

    #[test]
    fn push_deadline_times_out_on_a_wedged_consumer() {
        let q: ShardedQueue<usize> = ShardedQueue::new(1, 1);
        q.push(0, 0); // lane full; nobody will ever pop
        let t = Instant::now();
        match q.push_deadline(0, 1, Duration::from_millis(30)) {
            Err(SubmitError::Timeout(item)) => assert_eq!(item, 1),
            other => panic!("expected a timeout, got {other:?}"),
        }
        assert!(t.elapsed() >= Duration::from_millis(30), "returned before the deadline");
        // the refused item was never enqueued...
        assert_eq!(q.len(0), 1);
        // ...and with room the deadline path enqueues normally
        assert_eq!(q.pop(0).unwrap().item, 0);
        q.push_deadline(0, 2, Duration::from_millis(30)).unwrap();
        assert_eq!(q.pop(0).unwrap().item, 2);
        // a closed queue surfaces as an error here, not the push panic
        q.close();
        assert!(matches!(
            q.push_deadline(0, 3, Duration::from_millis(5)),
            Err(SubmitError::Closed(3))
        ));
    }

    #[test]
    fn empty_shard_steals_from_a_neighbours_tail() {
        let q: ShardedQueue<usize> = ShardedQueue::new(2, 8);
        q.push(0, 10);
        q.push(0, 11);
        q.push(0, 12);
        q.close();
        // shard 1 is empty: it steals shard 0's TAIL (12), leaving the
        // victim's FIFO head intact
        let p = q.pop(1).unwrap();
        assert_eq!((p.item, p.stolen), (12, true));
        let p = q.pop(0).unwrap();
        assert_eq!((p.item, p.stolen), (10, false));
        assert_eq!(q.pop(0).unwrap().item, 11);
        assert!(q.pop(0).is_none());
    }

    #[test]
    fn dequeue_seq_is_a_global_total_order() {
        let q: ShardedQueue<usize> = ShardedQueue::new(2, 4);
        q.push(0, 0);
        q.push(1, 1);
        q.push(0, 2);
        q.close();
        let seqs: Vec<u64> = [q.pop(0), q.pop(1), q.pop(0)]
            .into_iter()
            .map(|p| p.unwrap().seq)
            .collect();
        assert_eq!(seqs, [1, 2, 3]);
    }

    // ----- checkpoint stores ----------------------------------------------

    #[test]
    fn both_strategies_image_bitwise_identically_with_less_memory_retained() {
        for medium in [Medium::Vti, Medium::Tti] {
            let mut cfg = tiny_cfg(medium);
            cfg.snap_every = 2; // 6 snapshot steps over 12 steps
            let media = match medium {
                Medium::Vti => ShotMedia::Vti(Arc::new(media::layered_vti(
                    cfg.nz,
                    cfg.nx,
                    cfg.ny,
                    cfg.dx,
                    &media::default_layers(),
                ))),
                Medium::Tti => ShotMedia::Tti(Arc::new(media::layered_tti(
                    cfg.nz,
                    cfg.nx,
                    cfg.ny,
                    cfg.dx,
                    &media::default_layers(),
                ))),
            };
            let mut full = FullStateStore::new();
            let site = FaultSite::none();
            let fwd_full = forward_pass(&cfg, &media, &mut full, &site).unwrap();
            // 6 keyframe-spaced snaps → 1 keyframe (4 grids) vs 6 grids
            let mut sparse = BoundarySavingStore::new(6);
            let fwd_sparse = forward_pass(&cfg, &media, &mut sparse, &site).unwrap();
            assert_eq!(fwd_full.traces, fwd_sparse.traces, "{medium:?}: forward diverged");
            assert!(
                sparse.retained_words() < full.retained_words(),
                "{medium:?}: boundary-saving retains {} words, full-state {}",
                sparse.retained_words(),
                full.retained_words()
            );
            let (img_full, _) = adjoint_pass(&cfg, &media, &mut full, &fwd_full.traces);
            let (img_sparse, _) = adjoint_pass(&cfg, &media, &mut sparse, &fwd_sparse.traces);
            assert_eq!(
                img_full.img.data, img_sparse.img.data,
                "{medium:?}: strategies must image bitwise identically"
            );
            assert_eq!(img_full.illum.data, img_sparse.illum.data, "{medium:?}");
            assert_eq!(img_full.correlations, img_sparse.correlations, "{medium:?}");
        }
    }

    #[test]
    fn halo_codec_shots_stay_stable_and_f32_is_a_no_op() {
        // the error budgets proper live in rust/tests/precision.rs;
        // this pins the Prop plumbing: explicit F32 is bitwise the
        // default, and a 16-bit codec genuinely perturbs the shell
        let p = Platform::paper();
        let base = tiny_cfg(Medium::Vti);
        let (img_def, rep_def) = driver::run_shot(&base, &p);
        let mut c = base.clone();
        c.halo_codec = HaloCodec::F32;
        let (img_f32, rep_f32) = driver::run_shot(&c, &p);
        assert_eq!(rep_def.energy_trace, rep_f32.energy_trace);
        assert_eq!(img_def.img.data, img_f32.img.data);
        let mut c = base;
        c.halo_codec = HaloCodec::Bf16;
        let (img_bf, rep_bf) = driver::run_shot(&c, &p);
        assert!(rep_bf.energy_trace.iter().all(|e| e.is_finite()));
        assert!(rep_bf.image_energy > 0.0);
        assert_ne!(img_bf.img.data, img_def.img.data, "bf16 shells must touch the shot");
    }

    // ----- reduction -------------------------------------------------------

    #[test]
    fn tree_reduction_is_deterministic_and_counts_correlations() {
        let imgs = |seed: u64| -> Vec<Image> {
            (0..5)
                .map(|i| {
                    let mut im = Image::zeros(4, 4, 4);
                    im.accumulate(
                        &Grid3::random(4, 4, 4, seed + i),
                        &Grid3::random(4, 4, 4, seed + 100 + i),
                    );
                    im
                })
                .collect()
        };
        let a = reduce_images(imgs(7)).unwrap();
        let b = reduce_images(imgs(7)).unwrap();
        assert_eq!(a.img.data, b.img.data);
        assert_eq!(a.correlations, 5);
        assert!(reduce_images(Vec::new()).is_none());
    }

    // ----- scheduler contracts --------------------------------------------

    #[test]
    fn failed_shot_is_retried_once_then_surfaced_without_wedging() {
        let mut runner =
            SurveyRunner::new(SurveyConfig::default(), &Platform::paper()).unwrap();
        let jobs = vec![
            // fails once, succeeds on the retry
            ShotJob::builder(tiny_cfg(Medium::Vti)).inject_faults(1).build().unwrap(),
            // exhausts the retry budget → recorded as Failed
            ShotJob::builder(tiny_cfg(Medium::Vti)).inject_faults(2).build().unwrap(),
            // healthy shot behind the failures must still complete
            ShotJob::builder(tiny_cfg(Medium::Vti)).build().unwrap(),
        ];
        let report = runner.run(jobs);
        assert_eq!(report.records.len(), 3);
        assert_eq!(report.records[0].status, ShotStatus::Completed);
        assert_eq!(report.records[0].attempts, 2);
        assert!(matches!(report.records[1].status, ShotStatus::Failed(_)));
        assert_eq!(report.records[1].attempts, 2);
        assert_eq!(report.records[2].status, ShotStatus::Completed);
        assert_eq!((report.completed(), report.failed(), report.retries()), (2, 1, 2));
        assert!(report.image.is_some(), "completed shots still accumulate");
        assert!(report.shots_per_hour() > 0.0);
    }

    #[test]
    fn run_one_surfaces_a_fault_exhausted_job_as_an_error() {
        let mut runner =
            SurveyRunner::new(SurveyConfig::one_shot(), &Platform::paper()).unwrap();
        let job = ShotJob::builder(tiny_cfg(Medium::Vti)).inject_faults(1).build().unwrap();
        // one_shot grants no retries: the single injected fault kills it
        let err = runner.run_one(job).unwrap_err();
        assert!(err.to_string().contains("injected fault"), "{err}");
    }

    #[test]
    fn runner_rejects_degenerate_scheduler_shapes() {
        let p = Platform::paper();
        let mut cfg = SurveyConfig::default();
        cfg.shards = 0;
        assert_eq!(SurveyRunner::new(cfg, &p).err(), Some(ConfigError::ZeroShards));
        let mut cfg = SurveyConfig::default();
        cfg.queue_capacity = 0;
        assert_eq!(SurveyRunner::new(cfg, &p).err(), Some(ConfigError::ZeroQueueCapacity));
        // too few workers are raised, not deadlocked
        let mut cfg = SurveyConfig::default();
        cfg.shards = 3;
        cfg.workers = 1;
        assert_eq!(SurveyRunner::new(cfg, &p).unwrap().workers(), 6);
    }
}
