//! TTI (Tilted Transverse Isotropic) leapfrog propagator (paper §II-A).
//!
//! Mirrors `python/compile/kernels/ref.py::tti_step`: the H1/H2 operators
//! need all six second derivatives; the mixed ones (∂xy, ∂yz, ∂xz) are
//! composed from two first-derivative 1D passes — the paper's §IV-G
//! commutative-composition scheme.  Periodic boundaries, axes (Z, X, Y).
//!
//! All eight axis passes per field dispatch through the engine layer
//! ([`stencil::engine`](crate::stencil::engine)): [`step_with`] /
//! [`Derivs::compute_with`] take any [`Engine`] and fan fixed z-slab
//! claims over the persistent worker runtime, like the VTI propagator.

use super::media::TtiMedia;
use crate::coordinator::pool;
use crate::grid::Grid3;
use crate::stencil::engine::AxisPass;
use crate::stencil::{Engine, TunePlan};

/// Leapfrog time levels of the TTI field pair (p, q).
pub struct TtiState {
    /// Quasi-P field, current time level.
    pub p: Grid3,
    /// Auxiliary (quasi-SV) field, current time level.
    pub q: Grid3,
    /// `p` one step back (overwritten with the next level each step).
    pub p_prev: Grid3,
    /// `q` one step back (overwritten with the next level each step).
    pub q_prev: Grid3,
}

impl TtiState {
    /// All-zero wavefields of the given shape.
    pub fn zeros(nz: usize, nx: usize, ny: usize) -> Self {
        Self {
            p: Grid3::zeros(nz, nx, ny),
            q: Grid3::zeros(nz, nx, ny),
            p_prev: Grid3::zeros(nz, nx, ny),
            q_prev: Grid3::zeros(nz, nx, ny),
        }
    }

    /// Add a point source sample to both fields.
    pub fn inject(&mut self, z: usize, x: usize, y: usize, amp: f32) {
        let i = self.p.idx(z, x, y);
        self.p.data[i] += amp;
        self.q.data[i] += amp;
    }

    /// Total wavefield energy (sum of squares of both fields).
    pub fn energy(&self) -> f64 {
        self.p.energy() + self.q.energy()
    }
}

/// Precomputed per-cell trig weights of the H1 operator — computing
/// sin/cos per cell per step would dominate the pointwise stage.
pub struct TtiTrig {
    /// sin²θ·cos²φ (∂xx weight).
    pub st2cp2: Vec<f32>,
    /// sin²θ·sin²φ (∂yy weight).
    pub st2sp2: Vec<f32>,
    /// cos²θ (∂zz weight).
    pub ct2: Vec<f32>,
    /// sin²θ·sin 2φ (∂xy weight).
    pub st2s2p: Vec<f32>,
    /// sin 2θ·sin φ (∂yz weight).
    pub s2t_sp: Vec<f32>,
    /// sin 2θ·cos φ (∂xz weight).
    pub s2t_cp: Vec<f32>,
}

impl TtiTrig {
    /// Precompute the weights from the medium's tilt/azimuth fields.
    pub fn new(m: &TtiMedia) -> Self {
        let n = m.theta.len();
        let mut t = Self {
            st2cp2: vec![0.0; n],
            st2sp2: vec![0.0; n],
            ct2: vec![0.0; n],
            st2s2p: vec![0.0; n],
            s2t_sp: vec![0.0; n],
            s2t_cp: vec![0.0; n],
        };
        for i in 0..n {
            let th = m.theta.data[i];
            let ph = m.phi.data[i];
            let (st, ct) = th.sin_cos();
            let (sp, cp) = ph.sin_cos();
            let st2 = st * st;
            let s2t = (2.0 * th).sin();
            t.st2cp2[i] = st2 * cp * cp;
            t.st2sp2[i] = st2 * sp * sp;
            t.ct2[i] = ct * ct;
            t.st2s2p[i] = st2 * (2.0 * ph).sin();
            t.s2t_sp[i] = s2t * sp;
            t.s2t_cp[i] = s2t * cp;
        }
        t
    }
}

/// The six second derivatives of one field, reused as scratch per step.
pub struct Derivs {
    /// ∂xx of the field.
    pub dxx: Grid3,
    /// ∂yy of the field.
    pub dyy: Grid3,
    /// ∂zz of the field.
    pub dzz: Grid3,
    /// Mixed ∂xy (two first-derivative passes).
    pub dxy: Grid3,
    /// Mixed ∂yz (two first-derivative passes).
    pub dyz: Grid3,
    /// Mixed ∂xz (two first-derivative passes).
    pub dxz: Grid3,
    d1: Grid3,
    d1b: Grid3,
}

impl Derivs {
    /// Derivative workspaces sized for `(nz, nx, ny)` fields.
    pub fn new(nz: usize, nx: usize, ny: usize) -> Self {
        let mk = || Grid3::zeros(nz, nx, ny);
        Self {
            dxx: mk(),
            dyy: mk(),
            dzz: mk(),
            dxy: mk(),
            dyz: mk(),
            dxz: mk(),
            d1: mk(),
            d1b: mk(),
        }
    }

    /// Fill all six derivative grids of `f` through the default simd
    /// engine — compatibility wrapper over [`compute_with`](Self::compute_with).
    pub fn compute(&mut self, f: &Grid3, w2: &[f32], w1: &[f32], threads: usize) {
        self.compute_with(f, w2, w1, &Engine::from_plan(&TunePlan::simd(threads)));
    }

    /// Fill all six derivative grids of `f` (mirror of
    /// `ref.py::tti_h1`'s derivative set) through an explicit engine:
    /// eight 1-D axis passes (three second-derivative, five
    /// first-derivative) dispatched over the persistent runtime as
    /// **two** batched fan-outs — the five passes reading `f` share one
    /// barrier, the three mixed-derivative second legs (reading the
    /// fresh ∂z/∂x intermediates) share another.  Bitwise identical to
    /// the eight sequential calls.
    pub fn compute_with(&mut self, f: &Grid3, w2: &[f32], w1: &[f32], eng: &Engine) {
        let Derivs { dxx, dyy, dzz, dxy, dyz, dxz, d1, d1b } = self;
        // level 1: everything that reads only f
        let mut first = [
            AxisPass { src: f, band: w2, axis: 1, out: &mut *dxx },
            AxisPass { src: f, band: w2, axis: 2, out: &mut *dyy },
            AxisPass { src: f, band: w2, axis: 0, out: &mut *dzz },
            AxisPass { src: f, band: w1, axis: 0, out: &mut *d1 }, // ∂z
            AxisPass { src: f, band: w1, axis: 1, out: &mut *d1b }, // ∂x
        ];
        eng.band_axes_into(&mut first);
        // level 2: the mixed derivatives' second legs (∂x/∂y of ∂z f,
        // ∂y of ∂x f); `first`'s borrows of d1/d1b ended with its last
        // use above, so the shared reborrows below are clean
        let mut second = [
            AxisPass { src: &*d1, band: w1, axis: 1, out: &mut *dxz },
            AxisPass { src: &*d1, band: w1, axis: 2, out: &mut *dyz },
            AxisPass { src: &*d1b, band: w1, axis: 2, out: &mut *dxy },
        ];
        eng.band_axes_into(&mut second);
    }

    /// h1 = Σ trig-weighted derivatives; h2 = laplacian − h1; written
    /// into the two output slices in one lockstep chunk pass.
    pub fn h1h2(&self, trig: &TtiTrig, h1: &mut [f32], h2: &mut [f32], threads: usize) {
        let (dxx, dyy, dzz) = (&self.dxx.data, &self.dyy.data, &self.dzz.data);
        let (dxy, dyz, dxz) = (&self.dxy.data, &self.dyz.data, &self.dxz.data);
        pool::parallel_mut_chunks2(threads, h1, h2, |off, c1, c2| {
            for i in 0..c1.len() {
                let j = off + i;
                let a = trig.st2cp2[j] * dxx[j]
                    + trig.st2sp2[j] * dyy[j]
                    + trig.ct2[j] * dzz[j]
                    + trig.st2s2p[j] * dxy[j]
                    + trig.s2t_sp[j] * dyz[j]
                    + trig.s2t_cp[j] * dxz[j];
                c1[i] = a;
                c2[i] = dxx[j] + dyy[j] + dzz[j] - a;
            }
        });
    }
}

/// Whole-step scratch: derivative workspaces + the four operator grids.
pub struct TtiScratch {
    dv: Derivs,
    h1p: Vec<f32>,
    h2p: Vec<f32>,
    h1q: Vec<f32>,
    h2q: Vec<f32>,
}

impl TtiScratch {
    /// Scratch sized for `(nz, nx, ny)` wavefields.
    pub fn new(nz: usize, nx: usize, ny: usize) -> Self {
        let n = nz * nx * ny;
        Self {
            dv: Derivs::new(nz, nx, ny),
            h1p: vec![0.0; n],
            h2p: vec![0.0; n],
            h1q: vec![0.0; n],
            h2q: vec![0.0; n],
        }
    }
}

/// One TTI leapfrog step through the default simd engine (velocity-
/// squared fields in `m` already carry the dt²/dx² factor, matching
/// `media::layered_tti`).  Compatibility wrapper over [`step_with`].
pub fn step(
    state: &mut TtiState,
    m: &TtiMedia,
    trig: &TtiTrig,
    w2: &[f32],
    w1: &[f32],
    threads: usize,
    s: &mut TtiScratch,
) {
    step_with(state, m, trig, w2, w1, &Engine::from_plan(&TunePlan::simd(threads)), s);
}

/// One TTI leapfrog step through an explicit [`Engine`]: 16 axis
/// passes (eight per field) fan over the persistent runtime in four
/// batched dispatches (two dependency levels per field — see
/// [`Derivs::compute_with`]), then the H1/H2 and leapfrog pointwise
/// stages run through the pool chunk helpers.  Bitwise-stable for any
/// `eng.threads`.
pub fn step_with(
    state: &mut TtiState,
    m: &TtiMedia,
    trig: &TtiTrig,
    w2: &[f32],
    w1: &[f32],
    eng: &Engine,
    s: &mut TtiScratch,
) {
    // decaying wavefields hit the x86 denormal cliff without FTZ
    crate::util::enable_flush_to_zero();
    let threads = eng.threads;
    s.dv.compute_with(&state.p, w2, w1, eng);
    s.dv.h1h2(trig, &mut s.h1p, &mut s.h2p, threads);
    s.dv.compute_with(&state.q, w2, w1, eng);
    s.dv.h1h2(trig, &mut s.h1q, &mut s.h2q, threads);

    let (h1p, h2p, h1q, h2q) = (&s.h1p, &s.h2p, &s.h1q, &s.h2q);
    let (p, q) = (&state.p.data, &state.q.data);
    let (vpx2, vpz2, vpn2, vsz2, alpha) =
        (&m.vpx2.data, &m.vpz2.data, &m.vpn2.data, &m.vsz2.data, &m.alpha.data);
    {
        let pp = &mut state.p_prev.data;
        pool::parallel_mut_chunks(threads, pp, |off, chunk| {
            for (i, out) in chunk.iter_mut().enumerate() {
                let j = off + i;
                let rhs = vpx2[j] * h2p[j] + alpha[j] * vpz2[j] * h1q[j]
                    + vsz2[j] * (h1p[j] - alpha[j] * h1q[j]);
                *out = 2.0 * p[j] - *out + rhs;
            }
        });
    }
    {
        let qp = &mut state.q_prev.data;
        pool::parallel_mut_chunks(threads, qp, |off, chunk| {
            for (i, out) in chunk.iter_mut().enumerate() {
                let j = off + i;
                let rhs = (vpn2[j] / alpha[j]) * h2p[j] + vpz2[j] * h1q[j]
                    - vsz2[j] * (h2p[j] / alpha[j] - h2q[j]);
                *out = 2.0 * q[j] - *out + rhs;
            }
        });
    }
    std::mem::swap(&mut state.p, &mut state.p_prev);
    std::mem::swap(&mut state.q, &mut state.q_prev);
}

/// `k` fused TTI leapfrog steps — the boundary-free `[runtime]
/// time_block` consumer, mirroring
/// [`vti::step_k_with`](super::vti::step_k_with): bitwise identical to
/// `k` calls of [`step_with`]; imaging shots stay at `k = 1` because
/// the sponge/injection/recording are per-step boundary operations
/// (paper §III-B).
pub fn step_k_with(
    state: &mut TtiState,
    m: &TtiMedia,
    trig: &TtiTrig,
    w2: &[f32],
    w1: &[f32],
    eng: &Engine,
    s: &mut TtiScratch,
    k: usize,
) {
    for _ in 0..k.max(1) {
        step_with(state, m, trig, w2, w1, eng, s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rtm::fixtures::{self, PAR_WORKERS, WORKER_COUNTS};
    use crate::stencil::coeffs::{first_deriv, second_deriv};
    use crate::stencil::EngineKind;
    use crate::util::prop::assert_allclose;

    fn planned(kind: EngineKind, workers: usize) -> Engine {
        Engine::from_plan(&TunePlan { engine: kind, threads: workers, ..TunePlan::simd(1) })
    }

    #[test]
    fn mixed_derivatives_commute() {
        // ∂x∂z f == ∂z∂x f when composed from the same bands
        let g = Grid3::random(8, 8, 8, 3);
        let w1 = first_deriv(4);
        let t = PAR_WORKERS;
        let a = super::super::vti::d1_axis(&super::super::vti::d1_axis(&g, &w1, 0, t), &w1, 1, t);
        let b = super::super::vti::d1_axis(&super::super::vti::d1_axis(&g, &w1, 1, t), &w1, 0, t);
        assert_allclose(&a.data, &b.data, 1e-4, 1e-5);
    }

    #[test]
    fn zero_tilt_h1_is_dzz() {
        // θ = 0 → H1 = ∂zz, H2 = ∂xx + ∂yy
        let (nz, nx, ny) = (8, 8, 8);
        let mut m = fixtures::tti_media(nz, nx, ny);
        m.theta = Grid3::zeros(nz, nx, ny);
        m.phi = Grid3::zeros(nz, nx, ny);
        let trig = TtiTrig::new(&m);
        let g = Grid3::random(nz, nx, ny, 5);
        let w2 = second_deriv(4);
        let w1 = first_deriv(4);
        let mut dv = Derivs::new(nz, nx, ny);
        let t = PAR_WORKERS;
        dv.compute(&g, &w2, &w1, t);
        let n = nz * nx * ny;
        let (mut h1, mut h2) = (vec![0.0; n], vec![0.0; n]);
        dv.h1h2(&trig, &mut h1, &mut h2, t);
        let dzz = super::super::vti::d2_axis(&g, &w2, 0, t);
        let dxx = super::super::vti::d2_axis(&g, &w2, 1, t);
        let dyy = super::super::vti::d2_axis(&g, &w2, 2, t);
        assert_allclose(&h1, &dzz.data, 1e-4, 1e-5);
        let want: Vec<f32> = dxx.data.iter().zip(&dyy.data).map(|(a, b)| a + b).collect();
        assert_allclose(&h2, &want, 1e-4, 1e-5);
    }

    #[test]
    fn h1_plus_h2_is_laplacian_any_tilt() {
        let (nz, nx, ny) = (6, 10, 7);
        let m = fixtures::tti_media(nz, nx, ny);
        let trig = TtiTrig::new(&m);
        let g = Grid3::random(nz, nx, ny, 9);
        let w2 = second_deriv(3);
        let w1 = first_deriv(3);
        let mut dv = Derivs::new(nz, nx, ny);
        let t = PAR_WORKERS;
        dv.compute(&g, &w2, &w1, t);
        let n = nz * nx * ny;
        let (mut h1, mut h2) = (vec![0.0; n], vec![0.0; n]);
        dv.h1h2(&trig, &mut h1, &mut h2, t);
        let lap: Vec<f32> = dv
            .dxx
            .data
            .iter()
            .zip(&dv.dyy.data)
            .zip(&dv.dzz.data)
            .map(|((a, b), c)| a + b + c)
            .collect();
        let got: Vec<f32> = h1.iter().zip(&h2).map(|(a, b)| a + b).collect();
        assert_allclose(&got, &lap, 1e-4, 1e-5);
    }

    #[test]
    fn impulse_stays_bounded() {
        let (nz, nx, ny) = (20, 20, 20);
        let m = fixtures::tti_media(nz, nx, ny);
        let trig = TtiTrig::new(&m);
        let mut st = TtiState::zeros(nz, nx, ny);
        let mut sc = TtiScratch::new(nz, nx, ny);
        st.inject(10, 10, 10, 1.0);
        let w2 = second_deriv(4);
        let w1 = first_deriv(4);
        for _ in 0..120 {
            step(&mut st, &m, &trig, &w2, &w1, PAR_WORKERS, &mut sc);
        }
        let e = st.energy();
        assert!(e.is_finite() && e < 1e6, "unstable: energy {e}");
    }

    #[test]
    fn threads_do_not_change_step() {
        let (nz, nx, ny) = (10, 10, 10);
        let m = fixtures::tti_media(nz, nx, ny);
        let trig = TtiTrig::new(&m);
        let w2 = second_deriv(2);
        let w1 = first_deriv(2);
        let run = |threads: usize| {
            let mut st = TtiState::zeros(nz, nx, ny);
            let mut sc = TtiScratch::new(nz, nx, ny);
            st.inject(5, 5, 5, 1.0);
            for _ in 0..5 {
                step(&mut st, &m, &trig, &w2, &w1, threads, &mut sc);
            }
            st.p
        };
        let a = run(WORKER_COUNTS[0]);
        for &workers in &WORKER_COUNTS[1..] {
            let b = run(workers);
            assert_eq!(a.data, b.data, "workers={workers}");
        }
    }

    #[test]
    fn fused_steps_are_bitwise_the_stepped_loop() {
        let (nz, nx, ny) = (10, 12, 14);
        let m = fixtures::tti_media(nz, nx, ny);
        let trig = TtiTrig::new(&m);
        let w2 = second_deriv(4);
        let w1 = first_deriv(4);
        let eng = planned(EngineKind::MatrixUnit, PAR_WORKERS);
        for k in [2usize, 3] {
            let mk = || {
                let mut st = TtiState::zeros(nz, nx, ny);
                st.inject(5, 6, 7, 1.0);
                st
            };
            let mut fused = mk();
            let mut sc = TtiScratch::new(nz, nx, ny);
            step_k_with(&mut fused, &m, &trig, &w2, &w1, &eng, &mut sc, k);
            let mut looped = mk();
            let mut sc2 = TtiScratch::new(nz, nx, ny);
            for _ in 0..k {
                step_with(&mut looped, &m, &trig, &w2, &w1, &eng, &mut sc2);
            }
            assert_eq!(fused.p.data, looped.p.data, "k={k}");
            assert_eq!(fused.q.data, looped.q.data, "k={k}");
        }
    }

    #[test]
    fn every_engine_tti_step_matches_the_naive_oracle() {
        let (nz, nx, ny) = (12, 14, 16);
        let m = fixtures::tti_media(nz, nx, ny);
        let trig = TtiTrig::new(&m);
        let w2 = second_deriv(4);
        let w1 = first_deriv(4);
        let run = |eng: &Engine| {
            let mut st = TtiState::zeros(nz, nx, ny);
            let mut sc = TtiScratch::new(nz, nx, ny);
            st.inject(6, 7, 8, 1.0);
            for _ in 0..4 {
                step_with(&mut st, &m, &trig, &w2, &w1, eng, &mut sc);
            }
            st
        };
        let oracle = run(&Engine::new(EngineKind::Naive));
        for kind in [EngineKind::Simd, EngineKind::MatrixUnit, EngineKind::MatrixGemm] {
            for &workers in &WORKER_COUNTS {
                let got = run(&planned(kind, workers));
                assert_allclose(&got.p.data, &oracle.p.data, 1e-4, 1e-6);
                assert_allclose(&got.q.data, &oracle.q.data, 1e-4, 1e-6);
            }
        }
    }
}
