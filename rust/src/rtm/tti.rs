//! TTI (Tilted Transverse Isotropic) leapfrog propagator (paper §II-A).
//!
//! Mirrors `python/compile/kernels/ref.py::tti_step`: the H1/H2 operators
//! need all six second derivatives; the mixed ones (∂xy, ∂yz, ∂xz) are
//! composed from two first-derivative 1D passes — the paper's §IV-G
//! commutative-composition scheme.  Periodic boundaries, axes (Z, X, Y).

use super::media::TtiMedia;
use super::vti::{d1_axis_into, d2_axis_into};
use crate::coordinator::pool;
use crate::grid::Grid3;

/// Leapfrog time levels of the TTI field pair (p, q).
pub struct TtiState {
    pub p: Grid3,
    pub q: Grid3,
    pub p_prev: Grid3,
    pub q_prev: Grid3,
}

impl TtiState {
    pub fn zeros(nz: usize, nx: usize, ny: usize) -> Self {
        Self {
            p: Grid3::zeros(nz, nx, ny),
            q: Grid3::zeros(nz, nx, ny),
            p_prev: Grid3::zeros(nz, nx, ny),
            q_prev: Grid3::zeros(nz, nx, ny),
        }
    }

    pub fn inject(&mut self, z: usize, x: usize, y: usize, amp: f32) {
        let i = self.p.idx(z, x, y);
        self.p.data[i] += amp;
        self.q.data[i] += amp;
    }

    pub fn energy(&self) -> f64 {
        self.p.energy() + self.q.energy()
    }
}

/// Precomputed per-cell trig weights of the H1 operator — computing
/// sin/cos per cell per step would dominate the pointwise stage.
pub struct TtiTrig {
    pub st2cp2: Vec<f32>,
    pub st2sp2: Vec<f32>,
    pub ct2: Vec<f32>,
    pub st2s2p: Vec<f32>,
    pub s2t_sp: Vec<f32>,
    pub s2t_cp: Vec<f32>,
}

impl TtiTrig {
    pub fn new(m: &TtiMedia) -> Self {
        let n = m.theta.len();
        let mut t = Self {
            st2cp2: vec![0.0; n],
            st2sp2: vec![0.0; n],
            ct2: vec![0.0; n],
            st2s2p: vec![0.0; n],
            s2t_sp: vec![0.0; n],
            s2t_cp: vec![0.0; n],
        };
        for i in 0..n {
            let th = m.theta.data[i];
            let ph = m.phi.data[i];
            let (st, ct) = th.sin_cos();
            let (sp, cp) = ph.sin_cos();
            let st2 = st * st;
            let s2t = (2.0 * th).sin();
            t.st2cp2[i] = st2 * cp * cp;
            t.st2sp2[i] = st2 * sp * sp;
            t.ct2[i] = ct * ct;
            t.st2s2p[i] = st2 * (2.0 * ph).sin();
            t.s2t_sp[i] = s2t * sp;
            t.s2t_cp[i] = s2t * cp;
        }
        t
    }
}

/// The six second derivatives of one field, reused as scratch per step.
pub struct Derivs {
    pub dxx: Grid3,
    pub dyy: Grid3,
    pub dzz: Grid3,
    pub dxy: Grid3,
    pub dyz: Grid3,
    pub dxz: Grid3,
    d1: Grid3,
    d1b: Grid3,
}

impl Derivs {
    pub fn new(nz: usize, nx: usize, ny: usize) -> Self {
        let mk = || Grid3::zeros(nz, nx, ny);
        Self {
            dxx: mk(),
            dyy: mk(),
            dzz: mk(),
            dxy: mk(),
            dyz: mk(),
            dxz: mk(),
            d1: mk(),
            d1b: mk(),
        }
    }

    /// Fill all six derivative grids of `f` (mirror of
    /// `ref.py::tti_h1`'s derivative set).
    pub fn compute(&mut self, f: &Grid3, w2: &[f32], w1: &[f32], threads: usize) {
        d2_axis_into(f, w2, 1, &mut self.dxx, threads);
        d2_axis_into(f, w2, 2, &mut self.dyy, threads);
        d2_axis_into(f, w2, 0, &mut self.dzz, threads);
        // ∂z then ∂x / ∂y of it
        d1_axis_into(f, w1, 0, &mut self.d1, threads);
        d1_axis_into(&self.d1, w1, 1, &mut self.dxz, threads);
        d1_axis_into(&self.d1, w1, 2, &mut self.dyz, threads);
        // ∂x then ∂y of it
        d1_axis_into(f, w1, 1, &mut self.d1b, threads);
        d1_axis_into(&self.d1b, w1, 2, &mut self.dxy, threads);
    }

    /// h1 = Σ trig-weighted derivatives; h2 = laplacian − h1; written
    /// into the two output slices in one lockstep chunk pass.
    pub fn h1h2(&self, trig: &TtiTrig, h1: &mut [f32], h2: &mut [f32], threads: usize) {
        let (dxx, dyy, dzz) = (&self.dxx.data, &self.dyy.data, &self.dzz.data);
        let (dxy, dyz, dxz) = (&self.dxy.data, &self.dyz.data, &self.dxz.data);
        pool::parallel_mut_chunks2(threads, h1, h2, |off, c1, c2| {
            for i in 0..c1.len() {
                let j = off + i;
                let a = trig.st2cp2[j] * dxx[j]
                    + trig.st2sp2[j] * dyy[j]
                    + trig.ct2[j] * dzz[j]
                    + trig.st2s2p[j] * dxy[j]
                    + trig.s2t_sp[j] * dyz[j]
                    + trig.s2t_cp[j] * dxz[j];
                c1[i] = a;
                c2[i] = dxx[j] + dyy[j] + dzz[j] - a;
            }
        });
    }
}

/// Whole-step scratch: derivative workspaces + the four operator grids.
pub struct TtiScratch {
    dv: Derivs,
    h1p: Vec<f32>,
    h2p: Vec<f32>,
    h1q: Vec<f32>,
    h2q: Vec<f32>,
}

impl TtiScratch {
    pub fn new(nz: usize, nx: usize, ny: usize) -> Self {
        let n = nz * nx * ny;
        Self {
            dv: Derivs::new(nz, nx, ny),
            h1p: vec![0.0; n],
            h2p: vec![0.0; n],
            h1q: vec![0.0; n],
            h2q: vec![0.0; n],
        }
    }
}

/// One TTI leapfrog step (velocity-squared fields in `m` already carry
/// the dt²/dx² factor, matching `media::layered_tti`).
pub fn step(
    state: &mut TtiState,
    m: &TtiMedia,
    trig: &TtiTrig,
    w2: &[f32],
    w1: &[f32],
    threads: usize,
    s: &mut TtiScratch,
) {
    // decaying wavefields hit the x86 denormal cliff without FTZ
    crate::util::enable_flush_to_zero();
    s.dv.compute(&state.p, w2, w1, threads);
    s.dv.h1h2(trig, &mut s.h1p, &mut s.h2p, threads);
    s.dv.compute(&state.q, w2, w1, threads);
    s.dv.h1h2(trig, &mut s.h1q, &mut s.h2q, threads);

    let (h1p, h2p, h1q, h2q) = (&s.h1p, &s.h2p, &s.h1q, &s.h2q);
    let (p, q) = (&state.p.data, &state.q.data);
    let (vpx2, vpz2, vpn2, vsz2, alpha) =
        (&m.vpx2.data, &m.vpz2.data, &m.vpn2.data, &m.vsz2.data, &m.alpha.data);
    {
        let pp = &mut state.p_prev.data;
        pool::parallel_mut_chunks(threads, pp, |off, chunk| {
            for (i, out) in chunk.iter_mut().enumerate() {
                let j = off + i;
                let rhs = vpx2[j] * h2p[j] + alpha[j] * vpz2[j] * h1q[j]
                    + vsz2[j] * (h1p[j] - alpha[j] * h1q[j]);
                *out = 2.0 * p[j] - *out + rhs;
            }
        });
    }
    {
        let qp = &mut state.q_prev.data;
        pool::parallel_mut_chunks(threads, qp, |off, chunk| {
            for (i, out) in chunk.iter_mut().enumerate() {
                let j = off + i;
                let rhs = (vpn2[j] / alpha[j]) * h2p[j] + vpz2[j] * h1q[j]
                    - vsz2[j] * (h2p[j] / alpha[j] - h2q[j]);
                *out = 2.0 * q[j] - *out + rhs;
            }
        });
    }
    std::mem::swap(&mut state.p, &mut state.p_prev);
    std::mem::swap(&mut state.q, &mut state.q_prev);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rtm::media;
    use crate::stencil::coeffs::{first_deriv, second_deriv};
    use crate::util::prop::assert_allclose;

    #[test]
    fn mixed_derivatives_commute() {
        // ∂x∂z f == ∂z∂x f when composed from the same bands
        let g = Grid3::random(8, 8, 8, 3);
        let w1 = first_deriv(4);
        let a = super::super::vti::d1_axis(&super::super::vti::d1_axis(&g, &w1, 0, 2), &w1, 1, 2);
        let b = super::super::vti::d1_axis(&super::super::vti::d1_axis(&g, &w1, 1, 2), &w1, 0, 2);
        assert_allclose(&a.data, &b.data, 1e-4, 1e-5);
    }

    #[test]
    fn zero_tilt_h1_is_dzz() {
        // θ = 0 → H1 = ∂zz, H2 = ∂xx + ∂yy
        let (nz, nx, ny) = (8, 8, 8);
        let mut m = media::layered_tti(nz, nx, ny, 10.0, &media::default_layers());
        m.theta = Grid3::zeros(nz, nx, ny);
        m.phi = Grid3::zeros(nz, nx, ny);
        let trig = TtiTrig::new(&m);
        let g = Grid3::random(nz, nx, ny, 5);
        let w2 = second_deriv(4);
        let w1 = first_deriv(4);
        let mut dv = Derivs::new(nz, nx, ny);
        dv.compute(&g, &w2, &w1, 2);
        let n = nz * nx * ny;
        let (mut h1, mut h2) = (vec![0.0; n], vec![0.0; n]);
        dv.h1h2(&trig, &mut h1, &mut h2, 2);
        let dzz = super::super::vti::d2_axis(&g, &w2, 0, 2);
        let dxx = super::super::vti::d2_axis(&g, &w2, 1, 2);
        let dyy = super::super::vti::d2_axis(&g, &w2, 2, 2);
        assert_allclose(&h1, &dzz.data, 1e-4, 1e-5);
        let want: Vec<f32> = dxx.data.iter().zip(&dyy.data).map(|(a, b)| a + b).collect();
        assert_allclose(&h2, &want, 1e-4, 1e-5);
    }

    #[test]
    fn h1_plus_h2_is_laplacian_any_tilt() {
        let (nz, nx, ny) = (6, 10, 7);
        let m = media::layered_tti(nz, nx, ny, 10.0, &media::default_layers());
        let trig = TtiTrig::new(&m);
        let g = Grid3::random(nz, nx, ny, 9);
        let w2 = second_deriv(3);
        let w1 = first_deriv(3);
        let mut dv = Derivs::new(nz, nx, ny);
        dv.compute(&g, &w2, &w1, 3);
        let n = nz * nx * ny;
        let (mut h1, mut h2) = (vec![0.0; n], vec![0.0; n]);
        dv.h1h2(&trig, &mut h1, &mut h2, 3);
        let lap: Vec<f32> = dv
            .dxx
            .data
            .iter()
            .zip(&dv.dyy.data)
            .zip(&dv.dzz.data)
            .map(|((a, b), c)| a + b + c)
            .collect();
        let got: Vec<f32> = h1.iter().zip(&h2).map(|(a, b)| a + b).collect();
        assert_allclose(&got, &lap, 1e-4, 1e-5);
    }

    #[test]
    fn impulse_stays_bounded() {
        let (nz, nx, ny) = (20, 20, 20);
        let m = media::layered_tti(nz, nx, ny, 10.0, &media::default_layers());
        let trig = TtiTrig::new(&m);
        let mut st = TtiState::zeros(nz, nx, ny);
        let mut sc = TtiScratch::new(nz, nx, ny);
        st.inject(10, 10, 10, 1.0);
        let w2 = second_deriv(4);
        let w1 = first_deriv(4);
        for _ in 0..120 {
            step(&mut st, &m, &trig, &w2, &w1, 4, &mut sc);
        }
        let e = st.energy();
        assert!(e.is_finite() && e < 1e6, "unstable: energy {e}");
    }

    #[test]
    fn threads_do_not_change_step() {
        let (nz, nx, ny) = (10, 10, 10);
        let m = media::layered_tti(nz, nx, ny, 10.0, &media::default_layers());
        let trig = TtiTrig::new(&m);
        let w2 = second_deriv(2);
        let w1 = first_deriv(2);
        let run = |threads: usize| {
            let mut st = TtiState::zeros(nz, nx, ny);
            let mut sc = TtiScratch::new(nz, nx, ny);
            st.inject(5, 5, 5, 1.0);
            for _ in 0..5 {
                step(&mut st, &m, &trig, &w2, &w1, threads, &mut sc);
            }
            st.p
        };
        let a = run(1);
        let b = run(6);
        assert_eq!(a.data, b.data);
    }
}
