//! RTM shot driver (paper §V-F): forward propagation with a Ricker
//! source, surface-trace recording, backward propagation of the
//! time-reversed traces, and zero-lag imaging with snapshot
//! checkpointing — the full real-world workflow MMStencil integrates
//! into, with simulated-platform metrics attached.
//!
//! The propagation engine is part of the shot configuration
//! ([`RtmConfig::engine`]): both passes step through the engine
//! dispatch layer, so one config field switches a whole shot between
//! the naive oracle, the simd baseline, and the matrix-unit engine
//! (the paper's headline 1.8× RTM claim is exactly this switch).

use super::boundary::Sponge;
use super::image::Image;
use super::media::{self, TtiMedia, VtiMedia};
use super::tti::{self, TtiScratch, TtiState, TtiTrig};
use super::vti::{self, VtiScratch, VtiState};
use super::wavelet;
use crate::grid::Grid3;
use crate::simulator::roofline::{self, Engine as SimEngine, MemKind};
use crate::simulator::Platform;
use crate::stencil::coeffs::{first_deriv, second_deriv};
use crate::stencil::{Engine, EngineKind, StencilSpec};
use crate::util::Timer;

/// Anisotropy model of the run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Medium {
    /// Vertical transverse isotropy (pseudo-acoustic σH/σV pair).
    Vti,
    /// Tilted transverse isotropy (p/q pair with tilt/azimuth fields).
    Tti,
}

/// Shot configuration.
#[derive(Clone, Debug)]
pub struct RtmConfig {
    /// Anisotropy model of the run.
    pub medium: Medium,
    /// Grid cells along z (depth).
    pub nz: usize,
    /// Grid cells along x.
    pub nx: usize,
    /// Grid cells along y.
    pub ny: usize,
    /// grid spacing (m)
    pub dx: f64,
    /// forward/backward timesteps
    pub steps: usize,
    /// Ricker peak frequency (Hz)
    pub f0: f64,
    /// Worker-parallelism of the propagators (slab fan-out + pointwise
    /// chunking).
    pub threads: usize,
    /// store a source snapshot every k steps for imaging
    pub snap_every: usize,
    /// Absorbing-sponge ramp width (cells).
    pub sponge_width: usize,
    /// source position (z, x, y); default mid-surface
    pub src: Option<(usize, usize, usize)>,
    /// receiver plane depth (z index)
    pub receiver_z: usize,
    /// Stencil engine both propagation passes dispatch through
    /// (`EngineKind::by_name` selects it from configs/CLI).
    pub engine: EngineKind,
    /// Requested temporal-blocking depth (`[runtime] time_block`, CLI
    /// `rtm --time_block`).  [`run_shot`] consumes it through
    /// [`RtmConfig::shot_time_block`], which **clamps imaging shots to
    /// depth 1** — the sponge, source injection, and receiver recording
    /// are per-step boundary operations, the exact §III-B constraint
    /// that "boundary handling often constrains the depth of temporal
    /// blocking" (DESIGN.md §11).  Boundary-free callers pass the full
    /// value to [`vti::step_k_with`]/[`tti::step_k_with`] instead.
    pub time_block: usize,
}

impl RtmConfig {
    /// A small default shot (48³, 120 steps, simd engine).
    pub fn small(medium: Medium) -> Self {
        Self {
            medium,
            nz: 48,
            nx: 48,
            ny: 48,
            dx: 10.0,
            steps: 120,
            f0: 15.0,
            threads: 4,
            snap_every: 4,
            sponge_width: 8,
            src: None,
            receiver_z: 2,
            engine: EngineKind::Simd,
            time_block: 1,
        }
    }

    /// Source position: configured, or just below the sponge at the
    /// lateral centre.
    pub fn src_pos(&self) -> (usize, usize, usize) {
        self.src.unwrap_or((self.sponge_width + 1, self.nx / 2, self.ny / 2))
    }

    /// Total grid cells.
    pub fn cells(&self) -> usize {
        self.nz * self.nx * self.ny
    }

    /// The configured propagation engine, threaded per the config.
    pub fn propagation_engine(&self) -> Engine {
        Engine::new(self.engine).with_threads(self.threads)
    }

    /// The temporal-blocking depth an imaging shot can actually fuse:
    /// [`time_block`](Self::time_block) **clamped to 1**.  Every
    /// `run_shot` step applies the absorbing sponge and records the
    /// receiver plane (the backward pass also re-injects traces), and
    /// each of those must observe every intermediate time level —
    /// fusing across them would change the physics, not just the
    /// schedule.  This is the paper's §III-B observation made
    /// executable; the periodic, boundary-free entries
    /// ([`vti::step_k_with`]/[`tti::step_k_with`]) take the full
    /// requested depth instead.
    pub fn shot_time_block(&self) -> usize {
        self.time_block.clamp(1, 1)
    }
}

/// Metrics of one shot.
#[derive(Clone, Debug)]
pub struct RtmReport {
    /// Anisotropy model of the shot.
    pub medium: Medium,
    /// Timesteps per pass.
    pub steps: usize,
    /// Grid cells.
    pub cells: usize,
    /// Wall time of the forward pass (s).
    pub forward_s: f64,
    /// Wall time of the backward pass (s).
    pub backward_s: f64,
    /// grid-point updates per second (both passes, both fields)
    pub gpoints_per_s: f64,
    /// wavefield energy after each forward step
    pub energy_trace: Vec<f64>,
    /// max |trace| recorded at the receiver plane
    pub max_trace: f32,
    /// Energy of the accumulated zero-lag image.
    pub image_energy: f64,
    /// simulated single-NUMA bandwidth utilization on the paper platform
    pub sim_bandwidth_util: f64,
    /// simulated per-step time on the paper platform (MMStencil engine)
    pub sim_step_s: f64,
    /// simulated per-step time for the SIMD baseline (speedup denominator)
    pub sim_step_simd_s: f64,
}

impl RtmReport {
    /// Predicted MMStencil-over-SIMD speedup on the paper platform
    /// (paper: 2.00× VTI, 2.06× TTI).
    pub fn sim_speedup_vs_simd(&self) -> f64 {
        self.sim_step_simd_s / self.sim_step_s
    }
}

/// Equivalent radius-4 star-sweep count of one timestep: how many
/// full-grid stencil-sweep times (8 B/point of traffic each) the
/// medium's update costs.  VTI: two stencil passes (xy-laplacian of σH,
/// ∂zz of σV) + the leapfrog/media pointwise traffic (read prev pair +
/// three media fields, write pair ≈ 0.74 sweep-equivalents) → 2.74.
/// TTI: 9 axis passes per field shared through the §IV-G thread-private
/// block buffers ≈ 3.4 + leapfrog/media traffic (seven media fields)
/// ≈ 0.7 → 4.1 (× the 1.15 intermediate-spill penalty below = 4.7,
/// matching the paper's 27.35% utilization).
pub fn equiv_sweeps(medium: Medium) -> f64 {
    match medium {
        Medium::Vti => 2.74,
        Medium::Tti => 4.10,
    }
}

/// Temporal (intermediate-placement) penalty of a VTI step: none.  The
/// VTI update's three derivative grids fit the paper's thread-private
/// L1 block buffers, so no intermediate spills to memory — the §III-B
/// "memory usage conflict between adjacent layers" that temporal
/// blocking manages stays inside the cache hierarchy.
pub const VTI_TEMPORAL_SPILL_PENALTY: f64 = 1.0;

/// Temporal penalty of a TTI step: its six second-derivative
/// intermediates exceed L1 (paper §V-F reports bandwidth utilization
/// dropping to 27.35%), so adjacent-layer traffic spills — the §III-B
/// boundary on how deep intermediates can be blocked in time.  The
/// 1.15× factor charges that extra load/store traffic; together with
/// [`equiv_sweeps`]'s 4.10 it reproduces the paper's TTI utilization.
pub const TTI_TEMPORAL_SPILL_PENALTY: f64 = 1.15;

/// Application-integration penalty of the *baseline* engines on a VTI
/// step (paper §IV-G): the SIMD/naive RTM codes round-trip each
/// derivative pass's intermediates through main memory, while MMStencil
/// keeps them in thread-private buffers per block.  On a memory-bound
/// step that costs the baselines ~an extra half sweep of traffic per
/// derivative pass → 1.49× for VTI's three passes.
pub const VTI_BASELINE_INTEGRATION_PENALTY: f64 = 1.49;

/// [`VTI_BASELINE_INTEGRATION_PENALTY`]'s TTI counterpart: eight
/// passes per field push the baseline round-trip overhead to 1.55×
/// (paper §IV-G / §V-F; with the spill penalty this yields the ~2.06×
/// reported RTM speedup).
pub const TTI_BASELINE_INTEGRATION_PENALTY: f64 = 1.55;

/// The temporal spill penalty for `medium` (the
/// `*_TEMPORAL_SPILL_PENALTY` constants, which every engine pays).
pub fn temporal_penalty(medium: Medium) -> f64 {
    match medium {
        Medium::Vti => VTI_TEMPORAL_SPILL_PENALTY,
        Medium::Tti => TTI_TEMPORAL_SPILL_PENALTY,
    }
}

/// The integration penalty for `medium` under `engine`: 1 for
/// MMStencil (its block buffers absorb the intermediates), the
/// `*_BASELINE_INTEGRATION_PENALTY` constants otherwise.
pub fn integration_penalty(medium: Medium, engine: SimEngine) -> f64 {
    if engine == SimEngine::MMStencil {
        return 1.0;
    }
    match medium {
        Medium::Vti => VTI_BASELINE_INTEGRATION_PENALTY,
        Medium::Tti => TTI_BASELINE_INTEGRATION_PENALTY,
    }
}

/// Simulated per-step time + bandwidth utilization on the paper
/// platform for one NUMA node (used by Fig. 14/15 benches too).
pub fn simulate_step(cfg: &RtmConfig, engine: SimEngine, p: &Platform) -> (f64, f64) {
    let spec = StencilSpec::star3d(4);
    let est = roofline::predict(
        &spec,
        cfg.cells(),
        engine,
        roofline::engine_cfg(engine, MemKind::OnPkg),
        p,
    );
    let sweeps = equiv_sweeps(cfg.medium);
    let spill = temporal_penalty(cfg.medium);
    let integration = integration_penalty(cfg.medium, engine);
    let t = est.time_s * sweeps * spill * integration;
    // the paper's application metric counts the two updated stress/field
    // grids as useful traffic (2 × 8 B/point/step) against the full step
    // time — so utilization divides by the sweep-equivalents spent
    let util = est.bandwidth_util * 2.0 / (sweeps * spill * integration);
    (t, util)
}

/// Run one complete RTM shot (forward + backward + imaging).
pub fn run_shot(cfg: &RtmConfig, platform: &Platform) -> (Image, RtmReport) {
    match cfg.medium {
        Medium::Vti => run_shot_vti(cfg, platform),
        Medium::Tti => run_shot_tti(cfg, platform),
    }
}

fn record_plane(g: &Grid3, z: usize) -> Vec<f32> {
    g.as_slice()[z * g.nx * g.ny..(z + 1) * g.nx * g.ny].to_vec()
}

fn inject_plane(g: &mut Grid3, z: usize, plane: &[f32]) {
    let off = z * g.nx * g.ny;
    for (d, &s) in g.as_mut_slice()[off..off + plane.len()].iter_mut().zip(plane) {
        *d += s;
    }
}

fn run_shot_vti(cfg: &RtmConfig, platform: &Platform) -> (Image, RtmReport) {
    let (nz, nx, ny) = (cfg.nz, cfg.nx, cfg.ny);
    let m: VtiMedia = media::layered_vti(nz, nx, ny, cfg.dx, &media::default_layers());
    let w2 = second_deriv(4);
    let eng = cfg.propagation_engine();
    // per-step sponge + recording clamp the fusable depth to 1 (§III-B)
    let fuse = cfg.shot_time_block();
    let sponge = Sponge::new(nz, nx, ny, cfg.sponge_width, 0.0053);
    let (sz, sx, sy) = cfg.src_pos();
    let src_series = wavelet::ricker_series(cfg.steps, m.dt, cfg.f0);

    // ---- forward pass: record surface traces + snapshots -----------------
    let mut st = VtiState::zeros(nz, nx, ny);
    let mut sc = VtiScratch::new(nz, nx, ny);
    let mut snaps: Vec<(usize, Grid3)> = Vec::new();
    let mut traces: Vec<Vec<f32>> = Vec::with_capacity(cfg.steps);
    let mut energy_trace = Vec::with_capacity(cfg.steps);
    let t_fwd = Timer::start();
    for (i, &amp) in src_series.iter().enumerate() {
        st.inject(sz, sx, sy, amp);
        vti::step_k_with(&mut st, &m, &w2, &eng, &mut sc, fuse);
        sponge.apply(&mut st.sh);
        sponge.apply(&mut st.sv);
        sponge.apply(&mut st.sh_prev);
        sponge.apply(&mut st.sv_prev);
        traces.push(record_plane(&st.sh, cfg.receiver_z));
        if i % cfg.snap_every == 0 {
            snaps.push((i, st.sh.clone()));
        }
        energy_trace.push(st.energy());
    }
    let forward_s = t_fwd.secs();
    let max_trace = traces
        .iter()
        .flat_map(|t| t.iter().map(|v| v.abs()))
        .fold(0.0f32, f32::max);

    // ---- backward pass: re-inject time-reversed traces, correlate --------
    let mut rb = VtiState::zeros(nz, nx, ny);
    let mut image = Image::zeros(nz, nx, ny);
    let mut snap_iter = snaps.iter().rev().peekable();
    let t_bwd = Timer::start();
    for i in (0..cfg.steps).rev() {
        inject_plane(&mut rb.sh, cfg.receiver_z, &traces[i]);
        inject_plane(&mut rb.sv, cfg.receiver_z, &traces[i]);
        vti::step_k_with(&mut rb, &m, &w2, &eng, &mut sc, fuse);
        sponge.apply(&mut rb.sh);
        sponge.apply(&mut rb.sv);
        sponge.apply(&mut rb.sh_prev);
        sponge.apply(&mut rb.sv_prev);
        if let Some(&&(si, _)) = snap_iter.peek() {
            if si == i {
                let (_, snap) = snap_iter.next().unwrap();
                image.accumulate(snap, &rb.sh);
            }
        }
    }
    let backward_s = t_bwd.secs();

    let (sim_step_s, sim_util) = simulate_step(cfg, SimEngine::MMStencil, platform);
    let (sim_step_simd_s, _) = simulate_step(cfg, SimEngine::Simd, platform);
    let report = RtmReport {
        medium: Medium::Vti,
        steps: cfg.steps,
        cells: cfg.cells(),
        forward_s,
        backward_s,
        gpoints_per_s: (2.0 * 2.0 * cfg.steps as f64 * cfg.cells() as f64)
            / (forward_s + backward_s),
        energy_trace,
        max_trace,
        image_energy: image.img.energy(),
        sim_bandwidth_util: sim_util,
        sim_step_s,
        sim_step_simd_s,
    };
    (image, report)
}

fn run_shot_tti(cfg: &RtmConfig, platform: &Platform) -> (Image, RtmReport) {
    let (nz, nx, ny) = (cfg.nz, cfg.nx, cfg.ny);
    let m: TtiMedia = media::layered_tti(nz, nx, ny, cfg.dx, &media::default_layers());
    let trig = TtiTrig::new(&m);
    let w2 = second_deriv(4);
    let w1 = first_deriv(4);
    let eng = cfg.propagation_engine();
    // per-step sponge + recording clamp the fusable depth to 1 (§III-B)
    let fuse = cfg.shot_time_block();
    let sponge = Sponge::new(nz, nx, ny, cfg.sponge_width, 0.0053);
    let (sz, sx, sy) = cfg.src_pos();
    let src_series = wavelet::ricker_series(cfg.steps, m.dt, cfg.f0);

    let mut st = TtiState::zeros(nz, nx, ny);
    let mut sc = TtiScratch::new(nz, nx, ny);
    let mut snaps: Vec<(usize, Grid3)> = Vec::new();
    let mut traces: Vec<Vec<f32>> = Vec::with_capacity(cfg.steps);
    let mut energy_trace = Vec::with_capacity(cfg.steps);
    let t_fwd = Timer::start();
    for (i, &amp) in src_series.iter().enumerate() {
        st.inject(sz, sx, sy, amp);
        tti::step_k_with(&mut st, &m, &trig, &w2, &w1, &eng, &mut sc, fuse);
        sponge.apply(&mut st.p);
        sponge.apply(&mut st.q);
        sponge.apply(&mut st.p_prev);
        sponge.apply(&mut st.q_prev);
        traces.push(record_plane(&st.p, cfg.receiver_z));
        if i % cfg.snap_every == 0 {
            snaps.push((i, st.p.clone()));
        }
        energy_trace.push(st.energy());
    }
    let forward_s = t_fwd.secs();
    let max_trace = traces
        .iter()
        .flat_map(|t| t.iter().map(|v| v.abs()))
        .fold(0.0f32, f32::max);

    let mut rb = TtiState::zeros(nz, nx, ny);
    let mut image = Image::zeros(nz, nx, ny);
    let mut snap_iter = snaps.iter().rev().peekable();
    let t_bwd = Timer::start();
    for i in (0..cfg.steps).rev() {
        inject_plane(&mut rb.p, cfg.receiver_z, &traces[i]);
        inject_plane(&mut rb.q, cfg.receiver_z, &traces[i]);
        tti::step_k_with(&mut rb, &m, &trig, &w2, &w1, &eng, &mut sc, fuse);
        sponge.apply(&mut rb.p);
        sponge.apply(&mut rb.q);
        sponge.apply(&mut rb.p_prev);
        sponge.apply(&mut rb.q_prev);
        if let Some(&&(si, _)) = snap_iter.peek() {
            if si == i {
                let (_, snap) = snap_iter.next().unwrap();
                image.accumulate(snap, &rb.p);
            }
        }
    }
    let backward_s = t_bwd.secs();

    let (sim_step_s, sim_util) = simulate_step(cfg, SimEngine::MMStencil, platform);
    let (sim_step_simd_s, _) = simulate_step(cfg, SimEngine::Simd, platform);
    let report = RtmReport {
        medium: Medium::Tti,
        steps: cfg.steps,
        cells: cfg.cells(),
        forward_s,
        backward_s,
        gpoints_per_s: (2.0 * 2.0 * cfg.steps as f64 * cfg.cells() as f64)
            / (forward_s + backward_s),
        energy_trace,
        max_trace,
        image_energy: image.img.energy(),
        sim_bandwidth_util: sim_util,
        sim_step_s,
        sim_step_simd_s,
    };
    (image, report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vti_shot_produces_image_and_stable_trace() {
        let mut cfg = RtmConfig::small(Medium::Vti);
        cfg.nz = 32;
        cfg.nx = 32;
        cfg.ny = 32;
        cfg.steps = 60;
        let p = Platform::paper();
        let (image, rep) = run_shot(&cfg, &p);
        assert!(rep.max_trace > 0.0, "no signal reached the receivers");
        assert!(rep.image_energy > 0.0, "empty image");
        assert!(image.correlations > 0);
        assert!(rep.energy_trace.iter().all(|e| e.is_finite()));
        assert!(rep.gpoints_per_s > 0.0);
    }

    #[test]
    fn tti_shot_produces_image_and_stable_trace() {
        let mut cfg = RtmConfig::small(Medium::Tti);
        cfg.nz = 24;
        cfg.nx = 24;
        cfg.ny = 24;
        cfg.steps = 40;
        cfg.threads = 2;
        let p = Platform::paper();
        let (image, rep) = run_shot(&cfg, &p);
        assert!(rep.max_trace > 0.0);
        assert!(image.correlations > 0);
        assert!(rep.energy_trace.iter().all(|e| e.is_finite()));
    }

    #[test]
    fn penalty_constants_pin_the_estimator() {
        // the named constants are the paper-derived model inputs; this
        // pins both their values and their wiring through simulate_step
        // so a silent edit of either shows up as a test diff
        assert_eq!(VTI_TEMPORAL_SPILL_PENALTY, 1.0);
        assert_eq!(TTI_TEMPORAL_SPILL_PENALTY, 1.15);
        assert_eq!(VTI_BASELINE_INTEGRATION_PENALTY, 1.49);
        assert_eq!(TTI_BASELINE_INTEGRATION_PENALTY, 1.55);
        let p = Platform::paper();
        for medium in [Medium::Vti, Medium::Tti] {
            let cfg = RtmConfig::small(medium);
            for engine in [SimEngine::MMStencil, SimEngine::Simd] {
                let est = roofline::predict(
                    &StencilSpec::star3d(4),
                    cfg.cells(),
                    engine,
                    roofline::engine_cfg(engine, MemKind::OnPkg),
                    &p,
                );
                // mirror the estimator's exact expression shape: fp
                // multiplication association matters for bit equality
                let sweeps = equiv_sweeps(medium);
                let spill = temporal_penalty(medium);
                let integration = integration_penalty(medium, engine);
                let (t, util) = simulate_step(&cfg, engine, &p);
                assert_eq!(
                    t,
                    est.time_s * sweeps * spill * integration,
                    "{medium:?} {engine:?} step time"
                );
                assert_eq!(
                    util,
                    est.bandwidth_util * 2.0 / (sweeps * spill * integration),
                    "{medium:?} {engine:?} utilization"
                );
            }
        }
        // the MMStencil engine never pays the integration penalty; the
        // baselines pay exactly the named constants
        assert_eq!(integration_penalty(Medium::Vti, SimEngine::MMStencil), 1.0);
        assert_eq!(
            integration_penalty(Medium::Vti, SimEngine::Simd),
            VTI_BASELINE_INTEGRATION_PENALTY
        );
        assert_eq!(
            integration_penalty(Medium::Tti, SimEngine::Simd),
            TTI_BASELINE_INTEGRATION_PENALTY
        );
    }

    #[test]
    fn shots_clamp_temporal_blocking_to_one() {
        // §III-B made executable: whatever depth the config requests,
        // an imaging shot fuses nothing (sponge + recording per step),
        // and the result is bit-identical to the default config's
        let p = Platform::paper();
        let mut a = RtmConfig::small(Medium::Vti);
        a.nz = 20;
        a.nx = 20;
        a.ny = 20;
        a.steps = 12;
        let mut b = a.clone();
        b.time_block = 4;
        assert_eq!(b.shot_time_block(), 1);
        let (ia, ra) = run_shot(&a, &p);
        let (ib, rb) = run_shot(&b, &p);
        assert_eq!(ra.energy_trace, rb.energy_trace);
        assert_eq!(ia.img.data, ib.img.data);
    }

    #[test]
    fn sim_speedup_matches_paper_band() {
        // paper §V-F: 2.00× (VTI) and 2.06× (TTI) over the SIMD version
        let p = Platform::paper();
        for medium in [Medium::Vti, Medium::Tti] {
            let cfg = RtmConfig::small(medium);
            let (t_mm, _) = simulate_step(&cfg, SimEngine::MMStencil, &p);
            let (t_simd, _) = simulate_step(&cfg, SimEngine::Simd, &p);
            let s = t_simd / t_mm;
            assert!(
                (1.4..3.0).contains(&s),
                "{medium:?}: simulated speedup {s} outside plausible band"
            );
        }
    }

    #[test]
    fn vti_util_band_near_paper() {
        // paper: 47% bandwidth utilization for VTI on one NUMA node
        let p = Platform::paper();
        let cfg = RtmConfig::small(Medium::Vti);
        let (_, util) = simulate_step(&cfg, SimEngine::MMStencil, &p);
        assert!((0.3..0.7).contains(&util), "VTI util {util}");
    }

    #[test]
    fn shots_through_every_engine_image_the_same_reflectors() {
        // the config engine switch runs the whole shot through each
        // engine; images must agree closely (engines differ only in fp
        // accumulation order)
        let p = Platform::paper();
        let mut energies = Vec::new();
        for kind in EngineKind::ALL {
            let mut cfg = RtmConfig::small(Medium::Vti);
            cfg.nz = 24;
            cfg.nx = 24;
            cfg.ny = 24;
            cfg.steps = 30;
            cfg.threads = 2;
            cfg.engine = kind;
            let (image, rep) = run_shot(&cfg, &p);
            assert!(rep.image_energy > 0.0, "{kind:?}: empty image");
            assert!(image.correlations > 0);
            energies.push(rep.image_energy);
        }
        for e in &energies[1..] {
            assert!(
                (e / energies[0] - 1.0).abs() < 1e-2,
                "image energies diverge across engines: {energies:?}"
            );
        }
    }
}
