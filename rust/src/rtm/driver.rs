//! RTM shot driver (paper §V-F): forward propagation with a Ricker
//! source, surface-trace recording, backward propagation of the
//! time-reversed traces, and zero-lag imaging with snapshot
//! checkpointing — the full real-world workflow MMStencil integrates
//! into, with simulated-platform metrics attached.
//!
//! The propagation engine is part of the shot configuration
//! ([`RtmConfig::engine`]): both passes step through the engine
//! dispatch layer, so one config field switches a whole shot between
//! the naive oracle, the simd baseline, and the matrix-unit engine
//! (the paper's headline 1.8× RTM claim is exactly this switch).
//!
//! The shot loop itself lives in [`super::service`]: [`run_shot`] is a
//! thin compatibility wrapper that runs a single validated
//! [`ShotJob`](super::service::ShotJob) through a one-shot
//! [`SurveyRunner`](super::service::SurveyRunner).  This module keeps
//! the configuration ([`RtmConfig`], [`ConfigError`]), the report type,
//! and the simulated-platform cost model the service attaches to every
//! shot.

use super::image::Image;
use super::service;
use crate::grid::halo::HaloCodec;
use crate::simulator::roofline::{self, Engine as SimEngine, MemKind};
use crate::simulator::Platform;
use crate::stencil::{Engine, EngineKind, StencilSpec, TunePlan};
use std::fmt;

/// Anisotropy model of the run.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Medium {
    /// Vertical transverse isotropy (pseudo-acoustic σH/σV pair).
    Vti,
    /// Tilted transverse isotropy (p/q pair with tilt/azimuth fields).
    Tti,
}

/// Shot configuration.
#[derive(Clone, Debug)]
pub struct RtmConfig {
    /// Anisotropy model of the run.
    pub medium: Medium,
    /// Grid cells along z (depth).
    pub nz: usize,
    /// Grid cells along x.
    pub nx: usize,
    /// Grid cells along y.
    pub ny: usize,
    /// grid spacing (m)
    pub dx: f64,
    /// forward/backward timesteps
    pub steps: usize,
    /// Ricker peak frequency (Hz)
    pub f0: f64,
    /// Worker-parallelism of the propagators (slab fan-out + pointwise
    /// chunking).
    pub threads: usize,
    /// store a source snapshot every k steps for imaging
    pub snap_every: usize,
    /// Absorbing-sponge ramp width (cells).
    pub sponge_width: usize,
    /// source position (z, x, y); default mid-surface
    pub src: Option<(usize, usize, usize)>,
    /// receiver plane depth (z index)
    pub receiver_z: usize,
    /// Stencil engine both propagation passes dispatch through
    /// (`EngineKind::parse` selects it from configs/CLI).
    pub engine: EngineKind,
    /// Requested temporal-blocking depth (`[runtime] time_block`, CLI
    /// `rtm --time_block`).  [`run_shot`] consumes it through
    /// [`RtmConfig::shot_time_block`], which **clamps imaging shots to
    /// depth 1** — the sponge, source injection, and receiver recording
    /// are per-step boundary operations, the exact §III-B constraint
    /// that "boundary handling often constrains the depth of temporal
    /// blocking" (DESIGN.md §11).  Boundary-free callers pass the full
    /// value to [`vti::step_k_with`]/[`tti::step_k_with`] instead.
    pub time_block: usize,
    /// Wire codec the shot services apply to the radius-4 boundary
    /// shells of the propagating wavefields each step (`[runtime]
    /// halo_codec`, CLI `--halo_codec`) — the single-rank analogue of
    /// the multirank halo compression: the shell is what a decomposed
    /// run would put on the wire.  [`HaloCodec::F32`] (the default) is
    /// a no-op, keeping shots bitwise; the 16-bit codecs bound the
    /// injected error per `rust/tests/precision.rs`.
    pub halo_codec: HaloCodec,
}

impl RtmConfig {
    /// A small default shot (48³, 120 steps, simd engine).
    pub fn small(medium: Medium) -> Self {
        Self {
            medium,
            nz: 48,
            nx: 48,
            ny: 48,
            dx: 10.0,
            steps: 120,
            f0: 15.0,
            threads: 4,
            snap_every: 4,
            sponge_width: 8,
            src: None,
            receiver_z: 2,
            engine: EngineKind::Simd,
            time_block: 1,
            halo_codec: HaloCodec::F32,
        }
    }

    /// Source position: configured, or just below the sponge at the
    /// lateral centre.
    pub fn src_pos(&self) -> (usize, usize, usize) {
        self.src.unwrap_or((self.sponge_width + 1, self.nx / 2, self.ny / 2))
    }

    /// Total grid cells.
    pub fn cells(&self) -> usize {
        self.nz * self.nx * self.ny
    }

    /// The configured propagation engine, threaded per the config
    /// (default block geometry — a tuned geometry arrives via
    /// [`with_plan`](Self::with_plan) selecting the engine kind, and the
    /// propagators' own blocking).
    pub fn propagation_engine(&self) -> Engine {
        Engine::from_plan(&TunePlan {
            engine: self.engine,
            threads: self.threads.max(1),
            ..TunePlan::simd(1)
        })
    }

    /// Overlay a tuned plan onto this config: the plan selects the
    /// propagation engine, the worker fan-out, and the requested
    /// temporal-blocking depth (imaging shots still clamp fusion to 1 —
    /// [`shot_time_block`](Self::shot_time_block)).
    pub fn with_plan(mut self, plan: &TunePlan) -> Self {
        self.engine = plan.engine;
        self.threads = plan.threads.max(1);
        self.time_block = plan.time_block.max(1);
        self.halo_codec = plan.halo;
        self
    }

    /// Builder-style halo-codec override.  The resilience layer leans
    /// on this: the `fallback_f32_codec` health policy re-runs a sick
    /// attempt with [`HaloCodec::F32`] forced (lossless wire — nothing
    /// left to corrupt), and the chaos tests flip codecs per shot.
    pub fn with_halo_codec(mut self, codec: HaloCodec) -> Self {
        self.halo_codec = codec;
        self
    }

    /// The temporal-blocking depth an imaging shot can actually fuse:
    /// [`time_block`](Self::time_block) **clamped to 1**.  Every
    /// `run_shot` step applies the absorbing sponge and records the
    /// receiver plane (the backward pass also re-injects traces), and
    /// each of those must observe every intermediate time level —
    /// fusing across them would change the physics, not just the
    /// schedule.  This is the paper's §III-B observation made
    /// executable; the periodic, boundary-free entries
    /// ([`vti::step_k_with`]/[`tti::step_k_with`]) take the full
    /// requested depth instead.
    pub fn shot_time_block(&self) -> usize {
        self.time_block.clamp(1, 1)
    }

    /// Check every field combination that would otherwise panic deep
    /// inside the propagators: the grid must cover the radius-4 stencil
    /// halo, the receiver plane and source position must be in bounds,
    /// and the snapshot cadence must be non-zero.  Called by the
    /// [`ShotJob`](super::service::ShotJob) builder and the config/CLI
    /// paths, so a bad field is reported where the file or flag context
    /// still exists.
    pub fn validate(&self) -> Result<(), ConfigError> {
        let min = MIN_GRID_CELLS;
        if self.nz < min || self.nx < min || self.ny < min {
            return Err(ConfigError::GridTooSmall {
                nz: self.nz,
                nx: self.nx,
                ny: self.ny,
                min,
            });
        }
        if self.steps == 0 {
            return Err(ConfigError::ZeroSteps);
        }
        if self.snap_every == 0 {
            return Err(ConfigError::ZeroSnapEvery);
        }
        if self.receiver_z >= self.nz {
            return Err(ConfigError::ReceiverOutOfRange {
                receiver_z: self.receiver_z,
                nz: self.nz,
            });
        }
        let src = self.src_pos();
        if src.0 >= self.nz || src.1 >= self.nx || src.2 >= self.ny {
            return Err(ConfigError::SourceOutOfBounds {
                src,
                dims: (self.nz, self.nx, self.ny),
            });
        }
        Ok(())
    }
}

/// Minimum grid cells per axis: the radius-4 halo on both sides plus
/// the centre plane (2·4 + 1) — smaller grids have no interior for the
/// propagators to update.
pub const MIN_GRID_CELLS: usize = 9;

/// A rejected [`RtmConfig`] (or survey-scheduler shape): every variant
/// is a field combination that used to panic deep inside
/// `run_shot_vti`'s grid indexing instead of failing at construction.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ConfigError {
    /// A grid axis is smaller than the propagation stencil needs.
    GridTooSmall {
        /// Configured z extent.
        nz: usize,
        /// Configured x extent.
        nx: usize,
        /// Configured y extent.
        ny: usize,
        /// Minimum cells per axis ([`MIN_GRID_CELLS`]).
        min: usize,
    },
    /// `steps = 0`: the shot would propagate nothing and image nothing.
    ZeroSteps,
    /// `snap_every = 0`: the imaging loop's snapshot cadence divides by
    /// this value.
    ZeroSnapEvery,
    /// The receiver plane lies at or below the bottom of the grid.
    ReceiverOutOfRange {
        /// Configured receiver depth index.
        receiver_z: usize,
        /// Grid z extent it must stay inside.
        nz: usize,
    },
    /// The (resolved) source position lies outside the grid.
    SourceOutOfBounds {
        /// Resolved source position (`RtmConfig::src_pos`).
        src: (usize, usize, usize),
        /// Grid extents it must stay inside.
        dims: (usize, usize, usize),
    },
    /// A survey was configured with zero queue shards.
    ZeroShards,
    /// A survey was configured with a zero-capacity bounded queue.
    ZeroQueueCapacity,
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConfigError::GridTooSmall { nz, nx, ny, min } => write!(
                f,
                "grid {nz}×{nx}×{ny} is smaller than the radius-4 stencil halo \
                 (need ≥ {min} cells per axis)"
            ),
            ConfigError::ZeroSteps => write!(f, "steps must be ≥ 1"),
            ConfigError::ZeroSnapEvery => {
                write!(f, "snap_every must be ≥ 1 (the imaging loop divides by it)")
            }
            ConfigError::ReceiverOutOfRange { receiver_z, nz } => {
                write!(f, "receiver_z {receiver_z} is outside the grid (nz = {nz})")
            }
            ConfigError::SourceOutOfBounds { src, dims } => write!(
                f,
                "source position ({}, {}, {}) is outside the {}×{}×{} grid",
                src.0, src.1, src.2, dims.0, dims.1, dims.2
            ),
            ConfigError::ZeroShards => write!(f, "survey shards must be ≥ 1"),
            ConfigError::ZeroQueueCapacity => write!(f, "survey queue_capacity must be ≥ 1"),
        }
    }
}

impl std::error::Error for ConfigError {}

/// Metrics of one shot.
#[derive(Clone, Debug)]
pub struct RtmReport {
    /// Anisotropy model of the shot.
    pub medium: Medium,
    /// Timesteps per pass.
    pub steps: usize,
    /// Grid cells.
    pub cells: usize,
    /// Wall time of the forward pass (s).
    pub forward_s: f64,
    /// Wall time of the backward pass (s).
    pub backward_s: f64,
    /// grid-point updates per second (both passes, both fields)
    pub gpoints_per_s: f64,
    /// wavefield energy after each forward step
    pub energy_trace: Vec<f64>,
    /// max |trace| recorded at the receiver plane
    pub max_trace: f32,
    /// Energy of the accumulated zero-lag image.
    pub image_energy: f64,
    /// simulated single-NUMA bandwidth utilization on the paper platform
    pub sim_bandwidth_util: f64,
    /// simulated per-step time on the paper platform (MMStencil engine)
    pub sim_step_s: f64,
    /// simulated per-step time for the SIMD baseline (speedup denominator)
    pub sim_step_simd_s: f64,
}

impl RtmReport {
    /// Predicted MMStencil-over-SIMD speedup on the paper platform
    /// (paper: 2.00× VTI, 2.06× TTI).
    pub fn sim_speedup_vs_simd(&self) -> f64 {
        self.sim_step_simd_s / self.sim_step_s
    }
}

/// Equivalent radius-4 star-sweep count of one timestep: how many
/// full-grid stencil-sweep times (8 B/point of traffic each) the
/// medium's update costs.  VTI: two stencil passes (xy-laplacian of σH,
/// ∂zz of σV) + the leapfrog/media pointwise traffic (read prev pair +
/// three media fields, write pair ≈ 0.74 sweep-equivalents) → 2.74.
/// TTI: 9 axis passes per field shared through the §IV-G thread-private
/// block buffers ≈ 3.4 + leapfrog/media traffic (seven media fields)
/// ≈ 0.7 → 4.1 (× the 1.15 intermediate-spill penalty below = 4.7,
/// matching the paper's 27.35% utilization).
pub fn equiv_sweeps(medium: Medium) -> f64 {
    match medium {
        Medium::Vti => 2.74,
        Medium::Tti => 4.10,
    }
}

/// Temporal (intermediate-placement) penalty of a VTI step: none.  The
/// VTI update's three derivative grids fit the paper's thread-private
/// L1 block buffers, so no intermediate spills to memory — the §III-B
/// "memory usage conflict between adjacent layers" that temporal
/// blocking manages stays inside the cache hierarchy.
pub const VTI_TEMPORAL_SPILL_PENALTY: f64 = 1.0;

/// Temporal penalty of a TTI step: its six second-derivative
/// intermediates exceed L1 (paper §V-F reports bandwidth utilization
/// dropping to 27.35%), so adjacent-layer traffic spills — the §III-B
/// boundary on how deep intermediates can be blocked in time.  The
/// 1.15× factor charges that extra load/store traffic; together with
/// [`equiv_sweeps`]'s 4.10 it reproduces the paper's TTI utilization.
pub const TTI_TEMPORAL_SPILL_PENALTY: f64 = 1.15;

/// Application-integration penalty of the *baseline* engines on a VTI
/// step (paper §IV-G): the SIMD/naive RTM codes round-trip each
/// derivative pass's intermediates through main memory, while MMStencil
/// keeps them in thread-private buffers per block.  On a memory-bound
/// step that costs the baselines ~an extra half sweep of traffic per
/// derivative pass → 1.49× for VTI's three passes.
pub const VTI_BASELINE_INTEGRATION_PENALTY: f64 = 1.49;

/// [`VTI_BASELINE_INTEGRATION_PENALTY`]'s TTI counterpart: eight
/// passes per field push the baseline round-trip overhead to 1.55×
/// (paper §IV-G / §V-F; with the spill penalty this yields the ~2.06×
/// reported RTM speedup).
pub const TTI_BASELINE_INTEGRATION_PENALTY: f64 = 1.55;

/// The temporal spill penalty for `medium` (the
/// `*_TEMPORAL_SPILL_PENALTY` constants, which every engine pays).
pub fn temporal_penalty(medium: Medium) -> f64 {
    match medium {
        Medium::Vti => VTI_TEMPORAL_SPILL_PENALTY,
        Medium::Tti => TTI_TEMPORAL_SPILL_PENALTY,
    }
}

/// The integration penalty for `medium` under `engine`: 1 for
/// MMStencil (its block buffers absorb the intermediates), the
/// `*_BASELINE_INTEGRATION_PENALTY` constants otherwise.
pub fn integration_penalty(medium: Medium, engine: SimEngine) -> f64 {
    if engine == SimEngine::MMStencil {
        return 1.0;
    }
    match medium {
        Medium::Vti => VTI_BASELINE_INTEGRATION_PENALTY,
        Medium::Tti => TTI_BASELINE_INTEGRATION_PENALTY,
    }
}

/// Simulated per-step time + bandwidth utilization on the paper
/// platform for one NUMA node (used by Fig. 14/15 benches too).
pub fn simulate_step(cfg: &RtmConfig, engine: SimEngine, p: &Platform) -> (f64, f64) {
    let spec = StencilSpec::star3d(4);
    let est = roofline::predict(
        &spec,
        cfg.cells(),
        engine,
        roofline::engine_cfg(engine, MemKind::OnPkg),
        p,
    );
    let sweeps = equiv_sweeps(cfg.medium);
    let spill = temporal_penalty(cfg.medium);
    let integration = integration_penalty(cfg.medium, engine);
    let t = est.time_s * sweeps * spill * integration;
    // the paper's application metric counts the two updated stress/field
    // grids as useful traffic (2 × 8 B/point/step) against the full step
    // time — so utilization divides by the sweep-equivalents spent
    let util = est.bandwidth_util * 2.0 / (sweeps * spill * integration);
    (t, util)
}

/// Run one complete RTM shot (forward + backward + imaging).
///
/// Compatibility wrapper over the survey service: builds a single
/// validated [`ShotJob`](service::ShotJob) and runs it through a
/// one-shot [`SurveyRunner`](service::SurveyRunner) (one shard,
/// full-state snapshots) — bit-identical to the pre-service shot loop.
/// Panics on an invalid config; callers that want the error instead use
/// the builder + [`SurveyRunner::run_one`](service::SurveyRunner::run_one).
pub fn run_shot(cfg: &RtmConfig, platform: &Platform) -> (Image, RtmReport) {
    let job = service::ShotJob::builder(cfg.clone())
        .build()
        .unwrap_or_else(|e| panic!("run_shot: invalid RtmConfig: {e}"));
    let mut runner = service::SurveyRunner::new(service::SurveyConfig::one_shot(), platform)
        .expect("one-shot survey config is statically valid");
    runner
        .run_one(job)
        .expect("a shot without injected faults cannot fail")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vti_shot_produces_image_and_stable_trace() {
        let mut cfg = RtmConfig::small(Medium::Vti);
        cfg.nz = 32;
        cfg.nx = 32;
        cfg.ny = 32;
        cfg.steps = 60;
        let p = Platform::paper();
        let (image, rep) = run_shot(&cfg, &p);
        assert!(rep.max_trace > 0.0, "no signal reached the receivers");
        assert!(rep.image_energy > 0.0, "empty image");
        assert!(image.correlations > 0);
        assert!(rep.energy_trace.iter().all(|e| e.is_finite()));
        assert!(rep.gpoints_per_s > 0.0);
    }

    #[test]
    fn tti_shot_produces_image_and_stable_trace() {
        let mut cfg = RtmConfig::small(Medium::Tti);
        cfg.nz = 24;
        cfg.nx = 24;
        cfg.ny = 24;
        cfg.steps = 40;
        cfg.threads = 2;
        let p = Platform::paper();
        let (image, rep) = run_shot(&cfg, &p);
        assert!(rep.max_trace > 0.0);
        assert!(image.correlations > 0);
        assert!(rep.energy_trace.iter().all(|e| e.is_finite()));
    }

    #[test]
    fn penalty_constants_pin_the_estimator() {
        // the named constants are the paper-derived model inputs; this
        // pins both their values and their wiring through simulate_step
        // so a silent edit of either shows up as a test diff
        assert_eq!(VTI_TEMPORAL_SPILL_PENALTY, 1.0);
        assert_eq!(TTI_TEMPORAL_SPILL_PENALTY, 1.15);
        assert_eq!(VTI_BASELINE_INTEGRATION_PENALTY, 1.49);
        assert_eq!(TTI_BASELINE_INTEGRATION_PENALTY, 1.55);
        let p = Platform::paper();
        for medium in [Medium::Vti, Medium::Tti] {
            let cfg = RtmConfig::small(medium);
            for engine in [SimEngine::MMStencil, SimEngine::Simd] {
                let est = roofline::predict(
                    &StencilSpec::star3d(4),
                    cfg.cells(),
                    engine,
                    roofline::engine_cfg(engine, MemKind::OnPkg),
                    &p,
                );
                // mirror the estimator's exact expression shape: fp
                // multiplication association matters for bit equality
                let sweeps = equiv_sweeps(medium);
                let spill = temporal_penalty(medium);
                let integration = integration_penalty(medium, engine);
                let (t, util) = simulate_step(&cfg, engine, &p);
                assert_eq!(
                    t,
                    est.time_s * sweeps * spill * integration,
                    "{medium:?} {engine:?} step time"
                );
                assert_eq!(
                    util,
                    est.bandwidth_util * 2.0 / (sweeps * spill * integration),
                    "{medium:?} {engine:?} utilization"
                );
            }
        }
        // the MMStencil engine never pays the integration penalty; the
        // baselines pay exactly the named constants
        assert_eq!(integration_penalty(Medium::Vti, SimEngine::MMStencil), 1.0);
        assert_eq!(
            integration_penalty(Medium::Vti, SimEngine::Simd),
            VTI_BASELINE_INTEGRATION_PENALTY
        );
        assert_eq!(
            integration_penalty(Medium::Tti, SimEngine::Simd),
            TTI_BASELINE_INTEGRATION_PENALTY
        );
    }

    #[test]
    fn shots_clamp_temporal_blocking_to_one() {
        // §III-B made executable: whatever depth the config requests,
        // an imaging shot fuses nothing (sponge + recording per step),
        // and the result is bit-identical to the default config's
        let p = Platform::paper();
        let mut a = RtmConfig::small(Medium::Vti);
        a.nz = 20;
        a.nx = 20;
        a.ny = 20;
        a.steps = 12;
        let mut b = a.clone();
        b.time_block = 4;
        assert_eq!(b.shot_time_block(), 1);
        let (ia, ra) = run_shot(&a, &p);
        let (ib, rb) = run_shot(&b, &p);
        assert_eq!(ra.energy_trace, rb.energy_trace);
        assert_eq!(ia.img.data, ib.img.data);
    }

    #[test]
    fn sim_speedup_matches_paper_band() {
        // paper §V-F: 2.00× (VTI) and 2.06× (TTI) over the SIMD version
        let p = Platform::paper();
        for medium in [Medium::Vti, Medium::Tti] {
            let cfg = RtmConfig::small(medium);
            let (t_mm, _) = simulate_step(&cfg, SimEngine::MMStencil, &p);
            let (t_simd, _) = simulate_step(&cfg, SimEngine::Simd, &p);
            let s = t_simd / t_mm;
            assert!(
                (1.4..3.0).contains(&s),
                "{medium:?}: simulated speedup {s} outside plausible band"
            );
        }
    }

    #[test]
    fn vti_util_band_near_paper() {
        // paper: 47% bandwidth utilization for VTI on one NUMA node
        let p = Platform::paper();
        let cfg = RtmConfig::small(Medium::Vti);
        let (_, util) = simulate_step(&cfg, SimEngine::MMStencil, &p);
        assert!((0.3..0.7).contains(&util), "VTI util {util}");
    }

    #[test]
    fn shots_through_every_engine_image_the_same_reflectors() {
        // the config engine switch runs the whole shot through each
        // engine; images must agree closely (engines differ only in fp
        // accumulation order)
        let p = Platform::paper();
        let mut energies = Vec::new();
        for kind in EngineKind::ALL {
            let mut cfg = RtmConfig::small(Medium::Vti);
            cfg.nz = 24;
            cfg.nx = 24;
            cfg.ny = 24;
            cfg.steps = 30;
            cfg.threads = 2;
            cfg.engine = kind;
            let (image, rep) = run_shot(&cfg, &p);
            assert!(rep.image_energy > 0.0, "{kind:?}: empty image");
            assert!(image.correlations > 0);
            energies.push(rep.image_energy);
        }
        for e in &energies[1..] {
            assert!(
                (e / energies[0] - 1.0).abs() < 1e-2,
                "image energies diverge across engines: {energies:?}"
            );
        }
    }

    #[test]
    fn validate_accepts_defaults_and_names_each_bad_field() {
        for medium in [Medium::Vti, Medium::Tti] {
            assert_eq!(RtmConfig::small(medium).validate(), Ok(()));
        }
        let base = RtmConfig::small(Medium::Vti);

        let mut c = base.clone();
        c.ny = MIN_GRID_CELLS - 1;
        assert!(matches!(c.validate(), Err(ConfigError::GridTooSmall { .. })));
        assert!(c.validate().unwrap_err().to_string().contains("stencil halo"));

        let mut c = base.clone();
        c.steps = 0;
        assert_eq!(c.validate(), Err(ConfigError::ZeroSteps));

        let mut c = base.clone();
        c.snap_every = 0;
        assert_eq!(c.validate(), Err(ConfigError::ZeroSnapEvery));

        let mut c = base.clone();
        c.receiver_z = c.nz;
        assert!(matches!(c.validate(), Err(ConfigError::ReceiverOutOfRange { .. })));

        // an explicit source outside the grid is caught...
        let mut c = base.clone();
        c.src = Some((c.nz, 0, 0));
        assert!(matches!(c.validate(), Err(ConfigError::SourceOutOfBounds { .. })));
        // ...and so is the *derived* default source when the sponge is
        // deeper than the grid (the old panic-inside-inject case)
        let mut c = base.clone();
        c.nz = MIN_GRID_CELLS;
        c.nx = MIN_GRID_CELLS;
        c.ny = MIN_GRID_CELLS;
        assert!(
            matches!(c.validate(), Err(ConfigError::SourceOutOfBounds { .. })),
            "sponge_width {} puts the default source below a {}-cell grid",
            c.sponge_width,
            MIN_GRID_CELLS
        );
    }

    #[test]
    fn plan_overlay_selects_engine_threads_and_depth() {
        let plan =
            TunePlan::parse("engine=matrix_gemm vl=16 vz=4 tb=4 threads=8 halo=bf16").unwrap();
        let cfg = RtmConfig::small(Medium::Vti).with_plan(&plan);
        assert_eq!(cfg.engine, EngineKind::MatrixGemm);
        assert_eq!(cfg.threads, 8);
        assert_eq!(cfg.time_block, 4);
        assert_eq!(cfg.halo_codec, HaloCodec::Bf16);
        // imaging shots still clamp the fused depth (§III-B)
        assert_eq!(cfg.shot_time_block(), 1);
        let eng = cfg.propagation_engine();
        assert_eq!(eng.kind, EngineKind::MatrixGemm);
        assert_eq!(eng.threads, 8);
    }

    #[test]
    #[should_panic(expected = "invalid RtmConfig")]
    fn run_shot_rejects_invalid_configs_at_the_door() {
        let mut cfg = RtmConfig::small(Medium::Vti);
        cfg.receiver_z = cfg.nz + 5;
        run_shot(&cfg, &Platform::paper());
    }
}
