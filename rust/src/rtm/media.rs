//! Synthetic earth models: layered media with VTI / TTI anisotropy.
//!
//! Stands in for the proprietary velocity models of the industrial RTM
//! baselines (DESIGN.md §3): horizontally layered sediments with
//! increasing velocity, per-layer Thomsen parameters (ε ≥ δ for
//! pseudo-acoustic stability), and — for TTI — tilt/azimuth fields.

use crate::grid::Grid3;

/// VTI medium: Vp²·dt², ε, δ per cell (axes (Z, X, Y), z = depth).
pub struct VtiMedia {
    /// (Vp·dt/dx)² per cell — the update scale of the leapfrog step.
    pub vp2dt2: Grid3,
    /// Thomsen ε per cell.
    pub eps: Grid3,
    /// Thomsen δ per cell.
    pub delta: Grid3,
    /// Timestep (s), CFL-safe for the radius-4 band.
    pub dt: f64,
    /// Grid spacing (m).
    pub dx: f64,
}

/// One sediment layer.
#[derive(Clone, Copy, Debug)]
pub struct Layer {
    /// fraction of depth where the layer starts (0..1)
    pub top: f64,
    /// P velocity (m/s)
    pub vp: f64,
    /// Thomsen ε of the layer.
    pub eps: f64,
    /// Thomsen δ of the layer (kept ≤ ε for stability).
    pub delta: f64,
}

/// Default 3-layer model (sediment / chalk / salt-ish).
pub fn default_layers() -> Vec<Layer> {
    vec![
        Layer { top: 0.0, vp: 2000.0, eps: 0.10, delta: 0.05 },
        Layer { top: 0.4, vp: 3000.0, eps: 0.15, delta: 0.08 },
        Layer { top: 0.75, vp: 4200.0, eps: 0.05, delta: 0.02 },
    ]
}

fn layer_at(layers: &[Layer], frac: f64) -> &Layer {
    layers
        .iter()
        .rev()
        .find(|l| frac >= l.top)
        .unwrap_or(&layers[0])
}

/// CFL-safe timestep for the radius-4 second-derivative stencil:
/// `dt ≤ cfl · dx / (vmax · sqrt(3 · Σ|w2|))`.
pub fn stable_dt(dx: f64, vmax: f64, cfl: f64) -> f64 {
    let w2 = crate::stencil::coeffs::second_deriv(4);
    let s: f64 = w2.iter().map(|&w| (w as f64).abs()).sum();
    cfl * 2.0 * dx / (vmax * (3.0 * s).sqrt())
}

/// Build a VTI layered model over `(nz, nx, ny)` cells of spacing `dx`.
pub fn layered_vti(nz: usize, nx: usize, ny: usize, dx: f64, layers: &[Layer]) -> VtiMedia {
    let vmax = layers.iter().map(|l| l.vp).fold(0.0, f64::max);
    let dt = stable_dt(dx, vmax, 0.45);
    let mut vp2dt2 = Grid3::zeros(nz, nx, ny);
    let mut eps = Grid3::zeros(nz, nx, ny);
    let mut delta = Grid3::zeros(nz, nx, ny);
    for z in 0..nz {
        let l = layer_at(layers, z as f64 / nz as f64);
        let v = (l.vp * dt / dx).powi(2) as f32;
        for x in 0..nx {
            for y in 0..ny {
                vp2dt2.set(z, x, y, v);
                eps.set(z, x, y, l.eps as f32);
                delta.set(z, x, y, l.delta as f32);
            }
        }
    }
    VtiMedia { vp2dt2, eps, delta, dt, dx }
}

/// TTI medium: squared velocities (scaled by dt²/dx²), shear term,
/// anellipticity α, and tilt/azimuth angle fields.
pub struct TtiMedia {
    /// Horizontal P velocity squared, × dt²/dx².
    pub vpx2: Grid3,
    /// Vertical P velocity squared, × dt²/dx².
    pub vpz2: Grid3,
    /// NMO velocity squared, × dt²/dx².
    pub vpn2: Grid3,
    /// Vertical S velocity squared, × dt²/dx².
    pub vsz2: Grid3,
    /// Anellipticity coupling factor per cell.
    pub alpha: Grid3,
    /// Symmetry-axis tilt θ (radians) per cell.
    pub theta: Grid3,
    /// Symmetry-axis azimuth φ (radians) per cell.
    pub phi: Grid3,
    /// Timestep (s), CFL-safe with the TTI margin.
    pub dt: f64,
    /// Grid spacing (m).
    pub dx: f64,
}

/// Build a TTI layered model: same layering as VTI plus a smoothly
/// dipping tilt field (thrust-belt flavour).
pub fn layered_tti(nz: usize, nx: usize, ny: usize, dx: f64, layers: &[Layer]) -> TtiMedia {
    let vmax = layers.iter().map(|l| l.vp).fold(0.0, f64::max);
    // TTI couples more derivatives: keep an extra stability margin
    let dt = stable_dt(dx, vmax, 0.30);
    let mk = || Grid3::zeros(nz, nx, ny);
    let (mut vpx2, mut vpz2, mut vpn2, mut vsz2) = (mk(), mk(), mk(), mk());
    let (mut alpha, mut theta, mut phi) = (mk(), mk(), mk());
    for z in 0..nz {
        let l = layer_at(layers, z as f64 / nz as f64);
        let c = (dt / dx).powi(2);
        let vpz = l.vp;
        let vx2 = (vpz * vpz * (1.0 + 2.0 * l.eps) * c) as f32;
        let vz2 = (vpz * vpz * c) as f32;
        let vn2 = (vpz * vpz * (1.0 + 2.0 * l.delta) * c) as f32;
        let vs2 = (0.3 * vpz * 0.3 * vpz * c) as f32;
        for x in 0..nx {
            for y in 0..ny {
                vpx2.set(z, x, y, vx2);
                vpz2.set(z, x, y, vz2);
                vpn2.set(z, x, y, vn2);
                vsz2.set(z, x, y, vs2);
                alpha.set(z, x, y, 1.0);
                // gentle dip increasing with depth and x
                let th = 0.35 * (z as f32 / nz as f32) * (x as f32 / nx as f32);
                theta.set(z, x, y, th);
                phi.set(z, x, y, 0.2);
            }
        }
    }
    TtiMedia { vpx2, vpz2, vpn2, vsz2, alpha, theta, phi, dt, dx }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layers_ordered_by_depth() {
        let m = layered_vti(40, 8, 8, 10.0, &default_layers());
        // deeper layers are faster
        assert!(m.vp2dt2.get(39, 0, 0) > m.vp2dt2.get(0, 0, 0));
    }

    #[test]
    fn cfl_number_is_safe() {
        let m = layered_vti(32, 8, 8, 10.0, &default_layers());
        // vp·dt/dx for vmax must satisfy the r=4 stability bound with the
        // coupled-system amplification (1+2ε ≤ 1.3): vp2dt2·Σ|w2|·3·1.3 < 4
        let w2 = crate::stencil::coeffs::second_deriv(4);
        let s: f32 = w2.iter().map(|w| w.abs()).sum();
        let worst = m.vp2dt2.data.iter().cloned().fold(0.0f32, f32::max);
        assert!(worst * s * 3.0 * 1.3 < 4.0, "CFL violated: {}", worst * s * 3.0);
    }

    #[test]
    fn eps_ge_delta_everywhere() {
        // pseudo-acoustic stability requirement
        let m = layered_vti(32, 8, 8, 10.0, &default_layers());
        for (e, d) in m.eps.data.iter().zip(&m.delta.data) {
            assert!(e >= d);
        }
    }

    #[test]
    fn tti_angles_bounded() {
        let m = layered_tti(24, 24, 8, 10.0, &default_layers());
        for &t in &m.theta.data {
            assert!((0.0..0.4).contains(&t));
        }
        assert!(m.dt > 0.0);
    }
}
