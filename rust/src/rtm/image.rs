//! Zero-lag cross-correlation imaging condition with source-illumination
//! normalization — the standard RTM image:
//!
//! ```text
//! I(x)     = Σ_t S(x, t) · R(x, t)
//! illum(x) = Σ_t S(x, t)²
//! I_norm   = I / (illum + ε)
//! ```

use crate::grid::Grid3;

/// Accumulating RTM image.  `Clone` so the survey journal
/// ([`rtm::resilience`](crate::rtm::resilience)) can hand a resumed
/// shot's bit-exact slot back out while retaining its own copy.
#[derive(Clone)]
pub struct Image {
    /// Zero-lag cross-correlation sum Σ_t S·R.
    pub img: Grid3,
    /// Source illumination Σ_t S².
    pub illum: Grid3,
    /// Time levels accumulated so far.
    pub correlations: usize,
}

impl Image {
    /// An empty image of the given shape.
    pub fn zeros(nz: usize, nx: usize, ny: usize) -> Self {
        Self {
            img: Grid3::zeros(nz, nx, ny),
            illum: Grid3::zeros(nz, nx, ny),
            correlations: 0,
        }
    }

    /// Accumulate one time level: `src` is the (reconstructed) source
    /// wavefield, `rcv` the back-propagated receiver wavefield.
    pub fn accumulate(&mut self, src: &Grid3, rcv: &Grid3) {
        assert_eq!(src.shape(), self.img.shape());
        assert_eq!(rcv.shape(), self.img.shape());
        for ((i, l), (&s, &r)) in self
            .img
            .data
            .iter_mut()
            .zip(self.illum.data.iter_mut())
            .zip(src.data.iter().zip(&rcv.data))
        {
            *i += s * r;
            *l += s * s;
        }
        self.correlations += 1;
    }

    /// Merge another partial image into this one (pointwise sums of
    /// both accumulators plus the correlation count) — the combine step
    /// of the survey service's tree reduction
    /// ([`rtm::service::reduce_images`](crate::rtm::service::reduce_images)).
    /// Addition of already-accumulated sums, so `merge` is exact where
    /// interleaved `accumulate` calls would reassociate rounding.
    pub fn merge(&mut self, other: &Image) {
        assert_eq!(other.img.shape(), self.img.shape());
        for (d, &s) in self.img.data.iter_mut().zip(&other.img.data) {
            *d += s;
        }
        for (d, &s) in self.illum.data.iter_mut().zip(&other.illum.data) {
            *d += s;
        }
        self.correlations += other.correlations;
    }

    /// Illumination-normalized image.
    pub fn normalized(&self) -> Grid3 {
        let eps = 1e-12f32.max(self.illum.data.iter().cloned().fold(0.0, f32::max) * 1e-6);
        let mut out = Grid3::zeros(self.img.nz, self.img.nx, self.img.ny);
        for (o, (&i, &l)) in out.data.iter_mut().zip(self.img.data.iter().zip(&self.illum.data)) {
            *o = i / (l + eps);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn correlating_field_with_itself_is_illumination() {
        let g = Grid3::random(4, 5, 6, 21);
        let mut im = Image::zeros(4, 5, 6);
        im.accumulate(&g, &g);
        assert_eq!(im.img.data, im.illum.data);
        assert_eq!(im.correlations, 1);
    }

    #[test]
    fn normalized_self_image_is_near_one() {
        let mut g = Grid3::zeros(3, 3, 3);
        for (i, v) in g.data.iter_mut().enumerate() {
            *v = 1.0 + i as f32; // keep well away from zero
        }
        let mut im = Image::zeros(3, 3, 3);
        im.accumulate(&g, &g);
        let n = im.normalized();
        for &v in &n.data {
            assert!((v - 1.0).abs() < 1e-3, "{v}");
        }
    }

    #[test]
    fn uncorrelated_fields_give_small_image() {
        let a = Grid3::random(6, 6, 6, 1);
        let b = Grid3::random(6, 6, 6, 2);
        let mut im = Image::zeros(6, 6, 6);
        for _ in 0..8 {
            im.accumulate(&a, &b);
        }
        // cross-term energy must stay well below auto-term energy
        assert!(im.img.energy() < im.illum.energy());
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let a = Grid3::zeros(2, 2, 2);
        let b = Grid3::zeros(2, 2, 3);
        let mut im = Image::zeros(2, 2, 2);
        im.accumulate(&a, &b);
    }
}
