//! PJRT-backed VTI propagation engine: the L2 JAX grid step
//! (`rtm_vti_r4_grid64.hlo.txt`, lowered once at build time) executed
//! from the rust request path — the architecture's proof that the
//! *entire compute* can run through the AOT XLA artifacts with Python
//! nowhere in sight.
//!
//! Used by the end-to-end example and the integration tests to
//! cross-validate the rust-native propagator (`rtm::vti`) over many
//! steps, not just one.

use crate::anyhow;
use crate::util::err::Result;

use super::media::VtiMedia;
use super::vti::VtiState;
use crate::grid::Grid3;
use crate::runtime::{Runtime, Tensor};

/// A compiled whole-grid VTI stepper bound to one artifact.
pub struct PjrtVtiStepper<'rt> {
    rt: &'rt Runtime,
    artifact: String,
    shape: Vec<usize>,
    media: [Tensor; 3],
}

impl<'rt> PjrtVtiStepper<'rt> {
    /// Bind to `artifact` (e.g. `"rtm_vti_r4_grid64"`); the media grids
    /// are uploaded once and reused every step.
    pub fn new(rt: &'rt Runtime, artifact: &str, m: &VtiMedia) -> Result<Self> {
        let meta = rt
            .manifest
            .get(artifact)
            .ok_or_else(|| anyhow!("{artifact} not in manifest (run `make artifacts`)"))?;
        let shape = meta.inputs[0].shape.clone();
        let (nz, nx, ny) = (shape[0], shape[1], shape[2]);
        if m.vp2dt2.shape() != (nz, nx, ny) {
            return Err(anyhow!(
                "media shape {:?} != artifact grid {:?}",
                m.vp2dt2.shape(),
                shape
            ));
        }
        let t = |g: &Grid3| Tensor::new(shape.clone(), g.data.clone());
        let media = [t(&m.vp2dt2), t(&m.eps), t(&m.delta)];
        Ok(Self { rt, artifact: artifact.to_string(), shape, media })
    }

    /// Grid shape the bound artifact was lowered for.
    pub fn grid_shape(&self) -> (usize, usize, usize) {
        (self.shape[0], self.shape[1], self.shape[2])
    }

    /// Advance `state` one leapfrog step through the PJRT executable.
    pub fn step(&self, state: &mut VtiState) -> Result<()> {
        let t = |g: &Grid3| Tensor::new(self.shape.clone(), g.data.clone());
        let outs = self.rt.execute(
            &self.artifact,
            &[
                t(&state.sh),
                t(&state.sv),
                t(&state.sh_prev),
                t(&state.sv_prev),
                self.media[0].clone(),
                self.media[1].clone(),
                self.media[2].clone(),
            ],
        )?;
        // leapfrog rotation: (new, cur) ← (f(cur, prev), cur)
        std::mem::swap(&mut state.sh_prev, &mut state.sh);
        std::mem::swap(&mut state.sv_prev, &mut state.sv);
        state.sh.data.copy_from_slice(&outs[0].data);
        state.sv.data.copy_from_slice(&outs[1].data);
        Ok(())
    }

    /// Run `steps` steps injecting `source[i]` at `(z, x, y)` each step.
    pub fn propagate(
        &self,
        state: &mut VtiState,
        source: &[f32],
        z: usize,
        x: usize,
        y: usize,
    ) -> Result<Vec<f64>> {
        let mut energies = Vec::with_capacity(source.len());
        for &amp in source {
            state.inject(z, x, y, amp);
            self.step(state)?;
            energies.push(state.energy());
        }
        Ok(energies)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rtm::{media, vti, wavelet};
    use crate::stencil::coeffs::second_deriv;
    use crate::util::prop::assert_allclose;

    fn runtime() -> Option<Runtime> {
        match Runtime::open_default() {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("skipping PJRT propagation test: {e:#}");
                None
            }
        }
    }

    #[test]
    fn pjrt_propagation_tracks_native_for_ten_steps() {
        let Some(rt) = runtime() else { return };
        let n = 64;
        let m = media::layered_vti(n, n, n, 10.0, &media::default_layers());
        let stepper = PjrtVtiStepper::new(&rt, "rtm_vti_r4_grid64", &m).unwrap();
        assert_eq!(stepper.grid_shape(), (n, n, n));

        let w2 = second_deriv(4);
        let src = wavelet::ricker_series(10, m.dt, 15.0);
        let mut a = VtiState::zeros(n, n, n);
        let mut b = VtiState::zeros(n, n, n);
        let mut sc = vti::VtiScratch::new(n, n, n);
        for &amp in &src {
            a.inject(32, 32, 32, amp);
            b.inject(32, 32, 32, amp);
            stepper.step(&mut a).unwrap();
            vti::step(&mut b, &m, &w2, 1, &mut sc);
        }
        assert_allclose(&a.sh.data, &b.sh.data, 1e-3, 1e-5);
        assert_allclose(&a.sv.data, &b.sv.data, 1e-3, 1e-5);
    }

    #[test]
    fn stepper_rejects_mismatched_media() {
        let Some(rt) = runtime() else { return };
        let m = media::layered_vti(16, 16, 16, 10.0, &media::default_layers());
        assert!(PjrtVtiStepper::new(&rt, "rtm_vti_r4_grid64", &m).is_err());
    }

    #[test]
    fn propagate_reports_energies() {
        let Some(rt) = runtime() else { return };
        let n = 64;
        let m = media::layered_vti(n, n, n, 10.0, &media::default_layers());
        let stepper = PjrtVtiStepper::new(&rt, "rtm_vti_r4_grid64", &m).unwrap();
        let mut st = VtiState::zeros(n, n, n);
        let src = wavelet::ricker_series(5, m.dt, 15.0);
        let e = stepper.propagate(&mut st, &src, 32, 32, 32).unwrap();
        assert_eq!(e.len(), 5);
        assert!(e.iter().all(|v| v.is_finite()));
        assert!(e[4] > 0.0);
    }
}

/// TTI analog of [`PjrtVtiStepper`]: the 11-input whole-grid TTI step
/// (`rtm_tti_r4_grid32`), media + angle fields uploaded once.
pub struct PjrtTtiStepper<'rt> {
    rt: &'rt Runtime,
    artifact: String,
    shape: Vec<usize>,
    media: Vec<Tensor>,
}

impl<'rt> PjrtTtiStepper<'rt> {
    /// Bind to `artifact` (e.g. `"rtm_tti_r4_grid32"`); the seven media
    /// and angle grids are uploaded once and reused every step.
    pub fn new(rt: &'rt Runtime, artifact: &str, m: &super::media::TtiMedia) -> Result<Self> {
        let meta = rt
            .manifest
            .get(artifact)
            .ok_or_else(|| anyhow!("{artifact} not in manifest (run `make artifacts`)"))?;
        let shape = meta.inputs[0].shape.clone();
        if m.vpx2.shape() != (shape[0], shape[1], shape[2]) {
            return Err(anyhow!("media shape {:?} != artifact grid {:?}", m.vpx2.shape(), shape));
        }
        let t = |g: &Grid3| Tensor::new(shape.clone(), g.data.clone());
        let media = vec![
            t(&m.vpx2), t(&m.vpz2), t(&m.vpn2), t(&m.vsz2), t(&m.alpha), t(&m.theta), t(&m.phi),
        ];
        Ok(Self { rt, artifact: artifact.to_string(), shape, media })
    }

    /// Advance the TTI field pair one leapfrog step through PJRT.
    pub fn step(&self, state: &mut super::tti::TtiState) -> Result<()> {
        let t = |g: &Grid3| Tensor::new(self.shape.clone(), g.data.clone());
        let mut inputs = vec![t(&state.p), t(&state.q), t(&state.p_prev), t(&state.q_prev)];
        inputs.extend(self.media.iter().cloned());
        let outs = self.rt.execute(&self.artifact, &inputs)?;
        std::mem::swap(&mut state.p_prev, &mut state.p);
        std::mem::swap(&mut state.q_prev, &mut state.q);
        state.p.data.copy_from_slice(&outs[0].data);
        state.q.data.copy_from_slice(&outs[1].data);
        Ok(())
    }
}

#[cfg(test)]
mod tti_tests {
    use super::*;
    use crate::rtm::{media, tti, wavelet};
    use crate::stencil::coeffs::{first_deriv, second_deriv};
    use crate::util::prop::assert_allclose;

    #[test]
    fn pjrt_tti_tracks_native() {
        let Ok(rt) = Runtime::open_default() else { return };
        let n = 32;
        let m = media::layered_tti(n, n, n, 10.0, &media::default_layers());
        let stepper = PjrtTtiStepper::new(&rt, "rtm_tti_r4_grid32", &m).unwrap();
        let trig = tti::TtiTrig::new(&m);
        let (w2, w1) = (second_deriv(4), first_deriv(4));
        let src = wavelet::ricker_series(6, m.dt, 15.0);
        let mut a = tti::TtiState::zeros(n, n, n);
        let mut b = tti::TtiState::zeros(n, n, n);
        let mut sc = tti::TtiScratch::new(n, n, n);
        for &amp in &src {
            a.inject(16, 16, 16, amp);
            b.inject(16, 16, 16, amp);
            stepper.step(&mut a).unwrap();
            tti::step(&mut b, &m, &trig, &w2, &w1, 1, &mut sc);
        }
        assert_allclose(&a.p.data, &b.p.data, 1e-3, 1e-5);
        assert_allclose(&a.q.data, &b.q.data, 1e-3, 1e-5);
    }

    #[test]
    fn tti_stepper_rejects_mismatched_media() {
        let Ok(rt) = Runtime::open_default() else { return };
        let m = media::layered_tti(16, 16, 16, 10.0, &media::default_layers());
        assert!(PjrtTtiStepper::new(&rt, "rtm_tti_r4_grid32", &m).is_err());
    }
}
