//! MMStencil command-line launcher.
//!
//! Subcommands:
//!
//! * `info`                    — platform model, artifact inventory
//! * `sweep`                   — one parallel stencil sweep (single NUMA)
//! * `tune`                    — autotune a (kernel, n) shape to a `TunePlan`
//! * `rtm`                     — one RTM shot (VTI/TTI)
//! * `survey`                  — multi-shot RTM survey on the shot service
//! * `exchange`                — halo-exchange bandwidth test (Table II)
//! * `scaling`                 — strong/weak multi-NUMA scaling run
//! * `artifacts`               — verify PJRT artifacts against rust kernels
//! * `run --config file.toml`  — full experiment from a config file
//!
//! `sweep`, `rtm`, and `survey` all accept `--plan "engine=… vl=… vz=…
//! tb=… threads=…"` — a [`TunePlan`](mmstencil::stencil::TunePlan)
//! string (as printed by `tune`) that pins engine, block geometry,
//! fused depth, and fan-out in one value, overriding the per-knob
//! flags.  Arguments use `--key value`; run `mmstencil help` for a
//! summary.

use std::collections::HashMap;
use std::process::ExitCode;

use mmstencil::config;
use mmstencil::coordinator::driver as sweep_driver;
use mmstencil::coordinator::exchange::Backend;
use mmstencil::coordinator::tiles::Strategy;
use mmstencil::grid::halo::HaloCodec;
use mmstencil::grid::{CartDecomp, Grid3};
use mmstencil::metrics;
use mmstencil::rtm::driver::{Medium, RtmConfig};
use mmstencil::rtm::resilience::{FaultPlan, HealthPolicy};
use mmstencil::rtm::service::{CheckpointStrategy, ShotJob, SurveyConfig, SurveyRunner};
use mmstencil::runtime::{Runtime, Tensor};
use mmstencil::simulator::Platform;
use mmstencil::stencil::{naive, tune, StencilSpec, TunePlan};
use mmstencil::util::table::{f, Table};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = args.split_first() else {
        help();
        return ExitCode::SUCCESS;
    };
    let opts = parse_opts(rest);
    let result = match cmd.as_str() {
        "info" => cmd_info(&opts),
        "sweep" => cmd_sweep(&opts),
        "tune" => cmd_tune(&opts),
        "rtm" => cmd_rtm(&opts),
        "survey" => cmd_survey(&opts),
        "exchange" => cmd_exchange(&opts),
        "scaling" => cmd_scaling(&opts),
        "artifacts" => cmd_artifacts(&opts),
        "run" => cmd_run(&opts),
        "help" | "--help" | "-h" => {
            help();
            Ok(())
        }
        other => Err(format!("unknown subcommand {other:?}; try `mmstencil help`")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn help() {
    println!(
        "mmstencil — matrix-unit stencil framework (paper reproduction)

USAGE: mmstencil <subcommand> [--key value ...]

  info                                platform + artifact inventory
  sweep      --kernel 3DStarR4 --n 64 --threads 8 --strategy snoop|square
             --time_block k         fuse k sweeps per pass (arena double buffer)
             --halo_codec f32|bf16|f16   halo wire codec (f32 = bitwise classic)
             --plan \"engine=… vl=… vz=… tb=… threads=… tile=… wf=… halo=…\"  tuned plan (wins)
  tune       --kernel 3DStarR4 --n 256 --threads 8 [--cache plans.txt]
             autotune the shape against the roofline model; print (and
             optionally cache) the winning TunePlan
  rtm        --medium vti|tti --n 48 --steps 120 --threads 8
             --engine naive|simd|matrix_unit|matrix_gemm
             --time_block k         requested fuse depth (shots clamp to 1, §III-B)
             --halo_codec f32|bf16|f16   subdomain-shell wire codec
             --plan \"…\"             tuned plan overlay (wins over knobs)
  survey     --shots 8 --shards 2 --medium vti|tti --n 32 --steps 60
             --engine matrix_unit --checkpoint full_state|boundary_saving
             --halo_codec f32|bf16|f16 --queue_capacity 4 --plan \"…\"
             multi-shot survey on the shot service
             --faults \"seed=7 kernel=1@shot3\"   seeded chaos plan (DESIGN §16);
                                    failed shots under an active plan exit 0
             --health abort_shot|retry|fallback_f32_codec   wavefield monitor policy
             --submit_timeout_ms k  submission deadline per shot (0 = block)
             --journal shots.journal   write-ahead journal (crash-consistent)
             --resume  shots.journal   skip journaled shots, bitwise-identical image
  exchange   --n 128 --radius 4             Table II halo bandwidth test
  scaling    --mode strong|weak --kernel 3DStarR4 --n 64
             --steps 4 --time_block k   one halo exchange per k fused steps
             --tile z --wf b        in-rank (z, t) wavefront tiling of the
                                    fused sub-steps: z-extent per tile (0 =
                                    classic) and levels per dispatch barrier
             --halo_codec f32|bf16|f16   compress exchanged faces on the wire
  artifacts  [--dir artifacts]              verify PJRT vs rust kernels
  run        --config configs/example.toml  full experiment from a file"
    );
}

type Opts = HashMap<String, String>;

fn parse_opts(args: &[String]) -> Opts {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = args.get(i + 1).cloned().unwrap_or_default();
            m.insert(key.to_string(), val);
            i += 2;
        } else {
            i += 1;
        }
    }
    m
}

fn opt_usize(o: &Opts, k: &str, d: usize) -> usize {
    o.get(k).and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn opt_str<'a>(o: &'a Opts, k: &str, d: &'a str) -> &'a str {
    o.get(k).map(String::as_str).unwrap_or(d)
}

/// `--plan "engine=… vl=… vz=… tb=… threads=…"`: a parsed [`TunePlan`],
/// or `None` when the flag is absent.
fn opt_plan(o: &Opts) -> Result<Option<TunePlan>, String> {
    o.get("plan")
        .map(|s| TunePlan::parse(s).map_err(|e| format!("--plan: {e}")))
        .transpose()
}

/// `--halo_codec f32|bf16|f16`: the halo wire codec (default `f32`,
/// the bitwise classic transport).  A `--plan` carrying a `halo=` key
/// wins over this knob, mirroring `--time_block`.
fn opt_codec(o: &Opts) -> Result<HaloCodec, String> {
    o.get("halo_codec")
        .map(|s| HaloCodec::parse(s).map_err(|e| format!("--halo_codec: {e}")))
        .transpose()
        .map(|c| c.unwrap_or(HaloCodec::F32))
}

fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

fn cmd_info(opts: &Opts) -> Result<(), String> {
    let p = Platform::paper();
    println!("simulated platform (paper §II-B / §V-A):");
    println!(
        "  {} processors × {} dies × {} NUMA × {} cores = {} cores",
        p.processors,
        p.dies_per_processor,
        p.numa_per_die,
        p.cores_per_numa,
        p.total_cores()
    );
    println!("  SIMD peak / NUMA : {:.2} TFLOPS (fp32)", p.simd_flops_per_numa() / 1e12);
    println!("  Matrix peak / NUMA: {:.2} TFLOPS (fp32)", p.matrix_flops_per_numa() / 1e12);
    println!(
        "  on-package BW/NUMA: {:.0} GB/s   DDR/die: {:.0} GB/s",
        p.onpkg_bw_per_numa / 1e9,
        p.ddr_bw_per_die / 1e9
    );
    println!(
        "  §IV-B speedup model: r=1 {:.2}×  r=2 {:.2}×  r=4 {:.2}×",
        p.mmstencil_speedup(1),
        p.mmstencil_speedup(2),
        p.mmstencil_speedup(4)
    );
    let dir = opt_str(opts, "dir", "artifacts");
    match Runtime::open(dir) {
        Ok(rt) => {
            println!("\nPJRT platform: {}", rt.platform());
            println!("artifacts in {dir}/ ({}):", rt.artifact_names().len());
            for n in rt.artifact_names() {
                println!("  {n}");
            }
        }
        Err(e) => println!("\n(artifacts unavailable: {e}; run `make artifacts`)"),
    }
    Ok(())
}

fn cmd_sweep(opts: &Opts) -> Result<(), String> {
    let name = opt_str(opts, "kernel", "3DStarR4");
    let spec = StencilSpec::parse(name).map_err(|e| e.to_string())?;
    if spec.ndim != 3 {
        return Err("sweep drives 3D kernels; 2D kernels are bench-only".into());
    }
    let n = opt_usize(opts, "n", 64);
    let (nz, nx, ny) = (
        opt_usize(opts, "nz", n),
        opt_usize(opts, "nx", n),
        opt_usize(opts, "ny", n),
    );
    let plan = opt_plan(opts)?;
    let threads = plan
        .map(|p| p.threads.max(1))
        .unwrap_or_else(|| opt_usize(opts, "threads", default_threads()));
    let strategy = match opt_str(opts, "strategy", "snoop") {
        "square" => Strategy::Square,
        _ => Strategy::SnoopAware,
    };
    let time_block = plan
        .map(|p| p.time_block.max(1))
        .unwrap_or_else(|| opt_usize(opts, "time_block", 1).max(1));
    let halo_codec = match plan {
        Some(p) => p.halo,
        None => opt_codec(opts)?,
    };
    let platform = Platform::paper();
    let g = Grid3::random(nz, nx, ny, 42);
    println!(
        "sweep {name} on {nz}×{nx}×{ny}, {threads} threads, {strategy:?}, time_block {time_block}"
    );
    let mut driver = sweep_driver::Driver::new(threads, platform)
        .with_time_block(time_block)
        .with_halo_codec(halo_codec);
    if let Some(p) = &plan {
        println!("  plan: {p}");
        driver = driver.with_plan(p);
    }
    let (out, stats) = driver.sweep(&spec, &g, strategy);
    let mut check = naive::apply3(&spec, &g);
    for _ in 1..time_block {
        check = naive::apply3(&spec, &check);
    }
    // relative: fused sweeps compound both magnitudes and fp divergence
    let scale = check.as_slice().iter().fold(1.0f32, |a, &v| a.max(v.abs()));
    let err = out.max_abs_diff(&check) / scale;
    println!(
        "  host: {:.1} ms  {:.3} Gcell/s   rel max|Δ| vs naive = {err:.2e}",
        stats.real_s * 1e3,
        stats.gcells_per_s
    );
    println!(
        "  pool: {} persistent workers (spawned once, {:.2} ms), {} tasks, {} steals, util {:.0}%",
        stats.pool.workers,
        stats.pool.spawn_overhead_s * 1e3,
        stats.pool.tasks,
        stats.pool.steals,
        stats.pool.utilization * 100.0
    );
    println!(
        "  simulated on paper platform: {:.2} ms/sweep, bandwidth util {:.1}%",
        stats.sim_s * 1e3,
        stats.sim_bandwidth_util * 100.0
    );
    if err > 1e-3 {
        return Err(format!("verification failed: max|Δ| = {err}"));
    }
    Ok(())
}

fn cmd_tune(opts: &Opts) -> Result<(), String> {
    let name = opt_str(opts, "kernel", "3DStarR4");
    let spec = StencilSpec::parse(name).map_err(|e| e.to_string())?;
    if spec.ndim != 3 {
        return Err("tune drives 3D kernels; 2D kernels are bench-only".into());
    }
    let n = opt_usize(opts, "n", 256);
    let threads = opt_usize(opts, "threads", default_threads());
    let key = tune::shape_key(&spec, n);
    let (plan, note) = match opts.get("cache") {
        Some(path) => {
            let mut cache = mmstencil::runtime::PlanCache::load(path)
                .map_err(|e| e.to_string())?;
            let hit = cache.get(&key).is_some();
            let plan = cache.get_or_insert_with(&key, || tune::tune_default(&spec, n, threads));
            cache.store(path).map_err(|e| e.to_string())?;
            (plan, if hit { "cache hit" } else { "tuned, cached" })
        }
        None => (tune::tune_default(&spec, n, threads), "tuned"),
    };
    println!("{key}|{plan}  ({note})");
    println!("  replay with: mmstencil sweep --kernel {name} --n {n} --plan \"{plan}\"");
    Ok(())
}

fn cmd_rtm(opts: &Opts) -> Result<(), String> {
    let medium = match opt_str(opts, "medium", "vti") {
        "tti" => Medium::Tti,
        _ => Medium::Vti,
    };
    let mut cfg = RtmConfig::small(medium);
    let n = opt_usize(opts, "n", 48);
    cfg.nz = opt_usize(opts, "nz", n);
    cfg.nx = opt_usize(opts, "nx", n);
    cfg.ny = opt_usize(opts, "ny", n);
    cfg.steps = opt_usize(opts, "steps", 120);
    cfg.threads = opt_usize(opts, "threads", default_threads());
    let engine_name = opt_str(opts, "engine", "simd");
    cfg.engine =
        mmstencil::stencil::EngineKind::parse(engine_name).map_err(|e| format!("--engine: {e}"))?;
    cfg.time_block = opt_usize(opts, "time_block", 1).max(1);
    cfg.halo_codec = opt_codec(opts)?;
    if let Some(p) = opt_plan(opts)? {
        cfg = cfg.with_plan(&p);
    }
    if cfg.time_block > cfg.shot_time_block() {
        println!(
            "  note: time_block {} clamped to {} — imaging shots apply the sponge and \
             record receivers every step (paper §III-B)",
            cfg.time_block,
            cfg.shot_time_block()
        );
    }
    let p = Platform::paper();
    println!(
        "RTM {medium:?} shot: {}×{}×{} grid, {} steps, {} threads, {} engine",
        cfg.nz,
        cfg.nx,
        cfg.ny,
        cfg.steps,
        cfg.threads,
        cfg.engine.name()
    );
    let job = ShotJob::builder(cfg).build().map_err(|e| e.to_string())?;
    let mut runner =
        SurveyRunner::new(SurveyConfig::one_shot(), &p).map_err(|e| e.to_string())?;
    let (image, rep) = runner.run_one(job).map_err(|e| e.to_string())?;
    println!(
        "  forward {:.2}s + backward {:.2}s  →  {:.3} Gpoint/s",
        rep.forward_s,
        rep.backward_s,
        rep.gpoints_per_s / 1e9
    );
    println!(
        "  max receiver amplitude {:.3e}; image energy {:.3e} over {} correlations",
        rep.max_trace, rep.image_energy, image.correlations
    );
    println!(
        "  simulated on paper platform: util {:.1}%, step {:.2} ms, {:.2}× vs SIMD baseline",
        rep.sim_bandwidth_util * 100.0,
        rep.sim_step_s * 1e3,
        rep.sim_speedup_vs_simd()
    );
    Ok(())
}

fn cmd_survey(opts: &Opts) -> Result<(), String> {
    let medium = match opt_str(opts, "medium", "vti") {
        "tti" => Medium::Tti,
        _ => Medium::Vti,
    };
    let mut cfg = RtmConfig::small(medium);
    let n = opt_usize(opts, "n", 32);
    cfg.nz = opt_usize(opts, "nz", n);
    cfg.nx = opt_usize(opts, "nx", n);
    cfg.ny = opt_usize(opts, "ny", n);
    cfg.steps = opt_usize(opts, "steps", 60);
    cfg.threads = opt_usize(opts, "threads", default_threads());
    let engine_name = opt_str(opts, "engine", "matrix_unit");
    cfg.engine =
        mmstencil::stencil::EngineKind::parse(engine_name).map_err(|e| format!("--engine: {e}"))?;
    cfg.halo_codec = opt_codec(opts)?;
    if let Some(p) = opt_plan(opts)? {
        cfg = cfg.with_plan(&p);
    }
    let shots = opt_usize(opts, "shots", 8).max(1);
    let faults = match opts.get("faults") {
        Some(s) => FaultPlan::parse(s).map_err(|e| format!("--faults: {e}"))?,
        None => FaultPlan::default(),
    };
    let mut scfg = SurveyConfig::default();
    scfg.shards = opt_usize(opts, "shards", scfg.shards).max(1);
    scfg.queue_capacity = opt_usize(opts, "queue_capacity", scfg.queue_capacity).max(1);
    scfg.checkpoint = CheckpointStrategy::parse(opt_str(opts, "checkpoint", "full_state"))
        .map_err(|e| format!("--checkpoint: {e}"))?;
    scfg.health = HealthPolicy::parse(opt_str(opts, "health", scfg.health.name()))
        .map_err(|e| format!("--health: {e}"))?;
    scfg.submit_timeout_ms = opt_usize(opts, "submit_timeout_ms", 0) as u64;
    let jobs = survey_jobs(&cfg, shots, faults).map_err(|e| e.to_string())?;
    println!(
        "RTM {medium:?} survey: {} shots on {} shard(s), {}×{}×{} grid, {} steps, \
         {} engine, {} checkpointing",
        shots,
        scfg.shards,
        cfg.nz,
        cfg.nx,
        cfg.ny,
        cfg.steps,
        cfg.engine.name(),
        scfg.checkpoint.name()
    );
    let p = Platform::paper();
    let mut runner = SurveyRunner::new(scfg, &p).map_err(|e| e.to_string())?;
    let report = if let Some(path) = opts.get("resume") {
        println!("  resuming from journal {path}");
        runner.resume(jobs, path).map_err(|e| e.to_string())?
    } else if let Some(path) = opts.get("journal") {
        println!("  journaling to {path}");
        runner.run_journaled(jobs, path.as_str()).map_err(|e| e.to_string())?
    } else {
        runner.run(jobs)
    };
    let mut t = Table::new(&[
        "shot", "shard", "stolen", "attempts", "deq seq", "faults", "status", "Gpoint/s",
    ]);
    for r in &report.records {
        let (status, gpps) = match (&r.status, &r.report) {
            (mmstencil::rtm::service::ShotStatus::Completed, Some(rep)) => {
                ("ok".to_string(), f(rep.gpoints_per_s / 1e9, 3))
            }
            (mmstencil::rtm::service::ShotStatus::Completed, None) if r.resumed => {
                ("ok (resumed)".to_string(), "-".to_string())
            }
            (mmstencil::rtm::service::ShotStatus::Failed(e), _) => {
                (format!("FAILED: {e}"), "-".to_string())
            }
            _ => ("?".to_string(), "-".to_string()),
        };
        t.row(&[
            r.id.to_string(),
            r.shard.to_string(),
            if r.stolen { "yes" } else { "" }.to_string(),
            r.attempts.to_string(),
            r.dequeue_seq.to_string(),
            r.faults_injected.to_string(),
            status,
            gpps,
        ]);
    }
    t.print();
    println!(
        "  {} completed, {} failed, {} retried, {} stolen, {} fault(s) injected, \
         {} resumed in {:.2}s  →  {:.0} shots/hour",
        report.completed(),
        report.failed(),
        report.retries(),
        report.stolen(),
        report.faults_injected(),
        report.resumed_shots(),
        report.wall_s,
        report.shots_per_hour()
    );
    if let Some(image) = &report.image {
        println!(
            "  accumulated image energy {:.3e} over {} correlations",
            image.img.energy(),
            image.correlations
        );
    }
    if report.failed() > 0 {
        if faults.is_empty() {
            return Err(format!("{} shot(s) failed", report.failed()));
        }
        // contained chaos: an active fault plan expects casualties — the
        // survey kept going and the survivors imaged, so exit clean
        println!(
            "  {} shot(s) failed under the active fault plan — contained, exiting 0",
            report.failed()
        );
    }
    Ok(())
}

/// Build a line of shots whose sources sweep the interior x-axis of the
/// grid (the classic towed-line acquisition geometry).
fn survey_jobs(
    cfg: &RtmConfig,
    shots: usize,
    faults: FaultPlan,
) -> Result<Vec<ShotJob>, mmstencil::rtm::driver::ConfigError> {
    let (sz, _, sy) = cfg.src_pos();
    let lo = cfg.sponge_width + 1;
    let hi = cfg.nx.saturating_sub(cfg.sponge_width + 2).max(lo);
    (0..shots)
        .map(|s| {
            let sx = lo + (hi - lo) * s / shots.saturating_sub(1).max(1);
            ShotJob::builder(cfg.clone()).src(sz, sx, sy).fault_plan(faults).build()
        })
        .collect()
}

fn cmd_exchange(opts: &Opts) -> Result<(), String> {
    use mmstencil::coordinator::exchange;
    let n = opt_usize(opts, "n", 128);
    let r = opt_usize(opts, "radius", 4);
    let g = Grid3::random(n, n, n, 7);
    let mut t = Table::new(&["direction", "block shape", "MPI GB/s", "SDMA GB/s", "speedup"]);
    for (label, ranks) in [("X", (1, 2, 1)), ("Y", (1, 1, 2)), ("Z", (2, 1, 1))] {
        let d = CartDecomp::new(ranks.0, ranks.1, ranks.2);
        let mut rates = Vec::new();
        for backend in [Backend::mpi(), Backend::sdma()] {
            let mut grids = exchange::scatter(&g, &d, r);
            let rep = exchange::exchange(&d, &mut grids, &backend);
            rates.push(rep.bytes as f64 / rep.sim_time_s / 1e9);
        }
        let b = d.block(0, n, n, n);
        let (bz, bx, by) = b.dims();
        t.row(&[
            label.to_string(),
            format!("({bz},{bx},{by})"),
            f(rates[0], 2),
            f(rates[1], 1),
            format!("{:.1}×", rates[1] / rates[0]),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_scaling(opts: &Opts) -> Result<(), String> {
    let name = opt_str(opts, "kernel", "3DStarR4");
    let spec = StencilSpec::parse(name).map_err(|e| e.to_string())?;
    let n = opt_usize(opts, "n", 64);
    let threads = opt_usize(opts, "threads", default_threads());
    let steps = opt_usize(opts, "steps", 2);
    let mode = opt_str(opts, "mode", "strong");
    let time_block = opt_usize(opts, "time_block", 1).max(1);
    // in-rank wavefront tiling of the fused sub-steps (PR 8): --tile 0
    // keeps classic level-at-a-time stepping, --wf is the band depth
    // (sub-step levels per dispatch barrier)
    let tile = opt_usize(opts, "tile", 0);
    let wf = opt_usize(opts, "wf", 1).max(1);
    let halo_codec = opt_codec(opts)?;
    let platform = Platform::paper();
    // one driver covers all three stepping paths: time_block = 1 is the
    // classic exchange-per-step loop, > 1 fuses (with wavefront tiling
    // when tile > 0), and the codec rides on whichever path runs
    let driver = sweep_driver::Driver::new(threads, platform)
        .with_time_block(time_block)
        .with_wavefront(tile, wf)
        .with_halo_codec(halo_codec);
    let mut t = Table::new(&[
        "ranks",
        "backend",
        "sim compute ms",
        "sim comm ms",
        "sim step ms",
        "pipelined ms",
        "exchanges",
        "barriers",
    ]);
    for ranks in [(1, 1, 1), (1, 1, 2), (1, 2, 2), (2, 2, 2)] {
        let d = CartDecomp::new(ranks.0, ranks.1, ranks.2);
        let (gn_z, gn_x, gn_y) = if mode == "weak" {
            (n * ranks.0, n * ranks.1, n * ranks.2)
        } else {
            (n, n, n)
        };
        let g = Grid3::random(gn_z, gn_x, gn_y, 3);
        for backend in [Backend::mpi(), Backend::sdma()] {
            let (_, stats) = driver.multirank_sweep(&spec, &g, &d, &backend, steps);
            t.row(&[
                format!("{}×{}×{}", ranks.0, ranks.1, ranks.2),
                backend.name().to_string(),
                f(stats.sim_compute_s * 1e3, 2),
                f(stats.sim_comm_s * 1e3, 2),
                f(stats.sim_step_s * 1e3, 2),
                f(stats.sim_step_pipelined_s * 1e3, 2),
                format!("{}/{steps}", stats.comm_rounds),
                format!("{}", stats.substep_barriers),
            ]);
        }
    }
    println!(
        "{mode} scaling of {name} (grid {n}³{}, time_block {time_block}{}, halo {})",
        if mode == "weak" { " per rank" } else { " total" },
        if tile > 0 { format!(", wavefront tile {tile} wf {wf}") } else { String::new() },
        halo_codec.name()
    );
    t.print();
    Ok(())
}

fn cmd_artifacts(opts: &Opts) -> Result<(), String> {
    let dir = opt_str(opts, "dir", "artifacts");
    let rt = Runtime::open(dir).map_err(|e| e.to_string())?;
    println!(
        "PJRT {} — verifying block artifacts against rust-native kernels",
        rt.platform()
    );
    let mut records = metrics::RecordSet::new();
    let mut checked = 0;
    for (name, spec) in [
        ("star3d_r2_block", StencilSpec::star3d(2)),
        ("star3d_r4_block", StencilSpec::star3d(4)),
        ("box3d_r1_block", StencilSpec::box3d(1)),
        ("box3d_r2_block", StencilSpec::box3d(2)),
    ] {
        let Some(meta) = rt.manifest.get(name) else { continue };
        let ishape = meta.inputs[0].shape.clone();
        let (hz, hx, hy) = (ishape[0], ishape[1], ishape[2]);
        let halo = Grid3::random(hz, hx, hy, 99);
        let out = rt
            .execute(name, &[Tensor::new(ishape, halo.data.clone())])
            .map_err(|e| e.to_string())?;
        let r = spec.radius;
        // rust oracle: periodic naive apply on the halo cube, cropped to
        // the interior (halo wide enough that wrap never contaminates it)
        let full = naive::apply3(&spec, &halo);
        let (oz, ox, oy) = (hz - 2 * r, hx - 2 * r, hy - 2 * r);
        let mut err = 0.0f32;
        for z in 0..oz {
            for x in 0..ox {
                for y in 0..oy {
                    let want = full.get(z + r, x + r, y + r);
                    let got = out[0].data[(z * ox + x) * oy + y];
                    err = err.max((want - got).abs());
                }
            }
        }
        println!("  {name:22} max|Δ| = {err:.2e}");
        records.add("artifacts", "pjrt-vs-rust", name, "max_abs_err", err as f64);
        if err > 1e-3 {
            return Err(format!("{name}: artifact mismatch {err}"));
        }
        checked += 1;
    }
    if checked == 0 {
        return Err("no block artifacts found; run `make artifacts`".into());
    }
    println!("{checked} artifacts verified OK");
    Ok(())
}

fn cmd_run(opts: &Opts) -> Result<(), String> {
    let path = opts.get("config").ok_or("run requires --config <file.toml>")?;
    let cfg = config::load(path)?;
    println!("experiment: {}", cfg.title);
    let mut o: Opts = HashMap::new();
    o.insert("kernel".into(), cfg.sweep.kernel.clone());
    o.insert("nz".into(), cfg.sweep.nz.to_string());
    o.insert("nx".into(), cfg.sweep.nx.to_string());
    o.insert("ny".into(), cfg.sweep.ny.to_string());
    o.insert("threads".into(), cfg.sweep.threads.to_string());
    o.insert(
        "strategy".into(),
        if cfg.sweep.strategy == Strategy::Square { "square" } else { "snoop" }.to_string(),
    );
    o.insert("time_block".into(), cfg.runtime.time_block.to_string());
    o.insert("halo_codec".into(), cfg.runtime.halo_codec.name().to_string());
    // the [tune] plan (if any) rides along and wins over the knobs above
    if let Some(p) = cfg.tune.plan {
        o.insert("plan".into(), p.to_string());
    }
    cmd_sweep(&o)?;
    let mut o: Opts = HashMap::new();
    o.insert(
        "medium".into(),
        if cfg.rtm.medium == Medium::Tti { "tti" } else { "vti" }.to_string(),
    );
    o.insert("nz".into(), cfg.rtm.nz.to_string());
    o.insert("nx".into(), cfg.rtm.nx.to_string());
    o.insert("ny".into(), cfg.rtm.ny.to_string());
    o.insert("steps".into(), cfg.rtm.steps.to_string());
    o.insert("threads".into(), cfg.rtm.threads.to_string());
    o.insert("engine".into(), cfg.rtm.engine.name().to_string());
    o.insert("time_block".into(), cfg.rtm.time_block.to_string());
    o.insert("halo_codec".into(), cfg.rtm.halo_codec.name().to_string());
    if let Some(p) = cfg.tune.plan {
        o.insert("plan".into(), p.to_string());
    }
    cmd_rtm(&o)?;
    let mut o: Opts = HashMap::new();
    o.insert(
        "medium".into(),
        if cfg.rtm.medium == Medium::Tti { "tti" } else { "vti" }.to_string(),
    );
    o.insert("nz".into(), cfg.rtm.nz.to_string());
    o.insert("nx".into(), cfg.rtm.nx.to_string());
    o.insert("ny".into(), cfg.rtm.ny.to_string());
    o.insert("steps".into(), cfg.rtm.steps.to_string());
    o.insert("threads".into(), cfg.rtm.threads.to_string());
    o.insert("engine".into(), cfg.rtm.engine.name().to_string());
    o.insert("halo_codec".into(), cfg.rtm.halo_codec.name().to_string());
    o.insert("shots".into(), cfg.survey.shots.to_string());
    o.insert("shards".into(), cfg.survey.shards.to_string());
    o.insert("queue_capacity".into(), cfg.survey.queue_capacity.to_string());
    o.insert("checkpoint".into(), cfg.survey.checkpoint.name().to_string());
    if !cfg.survey.faults.is_empty() {
        o.insert("faults".into(), cfg.survey.faults.to_string());
    }
    o.insert("health".into(), cfg.survey.health.name().to_string());
    if let Some(p) = cfg.tune.plan {
        o.insert("plan".into(), p.to_string());
    }
    cmd_survey(&o)
}
