//! Parametric simulator of the paper's (confidential) multicore SoC.
//!
//! The paper's platform cannot be named or bought; its *mechanisms* are
//! fully described, so we rebuild them (DESIGN.md §3):
//!
//! * [`soc`]       — the platform parameter set (cores, NUMA, frequencies,
//!   CPI figures, memory bandwidths) reverse-engineered from the paper's
//!   published absolute numbers and peak percentages;
//! * [`cache`]     — set-associative LRU private caches;
//! * [`stream`]    — distinct-access-stream counting and the on-package
//!   1024-bit-port efficiency model (brick layout rationale);
//! * [`directory`] — NUMA root directory / cache-snoop data sharing;
//! * [`noc`]       — intra-NUMA ring interconnect;
//! * [`sdma`]      — the per-die SDMA engine (160 channels, strided
//!   copies), calibrated to Table II;
//! * [`mpi`]       — the lock-serialized MPI runtime cost model;
//! * [`roofline`]  — the §IV-B performance model tying it together.
//!
//! Contract: the simulator is a *model*, not a runtime — it owns no
//! grid data and shares no mutable state with the compute layers; it
//! maps workload descriptions (spec, cells, engine, memory kind) to
//! predicted times/utilizations, pure-functionally per call.

pub mod cache;
pub mod directory;
pub mod mpi;
pub mod noc;
pub mod roofline;
pub mod sdma;
pub mod soc;
pub mod stream;

pub use soc::Platform;
