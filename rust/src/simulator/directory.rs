//! NUMA root directory and the cache-snoop data-sharing scheme
//! (paper §IV-E, Fig. 8).
//!
//! With no shared LLC, a core missing in its private caches consults the
//! NUMA root directory; if the line lives in a peer core's cache it is
//! served over the intra-cluster interconnect (fast), otherwise from
//! main memory (slow).  MMStencil schedules adjacent tiles on adjacent
//! cores with narrow-Y tiles so halo rows are served by peers.

use super::soc::Platform;

/// Outcome classification for a halo access under a given schedule.
#[derive(Clone, Copy, Debug, Default)]
pub struct SnoopStats {
    /// halo bytes served from a peer core's cache
    pub peer_bytes: u64,
    /// halo bytes that had to come from main memory
    pub memory_bytes: u64,
    /// interior (owned-tile) bytes — always memory on first touch
    pub owned_bytes: u64,
}

impl SnoopStats {
    /// Fraction of total traffic removed from main memory.
    pub fn traffic_reduction(&self) -> f64 {
        let total = self.peer_bytes + self.memory_bytes + self.owned_bytes;
        if total == 0 {
            return 0.0;
        }
        self.peer_bytes as f64 / total as f64
    }

    /// Average access latency (ns) under the platform's snoop/memory
    /// latencies — the root-directory consultation is paid either way.
    pub fn avg_latency_ns(&self, p: &Platform) -> f64 {
        let total = (self.peer_bytes + self.memory_bytes + self.owned_bytes) as f64;
        if total == 0.0 {
            return 0.0;
        }
        (self.peer_bytes as f64 * p.snoop_latency_ns
            + (self.memory_bytes + self.owned_bytes) as f64 * p.mem_latency_ns)
            / total
    }

    /// Latency refined by the intra-NUMA ring (paper §II-B): peer-cache
    /// transfers ride the ring, so the *placement* of halo partners
    /// matters — the snoop-aware adjacent assignment puts them one hop
    /// apart while a scattered assignment pays the mean ring distance.
    /// This is the second mechanism (besides traffic) behind §IV-E.
    pub fn avg_latency_ns_on_ring(&self, p: &Platform, adjacent: bool) -> f64 {
        let total = (self.peer_bytes + self.memory_bytes + self.owned_bytes) as f64;
        if total == 0.0 {
            return 0.0;
        }
        let ring = super::noc::Ring::new(p.cores_per_numa);
        let hop = if adjacent { ring.latency_ns(0, 1) } else { ring.mean_latency_ns(0) };
        let peer = p.snoop_latency_ns + hop;
        (self.peer_bytes as f64 * peer
            + (self.memory_bytes + self.owned_bytes) as f64 * p.mem_latency_ns)
            / total
    }
}

/// Per-core tile assignment for the snoop analysis: tiles are
/// `(tile_x, tile_y)` cells in the XY plane (z streamed), each with halo
/// width `bx`/`by` on the respective axes.
#[derive(Clone, Copy, Debug)]
pub struct TileSchedule {
    pub tile_x: usize,
    pub tile_y: usize,
    pub halo_x: usize,
    pub halo_y: usize,
    /// adjacent assignment: neighbouring tiles run concurrently on
    /// neighbouring cores (the MMStencil scheme); false = scattered
    /// assignment (e.g. dynamic work stealing), no peer locality.
    pub adjacent: bool,
}

/// Analyze one z-slab sweep: each core processes its tile; halo regions
/// along Y can be served by the peer core that owns them *iff* the
/// schedule is adjacent and concurrent (paper: Tile_Y term drops from the
/// reuse ratio).  X-halos come from the core's own previously-processed
/// columns (memory or own cache).
pub fn analyze(sched: &TileSchedule, z_depth: usize, elem_bytes: usize) -> SnoopStats {
    let own = sched.tile_x * sched.tile_y * z_depth * elem_bytes;
    let halo_y = 2 * sched.halo_y * sched.tile_x * z_depth * elem_bytes;
    let halo_x = 2 * sched.halo_x * (sched.tile_y + 2 * sched.halo_y) * z_depth * elem_bytes;
    let mut s = SnoopStats {
        owned_bytes: own as u64,
        ..Default::default()
    };
    if sched.adjacent {
        s.peer_bytes = halo_y as u64;
        s.memory_bytes = halo_x as u64;
    } else {
        s.memory_bytes = (halo_x + halo_y) as u64;
    }
    s
}

/// The paper's reuse-ratio bounds (§IV-E).  Returns
/// `(plain_reuse, snoop_reuse)` for a tile `(tx, ty)` with brick halos
/// `(bx, by)`:
///   plain: tx·ty / ((tx+2bx)(ty+2by))
///   snoop: tx / (tx + 2bx)           (Tile_Y drops out)
pub fn reuse_ratios(tx: usize, ty: usize, bx: usize, by: usize) -> (f64, f64) {
    let plain = (tx * ty) as f64 / ((tx + 2 * bx) * (ty + 2 * by)) as f64;
    let snoop = tx as f64 / (tx + 2 * bx) as f64;
    (plain, snoop)
}

/// Search the best tile shape subject to the private-cache constraint
/// `(vz + 2bz)(tx + 2bx)(ty + 2by) · 4 ≤ cache_bytes` (paper's LLC-size
/// constraint with SIZE_LLC = per-core private cache here).  Returns
/// `(tx, ty, plain, snoop)` maximizing each ratio (power-of-two tiles).
pub fn best_tiles(
    cache_bytes: usize,
    vz: usize,
    bz: usize,
    bx: usize,
    by: usize,
) -> (usize, usize, f64, f64) {
    let budget = cache_bytes / 4 / (vz + 2 * bz);
    let mut best = (0usize, 0usize, 0.0f64, 0.0f64);
    let mut tx = 16;
    while tx <= 1024 {
        let mut ty = 4;
        while ty <= 1024 {
            if (tx + 2 * bx) * (ty + 2 * by) <= budget {
                let (plain, snoop) = reuse_ratios(tx, ty, bx, by);
                if plain > best.2 {
                    best.0 = tx;
                    best.1 = ty;
                    best.2 = plain;
                }
                if snoop > best.3 {
                    best.3 = snoop;
                }
            }
            ty *= 2;
        }
        tx *= 2;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adjacent_schedule_reduces_memory_traffic_20_to_30pct() {
        // paper §V-B: 22–26% global traffic reduction
        let sched = TileSchedule {
            tile_x: 64,
            tile_y: 16,
            halo_x: 16,
            halo_y: 4,
            adjacent: true,
        };
        let s = analyze(&sched, 64, 4);
        let red = s.traffic_reduction();
        assert!((0.15..0.35).contains(&red), "reduction {red:.3}");
        let scattered = analyze(&TileSchedule { adjacent: false, ..sched }, 64, 4);
        assert_eq!(scattered.traffic_reduction(), 0.0);
    }

    #[test]
    fn snoop_latency_beats_memory() {
        let p = Platform::paper();
        let sched = TileSchedule {
            tile_x: 64,
            tile_y: 16,
            halo_x: 16,
            halo_y: 4,
            adjacent: true,
        };
        let adj = analyze(&sched, 64, 4).avg_latency_ns(&p);
        let sca = analyze(&TileSchedule { adjacent: false, ..sched }, 64, 4).avg_latency_ns(&p);
        assert!(adj < sca);
    }

    #[test]
    fn ring_placement_latency_ordering() {
        // adjacent halo partners (1 hop) < scattered (mean ring distance)
        // < all-memory; and every snoop path beats main memory
        let p = Platform::paper();
        let sched = TileSchedule {
            tile_x: 64,
            tile_y: 16,
            halo_x: 16,
            halo_y: 4,
            adjacent: true,
        };
        let st = analyze(&sched, 64, 4);
        let adj = st.avg_latency_ns_on_ring(&p, true);
        let sca = st.avg_latency_ns_on_ring(&p, false);
        let no_ring = st.avg_latency_ns(&p);
        assert!(adj < sca, "adjacent must beat scattered: {adj} vs {sca}");
        assert!(no_ring <= adj, "ring hops add latency on top of the snoop base");
        assert!(sca < p.mem_latency_ns, "even scattered snoop beats memory");
    }

    #[test]
    fn reuse_ratio_formulas() {
        // plain ratio capped around 50% for cache-constrained tiles
        let (plain, snoop) = reuse_ratios(64, 16, 16, 4);
        assert!(plain < 0.6);
        assert!(snoop > plain);
        // snoop bound = tx/(tx+2bx) = 64/96
        assert!((snoop - 64.0 / 96.0).abs() < 1e-12);
    }

    #[test]
    fn best_tiles_respect_cache_budget() {
        let p = Platform::paper();
        let (tx, ty, plain, snoop) = best_tiles(p.l2_bytes, 4, 4, 16, 4);
        assert!(tx > 0 && ty > 0);
        assert!((4 + 8) * (tx + 32) * (ty + 8) * 4 <= p.l2_bytes);
        // paper: plain reuse caps low ("nearly one-third of memory
        // traffic redundant" ⇒ reuse ≈ 0.5–0.65), snoop clearly higher
        assert!(plain < 0.66, "plain {plain:.3}");
        assert!(snoop > plain + 0.1, "snoop {snoop:.3} vs plain {plain:.3}");
    }
}
