//! The performance model (paper §IV-B + roofline, Table I) that converts
//! instruction mixes and traffic estimates into predicted times,
//! GStencil/s, and bandwidth utilization on the simulated platform.
//!
//! Engine efficiency constants are calibrated to the paper's own anchors
//! (documented inline); everything else — traffic, stream efficiency,
//! snoop reuse, instruction counts — is derived mechanically from the
//! other simulator modules and the `stencil::matrix_unit` counters.

use super::directory;
use super::soc::Platform;
use super::stream::{self, BlockAccess};
use crate::grid::brick::BrickDims;
use crate::grid::{Grid2, Grid3};
use crate::stencil::{matrix_unit, Pattern, StencilSpec};

/// Roofline classification (Table I "Pattern" column).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Bound {
    Memory,
    Compute,
    Both,
}

impl std::fmt::Display for Bound {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Bound::Memory => write!(f, "Memory Bound"),
            Bound::Compute => write!(f, "Computation Bound"),
            Bound::Both => write!(f, "Both"),
        }
    }
}

/// Which implementation computes the sweep.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// compiler-autovectorized direct loops
    Compiler,
    /// hand-tuned SIMD intrinsics (2.5D blocking + brick layout)
    Simd,
    /// the matrix-unit algorithm (this paper)
    MMStencil,
}

/// Memory system the grid lives in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemKind {
    Ddr,
    OnPkg,
}

/// Sweep configuration for the breakdown experiments (Fig. 12).
#[derive(Clone, Copy, Debug)]
pub struct SweepConfig {
    pub mem: MemKind,
    pub brick: bool,
    pub snoop: bool,
    pub prefetch: bool,
}

impl SweepConfig {
    pub fn best(mem: MemKind) -> Self {
        Self { mem, brick: true, snoop: true, prefetch: true }
    }

    pub fn base(mem: MemKind) -> Self {
        Self { mem, brick: false, snoop: false, prefetch: false }
    }
}

/// A predicted sweep outcome on one NUMA node.
#[derive(Clone, Copy, Debug)]
pub struct Estimate {
    pub time_s: f64,
    pub compute_s: f64,
    pub memory_s: f64,
    pub gstencils_per_s: f64,
    /// the paper's metric: 2·sizeof(f32)·stencils/s ÷ peak bandwidth
    pub bandwidth_util: f64,
    pub bound: Bound,
}

/// Classify a kernel against the machine balance point (Table I).
pub fn classify(spec: &StencilSpec, p: &Platform, mem: MemKind) -> Bound {
    let ai = spec.flops_per_point() as f64 / spec.min_bytes_per_point() as f64;
    let bw = match mem {
        MemKind::Ddr => p.ddr_bw_per_die / p.numa_per_die as f64,
        MemKind::OnPkg => p.onpkg_bw_per_numa,
    };
    let balance = p.simd_flops_per_numa() / bw;
    if ai < 0.8 * balance {
        Bound::Memory
    } else if ai > 1.6 * balance {
        Bound::Compute
    } else {
        Bound::Both
    }
}

/// Where a wavefront-tiled fused sweep streams its re-used operands
/// from (`coordinator::wavefront`'s in-rank (z, t) tiling).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Residency {
    /// The tile working set exceeds the node's aggregate L2: every
    /// fused sub-step re-streams the grid from memory — the classic
    /// flat path, and any over-large tile geometry.
    Dram,
    /// The `(tile + 2·r·wf)`-layer working set fits the node's
    /// aggregate L2: sub-steps past the first are served at cache
    /// bandwidth instead of DRAM bandwidth.
    Cache,
}

impl std::fmt::Display for Residency {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Residency::Dram => write!(f, "DRAM-resident"),
            Residency::Cache => write!(f, "cache-resident"),
        }
    }
}

/// Effective bandwidth advantage of cache-resident streaming over the
/// on-package memory system: one NUMA node's aggregate L2 sustains
/// roughly this multiple of the on-package bandwidth, so a fused
/// sub-step whose working set stays resident costs `1/CACHE_BW_RATIO`
/// of its flat-path streaming time.
pub const CACHE_BW_RATIO: f64 = 4.0;

/// Bytes one wavefront tile column keeps live across `wf` fused
/// sub-step levels: `tile + 2·r·wf` z-layers of `n×n` f32 cells
/// (the tile core plus the r-halo each of the `wf` levels grows),
/// double buffered — the temporal ping-pong's src and dst slabs.
pub fn wavefront_working_set_bytes(spec: &StencilSpec, n: usize, tile: usize, wf: usize) -> u64 {
    let layers = (tile + 2 * spec.radius * wf.max(1)) as u64;
    layers * (n as u64 * n as u64) * 4 * 2
}

/// Classify a wavefront tile geometry against the simulated cache
/// hierarchy: `tile = 0` (classic level-at-a-time stepping) and
/// over-large working sets are [`Residency::Dram`]; a working set that
/// fits one NUMA node's aggregate L2 is [`Residency::Cache`] — the
/// score `stencil::tune` uses to pick the headline tile geometry.
pub fn wavefront_residency(
    p: &Platform,
    spec: &StencilSpec,
    n: usize,
    tile: usize,
    wf: usize,
) -> Residency {
    let cache = (p.l2_bytes * p.cores_per_numa) as u64;
    if tile == 0 || wavefront_working_set_bytes(spec, n, tile, wf) > cache {
        Residency::Dram
    } else {
        Residency::Cache
    }
}

/// Per-point matrix-unit instruction counts, measured by running the
/// emulation engine on exactly one block.
fn mm_counts_per_point(spec: &StencilSpec) -> matrix_unit::Counts {
    let dims = matrix_unit::BlockDims::default();
    if spec.ndim == 3 {
        let g = Grid3::zeros(dims.vz, dims.vl, dims.vl);
        let (_, c) = matrix_unit::apply3(spec, &g, dims);
        scale_counts(c, (dims.vz * dims.vl * dims.vl) as f64)
    } else {
        let g = Grid2::zeros(dims.vl, dims.vl);
        let (_, c) = matrix_unit::apply2(spec, &g, dims);
        scale_counts(c, (dims.vl * dims.vl) as f64)
    }
}

/// Normalize whole-sweep counts to fixed-point thousandths per point
/// (integral, so estimates stay deterministic).  The avoided-instruction
/// counters are comparison-only and are zeroed — they never cost cycles.
pub fn scale_counts(c: matrix_unit::Counts, pts: f64) -> matrix_unit::Counts {
    // keep fixed-point thousandths per point to stay integral
    matrix_unit::Counts {
        outer_products: (c.outer_products as f64 / pts * 1000.0) as u64,
        vec_loads: (c.vec_loads as f64 / pts * 1000.0) as u64,
        vec_stores: (c.vec_stores as f64 / pts * 1000.0) as u64,
        tile_slices: (c.tile_slices as f64 / pts * 1000.0) as u64,
        simd_permutes_avoided: 0,
        gathers_avoided: 0,
    }
}

/// Compute-side efficiency of the SIMD/compiler engines (fraction of
/// SIMD peak FLOPS actually sustained).  Anchors: §V-D — "the SIMD
/// version cannot attain its theoretical peak" (instruction-scheduling
/// bottleneck: two FMAs per cycle needed for peak); §V-C — SIMD outpaces
/// the compiler by 8% (2DBoxR2) and 112% (2DBoxR3); Fig. 3 — the
/// compiler matches hand-SIMD on 2D stars and degrades faster on 3D
/// high-order patterns (register pressure / spills).
fn scalar_engine_eff(engine: Engine, spec: &StencilSpec) -> f64 {
    let r = spec.radius as f64;
    match engine {
        Engine::Simd => match (spec.pattern, spec.ndim) {
            // 2D stars stream long rows: near the SIMD scheduling cap
            (Pattern::Star, 2) => 0.62,
            // register pressure + 3 axis streams erode issue slots with
            // radius (Fig. 3: hand-SIMD slows 1.80x from r1 to r4)
            (Pattern::Star, _) => 0.62 / (1.0 + 0.12 * (r - 1.0)),
            // box stencils pay unaligned loads + vector splicing per 1D
            // sub-stencil (the problem IV-C.d zeroes out for MMStencil)
            (Pattern::Box, _) => 0.38,
        },
        Engine::Compiler => match (spec.pattern, spec.ndim) {
            (Pattern::Star, 2) => 0.62,
            // Fig. 3: compiler code slows 2.25x from 3DStarR1 to R4
            (Pattern::Star, _) => 0.62 / (1.0 + 0.20 * (r - 1.0)),
            // V-C: SIMD outpaces the compiler by 8% (r=2) / 112% (r=3)
            (Pattern::Box, _) if spec.radius <= 2 => 0.35,
            (Pattern::Box, _) => 0.18,
        },
        Engine::MMStencil => unreachable!(),
    }
}

/// The configuration each engine actually runs with in the comparison
/// experiments (Fig. 11): the baselines are well-tuned (2.5D blocking,
/// brick layout for the SIMD version) but the cache-snoop scheme and the
/// gather prefetch are MMStencil framework features; the compiler
/// baseline cannot emit gather prefetches at all.
pub fn engine_cfg(engine: Engine, mem: MemKind) -> SweepConfig {
    match engine {
        Engine::MMStencil => SweepConfig::best(mem),
        Engine::Simd => SweepConfig { mem, brick: true, snoop: false, prefetch: true },
        Engine::Compiler => SweepConfig { mem, brick: false, snoop: false, prefetch: false },
    }
}

/// Predict one sweep of `n_points` grid points on one NUMA node.
/// The matrix-unit compute side uses the default-dims emulation counts
/// of `stencil::matrix_unit` — see [`predict_with_counts`] to model a
/// different instruction mix or block geometry (the autotuner's path).
pub fn predict(
    spec: &StencilSpec,
    n_points: usize,
    engine: Engine,
    cfg: SweepConfig,
    p: &Platform,
) -> Estimate {
    let counts = match engine {
        Engine::MMStencil => Some(mm_counts_per_point(spec)),
        _ => None,
    };
    predict_inner(spec, n_points, engine, counts, matrix_unit::BlockDims::default(), cfg, p)
}

/// Predict one matrix-unit-family sweep from an explicit per-point
/// instruction mix (fixed-point thousandths, see [`scale_counts`]) and
/// block geometry — the cost model the startup autotuner
/// (`stencil::tune`) scores candidate (engine, dims) plans against.
/// `predict` is exactly this with the default-dims emulation counts.
pub fn predict_with_counts(
    spec: &StencilSpec,
    n_points: usize,
    counts_per_kpoint: matrix_unit::Counts,
    dims: matrix_unit::BlockDims,
    cfg: SweepConfig,
    p: &Platform,
) -> Estimate {
    predict_inner(spec, n_points, Engine::MMStencil, Some(counts_per_kpoint), dims, cfg, p)
}

fn predict_inner(
    spec: &StencilSpec,
    n_points: usize,
    engine: Engine,
    counts_per_kpoint: Option<matrix_unit::Counts>,
    dims: matrix_unit::BlockDims,
    cfg: SweepConfig,
    p: &Platform,
) -> Estimate {
    let n = n_points as f64;
    let cores = p.cores_per_numa as f64;

    // ---- compute time -------------------------------------------------
    let compute_s = match engine {
        Engine::MMStencil => {
            let c = counts_per_kpoint.expect("matrix-unit prediction needs counts");
            let op_cycles = c.outer_products as f64 / 1000.0 * p.cpi_matrix;
            // auxiliary instructions (loads/stores/slices) dual-issue with
            // the outer products on the OOE core; charge 50%
            let aux_cycles = (c.vec_loads + c.vec_stores + c.tile_slices) as f64
                / 1000.0
                * 0.5;
            n * (op_cycles + aux_cycles) / (cores * p.freq_matrix_hz)
        }
        e => {
            let flops = spec.flops_per_point() as f64 * n;
            flops / (p.simd_flops_per_numa() * scalar_engine_eff(e, spec))
        }
    };

    // ---- memory time ----------------------------------------------------
    // reuse ratio from the tiling analysis (paper §IV-E); the snoop
    // scheme is an MMStencil framework feature
    let b = BrickDims::default();
    let (bx, by, bz) = if spec.ndim == 3 { (b.bx, b.by, b.bz) } else { (b.bx, b.by, 1) };
    let (_tx, _ty, plain, snoop) =
        directory::best_tiles(p.l2_bytes, if spec.ndim == 3 { 4 } else { 1 }, bz, bx, by);
    let use_snoop = cfg.snoop && engine == Engine::MMStencil;
    let reuse = if use_snoop {
        match cfg.mem {
            MemKind::Ddr => snoop,
            // §V-B: on on-package memory "each core must still consult
            // the root directory before retrieving data from another
            // core's cache, creating a bottleneck" — only part of the
            // snoop reuse benefit materializes there
            MemKind::OnPkg => plain + 0.35 * (snoop - plain),
        }
    } else {
        plain
    };
    // bytes per point: one input read amplified by (1/reuse), one write;
    // MMStencil's 3D-star z-pass intermediate partially spills at short
    // radii (too little compute to hide the tmp round-trip, §V-C)
    let tmp_exposed = if engine == Engine::MMStencil
        && spec.ndim == 3
        && spec.pattern == Pattern::Star
    {
        4.0 * (1.0 - spec.radius as f64 / 3.0).max(0.0)
    } else {
        0.0
    };
    let traffic = n * (4.0 / reuse + 4.0 + tmp_exposed);

    // access-pattern shape: 2D sweeps read naturally long rows; scalar 3D
    // engines stream (2r+1)·3 shifted rows of the 2.5D tile; the MM block
    // sweep is the paper's 226-stream pattern unless bricked
    let (run_bytes, streams) = if spec.ndim == 2 {
        (4096, 2 * spec.radius + 1)
    } else if engine != Engine::MMStencil {
        (2048, 3 * (2 * spec.radius + 1))
    } else if cfg.brick {
        let access = BlockAccess::star3d(dims.vl, dims.vl, dims.vz, spec.radius);
        (b.bytes(), access.bricked_streams(b))
    } else {
        let access = BlockAccess::star3d(dims.vl, dims.vl, dims.vz, spec.radius);
        (64, access.rowmajor_streams())
    };
    let has_prefetch = cfg.prefetch && engine != Engine::Compiler;
    let bw = match cfg.mem {
        MemKind::OnPkg => {
            let eff = stream::onpkg_efficiency(run_bytes, streams, p.onpkg_port_bytes());
            // no hardware prefetcher on this core (§IV-D.b): without the
            // gather-based software prefetch, latency exposure costs ~25%
            // on short brick runs; long 2D rows mostly self-prefetch at
            // the memory controller
            let pf = if has_prefetch {
                1.0
            } else if run_bytes >= 2048 {
                0.92
            } else {
                0.75
            };
            // sustained/peak ceiling of the on-package memory system
            // (refresh + row-buffer overheads; STREAM-style reality)
            p.onpkg_bw_per_numa * eff * pf * 0.85
        }
        MemKind::Ddr => {
            // narrow 64-bit port saturates easily (prefetch ineffective
            // there, §V-B) — but hundreds of concurrent streams thrash
            // the DRAM row buffers, which is what the brick layout fixes
            let run_eff = if run_bytes >= 256 { 0.95 } else { 0.80 };
            let page_eff = if streams <= 32 {
                1.0
            } else {
                (32.0 / streams as f64).powf(0.3)
            };
            (p.ddr_bw_per_die / p.numa_per_die as f64) * run_eff * page_eff
        }
    };
    let memory_s = traffic / bw;

    // computation/memory overlap: the gather-based software prefetch
    // hides the access latency behind compute (§IV-D.b); without it a
    // fraction of the smaller phase is exposed serially (no hardware
    // prefetcher on this core).  This is why prefetch still helps the
    // compute-bound 3DBoxR2 (paper: +19.7% on on-package memory).
    let exposed = if has_prefetch {
        0.0
    } else if cfg.mem == MemKind::OnPkg {
        0.22
    } else {
        0.05
    };
    let time_s = compute_s.max(memory_s) + exposed * compute_s.min(memory_s);
    let gst = n / time_s / 1e9;
    let peak = match cfg.mem {
        MemKind::OnPkg => p.onpkg_bw_per_numa,
        MemKind::Ddr => p.ddr_bw_per_die / p.numa_per_die as f64,
    };
    Estimate {
        time_s,
        compute_s,
        memory_s,
        gstencils_per_s: gst,
        bandwidth_util: 2.0 * 4.0 * (n / time_s) / peak,
        bound: classify(spec, p, cfg.mem),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const N3: usize = 512 * 512 * 512;
    const N2: usize = 8192 * 8192;

    fn p() -> Platform {
        Platform::paper()
    }

    #[test]
    fn table1_classification() {
        let plat = p();
        let want = [
            ("2DStarR2", Bound::Memory),
            ("2DStarR4", Bound::Memory),
            ("2DBoxR2", Bound::Memory),
            ("2DBoxR3", Bound::Both),
            ("3DStarR2", Bound::Memory),
            ("3DStarR4", Bound::Memory),
            ("3DBoxR1", Bound::Memory),
            ("3DBoxR2", Bound::Compute),
        ];
        for (name, b) in want {
            let spec = StencilSpec::parse(name).unwrap();
            assert_eq!(classify(&spec, &plat, MemKind::OnPkg), b, "{name}");
        }
    }

    #[test]
    fn wavefront_residency_matches_the_cache_capacity() {
        let plat = p();
        let spec = StencilSpec::parse("3DStarR4").unwrap();
        // the flat path is DRAM-resident by definition
        assert_eq!(wavefront_residency(&plat, &spec, 256, 0, 1), Residency::Dram);
        // the headline-sized geometry fits the 38-core aggregate L2
        assert_eq!(wavefront_residency(&plat, &spec, 256, 16, 2), Residency::Cache);
        // growing the tile past the aggregate L2 tips it back to DRAM
        assert_eq!(wavefront_residency(&plat, &spec, 256, 32, 1), Residency::Dram);
        // the working set is monotone in each knob and exactly the
        // documented (tile + 2·r·wf)-layer double-buffered slab
        let ws = |tile, wf| wavefront_working_set_bytes(&spec, 256, tile, wf);
        assert!(ws(16, 1) < ws(16, 2));
        assert!(ws(16, 2) < ws(32, 2));
        assert_eq!(ws(16, 2), (16 + 2 * 4 * 2) * 256 * 256 * 4 * 2);
        // display strings are part of the CLI/probe surface
        assert_eq!(Residency::Dram.to_string(), "DRAM-resident");
        assert_eq!(Residency::Cache.to_string(), "cache-resident");
    }

    #[test]
    fn high_order_3d_mmstencil_beats_simd() {
        // paper §V-C: ~80% average gain on high-order stencils
        let plat = p();
        let cfg = SweepConfig::best(MemKind::OnPkg);
        for name in ["3DStarR4", "3DBoxR2"] {
            let spec = StencilSpec::parse(name).unwrap();
            let mm = predict(&spec, N3, Engine::MMStencil, cfg, &plat);
            let simd = predict(&spec, N3, Engine::Simd, cfg, &plat);
            let speedup = simd.time_s / mm.time_s;
            assert!(speedup > 1.3, "{name}: speedup {speedup:.2}");
        }
    }

    #[test]
    fn simd_wins_3dstarr2() {
        // paper §V-C: "the SIMD intrinsic version surprisingly delivers
        // the best performance for the 3DStarR2 kernel"
        let plat = p();
        // SIMD runs at the higher SIMD-mode frequency and the kernel is
        // memory-bound: MMStencil's matrix-mode advantage evaporates and
        // its z-switch overhead costs compute time
        let spec = StencilSpec::parse("3DStarR2").unwrap();
        let cfg = SweepConfig::best(MemKind::OnPkg);
        let mm = predict(&spec, N3, Engine::MMStencil, cfg, &plat);
        let simd = predict(&spec, N3, Engine::Simd, cfg, &plat);
        // both are memory-bound → comparable; MMStencil must NOT win big
        assert!(mm.time_s / simd.time_s > 0.9, "mm should not dominate");
    }

    #[test]
    fn compute_bound_3dboxr2_near_85pct_of_peak() {
        // paper §V-C: 3.19 of 3.75 TFLOPS ≈ 85%
        let plat = p();
        let spec = StencilSpec::parse("3DBoxR2").unwrap();
        let est = predict(&spec, N3, Engine::MMStencil, SweepConfig::best(MemKind::OnPkg), &plat);
        assert_eq!(est.bound, Bound::Compute);
        let flops = spec.flops_per_point() as f64 * N3 as f64 / est.time_s;
        let frac = flops / plat.simd_flops_per_numa();
        assert!((0.6..1.1).contains(&frac), "fraction of 3.75T peak: {frac:.2}");
    }

    #[test]
    fn star2d_utilization_above_70pct() {
        // paper: 2D stars sustain >70% on-package utilization
        let plat = p();
        for name in ["2DStarR2", "2DStarR4"] {
            let spec = StencilSpec::parse(name).unwrap();
            let est = predict(
                &spec,
                N2,
                Engine::MMStencil,
                SweepConfig::best(MemKind::OnPkg),
                &plat,
            );
            assert!(est.bandwidth_util > 0.55, "{name}: {:.2}", est.bandwidth_util);
        }
    }

    #[test]
    fn brick_layout_is_biggest_single_gain_on_onpkg() {
        // Fig. 12 shape: base → +brick is the largest step
        let plat = p();
        let spec = StencilSpec::parse("3DStarR4").unwrap();
        let base = predict(&spec, N3, Engine::MMStencil, SweepConfig::base(MemKind::OnPkg), &plat);
        let brick = predict(
            &spec,
            N3,
            Engine::MMStencil,
            SweepConfig { brick: true, ..SweepConfig::base(MemKind::OnPkg) },
            &plat,
        );
        let full = predict(&spec, N3, Engine::MMStencil, SweepConfig::best(MemKind::OnPkg), &plat);
        let brick_gain = base.time_s / brick.time_s;
        let rest_gain = brick.time_s / full.time_s;
        assert!(brick_gain > rest_gain, "brick {brick_gain:.2} rest {rest_gain:.2}");
        assert!(brick_gain > 2.0);
    }

    #[test]
    fn snoop_helps_more_on_ddr_than_onpkg_relatively() {
        // paper §V-B: up to 26% on DDR, smaller on on-package
        let plat = p();
        let spec = StencilSpec::parse("3DStarR4").unwrap();
        let mk = |mem, snoop| {
            predict(
                &spec,
                N3,
                Engine::MMStencil,
                SweepConfig { mem, brick: true, snoop, prefetch: true },
                &plat,
            )
            .time_s
        };
        let ddr_gain = mk(MemKind::Ddr, false) / mk(MemKind::Ddr, true);
        assert!(ddr_gain > 1.1 && ddr_gain < 1.45, "ddr snoop gain {ddr_gain:.2}");
    }
}
