//! SDMA engine model (paper §IV-F, Table II).
//!
//! Each compute die carries an SDMA engine with 160 channels performing
//! asynchronous strided copies between / within NUMA domains, without
//! occupying cores or polluting caches.  Achieved bandwidth depends
//! sharply on the contiguous run length of the strided pattern; the model
//! interpolates a calibration table anchored to the paper's Table II:
//!
//! | direction | block (z,x,y)  | run bytes | GB/s  |
//! |-----------|----------------|-----------|-------|
//! | X         | (16, 512, 512) | 64        | 57.9  |
//! | Y         | (512, 4, 512)  | 8192      | 144.1 |
//! | Z         | (512, 512, 4)  | 4 MiB     | 285.1 |
//!
//! (Layout (z, y, x), x contiguous, in the paper's Table II coordinates;
//! in this repo's (z, x, y) layout the same run lengths arise for the
//! corresponding face orientations.)

/// An asynchronous SDMA copy descriptor.
#[derive(Clone, Copy, Debug)]
pub struct CopyDesc {
    /// total payload bytes
    pub bytes: u64,
    /// contiguous run length of the strided pattern
    pub run_bytes: u64,
}

impl CopyDesc {
    /// Face exchange descriptor for a halo slab of `(depth, a, b)` f32
    /// elements where `b` spans the contiguous axis and runs merge when
    /// the slab is contiguous across `a` too.
    pub fn face(depth: usize, a: usize, b: usize, full_a: bool) -> Self {
        let bytes = (depth * a * b * 4) as u64;
        let run = if full_a { (a * b * 4) as u64 } else { (b * 4) as u64 };
        Self { bytes, run_bytes: run }
    }
}

/// The SDMA engine model.
#[derive(Clone, Copy, Debug)]
pub struct Sdma {
    pub channels: usize,
    pub peak_bw: f64,
    /// per-descriptor setup latency
    pub setup_us: f64,
}

impl Default for Sdma {
    fn default() -> Self {
        Self { channels: 160, peak_bw: 300e9, setup_us: 2.0 }
    }
}

/// Calibration anchors: (run_bytes, efficiency = achieved / peak),
/// log-linear interpolated.  Anchored to Table II with peak = 300 GB/s.
const CAL: [(f64, f64); 4] = [
    (64.0, 0.193),      // X-direction: 57.9 GB/s
    (8192.0, 0.480),    // Y-direction: 144.1 GB/s
    (4194304.0, 0.950), // Z-direction: 285.1 GB/s
    (1e9, 0.97),
];

impl Sdma {
    /// Efficiency for a given contiguous run length.
    pub fn efficiency(&self, run_bytes: u64) -> f64 {
        let x = (run_bytes.max(1) as f64).ln();
        if x <= CAL[0].0.ln() {
            return CAL[0].1;
        }
        for w in CAL.windows(2) {
            let (x0, y0) = (w[0].0.ln(), w[0].1);
            let (x1, y1) = (w[1].0.ln(), w[1].1);
            if x <= x1 {
                return y0 + (y1 - y0) * (x - x0) / (x1 - x0);
            }
        }
        CAL[CAL.len() - 1].1
    }

    /// Achieved bandwidth for a copy.
    pub fn bandwidth(&self, c: CopyDesc) -> f64 {
        self.peak_bw * self.efficiency(c.run_bytes)
    }

    /// Simulated transfer time (seconds) for a batch of copies executed
    /// across the channel pool (channels process descriptors in parallel;
    /// the link itself is shared).
    pub fn batch_time_s(&self, copies: &[CopyDesc]) -> f64 {
        if copies.is_empty() {
            return 0.0;
        }
        let setup_waves = copies.len().div_ceil(self.channels) as f64;
        let setup = setup_waves * self.setup_us * 1e-6;
        let transfer: f64 = copies.iter().map(|&c| c.bytes as f64 / self.bandwidth(c)).sum();
        setup + transfer
    }

    /// Non-intrusiveness: SDMA does not occupy cores (paper §IV-F), so a
    /// compute phase of `compute_s` overlapped with `comm_s` of SDMA
    /// finishes in `max` rather than `sum`.
    pub fn overlapped_time_s(compute_s: f64, comm_s: f64) -> f64 {
        compute_s.max(comm_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gbs(bw: f64) -> f64 {
        bw / 1e9
    }

    #[test]
    fn table2_x_direction() {
        let s = Sdma::default();
        // X halo of a 512³ grid: runs of 16 f32 = 64 B
        let c = CopyDesc { bytes: 16 * 512 * 512 * 4, run_bytes: 64 };
        let bw = gbs(s.bandwidth(c));
        assert!((bw - 57.9).abs() / 57.9 < 0.05, "X: {bw:.1} GB/s");
    }

    #[test]
    fn table2_y_direction() {
        let s = Sdma::default();
        let c = CopyDesc { bytes: 512 * 4 * 512 * 4, run_bytes: 8192 };
        let bw = gbs(s.bandwidth(c));
        assert!((bw - 144.1).abs() / 144.1 < 0.05, "Y: {bw:.1} GB/s");
    }

    #[test]
    fn table2_z_direction() {
        let s = Sdma::default();
        let c = CopyDesc { bytes: 512 * 512 * 4 * 4, run_bytes: 4 << 20 };
        let bw = gbs(s.bandwidth(c));
        assert!((bw - 285.1).abs() / 285.1 < 0.05, "Z: {bw:.1} GB/s");
    }

    #[test]
    fn efficiency_monotone_in_run_length() {
        let s = Sdma::default();
        let mut last = 0.0;
        for run in [64u64, 256, 1024, 8192, 65536, 1 << 22] {
            let e = s.efficiency(run);
            assert!(e >= last, "run {run}: {e}");
            last = e;
        }
    }

    #[test]
    fn batch_amortizes_setup_across_channels() {
        let s = Sdma::default();
        let one = CopyDesc { bytes: 1 << 20, run_bytes: 1 << 20 };
        let t160 = s.batch_time_s(&vec![one; 160]);
        let t1 = s.batch_time_s(&[one]);
        // 160 descriptors pay one setup wave, not 160
        assert!(t160 < 160.0 * t1);
    }

    #[test]
    fn overlap_is_max_not_sum() {
        assert_eq!(Sdma::overlapped_time_s(2.0, 1.5), 2.0);
        assert_eq!(Sdma::overlapped_time_s(1.0, 3.0), 3.0);
    }
}
