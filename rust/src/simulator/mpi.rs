//! MPI runtime cost model (paper §IV-F, Table II).
//!
//! "All implementations of the MPI runtime layer require a global lock to
//! protect shared data structures, ensuring concurrency but not full
//! parallelization" — so with few processes per die the inter-NUMA link
//! cannot be saturated.  The model charges:
//!
//! * a per-message overhead (progress-engine + matching, serialized by
//!   the global lock across concurrent ranks),
//! * a single-stream copy bandwidth through the shared-memory path,
//! * a pack/unpack memcpy for strided faces (MPI datatypes fall back to
//!   pack on this platform — RMA cannot control memory placement).
//!
//! Calibrated to Table II: X 3.62 GB/s, Y 5.31 GB/s, Z 6.98 GB/s.

/// MPI transfer model parameters.
#[derive(Clone, Copy, Debug)]
pub struct MpiModel {
    /// per-message overhead (seconds) under the global lock
    pub msg_overhead_s: f64,
    /// single-rank copy bandwidth through the shm path
    pub copy_bw: f64,
    /// pack/unpack bandwidth for strided data (one extra pass each side)
    pub pack_bw: f64,
    /// eager/rendezvous chunk size: larger faces split into messages
    pub chunk_bytes: u64,
}

impl Default for MpiModel {
    fn default() -> Self {
        Self {
            msg_overhead_s: 15e-6,
            copy_bw: 7.2e9,
            pack_bw: 40e9,
            chunk_bytes: 1 << 20,
        }
    }
}

impl MpiModel {
    /// Transfer time for one face of `bytes` with contiguous runs of
    /// `run_bytes` (strided faces pay pack + unpack).
    pub fn transfer_time_s(&self, bytes: u64, run_bytes: u64) -> f64 {
        let msgs = bytes.div_ceil(self.chunk_bytes) as f64;
        let mut t = msgs * self.msg_overhead_s + bytes as f64 / self.copy_bw;
        if run_bytes < self.chunk_bytes {
            // pack on the send side, unpack on the receive side; shorter
            // runs cost more per byte (per-run loop overhead)
            let run_penalty = 1.0 + 64.0 / run_bytes.max(16) as f64;
            t += 2.0 * bytes as f64 / self.pack_bw * run_penalty;
        }
        t
    }

    /// Achieved bandwidth for one face.
    pub fn bandwidth(&self, bytes: u64, run_bytes: u64) -> f64 {
        bytes as f64 / self.transfer_time_s(bytes, run_bytes)
    }

    /// MPI communication does occupy a core (progress engine), so
    /// compute/comm "overlap" still serializes.
    pub fn overlapped_time_s(compute_s: f64, comm_s: f64) -> f64 {
        compute_s + comm_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gbs(b: f64) -> f64 {
        b / 1e9
    }

    #[test]
    fn table2_anchors_within_15pct() {
        let m = MpiModel::default();
        // X: (16,512,512) runs of 64 B → 3.62 GB/s
        let x = gbs(m.bandwidth(16 * 512 * 512 * 4, 64));
        assert!((x - 3.62).abs() / 3.62 < 0.15, "X {x:.2}");
        // Y: runs of 8 KiB → 5.31 GB/s
        let y = gbs(m.bandwidth(512 * 4 * 512 * 4, 8192));
        assert!((y - 5.31).abs() / 5.31 < 0.15, "Y {y:.2}");
        // Z: contiguous → 6.98 GB/s
        let z = gbs(m.bandwidth(512 * 512 * 4 * 4, 4 << 20));
        assert!((z - 6.98).abs() / 6.98 < 0.15, "Z {z:.2}");
    }

    #[test]
    fn sdma_speedup_magnitudes_match_table2() {
        // paper: 15.9× (X), 27.2× (Y), 40.8× (Z)
        let m = MpiModel::default();
        let s = super::super::sdma::Sdma::default();
        let cases = [
            (16 * 512 * 512 * 4u64, 64u64, 15.9),
            (512 * 4 * 512 * 4, 8192, 27.2),
            (512 * 512 * 4 * 4, 4 << 20, 40.8),
        ];
        for (bytes, run, want) in cases {
            let mpi = m.bandwidth(bytes, run);
            let sd = s.bandwidth(super::super::sdma::CopyDesc { bytes, run_bytes: run });
            let ratio = sd / mpi;
            assert!(
                (ratio - want).abs() / want < 0.25,
                "run {run}: ratio {ratio:.1} want {want}"
            );
        }
    }

    #[test]
    fn contiguous_beats_strided() {
        let m = MpiModel::default();
        assert!(m.bandwidth(1 << 22, 1 << 22) > m.bandwidth(1 << 22, 64));
    }

    #[test]
    fn overlap_serializes() {
        assert_eq!(MpiModel::overlapped_time_s(1.0, 2.0), 3.0);
    }
}
