//! Platform parameter set for the simulated multicore SoC.
//!
//! All numbers are either stated in the paper or derived from its
//! published results:
//!
//! * §V-A: one server node = 2 processors, 4 compute dies, 16 NUMA
//!   nodes, 608 cores → 38 cores per NUMA node;
//! * §II-B: 512-bit SIMD (VL = 16 fp32 lanes); the matrix accumulator is
//!   a 64×64-byte tile = 4 independent 16×16 fp32 tiles; DDR subsystem
//!   120 GB/s per die; SDMA with 160 channels;
//! * §IV-B: CPI_SIMD = 0.5, CPI_Matrix = 2 (single precision), and §V-D:
//!   outer-product latency 4 cycles;
//! * §V-C: 2D stars sustain >280 GB/s ≈ 70% of on-package peak →
//!   on-package peak ≈ 400 GB/s per NUMA node;
//! * §V-C: 3DBoxR2 theoretical peak = 3.75 TFLOPS per NUMA node; with
//!   r=2 the §IV-B ratio is exactly 1.0 × FLOPS_SIMD, so
//!   FLOPS_SIMD = 3.75e12 = cores × VL × 2 × (1/CPI_SIMD) × f_simd
//!   → f_simd ≈ 1.54 GHz at 38 cores;
//! * §V-C: "the core operates at a higher frequency in SIMD mode than in
//!   Matrix mode" — we model f_matrix = 0.94 × f_simd.

/// Static description of the simulated platform.
#[derive(Clone, Debug)]
pub struct Platform {
    // topology
    pub processors: usize,
    pub dies_per_processor: usize,
    pub numa_per_die: usize,
    pub cores_per_numa: usize,
    // vector / matrix units
    pub vl: usize,
    pub matrix_tiles: usize,
    pub cpi_simd: f64,
    pub cpi_matrix: f64,
    pub outer_product_latency: u64,
    pub freq_simd_hz: f64,
    pub freq_matrix_hz: f64,
    // private caches (no shared LLC on this SoC)
    pub l1_bytes: usize,
    pub l2_bytes: usize,
    pub cacheline_bytes: usize,
    // memory system
    pub onpkg_bw_per_numa: f64,
    pub onpkg_port_bits: usize,
    pub ddr_bw_per_die: f64,
    pub ddr_port_bits: usize,
    // SDMA engine
    pub sdma_channels: usize,
    pub sdma_peak_bw: f64,
    // inter-core transfer (snoop service) vs main memory
    pub snoop_latency_ns: f64,
    pub mem_latency_ns: f64,
}

impl Default for Platform {
    fn default() -> Self {
        Self::paper()
    }
}

impl Platform {
    /// The paper's experimental platform.
    pub fn paper() -> Self {
        Self {
            processors: 2,
            dies_per_processor: 2,
            numa_per_die: 4,
            cores_per_numa: 38,
            vl: 16,
            matrix_tiles: 4,
            cpi_simd: 0.5,
            cpi_matrix: 2.0,
            outer_product_latency: 4,
            freq_simd_hz: 1.54e9,
            freq_matrix_hz: 1.45e9,
            l1_bytes: 64 << 10,
            l2_bytes: 512 << 10,
            cacheline_bytes: 64,
            onpkg_bw_per_numa: 400e9,
            onpkg_port_bits: 1024,
            ddr_bw_per_die: 120e9,
            ddr_port_bits: 64,
            sdma_channels: 160,
            sdma_peak_bw: 300e9,
            snoop_latency_ns: 45.0,
            mem_latency_ns: 110.0,
        }
    }

    pub fn total_numa(&self) -> usize {
        self.processors * self.dies_per_processor * self.numa_per_die
    }

    pub fn total_cores(&self) -> usize {
        self.total_numa() * self.cores_per_numa
    }

    /// Peak SIMD FLOPS of one NUMA node (fp32, FMA = 2 flops/lane).
    pub fn simd_flops_per_numa(&self) -> f64 {
        self.cores_per_numa as f64 * self.vl as f64 * 2.0 * (1.0 / self.cpi_simd)
            * self.freq_simd_hz
    }

    /// Peak matrix-unit FLOPS of one NUMA node: one VL×VL outer product
    /// (2·VL² flops) per CPI_Matrix cycles per core.
    pub fn matrix_flops_per_numa(&self) -> f64 {
        self.cores_per_numa as f64 * 2.0 * (self.vl * self.vl) as f64
            / self.cpi_matrix
            * self.freq_matrix_hz
    }

    /// The §IV-B achievable matrix-unit throughput for a radius-r 1D
    /// stencil, as a fraction of SIMD peak:
    /// `VL(2r+1)·CPI_SIMD / ((VL+2r)·CPI_Matrix) × (f_matrix/f_simd)`.
    pub fn mmstencil_speedup(&self, radius: usize) -> f64 {
        let vl = self.vl as f64;
        let r = radius as f64;
        vl * (2.0 * r + 1.0) * self.cpi_simd / ((vl + 2.0 * r) * self.cpi_matrix)
            * (self.freq_matrix_hz / self.freq_simd_hz)
    }

    /// On-package DDR port width in bytes.
    pub fn onpkg_port_bytes(&self) -> usize {
        self.onpkg_port_bits / 8
    }

    /// Modeled cost of spawning `n` OS threads (~25 µs each, serialized
    /// in the parent).  A per-call scoped pool pays this on *every*
    /// dispatch; the persistent runtime pays it once per driver — the
    /// Fig. 13 bench reports both so scaling losses can be attributed.
    pub fn thread_spawn_overhead_s(&self, n: usize) -> f64 {
        n as f64 * 25e-6
    }

    /// A100 reference platform (for the GPU comparison series): 1955 GB/s
    /// HBM (paper §III-B).
    pub fn a100_bw() -> f64 {
        1955e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topology_matches_paper() {
        let p = Platform::paper();
        assert_eq!(p.total_numa(), 16);
        assert_eq!(p.total_cores(), 608);
    }

    #[test]
    fn simd_peak_near_3_75_tflops() {
        // §V-C: 3DBoxR2 theoretical peak 3.75 TFLOPS per NUMA
        let p = Platform::paper();
        let peak = p.simd_flops_per_numa();
        assert!((peak - 3.75e12).abs() / 3.75e12 < 0.01, "peak {peak:.3e}");
    }

    #[test]
    fn iv_b_model_values() {
        let p = Platform::paper();
        // r=1: 16·3·0.5/(18·2) = 0.667 × freq ratio → below 1: SIMD wins
        assert!(p.mmstencil_speedup(1) < 1.0);
        // r=2: ratio 1.0 × freq ratio ≈ 0.94
        assert!((p.mmstencil_speedup(2) - 0.94).abs() < 0.02);
        // r=4: 16·9·0.5/(24·2) = 1.5 × freq ratio ≈ 1.41 — the paper's
        // "theoretical 1.5× at r = 4"
        assert!(p.mmstencil_speedup(4) > 1.35);
        // monotone in r
        assert!(p.mmstencil_speedup(3) > p.mmstencil_speedup(2));
    }

    #[test]
    fn onpkg_utilization_anchor() {
        // 280 GB/s ≈ 70% of the modeled 400 GB/s peak
        let p = Platform::paper();
        assert!((280e9 / p.onpkg_bw_per_numa - 0.70) < 0.01);
    }

    #[test]
    fn spawn_overhead_scales_linearly() {
        let p = Platform::paper();
        assert_eq!(p.thread_spawn_overhead_s(0), 0.0);
        let one = p.thread_spawn_overhead_s(1);
        assert!((p.thread_spawn_overhead_s(38) - 38.0 * one).abs() < 1e-12);
        // a 38-thread respawn per dispatch costs ~1 ms — visible against
        // the sub-ms simulated sweep times the benches report
        assert!(p.thread_spawn_overhead_s(38) > 5e-4);
    }

    #[test]
    fn matrix_peak_exceeds_simd_peak() {
        // 256 MACs / 2 cycles ≫ 32 flops/cycle SIMD
        let p = Platform::paper();
        assert!(p.matrix_flops_per_numa() > p.simd_flops_per_numa());
    }
}
