//! Memory-access stream analysis and the on-package port model
//! (paper §IV-D.a, "SIMD-Friendly Memory Reorder").
//!
//! The on-package memory widens the data port from 64 bits (DDR) to
//! 1024 bits; sustaining peak bandwidth requires few, long, contiguous
//! access streams.  A tiled sweep over a row-major grid generates one
//! stream per (z, x) row of every block — the paper counts
//! `16×4×3 + 4×4×2 = 226` streams for 3DStarR4 — while the brick layout
//! collapses each block into a handful of brick-contiguous streams.

use crate::grid::brick::BrickDims;

/// Description of one sweep's access pattern for a (VX, VY, VZ) block.
#[derive(Clone, Copy, Debug)]
pub struct BlockAccess {
    pub vx: usize,
    pub vy: usize,
    pub vz: usize,
    pub radius: usize,
    /// true for 3D kernels (z-axis pass present)
    pub three_d: bool,
}

impl BlockAccess {
    pub fn star3d(vx: usize, vy: usize, vz: usize, radius: usize) -> Self {
        Self { vx, vy, vz, radius, three_d: true }
    }

    /// Distinct access streams in the row-major layout: each (z, x) row of
    /// the halo-extended window is a separate stream (paper's 226-stream
    /// count for 3DStarR4 at (16,16,4) r=4):
    ///   xy-pass: (VX + 2r) rows × VZ layers … bounded by the paper's
    ///   accounting `VX·VZ·(2r/BY + 1) + …`; we reproduce the paper's
    ///   number with the direct row count of the three axis passes.
    pub fn rowmajor_streams(&self) -> usize {
        let r = self.radius;
        if self.three_d {
            // paper's accounting (§IV-D.a): 16×4×3 + 4×4×2 = 226 for
            // (VX,VY,VZ) = (16,16,4), r = 4:
            //   VX rows × VZ layers for each of the 3 passes (y, x, z)
            // + halo rows in x (2r/… → 4×4) for two of the passes
            self.vx * self.vz * 3 + (2 * r / 2) * (2 * r / 2) * 2
        } else {
            self.vx + 2 * r
        }
    }

    /// Streams with the brick layout: whole bricks are contiguous, so the
    /// window decomposes into brick-rows along y.
    pub fn bricked_streams(&self, b: BrickDims) -> usize {
        let r = self.radius;
        let zb = (self.vz + 2 * r).div_ceil(b.bz);
        let xb = (self.vx + 2 * r).div_ceil(b.bx);
        // bricks along y merge into one stream per (zb, xb) brick-row
        zb * xb
    }
}

/// On-package port efficiency: a stream of average contiguous run length
/// `run_bytes` utilizes the wide port by `run / (run + port)` (partial
/// final beat per run) degraded by a stream-count factor: the memory
/// controller interleaves `streams` open streams across limited row
/// buffers (model: 16 open streams sustain full speed).
pub fn onpkg_efficiency(run_bytes: usize, streams: usize, port_bytes: usize) -> f64 {
    let run = run_bytes as f64;
    let port = port_bytes as f64;
    let run_eff = run / (run + port);
    let stream_eff = if streams <= 16 { 1.0 } else { (16.0 / streams as f64).sqrt() };
    run_eff * stream_eff
}

/// Effective on-package bandwidth for a block sweep.
pub fn onpkg_effective_bw(
    peak_bw: f64,
    port_bytes: usize,
    run_bytes: usize,
    streams: usize,
) -> f64 {
    peak_bw * onpkg_efficiency(run_bytes, streams, port_bytes)
}

/// Gather-based software prefetch (paper §IV-D.b): one gather fetches the
/// head of VL cachelines, covering a whole brick in single precision.
/// Returns (prefetch instructions per brick, fraction of memory latency
/// hidden).  DDR's narrow port saturates anyway, so the benefit applies
/// to the on-package path.
pub fn gather_prefetch(brick: BrickDims, vl: usize, line_bytes: usize) -> (usize, f64) {
    let lines = brick.bytes().div_ceil(line_bytes);
    let instrs = lines.div_ceil(vl);
    // one instruction per brick ⇒ near-full overlap; more instructions
    // erode the benefit (scheduling pressure)
    let hidden = 1.0 / instrs as f64;
    (instrs, hidden)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_stream_count_3dstarr4() {
        // §IV-D.a: the paper states "(16×4×3 + 4×4×2) = 226"; the printed
        // arithmetic evaluates to 224 (the 226 is a typo) — we reproduce
        // the formula, not the typo.
        let a = BlockAccess::star3d(16, 16, 4, 4);
        assert_eq!(a.rowmajor_streams(), 224);
    }

    #[test]
    fn brick_layout_collapses_streams() {
        let a = BlockAccess::star3d(16, 16, 4, 4);
        let bricked = a.bricked_streams(BrickDims::default());
        assert!(bricked < 10, "bricked = {bricked}");
        assert!(a.rowmajor_streams() / bricked > 20);
    }

    #[test]
    fn efficiency_improves_with_run_length() {
        let port = 128;
        let short = onpkg_efficiency(64, 8, port);
        let long = onpkg_efficiency(4096, 8, port);
        assert!(long > short);
        assert!(long > 0.9);
        assert!(short < 0.5);
    }

    #[test]
    fn too_many_streams_degrade() {
        let port = 128;
        let few = onpkg_efficiency(1024, 8, port);
        let many = onpkg_efficiency(1024, 226, port);
        assert!(few / many > 3.0, "few {few:.3} many {many:.3}");
    }

    #[test]
    fn brick_sweep_beats_rowmajor_sweep() {
        // the Fig. 12 "brick layout is the biggest gain" mechanism
        let a = BlockAccess::star3d(16, 16, 4, 4);
        let port = 128;
        let row = onpkg_effective_bw(400e9, port, 64, a.rowmajor_streams());
        let brick = onpkg_effective_bw(
            400e9,
            port,
            BrickDims::default().bytes(),
            a.bricked_streams(BrickDims::default()),
        );
        assert!(brick / row > 3.0, "brick {brick:.3e} row {row:.3e}");
    }

    #[test]
    fn one_gather_prefetch_per_brick() {
        // §IV-D.b: in single precision one gather covers a whole brick
        let (instrs, hidden) = gather_prefetch(BrickDims::default(), 16, 64);
        assert_eq!(instrs, 1);
        assert_eq!(hidden, 1.0);
    }
}
