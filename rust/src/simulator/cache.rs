//! Set-associative LRU cache simulator.
//!
//! The SoC has no shared LLC (paper §IV-E); each core owns private L1/L2
//! caches with an LRU policy.  This simulator validates the analytic
//! reuse-ratio formulas of `coordinator::tiles` on small grids and
//! quantifies the cache-pollution effect of writing intermediates to the
//! destination grid (§IV-C.c).

/// A set-associative cache with LRU replacement, tracked at cache-line
/// granularity.  Addresses are byte addresses.
#[derive(Clone, Debug)]
pub struct Cache {
    pub line_bytes: usize,
    pub sets: usize,
    pub ways: usize,
    /// tags[set][way], paired with an LRU timestamp.
    tags: Vec<Vec<(u64, u64)>>,
    clock: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// write-backs of dirty lines (write-allocate, write-back policy)
    pub writebacks: u64,
    dirty: Vec<Vec<bool>>,
}

impl Cache {
    /// Build from total capacity / associativity / line size.
    pub fn new(capacity_bytes: usize, ways: usize, line_bytes: usize) -> Self {
        let lines = capacity_bytes / line_bytes;
        assert!(lines >= ways && lines % ways == 0, "bad geometry");
        let sets = lines / ways;
        Self {
            line_bytes,
            sets,
            ways,
            tags: vec![Vec::with_capacity(ways); sets],
            dirty: vec![Vec::new(); sets],
            clock: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
            writebacks: 0,
        }
    }

    #[inline]
    fn set_of(&self, addr: u64) -> (usize, u64) {
        let line = addr / self.line_bytes as u64;
        ((line % self.sets as u64) as usize, line / self.sets as u64)
    }

    /// Access one byte address. Returns true on hit.
    pub fn access(&mut self, addr: u64, write: bool) -> bool {
        self.clock += 1;
        let (set, tag) = self.set_of(addr);
        let ways = &mut self.tags[set];
        let dirty = &mut self.dirty[set];
        if let Some(pos) = ways.iter().position(|&(t, _)| t == tag) {
            ways[pos].1 = self.clock;
            if write {
                dirty[pos] = true;
            }
            self.hits += 1;
            return true;
        }
        self.misses += 1;
        if ways.len() < self.ways {
            ways.push((tag, self.clock));
            dirty.push(write);
        } else {
            // evict LRU
            let (victim, _) = ways
                .iter()
                .enumerate()
                .min_by_key(|(_, &(_, ts))| ts)
                .map(|(i, v)| (i, *v))
                .unwrap();
            if dirty[victim] {
                self.writebacks += 1;
            }
            self.evictions += 1;
            ways[victim] = (tag, self.clock);
            dirty[victim] = write;
        }
        false
    }

    /// Access a contiguous byte range (every line it touches).
    pub fn access_range(&mut self, addr: u64, bytes: usize, write: bool) {
        let first = addr / self.line_bytes as u64;
        let last = (addr + bytes as u64 - 1) / self.line_bytes as u64;
        for line in first..=last {
            self.access(line * self.line_bytes as u64, write);
        }
    }

    pub fn reset_counters(&mut self) {
        self.hits = 0;
        self.misses = 0;
        self.evictions = 0;
        self.writebacks = 0;
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return 0.0;
        }
        self.hits as f64 / total as f64
    }

    /// Bytes of main-memory traffic implied so far (miss fills + WBs).
    pub fn traffic_bytes(&self) -> u64 {
        (self.misses + self.writebacks) * self.line_bytes as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_fill_then_rescan_hits() {
        let mut c = Cache::new(4096, 4, 64); // 64 lines
        for i in 0..32 {
            c.access(i * 64, false);
        }
        assert_eq!(c.misses, 32);
        c.reset_counters();
        for i in 0..32 {
            c.access(i * 64, false);
        }
        assert_eq!(c.hits, 32);
        assert_eq!(c.misses, 0);
    }

    #[test]
    fn capacity_eviction_under_streaming() {
        let mut c = Cache::new(1024, 2, 64); // 16 lines
        for i in 0..64 {
            c.access(i * 64, false);
        }
        // stream larger than capacity: all misses
        assert_eq!(c.misses, 64);
        assert!(c.evictions >= 48);
    }

    #[test]
    fn lru_prefers_recent() {
        // 1 set, 2 ways: A, B, touch A, then C evicts B (LRU)
        let mut c = Cache::new(128, 2, 64);
        assert_eq!(c.sets, 1);
        c.access(0, false); // A
        c.access(64, false); // B
        c.access(0, false); // A again (MRU)
        c.access(128, false); // C -> evicts B
        c.reset_counters();
        c.access(0, false);
        assert_eq!(c.hits, 1);
        c.access(64, false);
        assert_eq!(c.misses, 1, "B must have been evicted");
    }

    #[test]
    fn writeback_on_dirty_eviction() {
        let mut c = Cache::new(128, 2, 64);
        c.access(0, true); // dirty A
        c.access(64, false);
        c.access(128, false); // evicts dirty A
        assert_eq!(c.writebacks, 1);
    }

    #[test]
    fn destination_write_pollutes_cache() {
        // §IV-C.c: streaming writes to the destination evict the input
        // working set; a small temp buffer does not.
        let line = 64;
        let mut with_dest = Cache::new(8192, 4, line);
        let mut with_temp = Cache::new(8192, 4, line);
        let input = 0u64;
        let dest = 1 << 20;
        let temp = 2 << 20;
        let ws = 6 * 1024; // input working set fits in cache
        for round in 0..4 {
            let _ = round;
            // both read the same input working set
            for off in (0..ws).step_by(line) {
                with_dest.access(input + off as u64, false);
                with_temp.access(temp_read(off), false);
            }
            // dest version writes a large streaming output region
            for off in (0..32 * 1024).step_by(line) {
                with_dest.access(dest + off as u64, true);
            }
            // temp version reuses one small buffer
            for off in (0..1024).step_by(line) {
                with_temp.access(temp + off as u64, true);
            }
        }
        fn temp_read(off: usize) -> u64 {
            off as u64
        }
        assert!(
            with_temp.hit_rate() > with_dest.hit_rate(),
            "temp {:.3} vs dest {:.3}",
            with_temp.hit_rate(),
            with_dest.hit_rate()
        );
    }

    #[test]
    fn access_range_touches_every_line() {
        let mut c = Cache::new(4096, 4, 64);
        c.access_range(10, 200, false); // lines 0..3
        assert_eq!(c.misses, 4);
    }
}
