//! Intra-NUMA ring interconnect model (paper §II-B: ">32 cores connected
//! in a ring topology" per NUMA domain).
//!
//! Used by the snoop analysis to cost peer-cache transfers: latency grows
//! with hop distance on the ring, which is why the adjacent-tile
//! assignment matters (neighbouring tiles land on neighbouring cores).

/// A unidirectional-shortest-path ring of `n` stations.
#[derive(Clone, Copy, Debug)]
pub struct Ring {
    pub stations: usize,
    pub hop_latency_ns: f64,
    /// per-station injection overhead
    pub injection_ns: f64,
}

impl Ring {
    pub fn new(stations: usize) -> Self {
        Self { stations, hop_latency_ns: 1.2, injection_ns: 6.0 }
    }

    /// Shortest hop distance between two stations.
    pub fn hops(&self, a: usize, b: usize) -> usize {
        assert!(a < self.stations && b < self.stations);
        let d = a.abs_diff(b);
        d.min(self.stations - d)
    }

    /// One-way message latency between stations.
    pub fn latency_ns(&self, a: usize, b: usize) -> f64 {
        self.injection_ns + self.hops(a, b) as f64 * self.hop_latency_ns
    }

    /// Average latency from `a` to every other station (directory
    /// broadcast cost proxy).
    pub fn mean_latency_ns(&self, a: usize) -> f64 {
        let sum: f64 = (0..self.stations)
            .filter(|&b| b != a)
            .map(|b| self.latency_ns(a, b))
            .sum();
        sum / (self.stations - 1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hops_shortest_path() {
        let r = Ring::new(8);
        assert_eq!(r.hops(0, 1), 1);
        assert_eq!(r.hops(0, 7), 1); // wraps
        assert_eq!(r.hops(0, 4), 4);
        assert_eq!(r.hops(2, 2), 0);
    }

    #[test]
    fn adjacent_cores_cheapest() {
        let r = Ring::new(38);
        assert!(r.latency_ns(5, 6) < r.latency_ns(5, 20));
    }

    #[test]
    fn mean_latency_symmetric() {
        let r = Ring::new(16);
        assert!((r.mean_latency_ns(0) - r.mean_latency_ns(9)).abs() < 1e-9);
    }
}
