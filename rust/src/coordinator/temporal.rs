//! Temporal-blocking plan: deep halos, shrinking sub-step boxes, and
//! the depth clamp (the PR 5 tentpole geometry).
//!
//! The paper frames boundary handling as the limit on "the depth of
//! temporal blocking" (§III-B).  For the periodic multirank sweep the
//! only boundary is the halo, so the depth *is* tunable: widen every
//! rank's halo to `k·r` ([`HaloGrid::with_depth`]), exchange **once**,
//! then run `k` back-to-back sweeps over the slab.  Each fused sub-step
//! consumes `r` layers of halo validity, so its writable box shrinks by
//! `r` per side — the classic trapezoid rule:
//!
//! ```text
//! storage   [0 ........................... dim + 2h)      h = k·r
//! exchange  [═ halo ═][═══ interior ═══][═ halo ═]        valid: ± h
//! sub-step 0     [──────── ± (k-1)·r ────────]
//! sub-step 1        [────── ± (k-2)·r ──────]
//!   ⋮
//! sub-step k-1          [═══ interior ═══]               valid: ± 0
//! ```
//!
//! Every box returned here keeps all stencil reads **in bounds** of the
//! rank's storage (each point stays ≥ r away from the storage faces),
//! so the engines' wrap-free interior kernels compute the fused
//! sub-steps with exactly the per-point arithmetic of the k = 1 path —
//! the bitwise-equality contract `rust/tests/temporal.rs` pins.
//!
//! Ownership of halo depth: the plan (this module) decides `h = k·r`
//! once per fused round; [`HaloGrid`] stores it; the exchange packs and
//! unpacks whatever depth the grids carry (its boxes are depth-generic);
//! the engines never see the halo at all — they just get shrinking
//! claimed boxes.  See DESIGN.md §11.
//!
//! [`HaloGrid::with_depth`]: crate::grid::halo::HaloGrid::with_depth
//! [`HaloGrid`]: crate::grid::halo::HaloGrid

use crate::grid::decomp::CartDecomp;
use crate::grid::shell::{self, Boxes};

/// Maximum fusable depth `k` for one nearest-neighbour exchange: every
/// *decomposed* axis (process count > 1) must give each rank at least
/// `k·r` owned layers, or the packed face would reach past the
/// neighbour's interior into data it does not own.  Undecomposed axes
/// never exchange — their halos come straight from the global wrap
/// fill, which is depth-unlimited — so they do not clamp.  Always ≥ 1
/// (the classic one-step exchange is the floor the k = 1 path already
/// assumes).
pub fn max_depth(decomp: &CartDecomp, nz: usize, nx: usize, ny: usize, r: usize) -> usize {
    let mut cap = usize::MAX;
    for (p, n) in [(decomp.pz, nz), (decomp.px, nx), (decomp.py, ny)] {
        if p > 1 {
            // CartDecomp::split hands out near-equal chunks; the
            // smallest is the floor quotient
            cap = cap.min((n / p) / r.max(1));
        }
    }
    cap.max(1)
}

/// The depth a fused run actually uses: the requested `time_block`
/// clamped to `[1, max_depth]`.
pub fn effective_depth(
    requested: usize,
    decomp: &CartDecomp,
    nz: usize,
    nx: usize,
    ny: usize,
    r: usize,
) -> usize {
    requested.clamp(1, max_depth(decomp, nz, nx, ny, r))
}

/// Valid compute box (halo-storage coordinates) of fused sub-step
/// `s ∈ [0, k)` for a rank with interior `(nz, nx, ny)` and halo
/// `h = k·r`: the interior grown by `(k-1-s)·r` on every side.
/// Sub-step `s` reads its input on the next-larger extension
/// (`substep_box(.., s)` grown by `r`, which sub-step `s-1` wrote — or
/// the freshly exchanged frame for `s = 0`), and the final sub-step
/// writes exactly the interior.
pub fn substep_box(nz: usize, nx: usize, ny: usize, r: usize, k: usize, s: usize) -> [usize; 6] {
    assert!(s < k, "sub-step {s} out of range for depth {k}");
    let h = k * r;
    let e = (k - 1 - s) * r;
    [h - e, nz + h + e, h - e, nx + h + e, h - e, ny + h + e]
}

/// The halo-independent part of sub-step 0: the rank interior shrunk by
/// `r` (every stencil read stays inside the pre-exchange-valid interior
/// `[h, dim + h)`), in halo-storage coordinates.  `None` when the rank
/// is too thin to have one — then the whole sub-step-0 box waits for
/// the exchange.  This is the batch the SDMA exchange overlaps with
/// (paper Fig. 9), generalizing the k = 1 deep-interior batch.
pub fn substep0_deep_box(
    nz: usize,
    nx: usize,
    ny: usize,
    r: usize,
    k: usize,
) -> Option<[usize; 6]> {
    let h = k * r;
    shell::interior_box(nz, nx, ny, r)
        .map(|b| [b[0] + h, b[1] + h, b[2] + h, b[3] + h, b[4] + h, b[5] + h])
}

/// The halo-dependent frame of sub-step 0: its full box minus the deep
/// part — the ≤ 6 slabs that wait on the exchange
/// ([`shell::difference_boxes`]).
pub fn substep0_frame_boxes(nz: usize, nx: usize, ny: usize, r: usize, k: usize) -> Boxes<6, 6> {
    shell::difference_boxes(
        substep_box(nz, nx, ny, r, k, 0),
        substep0_deep_box(nz, nx, ny, r, k),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn substep_boxes_shrink_to_the_interior() {
        let (nz, nx, ny, r, k) = (10, 12, 14, 2, 3);
        let h = k * r;
        for s in 0..k {
            let b = substep_box(nz, nx, ny, r, k, s);
            let e = (k - 1 - s) * r;
            assert_eq!(b, [h - e, nz + h + e, h - e, nx + h + e, h - e, ny + h + e]);
            // stencil support of every computed point stays in storage
            assert!(b[0] >= r && b[1] + r <= nz + 2 * h);
        }
        // final sub-step writes exactly the interior
        assert_eq!(substep_box(nz, nx, ny, r, k, k - 1), [h, nz + h, h, nx + h, h, ny + h]);
        // k = 1 degenerates to the classic single-step box
        assert_eq!(substep_box(nz, nx, ny, r, 1, 0), [r, nz + r, r, nx + r, r, ny + r]);
    }

    #[test]
    fn substep_support_nests_by_one_radius() {
        // sub-step s+1 reads exactly what sub-step s wrote: its box
        // grown by r equals the previous sub-step's box
        let (nz, nx, ny, r, k) = (9, 7, 11, 3, 4);
        for s in 1..k {
            let prev = substep_box(nz, nx, ny, r, k, s - 1);
            let cur = substep_box(nz, nx, ny, r, k, s);
            for a in 0..3 {
                assert_eq!(cur[2 * a] - r, prev[2 * a], "s={s} axis={a}");
                assert_eq!(cur[2 * a + 1] + r, prev[2 * a + 1], "s={s} axis={a}");
            }
        }
    }

    #[test]
    fn substep_boxes_survive_degenerate_geometries() {
        // The wavefront planner slices these boxes into (z, t) tiles,
        // so the algebra must hold on pathologically thin ranks too:
        // zero-extent axes, single-cell ranks, every (r, k, s).
        for (nz, nx, ny) in [(0usize, 5, 5), (1, 1, 1), (2, 0, 7), (5, 5, 5)] {
            let dims = [nz, nx, ny];
            for r in [1usize, 2, 4] {
                for k in [1usize, 2, 4] {
                    let h = k * r;
                    for s in 0..k {
                        let b = substep_box(nz, nx, ny, r, k, s);
                        // nesting: growing box s by r gives box s-1
                        if s > 0 {
                            let prev = substep_box(nz, nx, ny, r, k, s - 1);
                            for a in 0..3 {
                                assert_eq!(b[2 * a] - r, prev[2 * a], "s={s} axis={a}");
                                assert_eq!(b[2 * a + 1] + r, prev[2 * a + 1], "s={s} axis={a}");
                            }
                        }
                        for a in 0..3 {
                            // sub-steps past the first keep a ≥ 2r
                            // margin from the storage faces — the
                            // wrap-free-interior guarantee the engines
                            // (and the wavefront tiles) rely on
                            let margin = if s == 0 { r } else { 2 * r };
                            assert!(b[2 * a] >= margin, "s={s} axis={a}: {b:?}");
                            assert!(
                                b[2 * a + 1] + margin <= dims[a] + 2 * h,
                                "s={s} axis={a}: {b:?}"
                            );
                            // extent is the axis plus the trapezoid
                            // growth: a zero-extent axis leaves an
                            // empty final box, a halo-only slab before
                            let e = (k - 1 - s) * r;
                            assert_eq!(b[2 * a + 1] - b[2 * a], dims[a] + 2 * e);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn deep_and_frame_partition_substep0() {
        for (nz, nx, ny, r, k) in [(10, 12, 14, 2, 3), (6, 6, 6, 1, 4), (3, 8, 8, 2, 2)] {
            let b0 = substep_box(nz, nx, ny, r, k, 0);
            let (sz, sx, sy) = (nz + 2 * k * r, nx + 2 * k * r, ny + 2 * k * r);
            let mut hits = vec![0u8; sz * sx * sy];
            let mut mark = |b: [usize; 6]| {
                for z in b[0]..b[1] {
                    for x in b[2]..b[3] {
                        for y in b[4]..b[5] {
                            hits[(z * sx + x) * sy + y] += 1;
                        }
                    }
                }
            };
            if let Some(d) = substep0_deep_box(nz, nx, ny, r, k) {
                mark(d);
            }
            for f in substep0_frame_boxes(nz, nx, ny, r, k) {
                mark(f);
            }
            for z in 0..sz {
                for x in 0..sx {
                    for y in 0..sy {
                        let inside = (b0[0]..b0[1]).contains(&z)
                            && (b0[2]..b0[3]).contains(&x)
                            && (b0[4]..b0[5]).contains(&y);
                        assert_eq!(
                            hits[(z * sx + x) * sy + y],
                            u8::from(inside),
                            "({nz},{nx},{ny}) r={r} k={k} at ({z},{x},{y})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn depth_clamps_on_decomposed_axes_only() {
        // (1,1,2) split of ny = 13 at r = 2: min block 6 → depth ≤ 3
        let d = CartDecomp::new(1, 1, 2);
        assert_eq!(max_depth(&d, 5, 5, 13, 2), 3);
        assert_eq!(effective_depth(8, &d, 5, 5, 13, 2), 3);
        assert_eq!(effective_depth(2, &d, 5, 5, 13, 2), 2);
        assert_eq!(effective_depth(0, &d, 5, 5, 13, 2), 1);
        // undecomposed axes never clamp: nz = 5 < 2r·4 is fine at pz = 1
        assert_eq!(max_depth(&CartDecomp::new(1, 1, 1), 5, 5, 5, 4), usize::MAX);
        // multiple decomposed axes take the tightest
        let d = CartDecomp::new(2, 3, 1);
        assert_eq!(max_depth(&d, 16, 9, 50, 1), 3); // nx/3 = 3 layers
        // a too-thin decomposed axis still reports 1 (the k = 1 floor)
        assert_eq!(max_depth(&CartDecomp::new(4, 1, 1), 7, 9, 9, 4), 1);
    }
}
