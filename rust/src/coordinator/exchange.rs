//! Inter-rank halo exchange (paper §IV-F).
//!
//! Ranks model NUMA-domain processes.  The *data path* is real — faces
//! are packed, moved, and unpacked between subdomain buffers — while the
//! *cost* of the transport is accounted under two backends:
//!
//! * `Sdma` — the per-die SDMA engine: descriptors batched across 160
//!   channels, non-intrusive (overlaps with compute);
//! * `Mpi`  — the lock-serialized MPI runtime: per-message overhead,
//!   single-stream copies, pack penalty for strided faces.
//!
//! The exchange operates on [`HaloView`]s: every halo write claims the
//! target frame box as an exclusive `TileViewMut`, so the SDMA variant
//! can run as a pool task concurrently with compute tasks that read the
//! same storage through the views' shared cell access — without any
//! `&mut` aliasing (see `grid::par`).  The `&mut [HaloGrid]` entry
//! points below are serial conveniences that open views internally.
//!
//! Face pack/unpack staging goes through the worker-local scratch arena
//! (`coordinator::scratch`): after the first step of a run, an exchange
//! round performs zero heap allocations for its staging buffers.

use std::sync::atomic::{AtomicU64, Ordering};

use super::scratch;
use crate::grid::decomp::CartDecomp;
use crate::grid::halo::{Axis, HaloCodec, HaloGrid, HaloView, Side};
use crate::grid::Grid3;
use crate::simulator::mpi::MpiModel;
use crate::simulator::sdma::{CopyDesc, Sdma};

/// Transport backend for the halo exchange.
#[derive(Clone, Copy, Debug)]
pub enum Backend {
    Sdma(Sdma),
    Mpi(MpiModel),
}

impl Backend {
    pub fn sdma() -> Self {
        Backend::Sdma(Sdma::default())
    }

    pub fn mpi() -> Self {
        Backend::Mpi(MpiModel::default())
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Sdma(_) => "SDMA",
            Backend::Mpi(_) => "MPI",
        }
    }
}

/// Process-wide count of transport rounds: one per [`exchange`] /
/// [`exchange_views`] call, regardless of how many faces the round
/// moves.  The temporal-blocking contract (`rust/tests/temporal.rs`)
/// asserts on deltas of this counter: a fused run must perform exactly
/// one round per `k` timesteps.  Summed over all threads — assert exact
/// deltas only from a context that owns every exchange in the window
/// (a dedicated test process).
static TRANSPORT_ROUNDS: AtomicU64 = AtomicU64::new(0);

/// Cumulative transport rounds since process start (see the contract
/// on the counter above).
pub fn transport_rounds() -> u64 {
    TRANSPORT_ROUNDS.load(Ordering::Relaxed)
}

/// Accounting for one exchange round.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExchangeReport {
    pub bytes: u64,
    /// simulated transport time on the paper's platform
    pub sim_time_s: f64,
    /// wall time of the real pack/move/unpack on this host
    pub real_time_s: f64,
    pub faces: usize,
}

/// Contiguous run length (bytes) of a packed face in the (z,x,y) layout:
/// Z faces are fully contiguous slabs, X faces are (h·ny)-element runs,
/// Y faces are h-element runs (the strided worst case).  `bpv` is the
/// wire bytes per value ([`HaloCodec::bytes_per_value`]): a compressed
/// face shrinks its runs along with its totals.
fn run_bytes(h: usize, nx: usize, ny: usize, axis: Axis, bpv: usize) -> u64 {
    match axis {
        Axis::Z => (h * nx * ny * bpv) as u64,
        Axis::X => (h * ny * bpv) as u64,
        Axis::Y => (h * bpv) as u64,
    }
}

/// `run_bytes` for an owned halo grid at full (f32) precision.
pub fn face_run_bytes(g: &HaloGrid, axis: Axis) -> u64 {
    run_bytes(g.h, g.nx, g.ny, axis, 4)
}

/// Exchange all interior faces of `grids` (one per rank) for one field.
/// Returns the per-round accounting.
pub fn exchange(decomp: &CartDecomp, grids: &mut [HaloGrid], backend: &Backend) -> ExchangeReport {
    let views: Vec<HaloView<'_>> = grids.iter_mut().map(|g| g.par_view()).collect();
    exchange_views(decomp, &views, backend)
}

/// View-based interior-face exchange at full precision — the form the
/// overlapped step submits as a pool task while compute proceeds on the
/// same views.  Exactly [`exchange_views_codec`] with
/// [`HaloCodec::F32`]: same code path, no quantization, bitwise the
/// pre-codec exchange.
pub fn exchange_views(
    decomp: &CartDecomp,
    grids: &[HaloView<'_>],
    backend: &Backend,
) -> ExchangeReport {
    exchange_views_codec(decomp, grids, backend, HaloCodec::F32)
}

/// [`exchange_views`] under a face-transport codec: each packed face is
/// quantized to what `codec`'s wire format would deliver
/// (`HaloView::pack_face_into_codec`) before the neighbour unpacks it,
/// and the byte/run accounting charges the codec's wire width — so a
/// 16-bit codec moves exactly half the f32 bytes on the same geometry.
/// [`HaloCodec::F32`] quantizes nothing and charges 4 bytes/value:
/// bitwise and byte-identical to the classic exchange.
pub fn exchange_views_codec(
    decomp: &CartDecomp,
    grids: &[HaloView<'_>],
    backend: &Backend,
    codec: HaloCodec,
) -> ExchangeReport {
    assert_eq!(grids.len(), decomp.ranks());
    TRANSPORT_ROUNDS.fetch_add(1, Ordering::Relaxed);
    let timer = crate::util::Timer::start();
    let mut report = ExchangeReport::default();
    let mut copies: Vec<CopyDesc> = Vec::new();
    let mut mpi_time = 0.0f64;

    // axis-ordered exchange (Z then X then Y): later axes pack the halos
    // the earlier axes filled, so edge/corner halos propagate through the
    // shared neighbours (needed by box stencils and the RTM kernels)
    let mut ordered: Vec<(usize, Axis, usize)> = Vec::new();
    for want in [Axis::Z, Axis::X, Axis::Y] {
        for (rank, axis, nb) in decomp.exchange_pairs() {
            if axis == want {
                ordered.push((rank, axis, nb));
            }
        }
    }
    for (rank, axis, nb) in ordered {
        // low rank's High face ↔ high rank's Low face, both directions —
        // staged through one worker-local scratch-arena buffer, so a
        // steady-state exchange allocates nothing per face.  One buffer
        // serialized over the two directions is safe: a pack reads only
        // the interior-boundary slab, which is disjoint from the halo
        // frame the preceding unpack wrote on the same axis.
        let nb_len = grids[rank].face_len(axis);
        let rank_len = grids[nb].face_len(axis);
        scratch::with(nb_len.max(rank_len), |buf| {
            grids[rank].pack_face_into_codec(axis, Side::High, &mut buf[..nb_len], codec);
            grids[nb].unpack_halo(axis, Side::Low, &buf[..nb_len]);
            grids[nb].pack_face_into_codec(axis, Side::Low, &mut buf[..rank_len], codec);
            grids[rank].unpack_halo(axis, Side::High, &buf[..rank_len]);
        });
        let bpv = codec.bytes_per_value();
        let bytes = (nb_len + rank_len) as u64 * bpv as u64;
        let run = run_bytes(grids[rank].h, grids[rank].nx, grids[rank].ny, axis, bpv);
        report.bytes += bytes;
        report.faces += 2;
        match backend {
            Backend::Sdma(_) => {
                copies.push(CopyDesc { bytes: bytes / 2, run_bytes: run });
                copies.push(CopyDesc { bytes: bytes / 2, run_bytes: run });
            }
            Backend::Mpi(m) => {
                // global lock: transfers serialize across all pairs
                mpi_time += m.transfer_time_s(bytes / 2, run) * 2.0;
            }
        }
    }
    report.sim_time_s = match backend {
        Backend::Sdma(s) => s.batch_time_s(&copies),
        Backend::Mpi(_) => mpi_time,
    };
    report.real_time_s = timer.secs();
    report
}

/// Build rank subdomain grids from a global periodic grid, interiors
/// filled, halos zero (to be exchanged / wrap-filled).
pub fn scatter(global: &Grid3, decomp: &CartDecomp, h: usize) -> Vec<HaloGrid> {
    (0..decomp.ranks())
        .map(|r| {
            let b = decomp.block(r, global.nz, global.nx, global.ny);
            let (nz, nx, ny) = b.dims();
            let mut hg = HaloGrid::zeros(nz, nx, ny, h);
            let interior =
                global.extract_wrap(b.z0 as isize, b.x0 as isize, b.y0 as isize, nz, nx, ny);
            hg.fill_interior(&interior);
            hg
        })
        .collect()
}

/// Fill *all* halos (including global-boundary wrap) directly from the
/// global grid — the oracle the exchange is checked against, and the
/// filler for the periodic outer boundary after an interior exchange.
pub fn fill_halos_from_global(
    global: &Grid3,
    decomp: &CartDecomp,
    grids: &mut [HaloGrid],
    only_boundary: bool,
) {
    let views: Vec<HaloView<'_>> = grids.iter_mut().map(|g| g.par_view()).collect();
    fill_halos_from_global_views(global, decomp, &views, only_boundary);
}

/// View-based variant of [`fill_halos_from_global`]: each halo-frame
/// box is claimed as an exclusive view before writing, so the wrap fill
/// can run inside the overlapped comm task.
pub fn fill_halos_from_global_views(
    global: &Grid3,
    decomp: &CartDecomp,
    grids: &[HaloView<'_>],
    only_boundary: bool,
) {
    for r in 0..decomp.ranks() {
        let b = decomp.block(r, global.nz, global.nx, global.ny);
        let g = &grids[r];
        let h = g.h as isize;
        for frame in g.frame_boxes() {
            let mut view = g.claim_box(frame);
            for z in frame[0]..frame[1] {
                for x in frame[2]..frame[3] {
                    for y in frame[4]..frame[5] {
                        let gz = b.z0 as isize + z as isize - h;
                        let gx = b.x0 as isize + x as isize - h;
                        let gy = b.y0 as isize + y as isize - h;
                        if only_boundary {
                            // skip halos the interior exchange provides
                            let inside = gz >= 0
                                && gz < global.nz as isize
                                && gx >= 0
                                && gx < global.nx as isize
                                && gy >= 0
                                && gy < global.ny as isize;
                            if inside {
                                continue;
                            }
                        }
                        view.set(z, x, y, global.get_wrap(gz, gx, gy));
                    }
                }
            }
        }
    }
}

/// Gather rank interiors back into a global grid.
pub fn gather(decomp: &CartDecomp, grids: &[HaloGrid], nz: usize, nx: usize, ny: usize) -> Grid3 {
    let mut out = Grid3::zeros(nz, nx, ny);
    for (r, g) in grids.iter().enumerate() {
        let b = decomp.block(r, nz, nx, ny);
        out.insert_block(b.z0, b.x0, b.y0, g.nz, g.nx, g.ny, &g.interior());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::Grid3;

    #[test]
    fn scatter_gather_roundtrip() {
        let g = Grid3::random(12, 16, 20, 1);
        let d = CartDecomp::new(2, 2, 2);
        let grids = scatter(&g, &d, 2);
        let back = gather(&d, &grids, 12, 16, 20);
        assert_eq!(back, g);
    }

    #[test]
    fn exchange_matches_global_fill() {
        // interior-face exchange must produce exactly the halos the
        // global-wrap oracle fills for interior neighbours
        let g = Grid3::random(8, 8, 8, 2);
        let d = CartDecomp::new(2, 1, 2);
        let mut via_exchange = scatter(&g, &d, 2);
        let mut via_oracle = scatter(&g, &d, 2);
        exchange(&d, &mut via_exchange, &Backend::sdma());
        fill_halos_from_global(&g, &d, &mut via_exchange, true); // boundary wrap
        fill_halos_from_global(&g, &d, &mut via_oracle, false); // everything
        for r in 0..d.ranks() {
            assert_eq!(
                via_exchange[r].grid.data, via_oracle[r].grid.data,
                "rank {r} halos differ"
            );
        }
    }

    #[test]
    fn exchange_matches_global_fill_on_uneven_decomps() {
        // property test over the asymmetric cases the symmetric test
        // above never reaches: prime-sized grids (uneven CartDecomp
        // splits), lopsided rank layouts (1×1×N, 2×3×1), and halo depths
        // beyond one radius (the temporal-blocking frames, h = k·r)
        use crate::util::prop::forall;
        const PRIMES: [usize; 5] = [5, 7, 11, 13, 17];
        const LAYOUTS: [(usize, usize, usize); 5] =
            [(1, 1, 2), (1, 1, 3), (1, 1, 4), (2, 3, 1), (3, 1, 2)];
        forall(12, 0xDEC0, |rng| {
            let nz = PRIMES[rng.range(0, PRIMES.len() - 1)];
            let nx = PRIMES[rng.range(0, PRIMES.len() - 1)];
            let ny = PRIMES[rng.range(0, PRIMES.len() - 1)];
            let (pz, px, py) = LAYOUTS[rng.range(0, LAYOUTS.len() - 1)];
            let d = CartDecomp::new(pz, px, py);
            // deepest halo a single nearest-neighbour exchange supports:
            // min owned layers on any decomposed axis (see
            // coordinator::temporal::max_depth)
            let mut max_h = 4;
            for (p, n) in [(pz, nz), (px, nx), (py, ny)] {
                if p > 1 {
                    max_h = max_h.min(n / p);
                }
            }
            let h = rng.range(1, max_h); // range() is lo..=hi inclusive
            let g = Grid3::random(nz, nx, ny, rng.next_u64());
            let mut via_exchange = scatter(&g, &d, h);
            let mut via_oracle = scatter(&g, &d, h);
            exchange(&d, &mut via_exchange, &Backend::sdma());
            fill_halos_from_global(&g, &d, &mut via_exchange, true);
            fill_halos_from_global(&g, &d, &mut via_oracle, false);
            for r in 0..d.ranks() {
                assert_eq!(
                    via_exchange[r].grid.data, via_oracle[r].grid.data,
                    "({nz},{nx},{ny}) ranks ({pz},{px},{py}) h={h}: rank {r} halos differ"
                );
            }
        });
    }

    #[test]
    fn sdma_sim_time_is_much_smaller_than_mpi() {
        let g = Grid3::random(64, 64, 64, 3);
        let d = CartDecomp::new(2, 2, 2);
        let mut a = scatter(&g, &d, 4);
        let mut b = scatter(&g, &d, 4);
        let sdma = exchange(&d, &mut a, &Backend::sdma());
        let mpi = exchange(&d, &mut b, &Backend::mpi());
        assert_eq!(sdma.bytes, mpi.bytes);
        assert!(
            mpi.sim_time_s / sdma.sim_time_s > 4.0,
            "mpi {:.2e} sdma {:.2e}",
            mpi.sim_time_s,
            sdma.sim_time_s
        );
    }

    #[test]
    fn run_lengths_by_axis() {
        let g = HaloGrid::zeros(16, 32, 64, 4);
        assert_eq!(face_run_bytes(&g, Axis::Z), 4 * 32 * 64 * 4);
        assert_eq!(face_run_bytes(&g, Axis::X), 4 * 64 * 4);
        assert_eq!(face_run_bytes(&g, Axis::Y), 16);
    }

    #[test]
    fn codec_exchange_halves_bytes_and_quantizes_only_halos() {
        let g = Grid3::random(8, 10, 12, 9);
        let d = CartDecomp::new(1, 2, 2);
        let mut full = scatter(&g, &d, 2);
        let full_rep = exchange(&d, &mut full, &Backend::sdma());
        for codec in [HaloCodec::Bf16, HaloCodec::F16] {
            let mut low = scatter(&g, &d, 2);
            let views: Vec<HaloView<'_>> = low.iter_mut().map(|hg| hg.par_view()).collect();
            let rep = exchange_views_codec(&d, &views, &Backend::sdma(), codec);
            drop(views);
            // exactly half the f32 bytes on the same geometry, same faces
            assert_eq!(rep.bytes * 2, full_rep.bytes, "{codec:?}");
            assert_eq!(rep.faces, full_rep.faces, "{codec:?}");
            for r in 0..d.ranks() {
                // interiors are untouched; received halo-frame cells are
                // exactly the quantized image of the f32-exchanged halos
                // (quantization is idempotent, so multi-hop corner
                // propagation lands on the same bits)
                assert_eq!(low[r].interior(), full[r].interior(), "{codec:?} rank {r}");
                let mut want = full[r].grid.data.clone();
                codec.quantize(&mut want);
                let (hw, nz, nx, ny) = (low[r].h, low[r].nz, low[r].nx, low[r].ny);
                let (sx, sy) = (nx + 2 * hw, ny + 2 * hw);
                for z in 0..nz + 2 * hw {
                    for x in 0..sx {
                        for y in 0..sy {
                            let interior = (hw..hw + nz).contains(&z)
                                && (hw..hw + nx).contains(&x)
                                && (hw..hw + ny).contains(&y);
                            if interior {
                                continue;
                            }
                            let i = (z * sx + x) * sy + y;
                            assert_eq!(
                                low[r].grid.data[i].to_bits(),
                                want[i].to_bits(),
                                "{codec:?} rank {r} frame cell ({z},{x},{y})"
                            );
                        }
                    }
                }
            }
        }
        // the F32 codec is bitwise the classic exchange
        let mut again = scatter(&g, &d, 2);
        let views: Vec<HaloView<'_>> = again.iter_mut().map(|hg| hg.par_view()).collect();
        let rep = exchange_views_codec(&d, &views, &Backend::sdma(), HaloCodec::F32);
        drop(views);
        assert_eq!(rep.bytes, full_rep.bytes);
        for r in 0..d.ranks() {
            for (got, want) in again[r].grid.data.iter().zip(&full[r].grid.data) {
                assert_eq!(got.to_bits(), want.to_bits(), "rank {r}");
            }
        }
    }

    #[test]
    fn exchange_report_counts_faces() {
        let g = Grid3::random(8, 8, 8, 4);
        let d = CartDecomp::new(2, 2, 2);
        let mut grids = scatter(&g, &d, 1);
        let rep = exchange(&d, &mut grids, &Backend::sdma());
        assert_eq!(rep.faces, 24); // 12 pairs × 2 directions
        assert!(rep.bytes > 0);
    }
}
