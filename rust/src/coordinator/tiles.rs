//! Per-core tile partitioning (paper §IV-E).
//!
//! Two strategies:
//!
//! * `Square` — the conventional cache-constrained tiling: each core gets
//!   a roughly square XY tile; halo traffic on both X and Y comes from
//!   main memory.
//! * `SnoopAware` — MMStencil's scheme: tiles are narrow along Y and
//!   assigned to *adjacent* cores in Y order, so concurrent neighbours
//!   hold each other's Y-halos in their private caches and the Y term
//!   drops from the reuse analysis.

use crate::grid::par::{ParGrid3, TileViewMut};
use crate::simulator::directory::{reuse_ratios, TileSchedule};

/// Tiling strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    Square,
    SnoopAware,
}

/// One core's tile: XY rectangle, swept over all z.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Tile {
    pub core: usize,
    pub x0: usize,
    pub x1: usize,
    pub y0: usize,
    pub y1: usize,
}

impl Tile {
    pub fn cells_per_layer(&self) -> usize {
        (self.x1 - self.x0) * (self.y1 - self.y0)
    }

    /// Claim this tile's exclusive output view (all z layers) — the
    /// typed handoff the sweep gives each runtime task.  Debug builds
    /// panic if the tile overlaps a live claim (a broken plan); release
    /// builds rely on [`TilePlan::validate`]'s static proof.
    pub fn claim<'p>(&self, pg: &'p ParGrid3<'_>) -> TileViewMut<'p> {
        pg.view(0, pg.nz(), self.x0, self.x1, self.y0, self.y1)
    }
}

/// A complete tile plan for one NUMA node's sweep.
#[derive(Clone, Debug)]
pub struct TilePlan {
    pub strategy: Strategy,
    pub tiles: Vec<Tile>,
    pub nx: usize,
    pub ny: usize,
}

/// Split `n` into `p` near-equal contiguous chunks.
fn chunks(n: usize, p: usize) -> Vec<(usize, usize)> {
    let base = n / p;
    let rem = n % p;
    let mut out = Vec::with_capacity(p);
    let mut lo = 0;
    for i in 0..p {
        let len = base + usize::from(i < rem);
        out.push((lo, lo + len));
        lo += len;
    }
    out
}

/// Build a tile plan for `cores` cores over an `(nx, ny)` XY plane.
pub fn plan(strategy: Strategy, cores: usize, nx: usize, ny: usize) -> TilePlan {
    assert!(cores >= 1);
    let tiles = match strategy {
        Strategy::Square => {
            // factor the core count into a grid as square as possible
            let mut px = (cores as f64).sqrt().floor() as usize;
            while cores % px != 0 {
                px -= 1;
            }
            let py = cores / px;
            let xs = chunks(nx, px);
            let ys = chunks(ny, py);
            let mut tiles = Vec::with_capacity(cores);
            for (i, &(x0, x1)) in xs.iter().enumerate() {
                for (j, &(y0, y1)) in ys.iter().enumerate() {
                    tiles.push(Tile { core: i * py + j, x0, x1, y0, y1 });
                }
            }
            tiles
        }
        Strategy::SnoopAware => {
            // narrow along Y, adjacent assignment: core k owns the k-th
            // Y strip, so cores k-1 / k+1 hold its Y halos
            chunks(ny, cores)
                .into_iter()
                .enumerate()
                .map(|(k, (y0, y1))| Tile { core: k, x0: 0, x1: nx, y0, y1 })
                .collect()
        }
    };
    TilePlan { strategy, tiles, nx, ny }
}

impl TilePlan {
    /// Verify full, non-overlapping coverage (panics otherwise) — used by
    /// the property tests.
    pub fn validate(&self) {
        let mut covered = vec![false; self.nx * self.ny];
        for t in &self.tiles {
            for x in t.x0..t.x1 {
                for y in t.y0..t.y1 {
                    let i = x * self.ny + y;
                    assert!(!covered[i], "overlap at ({x},{y})");
                    covered[i] = true;
                }
            }
        }
        assert!(covered.iter().all(|&c| c), "incomplete coverage");
    }

    /// Main-memory traffic (bytes) for one full-grid sweep of `nz`
    /// layers with halo widths `(bx, by)` and z-depth `vz` per slab:
    /// each core re-reads its tile + halos per slab; with the snoop-aware
    /// plan the Y-halo comes from peer caches instead of memory.
    pub fn memory_traffic(&self, nz: usize, bx: usize, by: usize) -> u64 {
        let mut bytes = 0u64;
        for t in &self.tiles {
            let tx = t.x1 - t.x0;
            let ty = t.y1 - t.y0;
            let sched = TileSchedule {
                tile_x: tx,
                tile_y: ty,
                halo_x: bx,
                halo_y: by,
                adjacent: self.strategy == Strategy::SnoopAware,
            };
            let s = crate::simulator::directory::analyze(&sched, nz, 4);
            bytes += s.owned_bytes + s.memory_bytes;
        }
        bytes
    }

    /// Mean reuse ratio over tiles (paper §IV-E formulas).
    pub fn mean_reuse(&self, bx: usize, by: usize) -> f64 {
        let sum: f64 = self
            .tiles
            .iter()
            .map(|t| {
                let (plain, snoop) = reuse_ratios(t.x1 - t.x0, t.y1 - t.y0, bx, by);
                match self.strategy {
                    Strategy::Square => plain,
                    Strategy::SnoopAware => snoop,
                }
            })
            .sum();
        sum / self.tiles.len() as f64
    }

    /// Y-neighbour pairs that can snoop-share (adjacent cores only).
    pub fn snoop_pairs(&self) -> Vec<(usize, usize)> {
        if self.strategy != Strategy::SnoopAware {
            return Vec::new();
        }
        (1..self.tiles.len()).map(|k| (k - 1, k)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn both_strategies_tile_exactly() {
        forall(40, 0x7117, |rng| {
            let cores = rng.range(1, 16);
            let nx = rng.range(cores, 128);
            let ny = rng.range(cores, 128);
            plan(Strategy::Square, cores, nx, ny).validate();
            plan(Strategy::SnoopAware, cores, nx, ny).validate();
        });
    }

    #[test]
    fn snoop_plan_is_adjacent_strips() {
        let p = plan(Strategy::SnoopAware, 4, 64, 64);
        for (a, b) in p.snoop_pairs() {
            assert_eq!(p.tiles[a].y1, p.tiles[b].y0, "strips must abut");
        }
        assert!(p.tiles.iter().all(|t| t.x0 == 0 && t.x1 == 64));
    }

    #[test]
    fn snoop_reduces_memory_traffic() {
        // paper §V-B: 22–26% reduction on the benchmark kernels
        let cores = 32;
        let (nx, ny, nz) = (512, 512, 512);
        let sq = plan(Strategy::Square, cores, nx, ny).memory_traffic(nz, 16, 4);
        let sn = plan(Strategy::SnoopAware, cores, nx, ny).memory_traffic(nz, 16, 4);
        let red = 1.0 - sn as f64 / sq as f64;
        assert!(red > 0.05, "reduction {red:.3}");
    }

    #[test]
    fn snoop_reuse_exceeds_square_reuse() {
        let cores = 32;
        let sq = plan(Strategy::Square, cores, 512, 512).mean_reuse(16, 4);
        let sn = plan(Strategy::SnoopAware, cores, 512, 512).mean_reuse(16, 4);
        assert!(sn > sq, "snoop {sn:.3} vs square {sq:.3}");
    }

    #[test]
    fn plan_tiles_claim_disjoint_views() {
        // every tile of a valid plan can hold its exclusive view at the
        // same time — the typed form of TilePlan::validate
        let mut out = crate::grid::Grid3::zeros(3, 16, 16);
        let pg = ParGrid3::new(&mut out);
        let p = plan(Strategy::SnoopAware, 4, 16, 16);
        let mut views: Vec<_> = p.tiles.iter().map(|t| t.claim(&pg)).collect();
        for (t, v) in p.tiles.iter().zip(views.iter_mut()) {
            v.set(0, t.x0, t.y0, 1.0);
        }
    }

    #[test]
    fn single_core_gets_everything() {
        let p = plan(Strategy::SnoopAware, 1, 40, 40);
        assert_eq!(p.tiles.len(), 1);
        assert_eq!(p.tiles[0].cells_per_layer(), 1600);
        assert!(p.snoop_pairs().is_empty());
    }
}
