//! Persistent NUMA-aware worker runtime (paper §IV-E / §V-E scheduling
//! substrate).
//!
//! The seed coordinator tore down and respawned scoped threads on every
//! `parallel_for` call, which (a) made the paper's scheduling story
//! unmeasurable — spawn cost dominated small dispatches — and (b) broke
//! the snoop-aware adjacency contract: a fresh thread set has no stable
//! worker↔core identity for adjacent tiles to land on.  This module
//! replaces it:
//!
//! * workers are spawned **once** per [`Runtime`] lifetime (the process
//!   global [`global()`] pool backs the `pool::parallel_*` free
//!   functions; a [`super::driver::Driver`] owns a dedicated one);
//! * each worker is pinned to a simulated NUMA/core-cluster slot
//!   ([`CoreSlot`]) derived from the platform topology in the config —
//!   worker *k* keeps the same slot for its whole life, so contiguous
//!   chunk assignment reproduces the paper's adjacent-core placement;
//! * dispatch goes through **per-worker injector queues**: a job is cut
//!   into contiguous chunks, chunk *j* lands on worker `j·W/m`, and idle
//!   workers **steal** from ring-adjacent victims for ragged tails —
//!   replacing the seed's single shared `AtomicUsize` claim counter;
//! * per-worker utilization, steal counts, and the one-time spawn
//!   overhead are recorded ([`RuntimeStats`]) so the Fig. 12/13 benches
//!   can attribute scaling losses to scheduling vs. memory.
//!
//! Submitters *help*: while waiting for a job, the submitting thread
//! executes queued chunks itself.  That keeps nested submissions (a task
//! that itself calls `parallel_for`) deadlock-free and lets a 1-worker
//! pool still overlap a comm task with caller-side compute.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Instant;

/// Simulated NUMA/core placement of one worker.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CoreSlot {
    pub numa: usize,
    pub core: usize,
}

/// Runtime construction parameters (see `config::RuntimeSpec` for the
/// TOML-file form).
#[derive(Clone, Debug)]
pub struct RuntimeConfig {
    /// Worker count; 0 = one per host hardware thread.
    pub workers: usize,
    /// Simulated cores per NUMA cluster used for slot assignment.
    pub cores_per_numa: usize,
    /// Simulated NUMA cluster count (slots wrap past the last cluster).
    pub numa_nodes: usize,
}

impl Default for RuntimeConfig {
    fn default() -> Self {
        let p = crate::simulator::Platform::paper();
        Self { workers: 0, cores_per_numa: p.cores_per_numa, numa_nodes: p.total_numa() }
    }
}

impl RuntimeConfig {
    pub fn with_workers(workers: usize) -> Self {
        Self { workers, ..Self::default() }
    }

    fn resolved_workers(&self) -> usize {
        if self.workers > 0 {
            return self.workers;
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    }

    /// Slot of worker `i`: fill a cluster with adjacent cores before
    /// moving to the next (paper §IV-E adjacency).
    pub fn slot(&self, i: usize) -> CoreSlot {
        let cpn = self.cores_per_numa.max(1);
        CoreSlot { numa: (i / cpn) % self.numa_nodes.max(1), core: i % cpn }
    }
}

/// Lifetime-erased task pointer.  SAFETY: [`Runtime::run`] blocks until
/// every chunk of the job has finished before the borrow it erases ends,
/// and nothing dereferences the pointer after the job completes.
struct RawTask(*const (dyn Fn(usize) + Sync));
unsafe impl Send for RawTask {}
unsafe impl Sync for RawTask {}

/// A task panic surfaced to the dispatching caller: carries the first
/// panic payload's message, so "which assertion fired" survives the
/// worker boundary instead of collapsing into a bare flag.
#[derive(Clone, Debug)]
pub struct WorkerPanic {
    /// The first panicking task's payload, rendered to a string
    /// (`"<non-string panic payload>"` when the payload is neither
    /// `&str` nor `String`).
    pub msg: String,
}

impl std::fmt::Display for WorkerPanic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // the "worker panicked" prefix is load-bearing: callers that
        // repanic with this Display keep the historical panic text
        write!(f, "worker panicked: {}", self.msg)
    }
}

impl std::error::Error for WorkerPanic {}

/// Render a caught panic payload to a message string.
pub(crate) fn panic_payload_msg(payload: &(dyn std::any::Any + Send)) -> String {
    payload
        .downcast_ref::<&'static str>()
        .map(|s| s.to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "<non-string panic payload>".to_string())
}

struct JobInner {
    task: RawTask,
    /// Items not yet finished; guarded so completion can signal `cv`.
    remaining: Mutex<usize>,
    cv: Condvar,
    panicked: AtomicBool,
    /// First panic payload's message (first writer wins); the flag
    /// above stays the fast-path check.
    panic_msg: Mutex<Option<String>>,
}

impl JobInner {
    fn new(task: RawTask, remaining: usize) -> Self {
        Self {
            task,
            remaining: Mutex::new(remaining),
            cv: Condvar::new(),
            panicked: AtomicBool::new(false),
            panic_msg: Mutex::new(None),
        }
    }

    /// Run items `[lo, hi)`, absorbing panics into the `panicked` flag
    /// (plus the first payload's message) so the submitter — not the
    /// worker — reports them.
    fn execute(&self, lo: usize, hi: usize) {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let task = unsafe { &*self.task.0 };
            for i in lo..hi {
                task(i);
            }
        }));
        if let Err(payload) = result {
            let mut slot = self.panic_msg.lock().unwrap();
            if slot.is_none() {
                *slot = Some(panic_payload_msg(payload.as_ref()));
            }
            self.panicked.store(true, Ordering::Relaxed);
        }
        let mut rem = self.remaining.lock().unwrap();
        *rem -= hi - lo;
        if *rem == 0 {
            self.cv.notify_all();
        }
    }

    /// The job's panic outcome, for a caller that has already joined.
    fn panic_result(&self) -> Result<(), WorkerPanic> {
        if !self.panicked.load(Ordering::Relaxed) {
            return Ok(());
        }
        let msg = self
            .panic_msg
            .lock()
            .unwrap()
            .clone()
            .unwrap_or_else(|| "<panic message unavailable>".to_string());
        Err(WorkerPanic { msg })
    }

    fn is_done(&self) -> bool {
        *self.remaining.lock().unwrap() == 0
    }
}

struct Chunk {
    job: Arc<JobInner>,
    lo: usize,
    hi: usize,
}

#[derive(Default)]
struct WorkerCounters {
    tasks: AtomicU64,
    steals: AtomicU64,
    busy_ns: AtomicU64,
}

struct Shared {
    injectors: Vec<Mutex<VecDeque<Chunk>>>,
    /// (epoch, cv): bumped on every submit so sleeping workers rescan.
    signal: (Mutex<u64>, Condvar),
    shutdown: AtomicBool,
    counters: Vec<WorkerCounters>,
    helper: WorkerCounters,
    jobs: AtomicU64,
    items: AtomicU64,
}

impl Shared {
    fn pop_for(&self, worker: usize) -> Option<(Chunk, bool)> {
        // own queue first, then ring-adjacent victims (±1, ±2, …) so a
        // steal lands as close as possible to the tile's intended core
        if let Some(c) = self.injectors[worker].lock().unwrap().pop_front() {
            return Some((c, false));
        }
        let w = self.injectors.len();
        for d in 1..w {
            let victim = if d % 2 == 1 {
                (worker + d.div_ceil(2)) % w
            } else {
                (worker + w - d / 2) % w
            };
            if let Some(c) = self.injectors[victim].lock().unwrap().pop_back() {
                return Some((c, true));
            }
        }
        None
    }

    fn pop_any(&self) -> Option<Chunk> {
        for q in &self.injectors {
            if let Some(c) = q.lock().unwrap().pop_front() {
                return Some(c);
            }
        }
        None
    }

    fn wake_all(&self) {
        let mut epoch = self.signal.0.lock().unwrap();
        *epoch += 1;
        drop(epoch);
        self.signal.1.notify_all();
    }
}

fn worker_loop(shared: Arc<Shared>, idx: usize) {
    // workers inherit a fresh MXCSR; keep the FTZ/DAZ policy of the
    // numeric kernels (see util::enable_flush_to_zero)
    crate::util::enable_flush_to_zero();
    let mut seen_epoch = *shared.signal.0.lock().unwrap();
    loop {
        if shared.shutdown.load(Ordering::Acquire) {
            return;
        }
        if let Some((chunk, stolen)) = shared.pop_for(idx) {
            let t = Instant::now();
            let n = (chunk.hi - chunk.lo) as u64;
            chunk.job.execute(chunk.lo, chunk.hi);
            let c = &shared.counters[idx];
            c.tasks.fetch_add(n, Ordering::Relaxed);
            c.busy_ns.fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
            if stolen {
                c.steals.fetch_add(1, Ordering::Relaxed);
            }
            continue;
        }
        let guard = shared.signal.0.lock().unwrap();
        if *guard == seen_epoch && !shared.shutdown.load(Ordering::Acquire) {
            let guard = shared.signal.1.wait(guard).unwrap();
            seen_epoch = *guard;
        } else {
            seen_epoch = *guard;
        }
    }
}

/// Per-worker statistics snapshot.
#[derive(Clone, Copy, Debug)]
pub struct WorkerStats {
    pub slot: CoreSlot,
    /// Items executed on this worker.
    pub tasks: u64,
    /// Chunks this worker stole from a neighbour's injector queue.
    pub steals: u64,
    /// Seconds spent executing task bodies.
    pub busy_s: f64,
}

/// Whole-runtime statistics snapshot (cumulative since construction or
/// the last [`Runtime::reset_stats`]).
#[derive(Clone, Debug)]
pub struct RuntimeStats {
    pub workers: Vec<WorkerStats>,
    /// Items executed inline by submitting threads while helping.
    pub helper_tasks: u64,
    pub helper_busy_s: f64,
    /// Jobs dispatched through the queues.
    pub jobs: u64,
    /// Total items across those jobs.
    pub items: u64,
    /// Threads ever spawned by this runtime (constant after startup —
    /// the regression contract `spawn_count == workers` holds for the
    /// whole lifetime).
    pub spawn_count: u64,
    /// One-time cost of spawning the worker set.
    pub spawn_overhead_s: f64,
}

impl RuntimeStats {
    pub fn total_steals(&self) -> u64 {
        self.workers.iter().map(|w| w.steals).sum()
    }

    pub fn total_tasks(&self) -> u64 {
        self.workers.iter().map(|w| w.tasks).sum::<u64>() + self.helper_tasks
    }

    /// Mean fraction of `wall_s` the workers spent executing tasks.
    pub fn mean_utilization(&self, wall_s: f64) -> f64 {
        if self.workers.is_empty() || wall_s <= 0.0 {
            return 0.0;
        }
        let busy: f64 = self.workers.iter().map(|w| w.busy_s).sum();
        (busy / self.workers.len() as f64 / wall_s).min(1.0)
    }

    /// Counter-wise difference `self − earlier` (worker list unchanged).
    pub fn delta_since(&self, earlier: &RuntimeStats) -> RuntimeStats {
        let workers = self
            .workers
            .iter()
            .zip(&earlier.workers)
            .map(|(a, b)| WorkerStats {
                slot: a.slot,
                tasks: a.tasks - b.tasks,
                steals: a.steals - b.steals,
                busy_s: a.busy_s - b.busy_s,
            })
            .collect();
        RuntimeStats {
            workers,
            helper_tasks: self.helper_tasks - earlier.helper_tasks,
            helper_busy_s: self.helper_busy_s - earlier.helper_busy_s,
            jobs: self.jobs - earlier.jobs,
            items: self.items - earlier.items,
            spawn_count: self.spawn_count,
            spawn_overhead_s: self.spawn_overhead_s,
        }
    }

    /// Flatten into metric records (`metrics::RunRecord` rows) for the
    /// bench CSV exports.
    pub fn to_records(
        &self,
        experiment: &str,
        series: &str,
        wall_s: f64,
    ) -> Vec<crate::metrics::RunRecord> {
        let mut out = Vec::new();
        for (i, w) in self.workers.iter().enumerate() {
            let label = format!("w{i}@numa{}", w.slot.numa);
            out.push(crate::metrics::RunRecord::new(
                experiment, series, &label, "worker_utilization",
                if wall_s > 0.0 { (w.busy_s / wall_s).min(1.0) } else { 0.0 },
            ));
            out.push(crate::metrics::RunRecord::new(
                experiment, series, &label, "steals", w.steals as f64,
            ));
        }
        out.push(crate::metrics::RunRecord::new(
            experiment, series, "pool", "spawn_overhead_s", self.spawn_overhead_s,
        ));
        out
    }
}

/// The persistent worker pool.
pub struct Runtime {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    config: RuntimeConfig,
    spawn_overhead_s: f64,
}

impl Runtime {
    pub fn new(config: RuntimeConfig) -> Self {
        let workers = config.resolved_workers().max(1);
        let shared = Arc::new(Shared {
            injectors: (0..workers).map(|_| Mutex::new(VecDeque::new())).collect(),
            signal: (Mutex::new(0), Condvar::new()),
            shutdown: AtomicBool::new(false),
            counters: (0..workers).map(|_| WorkerCounters::default()).collect(),
            helper: WorkerCounters::default(),
            jobs: AtomicU64::new(0),
            items: AtomicU64::new(0),
        });
        let t = Instant::now();
        let handles = (0..workers)
            .map(|i| {
                let s = shared.clone();
                let slot = config.slot(i);
                std::thread::Builder::new()
                    .name(format!("mmstencil-w{i}-numa{}", slot.numa))
                    .spawn(move || worker_loop(s, i))
                    .expect("spawning worker thread")
            })
            .collect();
        let spawn_overhead_s = t.elapsed().as_secs_f64();
        Self { shared, handles, config, spawn_overhead_s }
    }

    pub fn with_workers(workers: usize) -> Self {
        Self::new(RuntimeConfig::with_workers(workers))
    }

    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Threads ever spawned — equals [`workers`](Self::workers) for the
    /// whole runtime lifetime (the regression-test contract).
    pub fn spawn_count(&self) -> usize {
        self.handles.len()
    }

    pub fn config(&self) -> &RuntimeConfig {
        &self.config
    }

    /// Run `task(i)` for every `i in 0..n` on the pool and wait,
    /// repanicking (with the first payload's message) if any task
    /// panicked.  `concurrency` is the caller's parallelism hint
    /// (tile/thread count from the sweep config); it bounds chunk
    /// granularity, not worker count.  The submitting thread helps
    /// execute queued chunks.
    pub fn run(&self, concurrency: usize, n: usize, task: &(dyn Fn(usize) + Sync)) {
        if let Err(e) = self.try_run(concurrency, n, task) {
            panic!("{e}");
        }
    }

    /// [`run`](Self::run) that reports a task panic as an `Err` (with
    /// the first panic payload's message) instead of repanicking — the
    /// dispatching caller can distinguish "task panicked" from success
    /// and contain it.  `n <= 1` runs inline, so a panic there unwinds
    /// through the caller directly (nothing to contain: no worker was
    /// involved).
    pub fn try_run(
        &self,
        concurrency: usize,
        n: usize,
        task: &(dyn Fn(usize) + Sync),
    ) -> Result<(), WorkerPanic> {
        if n == 0 {
            return Ok(());
        }
        if n == 1 {
            task(0);
            return Ok(());
        }
        // erase the borrow; try_run() joins the job before returning, so
        // the pointee outlives every dereference (see RawTask)
        let raw: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(task) };
        let job = Arc::new(JobInner::new(RawTask(raw as *const _), n));
        let w = self.workers();
        // contiguous chunks; ~2 per hinted thread for steal slack, but
        // never more chunks than items
        let target = (concurrency.max(1) * 2).clamp(1, n).max(w.min(n));
        let chunk = n.div_ceil(target);
        let m = n.div_ceil(chunk);
        {
            for j in 0..m {
                let lo = j * chunk;
                let hi = ((j + 1) * chunk).min(n);
                // contiguous block assignment keeps adjacent chunks on
                // adjacent workers (snoop-aware placement)
                let target_worker = j * w / m;
                self.shared.injectors[target_worker]
                    .lock()
                    .unwrap()
                    .push_back(Chunk { job: job.clone(), lo, hi });
            }
        }
        self.shared.jobs.fetch_add(1, Ordering::Relaxed);
        self.shared.items.fetch_add(n as u64, Ordering::Relaxed);
        self.shared.wake_all();
        self.join_job(&job);
        job.panic_result()
    }

    /// Submit a job without waiting.  The returned handle joins the job
    /// on [`wait`](JobHandle::wait) *or* on drop (including unwind), so
    /// the erased borrow cannot be outlived by a running worker.
    ///
    /// # Safety
    /// The caller must not `mem::forget` the handle: leaking it skips
    /// the join and leaves workers dereferencing the erased borrow
    /// after it dies.
    pub unsafe fn submit_scoped(&self, n: usize, task: &(dyn Fn(usize) + Sync)) -> JobHandle<'_> {
        let raw: &'static (dyn Fn(usize) + Sync) = std::mem::transmute(task);
        let job = Arc::new(JobInner::new(RawTask(raw as *const _), n.max(1)));
        if n == 0 {
            *job.remaining.lock().unwrap() = 0;
            return JobHandle { job, rt: self };
        }
        let w = self.workers().max(1);
        for i in 0..n {
            self.shared.injectors[i * w / n]
                .lock()
                .unwrap()
                .push_back(Chunk { job: job.clone(), lo: i, hi: i + 1 });
        }
        self.shared.jobs.fetch_add(1, Ordering::Relaxed);
        self.shared.items.fetch_add(n as u64, Ordering::Relaxed);
        self.shared.wake_all();
        JobHandle { job, rt: self }
    }

    /// Block (helping with queued work) until every item of `job` has
    /// finished.  Does NOT propagate task panics — callers surface them
    /// through `JobInner::panic_result` afterwards; `JobHandle`'s drop
    /// uses this directly so joining during unwind cannot abort.
    fn join_job(&self, job: &Arc<JobInner>) {
        // the helping thread executes task bodies too: hold the same
        // FTZ/DAZ policy the pool workers set at startup — but restore
        // the submitter's own FP environment on exit, since this may be
        // an embedder's thread that relies on subnormal semantics
        let _ftz = crate::util::FtzGuard::new();
        loop {
            if job.is_done() {
                break;
            }
            // help: drain queued chunks (any job) instead of blocking
            if let Some(chunk) = self.shared.pop_any() {
                let t = Instant::now();
                let n = (chunk.hi - chunk.lo) as u64;
                chunk.job.execute(chunk.lo, chunk.hi);
                self.shared.helper.tasks.fetch_add(n, Ordering::Relaxed);
                self.shared
                    .helper
                    .busy_ns
                    .fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
                continue;
            }
            let rem = job.remaining.lock().unwrap();
            if *rem > 0 {
                // chunks are all claimed by workers; sleep until the
                // last one signals
                drop(job.cv.wait(rem).unwrap());
            }
        }
    }

    /// Cumulative statistics snapshot.
    pub fn stats(&self) -> RuntimeStats {
        RuntimeStats {
            workers: self
                .shared
                .counters
                .iter()
                .enumerate()
                .map(|(i, c)| WorkerStats {
                    slot: self.config.slot(i),
                    tasks: c.tasks.load(Ordering::Relaxed),
                    steals: c.steals.load(Ordering::Relaxed),
                    busy_s: c.busy_ns.load(Ordering::Relaxed) as f64 * 1e-9,
                })
                .collect(),
            helper_tasks: self.shared.helper.tasks.load(Ordering::Relaxed),
            helper_busy_s: self.shared.helper.busy_ns.load(Ordering::Relaxed) as f64 * 1e-9,
            jobs: self.shared.jobs.load(Ordering::Relaxed),
            items: self.shared.items.load(Ordering::Relaxed),
            spawn_count: self.handles.len() as u64,
            spawn_overhead_s: self.spawn_overhead_s,
        }
    }

    /// Zero the cumulative counters (spawn figures are preserved).
    pub fn reset_stats(&self) {
        for c in self.shared.counters.iter().chain(std::iter::once(&self.shared.helper)) {
            c.tasks.store(0, Ordering::Relaxed);
            c.steals.store(0, Ordering::Relaxed);
            c.busy_ns.store(0, Ordering::Relaxed);
        }
        self.shared.jobs.store(0, Ordering::Relaxed);
        self.shared.items.store(0, Ordering::Relaxed);
    }
}

impl Drop for Runtime {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.wake_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Handle to a job submitted with [`Runtime::submit_scoped`].  Dropping
/// the handle joins the job (like a scoped thread): even if the caller
/// unwinds before calling [`wait`](Self::wait), no worker can still be
/// executing the lifetime-erased task when its borrows die.
pub struct JobHandle<'rt> {
    job: Arc<JobInner>,
    rt: &'rt Runtime,
}

impl JobHandle<'_> {
    /// Block (helping with queued work) until the job finishes,
    /// repanicking (with the first payload's message) if any task
    /// panicked.
    pub fn wait(self) {
        if let Err(e) = self.join() {
            panic!("{e}");
        }
    }

    /// [`wait`](Self::wait) that reports a task panic as an `Err`
    /// carrying the first payload's message instead of repanicking —
    /// the dispatcher-facing form of the panic contract.
    pub fn join(self) -> Result<(), WorkerPanic> {
        self.rt.join_job(&self.job);
        let result = self.job.panic_result();
        drop(self); // re-join in Drop is a no-op: the job is done
        result
    }
}

impl Drop for JobHandle<'_> {
    fn drop(&mut self) {
        // join-on-drop, even during unwind (a panic cannot propagate
        // out of a Drop) — but never *silently*: a panicked job that
        // was only ever dropped aborts the process via the repanic
        // below unless we are already unwinding, in which case the
        // original panic is the one in flight and reporting is its job.
        self.rt.join_job(&self.job);
        if !std::thread::panicking() {
            if let Err(e) = self.job.panic_result() {
                panic!("{e} (job handle dropped without wait/join)");
            }
        }
    }
}

static GLOBAL: OnceLock<Runtime> = OnceLock::new();

/// The process-wide pool backing `pool::parallel_*`.  Spawned on first
/// use, never respawned; size = host hardware threads (min 4 so comm
/// tasks overlap compute even on small hosts).
pub fn global() -> &'static Runtime {
    GLOBAL.get_or_init(|| {
        let host = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Runtime::new(RuntimeConfig::with_workers(host.max(4)))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_every_index_once() {
        let rt = Runtime::with_workers(4);
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        rt.run(8, 1000, &|i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn repeated_jobs_do_not_respawn() {
        let rt = Runtime::with_workers(3);
        let before = rt.spawn_count();
        for _ in 0..50 {
            rt.run(3, 64, &|_| {});
        }
        assert_eq!(rt.spawn_count(), before);
        assert_eq!(rt.spawn_count(), 3);
        let s = rt.stats();
        assert_eq!(s.jobs, 50);
        assert_eq!(s.items, 50 * 64);
        assert_eq!(s.total_tasks(), 50 * 64);
    }

    #[test]
    fn slots_fill_clusters_adjacently() {
        let cfg = RuntimeConfig { workers: 8, cores_per_numa: 4, numa_nodes: 2 };
        assert_eq!(cfg.slot(0), CoreSlot { numa: 0, core: 0 });
        assert_eq!(cfg.slot(3), CoreSlot { numa: 0, core: 3 });
        assert_eq!(cfg.slot(4), CoreSlot { numa: 1, core: 0 });
        assert_eq!(cfg.slot(7), CoreSlot { numa: 1, core: 3 });
    }

    #[test]
    fn nested_submission_completes() {
        let rt = Runtime::with_workers(2);
        let total = AtomicU64::new(0);
        rt.run(2, 4, &|_| {
            // a task submitting more work must not deadlock the pool
            let inner = AtomicU64::new(0);
            super::global().run(2, 8, &|_| {
                inner.fetch_add(1, Ordering::Relaxed);
            });
            total.fetch_add(inner.load(Ordering::Relaxed), Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 32);
    }

    #[test]
    #[should_panic(expected = "worker panicked")]
    fn task_panic_propagates_to_submitter() {
        let rt = Runtime::with_workers(2);
        rt.run(2, 16, &|i| {
            if i == 7 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn try_run_surfaces_the_first_panic_payload_message() {
        let rt = Runtime::with_workers(2);
        let err = rt
            .try_run(2, 16, &|i| {
                if i == 7 {
                    panic!("halo buffer poisoned at lane {i}");
                }
            })
            .unwrap_err();
        assert_eq!(err.msg, "halo buffer poisoned at lane 7");
        assert_eq!(err.to_string(), "worker panicked: halo buffer poisoned at lane 7");
        // the pool survives containment: the next job runs clean
        rt.try_run(2, 8, &|_| {}).unwrap();
    }

    #[test]
    fn scoped_join_reports_panic_as_error_without_aborting() {
        let rt = Runtime::with_workers(2);
        let task = |i: usize| {
            if i == 1 {
                panic!("boom in scoped task");
            }
        };
        let h = unsafe { rt.submit_scoped(3, &task) };
        let err = h.join().unwrap_err();
        assert!(err.msg.contains("boom in scoped task"), "{err}");
    }

    #[test]
    fn stats_reset_preserves_spawn_figures() {
        let rt = Runtime::with_workers(2);
        rt.run(2, 100, &|_| {});
        rt.reset_stats();
        let s = rt.stats();
        assert_eq!(s.total_tasks(), 0);
        assert_eq!(s.spawn_count, 2);
        assert!(s.spawn_overhead_s >= 0.0);
    }

    #[test]
    fn submit_scoped_overlaps_with_caller() {
        let rt = Runtime::with_workers(2);
        let ran = AtomicU64::new(0);
        let task = |_: usize| {
            ran.fetch_add(1, Ordering::Relaxed);
        };
        let h = unsafe { rt.submit_scoped(3, &task) };
        h.wait();
        assert_eq!(ran.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn handle_drop_joins_job() {
        let rt = Runtime::with_workers(2);
        let done = AtomicU64::new(0);
        {
            let slow = |_: usize| {
                std::thread::sleep(std::time::Duration::from_millis(5));
                done.fetch_add(1, Ordering::Relaxed);
            };
            let _h = unsafe { rt.submit_scoped(2, &slow) };
            // handle dropped without wait(): Drop must join before the
            // borrowed closure (and `done`) go out of scope
        }
        assert_eq!(done.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn utilization_and_steals_observable() {
        let rt = Runtime::with_workers(4);
        rt.reset_stats();
        let t = Instant::now();
        // ragged workload: long tail forces steals with high likelihood
        rt.run(4, 64, &|i| {
            if i % 16 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        });
        let wall = t.elapsed().as_secs_f64();
        let s = rt.stats();
        assert_eq!(s.total_tasks(), 64);
        assert!(s.mean_utilization(wall) <= 1.0);
        // steals are opportunistic — just check the counter is sane
        assert!(s.total_steals() <= 64);
    }
}
