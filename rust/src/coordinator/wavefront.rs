//! In-rank diamond/wavefront temporal tiling of the fused sub-steps
//! (PR 8 tentpole, DESIGN.md §14): inside one rank's fused `k`-step
//! window the sub-step levels `1..k` are decomposed into z-slab tiles
//! whose `(z-extent × x × y)` working set fits the simulated cache
//! hierarchy, and a per-level **dependency ledger** advances workers
//! along the (z, t) wavefront — a tile at time level `t+1` becomes
//! claimable as soon as its r-halo dependencies at level `t` complete,
//! with **no global barrier between sub-steps** inside a band.
//!
//! Geometry: level `s`'s box is `temporal::substep_box(s)` — each level
//! shrinks by `r` per side, so a fixed z-tile traced through the levels
//! is a trapezoid in (z, t) and the skewed ready-order is the classic
//! diamond wavefront (Malas & Hager, arxiv 1510.04995).  Tiles clamp at
//! the rank's sub-step range: the inter-rank halo was prepaid by the
//! deep `k·r` exchange, so no diamond ever crosses a rank boundary.
//!
//! The one dependency rule (and why it is sufficient): tile `(B, s)` is
//! ready when every level `s−1` tile whose z-range intersects
//! `[B.z0 − r, B.z1 + r)` has completed.  That covers
//!
//! * the **true dependency** — those are exactly the cells `(B, s)`
//!   reads;
//! * the **anti-dependency** — level `s` and level `s−2` write the same
//!   buffer (the ping-pong has period 2), and a level-`s` write racing a
//!   level-`s−1` read of that buffer intersects the reader's grown
//!   range, i.e. is already an edge;
//! * **write–write ordering** — a level-`s` tile is transitively
//!   ordered after every level-`s−2` tile its write-box overlaps
//!   (grow twice by `r` ⊇ identity);
//!
//! so for any tile extent, worker count, and band depth the execution
//! order is a linear extension of the data-dependency DAG, and because
//! every engine's per-point accumulation order is fixed and
//! block-independent the result is **bitwise** the level-at-a-time
//! classic path (`rust/tests/wavefront.rs`).

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::runtime::Runtime;

/// One wavefront tile: a z-slab of one rank's sub-step level.  `level`
/// is band-relative (0 = the band's first sub-step); `z0..z1` is the
/// slab in halo-storage coordinates.  The x/y extent is the level's
/// full `substep_box` — the caller derives it per tile.
#[derive(Clone, Copy, Debug)]
pub struct Tile {
    pub rank: usize,
    pub level: usize,
    pub z0: usize,
    pub z1: usize,
}

/// A band's tiles plus the dependency ledger in CSR form: per tile its
/// in-degree and the successor list to decrement on completion.  Built
/// with a constant number of allocations (counted passes +
/// `with_capacity`), so the fused hot path keeps its O(1)-allocs
/// contract (`rust/tests/alloc_free.rs`).
pub struct BandPlan {
    /// Level-major, then rank-major, then ascending z — a deterministic
    /// order the CSR indices are computed against arithmetically.
    pub tiles: Vec<Tile>,
    /// `starts[level * ranks + rank]` = index of that cell's first tile.
    starts: Vec<u32>,
    /// Unsatisfied-predecessor count per tile (0 ⇒ initially ready).
    indegree: Vec<u32>,
    /// CSR successor lists: `succ_data[succ_offsets[i]..succ_offsets[i+1]]`.
    succ_offsets: Vec<u32>,
    succ_data: Vec<u32>,
    ranks: usize,
    tile: usize,
}

impl BandPlan {
    /// Number of tiles across the band.
    pub fn len(&self) -> usize {
        self.tiles.len()
    }

    /// True when the band has no tiles (empty level ranges).
    pub fn is_empty(&self) -> bool {
        self.tiles.is_empty()
    }

    /// Index range of the tiles covering one `(level, rank)` cell.
    pub fn cell(&self, level: usize, rank: usize) -> (usize, usize) {
        let c = level * self.ranks + rank;
        (self.starts[c] as usize, self.starts[c + 1] as usize)
    }

    /// The z-extent the band was tiled with.
    pub fn tile_extent(&self) -> usize {
        self.tile
    }
}

/// Plan one wavefront band of `depth` consecutive sub-step levels over
/// `ranks` ranks.  `range(level, rank)` returns that cell's z-range
/// `[z0, z1)` in storage coordinates (level is band-relative); `tile`
/// is the z-extent per tile and `r` the stencil radius the dependency
/// halo grows by.  Level 0 tiles have no in-band predecessors — their
/// inputs were completed by the previous band (or sub-step 0), which
/// the caller sequences before this one.
pub fn plan_band(
    ranks: usize,
    depth: usize,
    tile: usize,
    r: usize,
    range: &dyn Fn(usize, usize) -> (usize, usize),
) -> BandPlan {
    assert!(tile > 0, "wavefront tile extent must be positive");
    assert!(depth > 0 && ranks > 0);
    let cells = depth * ranks;
    let mut starts: Vec<u32> = Vec::with_capacity(cells + 1);
    starts.push(0);
    let mut total = 0usize;
    for level in 0..depth {
        for rank in 0..ranks {
            let (z0, z1) = range(level, rank);
            total += (z1 - z0).div_ceil(tile);
            starts.push(total as u32);
        }
    }

    let mut tiles: Vec<Tile> = Vec::with_capacity(total);
    for level in 0..depth {
        for rank in 0..ranks {
            let (z0, z1) = range(level, rank);
            let mut z = z0;
            while z < z1 {
                let ze = (z + tile).min(z1);
                tiles.push(Tile { rank, level, z0: z, z1: ze });
                z = ze;
            }
        }
    }

    // Parent-index range of tile `t` at `level > 0`: the level-1 tiles
    // of the same rank whose z-range intersects [t.z0 − r, t.z1 + r),
    // clamped to the parent range (the diamond's rank-boundary clamp).
    // Parent slabs are `tile`-aligned to their own z0, so the range is
    // arithmetic — no search.
    let plan_parents = |starts: &[u32], t: &Tile| -> (usize, usize) {
        let c = (t.level - 1) * ranks + t.rank;
        let (p0, p1) = (starts[c] as usize, starts[c + 1] as usize);
        debug_assert!(p1 > p0, "parent level range cannot be empty");
        let pa = tiles[p0].z0;
        let pb = tiles[p1 - 1].z1;
        let lo = t.z0.saturating_sub(r).max(pa);
        let hi = (t.z1 + r).min(pb) - 1;
        (p0 + (lo - pa) / tile, p0 + (hi - pa) / tile)
    };

    let mut indegree: Vec<u32> = Vec::with_capacity(total);
    let mut succ_offsets: Vec<u32> = vec![0; total + 1];
    for t in &tiles {
        if t.level == 0 {
            indegree.push(0);
            continue;
        }
        let (lo, hi) = plan_parents(&starts, t);
        indegree.push((hi - lo + 1) as u32);
        for p in lo..=hi {
            succ_offsets[p + 1] += 1;
        }
    }
    for i in 0..total {
        succ_offsets[i + 1] += succ_offsets[i];
    }
    let mut succ_data: Vec<u32> = vec![0; succ_offsets[total] as usize];
    let mut cursor: Vec<u32> = succ_offsets[..total].to_vec();
    for (i, t) in tiles.iter().enumerate() {
        if t.level == 0 {
            continue;
        }
        let (lo, hi) = plan_parents(&starts, t);
        for p in lo..=hi {
            succ_data[cursor[p] as usize] = i as u32;
            cursor[p] += 1;
        }
    }

    BandPlan { tiles, starts, indegree, succ_offsets, succ_data, ranks, tile }
}

/// Execute one band on the persistent runtime with **one dispatch**
/// (one global barrier for the whole band, however many levels it
/// spans): up to `threads` draining workers pop ready tiles from a
/// shared queue, run `exec`, and unlock successors by decrementing
/// their ledger counters — a tile starts the moment its r-halo
/// dependencies complete, never at a level boundary.
///
/// Deadlock-free by the DAG's minimal element: while `done < total`
/// some tile is either in the queue or mid-execution, so at least one
/// worker always makes progress; workers that find the queue empty spin
/// with `yield_now` and exit once the count drains.  Queue and counters
/// are pre-sized — no allocation after this function's fixed handful of
/// `with_capacity` events.
pub fn run_band(rt: &Runtime, threads: usize, plan: &BandPlan, exec: &(dyn Fn(&Tile) + Sync)) {
    run_band_with_deadline(rt, threads, plan, exec, None)
        .expect("a band without a deadline always drains");
}

/// An expired [`run_band_with_deadline`] deadline: the band was
/// abandoned with `completed` of `total` tiles executed.  Tiles already
/// popped finish (a mid-flight stencil sweep is never torn); the rest
/// are left unexecuted, so the band's output is incomplete and the
/// caller must treat the step as failed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BandTimeout {
    /// Tiles that finished before the workers gave up.
    pub completed: usize,
    /// Tiles the plan held.
    pub total: usize,
}

impl std::fmt::Display for BandTimeout {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "wavefront band deadline expired with {}/{} tiles completed",
            self.completed, self.total
        )
    }
}

impl std::error::Error for BandTimeout {}

/// [`run_band`] with an optional wall-clock deadline: when it expires,
/// workers stop claiming tiles (in-flight tiles finish) and the band
/// surfaces [`BandTimeout`] instead of spinning forever on a wedged or
/// pathologically slow `exec` — the containment half of the resilience
/// contract (DESIGN.md §16).  `None` is byte-for-byte the classic
/// [`run_band`] schedule: the deadline check is a branch on an `Option`
/// and touches no arithmetic, so the bitwise contract is untouched.
pub fn run_band_with_deadline(
    rt: &Runtime,
    threads: usize,
    plan: &BandPlan,
    exec: &(dyn Fn(&Tile) + Sync),
    deadline: Option<Duration>,
) -> Result<(), BandTimeout> {
    let total = plan.tiles.len();
    if total == 0 {
        return Ok(());
    }
    let expires_at = deadline.map(|d| Instant::now() + d);
    let expired = AtomicBool::new(false);
    let remaining: Vec<AtomicU32> = plan.indegree.iter().map(|&d| AtomicU32::new(d)).collect();
    let mut q = Vec::with_capacity(total);
    q.extend(
        plan.indegree
            .iter()
            .enumerate()
            .filter(|(_, &d)| d == 0)
            .map(|(i, _)| i as u32),
    );
    let ready = Mutex::new(q);
    let done = AtomicUsize::new(0);
    let workers = threads.min(total).max(1);
    rt.run(workers, workers, &|_| loop {
        if let Some(at) = expires_at {
            if expired.load(Ordering::Relaxed) || Instant::now() >= at {
                expired.store(true, Ordering::Relaxed);
                return;
            }
        }
        let next = ready.lock().unwrap().pop();
        match next {
            Some(t) => {
                exec(&plan.tiles[t as usize]);
                let (lo, hi) = (
                    plan.succ_offsets[t as usize] as usize,
                    plan.succ_offsets[t as usize + 1] as usize,
                );
                for &s in &plan.succ_data[lo..hi] {
                    if remaining[s as usize].fetch_sub(1, Ordering::AcqRel) == 1 {
                        ready.lock().unwrap().push(s);
                    }
                }
                done.fetch_add(1, Ordering::AcqRel);
            }
            None => {
                if done.load(Ordering::Acquire) >= total {
                    return;
                }
                std::thread::yield_now();
            }
        }
    });
    let completed = done.load(Ordering::Acquire);
    if completed < total {
        return Err(BandTimeout { completed, total });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::runtime::RuntimeConfig;

    /// Two ranks, three levels shrinking by r per side — the fused
    /// sub-step shape the driver feeds this module.
    fn shrinking(level: usize, _rank: usize) -> (usize, usize) {
        let r = 2;
        (8 + level * r, 40 - level * r)
    }

    #[test]
    fn tiles_partition_every_level_range() {
        for tile in [1, 3, 5, 64] {
            let plan = plan_band(2, 3, tile, 2, &shrinking);
            for level in 0..3 {
                for rank in 0..2 {
                    let (lo, hi) = plan.cell(level, rank);
                    let (z0, z1) = shrinking(level, rank);
                    assert!(hi > lo);
                    assert_eq!(plan.tiles[lo].z0, z0);
                    assert_eq!(plan.tiles[hi - 1].z1, z1);
                    for w in plan.tiles[lo..hi].windows(2) {
                        assert_eq!(w[0].z1, w[1].z0, "tiles must abut");
                        assert!(w[0].z1 - w[0].z0 == tile.min(z1 - z0));
                    }
                }
            }
        }
    }

    #[test]
    fn ledger_edges_are_exactly_the_r_halo_intersections() {
        let r = 2;
        let plan = plan_band(2, 3, 3, r, &shrinking);
        // recompute every edge by brute force and compare with the CSR
        let n = plan.tiles.len();
        let mut want = vec![0u32; n];
        for (i, t) in plan.tiles.iter().enumerate() {
            if t.level == 0 {
                continue;
            }
            for p in &plan.tiles {
                let same_cell = p.level + 1 == t.level && p.rank == t.rank;
                if same_cell && p.z1 + r > t.z0 && p.z0 < t.z1 + r {
                    want[i] += 1;
                }
            }
        }
        assert_eq!(plan.indegree, want);
        // successor lists mirror the in-degrees
        let edges: usize = want.iter().map(|&d| d as usize).sum();
        assert_eq!(plan.succ_data.len(), edges);
        for (p, &i) in plan.succ_offsets[..n].iter().zip(plan.succ_offsets[1..].iter()) {
            assert!(p <= &i);
        }
        for (p_idx, w) in plan.succ_offsets.windows(2).enumerate() {
            for &c in &plan.succ_data[w[0] as usize..w[1] as usize] {
                let (p, c) = (&plan.tiles[p_idx], &plan.tiles[c as usize]);
                assert_eq!(p.level + 1, c.level);
                assert_eq!(p.rank, c.rank);
                assert!(p.z1 + r > c.z0 && p.z0 < c.z1 + r, "edge without halo overlap");
            }
        }
    }

    #[test]
    fn executor_runs_each_tile_once_in_dependency_order() {
        let rt = Runtime::new(RuntimeConfig { workers: 4, cores_per_numa: 4, numa_nodes: 1 });
        for threads in [1usize, 2, 4] {
            for tile in [2, 5] {
                let plan = plan_band(2, 4, tile, 2, &|l, _| (8 + l * 2, 48 - l * 2));
                let order: Mutex<Vec<usize>> = Mutex::new(Vec::new());
                let key = |t: &Tile| ((t.level * 2 + t.rank) << 16) | t.z0;
                run_band(&rt, threads, &plan, &|t| {
                    order.lock().unwrap().push(key(t));
                });
                let order = order.into_inner().unwrap();
                assert_eq!(order.len(), plan.len(), "every tile exactly once");
                let pos = |k: usize| order.iter().position(|&o| o == k).unwrap();
                // every ledger edge is respected: parents run first
                for (p_idx, w) in plan.succ_offsets.windows(2).enumerate() {
                    for &c in &plan.succ_data[w[0] as usize..w[1] as usize] {
                        let (p, c) = (&plan.tiles[p_idx], &plan.tiles[c as usize]);
                        assert!(
                            pos(key(p)) < pos(key(c)),
                            "tile {c:?} ran before its dependency {p:?} (threads {threads})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn deadline_surfaces_a_timeout_instead_of_hanging() {
        let rt = Runtime::new(RuntimeConfig { workers: 2, cores_per_numa: 2, numa_nodes: 1 });
        let plan = plan_band(1, 2, 2, 2, &|l, _| (4 + l * 2, 24 - l * 2));
        // every tile outlives the deadline: the band must give up with
        // a Timeout, not spin on the wedged exec forever
        let err = run_band_with_deadline(
            &rt,
            2,
            &plan,
            &|_| std::thread::sleep(Duration::from_millis(20)),
            Some(Duration::from_millis(5)),
        )
        .unwrap_err();
        assert!(err.completed < err.total, "{err}");
        assert_eq!(err.total, plan.len());
        assert!(err.to_string().contains("deadline expired"), "{err}");
        // a generous deadline drains the whole band like the classic path
        let hits = AtomicUsize::new(0);
        run_band_with_deadline(
            &rt,
            2,
            &plan,
            &|_| {
                hits.fetch_add(1, Ordering::Relaxed);
            },
            Some(Duration::from_secs(30)),
        )
        .unwrap();
        assert_eq!(hits.load(Ordering::Relaxed), plan.len());
    }

    #[test]
    fn single_level_band_is_a_plain_parallel_dispatch() {
        let plan = plan_band(3, 1, 4, 4, &|_, rk| (0, 10 + rk));
        assert!(plan.indegree.iter().all(|&d| d == 0));
        assert!(plan.succ_data.is_empty());
        assert_eq!(plan.tile, 4);
    }
}
