//! The L3 coordinator: MMStencil's parallelism contribution.
//!
//! * [`tiles`]    — per-core tile partitioning, including the snoop-aware
//!   narrow-Y adjacent assignment (paper §IV-E);
//! * [`runtime`]  — the persistent NUMA-aware worker runtime: workers
//!   spawned once per driver lifetime, pinned to simulated core slots,
//!   fed through per-worker injector queues with work stealing;
//! * [`pool`]     — `parallel_for`-style helpers dispatching onto the
//!   process-global runtime (kept for the RTM propagators);
//! * [`scratch`]  — worker-local grow-only scratch arenas backing the
//!   engines' block windows, accumulator rows, and halo face staging
//!   (allocation-free steady state, with a test hook);
//! * [`exchange`] — halo exchange between rank subdomains, with both the
//!   SDMA and the MPI cost paths (paper §IV-F, Table II);
//! * [`pipeline`] — z-layer pipeline overlapping compute with exchange
//!   (paper Fig. 9), executed as runtime tasks;
//! * [`driver`]   — whole-sweep orchestration: grid → bricks → tiles →
//!   runtime batches → engine (rust-native or artifact) → metrics.

pub mod driver;
pub mod exchange;
pub mod pipeline;
pub mod pool;
pub mod runtime;
pub mod scratch;
pub mod tiles;
