//! The L3 coordinator: MMStencil's parallelism contribution.
//!
//! * [`tiles`]    — per-core tile partitioning, including the snoop-aware
//!   narrow-Y adjacent assignment (paper §IV-E);
//! * [`runtime`]  — the persistent NUMA-aware worker runtime: workers
//!   spawned once per driver lifetime, pinned to simulated core slots,
//!   fed through per-worker injector queues with work stealing;
//! * [`pool`]     — `parallel_for`-style helpers dispatching onto the
//!   process-global runtime (kept for the RTM propagators);
//! * [`scratch`]  — worker-local grow-only scratch arenas backing the
//!   engines' block windows, accumulator rows, and halo face staging
//!   (allocation-free steady state, with a test hook);
//! * [`exchange`] — halo exchange between rank subdomains, with both the
//!   SDMA and the MPI cost paths (paper §IV-F, Table II);
//! * [`pipeline`] — z-layer pipeline overlapping compute with exchange
//!   (paper Fig. 9), executed as runtime tasks;
//! * [`temporal`] — deep-halo temporal blocking: `k·r` halo frames,
//!   one exchange per `k` fused sub-steps, trapezoid sub-step boxes
//!   (paper §III-B's "depth of temporal blocking", made tunable);
//! * [`wavefront`] — in-rank diamond/wavefront tiling of the fused
//!   sub-steps: cache-resident (z, t) tiles advanced through a CSR
//!   dependency ledger with one dispatch per band — no global barrier
//!   between sub-step levels (DESIGN.md §14);
//! * [`driver`]   — whole-sweep orchestration: grid → bricks → tiles →
//!   runtime batches → engine (selected through `stencil::Engine`) →
//!   metrics.
//!
//! Ownership/aliasing contract: the coordinator owns the tile plans
//! and batch ordering, but never hands two tasks overlapping mutable
//! state — every region task claims an exclusive `TileViewMut` of its
//! output box, chunk helpers claim disjoint `ParSlice` ranges, and
//! scratch buffers belong to worker threads (checked out per task via
//! scoped closures, never shared).  Engines are dispatched per claim
//! through the `stencil::engine` layer.

pub mod driver;
pub mod exchange;
pub mod pipeline;
pub mod pool;
pub mod runtime;
pub mod scratch;
pub mod temporal;
pub mod tiles;
pub mod wavefront;
