//! The L3 coordinator: MMStencil's parallelism contribution.
//!
//! * [`tiles`]    — per-core tile partitioning, including the snoop-aware
//!   narrow-Y adjacent assignment (paper §IV-E);
//! * [`pool`]     — scoped thread pool executing tile tasks on real data;
//! * [`exchange`] — halo exchange between rank subdomains, with both the
//!   SDMA and the MPI cost paths (paper §IV-F, Table II);
//! * [`pipeline`] — z-layer pipeline overlapping compute with exchange
//!   (paper Fig. 9);
//! * [`driver`]   — whole-sweep orchestration: grid → bricks → tiles →
//!   threads → engine (rust-native or PJRT block artifacts) → metrics.

pub mod driver;
pub mod exchange;
pub mod pipeline;
pub mod pool;
pub mod tiles;
