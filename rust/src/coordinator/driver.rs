//! Sweep orchestration: single-NUMA multi-thread sweeps and multi-rank
//! (NUMA-process) stepped sweeps with halo exchange.
//!
//! Real data + real threads on the host, with simulated-platform timing
//! attached from `simulator::roofline` / the exchange models so every
//! experiment reports both "measured here" and "predicted on the paper's
//! platform" numbers.

use crate::grid::decomp::CartDecomp;
use crate::grid::Grid3;
use crate::simulator::roofline::{self, Engine, MemKind, SweepConfig};
use crate::simulator::Platform;
use crate::stencil::{simd, StencilSpec};
use crate::util::Timer;

use super::exchange::{self, Backend};
use super::pipeline::{self, Overlap};
use super::pool;
use super::tiles::{self, Strategy};

/// Statistics from one parallel sweep.
#[derive(Clone, Copy, Debug)]
pub struct SweepStats {
    pub real_s: f64,
    pub cells: usize,
    /// measured host throughput (stencil outputs / s)
    pub gcells_per_s: f64,
    /// simulated single-NUMA time on the paper platform
    pub sim_s: f64,
    pub sim_bandwidth_util: f64,
}

/// Shared-output wrapper: tiles are disjoint, so concurrent mutation is
/// race-free; assert-checked by `TilePlan::validate` in tests.
struct SharedOut(*mut Grid3);
unsafe impl Sync for SharedOut {}
unsafe impl Send for SharedOut {}

/// One full periodic sweep of `spec` over `g`, parallelized over
/// `threads` with the given tile strategy.  Returns the output grid and
/// host + simulated stats.
pub fn sweep(
    spec: &StencilSpec,
    g: &Grid3,
    threads: usize,
    strategy: Strategy,
    platform: &Platform,
) -> (Grid3, SweepStats) {
    assert_eq!(spec.ndim, 3);
    let plan = tiles::plan(strategy, threads.max(1), g.nx, g.ny);
    let mut out = Grid3::zeros(g.nz, g.nx, g.ny);
    let t = Timer::start();
    {
        let shared = SharedOut(&mut out as *mut Grid3);
        let shared = &shared;
        let tile_list = &plan.tiles;
        pool::parallel_for(threads, tile_list.len(), |i| {
            let tl = &tile_list[i];
            // SAFETY: tiles are disjoint XY regions over all z
            let out_ref: &mut Grid3 = unsafe { &mut *shared.0 };
            simd::apply3_region(spec, g, out_ref, 0, g.nz, tl.x0, tl.x1, tl.y0, tl.y1);
        });
    }
    let real_s = t.secs();
    let cells = g.len();
    let cfg = SweepConfig::best(MemKind::OnPkg);
    let est = roofline::predict(spec, cells, Engine::MMStencil, cfg, platform);
    (
        out,
        SweepStats {
            real_s,
            cells,
            gcells_per_s: cells as f64 / real_s / 1e9,
            sim_s: est.time_s,
            sim_bandwidth_util: est.bandwidth_util,
        },
    )
}

/// Multi-rank stepped sweep statistics (per step).
#[derive(Clone, Copy, Debug)]
pub struct StepStats {
    pub real_s: f64,
    /// simulated per-rank compute time
    pub sim_compute_s: f64,
    /// simulated exchange time under the chosen backend
    pub sim_comm_s: f64,
    /// simulated step time without overlap
    pub sim_step_s: f64,
    /// simulated step time with the pipeline-overlap scheme
    pub sim_step_pipelined_s: f64,
    pub exchanged_bytes: u64,
}

/// Run `steps` repeated sweeps of `spec` over a global periodic grid
/// decomposed across `decomp` ranks, exchanging halos through `backend`
/// each step.  Returns the final grid plus per-step stats (averaged).
pub fn multirank_sweep(
    spec: &StencilSpec,
    global: &Grid3,
    decomp: &CartDecomp,
    backend: &Backend,
    steps: usize,
    threads: usize,
    platform: &Platform,
) -> (Grid3, StepStats) {
    let r = spec.radius;
    let mut current = global.clone();
    let mut acc = StepStats {
        real_s: 0.0,
        sim_compute_s: 0.0,
        sim_comm_s: 0.0,
        sim_step_s: 0.0,
        sim_step_pipelined_s: 0.0,
        exchanged_bytes: 0,
    };
    for _ in 0..steps {
        let t = Timer::start();
        let mut grids = exchange::scatter(&current, decomp, r);
        let rep = exchange::exchange(decomp, &mut grids, backend);
        exchange::fill_halos_from_global(&current, decomp, &mut grids, true);

        // per-rank compute (parallel over ranks; each rank sweeps its
        // interior using the halo-extended storage as a periodic grid is
        // NOT valid — compute directly on storage with plain offsets)
        let rank_outputs = pool::parallel_map(threads, decomp.ranks(), |rk| {
            let hg = &grids[rk];
            // wrap-free: every interior point has its halo present
            let mut outg = Grid3::zeros(hg.nz, hg.nx, hg.ny);
            compute_interior(spec, hg, &mut outg);
            outg
        });
        let mut next = Grid3::zeros(current.nz, current.nx, current.ny);
        for (rk, og) in rank_outputs.iter().enumerate() {
            let b = decomp.block(rk, current.nz, current.nx, current.ny);
            next.insert_block(b.z0, b.x0, b.y0, og.nz, og.nx, og.ny, &og.data);
        }
        current = next;

        // simulated accounting: each rank is one NUMA node
        let rank_cells = decomp.block(0, current.nz, current.nx, current.ny).cells();
        let est = roofline::predict(
            spec,
            rank_cells,
            Engine::MMStencil,
            SweepConfig::best(MemKind::OnPkg),
            platform,
        );
        let overlap = match backend {
            Backend::Sdma(_) => Overlap::Concurrent,
            Backend::Mpi(_) => Overlap::Serialized,
        };
        let layers = 8usize;
        let (compute_l, comm_l) = pipeline::equal_layers(est.time_s, rep.sim_time_s, layers);
        let (no_overlap, pipelined) = pipeline::step_time(&compute_l, &comm_l, overlap);

        acc.real_s += t.secs();
        acc.sim_compute_s += est.time_s;
        acc.sim_comm_s += rep.sim_time_s;
        acc.sim_step_s += no_overlap;
        acc.sim_step_pipelined_s += pipelined;
        acc.exchanged_bytes += rep.bytes;
    }
    let n = steps.max(1) as f64;
    acc.real_s /= n;
    acc.sim_compute_s /= n;
    acc.sim_comm_s /= n;
    acc.sim_step_s /= n;
    acc.sim_step_pipelined_s /= n;
    (current, acc)
}

/// Compute the interior of a halo grid (all halos must be filled).
fn compute_interior(spec: &StencilSpec, hg: &crate::grid::halo::HaloGrid, out: &mut Grid3) {
    let r = spec.radius;
    // view the storage as a periodic grid restricted to interior points:
    // every needed neighbour is physically present, so wrap never fires
    let storage = &hg.grid;
    let mut tmp = Grid3::zeros(storage.nz, storage.nx, storage.ny);
    simd::apply3_region(
        spec,
        storage,
        &mut tmp,
        r,
        r + hg.nz,
        r,
        r + hg.nx,
        r,
        r + hg.ny,
    );
    for z in 0..hg.nz {
        for x in 0..hg.nx {
            let src = tmp.idx(z + r, x + r, r);
            let dst = out.idx(z, x, 0);
            out.data[dst..dst + hg.ny].copy_from_slice(&tmp.data[src..src + hg.ny]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::naive;
    use crate::util::prop::assert_allclose;

    #[test]
    fn parallel_sweep_matches_naive() {
        let spec = StencilSpec::star3d(4);
        let g = Grid3::random(12, 32, 48, 5);
        let want = naive::apply3(&spec, &g);
        let p = Platform::paper();
        for threads in [1, 2, 4] {
            for strat in [Strategy::Square, Strategy::SnoopAware] {
                let (got, stats) = sweep(&spec, &g, threads, strat, &p);
                assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
                assert!(stats.gcells_per_s > 0.0);
            }
        }
    }

    #[test]
    fn multirank_step_matches_single_grid_sweep() {
        let spec = StencilSpec::star3d(2);
        let g = Grid3::random(16, 16, 16, 6);
        let want = naive::apply3(&spec, &g);
        let p = Platform::paper();
        let d = CartDecomp::new(2, 2, 2);
        let (got, stats) =
            multirank_sweep(&spec, &g, &d, &Backend::sdma(), 1, 4, &p);
        assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
        assert!(stats.exchanged_bytes > 0);
    }

    #[test]
    fn multirank_multi_step_stays_consistent() {
        let spec = StencilSpec::star3d(1);
        let g = Grid3::random(12, 12, 12, 7);
        let p = Platform::paper();
        // two steps of decomposed == two steps of naive
        let mut want = g.clone();
        for _ in 0..2 {
            want = naive::apply3(&spec, &want);
        }
        let d = CartDecomp::new(1, 2, 2);
        let (got, _) = multirank_sweep(&spec, &g, &d, &Backend::sdma(), 2, 4, &p);
        assert_allclose(&got.data, &want.data, 1e-3, 1e-4);
    }

    #[test]
    fn pipelined_beats_serial_for_sdma() {
        let spec = StencilSpec::star3d(4);
        let g = Grid3::random(16, 32, 32, 8);
        let p = Platform::paper();
        let d = CartDecomp::new(1, 1, 2);
        let (_, sdma) = multirank_sweep(&spec, &g, &d, &Backend::sdma(), 1, 2, &p);
        assert!(sdma.sim_step_pipelined_s <= sdma.sim_step_s);
        let (_, mpi) = multirank_sweep(&spec, &g, &d, &Backend::mpi(), 1, 2, &p);
        // MPI gains nothing from pipelining and its comm is far slower
        assert_eq!(mpi.sim_step_pipelined_s, mpi.sim_step_s);
        assert!(mpi.sim_comm_s > sdma.sim_comm_s);
    }
}
