//! Sweep orchestration: single-NUMA multi-thread sweeps and multi-rank
//! (NUMA-process) stepped sweeps with halo exchange.
//!
//! Real data + real threads on the host, with simulated-platform timing
//! attached from `simulator::roofline` / the exchange models so every
//! experiment reports both "measured here" and "predicted on the paper's
//! platform" numbers.
//!
//! All work is dispatched onto the **persistent** worker runtime
//! ([`super::runtime`]): the free functions [`sweep`] /
//! [`multirank_sweep`] use the process-global pool, while a [`Driver`]
//! owns a dedicated pool whose workers are spawned exactly once for the
//! driver's lifetime.  Which *compute engine* a tile task runs is no
//! longer hardcoded at the call site: region tasks dispatch through the
//! engine layer (`stencil::engine`), selected per driver
//! ([`Driver::with_engine`]) or per call ([`sweep_with`]).
//!
//! A multirank step is submitted as dependency-ordered batches —
//! under the SDMA backend the halo exchange runs as a
//! pool task *concurrently* with the deep-interior tile batch (paper
//! Fig. 9), and only the boundary-shell batch waits for it; under MPI
//! the exchange is serialized ahead of all compute, matching the
//! paper's progress-engine semantics.

use std::sync::Mutex;

use crate::grid::decomp::CartDecomp;
use crate::grid::halo::{HaloCodec, HaloView};
use crate::grid::par::ParGrid3;
use crate::grid::shell;
use crate::grid::Grid3;
use crate::simulator::roofline::{self, Engine as SimEngine, MemKind, SweepConfig};
use crate::simulator::Platform;
use crate::stencil::{Engine, StencilSpec, TunePlan};
use crate::util::Timer;

use super::exchange::{self, Backend};
use super::pipeline::{self, Overlap};
use super::runtime::{self, Runtime, RuntimeConfig, RuntimeStats};
use super::scratch;
use super::temporal;
use super::tiles::{self, Strategy};
use super::wavefront;

/// Pool activity attributable to one sweep / stepped run.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolSnapshot {
    pub workers: usize,
    /// Items executed (tiles, slabs, comm tasks) across the run.
    pub tasks: u64,
    /// Chunks stolen from a neighbour's injector queue.
    pub steals: u64,
    /// Mean worker busy fraction over the run's wall time.
    pub utilization: f64,
    /// One-time worker spawn cost of the backing runtime (not paid per
    /// call — reported so benches can show what per-call respawn would
    /// have cost).
    pub spawn_overhead_s: f64,
}

fn pool_delta(rt: &Runtime, before: &RuntimeStats, wall_s: f64) -> PoolSnapshot {
    let d = rt.stats().delta_since(before);
    PoolSnapshot {
        workers: rt.workers(),
        tasks: d.total_tasks(),
        steals: d.total_steals(),
        utilization: d.mean_utilization(wall_s),
        spawn_overhead_s: d.spawn_overhead_s,
    }
}

/// Statistics from one parallel sweep.
#[derive(Clone, Copy, Debug)]
pub struct SweepStats {
    pub real_s: f64,
    pub cells: usize,
    /// measured host throughput (stencil outputs / s)
    pub gcells_per_s: f64,
    /// simulated single-NUMA time on the paper platform
    pub sim_s: f64,
    pub sim_bandwidth_util: f64,
    /// runtime activity during this sweep
    pub pool: PoolSnapshot,
}

// Concurrent output is shared through `grid::par` views, not raw
// pointers: one `&mut Grid3` becomes a `ParGrid3` of `UnsafeCell`
// slots, and every task claims an exclusive `TileViewMut` of its
// disjoint region (`TilePlan::validate` proves the plans statically;
// debug builds re-check every claim dynamically).  No overlapping
// `&mut` references ever exist, so the sweeps are clean under Rust's
// aliasing model — enforced by the CI `miri` job over
// `rust/tests/aliasing.rs`.  The seed's shared-raw-pointer idiom this
// replaces satisfied the weaker no-data-race requirement only.

/// A driver owns a dedicated persistent runtime: workers are spawned
/// once in [`Driver::new`] and reused by every subsequent sweep or
/// timestep — never per `parallel_for` call.  The compute engine is a
/// driver property ([`Driver::with_engine`]): every per-tile region
/// task dispatches through it instead of hardcoding one engine at the
/// call site.
pub struct Driver {
    rt: Runtime,
    platform: Platform,
    threads: usize,
    engine: Engine,
    time_block: usize,
    tile: usize,
    wf: usize,
    halo: HaloCodec,
}

impl Driver {
    /// Spawn a driver with its own `threads`-worker runtime and the
    /// default simd engine.
    pub fn new(threads: usize, platform: Platform) -> Self {
        let threads = threads.max(1);
        let cfg = RuntimeConfig {
            workers: threads,
            cores_per_numa: platform.cores_per_numa,
            numa_nodes: platform.total_numa(),
        };
        Self {
            rt: Runtime::new(cfg),
            platform,
            threads,
            engine: Engine::from_plan(&TunePlan::simd(1)),
            time_block: 1,
            tile: 0,
            wf: 1,
            halo: HaloCodec::F32,
        }
    }

    /// Build from an experiment config (`[runtime]` + `[sweep]` +
    /// optional `[tune]` tables).  A `[tune] plan` string wins over the
    /// legacy per-knob keys: it selects the engine, block geometry, and
    /// fused-sweep depth in one value.
    pub fn from_config(cfg: &crate::config::ExperimentConfig) -> Self {
        let rc = cfg.runtime.to_runtime_config(cfg.sweep.threads);
        let plan = cfg.tune.plan.unwrap_or_else(|| TunePlan {
            time_block: cfg.runtime.time_block.max(1),
            halo: cfg.runtime.halo_codec,
            ..TunePlan::simd(1)
        });
        Self {
            rt: Runtime::new(rc),
            platform: Platform::paper(),
            threads: cfg.sweep.threads.max(1),
            engine: Engine::from_plan(&TunePlan { threads: 1, ..plan }),
            time_block: plan.time_block.max(1),
            tile: plan.tile,
            wf: plan.wf.max(1),
            halo: plan.halo,
        }
    }

    /// Configure this driver from a tuned plan: region tasks dispatch
    /// through the plan's engine/geometry and stepped runs fuse the
    /// plan's `time_block` sub-steps per halo exchange.  The plan's
    /// `threads` field is ignored here — the driver's own runtime is
    /// the parallelism.
    pub fn with_plan(mut self, plan: &TunePlan) -> Self {
        self.engine = Engine::from_plan(&TunePlan { threads: 1, ..*plan });
        self.time_block = plan.time_block.max(1);
        self.tile = plan.tile;
        self.wf = plan.wf.max(1);
        self.halo = plan.halo;
        self
    }

    /// Tile the fused sub-steps into `tile`-deep z-slabs advanced as a
    /// dependency-driven (z, t) wavefront
    /// ([`coordinator::wavefront`](super::wavefront)), `wf` sub-step
    /// levels per dispatch barrier.  `tile = 0` (the default) keeps the
    /// classic level-at-a-time fused path; results are bitwise
    /// identical for any geometry (`rust/tests/wavefront.rs`).
    pub fn with_wavefront(mut self, tile: usize, wf: usize) -> Self {
        self.tile = tile;
        self.wf = wf.max(1);
        self
    }

    /// Wavefront `(tile, wf)` geometry (`tile = 0` ⇒ classic stepping).
    pub fn wavefront(&self) -> (usize, usize) {
        (self.tile, self.wf)
    }

    /// Compress halo faces with `codec` during multirank exchanges
    /// (`[runtime] halo_codec` / plan key `halo=`).  Faces are packed in
    /// f32, quantized to the codec's wire format, and expanded on
    /// unpack; [`HaloCodec::F32`] (the default) is the bitwise-identical
    /// classic transport, while `bf16`/`f16` halve
    /// [`StepStats::exchanged_bytes`] at a bounded relative error
    /// (`rust/tests/precision.rs`).
    pub fn with_halo_codec(mut self, codec: HaloCodec) -> Self {
        self.halo = codec;
        self
    }

    /// The halo wire codec multirank exchanges run through.
    pub fn halo_codec(&self) -> HaloCodec {
        self.halo
    }

    /// Route this driver's region tasks through `engine` (tasks run
    /// serially inside their claims — the driver's tiling is the
    /// parallelism, so the engine's own `threads` hint is unused here).
    pub fn with_engine(mut self, engine: Engine) -> Self {
        self.engine = engine;
        self
    }

    /// The engine region tasks dispatch through.
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Fuse `k` timesteps per halo exchange (`[runtime] time_block`,
    /// clamped to ≥ 1): periodic sweeps run `k` back-to-back passes
    /// ping-ponged through an arena double buffer, and multirank steps
    /// take the deep-halo temporal-blocking path
    /// ([`coordinator::temporal`](super::temporal)).  `1` is the
    /// classic one-exchange-per-step pipeline, bitwise unchanged.
    pub fn with_time_block(mut self, k: usize) -> Self {
        self.time_block = k.max(1);
        self
    }

    /// Timesteps fused per halo exchange (1 = classic stepping).
    pub fn time_block(&self) -> usize {
        self.time_block
    }

    /// The dedicated runtime backing this driver.
    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    /// Worker-parallelism of this driver's sweeps.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// One periodic sweep pass on this driver's runtime and engine —
    /// or, with [`with_time_block`](Self::with_time_block)` > 1`, `k`
    /// fused back-to-back passes (result = the `k`-times-composed
    /// sweep, bitwise equal to `k` separate calls; `SweepStats::cells`
    /// counts all `k·n³` updates).
    pub fn sweep(&self, spec: &StencilSpec, g: &Grid3, strategy: Strategy) -> (Grid3, SweepStats) {
        sweep_on(
            &self.rt,
            spec,
            g,
            self.threads,
            strategy,
            &self.platform,
            &self.engine,
            self.time_block,
        )
    }

    /// A multi-rank stepped sweep on this driver's runtime and engine.
    /// With [`with_time_block`](Self::with_time_block)` > 1` the steps
    /// run through the deep-halo temporal-blocking path: one exchange
    /// per `k` fused sub-steps, bitwise equal to the classic path.
    pub fn multirank_sweep(
        &self,
        spec: &StencilSpec,
        global: &Grid3,
        decomp: &CartDecomp,
        backend: &Backend,
        steps: usize,
    ) -> (Grid3, StepStats) {
        if self.time_block > 1 {
            multirank_sweep_fused_on(
                &self.rt,
                spec,
                global,
                decomp,
                backend,
                steps,
                self.threads,
                &self.platform,
                &self.engine,
                self.time_block,
                self.tile,
                self.wf,
                self.halo,
            )
        } else {
            multirank_sweep_on(
                &self.rt,
                spec,
                global,
                decomp,
                backend,
                steps,
                self.threads,
                &self.platform,
                &self.engine,
                self.halo,
            )
        }
    }
}

/// One full periodic sweep of `spec` over `g`, parallelized over
/// `threads` with the given tile strategy on the process-global pool
/// and the default simd engine ([`sweep_with`] takes an explicit one).
pub fn sweep(
    spec: &StencilSpec,
    g: &Grid3,
    threads: usize,
    strategy: Strategy,
    platform: &Platform,
) -> (Grid3, SweepStats) {
    sweep_with(spec, g, threads, strategy, platform, &Engine::from_plan(&TunePlan::simd(1)))
}

/// [`sweep`] with an explicit engine: every tile task dispatches its
/// region through `engine`.
pub fn sweep_with(
    spec: &StencilSpec,
    g: &Grid3,
    threads: usize,
    strategy: Strategy,
    platform: &Platform,
    engine: &Engine,
) -> (Grid3, SweepStats) {
    sweep_on(runtime::global(), spec, g, threads, strategy, platform, engine, 1)
}

#[allow(clippy::too_many_arguments)]
fn sweep_on(
    rt: &Runtime,
    spec: &StencilSpec,
    g: &Grid3,
    threads: usize,
    strategy: Strategy,
    platform: &Platform,
    engine: &Engine,
    time_block: usize,
) -> (Grid3, SweepStats) {
    assert_eq!(spec.ndim, 3);
    let k = time_block.max(1);
    let plan = tiles::plan(strategy, threads.max(1), g.nx, g.ny);
    // static proof of the disjointness every claim below relies on
    #[cfg(debug_assertions)]
    plan.validate();
    let mut out = Grid3::zeros(g.nz, g.nx, g.ny);
    let before = rt.stats();
    let t = Timer::start();
    {
        let tile_list = &plan.tiles;
        // one tiled pass src → dst; the tiles cover the grid, and every
        // engine overwrites its whole claim, so dst is fully defined
        let run_pass = |src: &Grid3, dst: &mut Grid3| {
            let out_pg = ParGrid3::new(dst);
            let out_pg = &out_pg;
            rt.run(threads.max(1), tile_list.len(), &|i| {
                // exclusive view of this tile's XY region over all z
                let mut view = tile_list[i].claim(out_pg);
                engine.apply3_region(spec, src, &mut view);
            });
        };
        run_pass(g, &mut out);
        if k > 1 {
            // fused passes ping-pong through one arena checkout instead
            // of allocating (and zeroing) a grid per pass — the
            // single-grid form of temporal blocking (no halo to pay, so
            // the whole win is allocation traffic + dst reuse in cache)
            let mut other = scratch::grid(g.nz, g.nx, g.ny);
            for _ in 1..k {
                run_pass(&out, &mut *other);
                std::mem::swap(&mut out, &mut *other);
            }
        }
    }
    let real_s = t.secs();
    let cells = k * g.len();
    let cfg = SweepConfig::best(MemKind::OnPkg);
    let est = roofline::predict(spec, g.len(), SimEngine::MMStencil, cfg, platform);
    (
        out,
        SweepStats {
            real_s,
            cells,
            gcells_per_s: cells as f64 / real_s / 1e9,
            sim_s: est.time_s * k as f64,
            sim_bandwidth_util: est.bandwidth_util,
            pool: pool_delta(rt, &before, real_s),
        },
    )
}

/// Multi-rank stepped sweep statistics (per step).
#[derive(Clone, Copy, Debug)]
pub struct StepStats {
    pub real_s: f64,
    /// measured wall time of the halo-exchange task (overlapped with the
    /// interior batch under SDMA)
    pub real_comm_s: f64,
    /// simulated per-rank compute time
    pub sim_compute_s: f64,
    /// simulated exchange time under the chosen backend
    pub sim_comm_s: f64,
    /// simulated step time without overlap
    pub sim_step_s: f64,
    /// simulated step time with the pipeline-overlap scheme
    pub sim_step_pipelined_s: f64,
    pub exchanged_bytes: u64,
    /// Halo-exchange transport rounds performed across the whole run
    /// (NOT averaged): `steps` on the classic path, `⌈steps / k⌉` under
    /// temporal blocking — the 1/k reduction the fused path exists for.
    pub comm_rounds: u64,
    /// Global dispatch barriers spent on the fused sub-steps past the
    /// exchange-overlapped first one, across the whole run (NOT
    /// averaged): `k − 1` per round on the classic fused path,
    /// `⌈(k − 1) / wf⌉` per round under wavefront tiling — the barrier
    /// reduction `coordinator::wavefront` exists for.  0 when `k = 1`
    /// (and on the unfused path, which has no sub-steps).
    pub substep_barriers: u64,
    /// runtime activity across all steps
    pub pool: PoolSnapshot,
}

/// One rank's compute region, in halo-storage coordinates.
#[derive(Clone, Copy, Debug)]
struct RegionTask {
    rank: usize,
    z0: usize,
    z1: usize,
    x0: usize,
    x1: usize,
    y0: usize,
    y1: usize,
}

// The deep-interior / boundary-shell split below comes from
// `grid::shell` (shared with the stencil engines' O(surface) boundary
// fills): `shell::interior_box` is the halo-independent batch,
// `shell::boundary_boxes` the ≤6 slabs that wait on the exchange.

/// Run `steps` repeated sweeps of `spec` over a global periodic grid
/// decomposed across `decomp` ranks on the process-global pool,
/// exchanging halos through `backend` each step (default simd engine).
pub fn multirank_sweep(
    spec: &StencilSpec,
    global: &Grid3,
    decomp: &CartDecomp,
    backend: &Backend,
    steps: usize,
    threads: usize,
    platform: &Platform,
) -> (Grid3, StepStats) {
    multirank_sweep_on(
        runtime::global(),
        spec,
        global,
        decomp,
        backend,
        steps,
        threads,
        platform,
        &Engine::from_plan(&TunePlan::simd(1)),
        HaloCodec::F32,
    )
}

#[allow(clippy::too_many_arguments)]
fn multirank_sweep_on(
    rt: &Runtime,
    spec: &StencilSpec,
    global: &Grid3,
    decomp: &CartDecomp,
    backend: &Backend,
    steps: usize,
    threads: usize,
    platform: &Platform,
    engine: &Engine,
    codec: HaloCodec,
) -> (Grid3, StepStats) {
    let r = spec.radius;
    let threads = threads.max(1);
    let mut current = global.clone();
    let mut acc = StepStats {
        real_s: 0.0,
        real_comm_s: 0.0,
        sim_compute_s: 0.0,
        sim_comm_s: 0.0,
        sim_step_s: 0.0,
        sim_step_pipelined_s: 0.0,
        exchanged_bytes: 0,
        comm_rounds: 0,
        substep_barriers: 0,
        pool: PoolSnapshot::default(),
    };
    let before = rt.stats();
    let run_timer = Timer::start();
    for _ in 0..steps {
        let t = Timer::start();
        let mut grids = exchange::scatter(&current, decomp, r);

        // per-rank output buffers in halo-storage shape, so region tasks
        // can write results at the same coordinates they compute
        let mut touts: Vec<Grid3> = grids
            .iter()
            .map(|hg| Grid3::zeros(hg.grid.nz, hg.grid.nx, hg.grid.ny))
            .collect();

        // deep-interior tasks (no halo dependency), split into z-slabs so
        // every worker gets work even with few ranks (one granularity
        // policy, shared with the fused path: `push_zslabs`)
        let mut deep: Vec<RegionTask> = Vec::new();
        let mut shell: Vec<RegionTask> = Vec::new();
        for (rk, hg) in grids.iter().enumerate() {
            if let Some(b) = shell::interior_box(hg.nz, hg.nx, hg.ny, r) {
                let shifted = [b[0] + r, b[1] + r, b[2] + r, b[3] + r, b[4] + r, b[5] + r];
                push_zslabs(&mut deep, rk, shifted, threads, decomp.ranks());
            }
            for [z0, z1, x0, x1, y0, y1] in shell::boundary_boxes(hg.nz, hg.nx, hg.ny, r) {
                shell.push(RegionTask {
                    rank: rk,
                    z0: z0 + r,
                    z1: z1 + r,
                    x0: x0 + r,
                    x1: x1 + r,
                    y0: y0 + r,
                    y1: y1 + r,
                });
            }
        }

        let comm_result: Mutex<Option<(exchange::ExchangeReport, f64)>> = Mutex::new(None);
        {
            // cell-level views for the concurrent phase: the comm task
            // writes halo frames through exclusive claims while region
            // tasks read interiors through the same views' shared cell
            // access and write their own claimed tout boxes — no `&mut`
            // aliasing anywhere (see grid::par)
            let hviews: Vec<HaloView<'_>> = grids.iter_mut().map(|hg| hg.par_view()).collect();
            let tout_pgs: Vec<ParGrid3<'_>> = touts.iter_mut().map(ParGrid3::new).collect();
            let hviews = &hviews;
            let tout_pgs = &tout_pgs;

            let do_comm = || {
                let ct = Timer::start();
                let rep = exchange::exchange_views_codec(decomp, hviews, backend, codec);
                exchange::fill_halos_from_global_views(&current, decomp, hviews, true);
                *comm_result.lock().unwrap() = Some((rep, ct.secs()));
            };
            let run_region = |task: &RegionTask| {
                // exclusive view of this task's output box; the input is
                // read through the rank's shared halo view
                let mut view = tout_pgs[task.rank]
                    .view(task.z0, task.z1, task.x0, task.x1, task.y0, task.y1);
                engine.apply3_region(spec, &hviews[task.rank].pg, &mut view);
            };

            match backend {
                Backend::Sdma(_) => {
                    // SDMA is non-intrusive: the exchange task and the
                    // deep-interior batch run concurrently on the pool
                    rt.run(threads + 1, deep.len() + 1, &|i| {
                        if i == 0 {
                            do_comm();
                        } else {
                            run_region(&deep[i - 1]);
                        }
                    });
                }
                Backend::Mpi(_) => {
                    // MPI's progress engine occupies a core: exchange
                    // first, then compute (serialized, as the paper
                    // models it)
                    do_comm();
                    rt.run(threads, deep.len(), &|i| run_region(&deep[i]));
                }
            }
            // dependency-ordered batch: the boundary shell needs the
            // halos the exchange just filled
            rt.run(threads, shell.len(), &|i| run_region(&shell[i]));
        }

        // assemble the next global grid from the per-rank interiors
        let (gnz, gnx, gny) = current.shape();
        let mut next = Grid3::zeros(gnz, gnx, gny);
        {
            let next_pg = ParGrid3::new(&mut next);
            let next_pg = &next_pg;
            let touts_ref = &touts;
            rt.run(threads, decomp.ranks(), &|rk| {
                let b = decomp.block(rk, gnz, gnx, gny);
                let tg = &touts_ref[rk];
                let (bz, bx, by) = b.dims();
                // rank blocks partition the global grid: each task claims
                // exactly its block
                let mut view = next_pg.view(b.z0, b.z0 + bz, b.x0, b.x0 + bx, b.y0, b.y0 + by);
                for z in 0..bz {
                    for x in 0..bx {
                        let src = tg.idx(z + r, x + r, r);
                        view.copy_row_from(b.z0 + z, b.x0 + x, b.y0, &tg.as_slice()[src..src + by]);
                    }
                }
            });
        }
        let (rep, comm_s) = comm_result
            .into_inner()
            .unwrap()
            .expect("halo-exchange task must have run");
        current = next;

        // simulated accounting: each rank is one NUMA node
        let rank_cells = decomp.block(0, current.nz, current.nx, current.ny).cells();
        let est = roofline::predict(
            spec,
            rank_cells,
            SimEngine::MMStencil,
            SweepConfig::best(MemKind::OnPkg),
            platform,
        );
        let overlap = match backend {
            Backend::Sdma(_) => Overlap::Concurrent,
            Backend::Mpi(_) => Overlap::Serialized,
        };
        let layers = 8usize;
        let (compute_l, comm_l) = pipeline::equal_layers(est.time_s, rep.sim_time_s, layers);
        let (no_overlap, pipelined) = pipeline::step_time(&compute_l, &comm_l, overlap);

        acc.real_s += t.secs();
        acc.real_comm_s += comm_s;
        acc.sim_compute_s += est.time_s;
        acc.sim_comm_s += rep.sim_time_s;
        acc.sim_step_s += no_overlap;
        acc.sim_step_pipelined_s += pipelined;
        acc.exchanged_bytes += rep.bytes;
        acc.comm_rounds += 1;
    }
    let n = steps.max(1) as f64;
    acc.real_s /= n;
    acc.real_comm_s /= n;
    acc.sim_compute_s /= n;
    acc.sim_comm_s /= n;
    acc.sim_step_s /= n;
    acc.sim_step_pipelined_s /= n;
    acc.pool = pool_delta(rt, &before, run_timer.secs());
    (current, acc)
}

/// Split one rank's box into contiguous z-slab tasks so every worker
/// gets work even with few ranks — the single granularity policy of
/// both the classic deep-interior batch and the fused sub-step batches.
fn push_zslabs(
    tasks: &mut Vec<RegionTask>,
    rank: usize,
    b: [usize; 6],
    threads: usize,
    ranks: usize,
) {
    let span = b[1] - b[0];
    if span == 0 || b[2] >= b[3] || b[4] >= b[5] {
        return;
    }
    let slabs = (threads * 2).div_ceil(ranks).clamp(1, span);
    let per = span.div_ceil(slabs);
    let mut z = b[0];
    while z < b[1] {
        let ze = (z + per).min(b[1]);
        tasks.push(RegionTask { rank, z0: z, z1: ze, x0: b[2], x1: b[3], y0: b[4], y1: b[5] });
        z = ze;
    }
}

/// [`multirank_sweep`] with deep-halo temporal blocking on the
/// process-global pool (default simd engine): halos widened to `k·r`
/// and exchanged **once per `k` fused timesteps**, with each rank
/// running `k` back-to-back sweeps over shrinking trapezoid boxes
/// (`coordinator::temporal`) ping-ponged between its scattered slab and
/// an arena-checked-out double buffer.  `time_block` is clamped to the
/// decomposition's maximum depth; results are bitwise equal to the
/// classic path for any `k`, worker count, and backend
/// (`rust/tests/temporal.rs`), while `StepStats::comm_rounds` drops to
/// `⌈steps / k⌉`.
#[allow(clippy::too_many_arguments)]
pub fn multirank_sweep_fused(
    spec: &StencilSpec,
    global: &Grid3,
    decomp: &CartDecomp,
    backend: &Backend,
    steps: usize,
    threads: usize,
    platform: &Platform,
    time_block: usize,
) -> (Grid3, StepStats) {
    multirank_sweep_fused_on(
        runtime::global(),
        spec,
        global,
        decomp,
        backend,
        steps,
        threads,
        platform,
        &Engine::from_plan(&TunePlan::simd(1)),
        time_block,
        0,
        1,
        HaloCodec::F32,
    )
}

/// [`multirank_sweep_fused`] with in-rank wavefront tiling of the fused
/// sub-steps (`coordinator::wavefront`, default simd engine): the
/// levels `1..k` are cut into `tile`-deep z-slabs and advanced through
/// the dependency ledger, `wf` levels per dispatch barrier.  `tile = 0`
/// is exactly [`multirank_sweep_fused`]; any `tile > 0` is bitwise
/// identical to it (`rust/tests/wavefront.rs`) while
/// `StepStats::substep_barriers` drops from `k − 1` to `⌈(k − 1)/wf⌉`
/// per exchange round.
#[allow(clippy::too_many_arguments)]
pub fn multirank_sweep_wavefront(
    spec: &StencilSpec,
    global: &Grid3,
    decomp: &CartDecomp,
    backend: &Backend,
    steps: usize,
    threads: usize,
    platform: &Platform,
    time_block: usize,
    tile: usize,
    wf: usize,
) -> (Grid3, StepStats) {
    multirank_sweep_fused_on(
        runtime::global(),
        spec,
        global,
        decomp,
        backend,
        steps,
        threads,
        platform,
        &Engine::from_plan(&TunePlan::simd(1)),
        time_block,
        tile,
        wf,
        HaloCodec::F32,
    )
}

#[allow(clippy::too_many_arguments)]
fn multirank_sweep_fused_on(
    rt: &Runtime,
    spec: &StencilSpec,
    global: &Grid3,
    decomp: &CartDecomp,
    backend: &Backend,
    steps: usize,
    threads: usize,
    platform: &Platform,
    engine: &Engine,
    time_block: usize,
    tile: usize,
    wf: usize,
    codec: HaloCodec,
) -> (Grid3, StepStats) {
    let r = spec.radius;
    let threads = threads.max(1);
    let k_max = temporal::effective_depth(time_block, decomp, global.nz, global.nx, global.ny, r);
    let mut current = global.clone();
    let mut acc = StepStats {
        real_s: 0.0,
        real_comm_s: 0.0,
        sim_compute_s: 0.0,
        sim_comm_s: 0.0,
        sim_step_s: 0.0,
        sim_step_pipelined_s: 0.0,
        exchanged_bytes: 0,
        comm_rounds: 0,
        substep_barriers: 0,
        pool: PoolSnapshot::default(),
    };
    let before = rt.stats();
    let run_timer = Timer::start();
    let mut done = 0usize;
    while done < steps {
        let kk = k_max.min(steps - done);
        let h = kk * r;
        let t = Timer::start();
        // src slabs with a kk-radii-deep halo frame; dst double buffers
        // in the same storage shape, checked out of the caller's arena
        // (stale contents: every sub-step overwrites its whole claimed
        // box before reading it back, and the gather reads only the
        // interior the final sub-step wrote)
        let mut grids = exchange::scatter(&current, decomp, h);
        let mut bufs: Vec<scratch::GridCheckout> = grids
            .iter()
            .map(|hg| scratch::grid(hg.grid.nz, hg.grid.nx, hg.grid.ny))
            .collect();

        // sub-step 0: the deep batch only reads the pre-exchange-valid
        // interior, so it overlaps with the SDMA exchange exactly like
        // the classic deep-interior batch; the frame slabs wait for the
        // kk·r-deep halos
        let mut deep: Vec<RegionTask> = Vec::new();
        let mut frame: Vec<RegionTask> = Vec::new();
        for (rk, hg) in grids.iter().enumerate() {
            if let Some(b) = temporal::substep0_deep_box(hg.nz, hg.nx, hg.ny, r, kk) {
                push_zslabs(&mut deep, rk, b, threads, decomp.ranks());
            }
            for b in temporal::substep0_frame_boxes(hg.nz, hg.nx, hg.ny, r, kk) {
                frame.push(RegionTask {
                    rank: rk,
                    z0: b[0],
                    z1: b[1],
                    x0: b[2],
                    x1: b[3],
                    y0: b[4],
                    y1: b[5],
                });
            }
        }

        let comm_result: Mutex<Option<(exchange::ExchangeReport, f64)>> = Mutex::new(None);
        {
            let hviews: Vec<HaloView<'_>> = grids.iter_mut().map(|hg| hg.par_view()).collect();
            let dst_pgs: Vec<ParGrid3<'_>> =
                bufs.iter_mut().map(|b| ParGrid3::new(&mut **b)).collect();
            let hviews = &hviews;
            let dst_pgs = &dst_pgs;

            let do_comm = || {
                let ct = Timer::start();
                let rep = exchange::exchange_views_codec(decomp, hviews, backend, codec);
                exchange::fill_halos_from_global_views(&current, decomp, hviews, true);
                *comm_result.lock().unwrap() = Some((rep, ct.secs()));
            };
            let run_region = |task: &RegionTask| {
                let mut view = dst_pgs[task.rank]
                    .view(task.z0, task.z1, task.x0, task.x1, task.y0, task.y1);
                engine.apply3_region(spec, &hviews[task.rank].pg, &mut view);
            };

            match backend {
                Backend::Sdma(_) => {
                    rt.run(threads + 1, deep.len() + 1, &|i| {
                        if i == 0 {
                            do_comm();
                        } else {
                            run_region(&deep[i - 1]);
                        }
                    });
                }
                Backend::Mpi(_) => {
                    do_comm();
                    rt.run(threads, deep.len(), &|i| run_region(&deep[i]));
                }
            }
            rt.run(threads, frame.len(), &|i| run_region(&frame[i]));
        }

        // sub-steps 1..kk: ping-pong between the scattered slabs and the
        // arena buffers over the shrinking trapezoid boxes — no halo
        // traffic, every read is data the previous sub-step wrote
        if tile > 0 && kk > 1 {
            // wavefront path (`coordinator::wavefront`): both buffer
            // families stay wrapped for the whole band — levels
            // alternate write targets, reads go through `&ParGrid3`
            // (its shared `GridSrc` cell access), writes through
            // transient per-tile claims — and each band of `wf` levels
            // is ONE dispatch whose tiles unlock through the dependency
            // ledger, not a barrier per level
            let rank_dims: Vec<(usize, usize, usize)> =
                grids.iter().map(|hg| (hg.nz, hg.nx, hg.ny)).collect();
            let grid_pgs: Vec<ParGrid3<'_>> =
                grids.iter_mut().map(|hg| ParGrid3::new(&mut hg.grid)).collect();
            let buf_pgs: Vec<ParGrid3<'_>> =
                bufs.iter_mut().map(|b| ParGrid3::new(&mut **b)).collect();
            let mut s0 = 1usize;
            while s0 < kk {
                let depth = wf.max(1).min(kk - s0);
                let plan = wavefront::plan_band(decomp.ranks(), depth, tile, r, &|lvl, rk| {
                    let (nz, nx, ny) = rank_dims[rk];
                    let b = temporal::substep_box(nz, nx, ny, r, kk, s0 + lvl);
                    (b[0], b[1])
                });
                wavefront::run_band(rt, threads, &plan, &|t| {
                    let s = s0 + t.level;
                    let (nz, nx, ny) = rank_dims[t.rank];
                    let b = temporal::substep_box(nz, nx, ny, r, kk, s);
                    // sub-step t's result lives in `bufs` iff t is
                    // even, so level s reads `bufs` iff s is odd
                    let (src, dst) = if s % 2 == 1 {
                        (&buf_pgs[t.rank], &grid_pgs[t.rank])
                    } else {
                        (&grid_pgs[t.rank], &buf_pgs[t.rank])
                    };
                    let mut view = dst.view(t.z0, t.z1, b[2], b[3], b[4], b[5]);
                    engine.apply3_region(spec, src, &mut view);
                });
                acc.substep_barriers += 1;
                s0 += depth;
            }
        } else {
            for s in 1..kk {
                let mut tasks: Vec<RegionTask> = Vec::new();
                for (rk, hg) in grids.iter().enumerate() {
                    let b = temporal::substep_box(hg.nz, hg.nx, hg.ny, r, kk, s);
                    push_zslabs(&mut tasks, rk, b, threads, decomp.ranks());
                }
                // sub-step t's result lives in `bufs` iff t is even, so
                // sub-step s reads `bufs` iff s is odd
                let src_is_buf = s % 2 == 1;
                let (srcs, dsts): (Vec<&Grid3>, Vec<ParGrid3<'_>>) = if src_is_buf {
                    (
                        bufs.iter().map(|b| &**b).collect(),
                        grids.iter_mut().map(|hg| ParGrid3::new(&mut hg.grid)).collect(),
                    )
                } else {
                    (
                        grids.iter().map(|hg| &hg.grid).collect(),
                        bufs.iter_mut().map(|b| ParGrid3::new(&mut **b)).collect(),
                    )
                };
                let srcs = &srcs;
                let dsts = &dsts;
                rt.run(threads, tasks.len(), &|i| {
                    let task = &tasks[i];
                    let mut view =
                        dsts[task.rank].view(task.z0, task.z1, task.x0, task.x1, task.y0, task.y1);
                    engine.apply3_region(spec, srcs[task.rank], &mut view);
                });
                acc.substep_barriers += 1;
            }
        }

        // gather: the final sub-step wrote exactly the interiors
        let (gnz, gnx, gny) = current.shape();
        let mut next = Grid3::zeros(gnz, gnx, gny);
        {
            let next_pg = ParGrid3::new(&mut next);
            let next_pg = &next_pg;
            let finals: Vec<&Grid3> = if kk % 2 == 1 {
                bufs.iter().map(|b| &**b).collect()
            } else {
                grids.iter().map(|hg| &hg.grid).collect()
            };
            let finals = &finals;
            rt.run(threads, decomp.ranks(), &|rk| {
                let b = decomp.block(rk, gnz, gnx, gny);
                let tg = finals[rk];
                let (bz, bx, by) = b.dims();
                let mut view = next_pg.view(b.z0, b.z0 + bz, b.x0, b.x0 + bx, b.y0, b.y0 + by);
                for z in 0..bz {
                    for x in 0..bx {
                        let src = tg.idx(z + h, x + h, h);
                        view.copy_row_from(b.z0 + z, b.x0 + x, b.y0, &tg.as_slice()[src..src + by]);
                    }
                }
            });
        }
        let (rep, comm_s) = comm_result
            .into_inner()
            .unwrap()
            .expect("halo-exchange task must have run");
        current = next;

        // simulated accounting: one exchange amortized over kk fused
        // sweeps — only the first sub-step can hide comm behind compute
        let rank_cells = decomp.block(0, current.nz, current.nx, current.ny).cells();
        let est = roofline::predict(
            spec,
            rank_cells,
            SimEngine::MMStencil,
            SweepConfig::best(MemKind::OnPkg),
            platform,
        );
        let overlap = match backend {
            Backend::Sdma(_) => Overlap::Concurrent,
            Backend::Mpi(_) => Overlap::Serialized,
        };
        let layers = 8usize;
        let (compute_l, comm_l) = pipeline::equal_layers(est.time_s, rep.sim_time_s, layers);
        let (no_overlap, pipelined) = pipeline::step_time(&compute_l, &comm_l, overlap);
        let tail = est.time_s * (kk as f64 - 1.0);

        acc.real_s += t.secs();
        acc.real_comm_s += comm_s;
        acc.sim_compute_s += est.time_s * kk as f64;
        acc.sim_comm_s += rep.sim_time_s;
        acc.sim_step_s += no_overlap + tail;
        acc.sim_step_pipelined_s += pipelined + tail;
        acc.exchanged_bytes += rep.bytes;
        acc.comm_rounds += 1;
        done += kk;
    }
    let n = steps.max(1) as f64;
    acc.real_s /= n;
    acc.real_comm_s /= n;
    acc.sim_compute_s /= n;
    acc.sim_comm_s /= n;
    acc.sim_step_s /= n;
    acc.sim_step_pipelined_s /= n;
    acc.pool = pool_delta(rt, &before, run_timer.secs());
    (current, acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::{naive, EngineKind};
    use crate::util::prop::assert_allclose;

    #[test]
    fn parallel_sweep_matches_naive() {
        let spec = StencilSpec::star3d(4);
        let g = Grid3::random(12, 32, 48, 5);
        let want = naive::apply3(&spec, &g);
        let p = Platform::paper();
        for threads in [1, 2, 4] {
            for strat in [Strategy::Square, Strategy::SnoopAware] {
                let (got, stats) = sweep(&spec, &g, threads, strat, &p);
                assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
                assert!(stats.gcells_per_s > 0.0);
            }
        }
    }

    #[test]
    fn every_engine_sweeps_through_the_coordinator() {
        // the tile plan + claims are engine-agnostic: each kind's
        // region kernel must reproduce the naive oracle under tiling
        let spec = StencilSpec::star3d(2);
        let g = Grid3::random(10, 28, 36, 15);
        let want = naive::apply3(&spec, &g);
        let p = Platform::paper();
        for kind in EngineKind::ALL {
            let eng = Engine::new(kind);
            let (got, stats) = sweep_with(&spec, &g, 4, Strategy::SnoopAware, &p, &eng);
            assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
            assert!(stats.gcells_per_s > 0.0, "{kind:?}");
        }
    }

    #[test]
    fn driver_engine_is_configurable() {
        let p = Platform::paper();
        let d = Driver::new(2, p).with_engine(Engine::new(EngineKind::MatrixUnit));
        assert_eq!(d.engine().kind, EngineKind::MatrixUnit);
        let spec = StencilSpec::star3d(1);
        let g = Grid3::random(8, 20, 20, 33);
        let want = naive::apply3(&spec, &g);
        let (got, _) = d.sweep(&spec, &g, Strategy::Square);
        assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
    }

    #[test]
    fn driver_consumes_tuned_plans() {
        // a plan carries engine + geometry + fused depth in one value,
        // whether it arrives via the builder or the config file
        let plan = TunePlan::parse("engine=matrix_gemm vl=16 vz=4 tb=2 threads=8").unwrap();
        let d = Driver::new(2, Platform::paper()).with_plan(&plan);
        assert_eq!(d.engine().kind, crate::stencil::EngineKind::MatrixGemm);
        assert_eq!(d.time_block(), 2);
        // a v7-era plan (no tile=/wf= keys) selects classic stepping
        assert_eq!(d.wavefront(), (0, 1));
        let wf_plan =
            TunePlan::parse("engine=simd vl=16 vz=4 tb=4 threads=2 tile=3 wf=2").unwrap();
        let d2 = Driver::new(2, Platform::paper()).with_plan(&wf_plan);
        assert_eq!(d2.wavefront(), (3, 2));
        // the driver's runtime is the parallelism; the engine stays serial
        assert_eq!(d.engine().threads, 1);
        let cfg = crate::config::from_text(
            "[tune]\nplan = \"engine=matrix_gemm vl=16 vz=4 tb=2 threads=8\"\n",
        )
        .unwrap();
        let d = Driver::from_config(&cfg);
        assert_eq!(d.engine().kind, crate::stencil::EngineKind::MatrixGemm);
        assert_eq!(d.time_block(), 2);
        // and the planned engine sweeps to the oracle through the tile path
        let spec = StencilSpec::star3d(1);
        let g = Grid3::random(8, 20, 20, 41);
        let want = naive::apply3(&spec, &g);
        let (got, _) = d.sweep(&spec, &g, Strategy::SnoopAware);
        assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
    }

    #[test]
    fn multirank_step_matches_single_grid_sweep() {
        let spec = StencilSpec::star3d(2);
        let g = Grid3::random(16, 16, 16, 6);
        let want = naive::apply3(&spec, &g);
        let p = Platform::paper();
        let d = CartDecomp::new(2, 2, 2);
        let (got, stats) = multirank_sweep(&spec, &g, &d, &Backend::sdma(), 1, 4, &p);
        assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
        assert!(stats.exchanged_bytes > 0);
        assert!(stats.real_comm_s >= 0.0);
    }

    #[test]
    fn multirank_multi_step_stays_consistent() {
        let spec = StencilSpec::star3d(1);
        let g = Grid3::random(12, 12, 12, 7);
        let p = Platform::paper();
        // two steps of decomposed == two steps of naive
        let mut want = g.clone();
        for _ in 0..2 {
            want = naive::apply3(&spec, &want);
        }
        let d = CartDecomp::new(1, 2, 2);
        let (got, _) = multirank_sweep(&spec, &g, &d, &Backend::sdma(), 2, 4, &p);
        assert_allclose(&got.data, &want.data, 1e-3, 1e-4);
    }

    #[test]
    fn pipelined_beats_serial_for_sdma() {
        let spec = StencilSpec::star3d(4);
        let g = Grid3::random(16, 32, 32, 8);
        let p = Platform::paper();
        let d = CartDecomp::new(1, 1, 2);
        let (_, sdma) = multirank_sweep(&spec, &g, &d, &Backend::sdma(), 1, 2, &p);
        assert!(sdma.sim_step_pipelined_s <= sdma.sim_step_s);
        let (_, mpi) = multirank_sweep(&spec, &g, &d, &Backend::mpi(), 1, 2, &p);
        // MPI gains nothing from pipelining and its comm is far slower
        assert_eq!(mpi.sim_step_pipelined_s, mpi.sim_step_s);
        assert!(mpi.sim_comm_s > sdma.sim_comm_s);
    }

    #[test]
    fn wavefront_driver_steps_are_bitwise_the_classic_fused_path() {
        // the full matrix lives in rust/tests/wavefront.rs; this pins
        // the Driver plumbing end to end (with_wavefront → fused arm)
        let spec = StencilSpec::star3d(2);
        let g = Grid3::random(20, 20, 20, 17);
        let p = Platform::paper();
        let dec = CartDecomp::new(1, 1, 2);
        let classic = Driver::new(3, p.clone()).with_time_block(2);
        let (want, ws) = classic.multirank_sweep(&spec, &g, &dec, &Backend::sdma(), 4);
        let tiled = Driver::new(3, p).with_time_block(2).with_wavefront(4, 1);
        let (got, ts) = tiled.multirank_sweep(&spec, &g, &dec, &Backend::sdma(), 4);
        assert_eq!(got.data, want.data, "wavefront tiling must be bitwise");
        assert_eq!(ts.comm_rounds, ws.comm_rounds, "tiling must not add exchanges");
    }

    #[test]
    fn halo_codec_halves_step_bytes_and_f32_stays_bitwise() {
        // wire-format contracts through the Driver plumbing; the error
        // budgets proper live in rust/tests/precision.rs
        let spec = StencilSpec::star3d(2);
        let g = Grid3::random(16, 16, 16, 23);
        let p = Platform::paper();
        let dec = CartDecomp::new(1, 2, 2);
        let classic = Driver::new(2, p.clone());
        let (want, ws) = classic.multirank_sweep(&spec, &g, &dec, &Backend::sdma(), 2);
        let explicit = Driver::new(2, p.clone()).with_halo_codec(HaloCodec::F32);
        let (got, fs) = explicit.multirank_sweep(&spec, &g, &dec, &Backend::sdma(), 2);
        assert_eq!(got.data, want.data, "F32 codec must be the bitwise classic transport");
        assert_eq!(fs.exchanged_bytes, ws.exchanged_bytes);
        let half = Driver::new(2, p).with_halo_codec(HaloCodec::Bf16);
        assert_eq!(half.halo_codec(), HaloCodec::Bf16);
        let (lossy, hs) = half.multirank_sweep(&spec, &g, &dec, &Backend::sdma(), 2);
        assert_eq!(hs.exchanged_bytes * 2, ws.exchanged_bytes, "bf16 wire must be half of f32");
        assert_allclose(&lossy.data, &want.data, 5e-2, 5e-2);
        // plans carry the codec as their optional 8th key
        let plan =
            TunePlan::parse("engine=simd vl=16 vz=4 tb=1 threads=2 tile=0 wf=1 halo=f16").unwrap();
        let d = Driver::new(1, Platform::paper()).with_plan(&plan);
        assert_eq!(d.halo_codec(), HaloCodec::F16);
    }

    #[test]
    fn driver_owns_one_worker_set_across_calls() {
        let p = Platform::paper();
        let d = Driver::new(3, p.clone());
        let spawned = d.runtime().spawn_count();
        assert_eq!(spawned, 3);
        let spec = StencilSpec::star3d(2);
        let g = Grid3::random(10, 24, 24, 9);
        let want = naive::apply3(&spec, &g);
        for _ in 0..5 {
            let (got, stats) = d.sweep(&spec, &g, Strategy::SnoopAware);
            assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
            assert_eq!(stats.pool.workers, 3);
        }
        let dec = CartDecomp::new(1, 2, 1);
        for _ in 0..3 {
            let (got, _) = d.multirank_sweep(&spec, &g, &dec, &Backend::sdma(), 1);
            assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
        }
        assert_eq!(d.runtime().spawn_count(), spawned, "Driver must never respawn workers");
    }
}
