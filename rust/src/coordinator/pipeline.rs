//! Pipeline overlapping scheme (paper §IV-F, Fig. 9).
//!
//! The grid is partitioned into layers along z; while layer `k` computes,
//! the SDMA engine exchanges the halos layer `k+1` needs.  Before moving
//! on, completion of the earlier SDMA task is checked.  MPI cannot
//! overlap this way (its progress engine occupies a core).
//!
//! The real overlapped step in `coordinator::driver` realizes this
//! scheme with the `grid::par` view model: the prefetching comm task
//! writes halo frames through exclusive `TileViewMut` claims while the
//! compute layers read the same storage through shared cell views, so
//! the concurrency here never materializes aliased `&mut` references.

/// Communication overlap semantics of a transport.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Overlap {
    /// transfers proceed concurrently with compute (SDMA)
    Concurrent,
    /// transfers serialize with compute (MPI progress engine)
    Serialized,
}

/// Simulated schedule for one timestep over `layers` z-layers.
///
/// * `compute_s[k]` — compute time of layer k
/// * `comm_s[k]`    — halo-exchange time for layer k's dependencies
///
/// Returns total step time under three schemes:
/// `(no_overlap, pipelined)` where `no_overlap` = all comm up front, then
/// all compute, and `pipelined` = Fig. 9 (comm for k+1 behind compute k).
pub fn step_time(compute_s: &[f64], comm_s: &[f64], overlap: Overlap) -> (f64, f64) {
    assert_eq!(compute_s.len(), comm_s.len());
    let total_compute: f64 = compute_s.iter().sum();
    let total_comm: f64 = comm_s.iter().sum();
    let no_overlap = total_compute + total_comm;
    let pipelined = match overlap {
        Overlap::Serialized => no_overlap, // MPI cannot hide anything
        Overlap::Concurrent => {
            // comm for layer 0 is exposed; afterwards layer k's compute
            // hides layer k+1's comm
            let mut t = comm_s[0];
            for k in 0..compute_s.len() {
                let next_comm = if k + 1 < comm_s.len() { comm_s[k + 1] } else { 0.0 };
                t += compute_s[k].max(next_comm);
            }
            t
        }
    };
    (no_overlap, pipelined)
}

/// Split a per-step workload into `layers` equal layers.
pub fn equal_layers(
    total_compute_s: f64,
    total_comm_s: f64,
    layers: usize,
) -> (Vec<f64>, Vec<f64>) {
    (
        vec![total_compute_s / layers as f64; layers],
        vec![total_comm_s / layers as f64; layers],
    )
}

/// A real (host-threaded) overlapped executor: runs `compute(k)` for each
/// layer while prefetching layer k+1 with `comm(k+1)` as a task on the
/// persistent worker runtime (no per-layer thread spawn).  Returns wall
/// seconds.  Used by the end-to-end driver to demonstrate actual
/// overlap, not just the model.
pub fn run_overlapped(
    layers: usize,
    compute: impl Fn(usize) + Sync,
    comm: impl Fn(usize) + Sync,
) -> f64 {
    let t = crate::util::Timer::start();
    if layers == 0 {
        return 0.0;
    }
    let rt = super::runtime::global();
    comm(0);
    for k in 0..layers {
        if k + 1 < layers {
            let next_comm = |_: usize| comm(k + 1);
            // SAFETY: the handle is waited before `next_comm` (and the
            // borrows it captures) leave this scope
            let handle = unsafe { rt.submit_scoped(1, &next_comm) };
            compute(k);
            handle.wait();
        } else {
            compute(k);
        }
    }
    t.secs()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn concurrent_overlap_hides_comm() {
        let (compute, comm) = equal_layers(8.0, 4.0, 8);
        let (no, pipe) = step_time(&compute, &comm, Overlap::Concurrent);
        assert_eq!(no, 12.0);
        // comm per layer (0.5) < compute per layer (1.0): only layer 0's
        // comm is exposed → 8.0 + 0.5
        assert!((pipe - 8.5).abs() < 1e-9, "pipe {pipe}");
    }

    #[test]
    fn serialized_gains_nothing() {
        let (compute, comm) = equal_layers(8.0, 4.0, 8);
        let (no, pipe) = step_time(&compute, &comm, Overlap::Serialized);
        assert_eq!(no, pipe);
    }

    #[test]
    fn comm_bound_pipeline_limited_by_comm() {
        let (compute, comm) = equal_layers(2.0, 8.0, 4);
        let (_, pipe) = step_time(&compute, &comm, Overlap::Concurrent);
        // comm dominates: t = comm[0] + 3×max(0.5, 2.0) + last compute
        assert!(pipe >= 8.0, "pipe {pipe}");
        assert!(pipe < 10.0);
    }

    #[test]
    fn more_layers_hide_more() {
        let few = {
            let (c, m) = equal_layers(8.0, 4.0, 2);
            step_time(&c, &m, Overlap::Concurrent).1
        };
        let many = {
            let (c, m) = equal_layers(8.0, 4.0, 16);
            step_time(&c, &m, Overlap::Concurrent).1
        };
        assert!(many <= few);
    }

    #[test]
    fn real_overlap_runs_all_layers() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let computed = AtomicUsize::new(0);
        let comms = AtomicUsize::new(0);
        run_overlapped(
            6,
            |_| {
                computed.fetch_add(1, Ordering::Relaxed);
            },
            |_| {
                comms.fetch_add(1, Ordering::Relaxed);
            },
        );
        assert_eq!(computed.load(Ordering::Relaxed), 6);
        assert_eq!(comms.load(Ordering::Relaxed), 6);
    }

    #[test]
    fn real_overlap_is_faster_than_serial_for_sleepy_tasks() {
        use std::time::Duration;
        let work = Duration::from_millis(4);
        let wall = run_overlapped(
            4,
            |_| std::thread::sleep(work),
            |_| std::thread::sleep(work),
        );
        // serial would be 8 layers × 4 ms = 32 ms; overlapped ≈ 20 ms
        assert!(wall < 0.030, "wall {wall}");
    }
}
