//! Data-parallel helpers for tile tasks, backed by the **persistent**
//! worker runtime ([`super::runtime`]).
//!
//! The seed implementation spawned a fresh crossbeam scope (and OS
//! threads) on every call and claimed indices from one shared
//! `AtomicUsize`; these wrappers keep the exact call signatures but
//! dispatch onto the process-global pool — workers are spawned once per
//! process, chunks land on per-worker injector queues with contiguous
//! (adjacency-preserving) assignment, and ragged tails are work-stolen.

use super::runtime;
use crate::grid::par::ParSlice;

/// Run `task(i)` for every index in `0..n` across the persistent pool.
/// `threads` is the parallelism hint (chunk granularity); `threads <= 1`
/// runs inline on the caller.
pub fn parallel_for(threads: usize, n: usize, task: impl Fn(usize) + Sync) {
    if threads <= 1 || n <= 1 {
        for i in 0..n {
            task(i);
        }
        return;
    }
    // FTZ/DAZ policy: pool workers set it at spawn; the submitting
    // thread sets it when it helps (runtime::Runtime::join_job)
    runtime::global().run(threads, n, &task);
}

/// Run `task(chunk_index, lo, hi)` over `0..n` split into `chunks`
/// contiguous ranges — the static assignment used by the snoop-aware
/// schedule (adjacency requires deterministic placement).
pub fn parallel_chunks(
    threads: usize,
    n: usize,
    chunks: usize,
    task: impl Fn(usize, usize, usize) + Sync,
) {
    let base = n / chunks;
    let rem = n % chunks;
    let bounds: Vec<(usize, usize)> = (0..chunks)
        .scan(0usize, |lo, i| {
            let len = base + usize::from(i < rem);
            let out = (*lo, *lo + len);
            *lo += len;
            Some(out)
        })
        .collect();
    parallel_for(threads, chunks, |i| {
        let (lo, hi) = bounds[i];
        task(i, lo, hi);
    });
}

/// Apply `f(offset, chunk)` over disjoint contiguous chunks of `data`
/// in parallel.  Writes go through [`ParSlice`] claims, so the chunk
/// disjointness is alias-model-clean and debug-checked (replaces the
/// seed's raw-pointer chunk writers in the RTM propagators).
pub fn parallel_mut_chunks(
    threads: usize,
    data: &mut [f32],
    f: impl Fn(usize, &mut [f32]) + Sync,
) {
    let n = data.len();
    if n == 0 {
        return;
    }
    let ps = ParSlice::new(data);
    let ps = &ps;
    parallel_chunks(threads, n, (threads.max(1) * 4).min(n), |_, lo, hi| {
        let mut claim = ps.claim(lo, hi);
        f(lo, claim.as_mut_slice());
    });
}

/// Lockstep variant of [`parallel_mut_chunks`] over two equal-length
/// slices: `f(offset, chunk_a, chunk_b)` gets the same range of both
/// (e.g. the TTI H1/H2 operator pair written in one pass).
pub fn parallel_mut_chunks2(
    threads: usize,
    a: &mut [f32],
    b: &mut [f32],
    f: impl Fn(usize, &mut [f32], &mut [f32]) + Sync,
) {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    if n == 0 {
        return;
    }
    let pa = ParSlice::new(a);
    let pb = ParSlice::new(b);
    let (pa, pb) = (&pa, &pb);
    parallel_chunks(threads, n, (threads.max(1) * 4).min(n), |_, lo, hi| {
        let mut ca = pa.claim(lo, hi);
        let mut cb = pb.claim(lo, hi);
        f(lo, ca.as_mut_slice(), cb.as_mut_slice());
    });
}

/// Map over indices in parallel collecting results (order preserved).
pub fn parallel_map<T: Send>(threads: usize, n: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let slots: Vec<std::sync::Mutex<&mut Option<T>>> =
            out.iter_mut().map(std::sync::Mutex::new).collect();
        parallel_for(threads, n, |i| {
            **slots[i].lock().unwrap() = Some(f(i));
        });
    }
    out.into_iter().map(|v| v.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn covers_every_index_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        parallel_for(8, 1000, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn single_thread_fallback() {
        let sum = AtomicU64::new(0);
        parallel_for(1, 10, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 45);
    }

    #[test]
    fn chunks_partition_range() {
        let seen = std::sync::Mutex::new(vec![0u8; 103]);
        parallel_chunks(4, 103, 7, |_, lo, hi| {
            let mut s = seen.lock().unwrap();
            for i in lo..hi {
                s[i] += 1;
            }
        });
        assert!(seen.lock().unwrap().iter().all(|&c| c == 1));
    }

    #[test]
    fn map_preserves_order() {
        let v = parallel_map(4, 64, |i| i * i);
        assert_eq!(v[10], 100);
        assert_eq!(v.len(), 64);
    }

    #[test]
    fn parallel_sum_matches_serial() {
        let data: Vec<f64> = (0..10_000).map(|i| (i as f64).sin()).collect();
        let serial: f64 = data.iter().sum();
        let partials = parallel_map(8, 8, |c| {
            let lo = c * 1250;
            data[lo..lo + 1250].iter().sum::<f64>()
        });
        let par: f64 = partials.iter().sum();
        assert!((serial - par).abs() < 1e-9);
    }

    #[test]
    fn mut_chunks_cover_every_element_once() {
        let mut v = vec![0.0f32; 1003];
        parallel_mut_chunks(4, &mut v, |off, chunk| {
            for (i, x) in chunk.iter_mut().enumerate() {
                *x += (off + i) as f32;
            }
        });
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i as f32);
        }
    }

    #[test]
    fn mut_chunks2_walk_in_lockstep() {
        let mut a = vec![0.0f32; 257];
        let mut b = vec![0.0f32; 257];
        parallel_mut_chunks2(4, &mut a, &mut b, |off, ca, cb| {
            assert_eq!(ca.len(), cb.len());
            for i in 0..ca.len() {
                ca[i] = (off + i) as f32;
                cb[i] = -(ca[i]);
            }
        });
        for i in 0..257 {
            assert_eq!(a[i], i as f32);
            assert_eq!(b[i], -(i as f32));
        }
    }

    #[test]
    fn repeated_calls_reuse_the_global_pool() {
        let rt = runtime::global();
        let spawned = rt.spawn_count();
        for _ in 0..20 {
            parallel_for(4, 128, |_| {});
        }
        assert_eq!(rt.spawn_count(), spawned, "parallel_for must never respawn workers");
    }
}
