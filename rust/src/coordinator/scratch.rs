//! Worker-local scratch arenas: reusable, grow-only `f32` buffers for
//! the engine hot paths (PR 3 tentpole).
//!
//! The seed engines heap-allocated a fresh halo-window `Vec` per block
//! (`GridSrc::extract_wrap`), a fresh `tmp` buffer per star block, and
//! fresh pack/unpack staging per halo face — exactly the redundant
//! allocation traffic §IV-C/§IV-D of the paper optimize away.  This
//! module replaces all of it with per-thread buffer pools:
//!
//! * **Worker-local** — the pool is a `thread_local!`, so each
//!   persistent runtime worker ([`super::runtime`]) keeps its own arena
//!   for its whole life; helping submitter threads get their own.  No
//!   locks, no cross-thread sharing, no false sharing.
//! * **Grow-only** — buffers are never shrunk or freed while the thread
//!   lives; a checkout reuses the largest free buffer and grows it only
//!   if the request exceeds its capacity.  After one warm-up sweep the
//!   steady state performs **zero heap allocations per block**.
//! * **Borrowed per task** — checkouts are scoped ([`with`] hands the
//!   buffer to a closure and reclaims it on return), so a buffer can
//!   never leak across tasks or outlive its checkout.  Nested checkouts
//!   (window + tmp in one block) pop distinct buffers.
//!
//! [`grow_events`] is the allocation-counting hook the regression tests
//! and `examples/perf_probe.rs` assert on: it counts every real heap
//! growth the arenas perform, so "allocation-free after warm-up" is a
//! testable property, not a claim.
//!
//! Ownership rules (DESIGN.md §9): buffers belong to the thread, never
//! to a task; contents are unspecified on checkout; no reference to a
//! buffer may escape the checkout closure.

use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicU64, Ordering};

/// Process-wide count of arena heap-growth events (a checkout that had
/// to allocate a new buffer or enlarge an existing one).  Steady-state
/// sweeps must not bump this — the allocation-counting perf hook
/// (`examples/perf_probe.rs` records deltas across timed sweeps).
static GROW_EVENTS: AtomicU64 = AtomicU64::new(0);

/// Cumulative arena heap-growth events since process start, summed over
/// **all** threads.  For deterministic single-thread assertions (unit
/// tests that may run concurrently with other arena users) use
/// [`local_grow_events`] instead.
pub fn grow_events() -> u64 {
    GROW_EVENTS.load(Ordering::Relaxed)
}

/// Arena heap-growth events performed by the **calling thread** only —
/// immune to concurrent test threads bumping the global counter.
pub fn local_grow_events() -> u64 {
    LOCAL_GROWS.with(|c| c.get())
}

thread_local! {
    /// Free buffers of this thread's arena (small: at most the maximum
    /// checkout nesting depth the engines use).
    static FREE: RefCell<Vec<Vec<f32>>> = const { RefCell::new(Vec::new()) };
    static LOCAL_GROWS: Cell<u64> = const { Cell::new(0) };
}

/// Check out a buffer with capacity ≥ `len`, growing only if needed.
fn take(len: usize) -> Vec<f32> {
    let mut buf = FREE.with(|f| {
        let mut free = f.borrow_mut();
        // reuse the largest free buffer: grow-only reuse converges on a
        // small set of buffers sized for the biggest blocks seen
        let best = free
            .iter()
            .enumerate()
            .max_by_key(|(_, b)| b.capacity())
            .map(|(i, _)| i);
        match best {
            Some(i) => free.swap_remove(i),
            None => Vec::new(),
        }
    });
    if buf.capacity() < len {
        GROW_EVENTS.fetch_add(1, Ordering::Relaxed);
        LOCAL_GROWS.with(|c| c.set(c.get() + 1));
        buf.reserve_exact(len - buf.len());
    }
    // keep the logical length pinned at full capacity: the fill runs
    // once per grow, so a warm checkout is O(1) — no re-memset when a
    // smaller request truncated the length on an earlier checkout
    if buf.len() < buf.capacity() {
        let cap = buf.capacity();
        buf.resize(cap, 0.0);
    }
    buf
}

/// Return a buffer to this thread's pool (capacity retained).
fn give(buf: Vec<f32>) {
    FREE.with(|f| f.borrow_mut().push(buf));
}

/// Run `f` with a borrowed `len`-element scratch buffer.  Contents are
/// **unspecified** (stale data from earlier checkouts) — callers must
/// fully overwrite what they read (every engine consumer writes before
/// reading, or `fill`s explicitly).
pub fn with<R>(len: usize, f: impl FnOnce(&mut [f32]) -> R) -> R {
    let mut buf = take(len);
    let r = f(&mut buf[..len]);
    give(buf);
    r
}

/// An arena-backed whole-grid checkout: a [`Grid3`] whose storage came
/// from this thread's pool and **returns to it on drop** — the RAII
/// form of [`with`] for callers that need several buffers alive at once
/// (the temporal-blocking driver checks one double-buffer grid per rank
/// out per fused round).  Same rules as [`with`]: contents are
/// unspecified on checkout, the buffer belongs to the checking-out
/// thread, and it must be dropped on that thread.
///
/// [`Grid3`]: crate::grid::Grid3
pub struct GridCheckout {
    g: Option<crate::grid::Grid3>,
}

impl std::ops::Deref for GridCheckout {
    type Target = crate::grid::Grid3;

    fn deref(&self) -> &crate::grid::Grid3 {
        self.g.as_ref().expect("GridCheckout accessed after drop")
    }
}

impl std::ops::DerefMut for GridCheckout {
    fn deref_mut(&mut self) -> &mut crate::grid::Grid3 {
        self.g.as_mut().expect("GridCheckout accessed after drop")
    }
}

impl Drop for GridCheckout {
    fn drop(&mut self) {
        if let Some(mut g) = self.g.take() {
            // restore take()'s len == capacity invariant before the
            // buffer re-enters the pool: grid() truncated the length, and
            // a short buffer would make the *next* checkout re-memset the
            // tail inside its (possibly hot) path — pay it here instead,
            // once per grid checkout, outside the engine loops
            let cap = g.data.capacity();
            g.data.resize(cap, 0.0);
            give(g.data);
        }
    }
}

/// Check a `(nz, nx, ny)` grid out of this thread's arena.  Contents
/// are **unspecified** — the caller must overwrite every cell it later
/// reads (the fused sub-step kernels overwrite their whole claimed box
/// before any read; cells outside the final box are never read).
pub fn grid(nz: usize, nx: usize, ny: usize) -> GridCheckout {
    let len = nz * nx * ny;
    let mut data = take(len);
    data.truncate(len);
    GridCheckout { g: Some(crate::grid::Grid3 { nz, nx, ny, data }) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffers_have_requested_length() {
        with(17, |b| assert_eq!(b.len(), 17));
        with(5, |b| assert_eq!(b.len(), 5));
    }

    #[test]
    fn nested_checkouts_are_distinct() {
        with(8, |a| {
            a.fill(1.0);
            with(8, |b| {
                b.fill(2.0);
                assert!(a.iter().all(|&v| v == 1.0));
            });
            assert!(a.iter().all(|&v| v == 1.0));
        });
    }

    #[test]
    fn warm_checkouts_do_not_grow() {
        // warm this thread's arena for the sizes used below
        with(1024, |_| {});
        with(1024, |a| with(256, |b| (a.len(), b.len())));
        let before = local_grow_events();
        for _ in 0..50 {
            with(1024, |a| {
                a[0] = 1.0;
                with(256, |b| b[0] = 2.0);
            });
            with(64, |_| {}); // smaller request reuses a big buffer
        }
        assert_eq!(local_grow_events(), before, "warm arena must not reallocate");
    }

    #[test]
    fn growth_is_counted() {
        // a fresh thread has an empty arena: the first checkout grows
        let handle = std::thread::spawn(|| {
            let before = local_grow_events();
            with(32, |_| {});
            local_grow_events() - before
        });
        assert_eq!(handle.join().unwrap(), 1);
    }

    #[test]
    fn grid_checkouts_are_distinct_and_return_capacity() {
        {
            let mut a = grid(2, 3, 4);
            assert_eq!(a.shape(), (2, 3, 4));
            assert_eq!(a.data.len(), 24);
            a.data.fill(1.0);
            let mut b = grid(2, 3, 4);
            b.data.fill(2.0);
            assert!(a.data.iter().all(|&v| v == 1.0), "checkouts must not alias");
        }
        // both storages are back in the pool: warm re-checkout of the
        // same shapes must not grow
        let before = local_grow_events();
        let _a = grid(2, 3, 4);
        let _b = grid(2, 3, 4);
        assert_eq!(local_grow_events(), before, "warm grid checkout grew the arena");
    }

    #[test]
    fn grid_checkout_interoperates_with_with() {
        // a grid checkout and a slice checkout nested on one thread pop
        // distinct buffers
        let mut g = grid(4, 4, 4);
        g.data.fill(3.0);
        with(64, |b| {
            b.fill(4.0);
            assert!(g.data.iter().all(|&v| v == 3.0));
        });
        assert!(g.data.iter().all(|&v| v == 3.0));
    }

    #[test]
    fn returned_values_pass_through() {
        let v = with(4, |b| {
            b[3] = 7.0;
            b[3]
        });
        assert_eq!(v, 7.0);
    }
}
