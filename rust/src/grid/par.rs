//! Alias-model-clean shared grid views for disjoint-region parallel
//! writes (the PR 2 tentpole; see DESIGN.md §8).
//!
//! The paper's multi-thread paradigm (§IV-E) hands every core an
//! exclusive tile of the output grid.  The seed reproduced that with a
//! shared-raw-pointer idiom: each task re-materialized `&mut Grid3`
//! from a `*mut` and wrote its tile.  Data-race-free — the tiles
//! are disjoint — but a violation of Rust's aliasing model: the moment
//! two tasks hold `&mut` to the same allocation, the provenance of one
//! of them is dead, and Miri's stacked-borrows checker rejects the whole
//! sweep.
//!
//! This module makes the disjointness a *typed* invariant instead:
//!
//! * [`ParGrid3`] converts one `&mut Grid3` into a shared slab of
//!   [`GridCell`]s (`UnsafeCell<f32>`).  No `&mut` to the storage exists
//!   afterwards; every write goes through a cell pointer, which the
//!   aliasing model permits to alias.
//! * [`TileViewMut`] is an exclusive *claim* on one
//!   `(z0..z1, x0..x1, y0..y1)` box, handed to exactly one task.  Debug
//!   builds keep a ledger of live claims and panic on overlap — the
//!   dynamic counterpart of the static `TilePlan::validate` proof the
//!   tile planners run.
//! * [`GridSrc`] abstracts the read side so the stencil engines accept
//!   either a quiescent `&Grid3` or a `ParGrid3` whose *other* cells are
//!   being written concurrently (the overlapped halo-exchange step).
//! * [`ParSlice`]/[`SliceClaim`] are the 1-D flavour backing
//!   `coordinator::pool::parallel_mut_chunks`.
//!
//! With every parallel write path routed through these types, the CI
//! `miri` job can run the real sweeps (`rust/tests/aliasing.rs`) under
//! stacked borrows.

use std::cell::UnsafeCell;
#[cfg(debug_assertions)]
use std::sync::Mutex;

use super::Grid3;

/// One f32 storage slot writable through a shared reference.
#[repr(transparent)]
pub struct GridCell(UnsafeCell<f32>);

// SAFETY: all mutation funnels through `UnsafeCell` pointers handed out
// by exclusive claims (`TileViewMut` / `SliceClaim`), whose disjointness
// the planners guarantee statically (`TilePlan::validate`) and debug
// builds re-check dynamically; concurrent access to *distinct* cells is
// exactly what `UnsafeCell` exists to permit.
unsafe impl Sync for GridCell {}

/// Live exclusive claims of one `ParGrid3`/`ParSlice` (debug builds
/// only): boxes as `[z0, z1, x0, x1, y0, y1]`.
#[cfg(debug_assertions)]
#[derive(Default)]
struct Ledger {
    next: u64,
    live: Vec<(u64, [usize; 6])>,
}

#[cfg(debug_assertions)]
fn boxes_overlap(a: &[usize; 6], b: &[usize; 6]) -> bool {
    a[0] < b[1] && b[0] < a[1] && a[2] < b[3] && b[2] < a[3] && a[4] < b[5] && b[4] < a[5]
}

/// Poison-tolerant lock: a claim-overlap panic must not abort the
/// process when an unwinding view releases its claim afterwards.
#[cfg(debug_assertions)]
fn lock(m: &Mutex<Ledger>) -> std::sync::MutexGuard<'_, Ledger> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

#[cfg(debug_assertions)]
fn claim_box(claims: &Mutex<Ledger>, what: &str, b: [usize; 6]) -> u64 {
    let mut led = lock(claims);
    for (_, other) in &led.live {
        assert!(
            !boxes_overlap(&b, other),
            "overlapping {what}: requested {b:?} intersects live exclusive claim {other:?}"
        );
    }
    led.next += 1;
    let id = led.next;
    led.live.push((id, b));
    id
}

#[cfg(debug_assertions)]
fn release_box(claims: &Mutex<Ledger>, id: u64) {
    let mut led = lock(claims);
    if let Some(i) = led.live.iter().position(|(c, _)| *c == id) {
        led.live.swap_remove(i);
    }
}

/// A `Grid3` opened for disjoint-region parallel access: shared reads
/// anywhere, writes only through claimed [`TileViewMut`]s.
///
/// Constructed from the one `&mut Grid3` — the unique borrow is traded
/// for cell-level shared access for the view's lifetime, so no `&mut`
/// aliases can exist while tasks run.
pub struct ParGrid3<'g> {
    nz: usize,
    nx: usize,
    ny: usize,
    cells: &'g [GridCell],
    #[cfg(debug_assertions)]
    claims: Mutex<Ledger>,
}

impl<'g> ParGrid3<'g> {
    pub fn new(g: &'g mut Grid3) -> Self {
        let (nz, nx, ny) = g.shape();
        let data: &'g mut [f32] = &mut g.data;
        // SAFETY: `GridCell` is `repr(transparent)` over `UnsafeCell<f32>`,
        // which has the layout of `f32`; the unique borrow we consume
        // here is the only access path until this `ParGrid3` drops.
        let cells: &'g [GridCell] = unsafe { &*(data as *mut [f32] as *const [GridCell]) };
        Self {
            nz,
            nx,
            ny,
            cells,
            #[cfg(debug_assertions)]
            claims: Mutex::new(Ledger::default()),
        }
    }

    pub fn nz(&self) -> usize {
        self.nz
    }

    pub fn nx(&self) -> usize {
        self.nx
    }

    pub fn ny(&self) -> usize {
        self.ny
    }

    pub fn shape(&self) -> (usize, usize, usize) {
        (self.nz, self.nx, self.ny)
    }

    pub fn len(&self) -> usize {
        self.cells.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    #[inline(always)]
    fn index(&self, z: usize, x: usize, y: usize) -> usize {
        debug_assert!(z < self.nz && x < self.nx && y < self.ny);
        (z * self.nx + x) * self.ny + y
    }

    /// Miri lane only: logical read-vs-claim checking.  The write side
    /// is always ledger-checked in debug builds; reads are checked only
    /// under Miri (where grids are tiny) so native debug hot loops stay
    /// cheap, yet the aliasing suite deterministically catches a read
    /// that overlaps a live exclusive claim even when the scheduler
    /// never interleaves the racing accesses.
    #[cfg(all(miri, debug_assertions))]
    fn check_read(&self, start: usize, len: usize) {
        let led = lock(&self.claims);
        let plane = self.nx * self.ny;
        for i in start..start + len {
            let (z, rem) = (i / plane, i % plane);
            let (x, y) = (rem / self.ny, rem % self.ny);
            for (_, b) in &led.live {
                assert!(
                    !(b[0] <= z && z < b[1] && b[2] <= x && x < b[3] && b[4] <= y && y < b[5]),
                    "shared read of ({z}, {x}, {y}) intersects live exclusive claim {b:?}"
                );
            }
        }
    }

    /// Shared read of one cell.  Orchestration invariant (ledger-checked
    /// for writes in debug builds, for reads under Miri): the cell is
    /// not concurrently written through a live claim.
    #[inline(always)]
    pub fn get(&self, z: usize, x: usize, y: usize) -> f32 {
        let i = self.index(z, x, y);
        #[cfg(all(miri, debug_assertions))]
        self.check_read(i, 1);
        // SAFETY: reading through the cell pointer; disjointness from
        // concurrent claimed writes is the caller's schedule invariant.
        unsafe { *self.cells[i].0.get() }
    }

    /// Shared read of `len` contiguous values from linear index `start`.
    /// The span must not intersect a region a live claim is writing.
    #[inline]
    pub fn span(&self, start: usize, len: usize) -> &[f32] {
        #[cfg(all(miri, debug_assertions))]
        self.check_read(start, len);
        let cells = &self.cells[start..start + len];
        // SAFETY: the span is quiescent for the reference's lifetime
        // (schedule invariant above); layout matches `[f32]`.
        unsafe { std::slice::from_raw_parts(cells.as_ptr() as *const f32, len) }
    }

    /// Claim the box `[z0,z1)×[x0,x1)×[y0,y1)` for exclusive writing.
    ///
    /// Debug builds panic if the box overlaps any live claim of this
    /// grid; the claim is released when the view drops.
    pub fn view(
        &self,
        z0: usize,
        z1: usize,
        x0: usize,
        x1: usize,
        y0: usize,
        y1: usize,
    ) -> TileViewMut<'_> {
        assert!(
            z0 <= z1 && z1 <= self.nz && x0 <= x1 && x1 <= self.nx && y0 <= y1 && y1 <= self.ny,
            "view out of bounds: ({z0}..{z1}, {x0}..{x1}, {y0}..{y1}) on {:?}",
            self.shape()
        );
        #[cfg(debug_assertions)]
        let claim = claim_box(&self.claims, "TileViewMut", [z0, z1, x0, x1, y0, y1]);
        TileViewMut {
            cells: self.cells,
            nz: self.nz,
            nx: self.nx,
            ny: self.ny,
            z0,
            z1,
            x0,
            x1,
            y0,
            y1,
            #[cfg(debug_assertions)]
            ledger: &self.claims,
            #[cfg(debug_assertions)]
            claim,
        }
    }

    /// Claim the whole grid as one view (serial engines).
    pub fn full_view(&self) -> TileViewMut<'_> {
        self.view(0, self.nz, 0, self.nx, 0, self.ny)
    }
}

/// Exclusive write view of one disjoint `(z, x, y)` box of a
/// [`ParGrid3`].  All coordinates are *absolute* grid coordinates — a
/// task computes and writes at the same indices the serial engines use.
pub struct TileViewMut<'a> {
    cells: &'a [GridCell],
    nz: usize,
    nx: usize,
    ny: usize,
    z0: usize,
    z1: usize,
    x0: usize,
    x1: usize,
    y0: usize,
    y1: usize,
    #[cfg(debug_assertions)]
    ledger: &'a Mutex<Ledger>,
    #[cfg(debug_assertions)]
    claim: u64,
}

#[cfg(debug_assertions)]
impl Drop for TileViewMut<'_> {
    fn drop(&mut self) {
        release_box(self.ledger, self.claim);
    }
}

impl TileViewMut<'_> {
    /// The claimed box as `(z0, z1, x0, x1, y0, y1)`.
    pub fn bounds(&self) -> (usize, usize, usize, usize, usize, usize) {
        (self.z0, self.z1, self.x0, self.x1, self.y0, self.y1)
    }

    /// Shape of the *backing grid* (not of the box).
    pub fn grid_shape(&self) -> (usize, usize, usize) {
        (self.nz, self.nx, self.ny)
    }

    #[inline(always)]
    fn index(&self, z: usize, x: usize, y: usize) -> usize {
        (z * self.nx + x) * self.ny + y
    }

    #[inline(always)]
    fn debug_check_row(&self, z: usize, x: usize, y: usize, len: usize) {
        debug_assert!(
            self.z0 <= z
                && z < self.z1
                && self.x0 <= x
                && x < self.x1
                && self.y0 <= y
                && y + len <= self.y1,
            "write outside claimed box: ({z}, {x}, {y}..{}) not in ({}..{}, {}..{}, {}..{})",
            y + len,
            self.z0,
            self.z1,
            self.x0,
            self.x1,
            self.y0,
            self.y1
        );
    }

    /// Write one cell of the claimed box.
    #[inline(always)]
    pub fn set(&mut self, z: usize, x: usize, y: usize, v: f32) {
        self.debug_check_row(z, x, y, 1);
        // SAFETY: the claim makes this view the only writer of the cell.
        unsafe { *self.cells[self.index(z, x, y)].0.get() = v }
    }

    /// Exclusive `[y, y+len)` row segment of `(z, x)` — the contiguous
    /// unit the vectorized engines accumulate into.
    #[inline]
    pub fn row_mut(&mut self, z: usize, x: usize, y: usize, len: usize) -> &mut [f32] {
        self.debug_check_row(z, x, y, len);
        let i = self.index(z, x, y);
        let cells = &self.cells[i..i + len];
        let ptr = UnsafeCell::raw_get(cells.as_ptr() as *const UnsafeCell<f32>);
        // SAFETY: the claim covers the whole segment exclusively, so a
        // unique reference derived through the cells cannot alias any
        // other live access; layout matches `[f32]`.
        unsafe { std::slice::from_raw_parts_mut(ptr, len) }
    }

    /// Copy a packed row into the claimed box at `(z, x, y0)`.
    pub fn copy_row_from(&mut self, z: usize, x: usize, y: usize, src: &[f32]) {
        self.row_mut(z, x, y, src.len()).copy_from_slice(src);
    }

    /// Copy a packed `(z, x, y)` block into the claimed box at
    /// `(z0, x0, y0)` — the view-side mirror of `Grid3::insert_block`.
    pub fn insert_block(
        &mut self,
        z0: usize,
        x0: usize,
        y0: usize,
        bz: usize,
        bx: usize,
        by: usize,
        block: &[f32],
    ) {
        assert_eq!(block.len(), bz * bx * by);
        for dz in 0..bz {
            for dx in 0..bx {
                let src = (dz * bx + dx) * by;
                self.copy_row_from(z0 + dz, x0 + dx, y0, &block[src..src + by]);
            }
        }
    }

    /// The whole claimed box as one mutable slice.  Requires a box that
    /// is contiguous in storage, i.e. full x and y extent (z-slabs).
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        assert!(
            self.x0 == 0 && self.x1 == self.nx && self.y0 == 0 && self.y1 == self.ny,
            "as_mut_slice needs a contiguous z-slab view (full x/y extent)"
        );
        let plane = self.nx * self.ny;
        let (lo, hi) = (self.z0 * plane, self.z1 * plane);
        let cells = &self.cells[lo..hi];
        let ptr = UnsafeCell::raw_get(cells.as_ptr() as *const UnsafeCell<f32>);
        // SAFETY: as in `row_mut` — the claim covers the slab.
        unsafe { std::slice::from_raw_parts_mut(ptr, hi - lo) }
    }
}

/// Read access the stencil engines accept: either a quiescent `&Grid3`
/// or a [`ParGrid3`] whose other cells are concurrently written through
/// claims (the overlapped halo-exchange step reads interiors while the
/// comm task fills halo frames).
pub trait GridSrc: Sync {
    fn shape(&self) -> (usize, usize, usize);

    /// Shared read of `len` contiguous values from linear index `start`.
    fn span(&self, start: usize, len: usize) -> &[f32];

    fn get(&self, z: usize, x: usize, y: usize) -> f32;

    #[inline]
    fn idx(&self, z: usize, x: usize, y: usize) -> usize {
        let (_, nx, ny) = self.shape();
        (z * nx + x) * ny + y
    }

    /// Periodic (wrapped) access — matches the jnp.roll oracles.
    #[inline]
    fn get_wrap(&self, z: isize, x: isize, y: isize) -> f32 {
        let (nz, nx, ny) = self.shape();
        let z = z.rem_euclid(nz as isize) as usize;
        let x = x.rem_euclid(nx as isize) as usize;
        let y = y.rem_euclid(ny as isize) as usize;
        self.get(z, x, y)
    }

    /// Extract a sub-block with periodic wrap into a packed buffer
    /// (z, x, y order) — mirror of `Grid3::extract_wrap`.
    fn extract_wrap(
        &self,
        z0: isize,
        x0: isize,
        y0: isize,
        bz: usize,
        bx: usize,
        by: usize,
    ) -> Vec<f32> {
        let mut out = Vec::with_capacity(bz * bx * by);
        for dz in 0..bz as isize {
            for dx in 0..bx as isize {
                for dy in 0..by as isize {
                    out.push(self.get_wrap(z0 + dz, x0 + dx, y0 + dy));
                }
            }
        }
        out
    }
}

impl GridSrc for Grid3 {
    fn shape(&self) -> (usize, usize, usize) {
        Grid3::shape(self)
    }

    #[inline]
    fn span(&self, start: usize, len: usize) -> &[f32] {
        &self.data[start..start + len]
    }

    #[inline]
    fn get(&self, z: usize, x: usize, y: usize) -> f32 {
        Grid3::get(self, z, x, y)
    }

    #[inline]
    fn get_wrap(&self, z: isize, x: isize, y: isize) -> f32 {
        Grid3::get_wrap(self, z, x, y)
    }

    fn extract_wrap(
        &self,
        z0: isize,
        x0: isize,
        y0: isize,
        bz: usize,
        bx: usize,
        by: usize,
    ) -> Vec<f32> {
        Grid3::extract_wrap(self, z0, x0, y0, bz, bx, by)
    }
}

impl GridSrc for ParGrid3<'_> {
    fn shape(&self) -> (usize, usize, usize) {
        ParGrid3::shape(self)
    }

    #[inline]
    fn span(&self, start: usize, len: usize) -> &[f32] {
        ParGrid3::span(self, start, len)
    }

    #[inline]
    fn get(&self, z: usize, x: usize, y: usize) -> f32 {
        ParGrid3::get(self, z, x, y)
    }
}

/// 1-D counterpart of [`ParGrid3`]: a `&mut [f32]` opened for disjoint
/// chunk-parallel writes (backs `pool::parallel_mut_chunks`).
pub struct ParSlice<'a> {
    cells: &'a [GridCell],
    #[cfg(debug_assertions)]
    claims: Mutex<Ledger>,
}

impl<'a> ParSlice<'a> {
    pub fn new(data: &'a mut [f32]) -> Self {
        // SAFETY: as in `ParGrid3::new` — layout-compatible transparent
        // wrapper, unique borrow consumed.
        let cells: &'a [GridCell] = unsafe { &*(data as *mut [f32] as *const [GridCell]) };
        Self {
            cells,
            #[cfg(debug_assertions)]
            claims: Mutex::new(Ledger::default()),
        }
    }

    pub fn len(&self) -> usize {
        self.cells.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    /// Claim `[lo, hi)` for exclusive writing; debug builds panic on
    /// overlap with a live claim.
    pub fn claim(&self, lo: usize, hi: usize) -> SliceClaim<'_> {
        assert!(
            lo <= hi && hi <= self.cells.len(),
            "claim out of bounds: {lo}..{hi} of {}",
            self.cells.len()
        );
        #[cfg(debug_assertions)]
        let claim = claim_box(&self.claims, "ParSlice claim", [lo, hi, 0, 1, 0, 1]);
        SliceClaim {
            cells: &self.cells[lo..hi],
            offset: lo,
            #[cfg(debug_assertions)]
            ledger: &self.claims,
            #[cfg(debug_assertions)]
            claim,
        }
    }
}

/// Exclusive claim on one contiguous chunk of a [`ParSlice`].
pub struct SliceClaim<'a> {
    cells: &'a [GridCell],
    offset: usize,
    #[cfg(debug_assertions)]
    ledger: &'a Mutex<Ledger>,
    #[cfg(debug_assertions)]
    claim: u64,
}

#[cfg(debug_assertions)]
impl Drop for SliceClaim<'_> {
    fn drop(&mut self) {
        release_box(self.ledger, self.claim);
    }
}

impl SliceClaim<'_> {
    /// Start of the claimed range in the parent slice.
    pub fn offset(&self) -> usize {
        self.offset
    }

    pub fn len(&self) -> usize {
        self.cells.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        let ptr = UnsafeCell::raw_get(self.cells.as_ptr() as *const UnsafeCell<f32>);
        // SAFETY: the claim covers the chunk exclusively (see `row_mut`).
        unsafe { std::slice::from_raw_parts_mut(ptr, self.cells.len()) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn views_write_through_to_the_grid() {
        let mut g = Grid3::zeros(2, 3, 4);
        {
            let pg = ParGrid3::new(&mut g);
            let mut a = pg.view(0, 2, 0, 3, 0, 2);
            let mut b = pg.view(0, 2, 0, 3, 2, 4);
            a.set(0, 0, 0, 1.0);
            a.copy_row_from(1, 2, 0, &[2.0, 3.0]);
            b.set(1, 2, 3, 4.0);
        }
        assert_eq!(g.get(0, 0, 0), 1.0);
        assert_eq!(g.get(1, 2, 0), 2.0);
        assert_eq!(g.get(1, 2, 1), 3.0);
        assert_eq!(g.get(1, 2, 3), 4.0);
    }

    #[test]
    fn reads_see_prior_writes() {
        let mut g = Grid3::from_fn(2, 2, 2, |z, x, y| (z * 4 + x * 2 + y) as f32);
        let pg = ParGrid3::new(&mut g);
        assert_eq!(pg.get(1, 1, 1), 7.0);
        assert_eq!(pg.span(0, 4), &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(GridSrc::get_wrap(&pg, -1, 0, 0), 4.0);
    }

    #[test]
    fn slab_view_is_contiguous() {
        let mut g = Grid3::zeros(3, 2, 2);
        {
            let pg = ParGrid3::new(&mut g);
            let mut v = pg.view(1, 2, 0, 2, 0, 2);
            v.as_mut_slice().fill(5.0);
        }
        assert!(g.as_slice()[4..8].iter().all(|&v| v == 5.0));
        assert!(g.as_slice()[0..4].iter().all(|&v| v == 0.0));
        assert!(g.as_slice()[8..12].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn par_slice_chunks_write_disjointly() {
        let mut v = vec![0.0f32; 10];
        {
            let ps = ParSlice::new(&mut v);
            let mut a = ps.claim(0, 5);
            let mut b = ps.claim(5, 10);
            a.as_mut_slice().fill(1.0);
            b.as_mut_slice().fill(2.0);
            assert_eq!(a.offset(), 0);
            assert_eq!(b.offset(), 5);
        }
        assert_eq!(&v[..5], &[1.0; 5]);
        assert_eq!(&v[5..], &[2.0; 5]);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "overlapping TileViewMut")]
    fn overlapping_views_panic_in_debug() {
        let mut g = Grid3::zeros(4, 4, 4);
        let pg = ParGrid3::new(&mut g);
        let _a = pg.view(0, 4, 0, 2, 0, 4);
        let _b = pg.view(0, 4, 1, 3, 0, 4);
    }

    #[cfg(debug_assertions)]
    #[test]
    fn dropped_view_releases_its_claim() {
        let mut g = Grid3::zeros(2, 2, 2);
        let pg = ParGrid3::new(&mut g);
        {
            let _a = pg.full_view();
        }
        let _b = pg.full_view();
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "overlapping ParSlice claim")]
    fn overlapping_slice_claims_panic_in_debug() {
        let mut v = vec![0.0f32; 8];
        let ps = ParSlice::new(&mut v);
        let _a = ps.claim(0, 5);
        let _b = ps.claim(4, 8);
    }

    #[test]
    fn empty_views_never_overlap() {
        let mut g = Grid3::zeros(2, 2, 2);
        let pg = ParGrid3::new(&mut g);
        let _a = pg.full_view();
        let _b = pg.view(0, 0, 0, 2, 0, 2);
        let _c = pg.view(1, 1, 0, 0, 0, 0);
    }

    #[test]
    fn concurrent_disjoint_slab_writes() {
        let mut g = Grid3::zeros(4, 4, 4);
        {
            let pg = ParGrid3::new(&mut g);
            let pg = &pg;
            std::thread::scope(|s| {
                for z in 0..4 {
                    s.spawn(move || {
                        let mut v = pg.view(z, z + 1, 0, 4, 0, 4);
                        for x in 0..4 {
                            for y in 0..4 {
                                v.set(z, x, y, (z * 100 + x * 10 + y) as f32);
                            }
                        }
                    });
                }
            });
        }
        for z in 0..4 {
            for x in 0..4 {
                for y in 0..4 {
                    assert_eq!(g.get(z, x, y), (z * 100 + x * 10 + y) as f32);
                }
            }
        }
    }
}
