//! O(surface) boundary-shell enumeration.
//!
//! A periodic stencil sweep splits a grid into the wrap-free **deep
//! interior** (`[r, n-r)` per axis) and the **boundary shell** (points
//! within `r` of a face).  The seed engines found the shell by scanning
//! the *whole volume* with an `inside()` predicate — O(N³) branchy work
//! for an O(N²·r) point set.  This module enumerates the shell directly
//! as at most six disjoint slabs (four in 2D), so engines visit only
//! the points they actually recompute.
//!
//! The box set comes back in a fixed-size container ([`Boxes`]) — no
//! heap allocation, so the per-task region paths that call this every
//! step stay allocation-free.
//!
//! The same boxes drive the coordinator's dependency-ordered multirank
//! batches (`coordinator::driver`): the deep interior runs concurrently
//! with the halo exchange, and the shell waits for it.

/// Up to `N` boxes of `D` bounds each (`[lo, hi)` pairs per axis),
/// stored inline.  Iterates by value as `[usize; D]` items.
#[derive(Clone, Copy, Debug)]
pub struct Boxes<const D: usize, const N: usize> {
    boxes: [[usize; D]; N],
    len: usize,
}

impl<const D: usize, const N: usize> Boxes<D, N> {
    fn new() -> Self {
        Self { boxes: [[0; D]; N], len: 0 }
    }

    fn push(&mut self, b: [usize; D]) {
        self.boxes[self.len] = b;
        self.len += 1;
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn as_slice(&self) -> &[[usize; D]] {
        &self.boxes[..self.len]
    }

    pub fn iter(&self) -> std::slice::Iter<'_, [usize; D]> {
        self.as_slice().iter()
    }
}

impl<const D: usize, const N: usize> IntoIterator for Boxes<D, N> {
    type Item = [usize; D];
    type IntoIter = std::iter::Take<std::array::IntoIter<[usize; D], N>>;

    fn into_iter(self) -> Self::IntoIter {
        self.boxes.into_iter().take(self.len)
    }
}

/// Wrap-free deep-interior box `[r, nz-r)×[r, nx-r)×[r, ny-r)` as
/// `[z0, z1, x0, x1, y0, y1]`, if non-empty.
pub fn interior_box(nz: usize, nx: usize, ny: usize, r: usize) -> Option<[usize; 6]> {
    if nz > 2 * r && nx > 2 * r && ny > 2 * r {
        Some([r, nz - r, r, nx - r, r, ny - r])
    } else {
        None
    }
}

/// Disjoint boxes `[z0, z1, x0, x1, y0, y1]` covering the boundary
/// shell (points within `r` of a face): two z-slabs over the full
/// cross-section, two x-slabs over interior z, two y-slabs over
/// interior z and x.  Union with [`interior_box`] partitions the
/// volume; when no interior exists the boxes cover everything.
pub fn boundary_boxes(nz: usize, nx: usize, ny: usize, r: usize) -> Boxes<6, 6> {
    let zl = r.min(nz);
    let zh = nz.saturating_sub(r).max(zl);
    let xl = r.min(nx);
    let xh = nx.saturating_sub(r).max(xl);
    let yl = r.min(ny);
    let yh = ny.saturating_sub(r).max(yl);
    let mut out = Boxes::new();
    let mut push = |b: [usize; 6]| {
        if b[0] < b[1] && b[2] < b[3] && b[4] < b[5] {
            out.push(b);
        }
    };
    push([0, zl, 0, nx, 0, ny]);
    push([zh, nz, 0, nx, 0, ny]);
    push([zl, zh, 0, xl, 0, ny]);
    push([zl, zh, xh, nx, 0, ny]);
    push([zl, zh, xl, xh, 0, yl]);
    push([zl, zh, xl, xh, yh, ny]);
    out
}

/// 2D wrap-free interior `[r, nx-r)×[r, ny-r)` as `[x0, x1, y0, y1]`,
/// if non-empty.
pub fn interior_box2(nx: usize, ny: usize, r: usize) -> Option<[usize; 4]> {
    if nx > 2 * r && ny > 2 * r {
        Some([r, nx - r, r, ny - r])
    } else {
        None
    }
}

/// 2D boundary shell as at most four disjoint `[x0, x1, y0, y1]` boxes.
pub fn boundary_boxes2(nx: usize, ny: usize, r: usize) -> Boxes<4, 4> {
    let xl = r.min(nx);
    let xh = nx.saturating_sub(r).max(xl);
    let yl = r.min(ny);
    let yh = ny.saturating_sub(r).max(yl);
    let mut out = Boxes::new();
    let mut push = |b: [usize; 4]| {
        if b[0] < b[1] && b[2] < b[3] {
            out.push(b);
        }
    };
    push([0, xl, 0, ny]);
    push([xh, nx, 0, ny]);
    push([xl, xh, 0, yl]);
    push([xl, xh, yh, ny]);
    out
}

/// Wrap-free interior of a **1-D band stencil** along `axis`
/// (0 = z, 1 = x, 2 = y): the grid shrunk by `r` along that axis only,
/// full extent elsewhere.  `None` when the axis is too short (or any
/// dimension is empty) — then [`axis_boundary_boxes`] covers everything.
pub fn axis_interior_box(
    nz: usize,
    nx: usize,
    ny: usize,
    axis: usize,
    r: usize,
) -> Option<[usize; 6]> {
    assert!(axis < 3, "axis must be 0 (z), 1 (x), or 2 (y)");
    let dims = [nz, nx, ny];
    if dims[axis] <= 2 * r || dims.contains(&0) {
        return None;
    }
    let mut b = [0, nz, 0, nx, 0, ny];
    b[2 * axis] = r;
    b[2 * axis + 1] = dims[axis] - r;
    Some(b)
}

/// Boundary shell of a 1-D band stencil along `axis`: at most two slabs
/// of thickness `r` at the low and high faces of that axis, full extent
/// on the other axes.  Union with [`axis_interior_box`] partitions the
/// volume; when no interior exists the slabs cover everything.
pub fn axis_boundary_boxes(nz: usize, nx: usize, ny: usize, axis: usize, r: usize) -> Boxes<6, 2> {
    assert!(axis < 3, "axis must be 0 (z), 1 (x), or 2 (y)");
    let dims = [nz, nx, ny];
    let lo = r.min(dims[axis]);
    let hi = dims[axis].saturating_sub(r).max(lo);
    let mut out = Boxes::new();
    let mut push = |a0: usize, a1: usize| {
        let mut b = [0, nz, 0, nx, 0, ny];
        b[2 * axis] = a0;
        b[2 * axis + 1] = a1;
        if b[0] < b[1] && b[2] < b[3] && b[4] < b[5] {
            out.push(b);
        }
    };
    push(0, lo);
    push(hi, dims[axis]);
    out
}

/// Disjoint boxes covering `outer` minus `inner` — the general form of
/// [`boundary_boxes`] (which is exactly `difference_boxes` of the full
/// grid against [`interior_box`]): two z slabs over the full
/// cross-section of `outer`, two x slabs over the clipped z range, two
/// y slabs over the clipped z and x ranges, in that order.  When
/// `inner` is `None` (or does not intersect `outer`) the single box
/// `outer` comes back.  The temporal-blocking coordinator uses this to
/// enumerate the halo-dependent frame of a fused sub-step: the part of
/// the sub-step's valid trapezoid box that the pre-exchange deep
/// interior cannot cover (`coordinator::temporal`).
pub fn difference_boxes(outer: [usize; 6], inner: Option<[usize; 6]>) -> Boxes<6, 6> {
    let mut out = Boxes::new();
    let mut push = |b: [usize; 6]| {
        if b[0] < b[1] && b[2] < b[3] && b[4] < b[5] {
            out.push(b);
        }
    };
    match inner.and_then(|i| intersect(outer, i)) {
        None => push(outer),
        Some(c) => {
            push([outer[0], c[0], outer[2], outer[3], outer[4], outer[5]]);
            push([c[1], outer[1], outer[2], outer[3], outer[4], outer[5]]);
            push([c[0], c[1], outer[2], c[2], outer[4], outer[5]]);
            push([c[0], c[1], c[3], outer[3], outer[4], outer[5]]);
            push([c[0], c[1], c[2], c[3], outer[4], c[4]]);
            push([c[0], c[1], c[2], c[3], c[5], outer[5]]);
        }
    }
    out
}

/// Intersection of two `[z0, z1, x0, x1, y0, y1]` boxes, `None` if
/// empty — used to clip the shell/interior split to a claimed region.
pub fn intersect(a: [usize; 6], b: [usize; 6]) -> Option<[usize; 6]> {
    let c = [
        a[0].max(b[0]),
        a[1].min(b[1]),
        a[2].max(b[2]),
        a[3].min(b[3]),
        a[4].max(b[4]),
        a[5].min(b[5]),
    ];
    if c[0] < c[1] && c[2] < c[3] && c[4] < c[5] {
        Some(c)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn boundary_and_interior_boxes_partition_the_volume() {
        for (nz, nx, ny, r) in [
            (16, 16, 16, 4),
            (8, 8, 8, 4),
            (12, 20, 9, 2),
            (5, 5, 5, 4),
            (9, 9, 9, 0),
            (1, 7, 7, 1),
        ] {
            let mut hits = vec![0u8; nz * nx * ny];
            let mut mark = |b: [usize; 6]| {
                for z in b[0]..b[1] {
                    for x in b[2]..b[3] {
                        for y in b[4]..b[5] {
                            hits[(z * nx + x) * ny + y] += 1;
                        }
                    }
                }
            };
            if let Some(b) = interior_box(nz, nx, ny, r) {
                mark(b);
            }
            for b in boundary_boxes(nz, nx, ny, r) {
                mark(b);
            }
            assert!(
                hits.iter().all(|&h| h == 1),
                "({nz},{nx},{ny}) r={r}: boxes must cover the volume exactly once"
            );
        }
    }

    #[test]
    fn shell_point_count_is_o_surface() {
        // 32³ at r=2: shell has N³ − (N−2r)³ points, enumerated exactly
        let (n, r) = (32usize, 2usize);
        let total: usize = boundary_boxes(n, n, n, r)
            .iter()
            .map(|b| (b[1] - b[0]) * (b[3] - b[2]) * (b[5] - b[4]))
            .sum();
        assert_eq!(total, n * n * n - (n - 2 * r).pow(3));
    }

    #[test]
    fn boxes2_partition_the_plane() {
        for (nx, ny, r) in [(10, 10, 2), (5, 9, 4), (4, 4, 4), (7, 7, 0)] {
            let mut hits = vec![0u8; nx * ny];
            let mut mark = |b: [usize; 4]| {
                for x in b[0]..b[1] {
                    for y in b[2]..b[3] {
                        hits[x * ny + y] += 1;
                    }
                }
            };
            if let Some(b) = interior_box2(nx, ny, r) {
                mark(b);
            }
            for b in boundary_boxes2(nx, ny, r) {
                mark(b);
            }
            assert!(hits.iter().all(|&h| h == 1), "({nx},{ny}) r={r}");
        }
    }

    #[test]
    fn box_set_is_inline_and_sized() {
        let b = boundary_boxes(16, 16, 16, 4);
        assert_eq!(b.len(), 6);
        assert!(!b.is_empty());
        assert_eq!(b.as_slice().len(), 6);
        let none = boundary_boxes(9, 9, 9, 0);
        assert!(none.is_empty());
        assert_eq!(none.into_iter().count(), 0);
    }

    #[test]
    fn axis_boxes_partition_the_volume() {
        for (nz, nx, ny, r) in [(16, 9, 7, 4), (8, 8, 8, 4), (5, 12, 3, 2), (3, 3, 3, 4)] {
            for axis in 0..3 {
                let mut hits = vec![0u8; nz * nx * ny];
                let mut mark = |b: [usize; 6]| {
                    for z in b[0]..b[1] {
                        for x in b[2]..b[3] {
                            for y in b[4]..b[5] {
                                hits[(z * nx + x) * ny + y] += 1;
                            }
                        }
                    }
                };
                if let Some(b) = axis_interior_box(nz, nx, ny, axis, r) {
                    mark(b);
                }
                for b in axis_boundary_boxes(nz, nx, ny, axis, r) {
                    mark(b);
                }
                assert!(
                    hits.iter().all(|&h| h == 1),
                    "({nz},{nx},{ny}) axis={axis} r={r}: axis boxes must partition"
                );
            }
        }
    }

    #[test]
    fn axis_interior_shrinks_one_axis_only() {
        assert_eq!(axis_interior_box(10, 11, 12, 0, 3), Some([3, 7, 0, 11, 0, 12]));
        assert_eq!(axis_interior_box(10, 11, 12, 1, 3), Some([0, 10, 3, 8, 0, 12]));
        assert_eq!(axis_interior_box(10, 11, 12, 2, 3), Some([0, 10, 0, 11, 3, 9]));
        assert_eq!(axis_interior_box(6, 11, 12, 0, 3), None);
        assert_eq!(axis_boundary_boxes(6, 11, 12, 0, 3).len(), 2);
    }

    #[test]
    fn difference_boxes_partition_outer_minus_inner() {
        for (outer, inner) in [
            ([2usize, 14, 1, 9, 3, 12], Some([4usize, 10, 2, 7, 5, 9])),
            ([0, 8, 0, 8, 0, 8], Some([1, 7, 1, 7, 1, 7])),
            ([0, 8, 0, 8, 0, 8], Some([0, 8, 0, 8, 0, 8])), // inner == outer
            ([0, 8, 0, 8, 0, 8], None),                     // no inner
            ([0, 8, 0, 8, 0, 8], Some([10, 12, 0, 8, 0, 8])), // disjoint inner
            ([3, 6, 3, 6, 3, 6], Some([0, 12, 0, 12, 0, 12])), // inner ⊇ outer
        ] {
            let (oz, ox, oy) = (outer[1], outer[3], outer[5]);
            let mut hits = vec![0u8; oz * ox * oy];
            for b in difference_boxes(outer, inner) {
                for z in b[0]..b[1] {
                    for x in b[2]..b[3] {
                        for y in b[4]..b[5] {
                            hits[(z * ox + x) * oy + y] += 1;
                        }
                    }
                }
            }
            let clipped = inner.and_then(|i| intersect(outer, i));
            for z in 0..oz {
                for x in 0..ox {
                    for y in 0..oy {
                        let in_outer = (outer[0]..outer[1]).contains(&z)
                            && (outer[2]..outer[3]).contains(&x)
                            && (outer[4]..outer[5]).contains(&y);
                        let in_inner = clipped.is_some_and(|c| {
                            (c[0]..c[1]).contains(&z)
                                && (c[2]..c[3]).contains(&x)
                                && (c[4]..c[5]).contains(&y)
                        });
                        let want = u8::from(in_outer && !in_inner);
                        assert_eq!(
                            hits[(z * ox + x) * oy + y],
                            want,
                            "outer={outer:?} inner={inner:?} at ({z},{x},{y})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn difference_boxes_handle_degenerate_inners() {
        // The wavefront planner leans on this algebra with inner boxes
        // that touch, exceed, collapse against, or invert inside the
        // outer box.  Sweep a coordinate grid of inners — including
        // zero-thickness slabs (lo == hi) and inverted ranges
        // (lo > hi) — and require cover-exactly-once every time.
        let outer = [1usize, 5, 0, 4, 2, 6];
        let (oz, ox, oy) = (outer[1], outer[3], outer[5]);
        let cands = [0usize, 1, 3, 5, 7];
        for &z0 in &cands {
            for &z1 in &cands {
                for &x0 in &cands {
                    for &x1 in &cands {
                        for &y0 in &cands {
                            for &y1 in &cands {
                                let inner = Some([z0, z1, x0, x1, y0, y1]);
                                let clipped = inner.and_then(|i| intersect(outer, i));
                                let mut hits = vec![0u8; oz * ox * oy];
                                for b in difference_boxes(outer, inner) {
                                    for z in b[0]..b[1] {
                                        for x in b[2]..b[3] {
                                            for y in b[4]..b[5] {
                                                hits[(z * ox + x) * oy + y] += 1;
                                            }
                                        }
                                    }
                                }
                                for z in 0..oz {
                                    for x in 0..ox {
                                        for y in 0..oy {
                                            let in_outer = (outer[0]..outer[1]).contains(&z)
                                                && (outer[2]..outer[3]).contains(&x)
                                                && (outer[4]..outer[5]).contains(&y);
                                            let in_inner = clipped.is_some_and(|c| {
                                                (c[0]..c[1]).contains(&z)
                                                    && (c[2]..c[3]).contains(&x)
                                                    && (c[4]..c[5]).contains(&y)
                                            });
                                            assert_eq!(
                                                hits[(z * ox + x) * oy + y],
                                                u8::from(in_outer && !in_inner),
                                                "inner={inner:?} at ({z},{x},{y})"
                                            );
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn difference_boxes_generalize_boundary_boxes() {
        // boundary_boxes is exactly the full-grid difference against the
        // interior box — same slabs, same order
        let (nz, nx, ny, r) = (12, 9, 15, 3);
        let via_diff = difference_boxes([0, nz, 0, nx, 0, ny], interior_box(nz, nx, ny, r));
        let direct = boundary_boxes(nz, nx, ny, r);
        assert_eq!(via_diff.as_slice(), direct.as_slice());
    }

    #[test]
    fn intersect_clips_and_rejects() {
        let a = [0, 10, 0, 10, 0, 10];
        assert_eq!(intersect(a, [5, 15, 2, 4, 0, 10]), Some([5, 10, 2, 4, 0, 10]));
        assert_eq!(intersect(a, [10, 12, 0, 10, 0, 10]), None);
        assert_eq!(intersect([3, 3, 0, 1, 0, 1], a), None); // empty input
    }
}
